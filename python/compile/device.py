"""Memristor device model shared by the L1 kernel, the L2 model and the AOT
exporter.

The paper (§4, Eq 16) uses the HP titanium-dioxide model:

    R_M = R_on * w + R_off * (1 - w)

where ``w`` in [0, 1] is the normalized width of the doped layer.  A trained
weight value is interpreted as a target conductance ``G = |weight| * g_scale``
and the framework solves Eq 16 for ``w``; because ``w`` is programmed with a
finite number of pulses the achievable conductances are *quantized* to
``levels`` discrete values, and programming adds a relative gaussian error
(``prog_sigma``).  The differential pair (G+, G-) plus the inverting TIA
restores signed weights (paper §3.2, Figure 2 — the op-amp-saving inverted
convention).
"""

from dataclasses import dataclass, asdict

import numpy as np


@dataclass(frozen=True)
class DeviceParams:
    """Physical constants of the memristor / op-amp process.

    Values follow the paper's cited devices: HP memristor with
    R_on = 100 Ω, R_off = 16 kΩ (Strukov et al. 2008), input mapped to
    ±2.5 mV, low-power op-amps with a 10 V/µs slew rate and mW-level power,
    100 ps crossbar response time.
    """

    r_on: float = 100.0            # Ω, fully doped
    r_off: float = 16_000.0        # Ω, fully undoped
    levels: int = 64               # programmable conductance levels (6-bit)
    prog_sigma: float = 0.01       # relative programming error (lognormal-ish)
    v_in: float = 2.5e-3           # V, input voltage full-scale (paper §5.3)
    # TIA output rail in *normalized* units (physical swing = v_rail * v_in).
    # Sized to the trained network's observed dynamic range (max activation
    # ≈ 19 on the training distribution; rail sweep in EXPERIMENTS.md shows
    # accuracy saturates at 24) with margin — the signal-conditioning gain
    # choice every analog design makes when mapping signals onto its rails.
    v_rail: float = 24.0
    t_mem: float = 100e-12         # s, crossbar response time (paper §5.2)
    slew_rate: float = 10e6        # V/s, op-amp slew rate (10 V/µs)
    v_swing: float = 5.0           # V, op-amp output swing used for T_o
    p_opamp: float = 1.0e-3        # W per op-amp (mW level, paper §3.2)
    p_memristor: float = 1.1e-6    # W per memristor, worst case (paper §5.3)
    p_aux: float = 0.5e-3          # W, activation/multiplier aux circuit

    @property
    def g_on(self) -> float:
        return 1.0 / self.r_on

    @property
    def g_off(self) -> float:
        return 1.0 / self.r_off

    @property
    def t_opamp(self) -> float:
        """Op-amp transition time: full swing divided by slew rate."""
        return self.v_swing / self.slew_rate

    def to_dict(self) -> dict:
        d = asdict(self)
        d["g_on"] = self.g_on
        d["g_off"] = self.g_off
        d["t_opamp"] = self.t_opamp
        return d


DEFAULT_DEVICE = DeviceParams()


def doped_width(conductance: np.ndarray, dev: DeviceParams = DEFAULT_DEVICE) -> np.ndarray:
    """Invert Eq 16: find w such that 1/(R_on*w + R_off*(1-w)) == conductance.

    conductance must lie in [g_off, g_on]; values are clipped.
    """
    g = np.clip(conductance, dev.g_off, dev.g_on)
    r = 1.0 / g
    return (dev.r_off - r) / (dev.r_off - dev.r_on)


def width_to_conductance(w: np.ndarray, dev: DeviceParams = DEFAULT_DEVICE) -> np.ndarray:
    """Eq 16 forward: doped width -> conductance."""
    r = dev.r_on * w + dev.r_off * (1.0 - w)
    return 1.0 / r


def quantize_unit(x: np.ndarray, levels: int) -> np.ndarray:
    """Quantize x in [0,1] to `levels` uniform steps (0 is always a level —
    a zero weight means *no memristor is placed*, paper §3.2)."""
    if levels <= 1:
        return np.zeros_like(x)
    q = np.round(np.clip(x, 0.0, 1.0) * (levels - 1)) / (levels - 1)
    return q


def weights_to_differential(
    w: np.ndarray,
    scale: float | None = None,
    dev: DeviceParams = DEFAULT_DEVICE,
    rng: np.random.Generator | None = None,
):
    """Map a signed weight matrix to the differential crossbar pair.

    Returns (w_pos_q, w_neg_q, scale) where the *effective* reconstructed
    weight is ``(w_neg_q - w_pos_q) * scale`` following the paper's inverted
    convention: positive weights live on the inverting half (w_neg_q carries
    them) and the TIA's sign flip restores polarity with a single op-amp per
    column.

    Quantization models the finite programmable levels; optional ``rng``
    applies relative programming noise (prog_sigma).
    """
    w = np.asarray(w, dtype=np.float64)
    if scale is None:
        scale = float(np.max(np.abs(w))) or 1.0
    wn = w / scale                      # in [-1, 1]
    pos_part = np.clip(wn, 0.0, None)   # magnitude of positive weights
    neg_part = np.clip(-wn, 0.0, None)  # magnitude of negative weights
    # inverted convention: positive weights -> "negative matrix" (inverting
    # inputs), negative weights -> "positive matrix" (direct inputs).
    w_neg_q = quantize_unit(pos_part, dev.levels)
    w_pos_q = quantize_unit(neg_part, dev.levels)
    if rng is not None and dev.prog_sigma > 0:
        w_neg_q = apply_prog_noise(w_neg_q, dev, rng)
        w_pos_q = apply_prog_noise(w_pos_q, dev, rng)
    return w_pos_q.astype(np.float32), w_neg_q.astype(np.float32), float(scale)


def apply_prog_noise(wq: np.ndarray, dev: DeviceParams, rng: np.random.Generator) -> np.ndarray:
    """Relative gaussian programming error on non-zero devices only (zero
    weight == absent memristor, which is exact)."""
    noise = 1.0 + dev.prog_sigma * rng.standard_normal(wq.shape)
    out = wq * noise
    out[wq == 0.0] = 0.0
    return np.clip(out, 0.0, 1.0)


def reconstruct(w_pos_q: np.ndarray, w_neg_q: np.ndarray, scale: float) -> np.ndarray:
    """Effective signed weight realized by the differential pair."""
    return (w_neg_q.astype(np.float64) - w_pos_q.astype(np.float64)) * scale
