//! `memx::pipeline` — the trait-based analog inference API: from a trained
//! [`Manifest`](crate::nn::Manifest) + [`WeightStore`](crate::nn::WeightStore)
//! to batched crossbar logits in one composable surface.
//!
//! The paper's architecture is a chain of five memristive module types —
//! convolution, batch normalization, activation, global average pooling and
//! fully connected. This module makes that chain the unit of the public
//! API: each paper module is an [`AnalogModule`] implementation
//! ([`CrossbarModule`], [`BatchNormModule`], [`ActivationModule`],
//! [`GapModule`], plus [`SeModule`] for the squeeze-and-excite side branch),
//! and a [`PipelineBuilder`] compiles the manifest directly into a runnable
//! [`Pipeline`] — replacing the old ad-hoc `map_network → emit → parse →
//! sim` choreography.
//!
//! # Manifest → logits walkthrough
//!
//! ```no_run
//! use memx::nn::{Manifest, WeightStore};
//! use memx::pipeline::{Fidelity, PipelineBuilder};
//!
//! fn main() -> anyhow::Result<()> {
//!     let dir = std::path::Path::new("artifacts");
//!     // 1. the typed network IR: layer inventory + weight table
//!     let manifest = Manifest::load(dir)?;
//!     let weights = WeightStore::load(dir, &manifest)?;
//!     // 2. compile it: quantize weights onto devices (Eq 16), lay out the
//!     //    differential crossbars (Algorithm 1) and pick the execution
//!     //    fidelity for every stage
//!     let mut pipeline = PipelineBuilder::new()
//!         .fidelity(Fidelity::Behavioural)
//!         .build(&manifest, &weights)?;
//!     // 3. run it, batch-first: one image in channel-major planes
//!     let image = vec![0.0; pipeline.in_dim()];
//!     let logits = pipeline.forward_batch(&[image])?;
//!     println!("predicted class {}", memx::pipeline::argmax(&logits[0]));
//!     Ok(())
//! }
//! ```
//!
//! # Fidelity levels
//!
//! * [`Fidelity::Ideal`] — exact quantized-weight arithmetic: crossbars via
//!   [`Crossbar::eval_ideal`](crate::mapper::Crossbar::eval_ideal),
//!   activations via the software functions. The digital reference for the
//!   mapped network.
//! * [`Fidelity::Behavioural`] — the analog operating point the L2 JAX
//!   model uses: the same crossbar arithmetic with TIA rail saturation, and
//!   the rail-clipped activation forms.
//! * [`Fidelity::Spice`] — circuit-level: every crossbar owns a resident
//!   [`CrossbarSim`](crate::netlist::CrossbarSim) (factor-once / solve-many,
//!   batches amortized over one multi-RHS substitution per segment via
//!   [`CrossbarSim::solve_batch`](crate::netlist::CrossbarSim::solve_batch)),
//!   and hard-sigmoid / hard-swish run through their Fig 4 op-amp circuits
//!   ([`ActCircuit`](crate::analog::ActCircuit)).
//!
//! # Execution units and the pipelined scheduler
//!
//! A compiled pipeline is a sequence of [`ExecUnit`]s — the spans between
//! residual checkpoints: a manifest unit that closes with a residual adder
//! is one atomic span (its entry snapshots the batch the adder consumes),
//! and every residual-free stage is its own span. Each unit is internally
//! sequential, so skip semantics never cross a unit boundary, and units
//! are free to run on different threads as long as micro-batches traverse
//! them in order.
//!
//! [`Pipeline::forward_batch`] executes units strictly in sequence — the
//! bit-exact reference path. [`Pipeline::forward_batch_pipelined`] is the
//! paper's §5.2 pipelined operating point: the batch is split into
//! micro-batches, the units are partitioned into contiguous groups (one per
//! worker, balanced by device weight), and groups are chained through
//! [`pool::pipeline_stream`](crate::util::pool::pipeline_stream) — bounded
//! rendezvous channels (capacity 1 — a double-buffered hand-off: each group
//! works on micro-batch k while micro-batch k+1 waits in its mailbox). So
//! stage N of micro-batch k overlaps stage N+1 of micro-batch k−1.
//! Sharding is only ever across images (micro-batches) and across
//! independent module leaves (conv channel banks inside a stage, via the
//! module's own worker pool — [`AnalogModule::shardable_leaves`] counts
//! them), never inside one analog accumulation, so per-image results are
//! bit-identical to the sequential path; the `forward_batch == forward`
//! proptests are the oracle.
//!
//! The scheduler records per-unit wall time ([`Pipeline::take_stage_stats`])
//! which the serving tier folds into its metrics snapshot.
//!
//! Data layout between modules: spatial tensors travel as channel-major
//! planes `[c][h*w]` (row-major within a plane); vectors are plain `[c]`.
//! [`image_to_input`] converts the dataset's HWC images.

pub mod builder;
pub mod modules;

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::util::pool;

pub use builder::{default_device, demo_network, synthetic_stack_crossbars, PipelineBuilder};
pub use modules::{
    ActivationModule, BatchNormModule, CrossbarModule, GapModule, ModuleCfg, SeModule,
};
/// Re-exported for builder callers: the SPICE engine's direct-vs-GMRES
/// selection ([`PipelineBuilder::solver`]).
pub use crate::backend::BackendChoice;
pub use crate::spice::krylov::SolverStrategy;

/// Execution fidelity of a compiled [`Pipeline`] (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// exact quantized-weight arithmetic, software activations
    Ideal,
    /// rail-clipped analog behavioural models (the L2 operating point)
    Behavioural,
    /// resident SPICE simulators per crossbar + Fig 4 activation circuits
    Spice,
}

impl std::str::FromStr for Fidelity {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Fidelity> {
        match s {
            "ideal" => Ok(Fidelity::Ideal),
            "behavioural" | "behavioral" => Ok(Fidelity::Behavioural),
            "spice" => Ok(Fidelity::Spice),
            other => bail!("unknown fidelity '{other}' (ideal|behavioural|spice)"),
        }
    }
}

impl std::fmt::Display for Fidelity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Fidelity::Ideal => "ideal",
            Fidelity::Behavioural => "behavioural",
            Fidelity::Spice => "spice",
        })
    }
}

/// One analog stage of the paper's module chain. Implementations own their
/// device state (crossbars, resident simulators, activation circuits) and
/// answer whole batches per call — the batch-first contract the serving
/// tier scales on. `Send` is part of the contract: module state is owned
/// device state (no shared interior aliasing), so a compiled [`Pipeline`]
/// can move between threads and its units can be distributed over the
/// pipelined scheduler's workers.
pub trait AnalogModule: Send {
    /// Layer name (manifest name or a synthetic label).
    fn name(&self) -> &str;

    /// Table 4 kind label ("Conv", "BN", "HSwish", "GAPool", "FC", ...).
    fn kind(&self) -> &'static str;

    /// Input vector length this module expects.
    fn in_dim(&self) -> usize;

    /// Output vector length this module produces.
    fn out_dim(&self) -> usize;

    /// Forward a batch of input vectors (each of length [`Self::in_dim`]).
    /// At [`Fidelity::Spice`] this is where the multi-RHS batch
    /// amortization happens — one factorization, one substitution pass per
    /// crossbar segment for the whole batch.
    fn forward_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>>;

    /// Single-vector convenience — `forward_batch` of a batch of one.
    fn forward(&mut self, x: &[f64]) -> Result<Vec<f64>> {
        let batch = [x.to_vec()];
        let mut out = self.forward_batch(&batch)?;
        out.pop().ok_or_else(|| anyhow::anyhow!("module returned an empty batch"))
    }

    /// Physically placed memristors (resource hook; Table 4 column).
    fn memristors(&self) -> usize {
        0
    }

    /// Op-amps (resource hook; Table 4 column).
    fn opamps(&self) -> usize {
        0
    }

    /// Memristor-crossbar stages this module contributes to the critical
    /// path (Eq 17 N_m). Composite modules may contribute several.
    fn memristor_stages(&self) -> usize {
        0
    }

    /// Independently schedulable sub-executions inside this module — the
    /// per-channel-pair conv banks, or the crossbars of the SE side branch.
    /// Each leaf is one complete analog accumulation, so the module's own
    /// worker pool may shard leaves within a stage (conv banks do, see
    /// `ConvBanks::forward_spice`) without ever splitting a dot product;
    /// the count is surfaced through [`Pipeline::shardable_leaves`] for
    /// balancing and resource reports. 1 = the module is atomic.
    fn shardable_leaves(&self) -> usize {
        1
    }

    /// Resident simulated circuits backing this module at
    /// [`Fidelity::Spice`] — crossbar netlist simulators, Fig 4 op-amp
    /// circuits. 0 means the module answers from its exact/behavioural
    /// transfer; at spice fidelity that is a conformance hole unless the
    /// module is CMOS by design (ReLU) — the fidelity suite
    /// (`rust/tests/fidelity.rs`) pins exactly this.
    fn spice_circuits(&self) -> usize {
        0
    }

    /// Structured interchange decks for every resident simulated circuit
    /// of this module ([`crate::netlist::interchange::Deck`]) — one deck
    /// per crossbar segment / activation cell, at the current operating
    /// point. Empty when the module holds no resident circuits (exact or
    /// behavioural fidelity, or CMOS-by-design modules). `memx validate`
    /// sweeps these through the emit → parse → simulate round-trip and the
    /// differential reference checks; the count matches
    /// [`AnalogModule::spice_circuits`] at [`Fidelity::Spice`].
    fn spice_decks(&self) -> Vec<crate::netlist::interchange::Deck> {
        Vec::new()
    }

    /// Auxiliary CMOS processing elements of this module — the per-element
    /// activation circuit instances (and, for the SE branch, its squeezed
    /// activations plus the per-channel trunk multipliers). Feeds the
    /// `p_aux` term of the stage-hook energy model
    /// ([`crate::power::energy_coverage`]); crossbar/BN/GAP stages have
    /// none (their op-amps are counted separately).
    fn cmos_elements(&self) -> usize {
        0
    }

    /// Evolve this module's resident device state by one lifetime
    /// [`FaultStep`](crate::fault::FaultStep) — log-time drift, read
    /// disturb, stuck-at cells — **in place**: placed conductances are
    /// decayed and, at [`Fidelity::Spice`], pushed into the resident
    /// simulators as value-only netlist edits
    /// ([`CrossbarSim::update_conductances`](crate::netlist::CrossbarSim::update_conductances)),
    /// so the cached symbolic factorization carries across every update.
    /// Default: no device state, nothing to do.
    fn inject_faults(&mut self, _step: &crate::fault::FaultStep) {}

    /// Recalibration write pass: restore pristine conductances, draw fresh
    /// programming noise (`prog_sigma`, seeded per `(seed, generation)`)
    /// and re-apply the immutable stuck-at mask of the last injected step —
    /// reprogramming heals drift, not dead cells. Returns the number of
    /// devices rewritten (0 for stateless modules).
    fn reprogram(&mut self, _prog_sigma: f64, _seed: u64, _generation: u64) -> usize {
        0
    }

    /// Lifetime telemetry snapshot: how far this module's devices have
    /// drifted since their last write, and how often they have been
    /// rewritten. `None` for modules with no fault-capable device state
    /// (activations, residual adders) — the serving watchdog only tables
    /// the modules that age. Cheap; called per metrics snapshot.
    fn drift_stats(&self) -> Option<ModuleDrift> {
        None
    }
}

/// Per-module lifetime telemetry record ([`AnalogModule::drift_stats`],
/// aggregated by [`Pipeline::drift_telemetry`] and printed in the serving
/// `Snapshot` table).
#[derive(Debug, Clone)]
pub struct ModuleDrift {
    pub name: String,
    pub kind: &'static str,
    /// Cumulative mean multiplicative conductance factor since the last
    /// (re)programming — 1.0 pristine, decaying toward 0 as the module
    /// ages. The product of each absorbed step's mean applied factor.
    pub drift_gain: f64,
    /// Fault steps absorbed since the last (re)programming.
    pub fault_steps: u64,
    /// Recalibration writes over this module's lifetime.
    pub reprograms: u64,
    /// Devices rewritten by the most recent reprogram (0 if never).
    pub devices_rewritten: usize,
}

/// One stage of a compiled [`Pipeline`].
pub enum Stage {
    /// A paper module, tagged with the manifest unit it belongs to.
    Module { unit: String, module: Box<dyn AnalogModule> },
    /// The residual summing amplifier closing a bottleneck unit: adds the
    /// vector that entered the unit (MobileNetV3 skip semantics — stride 1,
    /// matching channels). `dim` is the full vector length; `channels`
    /// counts the per-channel summing amplifiers (the mapper's "Add" row).
    Residual { name: String, unit: String, dim: usize, channels: usize },
}

impl Stage {
    fn unit(&self) -> &str {
        match self {
            Stage::Module { unit, .. } | Stage::Residual { unit, .. } => unit,
        }
    }
}

/// Per-stage fidelity/resource record ([`Pipeline::stage_coverage`]): the
/// module's kind and dims, its resource hooks (netlist-derived at
/// [`Fidelity::Spice`], closed-form otherwise) and its resident
/// simulated-circuit count. Residual adders appear as kind `"Add"` with no
/// circuits (the summing amplifier is evaluated exactly).
#[derive(Debug, Clone)]
pub struct StageCoverage {
    pub unit: String,
    pub name: String,
    pub kind: &'static str,
    pub in_dim: usize,
    pub out_dim: usize,
    pub memristors: usize,
    pub opamps: usize,
    pub memristor_stages: usize,
    pub spice_circuits: usize,
    /// auxiliary CMOS processing elements (activation circuit instances,
    /// SE channel multipliers; residual adders count one per channel)
    pub cmos_elements: usize,
}

impl StageCoverage {
    /// Is this stage allowed to answer its exact transfer at
    /// [`Fidelity::Spice`]? Only the CMOS ReLU (the paper realizes it
    /// without op-amps) and the residual summing amplifiers are — the
    /// single source of the exemption policy shared by `report --coverage`
    /// and the conformance suite (`rust/tests/fidelity.rs`).
    pub fn spice_exempt(&self) -> bool {
        matches!(self.kind, "ReLU" | "Add")
    }
}

/// Wall-time accounting for one execution unit, as recorded by the
/// schedulers ([`Pipeline::take_stage_stats`]).
#[derive(Debug, Clone)]
pub struct StageStat {
    /// unit name (manifest unit, e.g. "bneck3")
    pub name: String,
    /// total wall time spent inside the unit
    pub total: Duration,
    /// forward calls accumulated into `total` (one per micro-batch)
    pub calls: u64,
}

/// One schedulable span of a compiled [`Pipeline`]: either the contiguous
/// stages of a residual-closing manifest unit (checkpoint included — skip
/// semantics never cross a unit boundary) or a single residual-free stage.
/// Units are internally sequential; the pipelined scheduler distributes
/// whole units across worker threads.
pub struct ExecUnit {
    name: String,
    stages: Vec<Stage>,
    /// snapshot the entering batch — set when the unit closes a residual
    checkpoint: bool,
    /// accumulated wall time / calls (scheduler-recorded)
    ns: u64,
    calls: u64,
}

impl ExecUnit {
    /// Manifest unit name this span executes.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Stages inside this unit.
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Does this unit end in a residual summing amplifier (and therefore
    /// checkpoint its input)?
    pub fn closes_residual(&self) -> bool {
        self.checkpoint
    }

    /// Independently schedulable module leaves in this unit (conv banks,
    /// SE branch crossbars; residual adders count 1).
    pub fn shardable_leaves(&self) -> usize {
        self.stages
            .iter()
            .map(|s| match s {
                Stage::Module { module, .. } => module.shardable_leaves(),
                Stage::Residual { .. } => 1,
            })
            .sum()
    }

    /// Scheduling weight for partitioning units across workers: placed
    /// devices dominate crossbar cost, vector length dominates the
    /// per-element activation circuits.
    fn weight(&self) -> u64 {
        self.stages
            .iter()
            .map(|s| match s {
                Stage::Module { module, .. } => {
                    (module.memristors().max(module.in_dim()) as u64).max(1)
                }
                Stage::Residual { dim, .. } => *dim as u64,
            })
            .sum::<u64>()
            .max(1)
    }

    /// Run the whole batch through this unit's stages (checkpoint + modules
    /// + residual add). Exactly the per-unit slice of the sequential path.
    fn forward_batch(&mut self, batch: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        // the span is the per-call trace view; self.ns/self.calls stay the
        // aggregated StageStat view of the same interval
        let _sp = crate::telemetry::span_owned(&self.name, "pipeline")
            .arg("batch", batch.len() as f64);
        let t0 = Instant::now();
        let unit_input: Vec<Vec<f64>> = if self.checkpoint { batch.clone() } else { Vec::new() };
        let mut cur = batch;
        for stage in self.stages.iter_mut() {
            match stage {
                Stage::Module { module, .. } => {
                    let _msp = crate::telemetry::span_owned(module.name(), "module");
                    cur = module.forward_batch(&cur)?;
                }
                Stage::Residual { name, dim, .. } => {
                    if unit_input.len() != cur.len() {
                        bail!(
                            "residual '{name}': {} checkpointed inputs for a batch of {}",
                            unit_input.len(),
                            cur.len()
                        );
                    }
                    for (y, x0) in cur.iter_mut().zip(&unit_input) {
                        if y.len() != *dim || x0.len() != *dim {
                            bail!(
                                "residual '{name}': {} outputs vs {} unit inputs (expected {dim})",
                                y.len(),
                                x0.len()
                            );
                        }
                        for (a, b) in y.iter_mut().zip(x0) {
                            *a += b;
                        }
                    }
                }
            }
        }
        self.ns += t0.elapsed().as_nanos() as u64;
        self.calls += 1;
        Ok(cur)
    }
}

/// A runnable analog network: the paper's module chain compiled by
/// [`PipelineBuilder`] into [`ExecUnit`]s, with end-to-end
/// [`Pipeline::forward_batch`] (sequential reference) and
/// [`Pipeline::forward_batch_pipelined`] (§5.2 overlapped schedule).
pub struct Pipeline {
    units: Vec<ExecUnit>,
    fidelity: Fidelity,
    in_dim: usize,
    out_dim: usize,
}

impl Pipeline {
    /// Assemble a pipeline from explicit stages, validating that every
    /// module's input length matches its predecessor's output, then
    /// grouping the flat stage list into [`ExecUnit`]s (one per contiguous
    /// run of a manifest unit name).
    pub fn from_stages(stages: Vec<Stage>, fidelity: Fidelity) -> Result<Pipeline> {
        let mut dims: Option<(usize, usize)> = None; // (in, current)
        for s in &stages {
            match s {
                Stage::Module { module, .. } => {
                    let (input, cur) = match dims {
                        None => (module.in_dim(), module.in_dim()),
                        Some(d) => d,
                    };
                    if module.in_dim() != cur {
                        bail!(
                            "stage '{}' ({}) expects {} inputs, previous stage produces {}",
                            module.name(),
                            module.kind(),
                            module.in_dim(),
                            cur
                        );
                    }
                    dims = Some((input, module.out_dim()));
                }
                Stage::Residual { name, dim, .. } => {
                    let Some((input, cur)) = dims else {
                        bail!("residual '{name}' cannot be the first stage");
                    };
                    if *dim != cur {
                        bail!("residual '{name}' expects {dim} inputs, previous stage produces {cur}");
                    }
                    dims = Some((input, cur));
                }
            }
        }
        let Some((in_dim, out_dim)) = dims else {
            bail!("pipeline needs at least one module");
        };
        // group into execution units — the spans between residual
        // checkpoints: a contiguous same-unit span containing a residual is
        // atomic (its entry is the checkpoint the adder consumes, exactly
        // the first-stage-of-span snapshot the old flat walk marked), while
        // stages of residual-free spans each become their own unit so the
        // scheduler gets the finest safe granularity
        let mut runs: Vec<(usize, bool)> = Vec::new(); // (span length, has residual)
        let mut idx = 0;
        while idx < stages.len() {
            let unit = stages[idx].unit().to_string();
            let mut j = idx;
            let mut has_res = false;
            while j < stages.len() && stages[j].unit() == unit {
                has_res |= matches!(stages[j], Stage::Residual { .. });
                j += 1;
            }
            runs.push((j - idx, has_res));
            idx = j;
        }
        let mut units: Vec<ExecUnit> = Vec::new();
        let mut iter = stages.into_iter();
        for (len, has_res) in runs {
            if has_res {
                let span: Vec<Stage> = iter.by_ref().take(len).collect();
                units.push(ExecUnit {
                    name: span[0].unit().to_string(),
                    stages: span,
                    checkpoint: true,
                    ns: 0,
                    calls: 0,
                });
            } else {
                for stage in iter.by_ref().take(len) {
                    let name = match &stage {
                        Stage::Module { module, .. } => module.name().to_string(),
                        Stage::Residual { name, .. } => name.clone(),
                    };
                    units.push(ExecUnit {
                        name,
                        stages: vec![stage],
                        checkpoint: false,
                        ns: 0,
                        calls: 0,
                    });
                }
            }
        }
        Ok(Pipeline { units, fidelity, in_dim, out_dim })
    }

    /// Assemble a single-unit pipeline from bare modules.
    pub fn from_modules(
        modules: Vec<Box<dyn AnalogModule>>,
        fidelity: Fidelity,
    ) -> Result<Pipeline> {
        let stages = modules
            .into_iter()
            .map(|module| Stage::Module { unit: "main".into(), module })
            .collect();
        Self::from_stages(stages, fidelity)
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    pub fn fidelity(&self) -> Fidelity {
        self.fidelity
    }

    pub fn n_stages(&self) -> usize {
        self.units.iter().map(|u| u.stages.len()).sum()
    }

    /// Schedulable execution units (spans between residual checkpoints).
    pub fn n_units(&self) -> usize {
        self.units.len()
    }

    /// The compiled execution units, in chain order.
    pub fn units(&self) -> &[ExecUnit] {
        &self.units
    }

    fn stages(&self) -> impl Iterator<Item = &Stage> {
        self.units.iter().flat_map(|u| u.stages.iter())
    }

    /// Total placed memristors across all stages (Table 4 bottom row).
    pub fn memristors(&self) -> usize {
        self.stages()
            .map(|s| match s {
                Stage::Module { module, .. } => module.memristors(),
                Stage::Residual { .. } => 0,
            })
            .sum()
    }

    /// Total op-amps across all stages (residual adders count one summing
    /// amplifier per channel, as in the mapper).
    pub fn opamps(&self) -> usize {
        self.stages()
            .map(|s| match s {
                Stage::Module { module, .. } => module.opamps(),
                Stage::Residual { channels, .. } => *channels,
            })
            .sum()
    }

    /// Memristor-crossbar stages on the critical path (Eq 17 N_m).
    pub fn memristor_stages(&self) -> usize {
        self.stages()
            .map(|s| match s {
                Stage::Module { module, .. } => module.memristor_stages(),
                Stage::Residual { .. } => 0,
            })
            .sum()
    }

    /// Total independently schedulable module leaves across all units
    /// (conv banks, SE branch crossbars — the intra-stage sharding width
    /// available to module worker pools).
    pub fn shardable_leaves(&self) -> usize {
        self.units.iter().map(|u| u.shardable_leaves()).sum()
    }

    /// Total resident simulated circuits across all stages — non-zero only
    /// at [`Fidelity::Spice`], where every module except the CMOS ReLU and
    /// the residual summing amplifiers holds its emitted netlist
    /// ([`AnalogModule::spice_circuits`]).
    pub fn spice_circuits(&self) -> usize {
        self.stages()
            .map(|s| match s {
                Stage::Module { module, .. } => module.spice_circuits(),
                Stage::Residual { .. } => 0,
            })
            .sum()
    }

    /// Structured interchange decks for every resident simulated circuit
    /// in the pipeline, in chain order ([`AnalogModule::spice_decks`]).
    /// Non-empty only at [`Fidelity::Spice`]; residual adders contribute
    /// nothing here (their summing-amplifier netlist is emitted offline by
    /// [`crate::netlist::emit_layer_netlists`]). This is the corpus
    /// `memx validate` sweeps.
    pub fn spice_decks(&self) -> Vec<crate::netlist::interchange::Deck> {
        self.stages()
            .flat_map(|s| match s {
                Stage::Module { module, .. } => module.spice_decks(),
                Stage::Residual { .. } => Vec::new(),
            })
            .collect()
    }

    /// Per-stage fidelity/resource coverage, in chain order — the record
    /// the conformance suite, `report --coverage` and the stage-hook power
    /// model ([`crate::power::latency_coverage`]) consume. At
    /// [`Fidelity::Spice`] the counts come from the emitted netlists
    /// (see the fidelity coverage matrix in [`modules`]).
    pub fn stage_coverage(&self) -> Vec<StageCoverage> {
        self.units
            .iter()
            .flat_map(|u| u.stages.iter())
            .map(|s| match s {
                Stage::Module { unit, module } => StageCoverage {
                    unit: unit.clone(),
                    name: module.name().to_string(),
                    kind: module.kind(),
                    in_dim: module.in_dim(),
                    out_dim: module.out_dim(),
                    memristors: module.memristors(),
                    opamps: module.opamps(),
                    memristor_stages: module.memristor_stages(),
                    spice_circuits: module.spice_circuits(),
                    cmos_elements: module.cmos_elements(),
                },
                Stage::Residual { name, unit, dim, channels } => StageCoverage {
                    unit: unit.clone(),
                    name: name.clone(),
                    kind: "Add",
                    in_dim: *dim,
                    out_dim: *dim,
                    memristors: 0,
                    opamps: *channels,
                    memristor_stages: 0,
                    spice_circuits: 0,
                    cmos_elements: *channels,
                },
            })
            .collect()
    }

    /// One-line summary for logs and demos.
    pub fn describe(&self) -> String {
        format!(
            "{} stages in {} units ({} leaves, {} fidelity), {} -> {} dims, {} memristors / {} op-amps / N_m {}",
            self.n_stages(),
            self.n_units(),
            self.shardable_leaves(),
            self.fidelity,
            self.in_dim,
            self.out_dim,
            self.memristors(),
            self.opamps(),
            self.memristor_stages()
        )
    }

    fn check_inputs(&self, inputs: &[Vec<f64>]) -> Result<()> {
        for (k, x) in inputs.iter().enumerate() {
            if x.len() != self.in_dim {
                bail!("input {k} has {} values, pipeline expects {}", x.len(), self.in_dim);
            }
        }
        Ok(())
    }

    /// End-to-end batched inference, units strictly in sequence: every
    /// stage answers the whole batch before the next begins, so each
    /// crossbar read is one multi-RHS substitution pass per segment at
    /// [`Fidelity::Spice`]. This is the bit-exact reference the pipelined
    /// schedule is checked against.
    pub fn forward_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        self.check_inputs(inputs)?;
        let mut cur: Vec<Vec<f64>> = inputs.to_vec();
        for unit in self.units.iter_mut() {
            cur = unit.forward_batch(cur)?;
        }
        Ok(cur)
    }

    /// The §5.2 pipelined operating point: split `inputs` into micro-batches
    /// of `micro_batch` images (0 = auto), partition the units into up to
    /// `workers` contiguous groups, and stream micro-batches through the
    /// group chain over capacity-1 rendezvous channels (double-buffered
    /// hand-off) so consecutive micro-batches occupy different unit groups
    /// concurrently.
    ///
    /// Per-image results are bit-identical to [`Pipeline::forward_batch`]:
    /// micro-batching only re-slices the batch dimension, and every module
    /// evaluates each image independently (crossbar multi-RHS solves are
    /// per-column, activation circuits per-element). Falls back to the
    /// sequential path when there is nothing to overlap (one worker, one
    /// unit, or a single micro-batch).
    pub fn forward_batch_pipelined(
        &mut self,
        inputs: &[Vec<f64>],
        workers: usize,
        micro_batch: usize,
    ) -> Result<Vec<Vec<f64>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        self.check_inputs(inputs)?;
        let n_groups = workers.min(self.units.len()).max(1);
        let micro = if micro_batch == 0 {
            // enough micro-batches to fill the pipe twice over
            inputs.len().div_ceil(2 * n_groups).max(1)
        } else {
            micro_batch
        };
        if n_groups <= 1 || inputs.len() <= micro {
            return self.forward_batch(inputs);
        }

        // contiguous unit groups balanced by device weight
        let weights: Vec<u64> = self.units.iter().map(|u| u.weight()).collect();
        let sizes = partition_sizes(&weights, n_groups);
        let mut groups: Vec<&mut [ExecUnit]> = Vec::with_capacity(sizes.len());
        let mut rest: &mut [ExecUnit] = &mut self.units;
        for &sz in &sizes {
            let (head, tail) = rest.split_at_mut(sz);
            groups.push(head);
            rest = tail;
        }

        // stream the micro-batches through the group chain (capacity-1
        // double-buffered hand-off per boundary — see pool::pipeline_stream)
        let micro_batches: Vec<Vec<Vec<f64>>> =
            inputs.chunks(micro).map(|c| c.to_vec()).collect();
        let solved = pool::pipeline_stream(groups, micro_batches, |group, batch| {
            run_units(group, batch)
        })?;
        let mut out: Vec<Vec<f64>> = Vec::with_capacity(inputs.len());
        for rows in solved {
            out.extend(rows);
        }
        if out.len() != inputs.len() {
            bail!("pipelined scheduler produced {} rows for {} inputs", out.len(), inputs.len());
        }
        Ok(out)
    }

    /// Per-unit wall-time accounting accumulated by both schedulers since
    /// the last [`Pipeline::take_stage_stats`] call.
    pub fn stage_stats(&self) -> Vec<StageStat> {
        self.units
            .iter()
            .map(|u| StageStat {
                name: u.name.clone(),
                total: Duration::from_nanos(u.ns),
                calls: u.calls,
            })
            .collect()
    }

    /// Drain the per-unit wall-time counters (returns the snapshot and
    /// resets the accumulators — the serving tier calls this per batch).
    pub fn take_stage_stats(&mut self) -> Vec<StageStat> {
        let stats = self.stage_stats();
        for u in self.units.iter_mut() {
            u.ns = 0;
            u.calls = 0;
        }
        stats
    }

    /// Push one lifetime [`FaultStep`](crate::fault::FaultStep) through
    /// every module of the chain (see
    /// [`AnalogModule::inject_faults`]) — the serving tier calls this per
    /// batch to age the resident crossbars in place.
    pub fn inject_faults(&mut self, step: &crate::fault::FaultStep) {
        if step.is_noop() {
            return;
        }
        for unit in self.units.iter_mut() {
            for stage in unit.stages.iter_mut() {
                if let Stage::Module { module, .. } = stage {
                    module.inject_faults(step);
                }
            }
        }
    }

    /// Recalibration pass over every module (see
    /// [`AnalogModule::reprogram`]): pristine restore + fresh programming
    /// noise + stuck-mask re-application, all as value-only updates.
    /// Returns the total number of devices rewritten.
    pub fn reprogram(&mut self, prog_sigma: f64, seed: u64, generation: u64) -> usize {
        let mut rewritten = 0;
        for unit in self.units.iter_mut() {
            for stage in unit.stages.iter_mut() {
                if let Stage::Module { module, .. } = stage {
                    rewritten += module.reprogram(prog_sigma, seed, generation);
                }
            }
        }
        rewritten
    }

    /// Per-module drift telemetry, in chain order — one record per module
    /// holding fault-capable device state (see
    /// [`AnalogModule::drift_stats`]). The serving tier folds this into
    /// its metrics snapshot so the watchdog sees *where* damage
    /// accumulates, not just the global logit margins.
    pub fn drift_telemetry(&self) -> Vec<ModuleDrift> {
        self.stages()
            .filter_map(|s| match s {
                Stage::Module { module, .. } => module.drift_stats(),
                Stage::Residual { .. } => None,
            })
            .collect()
    }

    /// Single-vector forward — a batch of one.
    pub fn forward(&mut self, x: &[f64]) -> Result<Vec<f64>> {
        let batch = [x.to_vec()];
        let mut out = self.forward_batch(&batch)?;
        out.pop().ok_or_else(|| anyhow::anyhow!("pipeline returned an empty batch"))
    }

    /// Batched classification: forward then per-row argmax.
    pub fn classify_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<usize>> {
        Ok(self.forward_batch(inputs)?.iter().map(|row| argmax(row)).collect())
    }
}

/// Drive one micro-batch through a contiguous group of units.
fn run_units(units: &mut [ExecUnit], batch: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
    let mut cur = batch;
    for u in units.iter_mut() {
        cur = u.forward_batch(cur)?;
    }
    Ok(cur)
}

/// Contiguous partition of `weights.len()` items into up to `groups`
/// non-empty runs with roughly equal weight. Returns the run lengths
/// (summing to `weights.len()`).
fn partition_sizes(weights: &[u64], groups: usize) -> Vec<usize> {
    let n = weights.len();
    let groups = groups.min(n).max(1);
    let total: u64 = weights.iter().sum::<u64>().max(1);
    let mut sizes = Vec::with_capacity(groups);
    let mut acc = 0u64; // prefix weight over all closed groups + the open one
    let mut in_group = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        in_group += 1;
        let open_after = groups - sizes.len() - 1; // groups still to open
        let remaining_units = n - i - 1;
        // close at the ideal prefix boundary, or when the tail must be
        // reserved one-unit-per-remaining-group
        let boundary = total * (sizes.len() as u64 + 1) / groups as u64;
        if open_after > 0 && (acc >= boundary || remaining_units == open_after) {
            sizes.push(in_group);
            in_group = 0;
        }
    }
    if in_group > 0 {
        sizes.push(in_group);
    }
    sizes
}

/// Index of the largest logit (0 for an empty slice).
pub fn argmax(v: &[f64]) -> usize {
    let mut best = (0usize, f64::NEG_INFINITY);
    for (i, &x) in v.iter().enumerate() {
        if x > best.1 {
            best = (i, x);
        }
    }
    best.0
}

/// Convert one dataset image (HWC row-major, the PJRT/NHWC layout) into the
/// pipeline's channel-major planes `[c][h*w]`.
pub fn image_to_input(img: &[f32], h: usize, w: usize, c: usize) -> Vec<f64> {
    assert_eq!(img.len(), h * w * c, "image length != h*w*c");
    let mut v = vec![0.0; h * w * c];
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                v[ch * h * w + y * w + x] = img[(y * w + x) * c + ch] as f64;
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fidelity_fromstr_display_roundtrip() {
        for f in [Fidelity::Ideal, Fidelity::Behavioural, Fidelity::Spice] {
            let parsed: Fidelity = f.to_string().parse().unwrap();
            assert_eq!(parsed, f);
        }
        assert_eq!("behavioral".parse::<Fidelity>().unwrap(), Fidelity::Behavioural);
        assert!("fast".parse::<Fidelity>().is_err());
    }

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[-5.0, -1.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn image_to_input_channel_major() {
        // 1x2 image, 2 channels: HWC [p0c0, p0c1, p1c0, p1c1]
        let img = [1.0f32, 10.0, 2.0, 20.0];
        let v = image_to_input(&img, 1, 2, 2);
        assert_eq!(v, vec![1.0, 2.0, 10.0, 20.0]);
    }

    #[test]
    fn empty_pipeline_rejected() {
        assert!(Pipeline::from_modules(Vec::new(), Fidelity::Ideal).is_err());
    }

    #[test]
    fn partition_sizes_cover_and_respect_groups() {
        assert_eq!(partition_sizes(&[1, 1, 1, 1], 2), vec![2, 2]);
        assert_eq!(partition_sizes(&[1], 4), vec![1]);
        // heavy head: first group closes early, tail split by reservation
        let s = partition_sizes(&[100, 1, 1, 1], 3);
        assert_eq!(s.iter().sum::<usize>(), 4);
        assert_eq!(s.len(), 3);
        assert!(s.iter().all(|&x| x > 0));
        // every unit lands in exactly one group for awkward weights too
        let w = [3u64, 9, 2, 2, 8, 1, 5];
        for g in 1..=8 {
            let s = partition_sizes(&w, g);
            assert_eq!(s.iter().sum::<usize>(), w.len(), "groups {g}");
            assert!(s.len() <= g.min(w.len()), "groups {g}");
            assert!(s.iter().all(|&x| x > 0), "groups {g}");
        }
    }

    /// A unit-less synthetic module for scheduler tests: affine y = a*x + b
    /// per element, arbitrary dims.
    struct TestAffine {
        name: String,
        unit_dim: (usize, usize),
        a: f64,
        b: f64,
    }

    impl AnalogModule for TestAffine {
        fn name(&self) -> &str {
            &self.name
        }

        fn kind(&self) -> &'static str {
            "Test"
        }

        fn in_dim(&self) -> usize {
            self.unit_dim.0
        }

        fn out_dim(&self) -> usize {
            self.unit_dim.1
        }

        fn forward_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
            Ok(inputs
                .iter()
                .map(|x| {
                    (0..self.unit_dim.1)
                        .map(|i| self.a * x[i % self.unit_dim.0] + self.b)
                        .collect()
                })
                .collect())
        }
    }

    fn affine(unit: &str, name: &str, din: usize, dout: usize, a: f64, b: f64) -> Stage {
        Stage::Module {
            unit: unit.into(),
            module: Box::new(TestAffine {
                name: name.into(),
                unit_dim: (din, dout),
                a,
                b,
            }),
        }
    }

    fn residual(unit: &str, dim: usize) -> Stage {
        Stage::Residual { name: format!("{unit}.add"), unit: unit.into(), dim, channels: dim }
    }

    fn test_pipeline() -> Pipeline {
        // u0: 4 -> 4 with residual, u1: plain 4 -> 6, u2: 6 -> 6 residual
        let stages = vec![
            affine("u0", "m0", 4, 4, 1.25, 0.5),
            affine("u0", "m1", 4, 4, -0.75, 0.25),
            residual("u0", 4),
            affine("u1", "m2", 4, 6, 0.5, -1.0),
            affine("u2", "m3", 6, 6, 2.0, 0.125),
            residual("u2", 6),
        ];
        Pipeline::from_stages(stages, Fidelity::Ideal).unwrap()
    }

    #[test]
    fn stages_group_into_units_with_checkpoints() {
        let p = test_pipeline();
        assert_eq!(p.n_units(), 3);
        assert_eq!(p.n_stages(), 6);
        let flags: Vec<bool> = p.units().iter().map(|u| u.closes_residual()).collect();
        assert_eq!(flags, vec![true, false, true]);
        assert_eq!(p.units()[0].name(), "u0");
        assert_eq!(p.units()[0].n_stages(), 3);
        // residual-free spans split into single-stage units, named after
        // the module for the stage-time table
        assert_eq!(p.units()[1].name(), "m2");
        assert_eq!(p.units()[1].n_stages(), 1);
    }

    #[test]
    fn pipelined_matches_sequential_exactly() {
        let inputs: Vec<Vec<f64>> = (0..7)
            .map(|k| (0..4).map(|i| (k * 4 + i) as f64 * 0.17 - 1.3).collect())
            .collect();
        let mut seq = test_pipeline();
        let want = seq.forward_batch(&inputs).unwrap();
        for workers in [2, 3, 8] {
            for micro in [1, 2, 3] {
                let mut p = test_pipeline();
                let got = p.forward_batch_pipelined(&inputs, workers, micro).unwrap();
                assert_eq!(got, want, "workers {workers} micro {micro}");
            }
        }
        // auto micro-batch and degenerate workers fall back cleanly
        let mut p = test_pipeline();
        assert_eq!(p.forward_batch_pipelined(&inputs, 4, 0).unwrap(), want);
        let mut p = test_pipeline();
        assert_eq!(p.forward_batch_pipelined(&inputs, 1, 2).unwrap(), want);
    }

    #[test]
    fn pipelined_records_stage_stats() {
        let inputs: Vec<Vec<f64>> = (0..6).map(|_| vec![0.1; 4]).collect();
        let mut p = test_pipeline();
        p.forward_batch_pipelined(&inputs, 3, 2).unwrap();
        let stats = p.take_stage_stats();
        assert_eq!(stats.len(), 3);
        // 3 micro-batches traversed every unit
        assert!(stats.iter().all(|s| s.calls == 3), "{stats:?}");
        // drained: second take is zeroed
        assert!(p.take_stage_stats().iter().all(|s| s.calls == 0));
    }

    #[test]
    fn pipelined_propagates_module_errors() {
        let mut p = test_pipeline();
        let bad = vec![vec![0.0; 3]];
        assert!(p.forward_batch_pipelined(&bad, 2, 1).is_err());
        // dim mismatch mid-chain: feed through a stage that rejects
        struct Failing;
        impl AnalogModule for Failing {
            fn name(&self) -> &str {
                "fail"
            }
            fn kind(&self) -> &'static str {
                "Test"
            }
            fn in_dim(&self) -> usize {
                2
            }
            fn out_dim(&self) -> usize {
                2
            }
            fn forward_batch(&mut self, _inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
                bail!("injected failure")
            }
        }
        let stages = vec![
            affine("a", "ok", 2, 2, 1.0, 0.0),
            Stage::Module { unit: "b".into(), module: Box::new(Failing) },
            affine("c", "after", 2, 2, 1.0, 0.0),
        ];
        let mut p = Pipeline::from_stages(stages, Fidelity::Ideal).unwrap();
        let inputs: Vec<Vec<f64>> = (0..5).map(|_| vec![0.3, -0.1]).collect();
        let err = p.forward_batch_pipelined(&inputs, 3, 1).unwrap_err();
        assert!(format!("{err}").contains("injected failure"), "{err}");
    }
}
