//! Crossbar microbenchmark — behavioural VMM throughput and SPICE solve
//! cost per crossbar size (supports the §Perf L3 iteration log).
//!
//!   cargo bench --bench bench_crossbar

use memx::mapper::{self, MapMode};
use memx::netlist;
use memx::nn::DeviceJson;
use memx::spice::solve::Ordering;
use memx::util::bench::{append_json_report, black_box, Bench};
use memx::util::pool;

fn device() -> DeviceJson {
    DeviceJson {
        r_on: 100.0,
        r_off: 16000.0,
        levels: 64,
        prog_sigma: 0.01,
        v_in: 2.5e-3,
        v_rail: 24.0,
        t_mem: 1e-10,
        slew_rate: 1e7,
        v_swing: 5.0,
        p_opamp: 1e-3,
        p_memristor: 1.1e-6,
        p_aux: 5e-4,
        t_opamp: 5e-7,
    }
}

fn main() {
    let dev = device();
    let mut b = Bench::default();
    let mut derived: Vec<(String, f64)> = Vec::new();

    for &n in &[64usize, 256, 512] {
        let cb = mapper::build_synthetic_fc(n, n, 64, MapMode::Inverted, 5);
        let inputs: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.31).sin() * 0.4).collect();

        let s = b.run(&format!("eval_ideal {n}x{n}"), || {
            black_box(cb.eval_ideal(&inputs));
        });
        let macs = cb.devices.len() as f64;
        println!("    -> {:.1} M device-ops/s", macs / s.mean_secs() / 1e6);

        let segs = netlist::plan_segments(cb.cols, 64);
        let cold = b.run(&format!("spice seg64 {n}x{n} (emit+parse+solve all)"), || {
            for seg in &segs {
                let text = netlist::emit_crossbar(&cb, &dev, seg, Some(&inputs), segs.len());
                let c = netlist::parse(&text).unwrap();
                black_box(
                    netlist::solve_segment_outputs(&c, seg, true, Ordering::Smart).unwrap(),
                );
            }
        });

        // factor-once/solve-many: same read served from cached per-segment
        // LU factorizations, new inputs every iteration (RHS-only re-solves)
        let workers = pool::default_workers();
        let mut sim = cb.sim(&dev, 64, Ordering::Smart).unwrap();
        let mut k = 0usize;
        let warm = b.run(&format!("spice seg64 {n}x{n} cached resolve"), || {
            k += 1;
            let v: Vec<f64> =
                (0..n).map(|i| ((i + k) as f64 * 0.31).sin() * 0.4).collect();
            black_box(sim.solve_par(&v, workers).unwrap());
        });
        let speedup = cold.median.as_secs_f64() / warm.median.as_secs_f64().max(1e-12);
        println!("    -> cached-resolve median speedup {speedup:.1}x");
        derived.push((format!("seg64_{n}x{n}_cold_vs_cached"), speedup));
    }
    b.table("crossbar microbenchmarks");
    if let Err(e) = append_json_report("BENCH_spice.json", "bench_crossbar", &b.rows, &derived) {
        eprintln!("warning: could not write BENCH_spice.json: {e}");
    }
}
