//! E9 — the §3.2 op-amp-saving claim: the paper's inverted differential
//! convention halves op-amps per output port vs the conventional dual
//! mapping, cutting power (op-amps are mW; memristors are µW) and latency
//! (one fewer transition per stage: 1.24 µs vs 1.30 µs in the paper).
//!
//!   cargo bench --bench bench_opamp_ablation

use std::path::Path;

use memx::mapper::{self, MapMode};
use memx::nn::{Manifest, WeightStore};
use memx::power;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_opamp_ablation: artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let m = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &m)?;

    println!("== E9: inverted (this work) vs dual op-amp mapping ==");
    println!("| mode | memristors | op-amps | latency seq | latency pipe | energy |");
    println!("|---|---:|---:|---:|---:|---:|");
    let mut rows = Vec::new();
    for mode in [MapMode::Inverted, MapMode::Dual] {
        let net = mapper::map_network(&m, &ws, mode)?;
        let t = power::latency(&net, &m.device);
        let tp = power::latency_pipelined(&net, &m.device);
        let e = power::energy(&net, &m.device, &t);
        println!(
            "| {mode:?} | {} | {} | {:.3} µs | {:.3} µs | {:.2} µJ |",
            net.total_memristors(),
            net.total_opamps(),
            t.total * 1e6,
            tp.total * 1e6,
            e.total * 1e6
        );
        rows.push((net.total_memristors(), net.total_opamps(), e.total));
    }
    let (m_inv, o_inv, e_inv) = rows[0];
    let (m_dual, o_dual, e_dual) = rows[1];
    assert_eq!(m_inv, m_dual, "memristor count must be mode-independent");
    println!(
        "\nop-amp reduction: {:.1}% (paper claims 50%); energy saving {:.1}%",
        100.0 * (1.0 - o_inv as f64 / o_dual as f64),
        100.0 * (1.0 - e_inv / e_dual)
    );
    Ok(())
}
