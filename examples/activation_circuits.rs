//! activation_circuits — Fig 4 reproduction: build the hard-sigmoid and
//! hard-swish analog circuits (op-amp adder/divider + diode-and-source
//! limiter + multiplier), sweep the input, and print the transfer curves
//! next to the software functions.
//!
//!   cargo run --release --example activation_circuits [csv_path]

use memx::analog;

fn main() -> anyhow::Result<()> {
    let csv_path = std::env::args().nth(1);

    let mut hs = analog::build_hard_sigmoid();
    let mut hw = analog::build_hard_swish();

    let mut csv = String::from("vin,hsigmoid_spice,hsigmoid_sw,hswish_spice,hswish_sw\n");
    let mut worst_hs = 0f64;
    let mut worst_hw = 0f64;
    println!("  vin   hsig(spice)  hsig(sw)   hswish(spice)  hswish(sw)");
    for i in 0..=40 {
        let x = -4.0 + 8.0 * i as f64 / 40.0;
        let y_hs = hs.eval(x)?;
        let y_hw = hw.eval(x)?;
        let sw_hs = analog::hard_sigmoid_sw(x);
        let sw_hw = analog::hard_swish_sw(x);
        worst_hs = worst_hs.max((y_hs - sw_hs).abs());
        worst_hw = worst_hw.max((y_hw - sw_hw).abs());
        if i % 4 == 0 {
            println!("{x:+.2}   {y_hs:+.4}      {sw_hs:+.4}    {y_hw:+.4}        {sw_hw:+.4}");
        }
        csv.push_str(&format!("{x:.3},{y_hs:.5},{sw_hs:.5},{y_hw:.5},{sw_hw:.5}\n"));
    }
    println!("\nmax |circuit - software|: hard sigmoid {worst_hs:.3}, hard swish {worst_hw:.3}");
    println!("(diode limiter knees bound the error — paper Fig 4c/d show the same shape)");
    if let Some(p) = csv_path {
        std::fs::write(&p, csv)?;
        println!("curves written to {p}");
    }
    anyhow::ensure!(worst_hs < 0.2 && worst_hw < 0.6, "circuits diverged from Fig 4");
    println!("activation circuits OK");
    Ok(())
}
