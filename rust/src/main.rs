//! memx CLI — leader entrypoint.
//!
//! Subcommands:
//!   info                         artifact + manifest summary
//!   accuracy [--model analog|digital] [--n N] [--fidelity F]
//!            [--solver direct|iterative|auto]
//!            [--backend scalar|simd|auto]            Table 1 row
//!            (analog runs offline through the crossbar pipeline;
//!             digital needs the PJRT runtime)
//!   serve    [--n N] [--model ...] [--max-wait-us U] [--fidelity F]
//!            [--workers W] [--backend B]  demo serving run (analog serves
//!            the crossbar pipeline offline, with a synthetic demo network
//!            when no artifacts exist; digital needs the PJRT runtime)
//!   verify                       runtime vs python expected logits
//!   map      [--mode inverted|dual]                Table 4 resources
//!   netlist  --layer NAME [--outdir DIR] [--segment N]   emit SPICE
//!            (FC/PConv crossbars, §3.3 BN pairs, §3.5 GAP columns)
//!   spice    --layer NAME [--segment N] [--n N]
//!            [--solver direct|iterative|auto]
//!            [--backend scalar|simd|auto]            simulate a layer
//!   report   --table4|--fig4|--fig7|--fig8|--fig9|--coverage  paper
//!            artifacts (--coverage [--fidelity F]: per-stage module
//!            fidelity/resource table + stage-hook Eq 17/18 — at spice
//!            fidelity the counts come from the emitted netlists)
//!   drift    [--hours H1,H2,...] [--n N] [--fidelity F] [--nu V]
//!            [--nu-sigma V] [--nu-g V] [--stuck-off F] [--stuck-on F]
//!            [--prog-sigma S] [--tran] [--out FILE]   device-lifetime
//!            sweep on the synthetic demo network: age the crossbars along
//!            the hour grid, track label agreement vs the pristine network
//!            and the relative crossbar-read energy, then reprogram and
//!            report the recovered agreement; --tran additionally ages a
//!            probe crossbar on the same fault clock and re-measures its
//!            read-pulse settling time per hour point (the coarse
//!            FaultModel clock driving the fine `spice::transient` clock);
//!            appends BENCH_drift.json (MEMX_BENCH_QUICK=1 shrinks the
//!            sweep for CI)
//!   tran     [--rows R] [--cols C] [--mode inverted|dual]
//!            [--integrators be,trap,trbdf2] [--rise-ns T] [--seed S]
//!            [--backend B] [--out FILE]   time-domain read-pulse sweep on a synthetic
//!            FC crossbar: settle each integrator to the DC operating
//!            point and compare simulated settling latency / device energy
//!            against the closed-form Eq 17/18 columns; appends
//!            BENCH_transient.json (MEMX_BENCH_QUICK=1 shrinks the run)
//!   validate [--n N] [--fuzz N] [--seed S] [--segment N] [--quick]
//!            differential validation harness: sweep every resident
//!            interchange deck of the spice-fidelity demo network (plus the
//!            residual summing-amplifier netlists) through the emit → parse
//!            → simulate round-trip and the independent dense MNA reference
//!            / Krylov cross-checks, then a generated differential corpus
//!            and a fuzzed-deck parser sweep; --quick (or
//!            MEMX_BENCH_QUICK=1) shrinks the corpora for CI
//!
//! Observability (memx::telemetry):
//!   accuracy/serve/spice/drift/tran all take [--trace-out FILE] (chrome://
//!   tracing JSON) and [--trace-jsonl FILE] (one event per line); either
//!   flag enables span tracing for the run. serve additionally takes
//!   [--metrics-addr HOST:PORT] (Prometheus text at /metrics, JSON at
//!   /metrics.json) and [--linger-ms MS] to keep the exporter up for
//!   scrapes after the demo drive finishes.
//!
//! Flags are parsed by util::cli (clap is not in the offline crate cache).

use std::path::Path;
use std::str::FromStr;

use anyhow::{bail, Result};

use memx::backend::BackendChoice;
use memx::coordinator::{
    self, Backend, InferenceExecutor, PipelineExecutor, Server, ServerConfig,
};
use memx::pipeline::{default_device, image_to_input, Fidelity, PipelineBuilder};
use memx::spice::krylov::SolverStrategy;
#[cfg(feature = "runtime-xla")]
use memx::runtime::{Engine, Model};
use memx::util::bin::Dataset;
use memx::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let rest = argv[1..].to_vec();
    let code = match run(&cmd, &rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "memx — memristor crossbar computing paradigm for MobileNetV3\n\
         usage: memx <info|accuracy|serve|verify|map|netlist|spice|report|drift|tran|validate> [flags]\n\
         common flags: --artifacts DIR (default ./artifacts)"
    );
}

/// Which model a subcommand should run. `Analog` routes through the
/// crossbar [`memx::pipeline`] (works offline); `Digital` needs the PJRT
/// runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ModelChoice {
    Analog,
    Digital,
}

impl FromStr for ModelChoice {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ModelChoice> {
        match s {
            "analog" => Ok(ModelChoice::Analog),
            "digital" => Ok(ModelChoice::Digital),
            other => bail!("unknown model '{other}' (analog|digital)"),
        }
    }
}

/// Deprecated thin wrapper over the [`FromStr`] impl — prefer
/// `s.parse::<ModelChoice>()`.
fn parse_model(s: &str) -> Result<ModelChoice> {
    s.parse()
}

/// The shared `--trace-out` / `--trace-jsonl` profile flags: constructing
/// this from parsed args enables span tracing when either is present;
/// [`TraceFlags::finish`] drains the collector and writes the file(s).
struct TraceFlags {
    chrome: Option<String>,
    jsonl: Option<String>,
}

impl TraceFlags {
    fn from_args(a: &Args) -> TraceFlags {
        let t = TraceFlags {
            chrome: a.get("trace-out").map(str::to_string),
            jsonl: a.get("trace-jsonl").map(str::to_string),
        };
        if t.chrome.is_some() || t.jsonl.is_some() {
            memx::telemetry::set_level(memx::telemetry::Level::Spans);
        }
        t
    }

    /// Write the collected trace. Call after every worker/server thread has
    /// joined so their span buffers have flushed to the collector.
    fn finish(&self) -> Result<()> {
        if self.chrome.is_none() && self.jsonl.is_none() {
            return Ok(());
        }
        memx::telemetry::set_level(memx::telemetry::Level::Off);
        let events = memx::telemetry::drain();
        let dropped = memx::telemetry::dropped_events();
        let lost = if dropped > 0 { format!(", {dropped} dropped") } else { String::new() };
        if let Some(p) = &self.chrome {
            memx::telemetry::write_chrome_trace(p, &events)?;
            println!(
                "wrote chrome trace ({} events{lost}) to {p} — load in chrome://tracing or \
                 ui.perfetto.dev",
                events.len()
            );
        }
        if let Some(p) = &self.jsonl {
            memx::telemetry::write_jsonl(p, &events)?;
            println!("wrote trace event log ({} lines{lost}) to {p}", events.len());
        }
        Ok(())
    }
}

fn run(cmd: &str, rest: &[String]) -> Result<()> {
    match cmd {
        "info" => cmd_info(rest),
        "accuracy" => cmd_accuracy(rest),
        "serve" => cmd_serve(rest),
        "verify" => cmd_verify(rest),
        "map" => cmd_map(rest),
        "netlist" => cmd_netlist(rest),
        "spice" => cmd_spice(rest),
        "report" => cmd_report(rest),
        "drift" => cmd_drift(rest),
        "tran" => cmd_tran(rest),
        "validate" => cmd_validate(rest),
        _ => {
            usage();
            bail!("unknown command '{cmd}'")
        }
    }
}

fn cmd_info(rest: &[String]) -> Result<()> {
    let a = Args::parse(rest, &["artifacts"])?;
    let dir = Path::new(a.get_or("artifacts", "artifacts"));
    let m = memx::nn::Manifest::load(dir)?;
    println!("arch            {} (width {})", m.arch, m.width);
    println!("input           {0}x{0}x3, {1} classes", m.img, m.num_classes);
    println!("digital test acc{:>8.4}", m.digital_test_acc);
    println!("batch variants  {:?}", m.batch_sizes);
    println!("layers          {}", m.layers.len());
    println!("units           {:?}", m.units());
    println!("weights tensors {}", m.weights.len());
    println!(
        "device          Ron {}Ω Roff {}Ω, {} levels, σ_prog {}",
        m.device.r_on, m.device.r_off, m.device.levels, m.device.prog_sigma
    );
    Ok(())
}

fn cmd_accuracy(rest: &[String]) -> Result<()> {
    let a = Args::parse(
        rest,
        &[
            "artifacts", "model", "n", "fidelity", "mode", "segment", "solver", "backend",
            "trace-out", "trace-jsonl",
        ],
    )?;
    let trace = TraceFlags::from_args(&a);
    let dir = Path::new(a.get_or("artifacts", "artifacts"));
    let result = match parse_model(a.get_or("model", "analog"))? {
        ModelChoice::Analog => accuracy_analog(dir, &a),
        ModelChoice::Digital => {
            // the PJRT engine runs pre-compiled executables — the SPICE
            // engine's linear-solver / dense-kernel knobs do not apply to it
            for flag in ["solver", "backend"] {
                if a.get(flag).is_some() {
                    bail!(
                        "--{flag} configures the analog SPICE engine and does not apply \
                         to the digital PJRT model; drop it or use --model analog"
                    );
                }
            }
            accuracy_digital(dir, &a)
        }
    };
    trace.finish()?;
    result
}

/// Analog Table 1 row through the crossbar pipeline — the offline path:
/// manifest + weights compile into a [`memx::pipeline::Pipeline`], and the
/// coordinator batches the dataset through `Pipeline::forward_batch`.
fn accuracy_analog(dir: &Path, a: &Args) -> Result<()> {
    let fidelity: Fidelity = a.get_or("fidelity", "behavioural").parse()?;
    let mode: memx::mapper::MapMode = a.get_or("mode", "inverted").parse()?;
    let solver: SolverStrategy = a.get_or("solver", "auto").parse()?;
    let backend: BackendChoice = a.get_or("backend", "auto").parse()?;
    let m = memx::nn::Manifest::load(dir)?;
    let ws = memx::nn::WeightStore::load(dir, &m)?;
    let mut pipe = PipelineBuilder::new()
        .mode(mode)
        .fidelity(fidelity)
        .solver(solver)
        .backend(backend)
        .segment(a.get_usize("segment", 64)?)
        .build(&m, &ws)?;
    let ds = Dataset::load(&dir.join(&m.dataset_file))?;
    let n = a.get_usize("n", ds.n)?;
    println!(
        "classifying {n} images through the analog pipeline ({fidelity} fidelity, mode {mode}, \
         solver {solver}, backend {backend}): {}",
        pipe.describe()
    );
    let (labels, wall) = coordinator::classify_dataset_analog(&mut pipe, &ds, n, &m.batch_sizes)?;
    let acc = coordinator::accuracy(&labels, &ds.labels[..labels.len()]);
    println!(
        "accuracy {:.4} ({}/{} correct)  wall {:?}  {:.1} img/s",
        acc,
        (acc * labels.len() as f64).round() as usize,
        labels.len(),
        wall,
        labels.len() as f64 / wall.as_secs_f64()
    );
    println!("digital (python) reference accuracy: {:.4}", m.digital_test_acc);
    Ok(())
}

#[cfg(feature = "runtime-xla")]
fn accuracy_digital(dir: &Path, a: &Args) -> Result<()> {
    let engine = Engine::new(dir)?;
    let ds = Dataset::load(&dir.join(&engine.manifest().dataset_file))?;
    let n = a.get_usize("n", ds.n)?;
    println!("classifying {n} images with the digital model on {}", engine.platform());
    let (labels, wall) = coordinator::classify_dataset(&engine, Model::Digital, &ds, n)?;
    let acc = coordinator::accuracy(&labels, &ds.labels[..labels.len()]);
    println!(
        "accuracy {:.4} ({}/{} correct)  wall {:?}  {:.1} img/s",
        acc,
        (acc * labels.len() as f64).round() as usize,
        labels.len(),
        wall,
        labels.len() as f64 / wall.as_secs_f64()
    );
    println!("digital (python) reference accuracy: {:.4}", engine.manifest().digital_test_acc);
    Ok(())
}

#[cfg(not(feature = "runtime-xla"))]
fn accuracy_digital(_dir: &Path, _a: &Args) -> Result<()> {
    no_runtime("accuracy --model digital")
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let a = Args::parse(
        rest,
        &[
            "artifacts", "model", "n", "max-wait-us", "fidelity", "workers", "backend",
            "metrics-addr", "linger-ms", "trace-out", "trace-jsonl",
        ],
    )?;
    let trace = TraceFlags::from_args(&a);
    let dir = Path::new(a.get_or("artifacts", "artifacts"));
    let n = a.get_usize("n", 256)?;
    let max_wait = std::time::Duration::from_micros(a.get_usize("max-wait-us", 2000)? as u64);
    let metrics_addr = a.get("metrics-addr").map(str::to_string);
    let linger = std::time::Duration::from_millis(a.get_usize("linger-ms", 0)? as u64);
    let export = ExportCfg { metrics_addr, linger };
    let result = match parse_model(a.get_or("model", "analog"))? {
        ModelChoice::Analog => {
            let fidelity: Fidelity = a.get_or("fidelity", "behavioural").parse()?;
            let workers = a.get_usize("workers", 0)?;
            let backend: BackendChoice = a.get_or("backend", "auto").parse()?;
            serve_analog(dir, n, max_wait, fidelity, workers, backend, &export)
        }
        ModelChoice::Digital => {
            // the PJRT engine serves fixed pre-compiled executables — the
            // analog pipeline's fidelity/worker/kernel knobs do not apply
            for flag in ["fidelity", "workers", "backend"] {
                if a.get(flag).is_some() {
                    bail!(
                        "--{flag} configures the analog pipeline executor and does not \
                         apply to the PJRT backend; drop it or use --model analog"
                    );
                }
            }
            serve_digital(dir, n, max_wait, &export)
        }
    };
    // the serve thread has joined by now, so its spans are all collected
    trace.finish()?;
    result
}

/// `memx serve`'s export knobs: the optional metrics HTTP endpoint and how
/// long to keep it up after the demo drive (so external scrapers — the CI
/// smoke's curl — can observe the final counters).
struct ExportCfg {
    metrics_addr: Option<String>,
    linger: std::time::Duration,
}

impl ExportCfg {
    /// Start the exporter over the server's registry (no-op without
    /// `--metrics-addr`).
    fn start(&self, server: &Server) -> Result<Option<memx::telemetry::http::MetricsServer>> {
        let Some(addr) = &self.metrics_addr else { return Ok(None) };
        let exporter = server.serve_metrics(addr)?;
        println!("metrics exporter on http://{}/metrics", exporter.addr());
        Ok(Some(exporter))
    }

    /// Hold the endpoint open for `--linger-ms`, then stop it.
    fn finish(&self, exporter: Option<memx::telemetry::http::MetricsServer>) {
        let Some(exporter) = exporter else { return };
        if !self.linger.is_zero() {
            println!("metrics exporter lingering {:?} for scrapes", self.linger);
            std::thread::sleep(self.linger);
        }
        exporter.shutdown();
    }
}

/// Closed-loop serving drive: four submitter threads stream `n` dataset
/// images through the server. Returns (wall time, accuracy vs ds.labels).
fn drive_requests(server: &Server, ds: &Dataset, n: usize) -> (std::time::Duration, f64) {
    let t0 = std::time::Instant::now();
    let client = server.client();
    let correct = std::sync::atomic::AtomicUsize::new(0);
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let c = client.clone();
            let correct = &correct;
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                match c.classify(ds.image(i).to_vec()) {
                    Ok(p) if p.label == ds.labels[i] as usize => {
                        correct.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    _ => {}
                }
            });
        }
    });
    let wall = t0.elapsed();
    let acc = correct.load(std::sync::atomic::Ordering::Relaxed) as f64 / n.max(1) as f64;
    (wall, acc)
}

/// Serve the analog crossbar pipeline behind the batcher queue — fully
/// offline. With trained artifacts the manifest is compiled into the
/// pipeline executor; without them a synthetic FC-stack network (labeled
/// by its own sequential forward, so served accuracy must be 1.0) keeps
/// the request loop honest — the CI smoke run relies on this.
fn serve_analog(
    dir: &Path,
    n: usize,
    max_wait: std::time::Duration,
    fidelity: Fidelity,
    workers: usize,
    backend: BackendChoice,
    export: &ExportCfg,
) -> Result<()> {
    let synthetic = !dir.join("manifest.json").exists();
    let (server, ds) = if synthetic {
        println!("no artifacts at {dir:?} — serving the synthetic FC-stack demo network");
        synthetic_server(n, max_wait, fidelity, workers, backend)?
    } else {
        let m = memx::nn::Manifest::load(dir)?;
        let ds = Dataset::load(&dir.join(&m.dataset_file))?;
        let cfg =
            ServerConfig { backend: Backend::Analog { fidelity, workers, backend }, max_wait };
        (Server::start(dir, cfg)?, ds)
    };
    let exporter = export.start(&server)?;
    let n = n.min(ds.n);
    println!(
        "server up (analog pipeline, {fidelity} fidelity, workers {}), warmup {:?}",
        if workers == 0 { "auto".to_string() } else { workers.to_string() },
        server.warmup
    );
    let (wall, acc) = drive_requests(&server, &ds, n);
    println!("served {n} requests in {wall:?}  accuracy {acc:.4}");
    server.metrics().snapshot().print(wall);
    export.finish(exporter);
    server.shutdown();
    if synthetic && n > 0 && acc < 1.0 {
        bail!("synthetic serve smoke: served labels diverged from the sequential forward ({acc:.4})");
    }
    Ok(())
}

/// A manifest-free serving rig: deterministic random images through a
/// synthetic FC stack, labels pinned to the sequential pipeline's own
/// classification so the served (batched, pipelined) path is checked
/// end to end.
fn synthetic_server(
    n: usize,
    max_wait: std::time::Duration,
    fidelity: Fidelity,
    workers: usize,
    backend: BackendChoice,
) -> Result<(Server, Dataset)> {
    const SEED: u64 = 0xC1F0;
    let (h, w, c, classes) = (8usize, 8usize, 3usize, 10usize);
    let dims = [h * w * c, 32, classes];
    let dev = default_device();
    let n = n.clamp(1, 4096);

    let mut rng = memx::util::prng::Rng::new(SEED ^ 0xDA7A);
    let data: Vec<f32> = (0..n * h * w * c).map(|_| rng.f32()).collect();
    let mut ds = Dataset { n, h, w, c, data, labels: vec![0; n] };

    // ground truth = the sequential reference path
    let mut reference = PipelineBuilder::new()
        .fidelity(fidelity)
        .backend(backend)
        .build_fc_stack(&dims, &dev, SEED)?;
    for i in 0..n {
        let x = image_to_input(ds.image(i), h, w, c);
        // round through f32 exactly like the serving executor's logits do,
        // so label comparison is immune to f32 near-ties
        let logits: Vec<f64> =
            reference.forward(&x)?.iter().map(|&v| v as f32 as f64).collect();
        ds.labels[i] = memx::pipeline::argmax(&logits) as u8;
    }

    let server = Server::start_with(max_wait, move || {
        // module-internal solves stay single-threaded: the pipelined
        // scheduler (PipelineExecutor workers) owns the thread budget
        let pipeline = PipelineBuilder::new()
            .fidelity(fidelity)
            .backend(backend)
            .workers(1)
            .build_fc_stack(&dims, &default_device(), SEED)?;
        Ok(Box::new(PipelineExecutor::new(pipeline, (h, w, c), &[1, 4, 8], workers)?)
            as Box<dyn InferenceExecutor>)
    })?;
    Ok((server, ds))
}

#[cfg(feature = "runtime-xla")]
fn serve_digital(
    dir: &Path,
    n: usize,
    max_wait: std::time::Duration,
    export: &ExportCfg,
) -> Result<()> {
    let manifest = memx::nn::Manifest::load(dir)?;
    let ds = Dataset::load(&dir.join(&manifest.dataset_file))?;
    let n = n.min(ds.n);
    let server = Server::start(
        dir,
        ServerConfig { backend: Backend::Pjrt { model: Model::Digital }, max_wait },
    )?;
    let exporter = export.start(&server)?;
    println!("server up (pjrt digital), warmup {:?}", server.warmup);
    let (wall, acc) = drive_requests(&server, &ds, n);
    println!("served {n} requests in {wall:?}  accuracy {acc:.4}");
    server.metrics().snapshot().print(wall);
    export.finish(exporter);
    server.shutdown();
    Ok(())
}

#[cfg(not(feature = "runtime-xla"))]
fn serve_digital(
    _dir: &Path,
    _n: usize,
    _max_wait: std::time::Duration,
    _export: &ExportCfg,
) -> Result<()> {
    no_runtime("serve --model digital")
}

#[cfg(feature = "runtime-xla")]
fn cmd_verify(rest: &[String]) -> Result<()> {
    let a = Args::parse(rest, &["artifacts", "tol"])?;
    let dir = Path::new(a.get_or("artifacts", "artifacts"));
    let tol = a.get_f64("tol", 1e-3)?;
    let engine = Engine::new(dir)?;
    let m = engine.manifest();
    let ds = Dataset::load(&dir.join(&m.dataset_file))?;
    let (n, classes, expected) =
        memx::util::bin::read_expected_logits(&dir.join(&m.expected_file))?;
    println!("verifying {n} images against python logits (tol {tol})");
    let img = ds.image_len();
    let mut worst = 0f64;
    let mut i = 0;
    while i < n {
        let b = engine.pick_batch(n - i);
        let exec = engine.get(Model::Analog, b)?;
        let take = b.min(n - i);
        let mut buf = vec![0f32; b * img];
        for j in 0..take {
            buf[j * img..(j + 1) * img].copy_from_slice(ds.image(i + j));
        }
        for j in take..b {
            let src = ds.image(i + take - 1).to_vec();
            buf[j * img..(j + 1) * img].copy_from_slice(&src);
        }
        let got = exec.run(&buf)?;
        for j in 0..take {
            for c in 0..classes {
                let d = (got[j * classes + c] as f64 - expected[(i + j) * classes + c] as f64)
                    .abs();
                worst = worst.max(d);
            }
        }
        i += take;
    }
    println!("max |rust - python| over {n}x{classes} logits: {worst:.3e}");
    if worst > tol {
        bail!("verification FAILED: {worst:.3e} > {tol:.1e}");
    }
    println!("verification OK");
    Ok(())
}

#[cfg(not(feature = "runtime-xla"))]
fn cmd_verify(_rest: &[String]) -> Result<()> {
    no_runtime("verify")
}

#[cfg(not(feature = "runtime-xla"))]
fn no_runtime(cmd: &str) -> Result<()> {
    bail!(
        "'{cmd}' needs the PJRT runtime, which this binary was built without.\n\
         Rebuild with `cargo build --release --features runtime-xla` on a host\n\
         that has the xla crate + libxla_extension (see Cargo.toml)."
    )
}

fn cmd_map(rest: &[String]) -> Result<()> {
    let a = Args::parse(rest, &["artifacts", "mode"])?;
    let dir = Path::new(a.get_or("artifacts", "artifacts"));
    let mode: memx::mapper::MapMode = a.get_or("mode", "inverted").parse()?;
    let m = memx::nn::Manifest::load(dir)?;
    let ws = memx::nn::WeightStore::load(dir, &m)?;
    let mapped = memx::mapper::map_network(&m, &ws, mode)?;
    memx::report::print_table4(&mapped);
    Ok(())
}

fn cmd_netlist(rest: &[String]) -> Result<()> {
    let a = Args::parse(rest, &["artifacts", "layer", "outdir", "segment", "mode"])?;
    let dir = Path::new(a.get_or("artifacts", "artifacts"));
    let layer = a.get("layer").unwrap_or("cls.fc1");
    let outdir = Path::new(a.get_or("outdir", "netlists"));
    let segment = a.get_usize("segment", 0)?;
    let mode: memx::mapper::MapMode = a.get_or("mode", "inverted").parse()?;
    let m = memx::nn::Manifest::load(dir)?;
    let ws = memx::nn::WeightStore::load(dir, &m)?;
    let files = memx::netlist::emit_layer_netlists(&m, &ws, layer, mode, segment, outdir)?;
    println!("wrote {} netlist file(s) under {outdir:?}", files.len());
    for f in files.iter().take(5) {
        println!("  {f:?}");
    }
    if files.len() > 5 {
        println!("  ... ({} more)", files.len() - 5);
    }
    Ok(())
}

fn cmd_spice(rest: &[String]) -> Result<()> {
    let a = Args::parse(
        rest,
        &[
            "artifacts", "layer", "segment", "n", "mode", "solver", "backend", "trace-out",
            "trace-jsonl",
        ],
    )?;
    let trace = TraceFlags::from_args(&a);
    let dir = Path::new(a.get_or("artifacts", "artifacts"));
    let layer = a.get("layer").unwrap_or("cls.fc2");
    let segment = a.get_usize("segment", 64)?;
    let n = a.get_usize("n", 4)?;
    let mode: memx::mapper::MapMode = a.get_or("mode", "inverted").parse()?;
    let solver: SolverStrategy = a.get_or("solver", "auto").parse()?;
    let backend: BackendChoice = a.get_or("backend", "auto").parse()?;
    let result = memx::report::spice_layer_demo(dir, layer, mode, segment, n, solver, backend);
    trace.finish()?;
    result
}

fn cmd_report(rest: &[String]) -> Result<()> {
    let a = Args::parse(
        rest,
        &[
            "artifacts", "table4!", "fig4!", "fig7!", "fig8!", "fig9!", "all!", "out",
            "coverage!", "fidelity", "mode", "segment", "solver",
        ],
    )?;
    let dir = Path::new(a.get_or("artifacts", "artifacts"));
    let all = a.has("all");
    let mut any = false;
    // not part of --all: at spice fidelity this compiles resident
    // simulators for every crossbar of the network, which is a deliberate
    // (potentially heavy) request
    if a.has("coverage") {
        let fidelity: Fidelity = a.get_or("fidelity", "spice").parse()?;
        let mode: memx::mapper::MapMode = a.get_or("mode", "inverted").parse()?;
        let solver: SolverStrategy = a.get_or("solver", "auto").parse()?;
        memx::report::report_coverage(dir, fidelity, mode, a.get_usize("segment", 64)?, solver)?;
        any = true;
    }
    if a.has("table4") || all {
        memx::report::report_table4(dir)?;
        any = true;
    }
    if a.has("fig4") || all {
        memx::report::report_fig4(a.get("out"))?;
        any = true;
    }
    if a.has("fig7") || all {
        memx::report::report_fig7(dir)?;
        any = true;
    }
    if a.has("fig8") || all {
        memx::report::report_fig8(dir)?;
        any = true;
    }
    if a.has("fig9") || all {
        memx::report::report_fig9(dir)?;
        any = true;
    }
    if !any {
        bail!("pick at least one of --table4 --fig4 --fig7 --fig8 --fig9 --coverage --all");
    }
    Ok(())
}

/// Device-lifetime drift sweep on the synthetic demo network: one pristine
/// pipeline pins the reference labels, a second identical pipeline is aged
/// in place along the simulated-hour grid (log-time conductance decay +
/// read disturb + stuck cells from [`memx::fault`]), and each point reports
/// label agreement and the relative crossbar-read energy (the mean
/// conductance decay at fixed read voltage). A final reprogram cycle
/// restores the surviving devices and reports the recovered agreement.
fn cmd_drift(rest: &[String]) -> Result<()> {
    let a = Args::parse(
        rest,
        &[
            "hours", "n", "fidelity", "nu", "nu-sigma", "nu-g", "stuck-on", "stuck-off",
            "read-rate", "prog-sigma", "seed", "out", "tran!", "trace-out", "trace-jsonl",
        ],
    )?;
    let trace = TraceFlags::from_args(&a);
    let fidelity: Fidelity = a.get_or("fidelity", "behavioural").parse()?;
    let quick = std::env::var("MEMX_BENCH_QUICK").is_ok();
    let hours_spec = a.get_or("hours", if quick { "0,10" } else { "0,1,10,100,1000" });
    let mut hours = Vec::new();
    for tok in hours_spec.split(',') {
        let h: f64 = tok
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("--hours: '{tok}' is not a number"))?;
        if !h.is_finite() || h < 0.0 {
            bail!("--hours: {h} is not a valid simulated time");
        }
        hours.push(h);
    }
    hours.sort_by(|x, y| x.total_cmp(y));
    hours.dedup();
    if hours.is_empty() {
        bail!("--hours: empty sweep");
    }
    let n = a.get_usize("n", if quick { 16 } else { 64 })?.max(1);
    let seed = a.get_usize("seed", 0xC1F0)? as u64;

    let d = memx::fault::FaultConfig::default();
    let cfg = memx::fault::FaultConfig {
        drift_nu: a.get_f64("nu", d.drift_nu)?,
        nu_sigma: a.get_f64("nu-sigma", d.nu_sigma)?,
        nu_g: a.get_f64("nu-g", d.nu_g)?,
        stuck_on_frac: a.get_f64("stuck-on", d.stuck_on_frac)?,
        stuck_off_frac: a.get_f64("stuck-off", d.stuck_off_frac)?,
        read_disturb_rate: a.get_f64("read-rate", d.read_disturb_rate)?,
        ..d
    };
    let prog_sigma = a.get_f64("prog-sigma", 0.0)?;

    // the full-chain demo network (conv + BN + SE + GAP + FC) so every
    // module type's fault hooks are exercised
    let (m, ws) = memx::pipeline::demo_network(seed)?;
    let builder = || {
        PipelineBuilder::new().fidelity(fidelity).segment(8).build(&m, &ws)
    };
    let mut pristine = builder()?;
    let mut aged = builder()?;

    // --tran: a probe FC crossbar aged on the same FaultModel clock whose
    // read-pulse transient is re-run at each hour point, so the coarse
    // lifetime clock drives the fine `spice::transient` clock
    let mut probe = if a.has("tran") {
        let dev = default_device();
        let cb = memx::mapper::build_synthetic_fc(
            12,
            4,
            dev.levels,
            memx::mapper::MapMode::Inverted,
            seed ^ 0x7A,
        );
        let sim = memx::netlist::CrossbarSim::new(
            &cb,
            &dev,
            0,
            memx::spice::solve::Ordering::Smart,
            SolverStrategy::Auto,
        )?;
        let pristine_g: Vec<f64> = cb.devices.iter().map(|p| p.g_norm).collect();
        let mut prng = memx::util::prng::Rng::new(seed ^ 0x7A41);
        let inputs: Vec<f64> = (0..12).map(|_| (prng.f64() * 2.0 - 1.0) * 0.3).collect();
        Some((cb, sim, pristine_g, inputs, dev))
    } else {
        None
    };

    let mut rng = memx::util::prng::Rng::new(seed ^ 0xD21F7);
    let in_dim = pristine.in_dim();
    let batch: Vec<Vec<f64>> =
        (0..n).map(|_| (0..in_dim).map(|_| rng.f32() as f64 * 0.5).collect()).collect();
    let reference = pristine.classify_batch(&batch)?;

    println!(
        "drift sweep on the demo network ({fidelity} fidelity, {n} inputs): \
         nu {} (sigma {}), read rate {}, stuck on/off {}/{}",
        cfg.drift_nu, cfg.nu_sigma, cfg.read_disturb_rate, cfg.stuck_on_frac, cfg.stuck_off_frac
    );
    let mut model = memx::fault::FaultModel::new(cfg);
    let mut rows: Vec<memx::util::bench::Stats> = Vec::new();
    let mut derived: Vec<(String, f64)> = Vec::new();
    let mut energy = 1.0f64;
    for &h in &hours {
        let step = model.advance(h - model.hours(), n as u64);
        energy *= step.mean_decay();
        aged.inject_faults(&step);
        let t0 = std::time::Instant::now();
        let labels = aged.classify_batch(&batch)?;
        let wall = t0.elapsed();
        let agree = labels.iter().zip(&reference).filter(|(x, y)| x == y).count() as f64
            / n as f64;
        println!(
            "  t={h:>7}h  agreement {agree:.4}  energy factor {energy:.4}  classify {wall:?}"
        );
        rows.push(memx::util::bench::Stats {
            name: format!("classify_t{h}h"),
            iters: 1,
            mean: wall,
            median: wall,
            p95: wall,
            min: wall,
        });
        derived.push((format!("agreement_t{h}h"), agree));
        if let Some((cb, sim, pristine_g, inputs, dev)) = probe.as_mut() {
            let bank = memx::fault::bank_seed("tran_probe");
            memx::fault::apply_step_from(
                &step,
                bank,
                &mut cb.devices,
                Some(pristine_g.as_slice()),
                dev.r_on / dev.r_off,
            );
            sim.update_conductances(&cb.devices, dev.r_on);
            let rd = sim.tran_read(inputs, &memx::netlist::ReadPulse::default())?;
            println!(
                "             read settle {:.3e}s  device energy {:.3e}J",
                rd.settle_s, rd.energy_j
            );
            derived.push((format!("settle_s_t{h}h"), rd.settle_s));
        }
    }
    derived.push(("energy_factor_final".into(), energy));

    // recalibrate: pristine weights rewritten (stuck cells persist), fresh
    // programming noise, drift clock restarted
    let rewritten = aged.reprogram(prog_sigma, cfg.seed, 1);
    model.reset_clock();
    let recovered = aged
        .classify_batch(&batch)?
        .iter()
        .zip(&reference)
        .filter(|(x, y)| x == y)
        .count() as f64
        / n as f64;
    println!("  reprogrammed {rewritten} devices -> agreement {recovered:.4}");
    derived.push(("agreement_recovered".into(), recovered));
    derived.push(("devices_reprogrammed".into(), rewritten as f64));

    let out = a.get_or("out", "BENCH_drift.json");
    memx::util::bench::append_json_report(out, "drift", &rows, &derived)?;
    println!("appended drift trajectory to {out}");
    trace.finish()?;
    Ok(())
}

/// Time-domain read-pulse sweep (`spice::transient`): a synthetic FC
/// crossbar is read through [`memx::netlist::CrossbarSim::tran_read`]
/// under each requested integrator, the settled outputs are checked
/// against the DC operating point, and the simulated settling latency /
/// integrated device energy are printed next to the paper's closed-form
/// Eq 17/18 columns ([`memx::power::ReadComparison`]).
fn cmd_tran(rest: &[String]) -> Result<()> {
    use memx::netlist::{CrossbarSim, ReadPulse};
    use memx::power::{ReadComparison, SimulatedRead};
    use memx::spice::solve::Ordering;
    use memx::spice::transient::Integrator;

    let a = Args::parse(
        rest,
        &[
            "rows", "cols", "mode", "integrators", "rise-ns", "seed", "backend", "out",
            "trace-out", "trace-jsonl",
        ],
    )?;
    let trace = TraceFlags::from_args(&a);
    let quick = std::env::var("MEMX_BENCH_QUICK").is_ok();
    let rows = a.get_usize("rows", if quick { 8 } else { 24 })?;
    let cols = a.get_usize("cols", if quick { 4 } else { 12 })?;
    let mode: memx::mapper::MapMode = a.get_or("mode", "inverted").parse()?;
    let seed = a.get_usize("seed", 0xC1F0)? as u64;
    let integ_spec = a.get_or("integrators", if quick { "be" } else { "be,trap,trbdf2" });
    let mut integrators = Vec::new();
    for tok in integ_spec.split(',') {
        integrators.push(tok.trim().parse::<Integrator>()?);
    }

    let dev = default_device();
    let cb = memx::mapper::build_synthetic_fc(rows, cols, dev.levels, mode, seed);
    let mut sim = CrossbarSim::new(&cb, &dev, 0, Ordering::Smart, SolverStrategy::Auto)?;
    sim.set_backend(a.get_or("backend", "auto").parse::<BackendChoice>()?);
    let mut rng = memx::util::prng::Rng::new(seed ^ 0x7A4);
    let inputs: Vec<f64> = (0..rows).map(|_| (rng.f64() * 2.0 - 1.0) * 0.4).collect();
    let dc = sim.solve(&inputs)?;

    println!(
        "transient read sweep: {rows}x{cols} synthetic FC crossbar ({mode} mode, {} devices)",
        cb.devices.len()
    );
    let mut bench_rows: Vec<memx::util::bench::Stats> = Vec::new();
    let mut derived: Vec<(String, f64)> = Vec::new();
    for &integ in &integrators {
        let pulse = ReadPulse {
            rise: a.get_f64("rise-ns", 10.0)? * 1e-9,
            integrator: integ,
            ..ReadPulse::default()
        };
        let t0 = std::time::Instant::now();
        let rd = sim.tran_read(&inputs, &pulse)?;
        let wall = t0.elapsed();
        let worst =
            rd.outputs.iter().zip(&dc).map(|(t, d)| (t - d).abs()).fold(0.0f64, f64::max);
        let cmp = ReadComparison::new(
            &dev,
            mode,
            cb.devices.len(),
            &SimulatedRead { settle_s: rd.settle_s, energy_j: rd.energy_j },
        );
        let iname = integ.to_string();
        println!(
            "  {iname:<7} settle {:.3e}s (analytical {:.3e}s, x{:.2})  energy {:.3e}J \
             (worst-case {:.3e}J, x{:.3})",
            cmp.simulated_latency_s,
            cmp.analytical_latency_s,
            cmp.latency_ratio(),
            cmp.simulated_energy_j,
            cmp.analytical_energy_biased_j,
            cmp.energy_ratio(),
        );
        println!(
            "          steps {} (+{} rejected)  solves {}  max|tran-dc| {worst:.3e}  wall {wall:?}",
            rd.stats.steps_accepted, rd.stats.steps_rejected, rd.stats.solves
        );
        bench_rows.push(memx::util::bench::Stats {
            name: format!("tran_read_{iname}"),
            iters: 1,
            mean: wall,
            median: wall,
            p95: wall,
            min: wall,
        });
        derived.push((format!("settle_s_{iname}"), rd.settle_s));
        derived.push((format!("energy_j_{iname}"), rd.energy_j));
        derived.push((format!("latency_ratio_{iname}"), cmp.latency_ratio()));
        derived.push((format!("steps_{iname}"), rd.stats.steps_accepted as f64));
    }
    let out = a.get_or("out", "BENCH_transient.json");
    memx::util::bench::append_json_report(out, "transient", &bench_rows, &derived)?;
    println!("appended transient sweep to {out}");
    trace.finish()?;
    Ok(())
}

/// Differential validation harness (`memx::netlist::validate`): the
/// spice-fidelity demo network's resident interchange decks through the
/// emit → parse → simulate round-trip plus the independent dense MNA
/// reference and Krylov cross-checks, then a generated MNA corpus and a
/// fuzzed-deck parser sweep. Any contract violation is a hard error.
fn cmd_validate(rest: &[String]) -> Result<()> {
    use anyhow::Context;
    use memx::netlist::validate::{
        check_deck, differential_sweep, fuzz_sweep, REFERENCE_TOL, ROUNDTRIP_TOL,
    };
    use memx::spice::solve::Ordering;

    let a = Args::parse(
        rest,
        &["n", "fuzz", "seed", "segment", "quick!", "trace-out", "trace-jsonl"],
    )?;
    let trace = TraceFlags::from_args(&a);
    let quick = a.has("quick") || std::env::var("MEMX_BENCH_QUICK").is_ok();
    let seed = a.get_usize("seed", 0x5EED)? as u64;
    let diff_cases = a.get_usize("n", if quick { 20 } else { 80 })?;
    let fuzz_cases = a.get_usize("fuzz", if quick { 200 } else { 1000 })?;

    // leg 1: every resident deck of the demo network, snapshotted at a
    // nontrivial operating point (one deterministic batch drives the
    // sources away from their all-zero build state first)
    let (m, ws) = memx::pipeline::demo_network(seed)?;
    let mut pipe = PipelineBuilder::new()
        .fidelity(Fidelity::Spice)
        .segment(a.get_usize("segment", 8)?)
        .build(&m, &ws)?;
    let in_dim = pipe.in_dim();
    let mut rng = memx::util::prng::Rng::new(seed ^ 0xDECC);
    let batch: Vec<Vec<f64>> = (0..2)
        .map(|_| (0..in_dim).map(|_| (rng.f64() - 0.5) * 0.6).collect())
        .collect();
    pipe.forward_batch(&batch)?;
    let mut decks = pipe.spice_decks();
    // the residual adders run exact at forward time; their offline
    // summing-amplifier netlists join the sweep explicitly
    let dev = default_device();
    for row in pipe.stage_coverage().iter().filter(|r| r.kind == "Add") {
        let cb = memx::analog::build_residual_crossbar(
            &row.name,
            row.in_dim,
            memx::mapper::MapMode::Inverted,
        );
        let sim =
            memx::netlist::CrossbarSim::new(&cb, &dev, 0, Ordering::Smart, SolverStrategy::Auto)?;
        decks.extend(sim.decks(&row.name));
    }
    if decks.is_empty() {
        bail!("demo network produced no resident decks at spice fidelity");
    }
    println!(
        "validate: {} decks (round-trip <= {ROUNDTRIP_TOL:.0e}, reference/krylov <= {REFERENCE_TOL:.0e})",
        decks.len()
    );
    let (mut worst_rt, mut worst_ref, mut worst_kry) = (0.0f64, 0.0f64, 0.0f64);
    for d in &decks {
        let rep = check_deck(d).with_context(|| format!("deck '{}'", d.name))?;
        worst_rt = worst_rt.max(rep.roundtrip_rel);
        worst_kry = worst_kry.max(rep.krylov_rel);
        let ref_str = match rep.reference_rel {
            Some(r) => {
                worst_ref = worst_ref.max(r);
                format!("{r:.3e}")
            }
            None => "skipped (dim cap)".to_string(),
        };
        println!(
            "  {:<30} {:>4} nodes {:>5} elems  roundtrip {:.3e}  reference {ref_str}  krylov {:.3e}",
            rep.name, rep.nodes, rep.elements, rep.roundtrip_rel, rep.krylov_rel
        );
    }
    println!("  worst: roundtrip {worst_rt:.3e}  reference {worst_ref:.3e}  krylov {worst_kry:.3e}");

    // leg 2: generated MNA corpus (TIA zero-diagonal pivots included) vs
    // the independent dense reference
    let worst = differential_sweep(seed ^ 0xD1FF, diff_cases)?;
    println!("differential corpus: {diff_cases} generated circuits, worst rel {worst:.3e}");

    // leg 3: fuzzed decks — the parser must accept or cleanly reject
    let (ok, rejected) = fuzz_sweep(seed ^ 0xF022, fuzz_cases);
    println!("fuzz corpus: {fuzz_cases} decks -> {ok} parsed, {rejected} rejected, 0 panics");
    trace.finish()?;
    Ok(())
}
