//! Dynamic batching policy.
//!
//! The engine exports executables for a fixed set of batch sizes (1/8/32).
//! The batcher drains the request queue, picks the largest compiled batch
//! that the queue depth can fill, and pads the final partial batch by
//! replicating its last image (padded slots are discarded on the way out and
//! counted in metrics). A `max_wait` deadline bounds added latency when the
//! queue is shallow.

use std::time::Duration;

/// Decision for one assembled batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPlan {
    /// compiled batch size to run
    pub size: usize,
    /// how many real requests it carries (<= size)
    pub real: usize,
}

/// Policy: given available compiled sizes (ascending) and current queue
/// depth, choose the batch to run now, or None to wait for more requests.
///
/// `waited_out`: the oldest request has exceeded max_wait — run whatever we
/// have rather than waiting for a fuller batch.
pub fn plan_batch(available: &[usize], queued: usize, waited_out: bool) -> Option<BatchPlan> {
    if queued == 0 {
        return None;
    }
    let largest = *available.iter().max()?;
    // enough to fill the largest batch: go now
    if queued >= largest {
        return Some(BatchPlan { size: largest, real: largest });
    }
    if !waited_out {
        return None; // wait for either a full batch or the deadline
    }
    // deadline hit: smallest compiled size that covers the queue
    let size = available
        .iter()
        .copied()
        .filter(|&b| b >= queued)
        .min()
        .unwrap_or(largest);
    Some(BatchPlan { size, real: queued.min(size) })
}

/// Default deadline before a partial batch is dispatched.
pub fn default_max_wait() -> Duration {
    Duration::from_millis(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    const AVAIL: &[usize] = &[1, 8, 32];

    #[test]
    fn empty_queue_waits() {
        assert_eq!(plan_batch(AVAIL, 0, true), None);
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        assert_eq!(plan_batch(AVAIL, 32, false), Some(BatchPlan { size: 32, real: 32 }));
        assert_eq!(plan_batch(AVAIL, 40, false), Some(BatchPlan { size: 32, real: 32 }));
    }

    #[test]
    fn partial_waits_until_deadline() {
        assert_eq!(plan_batch(AVAIL, 5, false), None);
        assert_eq!(plan_batch(AVAIL, 5, true), Some(BatchPlan { size: 8, real: 5 }));
    }

    #[test]
    fn single_request_deadline_uses_b1() {
        assert_eq!(plan_batch(AVAIL, 1, true), Some(BatchPlan { size: 1, real: 1 }));
    }

    #[test]
    fn queue_between_sizes_picks_covering_size() {
        assert_eq!(plan_batch(AVAIL, 9, true), Some(BatchPlan { size: 32, real: 9 }));
        assert_eq!(plan_batch(AVAIL, 8, true), Some(BatchPlan { size: 8, real: 8 }));
    }

    #[test]
    fn no_sizes_yields_none() {
        assert_eq!(plan_batch(&[], 4, true), None);
    }
}
