//! Batched time-domain (transient) analysis on the factor-once/solve-many
//! substrate.
//!
//! Dynamic elements (C, L) are replaced per timestep by companion models —
//! a conductance plus a history term on the RHS — whose stamp *pattern* is
//! fixed at analysis time (`add_keep`, see [`super::Circuit::stamp_dyn`]).
//! One [`factor::Symbolic`] analysis therefore serves the DC
//! initialization plus every timestep of every RHS column: a timestep-size
//! change is a numeric refactor, and a fixed-step run after the first step
//! is pure multi-RHS substitution. Three integrators are provided
//! ([`Integrator`]): Backward Euler (order 1, L-stable, dissipative),
//! Trapezoidal (order 2, A-stable, rings on stiff steps), and TR-BDF2
//! (order 2, L-stable — the trapezoidal/BDF2 composite with
//! `γ = 2 − √2`); the adaptive controller estimates the local truncation
//! error against a linear predictor and rejects/retries with a smaller
//! `h` when it exceeds the tolerance.
//!
//! The multi-RHS batch shape of the DC engine carries over: the companion
//! matrix of a linear circuit is shared by all columns (source values are
//! RHS-only), so a B-column transient sweep performs one symbolic
//! analysis, at most one refactor per distinct `h`, and one multi-RHS
//! substitution per timestep. Under an iterative
//! [`krylov::SolverStrategy`] (pattern above the monolithic threshold),
//! each step runs [`krylov::gmres_batch`] off the locally cached ILU(0)
//! and falls back to the direct factor path on failure, bumping the same
//! process-wide warm/cold fallback counters as the DC engine.
//!
//! Fixed-step batched results are **bit-for-bit identical** to running
//! each column on its own: the matrix, RHS assembly, and the multi-RHS
//! substitution are column-independent (adaptive runs share one time grid
//! across columns — the controller takes the max error over the batch —
//! so a single-column adaptive rerun may pick a different grid).

use std::collections::BTreeMap;
use std::sync::atomic::Ordering as MemOrdering;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::solve::{self, SparseSys};
use super::{factor, krylov, residual_ok, Circuit, Element};
use crate::backend::{self, Backend};

/// Time-varying source value, attached to a V or I source via
/// [`Circuit::set_waveform`] / [`Circuit::vsource_wave`]. DC analyses use
/// the t=0 sample.
#[derive(Debug, Clone, PartialEq)]
pub enum Waveform {
    /// Constant value (a plain source, expressible for uniformity).
    Dc(f64),
    /// SPICE `PULSE(v1 v2 delay rise fall width period)`: `v1` until
    /// `delay`, linear rise to `v2` over `rise`, hold `width`, linear fall
    /// over `fall` back to `v1`; repeats every `period` when > 0.
    Pulse { v1: f64, v2: f64, delay: f64, rise: f64, fall: f64, width: f64, period: f64 },
    /// SPICE `SIN(offset ampl freq delay damping)`: `offset` until
    /// `delay`, then `offset + ampl·e^{−damping·(t−delay)}·sin(2πf(t−delay))`.
    Sin { offset: f64, ampl: f64, freq: f64, delay: f64, damping: f64 },
    /// Piecewise-linear `(t, v)` points (ascending t); clamps to the end
    /// values outside the table.
    Pwl(Vec<(f64, f64)>),
}

impl Waveform {
    /// Sample the waveform at time `t` (seconds).
    pub fn eval(&self, t: f64) -> f64 {
        match self {
            Waveform::Dc(v) => *v,
            Waveform::Pulse { v1, v2, delay, rise, fall, width, period } => {
                if t <= *delay {
                    return *v1;
                }
                let mut tl = t - *delay;
                if *period > 0.0 {
                    tl %= *period;
                }
                if tl < *rise {
                    v1 + (v2 - v1) * tl / *rise
                } else if tl < *rise + *width {
                    *v2
                } else if tl < *rise + *width + *fall {
                    v2 + (v1 - v2) * (tl - *rise - *width) / *fall
                } else {
                    *v1
                }
            }
            Waveform::Sin { offset, ampl, freq, delay, damping } => {
                if t <= *delay {
                    return *offset;
                }
                let tl = t - *delay;
                offset
                    + ampl
                        * (-damping * tl).exp()
                        * (2.0 * std::f64::consts::PI * freq * tl).sin()
            }
            Waveform::Pwl(points) => {
                let Some(&(t0, v0)) = points.first() else { return 0.0 };
                if t <= t0 {
                    return v0;
                }
                for w in points.windows(2) {
                    let (ta, va) = w[0];
                    let (tb, vb) = w[1];
                    if t <= tb {
                        return if tb > ta { va + (vb - va) * (t - ta) / (tb - ta) } else { vb };
                    }
                }
                points.last().map(|&(_, v)| v).unwrap_or(0.0)
            }
        }
    }
}

/// Implicit integration scheme for [`tran_batch`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Integrator {
    /// Order 1, L-stable; heavily damped (safe default for settle sims).
    BackwardEuler,
    /// Order 2, A-stable but not L-stable: rings on stiff steps.
    Trapezoidal,
    /// Order 2, L-stable composite (trapezoidal over `γh`, then BDF2),
    /// `γ = 2 − √2` — damps what trapezoidal rings on.
    TrBdf2,
}

impl Integrator {
    /// Order of accuracy (the LTE controller uses `err^(-1/(order+1))`).
    pub fn order(&self) -> usize {
        match self {
            Integrator::BackwardEuler => 1,
            Integrator::Trapezoidal | Integrator::TrBdf2 => 2,
        }
    }
}

impl std::str::FromStr for Integrator {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "be" | "backward-euler" | "euler" => Ok(Integrator::BackwardEuler),
            "trap" | "trapezoidal" => Ok(Integrator::Trapezoidal),
            "trbdf2" | "tr-bdf2" => Ok(Integrator::TrBdf2),
            other => bail!("unknown integrator '{other}' (be|trap|trbdf2)"),
        }
    }
}

impl std::fmt::Display for Integrator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Integrator::BackwardEuler => "be",
            Integrator::Trapezoidal => "trap",
            Integrator::TrBdf2 => "trbdf2",
        })
    }
}

/// Transient sweep configuration.
#[derive(Debug, Clone)]
pub struct TranConfig {
    /// Simulation end time (s); the run starts at t = 0 from the DC point.
    pub t_stop: f64,
    /// Initial timestep (s).
    pub h0: f64,
    /// Smallest timestep the controller may use.
    pub h_min: f64,
    /// Largest timestep the controller may use.
    pub h_max: f64,
    pub integrator: Integrator,
    /// Adaptive LTE control (reject/retry). When false the run uses `h0`
    /// fixed — required for bit-for-bit batch-vs-sequential comparisons.
    pub adaptive: bool,
    /// Relative LTE tolerance.
    pub reltol: f64,
    /// Absolute LTE floor (volts / amps).
    pub abstol: f64,
    /// Hard cap on step attempts (accepted + rejected).
    pub max_steps: usize,
    pub ordering: solve::Ordering,
    /// Worker threads for per-RHS GMRES sweeps on the iterative path
    /// (the direct multi-RHS substitution is single-pass).
    pub workers: usize,
}

impl TranConfig {
    /// Adaptive TR-BDF2 sweep to `t_stop` starting from step `h0`.
    pub fn new(t_stop: f64, h0: f64) -> Self {
        TranConfig {
            t_stop,
            h0,
            h_min: h0 * 1e-4,
            h_max: t_stop,
            integrator: Integrator::TrBdf2,
            adaptive: true,
            reltol: 1e-5,
            abstol: 1e-9,
            max_steps: 2_000_000,
            ordering: solve::Ordering::Smart,
            workers: 1,
        }
    }

    /// Fixed-step sweep: exactly `h` per step (no LTE control).
    pub fn fixed_step(t_stop: f64, h: f64) -> Self {
        TranConfig { h_min: h, h_max: h, adaptive: false, ..TranConfig::new(t_stop, h) }
    }

    pub fn with_integrator(mut self, integrator: Integrator) -> Self {
        self.integrator = integrator;
        self
    }
}

/// Work counters of one transient sweep. `symbolic_analyses` is the pinned
/// contract: a fixed-topology sweep — any number of timesteps, any batch
/// width, any number of accepted `h` changes — performs exactly one.
#[derive(Debug, Clone, Default)]
pub struct TranStats {
    /// Pattern analyses performed (direct `Symbolic` + iterative ILU(0)).
    pub symbolic_analyses: usize,
    /// Numeric refactorizations (one per distinct stage matrix / `h`).
    pub refactorizations: usize,
    pub steps_accepted: usize,
    pub steps_rejected: usize,
    /// Linear multi-RHS solve calls (a whole batch counts one).
    pub solves: usize,
    /// Total GMRES iterations on the iterative path.
    pub gmres_iterations: u64,
    /// Iterative→direct fallbacks inside this sweep (also mirrored into
    /// the process-wide warm/cold counters, see [`super::solver_fallbacks`]).
    pub fallbacks: u64,
    /// Peak resident factor/preconditioner entries.
    pub peak_entries: usize,
}

impl TranStats {
    /// Fold another sweep's counters into this one (multi-segment reads
    /// report one merged record; `peak_entries` takes the max, everything
    /// else sums).
    pub fn absorb(&mut self, other: &TranStats) {
        self.symbolic_analyses += other.symbolic_analyses;
        self.refactorizations += other.refactorizations;
        self.steps_accepted += other.steps_accepted;
        self.steps_rejected += other.steps_rejected;
        self.solves += other.solves;
        self.gmres_iterations += other.gmres_iterations;
        self.fallbacks += other.fallbacks;
        self.peak_entries = self.peak_entries.max(other.peak_entries);
    }
}

/// Result of a transient sweep: a shared time grid plus per-column node
/// voltage trajectories.
#[derive(Debug, Clone)]
pub struct TranResult {
    /// Accepted time points, starting at 0.0 (the DC init point).
    pub times: Vec<f64>,
    /// `voltages[col][step][node]`; node 0 is ground (always 0.0), node
    /// indices match [`Circuit::node_named`].
    pub voltages: Vec<Vec<Vec<f64>>>,
    pub stats: TranStats,
}

/// Capacitor companion bookkeeping (indices into the unknown vector).
struct CapEl {
    p: usize,
    n: usize,
    c: f64,
}

/// Inductor companion bookkeeping; `br` is the branch-current unknown row.
struct IndEl {
    p: usize,
    n: usize,
    l: f64,
    br: usize,
}

/// Per-column integration state at the last accepted time point.
#[derive(Clone)]
struct ColState {
    /// Full unknown vector (node voltages then branch currents).
    x: Vec<f64>,
    /// Voltage across each capacitor.
    cap_v: Vec<f64>,
    /// Current through each capacitor (trapezoidal/TR-BDF2 history).
    cap_i: Vec<f64>,
    /// Current through each inductor.
    ind_i: Vec<f64>,
    /// Voltage across each inductor (trapezoidal history).
    ind_v: Vec<f64>,
}

/// Intermediate TR-BDF2 stage values (at `t + γh`).
struct MidVals {
    cap_v: Vec<f64>,
    ind_i: Vec<f64>,
}

/// How the accepted step advanced the dynamic-element history.
enum Update {
    Be { h: f64 },
    Trap { h: f64 },
    Bdf2 { h: f64, gamma: f64, mids: Vec<MidVals> },
}

const ILU_MAX_FAILS: u64 = 3;

/// Linear-solver state shared by every stage of a sweep: one `Symbolic`,
/// one `Numeric` per stage slot (TR-BDF2 uses two stage matrices), and an
/// optional ILU(0) for the iterative path.
struct TranSolver {
    dim: usize,
    n_nodes: usize,
    krylov_cfg: Option<krylov::KrylovCfg>,
    workers: usize,
    /// Dense-kernel backend inherited from the circuit at sweep start.
    kern: &'static dyn Backend,
    sym: Arc<factor::Symbolic>,
    nums: [factor::Numeric; 2],
    /// Stage coefficient currently assembled into each slot (NaN = none).
    keys: [f64; 2],
    syss: [Option<SparseSys>; 2],
    ilu: Option<krylov::Ilu0>,
    ilu_key: f64,
    ilu_ever_ok: bool,
    stats: TranStats,
}

impl TranSolver {
    fn new(
        sys0: &SparseSys,
        solver: krylov::SolverStrategy,
        choice: backend::BackendChoice,
        cfg: &TranConfig,
        dim: usize,
        n_nodes: usize,
    ) -> Result<Self> {
        let sym = Arc::new(
            factor::analyze(sys0, cfg.ordering).context("transient symbolic analysis")?,
        );
        let stats = TranStats { symbolic_analyses: 1, ..Default::default() };
        let krylov_cfg =
            if solver.wants_iterative(sys0.nnz()) { Some(solver.cfg()) } else { None };
        Ok(TranSolver {
            dim,
            n_nodes,
            krylov_cfg,
            workers: cfg.workers.max(1),
            kern: backend::resolve(choice),
            nums: [factor::Numeric::new(sym.clone()), factor::Numeric::new(sym.clone())],
            sym,
            keys: [f64::NAN, f64::NAN],
            syss: [None, None],
            ilu: None,
            ilu_key: f64::NAN,
            ilu_ever_ok: false,
            stats,
        })
    }

    /// Ensure slot `slot` holds the stamped system for stage coefficient
    /// `a` (restamp only on coefficient change).
    fn ensure_sys(&mut self, c: &Circuit, a: f64, slot: usize) -> Result<()> {
        if self.syss[slot].is_none() || self.keys[slot] != a {
            let v0 = vec![0.0; self.n_nodes];
            self.syss[slot] = Some(c.stamp_dyn(self.dim, self.n_nodes, &v0, a, a)?);
            self.keys[slot] = a;
            // force reassembly of the direct factor for this slot
            self.nums[slot] = factor::Numeric::new(self.sym.clone());
        }
        Ok(())
    }

    /// Iterative attempt: GMRES(m) off the locally cached ILU(0). `None`
    /// means fall back to direct (fallback counters already bumped).
    fn solve_iterative(&mut self, slot: usize, rhss: &[Vec<f64>]) -> Option<Vec<Vec<f64>>> {
        let cfg = self.krylov_cfg?;
        if self.stats.fallbacks >= ILU_MAX_FAILS {
            return None; // iterative path repeatedly failed: stay direct
        }
        self.syss[slot].as_ref()?;
        let a = self.keys[slot];
        let workers = self.workers;
        let kern = self.kern;
        // lift the preconditioner out of `self` so the closure below only
        // borrows locals alongside the `sys` borrow of `self.syss`
        let had_ilu = self.ilu.is_some();
        let mut ilu = self.ilu.take();
        let mut ilu_key = self.ilu_key;
        let sys = self.syss[slot].as_ref().expect("checked above");
        let attempt = (|| -> Result<(Vec<Vec<f64>>, solve::SolveStats)> {
            if ilu.is_none() {
                ilu = Some(krylov::Ilu0::analyze(sys)?);
                ilu_key = f64::NAN;
            }
            let pre = ilu.as_mut().expect("just ensured");
            if ilu_key != a {
                pre.assemble(sys)?;
                pre.factor()?;
                ilu_key = a;
            }
            let (xs, st) = krylov::gmres_batch_kern(sys, rhss, &*pre, &cfg, workers, kern)?;
            if !xs.iter().zip(rhss).all(|(x, b)| residual_ok(sys, b, x)) {
                bail!("transient: batch GMRES solution failed the residual gate");
            }
            Ok((xs, st))
        })();
        if !had_ilu && ilu.is_some() {
            self.stats.symbolic_analyses += 1; // ILU(0) pattern analysis
        }
        self.ilu = ilu;
        self.ilu_key = ilu_key;
        match attempt {
            Ok((xs, st)) => {
                self.stats.gmres_iterations += st.iterations as u64;
                self.stats.peak_entries = self.stats.peak_entries.max(st.peak_entries);
                self.ilu_ever_ok = true;
                Some(xs)
            }
            Err(_) => {
                self.stats.fallbacks += 1;
                // mirror into the process-wide counters with the same
                // warm/cold distinction as the DC engine: a previously
                // serving ILU failing mid-sweep is the staleness signal
                if self.ilu_ever_ok {
                    super::SOLVER_FALLBACKS.fetch_add(1, MemOrdering::Relaxed);
                } else {
                    super::SOLVER_COLD_FALLBACKS.fetch_add(1, MemOrdering::Relaxed);
                }
                None
            }
        }
    }

    /// Solve the stage system `(a, slot)` for all RHS columns.
    fn solve(
        &mut self,
        c: &Circuit,
        a: f64,
        slot: usize,
        rhss: &[Vec<f64>],
        certify: bool,
    ) -> Result<Vec<Vec<f64>>> {
        self.ensure_sys(c, a, slot)?;
        self.stats.solves += 1;
        if let Some(xs) = self.solve_iterative(slot, rhss) {
            return Ok(xs);
        }
        // direct factor path: refactor only when the slot was restamped
        let sys = self.syss[slot].as_ref().expect("ensured above");
        let num = &mut self.nums[slot];
        let unchanged = num
            .assemble(sys)
            .context("transient stamp pattern diverged from the cached symbolic")?;
        if !unchanged || !num.is_factored() {
            num.refactor().context("transient numeric refactorization")?;
            self.stats.refactorizations += 1;
        }
        let xs =
            num.solve_multi_kern(rhss, self.kern).context("transient multi-RHS substitution")?;
        self.stats.peak_entries = self.stats.peak_entries.max(num.stats().peak_entries);
        if certify && !xs.iter().zip(rhss).all(|(x, b)| residual_ok(sys, b, x)) {
            bail!("transient: factored solution failed the residual gate");
        }
        Ok(xs)
    }
}

/// Source-only RHS at time `t` for one column: like `Circuit::stamp_rhs`
/// but evaluating attached [`Waveform`]s at `t` and applying the column's
/// per-source amplitude multipliers (companion history terms are added by
/// the integrator stage).
fn stage_rhs(
    c: &Circuit,
    dim: usize,
    n_nodes: usize,
    t: f64,
    scale: &BTreeMap<usize, f64>,
) -> Vec<f64> {
    let mut b = vec![0.0; dim];
    let idx = |node: usize| node.checked_sub(1);
    let mut br = n_nodes - 1;
    for (ei, e) in c.elements.iter().enumerate() {
        let s = scale.get(&ei).copied().unwrap_or(1.0);
        match *e {
            Element::Resistor(..)
            | Element::Diode(..)
            | Element::Capacitor(..)
            | Element::Vccs(..) => {}
            Element::Isource(_, a, k, amps) => {
                let v = s * c.waves.get(&ei).map_or(amps, |w| w.eval(t));
                if let Some(i) = idx(a) {
                    b[i] -= v;
                }
                if let Some(j) = idx(k) {
                    b[j] += v;
                }
            }
            Element::Vsource(_, _, _, volts) => {
                b[br] += s * c.waves.get(&ei).map_or(volts, |w| w.eval(t));
                br += 1;
            }
            Element::Vcvs(..) | Element::Mult(..) | Element::Inductor(..) => {
                br += 1;
            }
        }
    }
    b
}

/// Node voltage from an unknown vector (ground folded back in).
fn node_v(x: &[f64], node: usize) -> f64 {
    if node == 0 {
        0.0
    } else {
        x[node - 1]
    }
}

/// Full node-voltage vector (index = node id) from an unknown vector.
fn to_node_voltages(x: &[f64], n_nodes: usize) -> Vec<f64> {
    let mut v = vec![0.0; n_nodes];
    v[1..].copy_from_slice(&x[..n_nodes - 1]);
    v
}

fn add_companions_be(b: &mut [f64], caps: &[CapEl], inds: &[IndEl], st: &ColState, h: f64) {
    for (k, cap) in caps.iter().enumerate() {
        let i_hist = cap.c / h * st.cap_v[k];
        if cap.p > 0 {
            b[cap.p - 1] += i_hist;
        }
        if cap.n > 0 {
            b[cap.n - 1] -= i_hist;
        }
    }
    for (k, ind) in inds.iter().enumerate() {
        b[ind.br] += -(ind.l / h) * st.ind_i[k];
    }
}

fn add_companions_trap(b: &mut [f64], caps: &[CapEl], inds: &[IndEl], st: &ColState, h: f64) {
    for (k, cap) in caps.iter().enumerate() {
        let i_hist = 2.0 * cap.c / h * st.cap_v[k] + st.cap_i[k];
        if cap.p > 0 {
            b[cap.p - 1] += i_hist;
        }
        if cap.n > 0 {
            b[cap.n - 1] -= i_hist;
        }
    }
    for (k, ind) in inds.iter().enumerate() {
        b[ind.br] += -st.ind_v[k] - (2.0 * ind.l / h) * st.ind_i[k];
    }
}

fn add_companions_bdf2(
    b: &mut [f64],
    caps: &[CapEl],
    inds: &[IndEl],
    st: &ColState,
    mid: &MidVals,
    h: f64,
    g: f64,
) {
    // BDF2 over the uneven pair (t_n, t_{n+γ}, t_{n+1}):
    //   dy/dt ≈ a·y_{n+1} − bb·y_{n+γ} + cc·y_n
    // with a = (2−γ)/((1−γ)h), bb = 1/(γ(1−γ)h), cc = (1−γ)/(γh)
    let bb = 1.0 / (g * (1.0 - g) * h);
    let cc = (1.0 - g) / (g * h);
    for (k, cap) in caps.iter().enumerate() {
        let i_hist = cap.c * (bb * mid.cap_v[k] - cc * st.cap_v[k]);
        if cap.p > 0 {
            b[cap.p - 1] += i_hist;
        }
        if cap.n > 0 {
            b[cap.n - 1] -= i_hist;
        }
    }
    for (k, ind) in inds.iter().enumerate() {
        b[ind.br] += -ind.l * (bb * mid.ind_i[k] - cc * st.ind_i[k]);
    }
}

/// Advance one column's dynamic-element history to the accepted solution.
fn update_state(
    st: &mut ColState,
    x: Vec<f64>,
    caps: &[CapEl],
    inds: &[IndEl],
    upd: &Update,
    col: usize,
) {
    for (k, cap) in caps.iter().enumerate() {
        let vc_new = node_v(&x, cap.p) - node_v(&x, cap.n);
        st.cap_i[k] = match upd {
            Update::Be { h } => cap.c / h * (vc_new - st.cap_v[k]),
            Update::Trap { h } => 2.0 * cap.c / h * (vc_new - st.cap_v[k]) - st.cap_i[k],
            Update::Bdf2 { h, gamma: g, mids } => {
                let a = (2.0 - g) / ((1.0 - g) * h);
                let bb = 1.0 / (g * (1.0 - g) * h);
                let cc = (1.0 - g) / (g * h);
                cap.c * (a * vc_new - bb * mids[col].cap_v[k] + cc * st.cap_v[k])
            }
        };
        st.cap_v[k] = vc_new;
    }
    for (k, ind) in inds.iter().enumerate() {
        st.ind_i[k] = x[ind.br];
        st.ind_v[k] = node_v(&x, ind.p) - node_v(&x, ind.n);
    }
    st.x = x;
}

/// Transient sweep of a linear circuit over a batch of RHS columns.
///
/// Each entry of `scales` describes one column as `(element index,
/// amplitude multiplier)` pairs (see [`Circuit::vsource_index`] /
/// [`Circuit::vsource_wave`]): the column's value for that source is
/// `multiplier × (waveform sample | static value)`; unlisted sources keep
/// multiplier 1. Pass `&[Vec::new()]` (or use [`Circuit::tran`]) for a
/// single unscaled column.
///
/// The run starts from the batched DC operating point at t = 0 (caps
/// open, inductors short, waveforms at their t=0 samples) and integrates
/// to `cfg.t_stop`. Nonlinear elements (D, Mult) are rejected — read
/// pulses through the memristor fabric are linear RC networks.
pub fn tran_batch(
    c: &Circuit,
    cfg: &TranConfig,
    scales: &[Vec<(usize, f64)>],
) -> Result<TranResult> {
    if scales.is_empty() {
        return Ok(TranResult {
            times: Vec::new(),
            voltages: Vec::new(),
            stats: TranStats::default(),
        });
    }
    if !(cfg.t_stop > 0.0 && cfg.h0 > 0.0 && cfg.h_min > 0.0 && cfg.h_min <= cfg.h_max) {
        bail!(
            "invalid TranConfig: t_stop {} h0 {} h_min {} h_max {}",
            cfg.t_stop,
            cfg.h0,
            cfg.h_min,
            cfg.h_max
        );
    }
    if let Some(e) = c
        .elements
        .iter()
        .find(|e| matches!(e, Element::Diode(..) | Element::Mult(..)))
    {
        bail!(
            "transient analysis supports linear circuits (R/V/I/E/C/L); found nonlinear element {}",
            e.name()
        );
    }

    let mut sp = crate::span!("tran_batch", cols = scales.len());

    let n_nodes = c.node_count();
    let n_br = c.num_branches();
    let dim = (n_nodes - 1) + n_br;

    // dynamic elements + their branch rows (same walk order as stamp)
    let mut caps = Vec::new();
    let mut inds = Vec::new();
    {
        let mut br = n_nodes - 1;
        for e in &c.elements {
            match *e {
                Element::Vsource(..) | Element::Vcvs(..) | Element::Mult(..) => br += 1,
                Element::Capacitor(_, a, b, farads) => caps.push(CapEl { p: a, n: b, c: farads }),
                Element::Inductor(_, a, b, henries) => {
                    inds.push(IndEl { p: a, n: b, l: henries, br });
                    br += 1;
                }
                _ => {}
            }
        }
    }

    let col_scales: Vec<BTreeMap<usize, f64>> =
        scales.iter().map(|ov| ov.iter().copied().collect()).collect();
    let ncols = col_scales.len();

    // one symbolic analysis on the DC-init stamp serves the whole sweep
    let v0 = vec![0.0; n_nodes];
    let sys0 = c.stamp_dyn(dim, n_nodes, &v0, 0.0, 0.0)?;
    let mut solver = TranSolver::new(&sys0, c.solver(), c.backend(), cfg, dim, n_nodes)?;

    // batched DC operating point at t = 0 (certified: a bad factorization
    // would poison every step after it)
    let rhss0: Vec<Vec<f64>> =
        col_scales.iter().map(|s| stage_rhs(c, dim, n_nodes, 0.0, s)).collect();
    let xs0 = solver.solve(c, 0.0, 0, &rhss0, true)?;

    let mut states: Vec<ColState> = xs0
        .into_iter()
        .map(|x| {
            let cap_v = caps.iter().map(|cp| node_v(&x, cp.p) - node_v(&x, cp.n)).collect();
            let ind_i = inds.iter().map(|l| x[l.br]).collect();
            ColState {
                cap_v,
                cap_i: vec![0.0; caps.len()],
                ind_i,
                ind_v: vec![0.0; inds.len()],
                x,
            }
        })
        .collect();

    let mut times = vec![0.0];
    let mut voltages: Vec<Vec<Vec<f64>>> =
        states.iter().map(|s| vec![to_node_voltages(&s.x, n_nodes)]).collect();

    // Consistent 0⁺ initialization (the classic trapezoidal startup
    // problem): the DC point holds the t = 0⁻ histories — zero capacitor
    // current, zero inductor voltage — but a rise-0 pulse edge jumps the
    // sources at 0⁺, and trapezoidal/TR-BDF2 would drag that stale
    // history through the whole sweep as an O(h) startup error. One
    // backward-Euler micro-step (h → 0 limit, state effectively held)
    // computes the element currents/voltages just after the jump; only
    // the integration state advances — the recorded grid keeps the DC
    // sample at t = 0. The 1e-6 scale keeps the held-state error tiny
    // without inviting fp cancellation in the C/h·Δv history update.
    if !caps.is_empty() || !inds.is_empty() {
        let h_init = cfg.h0.min(cfg.t_stop) * 1e-6;
        let rhss: Vec<Vec<f64>> = col_scales
            .iter()
            .zip(&states)
            .map(|(s, st)| {
                let mut b = stage_rhs(c, dim, n_nodes, h_init, s);
                add_companions_be(&mut b, &caps, &inds, st, h_init);
                b
            })
            .collect();
        let xs = solver.solve(c, 1.0 / h_init, 0, &rhss, false)?;
        let upd = Update::Be { h: h_init };
        for (col, x) in xs.into_iter().enumerate() {
            update_state(&mut states[col], x, &caps, &inds, &upd, col);
        }
    }

    let gamma = 2.0 - std::f64::consts::SQRT_2;
    let order = cfg.integrator.order() as f64;
    let mut t = 0.0f64;
    let mut h = cfg.h0.clamp(cfg.h_min, cfg.h_max);
    // previous accepted point for the linear LTE predictor
    let mut prev: Option<(f64, Vec<Vec<f64>>)> = None;
    let mut attempts = 0usize;

    while t < cfg.t_stop * (1.0 - 1e-12) {
        attempts += 1;
        if attempts > cfg.max_steps {
            bail!(
                "transient exceeded max_steps {} at t = {t:.3e} (h = {h:.3e})",
                cfg.max_steps
            );
        }
        let h_eff = h.min(cfg.t_stop - t);

        // one integrator step for every column (state untouched until accept)
        let (new_xs, upd) = match cfg.integrator {
            Integrator::BackwardEuler => {
                let a = 1.0 / h_eff;
                let rhss: Vec<Vec<f64>> = col_scales
                    .iter()
                    .zip(&states)
                    .map(|(s, st)| {
                        let mut b = stage_rhs(c, dim, n_nodes, t + h_eff, s);
                        add_companions_be(&mut b, &caps, &inds, st, h_eff);
                        b
                    })
                    .collect();
                (solver.solve(c, a, 0, &rhss, false)?, Update::Be { h: h_eff })
            }
            Integrator::Trapezoidal => {
                let a = 2.0 / h_eff;
                let rhss: Vec<Vec<f64>> = col_scales
                    .iter()
                    .zip(&states)
                    .map(|(s, st)| {
                        let mut b = stage_rhs(c, dim, n_nodes, t + h_eff, s);
                        add_companions_trap(&mut b, &caps, &inds, st, h_eff);
                        b
                    })
                    .collect();
                (solver.solve(c, a, 0, &rhss, false)?, Update::Trap { h: h_eff })
            }
            Integrator::TrBdf2 => {
                // stage 1: trapezoidal over γh
                let h1 = gamma * h_eff;
                let a1 = 2.0 / h1;
                let rhss1: Vec<Vec<f64>> = col_scales
                    .iter()
                    .zip(&states)
                    .map(|(s, st)| {
                        let mut b = stage_rhs(c, dim, n_nodes, t + h1, s);
                        add_companions_trap(&mut b, &caps, &inds, st, h1);
                        b
                    })
                    .collect();
                let xg = solver.solve(c, a1, 0, &rhss1, false)?;
                let mids: Vec<MidVals> = xg
                    .iter()
                    .map(|x| MidVals {
                        cap_v: caps.iter().map(|cp| node_v(x, cp.p) - node_v(x, cp.n)).collect(),
                        ind_i: inds.iter().map(|l| x[l.br]).collect(),
                    })
                    .collect();
                // stage 2: BDF2 over (t, t+γh, t+h) — own Numeric slot so a
                // fixed-h run refactors each stage matrix once, not per step
                let a2 = (2.0 - gamma) / ((1.0 - gamma) * h_eff);
                let rhss2: Vec<Vec<f64>> = col_scales
                    .iter()
                    .zip(&states)
                    .zip(&mids)
                    .map(|((s, st), mid)| {
                        let mut b = stage_rhs(c, dim, n_nodes, t + h_eff, s);
                        add_companions_bdf2(&mut b, &caps, &inds, st, mid, h_eff, gamma);
                        b
                    })
                    .collect();
                (
                    solver.solve(c, a2, 1, &rhss2, false)?,
                    Update::Bdf2 { h: h_eff, gamma, mids },
                )
            }
        };

        // LTE estimate against the linear predictor from the last two
        // accepted points; max over the whole batch so every column shares
        // one time grid (and one matrix per step)
        let err = match (&prev, cfg.adaptive) {
            (Some((h_prev, xs_prev)), true) => {
                let r = h_eff / h_prev;
                let mut e = 0.0f64;
                for (col, new_x) in new_xs.iter().enumerate() {
                    let x_n = &states[col].x;
                    let x_p = &xs_prev[col];
                    for k in 0..dim {
                        let pred = x_n[k] + (x_n[k] - x_p[k]) * r;
                        let scale =
                            cfg.abstol + cfg.reltol * new_x[k].abs().max(x_n[k].abs());
                        e = e.max((new_x[k] - pred).abs() / scale);
                    }
                }
                e
            }
            _ => 0.0,
        };

        if cfg.adaptive && err > 1.0 && h_eff > cfg.h_min * 1.000001 {
            // reject: shrink and retry from the same state
            solver.stats.steps_rejected += 1;
            let fac = (0.9 * err.powf(-1.0 / (order + 1.0))).clamp(0.1, 0.5);
            h = (h_eff * fac).max(cfg.h_min);
            continue;
        }

        // accept
        let old_xs: Vec<Vec<f64>> = states.iter().map(|s| s.x.clone()).collect();
        for (col, x) in new_xs.into_iter().enumerate() {
            update_state(&mut states[col], x, &caps, &inds, &upd, col);
        }
        prev = Some((h_eff, old_xs));
        t += h_eff;
        times.push(t);
        for (col, st) in states.iter().enumerate() {
            voltages[col].push(to_node_voltages(&st.x, n_nodes));
        }
        solver.stats.steps_accepted += 1;
        if cfg.adaptive && err > 0.0 {
            let fac = (0.9 * err.powf(-1.0 / (order + 1.0))).clamp(0.2, 5.0);
            h = (h_eff * fac).clamp(cfg.h_min, cfg.h_max);
        }
    }

    debug_assert_eq!(voltages.len(), ncols);
    sp.set_arg("steps", solver.stats.steps_accepted as f64);
    sp.set_arg("solves", solver.stats.solves as f64);
    Ok(TranResult { times, voltages, stats: solver.stats })
}

impl Circuit {
    /// Single-column transient sweep (see [`tran_batch`]).
    pub fn tran(&self, cfg: &TranConfig) -> Result<TranResult> {
        tran_batch(self, cfg, &[Vec::new()])
    }

    /// Batched transient sweep over per-column source amplitude
    /// multipliers (see [`tran_batch`]).
    pub fn tran_batch(
        &self,
        cfg: &TranConfig,
        scales: &[Vec<(usize, f64)>],
    ) -> Result<TranResult> {
        tran_batch(self, cfg, scales)
    }
}

/// Integrated energy (J) dissipated over the sweep in every resistor whose
/// name starts with `prefix` ("RM" = the memristor devices of an emitted
/// crossbar netlist), for column `col`: trapezoidal `∫ Σ (Δv)²/R dt` over
/// the stored trajectory.
pub fn resistor_energy(c: &Circuit, res: &TranResult, col: usize, prefix: &str) -> f64 {
    let rs: Vec<(usize, usize, f64)> = c
        .elements
        .iter()
        .filter_map(|e| match e {
            Element::Resistor(n, a, b, r) if n.starts_with(prefix) => Some((*a, *b, *r)),
            _ => None,
        })
        .collect();
    if rs.is_empty() || res.times.len() < 2 {
        return 0.0;
    }
    let power = |v: &[f64]| -> f64 {
        rs.iter()
            .map(|&(a, b, r)| {
                let dv = v[a] - v[b];
                dv * dv / r
            })
            .sum()
    };
    let traj = &res.voltages[col];
    let mut e = 0.0;
    let mut p_prev = power(&traj[0]);
    for k in 1..res.times.len() {
        let p = power(&traj[k]);
        e += 0.5 * (p_prev + p) * (res.times[k] - res.times[k - 1]);
        p_prev = p;
    }
    e
}

/// Settling time (s) of column `col`: the earliest time after which every
/// watched node stays within `rtol·|v_final|` (plus a tiny absolute floor)
/// of its final value. Returns 0.0 if already settled at t = 0.
pub fn settling_time(res: &TranResult, col: usize, nodes: &[usize], rtol: f64) -> f64 {
    let traj = &res.voltages[col];
    let Some(last) = traj.last() else { return 0.0 };
    let tol: Vec<f64> =
        nodes.iter().map(|&n| rtol * last[n].abs() + 1e-12).collect();
    for k in (0..traj.len()).rev() {
        let outside = nodes
            .iter()
            .zip(&tol)
            .any(|(&n, &tl)| (traj[k][n] - last[n]).abs() > tl);
        if outside {
            return res.times[(k + 1).min(res.times.len() - 1)];
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waveform_pulse_golden() {
        let w = Waveform::Pulse {
            v1: 0.0,
            v2: 2.0,
            delay: 1.0,
            rise: 0.5,
            fall: 0.5,
            width: 2.0,
            period: 0.0,
        };
        assert_eq!(w.eval(0.0), 0.0);
        assert_eq!(w.eval(1.0), 0.0);
        assert!((w.eval(1.25) - 1.0).abs() < 1e-12); // mid-rise
        assert_eq!(w.eval(2.0), 2.0);
        assert_eq!(w.eval(3.4), 2.0);
        assert!((w.eval(3.75) - 1.0).abs() < 1e-12); // mid-fall
        assert_eq!(w.eval(5.0), 0.0);
        // periodic repeat
        let wp = Waveform::Pulse {
            v1: -1.0,
            v2: 1.0,
            delay: 0.0,
            rise: 0.0,
            fall: 0.0,
            width: 1.0,
            period: 2.0,
        };
        assert_eq!(wp.eval(0.0), -1.0); // t=0 sample is v1 by convention
        assert_eq!(wp.eval(0.5), 1.0);
        assert_eq!(wp.eval(1.5), -1.0);
        assert_eq!(wp.eval(2.5), 1.0);
        assert_eq!(wp.eval(3.5), -1.0);
    }

    #[test]
    fn waveform_sin_golden() {
        let w = Waveform::Sin { offset: 0.5, ampl: 2.0, freq: 10.0, delay: 0.1, damping: 0.0 };
        assert_eq!(w.eval(0.0), 0.5);
        assert_eq!(w.eval(0.1), 0.5);
        assert!((w.eval(0.1 + 0.025) - 2.5).abs() < 1e-9); // quarter period peak
        assert!((w.eval(0.1 + 0.05) - 0.5).abs() < 1e-9); // half period
        let wd = Waveform::Sin { offset: 0.0, ampl: 1.0, freq: 10.0, delay: 0.0, damping: 10.0 };
        let peak1 = wd.eval(0.025);
        let peak2 = wd.eval(0.125);
        assert!(peak1 > 0.0 && peak2 > 0.0 && peak2 < peak1, "damped envelope");
    }

    #[test]
    fn waveform_pwl_golden() {
        let w = Waveform::Pwl(vec![(0.0, 0.0), (1.0, 1.0), (3.0, -1.0)]);
        assert_eq!(w.eval(-1.0), 0.0);
        assert!((w.eval(0.5) - 0.5).abs() < 1e-12);
        assert_eq!(w.eval(1.0), 1.0);
        assert!((w.eval(2.0) - 0.0).abs() < 1e-12);
        assert_eq!(w.eval(10.0), -1.0);
        // vertical step segment doesn't divide by zero
        let s = Waveform::Pwl(vec![(0.0, 0.0), (1.0, 0.0), (1.0, 5.0), (2.0, 5.0)]);
        assert_eq!(s.eval(1.5), 5.0);
        assert_eq!(Waveform::Pwl(Vec::new()).eval(1.0), 0.0);
    }

    /// V —R— n1 —C— gnd with a unit step at t=0.
    fn rc_circuit(r: f64, cap: f64, v: f64) -> (Circuit, usize) {
        let mut c = Circuit::new("rc");
        let vin = c.node("in");
        let n1 = c.node("n1");
        c.vsource_wave(
            "V1",
            vin,
            0,
            Waveform::Pulse {
                v1: 0.0,
                v2: v,
                delay: 0.0,
                rise: 0.0,
                fall: 0.0,
                width: 1e9,
                period: 0.0,
            },
        );
        c.resistor("R1", vin, n1, r);
        c.capacitor("C1", n1, 0, cap);
        (c, n1)
    }

    /// Sup-norm error of the simulated RC charge vs V(1−e^{−t/τ}),
    /// normalized by the step amplitude.
    fn rc_max_err(integrator: Integrator, h_over_tau: f64, t_stop_over_tau: f64) -> f64 {
        let (r, cap, v) = (1000.0, 1e-6, 1.0);
        let tau = r * cap;
        let (c, n1) = rc_circuit(r, cap, v);
        let cfg = TranConfig::fixed_step(t_stop_over_tau * tau, h_over_tau * tau)
            .with_integrator(integrator);
        let res = c.tran(&cfg).unwrap();
        let mut err = 0.0f64;
        for (k, &t) in res.times.iter().enumerate() {
            let exact = v * (1.0 - (-t / tau).exp());
            err = err.max((res.voltages[0][k][n1] - exact).abs() / v);
        }
        err
    }

    #[test]
    fn rc_step_response_backward_euler_tight() {
        // order 1: error ~ (h/2τ)·t/τ·e^{−t/τ} — h = τ/2e5 over 0.1τ
        // lands near 2e-7, comfortably under the 1e-6 acceptance gate
        let err = rc_max_err(Integrator::BackwardEuler, 5e-6, 0.1);
        assert!(err <= 1e-6, "BE error {err:.3e}");
    }

    #[test]
    fn rc_step_response_trapezoidal_tight() {
        let err = rc_max_err(Integrator::Trapezoidal, 5e-4, 1.0);
        assert!(err <= 1e-6, "trapezoidal error {err:.3e}");
    }

    #[test]
    fn rc_step_response_trbdf2_tight() {
        let err = rc_max_err(Integrator::TrBdf2, 5e-4, 1.0);
        assert!(err <= 1e-6, "TR-BDF2 error {err:.3e}");
    }

    #[test]
    fn be_halving_h_reduces_error() {
        let coarse = rc_max_err(Integrator::BackwardEuler, 1e-2, 1.0);
        let fine = rc_max_err(Integrator::BackwardEuler, 5e-3, 1.0);
        assert!(fine < coarse, "halved h must reduce error: {fine:.3e} vs {coarse:.3e}");
        // order 1: roughly linear in h
        assert!(fine > coarse * 0.3, "error should shrink ~2x, not collapse");
    }

    #[test]
    fn rl_step_response_matches_closed_form() {
        // V —R— n1 —L— gnd: v(n1) = V·e^{−tR/L}
        let (r, l, v) = (100.0, 1e-3, 2.0);
        let tau = l / r;
        let mut c = Circuit::new("rl");
        let vin = c.node("in");
        let n1 = c.node("n1");
        c.vsource_wave(
            "V1",
            vin,
            0,
            Waveform::Pulse {
                v1: 0.0,
                v2: v,
                delay: 0.0,
                rise: 0.0,
                fall: 0.0,
                width: 1e9,
                period: 0.0,
            },
        );
        c.resistor("R1", vin, n1, r);
        c.inductor("L1", n1, 0, l);
        let cfg = TranConfig::fixed_step(tau, tau / 2000.0)
            .with_integrator(Integrator::Trapezoidal);
        let res = c.tran(&cfg).unwrap();
        let mut err = 0.0f64;
        for (k, &t) in res.times.iter().enumerate() {
            // v(0+) = V (inductor current continuous at 0): skip the DC
            // init sample, which legitimately holds the t=0⁻ short
            if k == 0 {
                continue;
            }
            let exact = v * (-t / tau).exp();
            err = err.max((res.voltages[0][k][n1] - exact).abs() / v);
        }
        assert!(err <= 1e-5, "RL error {err:.3e}");
    }

    #[test]
    fn trapezoidal_rings_where_trbdf2_damps() {
        // stiff step: h = 10τ (z = −10). Trapezoidal's amplification
        // −(1−5)/(1+5) = −2/3 rings slowly around the final value (first
        // sample overshoots to ~1.67V, |error| still ~4% after 8 steps);
        // TR-BDF2's R(−10) ≈ −0.204 damps geometrically — one bounded
        // ~20% excursion, then microvolts.
        let (r, cap, v) = (1000.0, 1e-6, 1.0);
        let tau = r * cap;
        let (c, n1) = rc_circuit(r, cap, v);
        let h = 10.0 * tau;
        let run = |integ: Integrator| {
            let res = c.tran(&TranConfig::fixed_step(8.0 * h, h).with_integrator(integ)).unwrap();
            let traj: Vec<f64> = res.voltages[0].iter().map(|vs| vs[n1]).collect();
            let overshoot = traj.iter().fold(0.0f64, |m, &x| m.max(x - v));
            let ring_samples = traj.iter().filter(|&&x| x > v * 1.05).count();
            let final_err = (traj.last().unwrap() - v).abs();
            (overshoot, ring_samples, final_err)
        };

        let (trap_over, trap_rings, trap_final) = run(Integrator::Trapezoidal);
        assert!(trap_over > 0.5 * v, "trap first sample must overshoot hard: {trap_over}");
        assert!(trap_rings >= 3, "trap must keep ringing above +5%: {trap_rings} samples");
        assert!(trap_final > 1e-2 * v, "trap error persists after 8 steps: {trap_final:e}");

        let (bdf_over, bdf_rings, bdf_final) = run(Integrator::TrBdf2);
        assert!(bdf_over < 0.25 * v, "TR-BDF2 excursion bounded: {bdf_over}");
        assert!(bdf_rings <= 1, "TR-BDF2 damps after one excursion: {bdf_rings} samples");
        assert!(bdf_final < 1e-3 * v, "TR-BDF2 settles: {bdf_final:e}");
        assert!(bdf_over < trap_over / 2.0, "TR-BDF2 strictly better damped");
    }

    #[test]
    fn adaptive_controller_rejects_on_pulse_edge() {
        let (r, cap, v) = (1000.0, 1e-6, 1.0);
        let tau = r * cap;
        let mut c = Circuit::new("adapt");
        let vin = c.node("in");
        let n1 = c.node("n1");
        c.vsource_wave(
            "V1",
            vin,
            0,
            Waveform::Pulse {
                v1: 0.0,
                v2: v,
                delay: 5.0 * tau,
                rise: tau / 100.0,
                fall: tau / 100.0,
                width: 1e9,
                period: 0.0,
            },
        );
        c.resistor("R1", vin, n1, r);
        c.capacitor("C1", n1, 0, cap);
        let mut cfg = TranConfig::new(15.0 * tau, tau / 2.0);
        cfg.h_min = tau * 1e-5;
        cfg.reltol = 1e-5;
        let res = c.tran(&cfg).unwrap();
        assert!(res.stats.steps_rejected > 0, "edge must force rejections");
        assert!(res.stats.steps_accepted > 10);
        assert_eq!(res.stats.symbolic_analyses, 1, "h changes are refactors only");
        let end = res.voltages[0].last().unwrap()[n1];
        assert!((end - v).abs() < 1e-3, "settled to the pulse top: {end}");
    }

    #[test]
    fn batched_sweep_one_symbolic_and_bitwise_equal_to_sequential() {
        // 64-RHS fixed-step sweep: exactly one symbolic analysis, and each
        // column bit-for-bit equal to its own single-column run
        let (r, cap, v) = (1000.0, 1e-6, 1.0);
        let tau = r * cap;
        let (c, _n1) = rc_circuit(r, cap, v);
        let src = 0usize; // V1 is element 0
        let cfg = TranConfig::fixed_step(tau, tau / 100.0)
            .with_integrator(Integrator::TrBdf2);
        let scales: Vec<Vec<(usize, f64)>> =
            (0..64).map(|k| vec![(src, 0.1 + 0.9 * (k as f64) / 63.0)]).collect();
        let batch = c.tran_batch(&cfg, &scales).unwrap();
        assert_eq!(batch.stats.symbolic_analyses, 1, "one Symbolic for 64 RHS x all steps");
        assert_eq!(batch.voltages.len(), 64);
        for (col, sc) in scales.iter().enumerate() {
            let single = c.tran_batch(&cfg, std::slice::from_ref(sc)).unwrap();
            assert_eq!(single.times.len(), batch.times.len());
            for (k, (bv, sv)) in
                batch.voltages[col].iter().zip(&single.voltages[0]).enumerate()
            {
                for (a, b) in bv.iter().zip(sv) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "col {col} step {k}: batch {a:e} vs sequential {b:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn rc_dissipated_energy_matches_half_cv_squared() {
        // charging a cap through a resistor dissipates exactly ½CV² in the
        // resistor, independent of R — a physics pin on resistor_energy
        let (r, cap, v) = (1000.0, 1e-6, 2.0);
        let tau = r * cap;
        let (c, _n1) = rc_circuit(r, cap, v);
        let cfg = TranConfig::fixed_step(12.0 * tau, tau / 500.0)
            .with_integrator(Integrator::Trapezoidal);
        let res = c.tran(&cfg).unwrap();
        let e = resistor_energy(&c, &res, 0, "R");
        let expect = 0.5 * cap * v * v;
        assert!(
            (e - expect).abs() / expect < 1e-2,
            "energy {e:.4e} vs ½CV² {expect:.4e}"
        );
    }

    #[test]
    fn settling_time_of_rc_charge() {
        let (r, cap, v) = (1000.0, 1e-6, 1.0);
        let tau = r * cap;
        let (c, n1) = rc_circuit(r, cap, v);
        let cfg = TranConfig::fixed_step(10.0 * tau, tau / 200.0)
            .with_integrator(Integrator::TrBdf2);
        let res = c.tran(&cfg).unwrap();
        // 1% settling of a first-order step is at t = ln(100)·τ ≈ 4.6τ
        let ts = settling_time(&res, 0, &[n1], 0.01);
        assert!(
            ts > 4.0 * tau && ts < 5.5 * tau,
            "1% settle {:.2}τ",
            ts / tau
        );
    }

    #[test]
    fn nonlinear_circuits_rejected() {
        let mut c = Circuit::new("nl");
        let vin = c.node("in");
        let mid = c.node("mid");
        c.vsource("V1", vin, 0, 1.0);
        c.resistor("R1", vin, mid, 1000.0);
        c.diode("D1", mid, 0);
        let err = c.tran(&TranConfig::new(1e-3, 1e-5)).unwrap_err();
        assert!(err.to_string().contains("linear"), "{err}");
    }

    #[test]
    fn dc_cache_untouched_by_transient_run() {
        // interleaving tran with dc_op must keep the DC factor cache warm:
        // the second dc_op is still a pure re-solve that matches reference
        let (c, n1) = rc_circuit(1000.0, 1e-6, 1.0);
        let mut c = c;
        c.set_vsource("V1", 1.0).unwrap();
        let v_before = c.dc_op().unwrap()[n1];
        let _ = c.tran(&TranConfig::fixed_step(1e-3, 1e-5)).unwrap();
        let v_after = c.dc_op().unwrap()[n1];
        assert_eq!(v_before.to_bits(), v_after.to_bits());
    }
}
