//! Factor-once / solve-many sparse LU engine.
//!
//! The reference solver ([`SparseSys::solve_with_stats`]) re-runs hash-map
//! Gaussian elimination from scratch on every call. Real SPICE engines
//! (ngspice, Spicier's faer-backed solver) split the work:
//!
//! * [`Symbolic`] — computed **once per circuit topology**: elimination
//!   order, pivot rows, the full fill pattern of L+U, and a flat "program"
//!   of update operations expressed as indices into a contiguous value
//!   array. Pivot selection preserves the reference semantics for both
//!   [`Ordering::Natural`] (partial pivoting in node order) and
//!   [`Ordering::Smart`] (Markowitz-lite sparsest-pivot preference), using
//!   the values present at analysis time for the magnitude guards.
//! * [`Numeric`] — re-assembles new element values into the fixed pattern
//!   (`refactor`, O(flops) with zero hashing) and substitutes right-hand
//!   sides (`solve` / `solve_multi`, O(nnz(L+U)) each).
//!
//! The pattern recorded by [`Symbolic`] is a *structural superset*: every
//! entry that can appear for *any* value assignment with the same triplet
//! stream is given a slot, so a cached factorization stays valid when only
//! element values change (Newton companion updates, reprogrammed sources).
//! Values that happen to cancel numerically simply ride along as zeros.
//!
//! Robustness: `refactor` rejects pivots that collapse below `1e-300`; the
//! caller ([`crate::spice::Circuit`]) additionally residual-checks factored
//! solutions and falls back to a fresh analysis (and ultimately to the
//! reference solver) if the fixed pivot order has gone stale for the new
//! values — so the factored path is never *less* accurate than the
//! reference within the 1e-9 test tolerances.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::backend::{self, Backend, LuLowerParts, LuUpperParts};

use super::solve::{Ordering, SolveStats, SparseSys};

/// Topology-dependent half of the factorization: elimination order, fill
/// pattern and the flat update program. Value-independent (reusable across
/// refactors); cheap to share via `Arc`.
#[derive(Debug)]
pub struct Symbolic {
    pub n: usize,
    pub ordering: Ordering,
    /// total value slots (assembled entries + fill-in)
    n_slots: usize,
    /// slots assembled straight from triplets (the rest is fill)
    n_assembled: usize,
    /// (i, j) of every triplet in the stream this analysis was built from —
    /// a cached factorization only applies to an identical stream
    pattern: Vec<(u32, u32)>,
    /// triplet k accumulates into `vals[triplet_slot[k]]`
    triplet_slot: Vec<usize>,
    /// (col, pivot_row) in elimination order; len == n on success
    pivots: Vec<(usize, usize)>,
    /// U row of pivot p: entries u_ptr[p]..u_ptr[p+1] of (u_cols, u_slots),
    /// diagonal (col, slot) first, then off-diagonals sorted by column
    u_ptr: Vec<usize>,
    u_cols: Vec<usize>,
    u_slots: Vec<usize>,
    /// elimination targets of pivot p: l_ptr[p]..l_ptr[p+1]
    l_ptr: Vec<usize>,
    /// target row id (for RHS forward substitution)
    l_rows: Vec<usize>,
    /// slot holding a[target, col] at elimination time (the L numerator)
    l_slots: Vec<usize>,
    /// update destinations of target t: op_ptr[t]..op_ptr[t+1]; aligned
    /// one-to-one with the pivot's off-diagonal U entries
    op_ptr: Vec<usize>,
    op_dst: Vec<usize>,
}

impl Symbolic {
    /// Does this analysis apply to `sys`? True iff the triplet (i, j)
    /// stream is identical (same stamp order, same topology).
    pub fn matches(&self, sys: &SparseSys) -> bool {
        sys.n == self.n && super::solve::pattern_matches(&self.pattern, sys)
    }

    /// Resident L+U entries (assembled + fill + multipliers) — the Fig 7
    /// memory counter for the factored path.
    pub fn factor_entries(&self) -> usize {
        self.n_slots + self.l_rows.len()
    }

    /// Entries assembled straight from the triplet stream (deduplicated
    /// pattern, before any fill).
    pub fn assembled_entries(&self) -> usize {
        self.n_assembled
    }

    /// Fill-in entries the elimination added on top of the assembled
    /// pattern (0 for the segmented/Smart crossbar systems — the paper's
    /// near-linear regime).
    pub fn fill_entries(&self) -> usize {
        self.n_slots - self.n_assembled
    }

    pub fn stats(&self) -> SolveStats {
        SolveStats::direct(self.factor_entries(), self.n)
    }
}

/// Analyze `sys`: run one pivoting elimination over hash rows (same
/// selection rules as the reference solver) while recording the fill
/// pattern and update program for fast numeric replay.
pub fn analyze(sys: &SparseSys, ordering: Ordering) -> Result<Symbolic> {
    let n = sys.n;
    // assemble: rows of col -> (value, slot); slots number the dedup pattern
    let mut rows: Vec<HashMap<usize, (f64, usize)>> = vec![HashMap::new(); n];
    let mut pattern = Vec::new();
    let mut triplet_slot = Vec::new();
    let mut n_slots = 0usize;
    for &(i, j, v) in sys.iter_triplets() {
        if i >= n || j >= n {
            bail!("factor: triplet ({i},{j}) out of range for n={n}");
        }
        pattern.push((i as u32, j as u32));
        let e = rows[i].entry(j).or_insert_with(|| {
            let s = n_slots;
            n_slots += 1;
            (0.0, s)
        });
        e.0 += v;
        triplet_slot.push(e.1);
    }
    let n_assembled = n_slots;

    // column -> candidate rows (may hold stale ids, pruned lazily)
    let mut col_rows: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, r) in rows.iter().enumerate() {
        for &j in r.keys() {
            col_rows[j].push(i);
        }
    }
    let col_order: Vec<usize> = match ordering {
        Ordering::Natural => (0..n).collect(),
        Ordering::Smart => {
            let mut order: Vec<usize> = (0..n).collect();
            let counts: Vec<usize> = (0..n).map(|j| col_rows[j].len()).collect();
            order.sort_by_key(|&j| counts[j]);
            order
        }
    };

    let mut used = vec![false; n];
    let mut pivots = Vec::with_capacity(n);
    let mut u_ptr = vec![0usize];
    let mut u_cols = Vec::new();
    let mut u_slots = Vec::new();
    let mut l_ptr = vec![0usize];
    let mut l_rows = Vec::new();
    let mut l_slots = Vec::new();
    let mut op_ptr = vec![0usize];
    let mut op_dst = Vec::new();

    // The recorded program is O(elimination flops) memory. Orderings that
    // flood with fill (Natural on big monolithic crossbars) would trade the
    // reference solver's time pathology for a memory pathology, so cap the
    // program at a generous multiple of the input (crossbar systems measure
    // well under 1x) and let the caller fall back to the reference solver.
    let max_ops = 8 * pattern.len().max(65_536);

    for &col in &col_order {
        // pivot selection — identical rules to the reference solver:
        // candidates are unused rows with a *numerically nonzero* entry
        let mut best: Option<(usize, f64, usize)> = None; // (row, |v|, nnz)
        let mut targets: Vec<usize> = Vec::with_capacity(col_rows[col].len());
        for &r in &col_rows[col] {
            if used[r] {
                continue;
            }
            let Some(&(v, _)) = rows[r].get(&col) else { continue };
            // structural target regardless of value (superset pattern)
            targets.push(r);
            if v == 0.0 {
                continue;
            }
            let av = v.abs();
            let nz = rows[r].len();
            let better = match (ordering, best) {
                (_, None) => true,
                (Ordering::Natural, Some((_, bv, _))) => av > bv,
                (Ordering::Smart, Some((_, bv, bn))) => {
                    (nz < bn && av > 1e-3 * bv) || (av > 1e3 * bv && nz <= bn)
                }
            };
            if better {
                best = Some((r, av, nz));
            }
        }
        let Some((prow, pv, _)) = best else {
            bail!("factor: singular at column {col}");
        };
        if pv < 1e-300 {
            bail!("factor: numerically singular at column {col}");
        }
        used[prow] = true;
        pivots.push((col, prow));

        // record the pivot's U row: diagonal first, off-diagonals sorted by
        // column for a deterministic program
        let (pivot_val, pivot_slot) = rows[prow][&col];
        let mut prow_data: Vec<(usize, f64, usize)> = rows[prow]
            .iter()
            .filter(|(&j, _)| j != col)
            .map(|(&j, &(v, s))| (j, v, s))
            .collect();
        prow_data.sort_unstable_by_key(|&(j, _, _)| j);
        u_cols.push(col);
        u_slots.push(pivot_slot);
        for &(j, _, s) in &prow_data {
            u_cols.push(j);
            u_slots.push(s);
        }
        u_ptr.push(u_cols.len());

        // eliminate every structural target (values updated alongside so
        // later pivot-magnitude guards stay realistic)
        for &r in &targets {
            if r == prow {
                continue;
            }
            let (vc, cslot) = rows[r].remove(&col).expect("structural target");
            l_rows.push(r);
            l_slots.push(cslot);
            let f = vc / pivot_val;
            for &(j, pval, _) in &prow_data {
                let e = rows[r].entry(j).or_insert_with(|| {
                    let s = n_slots;
                    n_slots += 1;
                    col_rows[j].push(r); // fill-in
                    (0.0, s)
                });
                e.0 -= f * pval;
                op_dst.push(e.1);
            }
            op_ptr.push(op_dst.len());
            if op_dst.len() > max_ops {
                bail!(
                    "factor: fill-in explosion under {ordering:?} ordering \
                     ({} update ops for {} triplets) — falling back to the \
                     reference solver",
                    op_dst.len(),
                    pattern.len()
                );
            }
        }
        l_ptr.push(l_rows.len());
        col_rows[col].clear();
    }

    Ok(Symbolic {
        n,
        ordering,
        n_slots,
        n_assembled,
        pattern,
        triplet_slot,
        pivots,
        u_ptr,
        u_cols,
        u_slots,
        l_ptr,
        l_rows,
        l_slots,
        op_ptr,
        op_dst,
    })
}

/// Value-dependent half: assembled matrix values, eliminated in place over
/// the symbolic pattern, plus the L multipliers.
#[derive(Debug, Clone)]
pub struct Numeric {
    sym: Arc<Symbolic>,
    /// raw assembled values (pre-elimination snapshot) — lets callers
    /// detect "matrix unchanged, only RHS differs" and skip the refactor
    assembled: Vec<f64>,
    /// working values: assembled pattern after elimination (the U factors)
    vals: Vec<f64>,
    /// one multiplier per (pivot, target) pair, program order (the L factors)
    lvals: Vec<f64>,
    factored: bool,
}

impl Numeric {
    pub fn new(sym: Arc<Symbolic>) -> Numeric {
        let n_slots = sym.n_slots;
        let n_l = sym.l_rows.len();
        Numeric {
            sym,
            assembled: vec![0.0; n_slots],
            vals: vec![0.0; n_slots],
            lvals: vec![0.0; n_l],
            factored: false,
        }
    }

    pub fn symbolic(&self) -> &Arc<Symbolic> {
        &self.sym
    }

    /// Does this factorization hold a valid (possibly value-stale) LU?
    /// The Krylov engine uses a stale-but-factored [`Numeric`] as a warm
    /// preconditioner without reassembling (which would clear the factor).
    pub fn is_factored(&self) -> bool {
        self.factored
    }

    /// Accumulate the triplet values of `sys` into the assembled slots.
    /// Returns `true` if the values are identical to the previous assembly
    /// (and a valid factorization exists) — i.e. a pure re-solve suffices.
    /// Errors if `sys` does not match this factorization's pattern.
    pub fn assemble(&mut self, sys: &SparseSys) -> Result<bool> {
        if !self.sym.matches(sys) {
            bail!("factor: circuit topology changed — re-analysis required");
        }
        let mut fresh = vec![0.0; self.sym.n_slots];
        for (k, &(_, _, v)) in sys.iter_triplets().enumerate() {
            fresh[self.sym.triplet_slot[k]] += v;
        }
        if self.factored && fresh == self.assembled {
            return Ok(true);
        }
        self.assembled = fresh;
        self.factored = false;
        Ok(false)
    }

    /// Numeric elimination over the fixed pattern: flat index arithmetic,
    /// no hashing, O(program length) = O(flops of the analysis-time
    /// elimination). Errors if a pivot collapsed for the current values.
    pub fn refactor(&mut self) -> Result<()> {
        let _sp = crate::span!("lu_refactor", n = self.sym.n);
        let s = &self.sym;
        self.vals.copy_from_slice(&self.assembled);
        for p in 0..s.pivots.len() {
            let u = s.u_ptr[p]..s.u_ptr[p + 1];
            let urow = &s.u_slots[u.clone()];
            let piv = self.vals[urow[0]];
            if piv.abs() < 1e-300 {
                self.factored = false;
                bail!(
                    "factor: pivot collapsed at column {} (|{piv:e}|) — stale ordering",
                    s.pivots[p].0
                );
            }
            for t in s.l_ptr[p]..s.l_ptr[p + 1] {
                let f = self.vals[s.l_slots[t]] / piv;
                self.lvals[t] = f;
                if f != 0.0 {
                    let dst = &s.op_dst[s.op_ptr[t]..s.op_ptr[t + 1]];
                    for (d, &src) in dst.iter().zip(&urow[1..]) {
                        self.vals[*d] -= f * self.vals[src];
                    }
                }
            }
        }
        self.factored = true;
        Ok(())
    }

    /// Borrowed view of the lower program + current multipliers for the
    /// [`Backend`] substitution kernels.
    fn lower_parts(&self) -> LuLowerParts<'_> {
        let s = &*self.sym;
        LuLowerParts {
            pivots: &s.pivots,
            l_ptr: &s.l_ptr,
            l_rows: &s.l_rows,
            lvals: &self.lvals,
        }
    }

    /// Borrowed view of the U rows + current values for the [`Backend`]
    /// substitution kernels.
    fn upper_parts(&self) -> LuUpperParts<'_> {
        let s = &*self.sym;
        LuUpperParts {
            pivots: &s.pivots,
            u_ptr: &s.u_ptr,
            u_cols: &s.u_cols,
            u_slots: &s.u_slots,
            vals: &self.vals,
        }
    }

    /// Substitute one right-hand side (indexed by row, like `SparseSys::b`).
    /// Returns x (indexed by column). O(nnz(L+U)).
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        self.solve_kern(b, backend::scalar())
    }

    /// [`Numeric::solve`] on an explicit [`Backend`] kernel set.
    pub fn solve_kern(&self, b: &[f64], kern: &dyn Backend) -> Result<Vec<f64>> {
        if !self.factored {
            bail!("factor: solve before refactor");
        }
        let s = &self.sym;
        if b.len() != s.n {
            bail!("factor: rhs has {} entries, system has {}", b.len(), s.n);
        }
        let _sp = crate::span!("subst", n = s.n);
        let t0 = Instant::now();
        // forward: replay eliminations on the RHS
        let mut w = b.to_vec();
        kern.subst_lower(&self.lower_parts(), &mut w);
        // backward: reverse elimination order over the U rows
        let mut x = vec![0.0; s.n];
        let bad = kern.subst_upper(&self.upper_parts(), &w, &mut x);
        backend::add_subst_ns(t0.elapsed().as_nanos() as u64);
        if let Some(col) = bad {
            bail!("factor: zero diagonal in back-substitution at column {col}");
        }
        Ok(x)
    }

    /// Batched substitution: solve the same factorization against many
    /// right-hand sides in one interleaved pass (one traversal of the L/U
    /// programs regardless of the batch size — the batched crossbar
    /// column-read path).
    pub fn solve_multi(&self, bs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        self.solve_multi_kern(bs, backend::scalar())
    }

    /// [`Numeric::solve_multi`] on an explicit [`Backend`] kernel set. The
    /// Simd backend streams lane-width column blocks through one program
    /// traversal; results are bit-identical per column across backends.
    pub fn solve_multi_kern(&self, bs: &[Vec<f64>], kern: &dyn Backend) -> Result<Vec<Vec<f64>>> {
        if !self.factored {
            bail!("factor: solve before refactor");
        }
        let s = &self.sym;
        let k = bs.len();
        if k == 0 {
            return Ok(Vec::new());
        }
        for b in bs {
            if b.len() != s.n {
                bail!("factor: rhs has {} entries, system has {}", b.len(), s.n);
            }
        }
        let _sp = crate::span!("subst_multi", n = s.n, k = k);
        let t0 = Instant::now();
        let mut w: Vec<Vec<f64>> = bs.to_vec();
        kern.subst_lower_multi(&self.lower_parts(), &mut w);
        let mut xs: Vec<Vec<f64>> = vec![vec![0.0; s.n]; k];
        let bad = kern.subst_upper_multi(&self.upper_parts(), &w, &mut xs);
        backend::add_subst_ns(t0.elapsed().as_nanos() as u64);
        if let Some(col) = bad {
            bail!("factor: zero diagonal in back-substitution at column {col}");
        }
        Ok(xs)
    }

    pub fn stats(&self) -> SolveStats {
        self.sym.stats()
    }
}

/// One-shot convenience: analyze + assemble + refactor + solve. The
/// factored equivalent of [`SparseSys::solve_with_stats`].
pub fn factor_solve(sys: &SparseSys, ordering: Ordering) -> Result<(Vec<f64>, Numeric)> {
    factor_solve_kern(sys, ordering, backend::scalar())
}

/// [`factor_solve`] on an explicit [`Backend`] kernel set.
pub fn factor_solve_kern(
    sys: &SparseSys,
    ordering: Ordering,
    kern: &dyn Backend,
) -> Result<(Vec<f64>, Numeric)> {
    let sym = Arc::new(analyze(sys, ordering)?);
    let mut num = Numeric::new(sym);
    num.assemble(sys)?;
    num.refactor()?;
    let x = num.solve_kern(&sys.b, kern)?;
    Ok((x, num))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spice::solve::solve_dense;
    use crate::util::prng::Rng;

    fn random_system(n: usize, rng: &mut Rng) -> (Vec<Vec<f64>>, SparseSys, Vec<f64>) {
        let mut dense = vec![vec![0.0; n]; n];
        let mut sys = SparseSys::new(n);
        for i in 0..n {
            for _ in 0..3 {
                let j = rng.below(n);
                let v = rng.range_f64(-1.0, 1.0);
                dense[i][j] += v;
                sys.add(i, j, v);
            }
            dense[i][i] += 5.0;
            sys.add(i, i, 5.0);
        }
        let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
        for (i, &v) in b.iter().enumerate() {
            sys.add_b(i, v);
        }
        (dense, sys, b)
    }

    #[test]
    fn factored_matches_dense_both_orderings() {
        let mut rng = Rng::new(77);
        for trial in 0..6 {
            let n = 4 + trial * 5;
            let (dense, sys, b) = random_system(n, &mut rng);
            let xd = solve_dense(&dense, &b).unwrap();
            for ord in [Ordering::Smart, Ordering::Natural] {
                let (x, _) = factor_solve(&sys, ord).unwrap();
                for i in 0..n {
                    assert!((xd[i] - x[i]).abs() < 1e-9, "{ord:?} trial {trial} x[{i}]");
                }
            }
        }
    }

    #[test]
    fn refactor_tracks_new_values() {
        // same topology, different values: refactor must track without
        // re-analysis
        let mut rng = Rng::new(5);
        let n = 12;
        let (_, sys, _) = random_system(n, &mut rng);
        let (x0, mut num) = factor_solve(&sys, Ordering::Smart).unwrap();
        assert_eq!(x0.len(), n);
        // rebuild the same stamp order with scaled values
        let mut sys2 = SparseSys::new(n);
        for &(i, j, v) in sys.iter_triplets() {
            sys2.add(i, j, v * 1.5);
        }
        for (i, &bv) in sys.b.iter().enumerate() {
            sys2.add_b(i, bv);
        }
        let unchanged = num.assemble(&sys2).unwrap();
        assert!(!unchanged);
        num.refactor().unwrap();
        let x2 = num.solve(&sys2.b).unwrap();
        assert!(sys2.residual(&x2) < 1e-9, "residual {}", sys2.residual(&x2));
        // A*1.5 with same b => x/1.5
        for i in 0..n {
            assert!((x2[i] * 1.5 - x0[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn pure_resolve_detected_on_identical_values() {
        let mut rng = Rng::new(9);
        let (_, sys, _) = random_system(10, &mut rng);
        let (_, mut num) = factor_solve(&sys, Ordering::Smart).unwrap();
        assert!(num.assemble(&sys).unwrap(), "identical matrix must skip refactor");
        let mut b2 = sys.b.clone();
        b2[3] += 1.0;
        let x = num.solve(&b2).unwrap();
        let mut sys2 = sys.clone();
        sys2.b = b2;
        assert!(sys2.residual(&x) < 1e-9);
    }

    #[test]
    fn zero_diagonal_needs_off_diagonal_pivot() {
        let mut s = SparseSys::new(2);
        s.add(0, 1, 1.0);
        s.add(1, 0, 1.0);
        s.add_b(0, 3.0);
        s.add_b(1, 7.0);
        for ord in [Ordering::Smart, Ordering::Natural] {
            let (x, _) = factor_solve(&s, ord).unwrap();
            assert!((x[0] - 7.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12, "{ord:?}");
        }
    }

    #[test]
    fn singular_detected() {
        let mut s = SparseSys::new(2);
        s.add(0, 0, 1.0);
        s.add(1, 0, 1.0); // column 1 empty
        assert!(factor_solve(&s, Ordering::Smart).is_err());
        assert!(factor_solve(&s, Ordering::Natural).is_err());
    }

    #[test]
    fn mismatched_topology_rejected() {
        let mut a = SparseSys::new(3);
        a.add(0, 0, 1.0);
        a.add(1, 1, 1.0);
        a.add(2, 2, 1.0);
        let (_, mut num) = factor_solve(&a, Ordering::Smart).unwrap();
        let mut b = SparseSys::new(3);
        b.add(0, 0, 1.0);
        b.add(1, 2, 1.0); // different pattern
        b.add(2, 1, 1.0);
        assert!(num.assemble(&b).is_err());
    }

    #[test]
    fn multi_rhs_matches_sequential() {
        let mut rng = Rng::new(21);
        let (_, sys, _) = random_system(14, &mut rng);
        let (_, num) = factor_solve(&sys, Ordering::Smart).unwrap();
        let bs: Vec<Vec<f64>> = (0..4)
            .map(|k| (0..14).map(|i| ((i + k) as f64 * 0.37).sin()).collect())
            .collect();
        let xs = num.solve_multi(&bs).unwrap();
        for (b, x) in bs.iter().zip(&xs) {
            let xi = num.solve(b).unwrap();
            for (a, c) in x.iter().zip(&xi) {
                assert!((a - c).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn high_gain_opamp_values_stay_accurate() {
        // 1e-4 conductances against 1e6 op-amp gains (the TIA pattern)
        let mut s = SparseSys::new(3);
        s.add(0, 0, 1e-4);
        s.add(0, 1, -1e-4);
        s.add(1, 0, -1e-4);
        s.add(1, 1, 2e-4);
        s.add(1, 2, 1.0);
        s.add(2, 1, 1e6);
        s.add(2, 2, 1.0);
        s.add_b(0, 1e-3);
        for ord in [Ordering::Smart, Ordering::Natural] {
            let (x, _) = factor_solve(&s, ord).unwrap();
            assert!(s.residual(&x) < 1e-9, "{ord:?} residual {}", s.residual(&x));
        }
    }

    #[test]
    fn duplicate_triplets_assemble_into_one_slot() {
        let mut s = SparseSys::new(1);
        s.add(0, 0, 1.5);
        s.add(0, 0, 0.5);
        s.add_b(0, 4.0);
        let (x, num) = factor_solve(&s, Ordering::Smart).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-12);
        assert_eq!(num.symbolic().factor_entries(), 1);
        assert_eq!(num.symbolic().assembled_entries(), 1);
        assert_eq!(num.symbolic().fill_entries(), 0);
    }

    #[test]
    fn block_diagonal_has_zero_fill() {
        // independent 2x2 blocks: Smart elimination must produce no fill-in
        let n = 40;
        let mut s = SparseSys::new(n);
        for k in 0..n / 2 {
            let i = 2 * k;
            s.add(i, i, 2.0);
            s.add(i, i + 1, 1.0);
            s.add(i + 1, i, 1.0);
            s.add(i + 1, i + 1, 3.0);
            s.add_b(i, 5.0);
            s.add_b(i + 1, 10.0);
        }
        let (x, num) = factor_solve(&s, Ordering::Smart).unwrap();
        assert_eq!(num.symbolic().fill_entries(), 0);
        for k in 0..n / 2 {
            assert!((x[2 * k] - 1.0).abs() < 1e-10);
            assert!((x[2 * k + 1] - 3.0).abs() < 1e-10);
        }
    }
}
