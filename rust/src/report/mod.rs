//! Report generators — regenerate the paper's tables and figures as
//! markdown/CSV text on stdout (EXPERIMENTS.md records the outputs).
//!
//!   Table 1 — accuracy row: `memx accuracy` (coordinator)
//!   Fig 4   — activation circuit transfer curves (CSV)
//!   Fig 7   — construction + simulation time, segmented vs monolithic
//!   Fig 8   — latency + energy vs baselines (Eqs 17/18)
//!   Fig 9   — memristor weight histogram
//!   Table 4 — per-layer resources

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::analog;
use crate::mapper::{self, MapMode, MappedNetwork};
use crate::netlist;
use crate::nn::{Manifest, WeightStore};
use crate::pipeline::{AnalogModule, Fidelity, PipelineBuilder};
use crate::power;
use crate::spice::krylov::SolverStrategy;
use crate::spice::solve::Ordering;

/// Table 4: size / memristors / op-amps / parallelism per layer.
pub fn print_table4(net: &MappedNetwork) {
    println!("## Table 4 — resources of the memristor-based MobileNetV3 (mode {:?})", net.mode);
    println!("| Unit | Layer | Size | Banks | Memristors | Op-amps | Parallelism |");
    println!("|---|---|---|---:|---:|---:|---:|");
    let mut last_unit = "";
    for l in &net.layers {
        let unit = if l.unit == last_unit { "" } else { &l.unit };
        last_unit = &l.unit;
        let size = l
            .size
            .map(|(r, c)| format!("{r}x{c}"))
            .unwrap_or_else(|| "-".into());
        println!(
            "| {} | {} | {} | {} | {} | {} | {} |",
            unit, l.kind, size, l.banks, l.memristors, l.opamps, l.parallelism
        );
    }
    println!(
        "| **total** | | | | **{}** | **{}** | |",
        net.total_memristors(),
        net.total_opamps()
    );
    println!(
        "memristor stages on critical path (Eq 17 N_m): {}",
        net.memristor_stages()
    );
}

pub fn report_table4(dir: &Path) -> Result<()> {
    let m = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &m)?;
    let net = mapper::map_network(&m, &ws, MapMode::Inverted)?;
    print_table4(&net);
    Ok(())
}

/// Fig 4(c)/(d): SPICE transfer curves of the activation circuits vs the
/// software functions (CSV to stdout or a file).
pub fn report_fig4(out: Option<&str>) -> Result<()> {
    let mut hs = analog::build_hard_sigmoid();
    let mut hw = analog::build_hard_swish();
    let mut csv = String::from("vin,hsigmoid_spice,hsigmoid_sw,hswish_spice,hswish_sw\n");
    for (x, y_hs) in hs.sweep(-4.0, 4.0, 81)? {
        let y_hw = hw.eval(x)?;
        csv.push_str(&format!(
            "{x:.3},{y_hs:.5},{:.5},{y_hw:.5},{:.5}\n",
            analog::hard_sigmoid_sw(x),
            analog::hard_swish_sw(x)
        ));
    }
    match out {
        Some(p) => {
            std::fs::write(p, &csv)?;
            println!("wrote Fig 4 curves to {p}");
        }
        None => print!("{csv}"),
    }
    Ok(())
}

/// Fig 7: construction + simulation time of FC crossbars, segmented vs
/// monolithic (quick in-process variant; the full sweep lives in
/// benches/bench_segmentation.rs), plus the factor-once/solve-many columns:
/// cached re-reads and batched multi-RHS reads through a Spice-fidelity
/// [`crate::pipeline::CrossbarModule`] (resident [`netlist::CrossbarSim`],
/// segments solved in parallel).
pub fn report_fig7(dir: &Path) -> Result<()> {
    let m = Manifest::load(dir)?;
    println!("## Fig 7 — FC crossbar construction + simulation time");
    println!("| size (in x out) | construct | netlist files | sim monolithic | sim segmented (64 cols) | speedup | cached re-read | vs monolithic | batched x16 per read |");
    println!("|---|---:|---:|---:|---:|---:|---:|---:|---:|");
    for &(cin, cout) in &[(64usize, 64usize), (128, 128), (256, 256)] {
        let t0 = Instant::now();
        let cb = mapper::build_synthetic_fc(cin, cout, m.device.levels, MapMode::Inverted, 42);
        let construct = t0.elapsed();
        let inputs: Vec<f64> = (0..cin).map(|i| ((i as f64) * 0.1).sin() * 0.5).collect();

        // one-shot emit+parse+solve — the legacy per-read cost Fig 7 charts
        let mono_segs = netlist::plan_segments(cb.cols, 0);
        let t0 = Instant::now();
        let text = netlist::emit_crossbar(&cb, &m.device, &mono_segs[0], Some(&inputs), 1);
        let circuit = netlist::parse(&text)?;
        let _ = netlist::solve_segment_outputs(&circuit, &mono_segs[0], true, Ordering::Natural)?;
        let mono = t0.elapsed();

        let segs = netlist::plan_segments(cb.cols, 64);
        let t0 = Instant::now();
        for seg in &segs {
            let text = netlist::emit_crossbar(&cb, &m.device, seg, Some(&inputs), segs.len());
            let circuit = netlist::parse(&text)?;
            let _ = netlist::solve_segment_outputs(&circuit, seg, true, Ordering::Natural)?;
        }
        let segd = t0.elapsed();

        // factor-once: compile the crossbar into a Spice-fidelity pipeline
        // module, then time cached re-reads with fresh input vectors (pure
        // RHS re-solves, parallel segments)
        let mut module = PipelineBuilder::new()
            .fidelity(Fidelity::Spice)
            .segment(64)
            .crossbar_module(cb, &m.device)?;
        let _ = module.forward(&inputs)?; // cold read primes the cache
        let reads = 4u32;
        let t0 = Instant::now();
        for k in 0..reads {
            let v: Vec<f64> =
                (0..cin).map(|i| ((i + k as usize) as f64 * 0.23).sin() * 0.5).collect();
            let _ = module.forward(&v)?;
        }
        let cached = t0.elapsed() / reads;

        // batched serving path: 16 vectors amortized over one multi-RHS
        // substitution pass per segment
        let batch: Vec<Vec<f64>> = (0..16usize)
            .map(|k| (0..cin).map(|i| ((i + 7 * k) as f64 * 0.17).sin() * 0.5).collect())
            .collect();
        let t0 = Instant::now();
        let _ = module.forward_batch(&batch)?;
        let batched = t0.elapsed() / 16;

        println!(
            "| {cin}x{cout} | {construct:?} | {} | {mono:?} | {segd:?} | {:.1}x | {cached:?} | {:.1}x | {batched:?} |",
            segs.len(),
            mono.as_secs_f64() / segd.as_secs_f64().max(1e-12),
            mono.as_secs_f64() / cached.as_secs_f64().max(1e-12)
        );
    }
    println!("(full sweep incl. 1024x1024: cargo bench --bench bench_segmentation)");
    Ok(())
}

/// Fig 8: latency + power of the analog paradigm vs dual-op-amp / GPU / CPU.
pub fn report_fig8(dir: &Path) -> Result<()> {
    let m = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &m)?;
    println!("## Fig 8 — latency and energy per inference");
    println!("| implementation | latency | vs analog | energy | vs analog |");
    println!("|---|---:|---:|---:|---:|");
    let inv = mapper::map_network(&m, &ws, MapMode::Inverted)?;
    let t_inv = power::latency(&inv, &m.device);
    let e_inv = power::energy(&inv, &m.device, &t_inv);
    let dual = mapper::map_network(&m, &ws, MapMode::Dual)?;
    let t_dual = power::latency(&dual, &m.device);
    let e_dual = power::energy(&dual, &m.device, &t_dual);
    let c = power::compare(&t_inv, &e_inv, None);
    let row = |name: &str, t: f64, e: f64| {
        println!(
            "| {name} | {:.4} µs | {:.1}x | {:.4} µJ | {:.1}x |",
            t * 1e6,
            t / t_inv.total,
            e * 1e6,
            e / e_inv.total
        );
    };
    let t_pipe = power::latency_pipelined(&inv, &m.device);
    let t_pipe_dual = power::latency_pipelined(&dual, &m.device);
    row("memristor sequential (this work)", t_inv.total, e_inv.total);
    row("memristor sequential (dual op-amp)", t_dual.total, e_dual.total);
    row("memristor pipelined (this work)", t_pipe.total, e_inv.total);
    row("memristor pipelined (dual op-amp)", t_pipe_dual.total, e_dual.total);
    row("GPU RTX 4090 (paper)", c.t_gpu, c.e_gpu);
    row("CPU i7-12700 (paper)", c.t_cpu, c.e_cpu);
    println!(
        "\nEq 17 breakdown: N_m = {}, T_m = {} ps, T_o = {} µs, T_r = {:.1} ns",
        t_inv.n_m,
        t_inv.t_mem * 1e12,
        t_inv.t_opamp * 1e6,
        t_inv.t_rest * 1e9
    );
    println!(
        "Eq 18 breakdown: memristors {:.3} µJ, op-amps {:.3} µJ, aux {:.3} µJ",
        e_inv.e_memristors * 1e6,
        e_inv.e_opamps * 1e6,
        e_inv.e_rest * 1e6
    );
    println!(
        "headline (sequential): {:.0}x vs GPU, {:.0}x vs CPU latency; {:.1}x / {:.1}x energy savings",
        c.speedup_vs_gpu(),
        c.speedup_vs_cpu(),
        c.savings_vs_gpu(),
        c.savings_vs_cpu()
    );
    println!(
        "headline (pipelined):  {:.0}x vs GPU, {:.0}x vs CPU latency (paper's §5.2 regime)",
        c.t_gpu / t_pipe.total,
        c.t_cpu / t_pipe.total
    );
    Ok(())
}

/// Fig 9: distribution of memristor weights (ASCII histogram + CSV rows).
pub fn report_fig9(dir: &Path) -> Result<()> {
    let m = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &m)?;
    let values = ws.all_vmm_values();
    println!("## Fig 9 — distribution of memristor weights ({} devices)", values.len());
    let bins = 40;
    let (lo, hi) = (-0.5f32, 0.5f32);
    let mut counts = vec![0usize; bins];
    let mut clipped = 0usize;
    for &v in &values {
        if v < lo || v >= hi {
            clipped += 1;
            continue;
        }
        let b = (((v - lo) / (hi - lo)) * bins as f32) as usize;
        counts[b.min(bins - 1)] += 1;
    }
    let max = counts.iter().copied().max().unwrap_or(1).max(1);
    for (i, &c) in counts.iter().enumerate() {
        let left = lo + (hi - lo) * i as f32 / bins as f32;
        let bar = "#".repeat(c * 50 / max);
        println!("{left:+.3} {c:>8} {bar}");
    }
    println!("outside [-0.5, 0.5): {clipped}");
    let in_band = values.iter().filter(|v| v.abs() <= 0.2).count();
    println!(
        "fraction within ±0.2 (paper: 'predominantly'): {:.1}%",
        100.0 * in_band as f64 / values.len() as f64
    );
    Ok(())
}

/// `memx report --coverage` — per-stage module fidelity coverage and
/// resources of the compiled pipeline, plus the stage-hook Eq 17/18 models
/// ([`power::latency_coverage`] / [`power::energy_coverage`]). At
/// `--fidelity spice` the counts come from the *emitted netlists* (the
/// §3.3 BN subtraction + scale/offset pair, the §3.5 GAP averaging
/// columns, per-bank conv crossbars) and the circuits column shows the
/// chain has no fidelity hole. Without artifacts the synthetic demo
/// network ([`crate::pipeline::demo_network`]) stands in, so the report
/// runs offline.
pub fn report_coverage(
    dir: &Path,
    fidelity: Fidelity,
    mode: MapMode,
    segment: usize,
    solver: SolverStrategy,
) -> Result<()> {
    let (m, ws) = if dir.join("manifest.json").exists() {
        let m = Manifest::load(dir)?;
        let ws = WeightStore::load(dir, &m)?;
        (m, ws)
    } else {
        println!("(no artifacts at {dir:?} — covering the synthetic demo network)");
        crate::pipeline::demo_network(0xC0DE)?
    };
    let pipe = PipelineBuilder::new()
        .mode(mode)
        .fidelity(fidelity)
        .segment(segment)
        .solver(solver)
        .build(&m, &ws)?;
    let cov = pipe.stage_coverage();
    println!("## Module fidelity coverage ({fidelity}, mode {mode})");
    println!("| Unit | Stage | Kind | Dims | Memristors | Op-amps | Circuits |");
    println!("|---|---|---|---|---:|---:|---:|");
    let mut last_unit = "";
    for s in &cov {
        let unit = if s.unit == last_unit { "" } else { &s.unit };
        last_unit = &s.unit;
        println!(
            "| {} | {} | {} | {}->{} | {} | {} | {} |",
            unit, s.name, s.kind, s.in_dim, s.out_dim, s.memristors, s.opamps, s.spice_circuits
        );
    }
    println!(
        "| **total** | | | | **{}** | **{}** | **{}** |",
        pipe.memristors(),
        pipe.opamps(),
        pipe.spice_circuits()
    );
    if fidelity == Fidelity::Spice {
        let holes: Vec<&str> = cov
            .iter()
            .filter(|s| s.spice_circuits == 0 && !s.spice_exempt())
            .map(|s| s.name.as_str())
            .collect();
        if holes.is_empty() {
            println!(
                "spice coverage: complete — every module runs its emitted netlist \
                 (CMOS ReLU and residual adders stay exact by design)"
            );
        } else {
            println!("spice coverage HOLES: {holes:?}");
        }
    }
    let t = power::latency_coverage(&cov, &m.device, mode);
    let e = power::energy_coverage(&cov, &m.device, &t);
    println!(
        "Eq 17 (stage hooks): N_m = {}, T_i = {:.4} µs | Eq 18: {:.4} µJ \
         (memristors {:.4}, op-amps {:.4}, aux {:.4})",
        t.n_m,
        t.total * 1e6,
        e.total * 1e6,
        e.e_memristors * 1e6,
        e.e_opamps * 1e6,
        e.e_rest * 1e6
    );
    let fallbacks = crate::spice::solver_fallbacks();
    if fallbacks > 0 {
        println!(
            "solver health: {fallbacks} iterative solve(s) fell back to direct factorization \
             this process"
        );
    }
    let (iters, reuses) = (crate::spice::gmres_iterations(), crate::spice::precond_reuses());
    if iters > 0 {
        println!(
            "solver work: {iters} GMRES iteration(s), {reuses} warm preconditioner reuse(s) \
             this process"
        );
    }
    let (subst, matvec) = (crate::backend::subst_ns(), crate::backend::matvec_ns());
    if subst > 0 || matvec > 0 {
        println!(
            "kernel time: substitution {:?}, matvec {:?} this process",
            std::time::Duration::from_nanos(subst),
            std::time::Duration::from_nanos(matvec)
        );
    }
    Ok(())
}

/// `memx spice` — compile one FC/PConv layer into a single-stage analog
/// [`crate::pipeline::Pipeline`] at SPICE fidelity (resident factor-once
/// [`netlist::CrossbarSim`], segments in parallel), batch-read a few input
/// vectors through `forward_batch` (one multi-RHS substitution pass per
/// segment) and compare against the same layer at ideal fidelity.
#[allow(clippy::too_many_arguments)]
pub fn spice_layer_demo(
    dir: &Path,
    layer: &str,
    mode: MapMode,
    segment: usize,
    n_vectors: usize,
    solver: SolverStrategy,
    backend: crate::backend::BackendChoice,
) -> Result<()> {
    let m = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &m)?;
    let base =
        PipelineBuilder::new().mode(mode).segment(segment).solver(solver).backend(backend);
    let t0 = Instant::now();
    let mut spice = base.clone().fidelity(Fidelity::Spice).build_layer(&m, &ws, layer)?;
    println!(
        "layer {layer} (mode {mode}, solver {solver}, backend {backend}): {}; \
         compiled for SPICE in {:?}",
        spice.describe(),
        t0.elapsed()
    );
    let mut ideal = base.fidelity(Fidelity::Ideal).build_layer(&m, &ws, layer)?;

    let mut rng = crate::util::prng::Rng::new(99);
    let batch: Vec<Vec<f64>> = (0..n_vectors)
        .map(|_| (0..spice.in_dim()).map(|_| rng.range_f64(-1.0, 1.0)).collect())
        .collect();
    let t0 = Instant::now();
    let got = spice.forward_batch(&batch)?;
    let wall = t0.elapsed();
    let want = ideal.forward_batch(&batch)?;

    let mut worst = 0f64;
    for (v, (g_row, w_row)) in got.iter().zip(&want).enumerate() {
        let err = g_row
            .iter()
            .zip(w_row)
            .fold(0f64, |a, (g, i)| a.max((g - i).abs()));
        worst = worst.max(err);
        println!("vector {v}: max |spice - ideal| = {err:.3e}");
    }
    println!(
        "{n_vectors} vectors batched in {wall:?} (factor-once, one multi-RHS pass per segment); \
         worst error {worst:.3e}"
    );
    Ok(())
}
