//! Micro-bench harness (criterion is not in the offline crate cache).
//!
//! Each `cargo bench` target is a `harness = false` binary that calls
//! [`Bench::run`] per case: warmup, then timed iterations until both a
//! minimum iteration count and a minimum wall budget are met; reports
//! median / mean / p95 like criterion's summary line and collects rows so
//! benches can print paper-style tables at the end.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

pub struct Bench {
    pub min_iters: usize,
    pub min_time: Duration,
    pub warmup: usize,
    pub rows: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self { min_iters: 10, min_time: Duration::from_millis(300), warmup: 2, rows: Vec::new() }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { min_iters: 3, min_time: Duration::from_millis(50), warmup: 1, rows: Vec::new() }
    }

    /// Time `f` (which must fully perform the work per call).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters || start.elapsed() < self.min_time {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
            if samples.len() >= 10_000 {
                break;
            }
        }
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let stats = Stats {
            name: name.to_string(),
            iters: n,
            mean,
            median: samples[n / 2],
            p95: samples[((n * 95) / 100).min(n - 1)],
            min: samples[0],
        };
        println!(
            "{:<44} {:>10} iters  mean {:>12?}  median {:>12?}  min {:>12?}",
            stats.name, stats.iters, stats.mean, stats.median, stats.min
        );
        self.rows.push(stats.clone());
        stats
    }

    /// Record an externally-measured single-shot duration (for expensive
    /// cases where repeated runs are impractical, e.g. large SPICE solves).
    pub fn record_once(&mut self, name: &str, d: Duration) -> Stats {
        let stats = Stats {
            name: name.to_string(),
            iters: 1,
            mean: d,
            median: d,
            p95: d,
            min: d,
        };
        println!("{:<44} {:>10} iter   once {:>12?}", stats.name, 1, d);
        self.rows.push(stats.clone());
        stats
    }

    pub fn table(&self, title: &str) {
        println!("\n== {title} ==");
        println!("{:<44} {:>14} {:>14}", "case", "median", "mean");
        for r in &self.rows {
            println!("{:<44} {:>14?} {:>14?}", r.name, r.median, r.mean);
        }
    }
}

/// Append one bench run to a JSON trajectory file (an array of run
/// objects; created if missing, appended otherwise — successive runs build
/// a history the perf dashboards can diff). Each row records ns timings;
/// `derived` carries computed headline numbers such as cached-vs-cold
/// speedups.
pub fn append_json_report(
    path: &str,
    bench: &str,
    rows: &[Stats],
    derived: &[(String, f64)],
) -> std::io::Result<()> {
    use crate::util::json::Json;

    let mut runs: Vec<Json> = match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text).ok().and_then(|j| j.as_arr().map(|a| a.to_vec()))
        {
            Some(runs) => runs,
            // an unreadable trajectory (e.g. a previous write was killed
            // mid-flight) must not be silently replaced — that would drop
            // the accumulated history
            None => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("{path}: existing trajectory is not a JSON array; refusing to overwrite"),
                ))
            }
        },
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        // other read failures (permissions, bad UTF-8) also mean an
        // existing history we must not clobber
        Err(e) => return Err(e),
    };
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::str(r.name.clone())),
                ("iters", Json::num(r.iters as f64)),
                ("median_ns", Json::num(r.median.as_nanos() as f64)),
                ("mean_ns", Json::num(r.mean.as_nanos() as f64)),
                ("p95_ns", Json::num(r.p95.as_nanos() as f64)),
                ("min_ns", Json::num(r.min.as_nanos() as f64)),
            ])
        })
        .collect();
    let derived_obj = Json::Obj(
        derived
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(*v)))
            .collect(),
    );
    runs.push(Json::obj(vec![
        ("bench", Json::str(bench)),
        ("rows", Json::Arr(row_objs)),
        ("derived", derived_obj),
    ]));
    // atomic replace: a killed bench run must not truncate the trajectory
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, Json::Arr(runs).to_string())?;
    std::fs::rename(&tmp, path)
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut b = Bench::quick();
        let s = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.iters >= 3);
        assert!(s.median <= s.p95 || s.iters < 20);
        assert_eq!(b.rows.len(), 1);
    }

    #[test]
    fn record_once_row() {
        let mut b = Bench::quick();
        b.record_once("big", Duration::from_millis(5));
        assert_eq!(b.rows[0].iters, 1);
    }

    #[test]
    fn json_trajectory_appends() {
        let path = std::env::temp_dir().join("memx_bench_traj_test.json");
        let p = path.to_str().unwrap();
        std::fs::remove_file(p).ok();
        let mut b = Bench::quick();
        b.record_once("case-a", Duration::from_micros(3));
        append_json_report(p, "t", &b.rows, &[("speedup".into(), 5.5)]).unwrap();
        append_json_report(p, "t", &b.rows, &[]).unwrap();
        let j = crate::util::json::Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap();
        let runs = j.as_arr().unwrap();
        assert_eq!(runs.len(), 2, "trajectory must append, not overwrite");
        assert_eq!(runs[0].get("bench").unwrap().as_str().unwrap(), "t");
        let d = runs[0].get("derived").unwrap();
        assert_eq!(d.get("speedup").unwrap().as_f64().unwrap(), 5.5);
        std::fs::remove_file(p).ok();
    }
}
