//! Hierarchical span tracing, typed events, and the metrics registry —
//! the observability substrate for the whole serving stack.
//!
//! # Spans
//!
//! A span is an RAII guard ([`SpanGuard`]) over a named interval of work:
//!
//! ```
//! {
//!     let _sp = memx::telemetry::span("lu_refactor", "kernel");
//!     // ... work ...
//! } // span recorded on drop
//! ```
//!
//! or, for kernels, the [`span!`](crate::span) macro with numeric payload
//! args: `let mut sp = span!("gmres", restarts = m); sp.set_arg("iters",
//! n as f64);`. Guards record into **thread-local buffers** that flush to a
//! global collector when full and when the owning thread exits — worker
//! threads spawned by `util::pool` are scoped and join before their caller
//! returns, so a [`drain`] after a parallel region observes every event.
//!
//! # Overhead contract
//!
//! * **Disabled** (the default, [`Level::Off`]): creating a span is one
//!   relaxed atomic load and returns an inert guard — no clock read, no
//!   allocation, no locking. The quick-mode `bench_spice` section
//!   `span_overhead` pins the end-to-end cost on the cached multi-RHS
//!   resolve workload to < 2%.
//! * **Enabled** ([`Level::Spans`]): each span costs two monotonic clock
//!   reads and a thread-local `Vec` push; the global mutex is touched once
//!   per `FLUSH_AT` events per thread. The collector is capped at
//!   [`MAX_EVENTS`]; overflow increments [`dropped_events`] instead of
//!   growing without bound.
//!
//! # Views over legacy structs
//!
//! The bespoke timing structs that predate this module are retained as
//! *views* so their printed output is unchanged:
//!
//! * `spice::solve::SolveStats` (`subst_ns`/`matvec_ns`) — per-solve view
//!   of the kernel wall time also recorded process-wide by
//!   [`crate::backend::subst_ns`]/[`crate::backend::matvec_ns`] and spans.
//! * `pipeline::StageStat` (`Pipeline::take_stage_stats`) — aggregated view
//!   of the per-unit spans (cat `"pipeline"`).
//! * `coordinator::metrics::Snapshot` — a read of the server's
//!   [`metrics::Registry`], which is what `--metrics-addr` exports.
//!
//! # Typed events
//!
//! Operational state changes ([`Event`]: drift detection, recalibration,
//! solver fallback, executor error, fault-clock steps) are recorded as
//! chrome-trace *instant* events so a saturation run's timeline shows when
//! the watchdog fired, not just how often.
//!
//! # Export
//!
//! [`drain`] takes the collected events; [`write_chrome_trace`] writes a
//! chrome://tracing / Perfetto-loadable `trace_event` JSON file and
//! [`write_jsonl`] a line-per-event log. Every CLI accepts
//! `--trace-out FILE` / `--trace-jsonl FILE`.

pub mod http;
pub mod metrics;

use std::borrow::Cow;
use std::cell::RefCell;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Level gate
// ---------------------------------------------------------------------------

/// Global tracing level. `Off` makes every span/event call a no-op behind
/// one relaxed atomic load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    Off = 0,
    Spans = 1,
}

static LEVEL: AtomicU8 = AtomicU8::new(0);

/// Set the global tracing level (also pins the trace epoch on first call,
/// so spans started right after enabling get positive timestamps).
pub fn set_level(l: Level) {
    let _ = epoch();
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    if enabled() {
        Level::Spans
    } else {
        Level::Off
    }
}

/// Cheap hot-path gate: one relaxed load.
#[inline]
pub fn enabled() -> bool {
    LEVEL.load(Ordering::Relaxed) != 0
}

/// Process trace epoch — all event timestamps are nanoseconds since this.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn ns_since_epoch(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_nanos().min(u64::MAX as u128) as u64
}

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// Chrome trace_event phase: complete spans (`"X"`) or instants (`"i"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ph {
    Span,
    Instant,
}

impl Ph {
    pub fn code(self) -> &'static str {
        match self {
            Ph::Span => "X",
            Ph::Instant => "i",
        }
    }
}

/// One recorded trace event (span or instant).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: Cow<'static, str>,
    /// category: "serve" | "queue" | "forward" | "pipeline" | "module"
    /// | "solve" | "kernel" | "event"
    pub cat: &'static str,
    pub ph: Ph,
    /// nanoseconds since the process trace epoch
    pub ts_ns: u64,
    /// span duration (0 for instants)
    pub dur_ns: u64,
    /// trace-local thread id (dense, assigned at first event per thread)
    pub tid: u64,
    /// numeric payload args (`iters`, `batch`, ...)
    pub args: Vec<(&'static str, f64)>,
    /// optional free-form payload (error details)
    pub detail: Option<String>,
}

/// Typed operational events: recorded as instant events (cat `"event"`)
/// when tracing is enabled, so timelines show *when* the serving stack
/// changed state.
#[derive(Debug, Clone)]
pub enum Event {
    /// The serving drift watchdog flagged a collapsed logit margin.
    DriftDetected { margin: f64 },
    /// A recalibration cycle rewrote `devices` crossbar cells.
    Recalibrated { devices: u64 },
    /// An iterative solve fell back to the direct factorization.
    SolverFallback { cold: bool },
    /// An executor failed a served batch.
    ExecutorError { batch: u64 },
    /// The device-lifetime fault clock advanced to `hours`.
    FaultStep { hours: f64 },
}

/// Record a typed instant event (no-op when tracing is disabled).
pub fn event(e: Event) {
    if !enabled() {
        return;
    }
    let (name, args): (&'static str, Vec<(&'static str, f64)>) = match e {
        Event::DriftDetected { margin } => ("drift_detected", vec![("margin", margin)]),
        Event::Recalibrated { devices } => ("recalibrated", vec![("devices", devices as f64)]),
        Event::SolverFallback { cold } => {
            ("solver_fallback", vec![("cold", if cold { 1.0 } else { 0.0 })])
        }
        Event::ExecutorError { batch } => ("executor_error", vec![("batch", batch as f64)]),
        Event::FaultStep { hours } => ("fault_step", vec![("hours", hours)]),
    };
    push_event(TraceEvent {
        name: Cow::Borrowed(name),
        cat: "event",
        ph: Ph::Instant,
        ts_ns: ns_since_epoch(Instant::now()),
        dur_ns: 0,
        tid: 0,
        args,
        detail: None,
    });
}

// ---------------------------------------------------------------------------
// Span guards
// ---------------------------------------------------------------------------

/// RAII span: records a complete event from construction to drop. Inert
/// (no clock reads, no allocation) when tracing is disabled.
pub struct SpanGuard {
    /// `None` = tracing disabled at construction; fully inert.
    start: Option<Instant>,
    name: Cow<'static, str>,
    cat: &'static str,
    args: Vec<(&'static str, f64)>,
}

impl SpanGuard {
    /// Attach a numeric payload arg (builder style).
    pub fn arg(mut self, k: &'static str, v: f64) -> SpanGuard {
        self.set_arg(k, v);
        self
    }

    /// Attach a numeric payload arg known only mid-span (e.g. iteration
    /// counts at solver exit).
    pub fn set_arg(&mut self, k: &'static str, v: f64) {
        if self.start.is_some() {
            self.args.push((k, v));
        }
    }

    /// Whether this guard is live (tracing was enabled at construction).
    pub fn active(&self) -> bool {
        self.start.is_some()
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let end = Instant::now();
        push_event(TraceEvent {
            name: std::mem::replace(&mut self.name, Cow::Borrowed("")),
            cat: self.cat,
            ph: Ph::Span,
            ts_ns: ns_since_epoch(start),
            dur_ns: end.saturating_duration_since(start).as_nanos().min(u64::MAX as u128) as u64,
            tid: 0,
            args: std::mem::take(&mut self.args),
            detail: None,
        });
    }
}

/// Open a span with a static name.
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { start: None, name: Cow::Borrowed(""), cat, args: Vec::new() };
    }
    SpanGuard { start: Some(Instant::now()), name: Cow::Borrowed(name), cat, args: Vec::new() }
}

/// Open a span with a runtime name (unit/module names); the name is only
/// cloned when tracing is enabled.
#[inline]
pub fn span_owned(name: &str, cat: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { start: None, name: Cow::Borrowed(""), cat, args: Vec::new() };
    }
    SpanGuard {
        start: Some(Instant::now()),
        name: Cow::Owned(name.to_string()),
        cat,
        args: Vec::new(),
    }
}

/// Record an already-elapsed interval as a span (e.g. request latency
/// measured from its enqueue instant). Instants before the trace epoch
/// saturate to it.
pub fn span_closed(name: &'static str, cat: &'static str, start: Instant, end: Instant) {
    span_closed_args(name, cat, start, end, &[]);
}

/// [`span_closed`] with numeric payload args.
pub fn span_closed_args(
    name: &'static str,
    cat: &'static str,
    start: Instant,
    end: Instant,
    args: &[(&'static str, f64)],
) {
    if !enabled() {
        return;
    }
    push_event(TraceEvent {
        name: Cow::Borrowed(name),
        cat,
        ph: Ph::Span,
        ts_ns: ns_since_epoch(start),
        dur_ns: end.saturating_duration_since(start).as_nanos().min(u64::MAX as u128) as u64,
        tid: 0,
        args: args.to_vec(),
        detail: None,
    });
}

/// Allocate a named virtual track (a chrome `tid` that belongs to no OS
/// thread) for interval spans that don't follow one thread's call stack —
/// e.g. per-request lifetimes, which start on client threads and close on
/// the serve thread, and may overlap each other across batch boundaries.
/// Keeping them off the real threads' tracks preserves strict span nesting
/// there.
pub fn virtual_track(name: &str) -> u64 {
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    locked(&THREAD_NAMES).push((tid, name.to_string()));
    tid
}

/// [`span_closed_args`] recorded onto a [`virtual_track`] instead of the
/// calling thread's track.
pub fn span_closed_on(
    track: u64,
    name: &'static str,
    cat: &'static str,
    start: Instant,
    end: Instant,
    args: &[(&'static str, f64)],
) {
    if !enabled() {
        return;
    }
    push_event(TraceEvent {
        name: Cow::Borrowed(name),
        cat,
        ph: Ph::Span,
        ts_ns: ns_since_epoch(start),
        dur_ns: end.saturating_duration_since(start).as_nanos().min(u64::MAX as u128) as u64,
        tid: track,
        args: args.to_vec(),
        detail: None,
    });
}

/// Kernel span with optional numeric payload args:
/// `span!("gmres")`, `span!("gmres", cols = bs.len())`,
/// `span!("subst", k = nrhs, n = unknowns)`. Expands to a
/// [`telemetry::span`](crate::telemetry::span) guard in category
/// `"kernel"` — bind it (`let _sp = span!(..);`) so it lives to the end of
/// the scope being measured.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::telemetry::span($name, "kernel")
    };
    ($name:expr $(, $k:ident = $v:expr)+ $(,)?) => {{
        let mut __sp = $crate::telemetry::span($name, "kernel");
        $( __sp.set_arg(stringify!($k), ($v) as f64); )+
        __sp
    }};
}

// ---------------------------------------------------------------------------
// Thread-local buffers → global collector
// ---------------------------------------------------------------------------

/// Per-thread buffer size that triggers a flush to the global collector.
const FLUSH_AT: usize = 1024;
/// Global collector cap; beyond this events are counted as dropped.
pub const MAX_EVENTS: usize = 4_000_000;

static COLLECTOR: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static THREAD_NAMES: Mutex<Vec<(u64, String)>> = Mutex::new(Vec::new());

struct ThreadBuf {
    tid: u64,
    events: Vec<TraceEvent>,
}

impl ThreadBuf {
    fn new() -> ThreadBuf {
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let name = std::thread::current()
            .name()
            .map(|n| n.to_string())
            .unwrap_or_else(|| format!("thread-{tid}"));
        locked(&THREAD_NAMES).push((tid, name));
        ThreadBuf { tid, events: Vec::new() }
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        flush_into_collector(&mut self.events);
    }
}

thread_local! {
    static TLS: RefCell<ThreadBuf> = RefCell::new(ThreadBuf::new());
}

fn locked<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn flush_into_collector(events: &mut Vec<TraceEvent>) {
    if events.is_empty() {
        return;
    }
    let mut g = locked(&COLLECTOR);
    let room = MAX_EVENTS.saturating_sub(g.len());
    if events.len() > room {
        DROPPED.fetch_add((events.len() - room) as u64, Ordering::Relaxed);
        events.truncate(room);
    }
    g.append(events);
}

fn push_event(ev: TraceEvent) {
    let mut ev = Some(ev);
    let _ = TLS.try_with(|cell| {
        let mut buf = cell.borrow_mut();
        let mut e = ev.take().expect("event present on first use");
        if e.tid == 0 {
            // tid 0 = "the recording thread"; nonzero = a virtual track
            e.tid = buf.tid;
        }
        buf.events.push(e);
        if buf.events.len() >= FLUSH_AT {
            let mut full = std::mem::take(&mut buf.events);
            drop(buf); // don't hold the TLS borrow across the global lock
            flush_into_collector(&mut full);
        }
    });
    if ev.is_some() {
        // thread is tearing down and its TLS slot is gone — count, don't lose silently
        DROPPED.fetch_add(1, Ordering::Relaxed);
    }
}

/// Flush the calling thread's buffered events to the global collector.
pub fn flush_thread() {
    let _ = TLS.try_with(|cell| {
        let mut buf = cell.borrow_mut();
        let mut full = std::mem::take(&mut buf.events);
        drop(buf);
        flush_into_collector(&mut full);
    });
}

/// Take every collected event, sorted by timestamp. Flushes the calling
/// thread first; other *live* threads' buffers are only visible after they
/// flush or exit (`util::pool` workers are scoped, so they have always
/// exited by the time their caller can drain).
pub fn drain() -> Vec<TraceEvent> {
    flush_thread();
    let mut v = std::mem::take(&mut *locked(&COLLECTOR));
    v.sort_by_key(|e| e.ts_ns);
    v
}

/// Discard all collected events and the dropped-event count (test helper).
pub fn clear() {
    flush_thread();
    locked(&COLLECTOR).clear();
    DROPPED.store(0, Ordering::Relaxed);
}

/// Events lost to the collector cap or thread teardown since the last
/// [`clear`].
pub fn dropped_events() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Snapshot of the trace-local thread-id → thread-name table.
pub fn thread_names() -> Vec<(u64, String)> {
    locked(&THREAD_NAMES).clone()
}

// ---------------------------------------------------------------------------
// Export: chrome://tracing JSON and JSONL
// ---------------------------------------------------------------------------

fn json_escaped(s: &str) -> String {
    crate::util::json::Json::str(s).to_string()
}

/// Render events as a chrome://tracing / Perfetto `trace_event` JSON
/// document (`{"traceEvents": [...]}`; `ts`/`dur` in microseconds with
/// nanosecond fraction, one `pid`, trace-local `tid`s with thread-name
/// metadata).
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    use std::fmt::Write;
    let mut out = String::with_capacity(events.len() * 96 + 256);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for (tid, name) in thread_names() {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
             \"args\":{{\"name\":{}}}}}",
            json_escaped(&name)
        );
    }
    for e in events {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\":{},\"cat\":\"{}\",\"ph\":\"{}\",\"pid\":1,\"tid\":{},\"ts\":{}",
            json_escaped(&e.name),
            e.cat,
            e.ph.code(),
            e.tid,
            e.ts_ns as f64 / 1e3,
        );
        if e.ph == Ph::Span {
            let _ = write!(out, ",\"dur\":{}", e.dur_ns as f64 / 1e3);
        } else {
            // instant events: thread scope
            out.push_str(",\"s\":\"t\"");
        }
        if !e.args.is_empty() || e.detail.is_some() {
            out.push_str(",\"args\":{");
            let mut afirst = true;
            for (k, v) in &e.args {
                if !afirst {
                    out.push(',');
                }
                afirst = false;
                let _ = write!(out, "\"{k}\":{}", crate::util::json::Json::num(*v));
            }
            if let Some(d) = &e.detail {
                if !afirst {
                    out.push(',');
                }
                let _ = write!(out, "\"detail\":{}", json_escaped(d));
            }
            out.push('}');
        }
        out.push('}');
    }
    out.push_str("]}");
    out
}

/// Write [`chrome_trace_json`] to `path` (atomically: tmp + rename).
pub fn write_chrome_trace(path: impl AsRef<Path>, events: &[TraceEvent]) -> std::io::Result<()> {
    let path = path.as_ref();
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(chrome_trace_json(events).as_bytes())?;
        f.flush()?;
    }
    std::fs::rename(&tmp, path)
}

/// Write one JSON object per event (nanosecond timestamps preserved) —
/// the grep/jq-friendly log form of the same trace.
pub fn write_jsonl(path: impl AsRef<Path>, events: &[TraceEvent]) -> std::io::Result<()> {
    use std::fmt::Write as _;
    let path = path.as_ref();
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        for e in events {
            let mut line = String::with_capacity(96);
            let _ = write!(
                line,
                "{{\"name\":{},\"cat\":\"{}\",\"ph\":\"{}\",\"tid\":{},\"ts_ns\":{},\"dur_ns\":{}",
                json_escaped(&e.name),
                e.cat,
                e.ph.code(),
                e.tid,
                e.ts_ns,
                e.dur_ns,
            );
            for (k, v) in &e.args {
                let _ = write!(line, ",\"{k}\":{}", crate::util::json::Json::num(*v));
            }
            if let Some(d) = &e.detail {
                let _ = write!(line, ",\"detail\":{}", json_escaped(d));
            }
            line.push('}');
            writeln!(f, "{line}")?;
        }
        f.flush()?;
    }
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Global tracing state is process-wide; serialize the tests that
    /// toggle it (other lib tests never enable tracing, so they only ever
    /// see the disabled fast path).
    fn lock_telemetry() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = lock_telemetry();
        set_level(Level::Off);
        clear();
        {
            let _sp = span("tele_test_disabled", "kernel");
            event(Event::SolverFallback { cold: true });
        }
        let evs = drain();
        assert!(
            !evs.iter().any(|e| e.name == "tele_test_disabled" || e.name == "solver_fallback"),
            "disabled level must add zero events"
        );
    }

    #[test]
    fn spans_nest_and_carry_args() {
        let _g = lock_telemetry();
        set_level(Level::Spans);
        clear();
        {
            let _outer = span("tele_test_outer", "serve").arg("batch", 4.0);
            std::thread::sleep(Duration::from_millis(2));
            {
                let mut inner = crate::span!("tele_test_inner", iters = 3usize);
                inner.set_arg("resid", 0.5);
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        event(Event::DriftDetected { margin: 0.25 });
        set_level(Level::Off);
        let evs = drain();
        let outer = evs.iter().find(|e| e.name == "tele_test_outer").expect("outer span");
        let inner = evs.iter().find(|e| e.name == "tele_test_inner").expect("inner span");
        let drift = evs.iter().find(|e| e.name == "drift_detected").expect("drift event");
        assert_eq!(outer.ph, Ph::Span);
        assert_eq!(drift.ph, Ph::Instant);
        assert_eq!(outer.tid, inner.tid, "same thread, same track");
        // strict containment on the shared monotonic clock
        assert!(inner.ts_ns >= outer.ts_ns);
        assert!(inner.ts_ns + inner.dur_ns <= outer.ts_ns + outer.dur_ns);
        assert!(inner.dur_ns >= 1_000_000, "slept 1ms inside");
        assert_eq!(outer.args, vec![("batch", 4.0)]);
        assert_eq!(inner.args, vec![("iters", 3.0), ("resid", 0.5)]);
    }

    #[test]
    fn worker_thread_events_flush_on_exit() {
        let _g = lock_telemetry();
        set_level(Level::Spans);
        clear();
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    let _sp = span("tele_test_worker", "kernel");
                });
            }
        });
        set_level(Level::Off);
        let evs = drain();
        let workers: Vec<_> = evs.iter().filter(|e| e.name == "tele_test_worker").collect();
        assert_eq!(workers.len(), 2, "joined workers' buffers are drained");
        assert_ne!(workers[0].tid, workers[1].tid, "distinct trace tids");
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let _g = lock_telemetry();
        set_level(Level::Spans);
        clear();
        {
            let _sp = span_owned("tele \"quoted\" name", "module").arg("k", 1.5);
        }
        event(Event::ExecutorError { batch: 7 });
        set_level(Level::Off);
        let evs = drain();
        let evs: Vec<TraceEvent> = evs
            .into_iter()
            .filter(|e| e.name.contains("tele") || e.name == "executor_error")
            .collect();
        let doc = chrome_trace_json(&evs);
        let parsed = crate::util::json::Json::parse(&doc).expect("valid json");
        let arr = parsed.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents");
        // metadata rows + our two events
        assert!(arr.len() >= 2, "{doc}");
        for ev in arr {
            let ph = ev.get("ph").and_then(|v| v.as_str()).expect("ph");
            assert!(matches!(ph, "X" | "i" | "M"), "unexpected ph {ph}");
            if ph == "X" {
                assert!(ev.get("dur").and_then(|v| v.as_f64()).expect("dur") >= 0.0);
                assert!(ev.get("ts").and_then(|v| v.as_f64()).expect("ts") >= 0.0);
            }
        }
        // JSONL: one parseable object per line
        let tmp = std::env::temp_dir().join(format!("memx_tele_{}.jsonl", std::process::id()));
        write_jsonl(&tmp, &evs).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        std::fs::remove_file(&tmp).ok();
        assert_eq!(text.lines().count(), evs.len());
        for line in text.lines() {
            crate::util::json::Json::parse(line).expect("jsonl line parses");
        }
    }
}
