//! L3 coordinator — the inference service.
//!
//! Topology (PJRT wrappers are !Send, so the engine is pinned):
//!
//! ```text
//!   clients ──mpsc──► batcher thread ──(assembled batches)──► executor
//!   (Client::classify)  plan_batch()        same thread owns Engine
//!        ◄──────────── per-request oneshot responses ◄────────┘
//! ```
//!
//! The batcher+executor run on a single dedicated thread: it drains the
//! queue, assembles a batch per [`batcher::plan_batch`], executes via PJRT
//! and answers each request through its response channel. This mirrors the
//! paper's deployment model where one analog accelerator serves a stream of
//! sensor frames; metrics capture latency/throughput for Fig 8-style runs.

//! The batching policy ([`batcher`]), metrics ([`metrics`]), [`accuracy`]
//! and the crossbar-pipeline analog path ([`classify_dataset_analog`],
//! batching images through
//! [`Pipeline::forward_batch`](crate::pipeline::Pipeline::forward_batch))
//! are pure and always available; the PJRT-backed service (`Server`,
//! `classify_dataset`) needs the `runtime-xla` feature.

pub mod batcher;
pub mod metrics;

#[cfg(feature = "runtime-xla")]
use std::path::Path;
#[cfg(feature = "runtime-xla")]
use std::sync::atomic::{AtomicBool, Ordering};
#[cfg(feature = "runtime-xla")]
use std::sync::mpsc::{channel, Receiver, Sender};
#[cfg(feature = "runtime-xla")]
use std::sync::Arc;
use std::time::Instant;

#[cfg(feature = "runtime-xla")]
use anyhow::anyhow;
use anyhow::Result;

use crate::pipeline::{image_to_input, Pipeline};
use crate::util::bin::Dataset;

#[cfg(feature = "runtime-xla")]
use crate::runtime::{argmax_rows, Engine, Model};
#[cfg(feature = "runtime-xla")]
use metrics::Metrics;

#[cfg(feature = "runtime-xla")]
/// One classification result.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub label: usize,
    pub logits: Vec<f32>,
    /// end-to-end latency observed by the server
    pub latency: std::time::Duration,
}

#[cfg(feature = "runtime-xla")]
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    resp: Sender<Result<Prediction>>,
}

#[cfg(feature = "runtime-xla")]
/// Cloneable submission handle.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    img_elems: usize,
    metrics: Arc<Metrics>,
}

#[cfg(feature = "runtime-xla")]
impl Client {
    /// Blocking classify of one NHWC image.
    pub fn classify(&self, image: Vec<f32>) -> Result<Prediction> {
        if image.len() != self.img_elems {
            return Err(anyhow!("image has {} floats, expected {}", image.len(), self.img_elems));
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.tx
            .send(Request { image, enqueued: Instant::now(), resp: tx })
            .map_err(|_| anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

#[cfg(feature = "runtime-xla")]
/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub model: Model,
    pub max_wait: std::time::Duration,
}

#[cfg(feature = "runtime-xla")]
impl Default for ServerConfig {
    fn default() -> Self {
        Self { model: Model::Analog, max_wait: batcher::default_max_wait() }
    }
}

#[cfg(feature = "runtime-xla")]
pub struct Server {
    client: Client,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    pub warmup: std::time::Duration,
}

#[cfg(feature = "runtime-xla")]
impl Server {
    /// Start the service: builds the engine on the service thread (PJRT
    /// handles are !Send), pre-compiles all batch variants, then serves.
    pub fn start(artifacts_dir: &Path, cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let dir = artifacts_dir.to_path_buf();
        let m2 = metrics.clone();
        let stop2 = stop.clone();

        // probe the manifest on the caller thread for early errors + geometry
        let manifest = crate::nn::Manifest::load(artifacts_dir)?;
        let img_elems = manifest.img * manifest.img * 3;

        let (ready_tx, ready_rx) = channel::<Result<std::time::Duration>>();
        let join = std::thread::Builder::new()
            .name("memx-serve".into())
            .spawn(move || serve_thread(dir, cfg, rx, m2, stop2, ready_tx))
            .expect("spawn server thread");
        let warmup = ready_rx
            .recv()
            .map_err(|_| anyhow!("server thread died during warmup"))??;
        Ok(Server {
            client: Client { tx, img_elems, metrics },
            stop,
            join: Some(join),
            warmup,
        })
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.client.metrics.clone()
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            j.join().ok();
        }
    }
}

#[cfg(feature = "runtime-xla")]
impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            j.join().ok();
        }
    }
}

#[cfg(feature = "runtime-xla")]
fn serve_thread(
    dir: std::path::PathBuf,
    cfg: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    ready: Sender<Result<std::time::Duration>>,
) {
    // build + warm the engine
    let t0 = Instant::now();
    let engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            ready.send(Err(e)).ok();
            return;
        }
    };
    let sizes = engine.available_batches();
    for &b in &sizes {
        if let Err(e) = engine.get(cfg.model, b) {
            ready.send(Err(e)).ok();
            return;
        }
    }
    ready.send(Ok(t0.elapsed())).ok();

    let mut queue: Vec<Request> = Vec::new();
    // reusable input buffer — hot path stays allocation-free after warmup
    let largest = sizes.iter().copied().max().unwrap_or(1);
    let img_elems = engine.manifest().img * engine.manifest().img * 3;
    let mut input = vec![0f32; largest * img_elems];

    while !stop.load(Ordering::Relaxed) {
        // drain everything currently queued
        while let Ok(r) = rx.try_recv() {
            queue.push(r);
        }
        let waited_out = queue
            .first()
            .map(|r| r.enqueued.elapsed() >= cfg.max_wait)
            .unwrap_or(false);
        let Some(plan) = batcher::plan_batch(&sizes, queue.len(), waited_out) else {
            // nothing to do: block briefly for the next request
            match rx.recv_timeout(std::time::Duration::from_millis(1)) {
                Ok(r) => queue.push(r),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    if queue.is_empty() {
                        break;
                    }
                }
            }
            continue;
        };

        let batch: Vec<Request> = queue.drain(..plan.real).collect();
        let buf = &mut input[..plan.size * img_elems];
        for (i, r) in batch.iter().enumerate() {
            buf[i * img_elems..(i + 1) * img_elems].copy_from_slice(&r.image);
            metrics.record_queue(r.enqueued.elapsed());
        }
        // pad by replicating the last real image
        for i in plan.real..plan.size {
            let (head, tail) = buf.split_at_mut(i * img_elems);
            tail[..img_elems].copy_from_slice(&head[(plan.real - 1) * img_elems..plan.real * img_elems]);
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics
            .padded_slots
            .fetch_add((plan.size - plan.real) as u64, Ordering::Relaxed);

        let exec = engine.get(cfg.model, plan.size).expect("precompiled");
        match exec.run(buf) {
            Ok(logits) => {
                let classes = exec.num_classes;
                let labels = argmax_rows(&logits, classes);
                for (i, r) in batch.into_iter().enumerate() {
                    let latency = r.enqueued.elapsed();
                    metrics.record_latency(latency);
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    let pred = Prediction {
                        label: labels[i],
                        logits: logits[i * classes..(i + 1) * classes].to_vec(),
                        latency,
                    };
                    r.resp.send(Ok(pred)).ok();
                }
            }
            Err(e) => {
                for r in batch {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                    r.resp.send(Err(anyhow!("execute failed: {e}"))).ok();
                }
            }
        }
    }
}

#[cfg(feature = "runtime-xla")]
/// Synchronous bulk evaluation (no batcher thread): classify `n` images from
/// a dataset with greedy largest-batch packing. Returns (labels, wall time).
pub fn classify_dataset(
    engine: &Engine,
    model: Model,
    ds: &crate::util::bin::Dataset,
    n: usize,
) -> Result<(Vec<usize>, std::time::Duration)> {
    let n = n.min(ds.n);
    let img = ds.image_len();
    let mut labels = Vec::with_capacity(n);
    let t0 = Instant::now();
    let mut i = 0;
    while i < n {
        let b = engine.pick_batch(n - i);
        let exec = engine.get(model, b)?;
        let take = b.min(n - i);
        let mut buf = vec![0f32; b * img];
        for j in 0..take {
            buf[j * img..(j + 1) * img].copy_from_slice(ds.image(i + j));
        }
        for j in take..b {
            let src = ds.image(i + take - 1).to_vec();
            buf[j * img..(j + 1) * img].copy_from_slice(&src);
        }
        let logits = exec.run(&buf)?;
        labels.extend(argmax_rows(&logits, exec.num_classes).into_iter().take(take));
        i += take;
    }
    Ok((labels, t0.elapsed()))
}

/// Synchronous bulk evaluation through the analog crossbar [`Pipeline`] —
/// the offline counterpart of the PJRT `classify_dataset` and the serving
/// path the ROADMAP asked for: images are packed with the same [`batcher::plan_batch`]
/// policy the PJRT server uses, and each batch is answered by one
/// [`Pipeline::forward_batch`] call — so at
/// [`Fidelity::Spice`](crate::pipeline::Fidelity::Spice) every crossbar read
/// amortizes the whole batch over a single multi-RHS
/// [`CrossbarSim::solve_batch`](crate::netlist::CrossbarSim::solve_batch)
/// substitution pass per segment. Returns (labels, wall time).
pub fn classify_dataset_analog(
    pipeline: &mut Pipeline,
    ds: &Dataset,
    n: usize,
    batch_sizes: &[usize],
) -> Result<(Vec<usize>, std::time::Duration)> {
    let n = n.min(ds.n);
    let mut sizes: Vec<usize> = batch_sizes.iter().copied().filter(|&b| b > 0).collect();
    if sizes.is_empty() {
        sizes.push(16);
    }
    sizes.sort_unstable();
    sizes.dedup();
    let mut labels = Vec::with_capacity(n);
    let t0 = Instant::now();
    let mut i = 0;
    while i < n {
        // waited_out: bulk evaluation never holds requests back
        let Some(plan) = batcher::plan_batch(&sizes, n - i, true) else {
            break;
        };
        let take = plan.real.min(n - i);
        let batch: Vec<Vec<f64>> = (0..take)
            .map(|j| image_to_input(ds.image(i + j), ds.h, ds.w, ds.c))
            .collect();
        labels.extend(pipeline.classify_batch(&batch)?);
        i += take;
    }
    Ok((labels, t0.elapsed()))
}

/// Accuracy of predicted labels vs dataset ground truth.
pub fn accuracy(labels: &[usize], truth: &[u8]) -> f64 {
    if labels.is_empty() {
        return 0.0;
    }
    let correct = labels.iter().zip(truth).filter(|(p, t)| **p == **t as usize).count();
    correct as f64 / labels.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn analog_path_batches_and_classifies() {
        use crate::pipeline::{argmax, default_device, Fidelity, PipelineBuilder};
        let (h, w, c) = (2, 2, 3);
        let n = 5;
        let ds = Dataset {
            n,
            h,
            w,
            c,
            data: (0..n * h * w * c).map(|i| (i % 7) as f32 / 7.0).collect(),
            labels: vec![0; n],
        };
        let dev = default_device();
        let mut p = PipelineBuilder::new()
            .fidelity(Fidelity::Ideal)
            .build_fc_stack(&[h * w * c, 4], &dev, 3)
            .unwrap();
        let (labels, _) = classify_dataset_analog(&mut p, &ds, n, &[2]).unwrap();
        assert_eq!(labels.len(), n);
        assert!(labels.iter().all(|&l| l < 4));
        // the batched serving path must agree with per-image forwards
        for (i, &label) in labels.iter().enumerate() {
            let x = image_to_input(ds.image(i), h, w, c);
            assert_eq!(label, argmax(&p.forward(&x).unwrap()), "image {i}");
        }
    }
}
