//! Borrowed tensor view over the weights blob.

use crate::backend::{self, BackendChoice};

/// A read-only tensor slice of weights.bin with its manifest metadata.
#[derive(Debug, Clone)]
pub struct Tensor<'a> {
    pub shape: Vec<usize>,
    pub data: &'a [f32],
    /// analog scale (max |w|) if this tensor is mapped to a crossbar
    pub scale: Option<f64>,
}

impl<'a> Tensor<'a> {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Conv weight (k,k,cin,cout) -> crossbar matrix (cin*k*k, cout) in the
    /// (C, kh, kw) feature order used by the im2col dataflow (model.py
    /// `_w_matrix`). FC weights (cin,cout) pass through.
    pub fn as_matrix(&self) -> (usize, usize, Vec<f32>) {
        match self.shape.as_slice() {
            [k1, k2, cin, cout] => {
                let (k1, k2, cin, cout) = (*k1, *k2, *cin, *cout);
                let rows = cin * k1 * k2;
                let mut m = vec![0f32; rows * cout];
                backend::resolve(BackendChoice::Auto)
                    .conv_reorder(self.data, [k1, k2, cin, cout], &mut m);
                (rows, cout, m)
            }
            [cin, cout] => (*cin, *cout, self.data.to_vec()),
            [c] => (1, *c, self.data.to_vec()),
            other => panic!("unsupported weight rank {other:?}"),
        }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0f32, |a, &x| a.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_matrix_passthrough() {
        let data: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let t = Tensor { shape: vec![2, 3], data: &data, scale: None };
        let (r, c, m) = t.as_matrix();
        assert_eq!((r, c), (2, 3));
        assert_eq!(m, data);
    }

    #[test]
    fn conv_matrix_feature_order() {
        // (k1,k2,cin,cout) = (2,1,2,1): features must come out as (C,kh,kw)
        let data = vec![
            1.0, // a=0,b=0,c=0
            2.0, // a=0,b=0,c=1
            3.0, // a=1,b=0,c=0
            4.0, // a=1,b=0,c=1
        ];
        let t = Tensor { shape: vec![2, 1, 2, 1], data: &data, scale: None };
        let (r, c, m) = t.as_matrix();
        assert_eq!((r, c), (4, 1));
        // order: c0(a0,a1), c1(a0,a1)
        assert_eq!(m, vec![1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn max_abs() {
        let data = vec![-3.0, 1.0, 2.5];
        let t = Tensor { shape: vec![3], data: &data, scale: None };
        assert_eq!(t.max_abs(), 3.0);
    }
}
