//! PJRT runtime — loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client (`xla` crate 0.1.6 / xla_extension 0.5.1).
//!
//! Interchange is HLO **text**: `HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id protos jax >= 0.5 emits that
//! this XLA build rejects (see /opt/xla-example/README.md).
//!
//! Python never runs here: artifacts are compiled once at startup (or
//! lazily, cached per batch size) and the request path is pure rust + XLA.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::nn::Manifest;

/// Which lowered forward to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Model {
    /// memristor computing paradigm (quantized differential crossbars)
    Analog,
    /// fp32 digital baseline ("CPU" row of Fig 8)
    Digital,
}

impl Model {
    pub fn artifact_key(&self, batch: usize) -> String {
        match self {
            Model::Analog => format!("model_b{batch}"),
            Model::Digital => format!("digital_b{batch}"),
        }
    }
}

/// A compiled executable with its input geometry.
pub struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub img_elems: usize,
    pub num_classes: usize,
    pub compile_time: std::time::Duration,
}

impl Compiled {
    /// Run one batch. `images` must be exactly `batch * img_elems` floats
    /// (NHWC). Returns row-major logits (batch x num_classes).
    pub fn run(&self, images: &[f32]) -> Result<Vec<f32>> {
        if images.len() != self.batch * self.img_elems {
            bail!(
                "input size {} != batch {} * img {}",
                images.len(),
                self.batch,
                self.img_elems
            );
        }
        let hw = ((self.img_elems / 3) as f64).sqrt() as i64;
        let lit = xla::Literal::vec1(images)
            .reshape(&[self.batch as i64, hw, hw, 3])
            .context("reshape input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?; // lowered with return_tuple=True
        Ok(result.to_vec::<f32>()?)
    }
}

/// The engine owns the PJRT client and an executable cache keyed by
/// (model, batch).
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<BTreeMap<(Model, usize), &'static Compiled>>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Engine {
            client,
            dir: artifacts_dir.to_path_buf(),
            manifest,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Batch sizes for which artifacts exist, ascending.
    pub fn available_batches(&self) -> Vec<usize> {
        self.manifest.batch_sizes.clone()
    }

    /// Get (compiling + caching on first use) the executable for a model and
    /// exact batch size.
    ///
    /// Executables are leaked into 'static: a handful of variants live for
    /// the process lifetime anyway, and this keeps the hot path free of
    /// lock-held references.
    ///
    /// The cache lock is held across the compile (single-flight): if two
    /// threads raced the old check-then-insert, both compiled the same
    /// artifact and the loser's `Box::leak` was orphaned for the process
    /// lifetime. Compiles are rare (a handful of variants at warmup), so
    /// serializing them is the simple correct choice.
    pub fn get(&self, model: Model, batch: usize) -> Result<&'static Compiled> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(c) = cache.get(&(model, batch)) {
            return Ok(c);
        }
        let key = model.artifact_key(batch);
        let compiled = Box::leak(Box::new(self.compile_artifact(&key, batch)?));
        cache.insert((model, batch), compiled);
        Ok(compiled)
    }

    /// Compile an arbitrary artifact by manifest key (e.g. the
    /// "model_kernelpath_b8" pallas-lowering cross-validation variant).
    /// Not cached — intended for tests/benches.
    pub fn compile_key(&self, key: &str, batch: usize) -> Result<Compiled> {
        self.compile_artifact(key, batch)
    }

    fn compile_artifact(&self, key: &str, batch: usize) -> Result<Compiled> {
        let file = self
            .manifest
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow!("no artifact '{key}' (batch {batch} not exported)"))?;
        let path = self.dir.join(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let exe = self
            .client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .map_err(|e| anyhow!("XLA compile {key}: {e}"))?;
        Ok(Compiled {
            exe,
            batch,
            img_elems: self.manifest.img * self.manifest.img * 3,
            num_classes: self.manifest.num_classes,
            compile_time: t0.elapsed(),
        })
    }

    /// Largest available batch size <= want (or the smallest overall).
    pub fn pick_batch(&self, want: usize) -> usize {
        let mut best = None;
        for &b in &self.manifest.batch_sizes {
            if b <= want {
                best = Some(best.map_or(b, |x: usize| x.max(b)));
            }
        }
        best.unwrap_or_else(|| self.manifest.batch_sizes.iter().copied().min().unwrap_or(1))
    }
}

/// argmax over each row of logits (shared with the serving coordinator).
/// Ties resolve to the FIRST maximum — the crate-wide convention
/// (`pipeline::argmax`); the previous local implementation picked the
/// last, which only differed on exactly-tied f32 rows.
pub use crate::util::argmax_rows;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        let logits = vec![0.1, 0.9, 0.0, /* row2 */ 5.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }

    #[test]
    fn artifact_keys() {
        assert_eq!(Model::Analog.artifact_key(8), "model_b8");
        assert_eq!(Model::Digital.artifact_key(1), "digital_b1");
    }
}
