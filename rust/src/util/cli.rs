//! Flag parsing for the `memx` CLI (clap is not in the offline cache).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; unknown flags are an error so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// `spec`: list of accepted flag names (without `--`). Flags listed with
    /// a trailing `!` are boolean (no value).
    pub fn parse(argv: &[String], spec: &[&str]) -> Result<Args> {
        let mut a = Args::default();
        let bool_flags: Vec<&str> =
            spec.iter().filter(|s| s.ends_with('!')).map(|s| &s[..s.len() - 1]).collect();
        let val_flags: Vec<&str> =
            spec.iter().filter(|s| !s.ends_with('!')).map(|s| *s).collect();
        a.known = spec.iter().map(|s| s.trim_end_matches('!').to_string()).collect();

        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(flag) = arg.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    if !val_flags.contains(&k) {
                        bail!("unknown flag --{k}");
                    }
                    a.flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&flag) {
                    a.bools.push(flag.to_string());
                } else if val_flags.contains(&flag) {
                    i += 1;
                    let Some(v) = argv.get(i) else { bail!("--{flag} needs a value") };
                    a.flags.insert(flag.to_string(), v.clone());
                } else {
                    bail!("unknown flag --{flag}");
                }
            } else {
                a.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn has(&self, flag: &str) -> bool {
        self.bools.iter().any(|b| b == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_and_bools() {
        let a = Args::parse(&sv(&["--n", "5", "--verbose", "pos1", "--k=v"]),
                            &["n", "k", "verbose!"]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        assert_eq!(a.get("k"), Some("v"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(Args::parse(&sv(&["--nope"]), &["n"]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--n"]), &["n"]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[]), &["n"]).unwrap();
        assert_eq!(a.get_usize("n", 42).unwrap(), 42);
        assert_eq!(a.get_f64("n", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_or("n", "d"), "d");
    }
}
