//! Quickstart — the five-minute tour of the memx public API, built around
//! the `memx::pipeline` builder.
//!
//!   cargo run --release --example quickstart
//!
//! With trained artifacts present (`make artifacts`), compiles the full
//! manifest into a runnable analog pipeline, classifies a few images
//! batch-first, cross-checks one layer at SPICE fidelity and prints the
//! Eq 17/18 latency + energy estimates. Without artifacts it falls back to
//! a synthetic FC stack, so the tour always runs — no PJRT required
//! (see examples/serve_cifar.rs for the PJRT serving demo).
//!
//! `Fidelity::Spice` (the CLI's `--fidelity spice`) now covers the whole
//! module chain: batch-norm runs its §3.3 subtraction + scale/offset
//! netlists and global average pooling its §3.5 averaging column, next to
//! the crossbar layers and the Fig 4 activation circuits — no module falls
//! back to its exact transfer (`memx report --coverage` prints the
//! per-stage table; rust/tests/fidelity.rs pins it).
//!
//! # Interchange and validation
//!
//! Every resident circuit also exports as a standard `.SUBCKT` deck
//! (`memx::netlist::interchange`) that external SPICE tooling — or
//! `parse_deck` itself — can read back. `memx validate [--quick]` proves
//! emit -> parse -> sim matches every resident solve and cross-checks the
//! production engine against an independent dense MNA reference plus
//! fuzzed corpora (`memx::netlist::validate`); the tour below round-trips
//! one crossbar deck.
//!
//! # Backend selection
//!
//! Every dense hot loop behind the SPICE engine — multi-RHS LU
//! substitution, GMRES matvec/axpy/dot, ILU(0) sweeps, im2col — runs
//! through a pluggable [`memx::backend::Backend`]. Pick one with
//! [`PipelineBuilder::backend`] (as below), `--backend scalar|simd|auto`
//! on the `spice`/`accuracy`/`serve`/`tran` subcommands, or the
//! `MEMX_BACKEND` environment variable. `auto` (the default) resolves to
//! the portable-SIMD lane-blocked kernels; `scalar` is the verbatim
//! reference the parity suite (rust/tests/backend.rs) pins it against.
//!
//! # Observability
//!
//! The whole stack is span-instrumented through [`memx::telemetry`]:
//! serve request -> batch -> executor forward -> execution unit -> module
//! -> segment solve -> factor/GMRES/transient kernels, plus typed instant
//! events for drift detections, recalibrations, solver fallbacks and
//! executor errors. Tracing is off by default (one relaxed atomic load per
//! span site); the `serve`/`accuracy`/`spice`/`drift`/`tran` subcommands
//! enable it with `--trace-out trace.json` (a chrome://tracing /
//! ui.perfetto.dev file) or `--trace-jsonl trace.jsonl` (grep/jq-friendly).
//! `memx serve` additionally takes `--metrics-addr HOST:PORT` to serve the
//! metrics registry as Prometheus text exposition (`/metrics`) and JSON
//! (`/metrics.json`) — request/latency/drift/fallback series — and
//! `--linger-ms N` to hold the endpoint open after the demo drive so an
//! external scraper can read the final counters. The tour below records a
//! trace of one SPICE forward in-process.

use std::path::Path;

use memx::fault::{FaultConfig, FaultModel};
use memx::mapper::{self, MapMode};
use memx::nn::{Manifest, WeightStore};
use memx::pipeline::{
    argmax, default_device, image_to_input, BackendChoice, Fidelity, PipelineBuilder,
    SolverStrategy,
};
use memx::power;
use memx::util::bin::Dataset;
use memx::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        artifact_tour(dir)
    } else {
        synthetic_tour()
    }
}

/// Manifest-free tour: a synthetic FC stack through every fidelity level.
fn synthetic_tour() -> anyhow::Result<()> {
    println!("(artifacts missing — run `make artifacts` for the full-network tour)");
    let dev = default_device();
    let dims = [32usize, 24, 10];
    let mut rng = Rng::new(2024);
    let batch: Vec<Vec<f64>> = (0..4)
        .map(|_| (0..dims[0]).map(|_| rng.range_f64(-0.5, 0.5)).collect())
        .collect();
    for fidelity in [Fidelity::Ideal, Fidelity::Behavioural, Fidelity::Spice] {
        // SolverStrategy::Auto (the default) keeps small segmented
        // circuits on the direct factor engine and moves giant monolithic
        // crossbars (the paper's 2050x1024 case) onto preconditioned GMRES
        // — see spice::krylov. BackendChoice::Auto likewise resolves the
        // dense kernels (SIMD unless MEMX_BACKEND overrides — see
        // memx::backend)
        let mut pipe = PipelineBuilder::new()
            .fidelity(fidelity)
            .solver(SolverStrategy::Auto)
            .backend(BackendChoice::Auto)
            .segment(8)
            .build_fc_stack(&dims, &dev, 7)?;
        let logits = pipe.forward_batch(&batch)?;
        let labels: Vec<usize> = logits.iter().map(|row| argmax(row)).collect();
        let tag = fidelity.to_string();
        // at spice fidelity every module holds its emitted netlist — the
        // resident-circuit count is the no-fidelity-holes evidence
        let circuits = if fidelity == Fidelity::Spice {
            format!(" ({} resident circuits)", pipe.spice_circuits())
        } else {
            String::new()
        };
        println!(
            "{tag:<11} {} -> labels {labels:?}, logits[0][0] = {:+.5}{circuits}",
            pipe.describe(),
            logits[0][0]
        );
    }

    // device lifetime: age the resident crossbars in place with the
    // memx::fault engine (log-time drift + read disturb + stuck cells),
    // then reprogram — the write pass that a self-recalibrating server
    // triggers from its logit-margin watchdog (see `memx drift` for the
    // full accuracy/energy-vs-hours sweep)
    let mut pipe = PipelineBuilder::new()
        .fidelity(Fidelity::Behavioural)
        .build_fc_stack(&dims, &dev, 7)?;
    let fresh: Vec<usize> = pipe.classify_batch(&batch)?;
    let mut clock = FaultModel::new(FaultConfig { stuck_off_frac: 0.05, ..Default::default() });
    pipe.inject_faults(&clock.advance(10_000.0, 5_000_000));
    let aged = pipe.classify_batch(&batch)?;
    let rewritten = pipe.reprogram(0.0, clock.cfg().seed, 1);
    clock.reset_clock();
    let recovered = pipe.classify_batch(&batch)?;
    println!(
        "lifetime     labels fresh {fresh:?} -> aged 10kh {aged:?} -> \
         reprogrammed {recovered:?} ({rewritten} devices rewritten)"
    );

    // time-domain: the DC operating points above say nothing about *when*
    // a read settles — spice::transient replays one read pulse against a
    // synthetic crossbar (pulsed inputs, column parasitics, an RC
    // line-driver stage per output) and integrates the device energy,
    // next to the paper's closed-form Eq 17/18 columns (see `memx tran`
    // for the full integrator sweep appending BENCH_transient.json)
    let cb = mapper::build_synthetic_fc(16, 4, dev.levels, MapMode::Inverted, 11);
    let sim = memx::netlist::CrossbarSim::new(
        &cb,
        &dev,
        0,
        memx::spice::solve::Ordering::Smart,
        SolverStrategy::Auto,
    )?;
    let inputs: Vec<f64> = (0..16).map(|i| (i as f64 * 0.4).sin() * 0.3).collect();
    let read = sim.tran_read(&inputs, &memx::netlist::ReadPulse::default())?;
    let cmp = power::ReadComparison::new(
        &dev,
        MapMode::Inverted,
        cb.devices.len(),
        &power::SimulatedRead { settle_s: read.settle_s, energy_j: read.energy_j },
    );
    println!(
        "transient    read settles in {:.2} µs (analytical {:.2} µs), \
         {:.3} nJ in the devices over {} adaptive steps",
        read.settle_s * 1e6,
        cmp.analytical_latency_s * 1e6,
        read.energy_j * 1e9,
        read.stats.steps_accepted
    );

    // interchange: every resident circuit also speaks the standard
    // .SUBCKT dialect — emit a deck for external SPICE tooling, parse it
    // back (memx::netlist::interchange::parse_deck reads external decks
    // the same way), and prove the re-simulated operating point matches
    // the resident solve. `memx validate [--quick]` sweeps the whole demo
    // network plus generated differential/fuzz corpora through this
    // contract; rust/tests/interchange.rs pins it
    let decks = sim.decks("quickstart_fc");
    let deck = &decks[0];
    let text = memx::netlist::interchange::emit_deck(deck);
    let parsed = memx::netlist::interchange::parse_deck(&text)?;
    let report = memx::netlist::validate::check_deck(deck)?;
    println!(
        "interchange  {} -> {} deck lines, parsed back to {} elements, \
         round-trip rel {:.1e} (`memx validate --quick` sweeps every deck)",
        deck.name,
        text.lines().count(),
        parsed.elements.len(),
        report.roundtrip_rel
    );

    // observability: rerun one spice forward with span tracing enabled —
    // the drained events are the same hierarchy `--trace-out` writes for
    // chrome://tracing (unit -> module -> segment solve -> kernel spans)
    memx::telemetry::set_level(memx::telemetry::Level::Spans);
    let mut traced = PipelineBuilder::new()
        .fidelity(Fidelity::Spice)
        .segment(8)
        .build_fc_stack(&dims, &dev, 7)?;
    traced.forward_batch(&batch)?;
    memx::telemetry::set_level(memx::telemetry::Level::Off);
    let events = memx::telemetry::drain();
    let kernels = events.iter().filter(|e| e.cat == "kernel").count();
    println!(
        "telemetry    {} spans from one spice forward ({kernels} kernel-level) — \
         `memx serve --metrics-addr 127.0.0.1:9095 --trace-out trace.json` exports \
         the live equivalent",
        events.len()
    );
    Ok(())
}

/// Full tour over the trained artifacts.
fn artifact_tour(dir: &Path) -> anyhow::Result<()> {
    // 1. pipeline: compile manifest + weights into the analog module chain
    let manifest = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &manifest)?;
    let mut pipe = PipelineBuilder::new()
        .mode(MapMode::Inverted)
        .fidelity(Fidelity::Behavioural)
        .build(&manifest, &ws)?;
    println!("analog pipeline: {}", pipe.describe());

    // 2. classify a few held-out images, batch-first
    let ds = Dataset::load(&dir.join(&manifest.dataset_file))?;
    let n = 8.min(ds.n);
    let batch: Vec<Vec<f64>> =
        (0..n).map(|i| image_to_input(ds.image(i), ds.h, ds.w, ds.c)).collect();
    let labels = pipe.classify_batch(&batch)?;
    let correct = labels
        .iter()
        .zip(&ds.labels)
        .filter(|(p, t)| **p == **t as usize)
        .count();
    println!("classified {n} images in one batched forward: {correct}/{n} correct");

    // 3. one layer at SPICE fidelity vs the ideal crossbar
    let base = PipelineBuilder::new().segment(4);
    let mut spice = base.clone().fidelity(Fidelity::Spice).build_layer(&manifest, &ws, "cls.fc2")?;
    let mut ideal = base.fidelity(Fidelity::Ideal).build_layer(&manifest, &ws, "cls.fc2")?;
    let mut rng = Rng::new(5);
    let probe: Vec<Vec<f64>> = (0..3)
        .map(|_| (0..spice.in_dim()).map(|_| rng.range_f64(-0.5, 0.5)).collect())
        .collect();
    let err = spice
        .forward_batch(&probe)?
        .iter()
        .flatten()
        .zip(ideal.forward_batch(&probe)?.iter().flatten())
        .fold(0f64, |a, (s, i)| a.max((s - i).abs()));
    println!("cls.fc2 SPICE vs ideal: max error {err:.2e} over 3 batched vectors");

    // 4. analytical models: Eq 17 latency + Eq 18 energy
    let net = mapper::map_network(&manifest, &ws, MapMode::Inverted)?;
    let t = power::latency(&net, &manifest.device);
    let e = power::energy(&net, &manifest.device, &t);
    println!(
        "mapped network: {} memristors, {} op-amps, {} crossbar stages",
        net.total_memristors(),
        net.total_opamps(),
        net.memristor_stages()
    );
    println!(
        "inference: {:.2} µs sequential / {:.2} µs pipelined, {:.1} µJ",
        t.total * 1e6,
        power::latency_pipelined(&net, &manifest.device).total * 1e6,
        e.total * 1e6
    );
    Ok(())
}
