//! Device-lifetime fault engine, end to end: aging degrades the compiled
//! pipeline, reprogramming recovers it, and post-recalibration SPICE
//! re-solves ride the cached factorizations (the factor-once contract
//! across in-place conductance updates).

use memx::fault::{self, FaultConfig, FaultModel};
use memx::mapper::{build_synthetic_fc, MapMode};
use memx::netlist::CrossbarSim;
use memx::pipeline::{default_device, demo_network, Fidelity, PipelineBuilder, SolverStrategy};
use memx::spice::solve::Ordering;
use memx::util::prng::Rng;

fn demo_inputs(n: usize, dim: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..dim).map(|_| rng.f32() as f64 * 0.5).collect()).collect()
}

fn agreement(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len().max(1) as f64
}

#[test]
fn aggressive_aging_flips_labels_but_stays_finite() {
    let (m, ws) = demo_network(0xD511).unwrap();
    let mut pristine =
        PipelineBuilder::new().fidelity(Fidelity::Behavioural).build(&m, &ws).unwrap();
    let mut aged = PipelineBuilder::new().fidelity(Fidelity::Behavioural).build(&m, &ws).unwrap();
    let batch = demo_inputs(24, pristine.in_dim(), 0x5EED);
    let want = pristine.classify_batch(&batch).unwrap();

    let cfg = FaultConfig {
        drift_nu: 0.3,
        nu_sigma: 0.8,
        stuck_off_frac: 0.1,
        ..FaultConfig::default()
    };
    let mut model = FaultModel::new(cfg);
    let step = model.advance(10_000.0, 1_000_000);
    aged.inject_faults(&step);
    let drifted = aged.classify_batch(&batch).unwrap();
    assert!(
        agreement(&drifted, &want) < 1.0,
        "a decade of heavy drift plus 10% stuck-OFF cells must flip at least one label"
    );
    for row in aged.forward_batch(&batch).unwrap() {
        for v in row {
            assert!(v.is_finite(), "faulted logits must stay finite");
        }
    }
}

#[test]
fn reprogram_recovers_pristine_labels_under_default_drift() {
    // the acceptance bar: after recalibration the network must classify
    // within 1% of the pristine build under the default fault config
    // (drift + read disturb, no stuck cells)
    let (m, ws) = demo_network(0xD512).unwrap();
    let mut pristine =
        PipelineBuilder::new().fidelity(Fidelity::Behavioural).build(&m, &ws).unwrap();
    let mut aged = PipelineBuilder::new().fidelity(Fidelity::Behavioural).build(&m, &ws).unwrap();
    let batch = demo_inputs(32, pristine.in_dim(), 0x5EED2);
    let want = pristine.classify_batch(&batch).unwrap();

    let cfg = FaultConfig::default();
    let mut model = FaultModel::new(cfg);
    let step = model.advance(5_000.0, 500_000);
    aged.inject_faults(&step);

    let rewritten = aged.reprogram(0.0, cfg.seed, 1);
    assert!(rewritten > 0, "behavioural pipeline still reports reprogrammed devices");
    model.reset_clock();
    assert_eq!(model.hours(), 0.0);
    let recovered = aged.classify_batch(&batch).unwrap();
    let agree = agreement(&recovered, &want);
    assert!(agree >= 0.99, "post-recalibration agreement {agree} < 0.99");
}

#[test]
fn recalibration_resolves_ride_warm_gmres() {
    // factor once, age the devices, value-only update, and every
    // post-recalibration re-solve must reuse the cached preconditioner
    let mut cb = build_synthetic_fc(12, 6, 64, MapMode::Inverted, 7);
    let dev = default_device();
    let mut sim = CrossbarSim::new(
        &cb,
        &dev,
        3,
        Ordering::Smart,
        SolverStrategy::Iterative { restart: 16, tol: 1e-11, max_iter: 400 },
    )
    .unwrap();
    let inputs: Vec<f64> = (0..12).map(|i| (i as f64 * 0.37).sin() * 0.3).collect();
    let (_, cold) = sim.solve_stats(&inputs).unwrap();
    assert!(!cold.is_empty());
    assert!(cold.iter().all(|s| s.iterations > 0), "iterative path must run cold too");

    let mut model = FaultModel::new(FaultConfig::default());
    let step = model.advance(100.0, 1_000);
    let g_min = dev.r_on / dev.r_off;
    fault::apply_step(&step, fault::bank_seed("warm-test"), &mut cb.devices, g_min);
    let n = sim.update_conductances(&cb.devices, dev.r_on);
    assert_eq!(n, cb.devices.len(), "every placed device is rewritten in place");

    let (out, warm) = sim.solve_stats(&inputs).unwrap();
    assert_eq!(warm.len(), sim.n_segments());
    for st in &warm {
        assert!(
            st.precond_reused,
            "post-recalibration re-solve must ride the cached preconditioner"
        );
        assert!(st.iterations > 0, "warm solve is still iterative");
    }
    assert!(out.iter().all(|v| v.is_finite()));
}

#[test]
fn spice_pipeline_survives_faults_and_reprograms() {
    let (m, ws) = demo_network(0xD311).unwrap();
    let mut spice = PipelineBuilder::new()
        .segment(8)
        .workers(2)
        .fidelity(Fidelity::Spice)
        .build(&m, &ws)
        .unwrap();
    let batch = demo_inputs(2, spice.in_dim(), 0xA11CE);
    let before = spice.forward_batch(&batch).unwrap();

    let cfg = FaultConfig { stuck_off_frac: 0.02, ..FaultConfig::default() };
    let mut model = FaultModel::new(cfg);
    let step = model.advance(1_000.0, 10_000);
    spice.inject_faults(&step);
    let after = spice.forward_batch(&batch).unwrap();
    for row in &after {
        for &v in row {
            assert!(v.is_finite(), "faulted spice outputs must stay finite");
        }
    }
    let moved = before
        .iter()
        .flatten()
        .zip(after.iter().flatten())
        .any(|(a, b)| (a - b).abs() > 1e-9);
    assert!(moved, "aging must perturb the emitted-netlist outputs");

    let rewritten = spice.reprogram(0.0, cfg.seed, 1);
    assert!(rewritten > 0, "spice pipeline must reprogram its resident crossbars");
    let restored = spice.forward_batch(&batch).unwrap();
    for row in &restored {
        for &v in row {
            assert!(v.is_finite());
        }
    }
}
