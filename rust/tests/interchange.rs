//! Interchange-dialect conformance suite: golden deck snapshots per
//! module kind, the emit → parse → sim round-trip contract over the full
//! demo network at SPICE fidelity, parser error-path coverage, and
//! property tests (util::prop mini-harness) over fuzzed decks, random MNA
//! systems with zero-diagonal pivot pairs, and the emit/parse fixpoint.

use memx::analog::{
    build_bn_crossbars, build_gap_crossbar, build_hard_sigmoid, build_hard_swish,
    build_residual_crossbar,
};
use memx::mapper::{build_synthetic_fc, MapMode};
use memx::netlist::interchange::{emit_cards, emit_deck, emit_flat, parse_deck, Deck};
use memx::netlist::validate::{
    check_deck, differential_sweep, fuzz_deck, fuzz_sweep, gen_mna_circuit, rel_diff,
    reference_vs_production, REFERENCE_TOL, ROUNDTRIP_TOL,
};
use memx::netlist::CrossbarSim;
use memx::pipeline::{default_device, demo_network, Fidelity, PipelineBuilder, SolverStrategy};
use memx::spice::solve::Ordering;
use memx::spice::Circuit;
use memx::util::prng::Rng;
use memx::util::prop::check;

// ---------------------------------------------------------------------------
// golden decks
// ---------------------------------------------------------------------------

/// The emitted dialect is part of the interchange contract: a hand-built
/// divider must serialize to exactly this deck, byte for byte.
#[test]
fn golden_divider_deck() {
    let mut c = Circuit::new("div");
    let top = c.node("top");
    let mid = c.node("mid");
    c.vsource("V1", top, 0, 6.0);
    c.resistor("R1", top, mid, 1000.0);
    c.resistor("R2", mid, 0, 2000.0);
    let deck = Deck {
        name: "div".into(),
        circuit: c,
        inputs: vec!["top".into()],
        outputs: vec!["mid".into()],
    };
    let expected = "\
* memx interchange deck: div
.SUBCKT div top mid
* node-order pins (0 A): fix MNA unknown ordering for exact round-trip
Ipin1 top 0 DC 0
Ipin2 mid 0 DC 0
V1 top 0 DC 6
R1 top mid 1000
R2 mid 0 2000
.ENDS div
X1 top mid div
.END
";
    assert_eq!(emit_deck(&deck), expected);
}

/// Every resident module kind — FC crossbar, BN subtract + scale pair,
/// GAP averaging columns, residual summer, Fig-4 activation cells — must
/// emit a structurally well-formed `.SUBCKT` deck that passes the full
/// conformance contract ([`check_deck`]: lossless capture, exact
/// round-trip sim, independent reference, Krylov cross-check).
#[test]
fn module_decks_emit_and_conform() {
    let dev = default_device();
    let mut decks: Vec<Deck> = Vec::new();

    // FC crossbar at a solved operating point
    let fc = build_synthetic_fc(4, 3, 16, MapMode::Inverted, 0x5EED);
    let mut sim = CrossbarSim::new(&fc, &dev, 0, Ordering::Smart, SolverStrategy::Auto).unwrap();
    sim.solve(&[0.1, -0.2, 0.05, 0.3]).unwrap();
    decks.extend(sim.decks("fc"));

    // BN subtraction + scale/offset crossbar pair
    let (sub, scale) = build_bn_crossbars(
        "bn",
        3,
        1,
        &[1.1, 0.9, 1.3],
        &[0.2, -0.1, 0.0],
        &[0.05, 0.0, -0.02],
        MapMode::Inverted,
    );
    for cb in [&sub, &scale] {
        let s = CrossbarSim::new(cb, &dev, 0, Ordering::Smart, SolverStrategy::Auto).unwrap();
        decks.extend(s.decks(&cb.name));
    }

    // GAP averaging columns
    let gap = build_gap_crossbar("gap", 2, 4, MapMode::Inverted);
    let s = CrossbarSim::new(&gap, &dev, 0, Ordering::Smart, SolverStrategy::Auto).unwrap();
    decks.extend(s.decks("gap"));

    // residual summing stage (dual mode, for mapping-scheme coverage)
    let res = build_residual_crossbar("res", 3, MapMode::Dual);
    let s = CrossbarSim::new(&res, &dev, 0, Ordering::Smart, SolverStrategy::Auto).unwrap();
    decks.extend(s.decks("res"));

    // Fig-4 activation cells at a nonzero operating point
    for (label, mut ac) in
        [("hsig", build_hard_sigmoid()), ("hswish", build_hard_swish())]
    {
        ac.eval(0.7).unwrap();
        decks.push(Deck {
            name: format!("{label}.act"),
            circuit: ac.circuit.clone(),
            inputs: vec!["vin".into()],
            outputs: vec![ac.out_node.clone()],
        });
    }

    assert_eq!(decks.len(), 7, "one deck per module kind (bn contributes two)");
    for deck in &decks {
        let text = emit_deck(deck);
        assert!(
            text.starts_with(&format!("* memx interchange deck: {}\n", deck.name)),
            "deck '{}' lost its title",
            deck.name
        );
        assert!(text.contains(&format!(".SUBCKT {} ", deck.name)), "deck '{}'", deck.name);
        assert!(text.contains("\nIpin1 "), "deck '{}' lost its node-order pins", deck.name);
        assert!(text.contains(&format!("\n.ENDS {}\nX1 ", deck.name)), "deck '{}'", deck.name);
        assert!(text.ends_with(".END\n"), "deck '{}' unterminated", deck.name);
        let rep = check_deck(deck).unwrap_or_else(|e| panic!("deck '{}': {e:#}", deck.name));
        assert!(rep.roundtrip_rel <= ROUNDTRIP_TOL, "deck '{}'", deck.name);
        assert!(rep.krylov_rel <= REFERENCE_TOL, "deck '{}'", deck.name);
        assert!(rep.reference_rel.is_some(), "module decks are under the reference dim cap");
    }

    // the hard-swish multiplier is named XMUL; the emitter must prepend
    // the card-type letter and validation must still prove lossless capture
    let swish = decks.iter().find(|d| d.name == "hswish.act").unwrap();
    assert!(emit_deck(swish).contains("\nBXMUL "), "multiplier card lost its type letter");
}

// ---------------------------------------------------------------------------
// demo network contract
// ---------------------------------------------------------------------------

/// Every deck the demo network exposes at SPICE fidelity — crossbar
/// segments, BN pairs, GAP, SE internals, activation cells — must pass
/// the full round-trip + differential contract at its live operating
/// point (after a forward pass).
#[test]
fn demo_network_decks_roundtrip() {
    let (m, ws) = demo_network(0x5EED).unwrap();
    let mut pipe = PipelineBuilder::new()
        .fidelity(Fidelity::Spice)
        .segment(8)
        .build(&m, &ws)
        .unwrap();
    let in_dim = pipe.in_dim();
    let mut rng = Rng::new(0xDECC);
    let batch = vec![(0..in_dim).map(|_| (rng.f64() - 0.5) * 0.6).collect::<Vec<f64>>()];
    pipe.forward_batch(&batch).unwrap();

    let decks = pipe.spice_decks();
    assert!(decks.len() >= 4, "demo network exposed only {} decks", decks.len());
    let mut worst_rt = 0.0f64;
    for deck in &decks {
        let rep = check_deck(deck).unwrap_or_else(|e| panic!("deck '{}': {e:#}", deck.name));
        worst_rt = worst_rt.max(rep.roundtrip_rel);
    }
    assert!(worst_rt <= ROUNDTRIP_TOL, "worst round-trip {worst_rt:.3e}");
}

// ---------------------------------------------------------------------------
// parser error paths
// ---------------------------------------------------------------------------

#[test]
fn parser_errors_are_structured() {
    // truncated deck: unterminated .SUBCKT, with and without .END
    let e = parse_deck("* t\n.SUBCKT s p\nR1 p 0 1\n.END\n").unwrap_err();
    assert!(e.msg.contains("truncated"), "{e}");
    let e = parse_deck("* t\n.SUBCKT s p\nR1 p 0 1\n").unwrap_err();
    assert!(e.msg.contains("truncated"), "{e}");

    // undefined subcircuit
    let e = parse_deck("* t\nX1 a nosuch\n.END\n").unwrap_err();
    assert!(e.msg.contains("undefined subcircuit 'nosuch'"), "{e}");
    assert_eq!(e.line, 2);

    // duplicate / ground ports
    let e = parse_deck("* t\n.SUBCKT s p p\n.ENDS s\n.END\n").unwrap_err();
    assert!(e.msg.contains("duplicate node 'p'"), "{e}");
    let e = parse_deck("* t\n.SUBCKT s gnd\n.ENDS s\n.END\n").unwrap_err();
    assert!(e.msg.contains("ground node"), "{e}");

    // malformed cards carry the offending token's position
    let e = parse_deck("* t\nV1 a 0 DC nope\n.END\n").unwrap_err();
    assert_eq!((e.line, e.col), (2, 11), "{e}");
    let e = parse_deck("* t\nR1 a b\n.END\n").unwrap_err();
    assert!(e.msg.contains("4 tokens"), "{e}");
    let e = parse_deck("* t\nQ1 a b c\n.END\n").unwrap_err();
    assert!(e.msg.contains("unsupported element"), "{e}");

    // mismatched .ENDS name, orphan .ENDS, orphan continuation
    let e = parse_deck("* t\n.SUBCKT a\nR1 x 0 1\n.ENDS b\n.END\n").unwrap_err();
    assert!(e.msg.contains(".ENDS 'b' closes .SUBCKT 'a'"), "{e}");
    let e = parse_deck("* t\n.ENDS s\n.END\n").unwrap_err();
    assert!(e.msg.contains(".ENDS without"), "{e}");
    let e = parse_deck("* t\n+ 10k\n.END\n").unwrap_err();
    assert!(e.msg.contains("continuation"), "{e}");

    // instance/port arity mismatch
    let e = parse_deck("* t\n.SUBCKT s p q\nR1 p q 1k\n.ENDS s\nX1 a s\n.END\n").unwrap_err();
    assert!(e.msg.contains("2 ports, instance connects 1"), "{e}");

    // every error renders with its source position
    let e = parse_deck("* t\nR1 a b\n.END\n").unwrap_err();
    assert!(format!("{e}").contains("line 2"), "{e}");
}

// ---------------------------------------------------------------------------
// property tests
// ---------------------------------------------------------------------------

/// Fuzzed (partially corrupted) decks must parse or reject cleanly — a
/// structured error with a real source position — and never panic.
#[test]
fn prop_fuzzed_decks_parse_or_reject() {
    check(
        "fuzz-decks",
        300,
        |rng: &mut Rng, size: usize| fuzz_deck(rng, size),
        |deck| match parse_deck(deck) {
            Ok(_) => true,
            Err(e) => e.line >= 1 && e.col >= 1 && !e.msg.is_empty(),
        },
    );
}

/// The independent dense reference must agree with the production engine
/// on random MNA systems, including the zero-diagonal V-source / VCVS
/// pivot pairs every generated circuit contains.
#[test]
fn prop_reference_agrees_on_random_mna() {
    check(
        "mna-reference",
        40,
        |rng: &mut Rng, size: usize| gen_mna_circuit(rng, size),
        |c| match reference_vs_production(c) {
            Ok(rel) => rel < REFERENCE_TOL,
            Err(e) => {
                eprintln!("reference solve failed: {e:#}");
                false
            }
        },
    );
}

/// The Krylov engine must match the direct solve on the same systems.
#[test]
fn prop_krylov_matches_direct_on_random_mna() {
    check(
        "mna-krylov",
        30,
        |rng: &mut Rng, size: usize| gen_mna_circuit(rng, size),
        |c| {
            let direct = c.dc_op().unwrap();
            let mut kc = c.clone();
            kc.set_solver(memx::spice::krylov::SolverStrategy::Iterative {
                restart: 48,
                tol: 1e-12,
                max_iter: 600,
            });
            rel_diff(&direct, &kc.dc_op().unwrap()) < REFERENCE_TOL
        },
    );
}

/// `emit(parse(emit(x)))` is a fixpoint: one emit canonicalizes names,
/// after which parse/emit round-trips byte-identically.
#[test]
fn prop_emit_parse_emit_fixpoint() {
    check(
        "emit-fixpoint",
        40,
        |rng: &mut Rng, size: usize| gen_mna_circuit(rng, size),
        |c| {
            let t1 = emit_cards(c);
            match parse_deck(&emit_flat(c)) {
                Ok(c2) => emit_cards(&c2) == t1,
                Err(e) => {
                    eprintln!("emitted deck failed to parse: {e}");
                    false
                }
            }
        },
    );
}

/// Full conformance on generated circuits wrapped as decks: lossless
/// capture, exact round-trip sim, independent reference, Krylov agreement.
#[test]
fn prop_generated_decks_pass_check_deck() {
    check(
        "deck-conformance",
        25,
        |rng: &mut Rng, size: usize| gen_mna_circuit(rng, size),
        |c| {
            let deck = Deck {
                name: "gen".into(),
                circuit: c.clone(),
                inputs: Vec::new(),
                outputs: Vec::new(),
            };
            match check_deck(&deck) {
                Ok(rep) => rep.roundtrip_rel <= ROUNDTRIP_TOL,
                Err(e) => {
                    eprintln!("check_deck failed: {e:#}");
                    false
                }
            }
        },
    );
}

/// A renamed element (multiplier `XMUL` → card `BXMUL`) converges to the
/// fixpoint after one emit and keeps simulating identically.
#[test]
fn renamed_mult_reaches_fixpoint() {
    let mut c = Circuit::new("ren");
    let a = c.node("a");
    let b = c.node("b");
    let out = c.node("out");
    c.vsource("V1", a, 0, 0.5);
    c.vsource("V2", b, 0, -0.25);
    c.resistor("R1", out, 0, 1e3);
    c.mult("XMUL", out, a, b, 2.0);
    let t1 = emit_cards(&c);
    assert!(t1.contains("BXMUL "), "type letter not prepended: {t1}");
    let c2 = parse_deck(&emit_flat(&c)).unwrap();
    assert_eq!(emit_cards(&c2), t1, "fixpoint after one emit");
    let rel = rel_diff(&c.dc_op().unwrap(), &c2.dc_op().unwrap());
    assert!(rel < 1e-12, "renamed round trip diverged: {rel:.3e}");
}

// ---------------------------------------------------------------------------
// sweep smoke (the CI `memx validate --quick` path in miniature)
// ---------------------------------------------------------------------------

#[test]
fn sweeps_run_clean() {
    let worst = differential_sweep(0xD1FF, 30).unwrap();
    assert!(worst < REFERENCE_TOL, "worst = {worst:.3e}");
    let (ok, rejected) = fuzz_sweep(0xF0, 300);
    assert!(ok > 0 && rejected > 0, "fuzzer must exercise both paths ({ok}/{rejected})");
}
