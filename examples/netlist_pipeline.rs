//! netlist_pipeline — the automated framework end-to-end (paper §4):
//! trained weights -> conductances (Eq 16) -> crossbar layout (Alg 1) ->
//! segmented SPICE netlists -> parallel DC simulation -> functional check.
//!
//!   cargo run --release --example netlist_pipeline [layer] [segment_cols]
//!
//! Mirrors the paper's Fig 6 block diagram: conversion module (mapper),
//! layer module (netlist emitter with §4.2 segmentation), model module
//! (the layer picked from the trained manifest), assessment module (the
//! layer compiled into a SPICE-fidelity `memx::pipeline` stage, batch-read
//! and validated against its ideal transfer).

use std::path::Path;
use std::time::Instant;

use memx::mapper::{self, MapMode};
use memx::netlist;
use memx::nn::{Manifest, WeightStore};
use memx::pipeline::{Fidelity, PipelineBuilder};
use memx::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let layer = std::env::args().nth(1).unwrap_or_else(|| "cls.fc1".into());
    let segment: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(64);
    let dir = Path::new("artifacts");
    let outdir = Path::new("target/netlists");

    // conversion module: weights -> differential quantized conductances
    let m = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &m)?;
    let t0 = Instant::now();
    let cb = mapper::build_fc_crossbar(&m, &ws, &layer, MapMode::Inverted)?;
    println!(
        "[convert+layout] {layer}: {}x{} crossbar, {} devices in {:?}",
        cb.rows,
        cb.cols,
        cb.devices.len(),
        t0.elapsed()
    );

    // layer module: emit segmented netlist files (construction-time metric)
    let t0 = Instant::now();
    let files = netlist::emit_layer_netlists(&m, &ws, &layer, MapMode::Inverted, segment, outdir)?;
    println!(
        "[netlist] {} file(s) ({} columns each) in {:?} -> {outdir:?}",
        files.len(),
        segment,
        t0.elapsed()
    );

    // assessment module: compile the layer into a SPICE-fidelity pipeline
    // stage (resident factor-once simulator, parallel segments) and batch a
    // few random vectors through it — one multi-RHS substitution pass per
    // segment — validating against the ideal-fidelity transfer
    let base = PipelineBuilder::new().mode(MapMode::Inverted).segment(segment);
    let t0 = Instant::now();
    let mut spice = base.clone().fidelity(Fidelity::Spice).build_layer(&m, &ws, &layer)?;
    let compile = t0.elapsed();
    let mut ideal = base.fidelity(Fidelity::Ideal).build_layer(&m, &ws, &layer)?;

    let mut rng = Rng::new(2024);
    let batch: Vec<Vec<f64>> = (0..4)
        .map(|_| (0..cb.region).map(|_| rng.range_f64(-0.5, 0.5)).collect())
        .collect();
    let t0 = Instant::now();
    let got = spice.forward_batch(&batch)?;
    let wall = t0.elapsed();
    let want = ideal.forward_batch(&batch)?;

    let max_err = got
        .iter()
        .flatten()
        .zip(want.iter().flatten())
        .fold(0f64, |a, (s, i)| a.max((s - i).abs()));
    println!(
        "[assess] compiled in {compile:?}; {} vectors batched in {wall:?}; \
         max |SPICE - ideal| = {max_err:.3e}",
        batch.len()
    );
    anyhow::ensure!(max_err < 1e-3, "SPICE disagrees with the analog model");
    println!("netlist pipeline OK");
    Ok(())
}
