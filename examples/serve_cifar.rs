//! serve_cifar — the END-TO-END driver (DESIGN.md E1): brings the full
//! three-layer stack up as a serving system and measures the paper's
//! headline metric on a real workload.
//!
//!   cargo run --release --example serve_cifar [n_requests]
//!
//! Flow: the coordinator starts its service thread (executor + dynamic
//! batcher), four closed-loop clients stream the held-out synth-cifar test
//! split as individual classification requests, and we report accuracy,
//! latency percentiles and throughput. The offline build serves the analog
//! crossbar pipeline (behavioural fidelity, pipelined stage scheduler);
//! with `--features runtime-xla` the digital fp32 PJRT baseline is served
//! too — the Table 1 row plus the Fig 8 "this testbed" columns. Results
//! are recorded in EXPERIMENTS.md §E1.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use memx::coordinator::{Backend, Server, ServerConfig};
use memx::util::bin::Dataset;

fn run_backend(
    dir: &Path,
    label: &str,
    backend: Backend,
    ds: &Dataset,
    n: usize,
) -> anyhow::Result<f64> {
    println!("\n=== {label}, {n} requests, 4 closed-loop clients ===");
    let server = Server::start(
        dir,
        ServerConfig { backend, max_wait: std::time::Duration::from_millis(5) },
    )?;
    println!("warmup (compile / factor-cache priming): {:?}", server.warmup);

    let client = server.client();
    let correct = AtomicUsize::new(0);
    let next = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let c = client.clone();
            let correct = &correct;
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if let Ok(p) = c.classify(ds.image(i).to_vec()) {
                    if p.label == ds.labels[i] as usize {
                        correct.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();
    let acc = correct.load(Ordering::Relaxed) as f64 / n as f64;
    println!("accuracy {:.4} over {n} requests, wall {wall:?}", acc);
    server.metrics().snapshot().print(wall);
    server.shutdown();
    Ok(acc)
}

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);

    let manifest = memx::nn::Manifest::load(dir)?;
    let ds = Dataset::load(&dir.join(&manifest.dataset_file))?;
    let n = n.min(ds.n);
    println!(
        "memristor-MobileNetV3 serving demo — {} (width {:.2}), {} classes",
        manifest.arch, manifest.width, manifest.num_classes
    );

    let analog = Backend::Analog {
        fidelity: memx::pipeline::Fidelity::Behavioural,
        workers: 0,
    };
    let acc_analog = run_backend(dir, "analog crossbar pipeline", analog, &ds, n)?;

    #[cfg(feature = "runtime-xla")]
    {
        let digital = Backend::Pjrt { model: memx::runtime::Model::Digital };
        let acc_digital = run_backend(dir, "digital fp32 (PJRT)", digital, &ds, n)?;
        println!("\n=== Table 1 row (this work) ===");
        println!("digital fp32 baseline : {:.2}%", acc_digital * 100.0);
        println!("memristor analog model: {:.2}%", acc_analog * 100.0);
        println!("paper target          : > 90% and analog ≈ digital");
        let ok = acc_analog > 0.9 && (acc_digital - acc_analog).abs() < 0.02;
        println!("reproduction          : {}", if ok { "PASS" } else { "CHECK" });
    }
    #[cfg(not(feature = "runtime-xla"))]
    {
        println!("\nmemristor analog model: {:.2}%", acc_analog * 100.0);
        println!(
            "(digital fp32 baseline needs the PJRT runtime: rebuild with \
             --features runtime-xla)"
        );
    }
    Ok(())
}
