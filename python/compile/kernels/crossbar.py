"""L1 — Pallas differential crossbar VMM kernel.

One analog crossbar bank multiplies an input-voltage vector by a conductance
matrix in a single step (Ohm's law per device, Kirchhoff per column); the
differential pair (Gpos on the direct inputs, Gneg on the inverting inputs —
the paper's op-amp-saving inverted convention) realizes signed weights, and
the per-column TIA converts current back to a rail-limited voltage.

Hardware adaptation (DESIGN.md §2): each crossbar *tile* maps to one Pallas
block.  BlockSpec expresses the HBM→VMEM staging of conductance submatrices
the way the paper banks physical arrays per channel; the MXU performs the
G·V contraction the analog array performs in the current domain.  The rail
clip is fused into the same kernel so the AOT'd HLO is the analog-faithful
model with no extra memory round-trip.

interpret=True everywhere on CPU: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example/README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the (8, 128) f32 TPU tiling; the MXU is
# 128x128 so the C tile rides the systolic-array width.  VMEM residency per
# block with the defaults is ~540 KiB (see vmem_bytes) « 16 MiB.
BLOCK_B = 8
BLOCK_R = 256
BLOCK_C = 256


def _vmm_kernel(v_ref, gp_ref, gn_ref, out_ref, *, rf_scale, v_rail, nk):
    """Grid = (B/bb, C/bc, R/br).  The output block is revisited for every
    R-step (its index_map ignores k), so partial Kirchhoff sums accumulate
    in-place; the TIA gain + rail clip are applied on the last R-step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = v_ref[...]
    # Differential pair: single fused contraction against (Gneg - Gpos) —
    # numerically identical to two matmuls, half the MXU passes.
    g = gn_ref[...] - gp_ref[...]
    out_ref[...] += jnp.dot(v, g, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _tia():
        out_ref[...] = jnp.clip(out_ref[...] * rf_scale, -v_rail, v_rail)


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("rf_scale", "v_rail", "block_b", "block_r", "block_c", "interpret"),
)
def crossbar_vmm(
    v,
    g_pos,
    g_neg,
    rf_scale: float = 1.0,
    v_rail: float = 8.0,
    block_b: int = BLOCK_B,
    block_r: int = BLOCK_R,
    block_c: int = BLOCK_C,
    interpret: bool = True,
):
    """Differential crossbar VMM: ``clip((v @ (g_neg - g_pos)) * rf_scale)``.

    v: (B, R) input voltages (normalized units)
    g_pos, g_neg: (R, C) normalized conductances in [0, 1]
    Returns (B, C) TIA output voltages, rail-limited to ±v_rail.
    """
    assert v.ndim == 2, "v must be (batch, rows)"
    assert g_pos.shape == g_neg.shape, "differential pair shape mismatch"
    assert v.shape[1] == g_pos.shape[0], "rows mismatch"
    b, r = v.shape
    _, c = g_pos.shape
    bb = min(block_b, max(1, b))
    br = min(block_r, r)
    bc = min(block_c, c)

    vp = _pad_to(_pad_to(v.astype(jnp.float32), 0, bb), 1, br)
    gp = _pad_to(_pad_to(g_pos.astype(jnp.float32), 0, br), 1, bc)
    gn = _pad_to(_pad_to(g_neg.astype(jnp.float32), 0, br), 1, bc)
    pb, pr = vp.shape
    _, pc = gp.shape
    nk = pr // br

    out = pl.pallas_call(
        functools.partial(_vmm_kernel, rf_scale=rf_scale, v_rail=v_rail, nk=nk),
        grid=(pb // bb, pc // bc, nk),
        in_specs=[
            pl.BlockSpec((bb, br), lambda i, j, k: (i, k)),
            pl.BlockSpec((br, bc), lambda i, j, k: (k, j)),
            pl.BlockSpec((br, bc), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pc), jnp.float32),
        interpret=interpret,
    )(vp, gp, gn)
    return out[:b, :c]


def crossbar_vmm_grouped(v, g_pos, g_neg, rf_scale=1.0, v_rail=8.0, interpret=True):
    """Batched banks: v (G, B, R), g (G, R, C) -> (G, B, C).

    Models per-channel crossbars (depthwise convolution, paper Fig 10a) as a
    vmap over independent banks; each bank keeps the full differential + TIA
    semantics.
    """
    fn = functools.partial(
        crossbar_vmm, rf_scale=rf_scale, v_rail=v_rail, interpret=interpret
    )
    return jax.vmap(fn)(v, g_pos, g_neg)


def vmem_bytes(block_b=BLOCK_B, block_r=BLOCK_R, block_c=BLOCK_C):
    """Estimated VMEM residency (bytes) of one kernel block invocation (f32):
    input tile + both conductance tiles + resident output/accumulator tile."""
    v = block_b * block_r
    g = 2 * block_r * block_c
    out = block_b * block_c
    return 4 * (v + g + out)


def mxu_macs(b, r, c):
    """MAC count of one differential VMM (fused single contraction)."""
    return b * r * c
