//! E1 / Table 1 — accuracy + inference throughput of the analog memristor
//! model vs the digital fp32 baseline on the held-out test split.
//!
//!   cargo bench --bench bench_accuracy

#[cfg(feature = "runtime-xla")]
use std::path::Path;

#[cfg(feature = "runtime-xla")]
use memx::coordinator::{accuracy, classify_dataset};
#[cfg(feature = "runtime-xla")]
use memx::runtime::{Engine, Model};
#[cfg(feature = "runtime-xla")]
use memx::util::bin::Dataset;

#[cfg(feature = "runtime-xla")]
fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_accuracy: artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::new(dir)?;
    let ds = Dataset::load(&dir.join(&engine.manifest().dataset_file))?;
    let n = 256.min(ds.n);

    println!("== Table 1: accuracy + throughput ({n} images) ==");
    println!("| model | accuracy | wall | img/s |");
    println!("|---|---:|---:|---:|");
    for model in [Model::Digital, Model::Analog] {
        let (labels, wall) = classify_dataset(&engine, model, &ds, n)?;
        let acc = accuracy(&labels, &ds.labels[..labels.len()]);
        println!(
            "| {model:?} | {:.4} | {wall:?} | {:.1} |",
            acc,
            n as f64 / wall.as_secs_f64()
        );
    }
    println!("paper Table 1 'this work': 90.36% on CIFAR-10 (analog ≈ digital)");
    Ok(())
}

#[cfg(not(feature = "runtime-xla"))]
fn main() {
    eprintln!("bench_accuracy: built without the runtime-xla feature; skipping (PJRT required)");
}
