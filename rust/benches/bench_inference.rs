//! E5/E6/E10 / Fig 8 — inference latency + energy: analytical crossbar
//! models (Eqs 17/18) against the paper's GPU/CPU baselines, plus the
//! *measured* digital PJRT latency on this host per batch size.
//!
//!   cargo bench --bench bench_inference

#[cfg(feature = "runtime-xla")]
use std::path::Path;

#[cfg(feature = "runtime-xla")]
use memx::mapper::{self, MapMode};
#[cfg(feature = "runtime-xla")]
use memx::nn::{Manifest, WeightStore};
#[cfg(feature = "runtime-xla")]
use memx::power;
#[cfg(feature = "runtime-xla")]
use memx::runtime::{Engine, Model};
#[cfg(feature = "runtime-xla")]
use memx::util::bench::Bench;
#[cfg(feature = "runtime-xla")]
use memx::util::bin::Dataset;

#[cfg(feature = "runtime-xla")]
fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_inference: artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let m = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &m)?;

    // --- analytical crossbar latency/energy (Fig 8 analog columns) ---
    let net = mapper::map_network(&m, &ws, MapMode::Inverted)?;
    let t_seq = power::latency(&net, &m.device);
    let t_pipe = power::latency_pipelined(&net, &m.device);
    let e = power::energy(&net, &m.device, &t_seq);
    println!("== Fig 8(a,b): analytical memristor inference ==");
    println!(
        "sequential: {:.3} µs (N_m={} stages) | pipelined: {:.3} µs | energy {:.2} µJ",
        t_seq.total * 1e6,
        t_seq.n_m,
        t_pipe.total * 1e6,
        e.total * 1e6
    );
    println!(
        "vs paper baselines: GPU {:.1}x/{:.0}x (seq/pipe), CPU {:.1}x/{:.0}x",
        power::T_GPU_RTX4090 / t_seq.total,
        power::T_GPU_RTX4090 / t_pipe.total,
        power::T_CPU_I7_12700 / t_seq.total,
        power::T_CPU_I7_12700 / t_pipe.total
    );

    // --- measured digital + analog-model PJRT latency on this host ---
    let engine = Engine::new(dir)?;
    let ds = Dataset::load(&dir.join(&m.dataset_file))?;
    let mut b = Bench::quick(); // analog-model runs are seconds each
    for &batch in &engine.available_batches() {
        for model in [Model::Digital, Model::Analog] {
            let exec = engine.get(model, batch)?;
            let img = ds.image_len();
            let mut buf = vec![0f32; batch * img];
            for j in 0..batch {
                buf[j * img..(j + 1) * img].copy_from_slice(ds.image(j % ds.n));
            }
            let stats = b.run(&format!("{model:?} pjrt b{batch}"), || {
                exec.run(&buf).expect("execute");
            });
            println!(
                "    -> per-image {:.3} ms",
                stats.mean_secs() * 1e3 / batch as f64
            );
        }
    }
    b.table("Fig 8 — measured digital/analog-model latency on this host");
    println!("\npaper §5.2: GPU 0.1654 ms, CPU 3.3924 ms per image; analog 1.24 µs");
    Ok(())
}

#[cfg(not(feature = "runtime-xla"))]
fn main() {
    eprintln!("bench_inference: built without the runtime-xla feature; skipping (PJRT required)");
}
