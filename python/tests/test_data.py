"""synth-cifar generator + binary format tests (shared with rust)."""

import numpy as np
import pytest

from compile import data as D


class TestGenerator:
    def test_shapes_and_range(self):
        x, y = D.make_dataset(20, seed=0)
        assert x.shape == (20, 32, 32, 3) and x.dtype == np.float32
        assert y.shape == (20,) and y.dtype == np.uint8
        assert x.min() >= 0.0 and x.max() <= 1.0

    def test_balanced_classes(self):
        _, y = D.make_dataset(100, seed=0)
        counts = np.bincount(y, minlength=10)
        assert np.all(counts == 10)

    def test_deterministic(self):
        x1, y1 = D.make_dataset(10, seed=42)
        x2, y2 = D.make_dataset(10, seed=42)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)

    def test_seeds_differ(self):
        x1, _ = D.make_dataset(10, seed=1)
        x2, _ = D.make_dataset(10, seed=2)
        assert not np.array_equal(x1, x2)

    def test_classes_are_distinguishable(self):
        """Mean intra-class distance < mean inter-class distance (the task
        must be learnable)."""
        x, y = D.make_dataset(200, seed=3)
        flat = x.reshape(len(x), -1)
        centroids = np.stack([flat[y == c].mean(0) for c in range(10)])
        intra = np.mean([np.linalg.norm(flat[y == c] - centroids[c], axis=1).mean()
                         for c in range(10)])
        dists = np.linalg.norm(centroids[:, None] - centroids[None], axis=-1)
        inter = dists[dists > 0].mean()
        assert inter > 0.5 * intra

    def test_all_classes_produce_masks(self):
        rng = np.random.default_rng(0)
        for c in range(10):
            m = D._mask_for(c, rng)
            assert m.shape == (32, 32)
            assert 0 < m.sum() < 32 * 32


class TestBinaryFormat:
    def test_roundtrip(self, tmp_path):
        x, y = D.make_dataset(12, seed=7)
        p = str(tmp_path / "d.bin")
        D.write_dataset_bin(p, x, y)
        x2, y2 = D.read_dataset_bin(p)
        np.testing.assert_array_equal(x, x2)
        np.testing.assert_array_equal(y, y2)

    def test_header_layout(self, tmp_path):
        x, y = D.make_dataset(3, seed=7)
        p = str(tmp_path / "d.bin")
        D.write_dataset_bin(p, x, y)
        raw = open(p, "rb").read()
        import struct
        magic, n, h, w, c = struct.unpack("<IIIII", raw[:20])
        assert magic == D.MAGIC and (n, h, w, c) == (3, 32, 32, 3)
        assert len(raw) == 20 + 3 * 32 * 32 * 3 * 4 + 3

    def test_bad_magic_rejected(self, tmp_path):
        p = str(tmp_path / "bad.bin")
        with open(p, "wb") as f:
            f.write(b"\x00" * 64)
        with pytest.raises(AssertionError):
            D.read_dataset_bin(p)
