//! Integration tests for `spice::krylov` — the preconditioned iterative
//! path for giant monolithic crossbars.
//!
//! Pins the subsystem's acceptance contract on a monolithic ideal-TIA
//! crossbar MNA system:
//!   * GMRES+ILU(0) outputs match the direct factor engine within the
//!     documented 1e-6 tolerance,
//!   * the iterative path's peak resident entries (preconditioner +
//!     Krylov basis) stay strictly below the direct LU's factor entries,
//!   * warm re-solves after value-only restamps reuse the cached
//!     preconditioner and converge without refactorization.
//!
//! The paper-scale 2050x1024 run is the same code path at full size; it is
//! exercised by `cargo bench --bench bench_crossbar` and by the env-gated
//! test below (`MEMX_FULL_SCALE=1`, release profile recommended).

use memx::spice::krylov::SolverStrategy;
use memx::spice::solve::Ordering;
use memx::spice::{synthetic_crossbar_circuit as monolithic_crossbar, Element};

fn iterative(restart: usize) -> SolverStrategy {
    SolverStrategy::Iterative { restart, tol: 1e-11, max_iter: 600 }
}

#[test]
fn monolithic_gmres_matches_direct_with_strictly_less_memory() {
    let mut direct = monolithic_crossbar(320, 128, 100.0, 42);
    direct.set_solver(SolverStrategy::Direct);
    let (xd, sd) = direct.dc_op_stats(Ordering::Smart).unwrap();
    assert_eq!(sd.iterations, 0);

    let mut gmres = monolithic_crossbar(320, 128, 100.0, 42);
    gmres.set_solver(iterative(16));
    let (xi, si) = gmres.dc_op_stats(Ordering::Smart).unwrap();
    assert!(si.iterations > 0, "iterative path must have run");
    assert!(
        si.peak_entries < sd.peak_entries,
        "iterative peak {} must be strictly below direct factor peak {}",
        si.peak_entries,
        sd.peak_entries
    );
    for (a, b) in xi.iter().zip(&xd) {
        assert!((a - b).abs() < 1e-6, "documented tolerance: {a} vs {b}");
    }
}

#[test]
fn warm_resolves_after_value_restamps_skip_refactorization() {
    // cold iterative solve caches the ILU pattern; value-only restamps
    // (drifted conductances) re-solve off the cached preconditioner
    let mut c = monolithic_crossbar(96, 48, 100.0, 7);
    c.set_solver(iterative(16));
    let (_, cold) = c.dc_op_stats(Ordering::Smart).unwrap();
    assert!(!cold.precond_reused, "first solve is cold");
    for drift in 1..=3 {
        for e in c.elements.iter_mut() {
            if let Element::Resistor(name, _, _, r) = e {
                if name.starts_with("RM") {
                    *r *= 1.0 + 0.003 * drift as f64;
                }
            }
        }
        let (x, warm) = c.dc_op_stats(Ordering::Smart).unwrap();
        assert!(warm.precond_reused, "drift {drift}: cached preconditioner must be reused");
        assert!(warm.iterations > 0);
        let (reference, _) = c.dc_op_stats_reference(Ordering::Smart).unwrap();
        for (a, b) in x.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-6, "drift {drift}: {a} vs {b}");
        }
    }
}

#[test]
fn wire_resistance_extremes_stay_within_tolerance() {
    // r_base spans 1e-2 .. 1e5 ohms — conductances from 1e2 down to 1e-6
    // siemens against the 1e6 op-amp gains
    for &r_base in &[1e-2, 1e2, 1e5] {
        let mut direct = monolithic_crossbar(48, 16, r_base, 11);
        direct.set_solver(SolverStrategy::Direct);
        let (xd, _) = direct.dc_op_stats(Ordering::Smart).unwrap();
        let mut gmres = monolithic_crossbar(48, 16, r_base, 11);
        gmres.set_solver(iterative(16));
        let (xi, si) = gmres.dc_op_stats(Ordering::Smart).unwrap();
        assert!(si.iterations > 0, "r_base {r_base}");
        let scale = xd.iter().fold(1.0f64, |a, v| a.max(v.abs()));
        for (a, b) in xi.iter().zip(&xd) {
            assert!(
                (a - b).abs() < 1e-6 * scale,
                "r_base {r_base}: {a} vs {b} (scale {scale})"
            );
        }
    }
}

#[test]
fn batched_reads_share_one_preconditioner() {
    let mut c = monolithic_crossbar(64, 24, 100.0, 13);
    c.set_solver(iterative(16));
    let idxs: Vec<usize> = (0..64).map(|r| c.vsource_index(&format!("V{r}")).unwrap()).collect();
    let batches: Vec<Vec<(usize, f64)>> = (0..6)
        .map(|k| {
            idxs.iter()
                .enumerate()
                .map(|(r, &i)| (i, ((r * 3 + k) as f64 * 0.23).sin() * 0.4))
                .collect()
        })
        .collect();
    let batched = c.clone().dc_op_batch_par(&batches, Ordering::Smart, 3).unwrap();
    assert_eq!(batched.len(), 6);
    for (k, ov) in batches.iter().enumerate() {
        for &(i, v) in ov {
            c.set_vsource_at(i, v).unwrap();
        }
        let (seq, _) = c.dc_op_stats_reference(Ordering::Smart).unwrap();
        for (a, b) in batched[k].iter().zip(&seq) {
            assert!((a - b).abs() < 1e-6, "batch {k}: {a} vs {b}");
        }
    }
}

/// The paper's monolithic 2050x1024 case end to end. Heavy — opt in with
/// `MEMX_FULL_SCALE=1 cargo test --release --test krylov -- full_scale`;
/// `cargo bench --bench bench_crossbar` sweeps the same sizes on every
/// full bench run.
#[test]
fn full_scale_2050x1024_gmres_beats_direct_factorization() {
    if std::env::var("MEMX_FULL_SCALE").is_err() {
        eprintln!("skipping full-scale 2050x1024 run (set MEMX_FULL_SCALE=1 to enable)");
        return;
    }
    let mut direct = monolithic_crossbar(2050, 1024, 100.0, 99);
    direct.set_solver(SolverStrategy::Direct);
    let (xd, sd) = direct.dc_op_stats(Ordering::Smart).unwrap();

    let mut gmres = monolithic_crossbar(2050, 1024, 100.0, 99);
    gmres.set_solver(iterative(24));
    let (xi, si) = gmres.dc_op_stats(Ordering::Smart).unwrap();
    assert!(si.iterations > 0);
    assert!(
        si.peak_entries < sd.peak_entries,
        "2050x1024: iterative peak {} vs direct {}",
        si.peak_entries,
        sd.peak_entries
    );
    let scale = xd.iter().fold(1.0f64, |a, v| a.max(v.abs()));
    for (a, b) in xi.iter().zip(&xd) {
        assert!((a - b).abs() < 1e-6 * scale.max(1.0));
    }
}
