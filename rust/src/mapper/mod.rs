//! Mapper — the automated framework's conversion + layout stage.
//!
//! Converts trained weights into differential quantized conductances
//! (HP model, Eq 16; inverted op-amp-saving convention, §3.2), lays out
//! every layer's crossbars (Algorithm 1, Eqs 1-3) and counts resources
//! (Eqs 5-6, 10-15) — regenerating the paper's Table 4 and feeding the
//! netlist emitter and the latency/energy models.

pub mod layout;

use anyhow::{anyhow, bail, Result};

use crate::nn::{ActKind, Layer, Manifest, WeightStore};
use crate::util::prng::Rng;
use layout::{ConvXbarGeom, FcXbarGeom, Placed};

/// Differential mapping convention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapMode {
    /// Paper's §3.2 scheme: positive weights on the negated-input region,
    /// one inverting TIA per output port.
    Inverted,
    /// Conventional dual-op-amp scheme (Li & Shi 2022, Zhang et al. 2019):
    /// same placements mirrored, plus an extra inverter per output port.
    Dual,
}

impl std::str::FromStr for MapMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<MapMode> {
        match s {
            "inverted" => Ok(MapMode::Inverted),
            "dual" => Ok(MapMode::Dual),
            other => bail!("unknown map mode '{other}' (inverted|dual)"),
        }
    }
}

impl std::fmt::Display for MapMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MapMode::Inverted => "inverted",
            MapMode::Dual => "dual",
        })
    }
}

impl MapMode {
    /// Deprecated alias for the [`std::str::FromStr`] impl — prefer
    /// `s.parse::<MapMode>()`. Retained for source compatibility.
    pub fn parse(s: &str) -> Result<MapMode> {
        s.parse()
    }

    pub fn inverted(&self) -> bool {
        matches!(self, MapMode::Inverted)
    }

    /// Op-amps per crossbar output port.
    pub fn opamps_per_port(&self) -> usize {
        match self {
            MapMode::Inverted => 1,
            MapMode::Dual => 2,
        }
    }
}

/// Quantize |w|/scale to the device's discrete levels (device.py mirror).
pub fn quantize_unit(x: f64, levels: usize) -> f64 {
    if levels <= 1 {
        return 0.0;
    }
    (x.clamp(0.0, 1.0) * (levels - 1) as f64).round() / (levels - 1) as f64
}

/// Normalize + quantize a signed weight slice into per-element signed
/// conductance units (sign kept; magnitude quantized).
pub fn quantize_signed(w: &[f32], scale: f64, levels: usize) -> Vec<f64> {
    w.iter()
        .map(|&x| {
            let n = (x as f64 / scale).clamp(-1.0, 1.0);
            n.signum() * quantize_unit(n.abs(), levels)
        })
        .collect()
}

/// Relative programming noise on nonzero devices (zero = absent memristor).
pub fn apply_prog_noise(q: &mut [f64], sigma: f64, rng: &mut Rng) {
    if sigma <= 0.0 {
        return;
    }
    for v in q.iter_mut() {
        if *v != 0.0 {
            let noisy = *v * (1.0 + sigma * rng.gaussian());
            *v = noisy.clamp(-1.0, 1.0);
        }
    }
}

/// Relative programming noise on *analog-valued* placed devices — the
/// §3.3 batch-norm and §3.5 averaging-column conductances
/// ([`crate::analog::build_bn_crossbars`] /
/// [`crate::analog::build_gap_crossbar`]), which realize arbitrary reals
/// rather than quantized weight levels. The level floor of
/// [`apply_prog_noise_placed`] must NOT apply here: a GAP column's `1/N`
/// conductance legitimately sits far below half the smallest quantized
/// level and inflating it to the floor would scale the computed mean.
/// Instead the multiplicative perturbation itself is floored (at 0.05) so
/// no device crosses zero or vanishes from the netlist, and the result is
/// capped at the normalized full-on conductance (or the device's own
/// nominal, if larger) so no device leaves the HP model's resistance
/// window — the same upper clamp as [`apply_prog_noise_placed`].
pub fn apply_prog_noise_analog(devices: &mut [Placed], sigma: f64, rng: &mut Rng) {
    if sigma <= 0.0 {
        return;
    }
    for d in devices.iter_mut() {
        let noisy = d.g_norm * (1.0 + sigma * rng.gaussian()).max(0.05);
        d.g_norm = noisy.min(d.g_norm.max(1.0));
    }
}

/// `gamma / sqrt(var + BN_EPS)` fold constant (python/compile/model.py
/// mirror) — the single source shared by the pipeline's exact transfer and
/// the §3.3 netlist builder.
pub const BN_EPS: f64 = 1e-5;

/// Folded batch-norm parameters: `y = (x - mean) * k + beta` with
/// `k = gamma / sqrt(var + BN_EPS)` — the programmed-conductance form of
/// the paper's §3.3 circuit (mean/variance folded at compile time).
#[derive(Debug, Clone)]
pub struct BnFold {
    pub k: Vec<f64>,
    pub mean: Vec<f64>,
    pub beta: Vec<f64>,
}

impl BnFold {
    /// Fold raw batch statistics into the affine form.
    pub fn from_stats(gamma: &[f64], beta: &[f64], mean: &[f64], var: &[f64]) -> BnFold {
        BnFold {
            k: gamma.iter().zip(var).map(|(g, v)| g / (v + BN_EPS).sqrt()).collect(),
            mean: mean.to_vec(),
            beta: beta.to_vec(),
        }
    }
}

/// Resolve a manifest BN layer's folded parameters from the weight store.
/// `weight` names the gamma tensor (`<base>.gamma`); the companion
/// beta/mean/var tensors are optional with identity defaults — python
/// always emits them, synthetic manifests may not.
pub fn bn_fold(ws: &WeightStore, weight: &str, c: usize) -> Result<BnFold> {
    let base = weight.strip_suffix(".gamma").unwrap_or(weight);
    let tensor = |suffix: &str| {
        ws.get(&format!("{base}.{suffix}"))
            .map(|t| t.data.iter().map(|&v| v as f64).collect::<Vec<f64>>())
    };
    let gamma = tensor("gamma")
        .ok_or_else(|| anyhow!("bn fold: tensor '{base}.gamma' not in store"))?;
    let beta = tensor("beta").unwrap_or_else(|| vec![0.0; c]);
    let mean = tensor("mean").unwrap_or_else(|| vec![0.0; c]);
    let var = tensor("var").unwrap_or_else(|| vec![1.0; c]);
    for (label, t) in [("gamma", &gamma), ("beta", &beta), ("mean", &mean), ("var", &var)] {
        if t.len() != c {
            bail!("bn fold '{base}': {label} has {} values for {c} channels", t.len());
        }
    }
    Ok(BnFold::from_stats(&gamma, &beta, &mean, &var))
}

/// Relative programming noise on placed crossbar devices — the [`Placed`]
/// mirror of [`apply_prog_noise`]. Conductances stay physical: floored at
/// half the smallest programmable level (so no device leaves the HP model's
/// resistance window) and capped at the full-on conductance — except bias
/// devices, which legitimately realize `|b|·bscale/scale > 1` (see
/// [`build_fc_crossbar`]) and are capped at their own nominal value instead
/// of being crushed to 1.
pub fn apply_prog_noise_placed(
    devices: &mut [Placed],
    sigma: f64,
    levels: usize,
    rng: &mut Rng,
) {
    if sigma <= 0.0 {
        return;
    }
    let floor = 0.5 / (levels.max(2) - 1) as f64;
    for d in devices.iter_mut() {
        let noisy = d.g_norm * (1.0 + sigma * rng.gaussian());
        d.g_norm = noisy.clamp(floor, d.g_norm.max(1.0));
    }
}

/// One mapped layer — a Table 4 row.
#[derive(Debug, Clone)]
pub struct MappedLayer {
    pub unit: String,
    pub name: String,
    pub kind: &'static str,
    /// crossbar dimensions (rows x cols) of one bank; None for pure-CMOS
    pub size: Option<(usize, usize)>,
    /// concurrent crossbar banks of that size
    pub banks: usize,
    /// physically placed devices (zero weights omitted)
    pub memristors: usize,
    pub opamps: usize,
    /// the paper's closed-form counts (Eqs 5/6, 10/11, 12/13, 14/15)
    pub formula_memristors: usize,
    pub formula_opamps: usize,
    pub parallelism: usize,
    /// contributes a memristor+TIA stage to the latency chain (Eq 17 N_m)
    pub is_memristor_stage: bool,
}

/// Whole-network mapping result.
#[derive(Debug, Clone)]
pub struct MappedNetwork {
    pub mode: MapMode,
    pub layers: Vec<MappedLayer>,
}

impl MappedNetwork {
    pub fn total_memristors(&self) -> usize {
        self.layers.iter().map(|l| l.memristors).sum()
    }

    pub fn total_opamps(&self) -> usize {
        self.layers.iter().map(|l| l.opamps).sum()
    }

    /// N_m of Eq 17: number of memristor-crossbar stages on the critical
    /// (sequential) path.
    pub fn memristor_stages(&self) -> usize {
        self.layers.iter().filter(|l| l.is_memristor_stage).count()
    }
}

/// Count nonzero quantized values.
fn nnz(q: &[f64]) -> usize {
    q.iter().filter(|&&v| v != 0.0).count()
}

/// Map the full network from the manifest + weights (Table 4 generator).
pub fn map_network(m: &Manifest, ws: &WeightStore, mode: MapMode) -> Result<MappedNetwork> {
    let levels = m.device.levels;
    let mut layers = Vec::new();
    for l in &m.layers {
        layers.push(map_layer(m, ws, l, mode, levels)?);
    }
    Ok(MappedNetwork { mode, layers })
}

/// Resolve a named tensor to (shape, quantized signed units, analog scale)
/// — the single source of the scale-fallback rule (max |w|, floored at
/// 1e-12) shared by the mapper and the pipeline builder.
pub(crate) fn weight_q(
    ws: &WeightStore,
    name: &str,
    levels: usize,
) -> Result<(Vec<usize>, Vec<f64>, f64)> {
    let t = ws.get(name).ok_or_else(|| anyhow!("weight '{name}' not in store"))?;
    let scale = t.scale.unwrap_or_else(|| t.max_abs() as f64).max(1e-12);
    let q = quantize_signed(t.data, scale, levels);
    Ok((t.shape.clone(), q, scale))
}

fn map_layer(
    _m: &Manifest,
    ws: &WeightStore,
    l: &Layer,
    mode: MapMode,
    levels: usize,
) -> Result<MappedLayer> {
    let ppo = mode.opamps_per_port();
    Ok(match l {
        Layer::Conv(g) => {
            let (_, q, _) = weight_q(ws, &g.weight, levels)?;
            let geom = ConvXbarGeom::from_conv(g.h_in, g.w_in, g.k, g.stride, g.padding);
            // devices: each nonzero kernel element appears once per output
            // position, per (cin, cout) pair
            let kk = g.k * g.k;
            let mut dev = 0usize;
            for co in 0..g.cout {
                for ci in 0..g.cin {
                    let mut cnt = 0;
                    for a in 0..kk {
                        // HWIO layout: ((a) * cin + ci) * cout + co
                        if q[a * g.cin * g.cout + ci * g.cout + co] != 0.0 {
                            cnt += 1;
                        }
                    }
                    dev += cnt * geom.cols();
                }
            }
            MappedLayer {
                unit: g.unit.clone(),
                name: g.name.clone(),
                kind: "Conv",
                size: Some((geom.rows(), geom.cols())),
                banks: g.cin * g.cout,
                memristors: dev,
                opamps: geom.cols() * g.cout * ppo,
                // Eq 5 as printed (the paper's expression; see DESIGN.md note)
                formula_memristors: geom.cols() * (g.k * g.k + 1) * g.cin * g.cout,
                formula_opamps: geom.cols() * g.cout,
                parallelism: g.cout,
                is_memristor_stage: true,
            }
        }
        Layer::DwConv(g) => {
            let (_, q, _) = weight_q(ws, &g.weight, levels)?;
            let geom = ConvXbarGeom::from_conv(g.h_in, g.w_in, g.k, g.stride, g.padding);
            let kk = g.k * g.k;
            let mut dev = 0usize;
            for c in 0..g.cout {
                let mut cnt = 0;
                for a in 0..kk {
                    // (k,k,1,C): a*C + c
                    if q[a * g.cout + c] != 0.0 {
                        cnt += 1;
                    }
                }
                dev += cnt * geom.cols();
            }
            MappedLayer {
                unit: g.unit.clone(),
                name: g.name.clone(),
                kind: "DConv",
                size: Some((geom.rows(), geom.cols())),
                banks: g.cout,
                memristors: dev,
                opamps: geom.cols() * g.cout * ppo,
                formula_memristors: geom.cols() * (kk + 1) * g.cout,
                formula_opamps: geom.cols() * g.cout,
                parallelism: g.cout,
                is_memristor_stage: true,
            }
        }
        Layer::PConv { name, unit, cin, cout, weight } => {
            let (_, q, _) = weight_q(ws, weight, levels)?;
            // SE FCs carry a bias vector alongside
            let bias_name = weight.replace(".w", ".b");
            let bias_dev = match ws.get(&bias_name) {
                Some(b) => {
                    let scale = b.scale.unwrap_or(1.0).max(1e-12);
                    nnz(&quantize_signed(b.data, scale, levels))
                }
                None => 0,
            };
            let g = FcXbarGeom { cin: *cin, cout: *cout };
            MappedLayer {
                unit: unit.clone(),
                name: name.clone(),
                kind: "PConv",
                size: Some((g.rows(), g.cols())),
                banks: 1,
                memristors: nnz(&q) + bias_dev,
                opamps: cout * ppo,
                formula_memristors: (cin + 1) * cout, // Eq 14 shape
                formula_opamps: *cout,                // Eq 15
                parallelism: 1,
                is_memristor_stage: true,
            }
        }
        Layer::Bn { name, unit, c, .. } => MappedLayer {
            unit: unit.clone(),
            name: name.clone(),
            kind: "BN",
            // subtraction pair (4 inputs x 2 devices) + scale/offset pair
            size: Some((4, 2)),
            banks: *c,
            memristors: 4 * c,      // Eq 10
            opamps: 2 * c * ppo,    // Eq 11 (doubled in dual mode)
            formula_memristors: 4 * c,
            formula_opamps: 2 * c,
            parallelism: *c,
            is_memristor_stage: true,
        },
        Layer::Act { name, unit, kind, c } => {
            let (label, ops): (&'static str, usize) = match kind {
                // Fig 4a: adder + divider + limiter ≈ 4 op-amps per module
                ActKind::HSigmoid => ("HSigmoid", 4),
                // Fig 4b: hard-sigmoid branch + multiplier, per channel
                ActKind::HSwish => ("HSwish", 4 * c),
                // CMOS ReLU (Priyanka et al. 2019): no op-amps
                ActKind::Relu => ("ReLU", 0),
            };
            MappedLayer {
                unit: unit.clone(),
                name: name.clone(),
                kind: label,
                size: None,
                banks: *c,
                memristors: 0,
                opamps: ops,
                formula_memristors: 0,
                formula_opamps: ops,
                parallelism: *c,
                is_memristor_stage: false,
            }
        }
        Layer::GaPool { name, unit, c, h_in, w_in } => MappedLayer {
            unit: unit.clone(),
            name: name.clone(),
            kind: "GAPool",
            size: Some((h_in * w_in, 1)),
            banks: *c,
            memristors: h_in * w_in * c, // Eq 12
            opamps: c * ppo,
            formula_memristors: h_in * w_in * c,
            formula_opamps: *c, // Eq 13
            parallelism: *c,
            is_memristor_stage: true,
        },
        Layer::Fc { name, unit, cin, cout, weight } => {
            let (_, q, _) = weight_q(ws, weight, levels)?;
            let bias_name = weight.replace(".w", ".b");
            let bias_dev = match ws.get(&bias_name) {
                Some(b) => {
                    let scale = b.scale.unwrap_or(1.0).max(1e-12);
                    nnz(&quantize_signed(b.data, scale, levels))
                }
                None => 0,
            };
            let g = FcXbarGeom { cin: *cin, cout: *cout };
            MappedLayer {
                unit: unit.clone(),
                name: name.clone(),
                kind: "FC",
                size: Some((g.rows(), g.cols())),
                banks: 1,
                memristors: nnz(&q) + bias_dev,
                opamps: cout * ppo,
                formula_memristors: (cin + 1) * cout, // Eq 14
                formula_opamps: *cout,                // Eq 15
                parallelism: 1,
                is_memristor_stage: true,
            }
        }
        Layer::Residual { name, unit, c } => MappedLayer {
            unit: unit.clone(),
            name: name.clone(),
            kind: "Add",
            size: None,
            banks: *c,
            memristors: 0,
            opamps: *c, // summing amplifier per channel
            formula_memristors: 0,
            formula_opamps: *c,
            parallelism: *c,
            is_memristor_stage: false,
        },
    })
}

/// A concrete crossbar (devices + geometry) ready for netlist emission or
/// behavioural simulation.
#[derive(Debug, Clone)]
pub struct Crossbar {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// region size: rows in [0, region) are direct inputs, [region, 2*region)
    /// negated inputs; remaining rows are bias lines.
    pub region: usize,
    pub devices: Vec<Placed>,
    /// de-normalization: V_out = rf_scale * Σ v_i * (±g_norm)
    pub rf_scale: f64,
    pub mode: MapMode,
}

impl Crossbar {
    /// SPICE-level reader for this crossbar: emits + parses the segmented
    /// netlists once and answers every subsequent input vector from the
    /// cached LU factorization or Krylov preconditioner (see
    /// [`crate::netlist::CrossbarSim`]). `segment` = columns per netlist
    /// file (0 = monolithic); `solver` selects direct vs GMRES per segment
    /// ([`crate::spice::krylov::SolverStrategy::Auto`] keeps small
    /// segments direct and giant monolithic solves iterative).
    pub fn sim(
        &self,
        dev: &crate::nn::DeviceJson,
        segment: usize,
        ordering: crate::spice::solve::Ordering,
        solver: crate::spice::krylov::SolverStrategy,
    ) -> Result<crate::netlist::CrossbarSim> {
        crate::netlist::CrossbarSim::new(self, dev, segment, ordering, solver)
    }

    /// Behavioural evaluation (ideal TIA): inputs `v` of len `region` (the
    /// direct-region voltages; negated region is implied), bias voltages
    /// (vb+, vb-) = (1, -1). Returns per-column outputs.
    pub fn eval_ideal(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.region, "input length != region");
        let mut out = vec![0.0; self.cols];
        for d in &self.devices {
            let vin = if d.row < self.region {
                v[d.row]
            } else if d.row < 2 * self.region {
                -v[d.row - self.region]
            } else if d.row == 2 * self.region {
                1.0
            } else {
                -1.0
            };
            out[d.col] += vin * d.g_norm;
        }
        // Accumulated `out` is the column current in normalized units.
        // Inverted mode: positives sit on the negated inputs, so the current
        // is -Σ v·w and the single TIA's -Rf restores +Σ v·w·Rf.
        // Dual mode: current is +Σ v·w; TIA then inverter gives the same.
        let mult = if self.mode.inverted() { -self.rf_scale } else { self.rf_scale };
        for o in out.iter_mut() {
            *o *= mult;
        }
        out
    }
}

/// Build the concrete FC crossbar for a named fc/pconv layer.
pub fn build_fc_crossbar(
    m: &Manifest,
    ws: &WeightStore,
    layer_name: &str,
    mode: MapMode,
) -> Result<Crossbar> {
    let layer = m
        .layers
        .iter()
        .find(|l| l.name() == layer_name)
        .ok_or_else(|| anyhow!("layer '{layer_name}' not found"))?;
    let (cin, cout, wname) = match layer {
        Layer::Fc { cin, cout, weight, .. } | Layer::PConv { cin, cout, weight, .. } => {
            (*cin, *cout, weight.clone())
        }
        other => bail!("layer '{layer_name}' is {} — not FC/PConv", other.kind_label()),
    };
    let (shape, q, scale) = weight_q(ws, &wname, m.device.levels)?;
    if shape != vec![cin, cout] {
        bail!("weight shape {shape:?} != ({cin}, {cout})");
    }
    let bias_name = wname.replace(".w", ".b");
    let bias_q = ws.get(&bias_name).map(|b| {
        let bscale = b.scale.unwrap_or(1.0).max(1e-12);
        // bias devices realize beta * (bscale/scale) relative to weight scale
        quantize_signed(b.data, bscale, m.device.levels)
            .into_iter()
            .map(|v| v * bscale / scale)
            .collect::<Vec<f64>>()
    });
    let g = FcXbarGeom { cin, cout };
    let devices = layout::place_fc(&g, &q, bias_q.as_deref(), mode.inverted());
    Ok(Crossbar {
        name: layer_name.to_string(),
        rows: g.rows(),
        cols: g.cols(),
        region: cin,
        devices,
        rf_scale: scale,
        mode,
    })
}

/// Build a synthetic FC crossbar of arbitrary size (Fig 7 benchmarks use
/// sizes beyond the trained network's layers).
pub fn build_synthetic_fc(cin: usize, cout: usize, levels: usize, mode: MapMode, seed: u64) -> Crossbar {
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..cin * cout)
        .map(|_| ((rng.f64() * 2.0 - 1.0) * 0.4) as f32)
        .collect();
    let q = quantize_signed(&w, 0.4, levels);
    let g = FcXbarGeom { cin, cout };
    let devices = layout::place_fc(&g, &q, None, mode.inverted());
    Crossbar {
        name: format!("synthetic_fc_{cin}x{cout}"),
        rows: g.rows(),
        cols: g.cols(),
        region: cin,
        devices,
        rf_scale: 0.4,
        mode,
    }
}

/// Build the per-(cin,cout) conv-channel crossbar for a named conv layer.
pub fn build_conv_crossbar(
    m: &Manifest,
    ws: &WeightStore,
    layer_name: &str,
    ci: usize,
    co: usize,
    mode: MapMode,
) -> Result<Crossbar> {
    let layer = m
        .layers
        .iter()
        .find(|l| l.name() == layer_name)
        .ok_or_else(|| anyhow!("layer '{layer_name}' not found"))?;
    let g = match layer {
        Layer::Conv(g) | Layer::DwConv(g) => g.clone(),
        other => bail!("layer '{layer_name}' is {} — not a conv", other.kind_label()),
    };
    if ci >= g.cin || co >= g.cout {
        bail!("channel ({ci},{co}) out of range ({},{})", g.cin, g.cout);
    }
    let (shape, q, scale) = weight_q(ws, &g.weight, m.device.levels)?;
    let kk = g.k * g.k;
    // HWIO: extract kernel (ci, co) — for dwconv shape is (k,k,1,C)
    let (ci_eff, cin_eff) = if shape[2] == 1 { (0, 1) } else { (ci, g.cin) };
    let kernel: Vec<f64> = (0..kk)
        .map(|a| q[(a * cin_eff + ci_eff) * g.cout + co])
        .collect();
    let geom = ConvXbarGeom::from_conv(g.h_in, g.w_in, g.k, g.stride, g.padding);
    let devices = layout::place_conv_kernel(&geom, &kernel, mode.inverted());
    Ok(Crossbar {
        name: format!("{layer_name}_ci{ci}_co{co}"),
        rows: geom.rows(),
        cols: geom.cols(),
        region: geom.wr * geom.wc,
        devices,
        rf_scale: scale,
        mode,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_unit_grid() {
        assert_eq!(quantize_unit(0.0, 64), 0.0);
        assert_eq!(quantize_unit(1.0, 64), 1.0);
        let q = quantize_unit(0.5, 64);
        assert!((q - 0.5).abs() <= 0.5 / 63.0);
    }

    #[test]
    fn quantize_signed_symmetry() {
        let q = quantize_signed(&[0.2, -0.2, 0.0], 0.4, 64);
        assert_eq!(q[0], -q[1]);
        assert_eq!(q[2], 0.0);
    }

    #[test]
    fn prog_noise_preserves_zero() {
        let mut q = vec![0.0, 0.5, 1.0];
        let mut rng = Rng::new(1);
        apply_prog_noise(&mut q, 0.05, &mut rng);
        assert_eq!(q[0], 0.0);
        assert!(q[1] != 0.5 || q[2] != 1.0); // noise applied somewhere
        assert!(q.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn mode_parse() {
        assert_eq!(MapMode::parse("inverted").unwrap(), MapMode::Inverted);
        assert_eq!(MapMode::parse("dual").unwrap(), MapMode::Dual);
        assert!(MapMode::parse("x").is_err());
        assert_eq!(MapMode::Inverted.opamps_per_port(), 1);
        assert_eq!(MapMode::Dual.opamps_per_port(), 2);
    }

    #[test]
    fn mode_fromstr_display_roundtrip() {
        for mode in [MapMode::Inverted, MapMode::Dual] {
            let parsed: MapMode = mode.to_string().parse().unwrap();
            assert_eq!(parsed, mode);
        }
        assert!("INVERTED".parse::<MapMode>().is_err());
    }

    #[test]
    fn prog_noise_placed_stays_physical() {
        let mut devices: Vec<layout::Placed> = (0..64)
            .map(|i| layout::Placed { row: i, col: 0, g_norm: (i + 1) as f64 / 64.0 })
            .collect();
        let before = devices.clone();
        let mut rng = Rng::new(7);
        apply_prog_noise_placed(&mut devices, 0.2, 64, &mut rng);
        let floor = 0.5 / 63.0;
        assert!(devices.iter().all(|d| d.g_norm >= floor && d.g_norm <= 1.0));
        assert!(devices.iter().zip(&before).any(|(a, b)| a.g_norm != b.g_norm));
        // sigma 0 is a no-op
        let mut copy = before.clone();
        apply_prog_noise_placed(&mut copy, 0.0, 64, &mut rng);
        assert!(copy.iter().zip(&before).all(|(a, b)| a.g_norm == b.g_norm));
    }

    #[test]
    fn prog_noise_placed_keeps_over_unity_bias_devices() {
        // bias devices realize |b|·bscale/scale and can exceed unit
        // conductance — noise must perturb around the nominal value, not
        // crush it to 1
        let mut devices =
            vec![layout::Placed { row: 0, col: 0, g_norm: 8.0 }; 32];
        let mut rng = Rng::new(3);
        apply_prog_noise_placed(&mut devices, 0.05, 64, &mut rng);
        assert!(devices.iter().all(|d| d.g_norm > 1.0 && d.g_norm <= 8.0));
        assert!(devices.iter().any(|d| d.g_norm != 8.0));
    }

    #[test]
    fn prog_noise_analog_keeps_tiny_conductances_unfloored() {
        // a 1/N averaging conductance far below the quantized-level floor
        // must stay near its nominal value (the placed-noise floor would
        // inflate it and scale the computed mean)
        let nominal = 1.0 / 1024.0;
        let mut devices =
            vec![layout::Placed { row: 0, col: 0, g_norm: nominal }; 64];
        let mut rng = Rng::new(11);
        apply_prog_noise_analog(&mut devices, 0.02, &mut rng);
        assert!(devices.iter().any(|d| d.g_norm != nominal), "noise must perturb");
        assert!(
            devices.iter().all(|d| d.g_norm > 0.0 && (d.g_norm / nominal - 1.0).abs() < 0.2),
            "noise must stay a small relative perturbation"
        );
        // sigma 0 is a no-op
        let before = devices.clone();
        apply_prog_noise_analog(&mut devices, 0.0, &mut rng);
        assert!(devices.iter().zip(&before).all(|(a, b)| a.g_norm == b.g_norm));
        // full-on devices stay inside the physical window (g_norm <= 1)
        let mut full = vec![layout::Placed { row: 0, col: 0, g_norm: 1.0 }; 64];
        apply_prog_noise_analog(&mut full, 0.3, &mut rng);
        assert!(full.iter().all(|d| d.g_norm > 0.0 && d.g_norm <= 1.0));
        assert!(full.iter().any(|d| d.g_norm != 1.0), "noise must still perturb downward");
    }

    #[test]
    fn bn_fold_from_stats_matches_formula() {
        let fold = BnFold::from_stats(&[1.5, -0.8], &[0.1, -0.2], &[0.05, 0.2], &[0.9, 0.4]);
        assert!((fold.k[0] - 1.5 / (0.9f64 + BN_EPS).sqrt()).abs() < 1e-15);
        assert!((fold.k[1] - -0.8 / (0.4f64 + BN_EPS).sqrt()).abs() < 1e-15);
        assert_eq!(fold.mean, vec![0.05, 0.2]);
        assert_eq!(fold.beta, vec![0.1, -0.2]);
    }

    #[test]
    fn synthetic_fc_eval_matches_weights() {
        // ideal crossbar must reproduce W^T v within quantization error
        let cb = build_synthetic_fc(16, 4, 4096, MapMode::Inverted, 9);
        let v: Vec<f64> = (0..16).map(|i| (i as f64 - 8.0) / 8.0).collect();
        let out = cb.eval_ideal(&v);
        assert_eq!(out.len(), 4);
        // reconstruct weights from devices and compare
        let mut w = vec![0.0; 16 * 4];
        for d in &cb.devices {
            let (i, sgn) = if d.row < 16 { (d.row, -1.0) } else { (d.row - 16, 1.0) };
            // inverted: neg region holds positives
            w[i * 4 + d.col] += sgn * d.g_norm * cb.rf_scale;
        }
        for c in 0..4 {
            let expect: f64 = (0..16).map(|i| v[i] * w[i * 4 + c]).sum();
            assert!((out[c] - expect).abs() < 1e-9, "col {c}: {} vs {expect}", out[c]);
        }
    }

    #[test]
    fn dual_mode_eval_equals_inverted() {
        let a = build_synthetic_fc(12, 3, 64, MapMode::Inverted, 4);
        let b = build_synthetic_fc(12, 3, 64, MapMode::Dual, 4);
        let v: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let oa = a.eval_ideal(&v);
        let ob = b.eval_ideal(&v);
        for (x, y) in oa.iter().zip(&ob) {
            assert!((x - y).abs() < 1e-12, "modes must compute the same function");
        }
    }
}
