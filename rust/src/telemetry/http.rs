//! Tiny HTTP metrics exporter (std-only: no async runtime, no HTTP crate
//! in the offline cache — a blocking accept loop on its own thread is
//! plenty for a scrape endpoint).
//!
//! Routes:
//! * `GET /metrics`      → Prometheus text exposition 0.0.4
//! * `GET /metrics.json` → the same registry rendered as JSON
//! * `GET /`             → a one-line index
//!
//! Started by `memx serve --metrics-addr HOST:PORT` (see
//! `Server::serve_metrics`), or directly over any
//! [`Registry`](crate::telemetry::metrics::Registry).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::metrics::Registry;

/// A running metrics endpoint; the listener thread stops on drop or
/// [`MetricsServer::shutdown`].
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9095`; port 0 picks a free port) and
    /// serve `registry` until shutdown.
    pub fn serve(addr: &str, registry: Arc<Registry>) -> Result<MetricsServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind metrics listener on {addr}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::Builder::new()
            .name("memx-metrics".into())
            .spawn(move || accept_loop(listener, registry, stop2))
            .context("spawn metrics listener thread")?;
        Ok(MetricsServer { addr, stop, join: Some(join) })
    }

    /// The bound address (resolves port 0 to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            j.join().ok();
        }
    }

    /// Stop the listener and wait for its thread (also performed on drop).
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(listener: TcpListener, registry: Arc<Registry>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                // one scrape at a time: a scrape endpoint has no
                // concurrency requirements, and inline handling keeps the
                // exporter to a single thread
                handle(stream, &registry).ok();
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn handle(mut stream: TcpStream, registry: &Registry) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    // read the request head (we only route on the request line)
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let line = String::from_utf8_lossy(&head);
    let path = line.split_whitespace().nth(1).unwrap_or("/");

    let (status, ctype, body) = match path {
        "/metrics" => {
            ("200 OK", "text/plain; version=0.0.4; charset=utf-8", registry.render_prometheus())
        }
        "/metrics.json" | "/json" => {
            ("200 OK", "application/json; charset=utf-8", registry.render_json())
        }
        "/" => (
            "200 OK",
            "text/plain; charset=utf-8",
            "memx metrics exporter — GET /metrics (prometheus) or /metrics.json\n".to_string(),
        ),
        _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).expect("connect");
        write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).expect("read response");
        out
    }

    #[test]
    fn serves_prometheus_and_json() {
        let registry = Arc::new(Registry::default());
        let c = registry.counter("memx_http_test_total", "exporter test counter");
        c.add(5);
        registry.histogram("memx_http_test_seconds", "exporter test histogram")
            .record(Duration::from_micros(100));
        let server = MetricsServer::serve("127.0.0.1:0", registry).expect("exporter up");
        let addr = server.addr();

        let prom = get(addr, "/metrics");
        assert!(prom.starts_with("HTTP/1.1 200 OK"), "{prom}");
        assert!(prom.contains("text/plain; version=0.0.4"), "{prom}");
        assert!(prom.contains("memx_http_test_total 5"), "{prom}");
        assert!(prom.contains("memx_http_test_seconds_bucket{le=\"+Inf\"} 1"), "{prom}");

        let json = get(addr, "/metrics.json");
        assert!(json.starts_with("HTTP/1.1 200 OK"), "{json}");
        let body = json.split("\r\n\r\n").nth(1).expect("body");
        let parsed = crate::util::json::Json::parse(body).expect("json body parses");
        assert_eq!(
            parsed.get("memx_http_test_total").and_then(|v| v.as_f64()),
            Some(5.0),
            "{body}"
        );

        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
        server.shutdown();
    }
}
