//! Crossbar layout — the paper's Algorithm 1 and Eqs 1-3.
//!
//! A regular-convolution crossbar (per input channel, per output channel)
//! has rows = [positive input region | negative input region | 2 bias rows]
//! where each region is the row-unfolded padded input (Wr*Wc lines), and
//! cols = the flattened output positions (Or*Oc lines).  The memristor for
//! kernel element (a, b) of output i sits at row P_i + a*(Wc) + b, i.e.
//! starting from Eq 2/3's P_Pi / P_Ni and skipping (Wc - Fc) positions
//! between kernel rows (the paper writes the skip as Wc - Fc + 2P because it
//! indexes the *unpadded* input; we unfold the padded input directly so the
//! skip is Wc_padded - Fc).

/// Eq 1 (one spatial dim): O = (W - F + 2P)/S + 1.
pub fn out_dim(w: usize, f: usize, p: usize, s: usize) -> usize {
    (w + 2 * p - f) / s + 1
}

/// Eq 2: starting row of output i in the positive input region, over the
/// *padded* input of width `wc_pad`.
pub fn p_pos(i: usize, oc: usize, wc_pad: usize, s: usize) -> usize {
    ((i / oc) * wc_pad + (i % oc)) * s
}

/// Eq 3: starting row in the negative input region (offset by the region
/// size Wr*Wc of the padded input).
pub fn p_neg(i: usize, oc: usize, wr_pad: usize, wc_pad: usize, s: usize) -> usize {
    p_pos(i, oc, wc_pad, s) + wr_pad * wc_pad
}

/// One placed memristor: crossbar coordinates + normalized conductance.
#[derive(Debug, Clone, PartialEq)]
pub struct Placed {
    pub row: usize,
    pub col: usize,
    /// normalized conductance in (0, 1] (quantized |weight| / scale)
    pub g_norm: f64,
}

/// Geometry of one conv-channel crossbar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvXbarGeom {
    pub wr: usize,      // padded input rows
    pub wc: usize,      // padded input cols
    pub fr: usize,      // kernel rows
    pub fc: usize,      // kernel cols
    pub stride: usize,
    pub or_: usize,     // output rows
    pub oc: usize,      // output cols
}

impl ConvXbarGeom {
    pub fn from_conv(h_in: usize, w_in: usize, k: usize, stride: usize, pad: usize) -> Self {
        ConvXbarGeom {
            wr: h_in + 2 * pad,
            wc: w_in + 2 * pad,
            fr: k,
            fc: k,
            stride,
            or_: out_dim(h_in, k, pad, stride),
            oc: out_dim(w_in, k, pad, stride),
        }
    }

    /// Total input lines: pos region + neg region + 2 bias rows.
    pub fn rows(&self) -> usize {
        2 * self.wr * self.wc + 2
    }

    /// Output columns (flattened output positions).
    pub fn cols(&self) -> usize {
        self.or_ * self.oc
    }

    pub fn bias_row_pos(&self) -> usize {
        2 * self.wr * self.wc
    }

    pub fn bias_row_neg(&self) -> usize {
        2 * self.wr * self.wc + 1
    }
}

/// Place one 2-D kernel (row-major `fr*fc` normalized weights, signed) onto
/// a conv crossbar following Algorithm 1.  `inverted` selects the paper's
/// op-amp-saving convention (positive weights on the negated-input region).
/// Zero weights place no device (paper §3.2).
pub fn place_conv_kernel(g: &ConvXbarGeom, kernel_norm: &[f64], inverted: bool) -> Vec<Placed> {
    assert_eq!(kernel_norm.len(), g.fr * g.fc, "kernel size mismatch");
    let mut placed = Vec::new();
    let region = g.wr * g.wc;
    for i in 0..g.cols() {
        let base = p_pos(i, g.oc, g.wc, g.stride);
        for a in 0..g.fr {
            for b in 0..g.fc {
                let w = kernel_norm[a * g.fc + b];
                if w == 0.0 {
                    continue;
                }
                // row within the positive region for this kernel element
                let row_pos = base + a * g.wc + b;
                debug_assert!(row_pos < region, "placement overflows region");
                // inverted convention: w > 0 -> negative (negated-input)
                // region; w < 0 -> positive region. dual convention is the
                // mirror image.
                let to_neg_region = if inverted { w > 0.0 } else { w < 0.0 };
                let row = if to_neg_region { row_pos + region } else { row_pos };
                placed.push(Placed { row, col: i, g_norm: w.abs() });
            }
        }
    }
    placed
}

/// FC layout (paper §3.6): rows = [cin (pos) | cin (neg) | 2 bias], columns
/// = outputs; weight matrix row-major (cin x cout), bias per column.
#[derive(Debug, Clone, Copy)]
pub struct FcXbarGeom {
    pub cin: usize,
    pub cout: usize,
}

impl FcXbarGeom {
    pub fn rows(&self) -> usize {
        2 * self.cin + 2
    }

    pub fn cols(&self) -> usize {
        self.cout
    }
}

pub fn place_fc(
    g: &FcXbarGeom,
    w_norm: &[f64],
    bias_norm: Option<&[f64]>,
    inverted: bool,
) -> Vec<Placed> {
    assert_eq!(w_norm.len(), g.cin * g.cout);
    let mut placed = Vec::new();
    for o in 0..g.cout {
        for i in 0..g.cin {
            let w = w_norm[i * g.cout + o];
            if w == 0.0 {
                continue;
            }
            let to_neg = if inverted { w > 0.0 } else { w < 0.0 };
            let row = if to_neg { i + g.cin } else { i };
            placed.push(Placed { row, col: o, g_norm: w.abs() });
        }
        if let Some(b) = bias_norm {
            let w = b[o];
            if w != 0.0 {
                let to_neg = if inverted { w > 0.0 } else { w < 0.0 };
                let row = 2 * g.cin + usize::from(to_neg);
                placed.push(Placed { row, col: o, g_norm: w.abs() });
            }
        }
    }
    placed
}

/// Global-average-pool layout (paper §3.5): one averaging column of `1/N`
/// conductances. Rows are *region-relative* input lines of one channel
/// plane; [`crate::analog::build_gap_crossbar`] offsets them into the
/// differential region (the negated-input region under the inverted
/// convention, so the single TIA emits `+mean`) and tiles one such column
/// per channel.
pub fn place_gap(n_inputs: usize) -> Vec<Placed> {
    (0..n_inputs)
        .map(|i| Placed { row: i, col: 0, g_norm: 1.0 / n_inputs.max(1) as f64 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's worked example (Fig 2): 3x3 input, 2x2 kernel, stride 1,
    /// pad 0 -> 2x2 output; kernel [[0, 0.4], [0.6, 0]] positive part and
    /// [[ -0.1, 0], [0, -0.5]] merged as [[-0.1, 0.4], [0.6, -0.5]].
    #[test]
    fn paper_fig2_example() {
        let g = ConvXbarGeom::from_conv(3, 3, 2, 1, 0);
        assert_eq!((g.or_, g.oc), (2, 2));
        assert_eq!(g.rows(), 20); // 9 + 9 + 2 — matches the paper's "20 inputs"
        assert_eq!(g.cols(), 4);
        // Eq 2 starting positions: 0 -> 0? paper says (1,2,4,5) with 1-based
        // columns; 0-based: i=0 -> 0*3+0 = 0... the paper's example uses
        // starting position *after* the first element for its 1-indexed
        // figure; our 0-based P_0 = 0, P_1 = 1, P_2 = 3, P_3 = 4.
        assert_eq!(p_pos(0, 2, 3, 1), 0);
        assert_eq!(p_pos(1, 2, 3, 1), 1);
        assert_eq!(p_pos(2, 2, 3, 1), 3);
        assert_eq!(p_pos(3, 2, 3, 1), 4);
        assert_eq!(p_neg(0, 2, 3, 3, 1), 9);

        let kernel = [-0.1, 0.4, 0.6, -0.5];
        let placed = place_conv_kernel(&g, &kernel, true);
        // 4 outputs x 4 nonzero weights
        assert_eq!(placed.len(), 16);
        // output 0: -0.1 at (0,0) pos region; 0.4 at row 1 neg region (9+1);
        // 0.6 at row 3 (0 + 1*3 + 0) pos?? 0.6>0 -> neg region row 9+3=12;
        // -0.5 at row 0+1*3+1=4 pos region.
        let o0: Vec<&Placed> = placed.iter().filter(|p| p.col == 0).collect();
        let rows: Vec<usize> = o0.iter().map(|p| p.row).collect();
        assert!(rows.contains(&0));      // -0.1 direct region
        assert!(rows.contains(&10));     // +0.4 negated region (9 + 1)
        assert!(rows.contains(&12));     // +0.6 negated region (9 + 3)
        assert!(rows.contains(&4));      // -0.5 direct region
    }

    #[test]
    fn eq1_matches_manifest_geometry() {
        assert_eq!(out_dim(32, 3, 1, 1), 32);
        assert_eq!(out_dim(32, 3, 1, 2), 16);
        assert_eq!(out_dim(8, 5, 2, 1), 8);
    }

    #[test]
    fn zero_weights_place_nothing() {
        let g = ConvXbarGeom::from_conv(4, 4, 3, 1, 1);
        let placed = place_conv_kernel(&g, &[0.0; 9], true);
        assert!(placed.is_empty());
    }

    #[test]
    fn inverted_vs_dual_mirror() {
        let g = ConvXbarGeom::from_conv(4, 4, 2, 1, 0);
        let kernel = [0.5, -0.25, 0.0, 1.0];
        let inv = place_conv_kernel(&g, &kernel, true);
        let dual = place_conv_kernel(&g, &kernel, false);
        assert_eq!(inv.len(), dual.len());
        let region = g.wr * g.wc;
        for (a, b) in inv.iter().zip(&dual) {
            assert_eq!(a.col, b.col);
            assert_eq!(a.g_norm, b.g_norm);
            // same physical input line, opposite region
            assert_eq!(a.row % region, b.row % region);
            assert_ne!(a.row / region, b.row / region);
        }
    }

    #[test]
    fn rows_within_crossbar() {
        let g = ConvXbarGeom::from_conv(32, 32, 5, 2, 2);
        let kernel: Vec<f64> = (0..25).map(|i| (i as f64 - 12.0) / 12.0).collect();
        for p in place_conv_kernel(&g, &kernel, true) {
            assert!(p.row < g.rows() - 2, "row {} in {}", p.row, g.rows());
            assert!(p.col < g.cols());
            assert!(p.g_norm > 0.0 && p.g_norm <= 1.0);
        }
    }

    #[test]
    fn fc_placement_counts() {
        let g = FcXbarGeom { cin: 3, cout: 2 };
        assert_eq!(g.rows(), 8);
        let w = [0.5, -0.5, 0.0, 0.25, 1.0, 0.0];
        let b = [0.1, 0.0];
        let placed = place_fc(&g, &w, Some(&b), true);
        // nonzero weights: 4, nonzero bias: 1
        assert_eq!(placed.len(), 5);
        // w[0,0]=0.5 > 0 -> neg region row 0+3=3
        assert!(placed.iter().any(|p| p.row == 3 && p.col == 0));
        // w[0,1]=-0.5 -> pos region row 0
        assert!(placed.iter().any(|p| p.row == 0 && p.col == 1));
        // bias col 0 positive -> row 2*3+1 = 7
        assert!(placed.iter().any(|p| p.row == 7 && p.col == 0));
    }

    #[test]
    fn gap_places_n_devices() {
        let placed = place_gap(16);
        assert_eq!(placed.len(), 16);
        assert!(placed.iter().all(|p| p.col == 0 && p.g_norm == 1.0 / 16.0));
        // the column sums to unity conductance — the §3.5 mean weighting
        let total: f64 = placed.iter().map(|p| p.g_norm).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }
}
