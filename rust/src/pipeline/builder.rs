//! [`PipelineBuilder`] — compiles a [`Manifest`] + [`WeightStore`] (or a
//! synthetic FC stack) into a runnable [`Pipeline`].
//!
//! The builder owns every mapping decision the old free-function
//! choreography spread across call sites: differential convention
//! ([`MapMode`]), quantization levels, programming noise, netlist segment
//! size, worker count and execution [`Fidelity`]. `build` walks the
//! manifest's layer list, converts each entry into its [`AnalogModule`]
//! (squeeze-and-excite sub-chains collapse into one [`SeModule`]; residual
//! markers become summing-amplifier stages), and validates that the module
//! dims chain end to end.

use anyhow::{anyhow, bail, Result};

use crate::mapper::{
    apply_prog_noise, apply_prog_noise_placed, bn_fold, build_fc_crossbar, build_synthetic_fc,
    weight_q, Crossbar, MapMode,
};
use crate::nn::{ActKind, ConvGeom, DeviceJson, Layer, Manifest, WeightStore};
use crate::backend::BackendChoice;
use crate::spice::krylov::SolverStrategy;
use crate::spice::solve::Ordering;
use crate::util::pool;
use crate::util::prng::Rng;

use super::modules::{
    ActivationModule, BatchNormModule, ConvModuleCfg, CrossbarModule, GapModule, ModuleCfg,
    SeModule,
};
use super::{AnalogModule, Fidelity, Pipeline, Stage};

/// Running tensor shape while walking the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Shape {
    /// channel-major planes `[c][h*w]`
    Spatial { c: usize, h: usize, w: usize },
    /// plain vector
    Flat(usize),
}

impl Shape {
    fn len(&self) -> usize {
        match *self {
            Shape::Spatial { c, h, w } => c * h * w,
            Shape::Flat(n) => n,
        }
    }

    fn channels(&self) -> usize {
        match *self {
            Shape::Spatial { c, .. } => c,
            Shape::Flat(n) => n,
        }
    }

    fn spatial(&self) -> usize {
        match *self {
            Shape::Spatial { h, w, .. } => h * w,
            Shape::Flat(_) => 1,
        }
    }
}

/// The device constants the synthetic/test paths use when no manifest is
/// around (HP model values matching the trained artifacts' device.json).
pub fn default_device() -> DeviceJson {
    DeviceJson {
        r_on: 100.0,
        r_off: 16000.0,
        levels: 64,
        prog_sigma: 0.0,
        v_in: 2.5e-3,
        v_rail: 8.0,
        t_mem: 1e-10,
        slew_rate: 1e7,
        v_swing: 5.0,
        p_opamp: 1e-3,
        p_memristor: 1.1e-6,
        p_aux: 5e-4,
        t_opamp: 5e-7,
    }
}

/// The deterministic crossbar sequence behind
/// [`PipelineBuilder::build_fc_stack`] — exposed so tests can reconstruct
/// the exact same layers and compare module transfers against
/// [`Crossbar::eval_ideal`] directly.
pub fn synthetic_stack_crossbars(
    dims: &[usize],
    levels: usize,
    mode: MapMode,
    seed: u64,
) -> Vec<Crossbar> {
    dims.windows(2)
        .enumerate()
        .map(|(i, w)| {
            build_synthetic_fc(w[0], w[1], levels, mode, seed.wrapping_add(i as u64 * 0x9E3779B9))
        })
        .collect()
}

/// Fluent configuration for compiling analog pipelines (see module docs).
#[derive(Debug, Clone)]
pub struct PipelineBuilder {
    mode: MapMode,
    fidelity: Fidelity,
    levels: Option<usize>,
    prog_sigma: f64,
    noise_seed: u64,
    segment: usize,
    workers: usize,
    ordering: Ordering,
    solver: SolverStrategy,
    backend: BackendChoice,
}

impl Default for PipelineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl PipelineBuilder {
    pub fn new() -> PipelineBuilder {
        PipelineBuilder {
            mode: MapMode::Inverted,
            fidelity: Fidelity::Behavioural,
            levels: None,
            prog_sigma: 0.0,
            noise_seed: 0x5EED,
            segment: 64,
            workers: 0,
            ordering: Ordering::Smart,
            solver: SolverStrategy::Auto,
            backend: BackendChoice::Auto,
        }
    }

    /// Differential mapping convention (default: the paper's inverted §3.2).
    pub fn mode(mut self, mode: MapMode) -> Self {
        self.mode = mode;
        self
    }

    /// Execution fidelity (default: [`Fidelity::Behavioural`]).
    pub fn fidelity(mut self, fidelity: Fidelity) -> Self {
        self.fidelity = fidelity;
        self
    }

    /// Override the device's quantization levels.
    pub fn levels(mut self, levels: usize) -> Self {
        self.levels = Some(levels);
        self
    }

    /// Relative programming noise applied to every placed device at compile
    /// time (default 0: deterministic mapping).
    pub fn prog_noise(mut self, sigma: f64, seed: u64) -> Self {
        self.prog_sigma = sigma;
        self.noise_seed = seed;
        self
    }

    /// Columns per netlist segment for [`Fidelity::Spice`] simulators
    /// (0 = monolithic; default 64, the paper's §4.2 sweet spot).
    pub fn segment(mut self, segment: usize) -> Self {
        self.segment = segment;
        self
    }

    /// Worker threads for parallel segment solves (0 = auto).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Elimination ordering for the SPICE engine.
    pub fn ordering(mut self, ordering: Ordering) -> Self {
        self.ordering = ordering;
        self
    }

    /// Linear-solver strategy for the SPICE engine (default
    /// [`SolverStrategy::Auto`]: direct factorization below the monolithic
    /// thresholds, preconditioned GMRES above them — see
    /// [`crate::spice::krylov`]).
    pub fn solver(mut self, solver: SolverStrategy) -> Self {
        self.solver = solver;
        self
    }

    /// Dense-kernel backend for the SPICE engine (default
    /// [`BackendChoice::Auto`]: honour the `MEMX_BACKEND` env override,
    /// else the portable-SIMD kernels — see [`crate::backend`]).
    pub fn backend(mut self, backend: BackendChoice) -> Self {
        self.backend = backend;
        self
    }

    fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            pool::default_workers()
        } else {
            self.workers
        }
    }

    /// The circuit-compilation environment this builder resolves for module
    /// constructors — one struct threading device config, fidelity, netlist
    /// segmentation, solver strategy, workers and programming noise into
    /// every [`super::AnalogModule`], so the §3.3/§3.5 BN and GAP netlists
    /// honour exactly the same knobs as the crossbar layers.
    pub fn module_cfg<'a>(&self, dev: &'a DeviceJson) -> ModuleCfg<'a> {
        ModuleCfg {
            dev,
            fidelity: self.fidelity,
            segment: self.segment,
            ordering: self.ordering,
            solver: self.solver,
            backend: self.backend,
            workers: self.resolved_workers(),
            prog_sigma: self.prog_sigma,
        }
    }

    /// Compile the full manifest into a runnable [`Pipeline`].
    pub fn build(&self, m: &Manifest, ws: &WeightStore) -> Result<Pipeline> {
        if m.layers.is_empty() {
            bail!("manifest has no layers");
        }
        let mut mm = m.clone();
        if let Some(l) = self.levels {
            mm.device.levels = l;
        }
        let dev = mm.device.clone();
        let cfg = self.module_cfg(&dev);
        let mut rng = Rng::new(self.noise_seed);
        let mut stages: Vec<Stage> = Vec::new();
        let mut shape = input_shape(&mm.layers[0]);
        let mut i = 0;
        while i < mm.layers.len() {
            let l = mm.layers[i].clone();
            match &l {
                Layer::Conv(g) | Layer::DwConv(g) => {
                    let depthwise = matches!(l, Layer::DwConv(_));
                    let want_c = if depthwise { g.cout } else { g.cin };
                    ensure_spatial(shape, want_c, g.h_in, g.w_in, &g.name)?;
                    let module = self.conv_module(g, depthwise, &mm, ws, &mut rng)?;
                    shape = Shape::Spatial { c: g.cout, h: g.h_out, w: g.w_out };
                    stages.push(Stage::Module { unit: g.unit.clone(), module: Box::new(module) });
                }
                Layer::Bn { name, unit, c, weight } => {
                    ensure_channels(shape, *c, name)?;
                    let module =
                        self.bn_module(name, weight, *c, shape.spatial(), ws, &cfg, &mut rng)?;
                    stages.push(Stage::Module { unit: unit.clone(), module: Box::new(module) });
                }
                Layer::Act { name, unit, kind, c } => {
                    ensure_channels(shape, *c, name)?;
                    let module = ActivationModule::new(
                        name.clone(),
                        *kind,
                        *c,
                        shape.spatial(),
                        self.fidelity,
                        dev.v_rail,
                        self.resolved_workers(),
                    );
                    stages.push(Stage::Module { unit: unit.clone(), module: Box::new(module) });
                }
                Layer::GaPool { name, unit, c, h_in, w_in } => {
                    ensure_spatial(shape, *c, *h_in, *w_in, name)?;
                    if is_se_block(&mm.layers[i..]) {
                        let module =
                            self.se_module(&mm, ws, i, shape.spatial(), &cfg, &mut rng)?;
                        stages
                            .push(Stage::Module { unit: unit.clone(), module: Box::new(module) });
                        i += 5;
                        continue;
                    }
                    let module =
                        GapModule::new(name.clone(), *c, *h_in, *w_in, self.mode, &cfg, &mut rng)?;
                    shape = Shape::Flat(*c);
                    stages.push(Stage::Module { unit: unit.clone(), module: Box::new(module) });
                }
                Layer::Fc { name, unit, cin, cout, .. }
                | Layer::PConv { name, unit, cin, cout, .. } => {
                    if shape.len() != *cin {
                        bail!(
                            "layer '{name}' expects {cin} inputs, network provides {}",
                            shape.len()
                        );
                    }
                    let kind = if matches!(l, Layer::Fc { .. }) { "FC" } else { "PConv" };
                    let module = self.fc_module(&mm, ws, name, kind, &mut rng)?;
                    shape = Shape::Flat(*cout);
                    stages.push(Stage::Module { unit: unit.clone(), module: Box::new(module) });
                }
                Layer::Residual { name, unit, c } => {
                    ensure_channels(shape, *c, name)?;
                    stages.push(Stage::Residual {
                        name: name.clone(),
                        unit: unit.clone(),
                        dim: shape.len(),
                        channels: *c,
                    });
                }
            }
            i += 1;
        }
        Pipeline::from_stages(stages, self.fidelity)
    }

    /// Compile a single named FC/PConv layer into a one-stage pipeline —
    /// the `memx spice` / layer-demo path.
    pub fn build_layer(&self, m: &Manifest, ws: &WeightStore, layer: &str) -> Result<Pipeline> {
        let mut mm = m.clone();
        if let Some(l) = self.levels {
            mm.device.levels = l;
        }
        let found = mm
            .layers
            .iter()
            .find(|l| l.name() == layer)
            .ok_or_else(|| anyhow!("layer '{layer}' not found"))?;
        let (kind, unit) = match found {
            Layer::Fc { unit, .. } => ("FC", unit.clone()),
            Layer::PConv { unit, .. } => ("PConv", unit.clone()),
            other => bail!(
                "layer '{layer}' is {} — single-layer pipelines support FC/PConv",
                other.kind_label()
            ),
        };
        let mut rng = Rng::new(self.noise_seed);
        let module = self.fc_module(&mm, ws, layer, kind, &mut rng)?;
        Pipeline::from_stages(
            vec![Stage::Module { unit, module: Box::new(module) }],
            self.fidelity,
        )
    }

    /// Compile a synthetic FC stack (`dims[0] -> dims[1] -> ...`) — the
    /// manifest-free path benches and property tests use. Layer weights
    /// come from [`synthetic_stack_crossbars`] with the same `seed`.
    pub fn build_fc_stack(&self, dims: &[usize], dev: &DeviceJson, seed: u64) -> Result<Pipeline> {
        if dims.len() < 2 {
            bail!("fc stack needs at least two dims");
        }
        let levels = self.levels.unwrap_or(dev.levels);
        let mut rng = Rng::new(self.noise_seed);
        let mut modules: Vec<Box<dyn AnalogModule>> = Vec::new();
        for mut cb in synthetic_stack_crossbars(dims, levels, self.mode, seed) {
            apply_prog_noise_placed(&mut cb.devices, self.prog_sigma, levels, &mut rng);
            modules.push(Box::new(self.crossbar_module(cb, dev)?));
        }
        Pipeline::from_modules(modules, self.fidelity)
    }

    /// Wrap an explicit crossbar in a [`CrossbarModule`] using this
    /// builder's fidelity / segment / ordering / workers configuration.
    pub fn crossbar_module(&self, cb: Crossbar, dev: &DeviceJson) -> Result<CrossbarModule> {
        CrossbarModule::fc(
            cb.name.clone(),
            "FC",
            cb,
            dev,
            self.fidelity,
            self.segment,
            self.ordering,
            self.solver,
            self.backend,
            self.resolved_workers(),
        )
    }

    fn fc_module(
        &self,
        m: &Manifest,
        ws: &WeightStore,
        name: &str,
        kind: &'static str,
        rng: &mut Rng,
    ) -> Result<CrossbarModule> {
        let mut cb = build_fc_crossbar(m, ws, name, self.mode)?;
        apply_prog_noise_placed(&mut cb.devices, self.prog_sigma, m.device.levels, rng);
        CrossbarModule::fc(
            name.to_string(),
            kind,
            cb,
            &m.device,
            self.fidelity,
            self.segment,
            self.ordering,
            self.solver,
            self.backend,
            self.resolved_workers(),
        )
    }

    fn conv_module(
        &self,
        g: &ConvGeom,
        depthwise: bool,
        m: &Manifest,
        ws: &WeightStore,
        rng: &mut Rng,
    ) -> Result<CrossbarModule> {
        let levels = m.device.levels;
        let (shape, mut q, scale) = weight_q(ws, &g.weight, levels)?;
        let expect = if depthwise {
            vec![g.k, g.k, 1, g.cout]
        } else {
            vec![g.k, g.k, g.cin, g.cout]
        };
        if shape != expect {
            bail!("conv '{}': weight shape {shape:?} != {expect:?}", g.name);
        }
        apply_prog_noise(&mut q, self.prog_sigma, rng);
        // HWIO -> bank layout (see modules::ConvBanks::kernels)
        let kk = g.k * g.k;
        let kernels = if depthwise {
            let mut ks = vec![0.0; g.cout * kk];
            for c in 0..g.cout {
                for a in 0..kk {
                    ks[c * kk + a] = q[a * g.cout + c];
                }
            }
            ks
        } else {
            let mut ks = vec![0.0; g.cin * g.cout * kk];
            for co in 0..g.cout {
                for ci in 0..g.cin {
                    for a in 0..kk {
                        ks[(co * g.cin + ci) * kk + a] = q[(a * g.cin + ci) * g.cout + co];
                    }
                }
            }
            ks
        };
        CrossbarModule::conv(
            ConvModuleCfg {
                name: g.name.clone(),
                kind: if depthwise { "DConv" } else { "Conv" },
                geom: g.clone(),
                depthwise,
                kernels,
                scale,
                mode: self.mode,
                fidelity: self.fidelity,
                segment: self.segment,
                ordering: self.ordering,
                solver: self.solver,
                backend: self.backend,
                workers: self.resolved_workers(),
            },
            &m.device,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn bn_module(
        &self,
        name: &str,
        weight: &str,
        c: usize,
        spatial: usize,
        ws: &WeightStore,
        cfg: &ModuleCfg,
        rng: &mut Rng,
    ) -> Result<BatchNormModule> {
        // python always emits the companion stats; synthetic manifests may
        // not — bn_fold's identity defaults keep the fold well-defined
        // (its errors already name the tensor base)
        let fold = bn_fold(ws, weight, c)?;
        BatchNormModule::new(name, c, spatial, fold, self.mode, cfg, rng)
    }

    fn se_module(
        &self,
        m: &Manifest,
        ws: &WeightStore,
        i: usize,
        spatial: usize,
        cfg: &ModuleCfg,
        rng: &mut Rng,
    ) -> Result<SeModule> {
        let dev = &m.device;
        let (
            Layer::GaPool { name, c, h_in, w_in, .. },
            Layer::PConv { name: n1, .. },
            Layer::Act { name: na1, c: c1, .. },
            Layer::PConv { name: n2, .. },
            Layer::Act { name: na2, c: c2, .. },
        ) = (
            &m.layers[i],
            &m.layers[i + 1],
            &m.layers[i + 2],
            &m.layers[i + 3],
            &m.layers[i + 4],
        )
        else {
            bail!("squeeze-and-excite block structure mismatch at layer {i}");
        };
        let gap = GapModule::new(name.clone(), *c, *h_in, *w_in, self.mode, cfg, rng)?;
        let fc1 = self.fc_module(m, ws, n1, "PConv", rng)?;
        let act1 = ActivationModule::new(
            na1.clone(),
            ActKind::Relu,
            *c1,
            1,
            self.fidelity,
            dev.v_rail,
            self.resolved_workers(),
        );
        let fc2 = self.fc_module(m, ws, n2, "PConv", rng)?;
        let act2 = ActivationModule::new(
            na2.clone(),
            ActKind::HSigmoid,
            *c2,
            1,
            self.fidelity,
            dev.v_rail,
            self.resolved_workers(),
        );
        let se_name = name.strip_suffix(".gap").unwrap_or(name).to_string();
        SeModule::new(se_name, *c, spatial, gap, fc1, act1, fc2, act2)
    }
}

/// Squeeze-and-excite structural pattern: pool → PConv → ReLU → PConv →
/// hard sigmoid (the classifier's pool is followed by an FC, so it never
/// matches).
fn is_se_block(layers: &[Layer]) -> bool {
    layers.len() >= 5
        && matches!(layers[0], Layer::GaPool { .. })
        && matches!(layers[1], Layer::PConv { .. })
        && matches!(layers[2], Layer::Act { kind: ActKind::Relu, .. })
        && matches!(layers[3], Layer::PConv { .. })
        && matches!(layers[4], Layer::Act { kind: ActKind::HSigmoid, .. })
}

/// Input shape the first manifest layer expects.
fn input_shape(first: &Layer) -> Shape {
    match first {
        Layer::Conv(g) => Shape::Spatial { c: g.cin, h: g.h_in, w: g.w_in },
        Layer::DwConv(g) => Shape::Spatial { c: g.cout, h: g.h_in, w: g.w_in },
        Layer::GaPool { c, h_in, w_in, .. } => Shape::Spatial { c: *c, h: *h_in, w: *w_in },
        Layer::Fc { cin, .. } | Layer::PConv { cin, .. } => Shape::Flat(*cin),
        Layer::Bn { c, .. } | Layer::Act { c, .. } | Layer::Residual { c, .. } => Shape::Flat(*c),
    }
}

fn ensure_channels(shape: Shape, c: usize, name: &str) -> Result<()> {
    if shape.channels() != c {
        bail!("layer '{name}' expects {c} channels, network provides {}", shape.channels());
    }
    Ok(())
}

fn ensure_spatial(shape: Shape, c: usize, h: usize, w: usize, name: &str) -> Result<()> {
    match shape {
        Shape::Spatial { c: sc, h: sh, w: sw } if sc == c && sh == h && sw == w => Ok(()),
        other => bail!("layer '{name}' expects {c}x{h}x{w} input, network provides {other:?}"),
    }
}

/// A deterministic synthetic mini-MobileNetV3 over 4x4x3 inputs: stem conv
/// + BN + h-swish, one bottleneck unit (depthwise conv + BN + ReLU +
/// squeeze-and-excite + residual), then the GAP + FC classifier head —
/// every paper module type in one chain. This is the manifest-free
/// demo network the full-chain fidelity conformance suite
/// (`rust/tests/fidelity.rs`), `report --coverage` without artifacts and
/// the bench smoke all share. Weight magnitudes are kept small enough that
/// no stage approaches the TIA rails, so Spice and Behavioural runs are
/// comparable without clamp effects.
pub fn demo_network(seed: u64) -> Result<(Manifest, WeightStore)> {
    struct Blob {
        data: Vec<f32>,
        entries: Vec<String>,
    }
    impl Blob {
        fn tensor(&mut self, name: &str, shape: &[usize], vals: Vec<f32>, scale: Option<f64>) {
            let dims =
                shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",");
            let scale_s = scale.map(|s| format!(",\"scale\":{s}")).unwrap_or_default();
            self.entries.push(format!(
                "{{\"name\":\"{name}\",\"shape\":[{dims}],\"offset\":{},\"len\":{}{scale_s}}}",
                self.data.len(),
                vals.len()
            ));
            self.data.extend(vals);
        }

        /// Gentle batch stats: one negative gamma (the §3.3 scale pair's
        /// sign path), variances away from zero so the fold stays below
        /// the rails on demo-scale activations.
        fn bn(&mut self, base: &str, c: usize, rng: &mut Rng) {
            let gamma: Vec<f32> =
                (0..c).map(|i| if i == 0 { -0.9 } else { 0.6 + rng.f32() * 0.8 }).collect();
            self.tensor(&format!("{base}.gamma"), &[c], gamma, None);
            self.tensor(&format!("{base}.beta"), &[c], rand_vals(rng, c, 0.2), None);
            self.tensor(&format!("{base}.mean"), &[c], rand_vals(rng, c, 0.2), None);
            let var: Vec<f32> = (0..c).map(|_| 0.5 + rng.f32()).collect();
            self.tensor(&format!("{base}.var"), &[c], var, None);
        }
    }
    fn rand_vals(rng: &mut Rng, n: usize, amp: f32) -> Vec<f32> {
        (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * amp).collect()
    }

    let mut rng = Rng::new(seed);
    let mut b = Blob { data: Vec::new(), entries: Vec::new() };
    b.tensor("stem.conv.w", &[3, 3, 3, 4], rand_vals(&mut rng, 108, 0.3), Some(0.3));
    b.bn("stem.bn", 4, &mut rng);
    b.tensor("b1.dw.w", &[3, 3, 1, 4], rand_vals(&mut rng, 36, 0.3), Some(0.3));
    b.bn("b1.bn", 4, &mut rng);
    b.tensor("b1.se.fc1.w", &[4, 2], rand_vals(&mut rng, 8, 0.4), Some(0.4));
    b.tensor("b1.se.fc2.w", &[2, 4], rand_vals(&mut rng, 8, 0.4), Some(0.4));
    b.tensor("cls.fc.w", &[4, 3], rand_vals(&mut rng, 12, 0.4), Some(0.4));

    let layers = r#"
        {"unit":"stem","layer":"conv","name":"stem.conv","k":3,"stride":1,"padding":1,
         "cin":3,"cout":4,"h_in":4,"w_in":4,"h_out":4,"w_out":4,"weight":"stem.conv.w"},
        {"unit":"stem","layer":"bn","name":"stem.bn","c":4,"weight":"stem.bn.gamma"},
        {"unit":"stem","layer":"hswish","name":"stem.act","c":4},
        {"unit":"b1","layer":"dwconv","name":"b1.dw","k":3,"stride":1,"padding":1,
         "cin":4,"cout":4,"h_in":4,"w_in":4,"h_out":4,"w_out":4,"weight":"b1.dw.w"},
        {"unit":"b1","layer":"bn","name":"b1.bn","c":4,"weight":"b1.bn.gamma"},
        {"unit":"b1","layer":"relu","name":"b1.act","c":4},
        {"unit":"b1","layer":"gapool","name":"b1.se.gap","c":4,"h_in":4,"w_in":4},
        {"unit":"b1","layer":"pconv","name":"b1.se.fc1","cin":4,"cout":2,"weight":"b1.se.fc1.w"},
        {"unit":"b1","layer":"relu","name":"b1.se.act1","c":2},
        {"unit":"b1","layer":"pconv","name":"b1.se.fc2","cin":2,"cout":4,"weight":"b1.se.fc2.w"},
        {"unit":"b1","layer":"hsigmoid","name":"b1.se.act2","c":4},
        {"unit":"b1","layer":"residual","name":"b1.add","c":4},
        {"unit":"cls","layer":"gapool","name":"cls.gap","c":4,"h_in":4,"w_in":4},
        {"unit":"cls","layer":"fc","name":"cls.fc","cin":4,"cout":3,"weight":"cls.fc.w"}"#;
    let json = format!(
        r#"{{
        "arch":"demo","width":1.0,"img":4,"num_classes":3,
        "digital_test_acc":0.0,"batch_sizes":[1,4],
        "artifacts":{{}},
        "device":{{"r_on":100,"r_off":16000,"levels":64,"prog_sigma":0.0,
          "v_in":0.0025,"v_rail":8.0,"t_mem":1e-10,"slew_rate":1e7,
          "v_swing":5.0,"p_opamp":0.001,"p_memristor":1.1e-6,"p_aux":0.0005,
          "t_opamp":5e-7}},
        "dataset":{{"file":"dataset.bin","n":0}},
        "expected_logits":{{"file":"expected.bin","n":0}},
        "weights":[{weights}],
        "layers":[{layers}]
        }}"#,
        weights = b.entries.join(",")
    );
    let m = Manifest::parse(&json)?;
    let ws = WeightStore::from_parts(b.data, m.weights.clone())?;
    Ok((m, ws))
}
