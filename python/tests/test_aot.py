"""AOT path tests: HLO-text interchange + artifact sidecar formats."""

import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import device as dv
from compile import model as M


def test_to_hlo_text_roundtrips_simple_fn():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "dot(" in text or "dot " in text


def test_to_hlo_text_contains_no_serialized_proto():
    """Interchange must be text (xla_extension 0.5.1 rejects 64-bit-id
    protos) — sanity: output is ASCII-decodable."""
    def fn(x):
        return (x * 2.0,)

    spec = jax.ShapeDtypeStruct((4,), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec))
    text.encode("ascii")


def test_lower_forward_smoke_digital():
    width = 0.25
    params = M.init_params(0, width)
    lowered = aot.lower_forward(params, width, 2, M.Ctx())
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # weights are baked: no parameter other than the image input
    head = text.split("ENTRY")[1][:2000]
    assert head.count("parameter(") == 1


def test_lower_forward_smoke_analog():
    width = 0.25
    params = M.init_params(0, width)
    analog = M.convert_params_analog(params, dv.DEFAULT_DEVICE)
    lowered = aot.lower_forward(params, width, 1, M.Ctx(analog=analog))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text


def test_export_weights_table(tmp_path):
    params = M.init_params(0, 0.25)
    analog = M.convert_params_analog(params, dv.DEFAULT_DEVICE)
    table = aot.export_weights(params, analog, str(tmp_path))
    raw = open(tmp_path / "weights.bin", "rb").read()
    magic, n = struct.unpack("<II", raw[:8])
    assert magic == 0x4D454D58
    total = sum(e["len"] for e in table)
    assert n == total
    assert len(raw) == 8 + 4 * total
    # offsets are cumulative and sorted by name
    names = [e["name"] for e in table]
    assert names == sorted(names)
    off = 0
    for e in table:
        assert e["offset"] == off
        off += e["len"]
    # spot-check one tensor round-trips
    e = next(t for t in table if t["name"] == "stem.conv.w")
    got = np.frombuffer(raw[8 + 4 * e["offset"]: 8 + 4 * (e["offset"] + e["len"])],
                        dtype="<f4").reshape(e["shape"])
    np.testing.assert_array_equal(got, params["stem.conv.w"])


def test_weight_table_scales_match_analog(tmp_path):
    params = M.init_params(0, 0.25)
    analog = M.convert_params_analog(params, dv.DEFAULT_DEVICE)
    table = aot.export_weights(params, analog, str(tmp_path))
    for e in table:
        if e["name"] in analog:
            assert abs(e["scale"] - float(analog[e["name"]]["scale"])) < 1e-6
