//! [`AnalogModule`] implementations — one per paper module type.
//!
//! * [`CrossbarModule`] — FC/PConv layers own one differential crossbar;
//!   Conv/DConv layers own a bank per channel pair (Algorithm 1 layout).
//!   At [`Fidelity::Spice`] every crossbar holds a resident
//!   [`CrossbarSim`] and batches are answered by
//!   [`CrossbarSim::solve_batch`] — one multi-RHS substitution pass per
//!   netlist segment.
//! * [`BatchNormModule`] — the §3.3 subtraction + scale/offset circuit,
//!   folded to its exact affine form `(x - mean) * k + beta`; at
//!   [`Fidelity::Spice`] a resident netlist pair
//!   ([`analog::build_bn_crossbars`]).
//! * [`ActivationModule`] — behavioural fast path (software / rail-clipped
//!   forms) with the SPICE-backed Fig 4 [`ActCircuit`] at
//!   [`Fidelity::Spice`].
//! * [`GapModule`] — the §3.5 averaging column (1/N conductances); at
//!   [`Fidelity::Spice`] a resident [`analog::build_gap_crossbar`] netlist.
//! * [`SeModule`] — the squeeze-and-excite side branch: pool → FC → ReLU →
//!   FC → hard sigmoid → per-channel scale of the trunk tensor.
//!
//! # Fidelity coverage matrix
//!
//! What each module actually executes per [`Fidelity`] — pinned by the
//! conformance suite in `rust/tests/fidelity.rs`, so a module can only
//! claim a fidelity it passes:
//!
//! | Module | Ideal | Behavioural | Spice |
//! |---|---|---|---|
//! | [`CrossbarModule`] FC/PConv | `Crossbar::eval_ideal` | eval + rail clamp | resident [`CrossbarSim`] |
//! | [`CrossbarModule`] Conv/DConv | direct-form bank transfer | + rail clamp | per-bank [`CrossbarSim`]s |
//! | [`BatchNormModule`] | exact affine fold | fold + rail clamp | §3.3 subtraction + scale/offset netlists |
//! | [`ActivationModule`] h-sigmoid/h-swish | software forms | rail-clipped analog forms | Fig 4 op-amp circuits |
//! | [`ActivationModule`] ReLU | software | rail-clipped CMOS | rail-clipped CMOS (by design: the paper realizes ReLU in CMOS, not op-amps) |
//! | [`GapModule`] | exact per-channel mean | exact mean | §3.5 averaging-column netlist |
//! | [`SeModule`] | composes the above | composes the above | composes the above |
//! | residual stages | exact add | exact add | exact add (the summing amplifier is not circuit-simulated) |
//!
//! At [`Fidelity::Spice`] the resource hooks (`memristors` / `opamps` /
//! `memristor_stages`) count the *emitted netlists* — BN reports its
//! per-channel two-stage circuit pair (the placed devices of the Eq 10
//! hardware, two crossbar stages on the Eq 17 path) and conv its per-bank
//! placements — so `report --coverage` and the stage-hook power model
//! ([`crate::power::latency_coverage`] / `energy_coverage`) reflect the
//! circuits actually simulated; at the other fidelities they report the
//! paper's closed-form counts (Eqs 10-13). [`AnalogModule::spice_circuits`]
//! exposes the resident-circuit count the conformance suite checks for
//! fidelity holes.
//!
//! # Device-lifetime faults and the coverage matrix
//!
//! Every module with resident device state implements
//! [`AnalogModule::inject_faults`] / [`AnalogModule::reprogram`] (see
//! [`crate::fault`]), and faults apply at **every** fidelity — but with
//! the per-fidelity approximation implied by the matrix above. FC/PConv
//! layers age their placed conductances, so `Crossbar::eval_ideal` (ideal
//! and behavioural) and the resident [`CrossbarSim`] (spice) see the same
//! per-device damage. Conv banks age placed devices at
//! [`Fidelity::Spice`]; below it they age their signed kernels
//! element-wise. BN and GAP have no per-device representation below
//! spice, so there they apply the population-mean decay scalar
//! ([`crate::fault::FaultStep::mean_decay`], squared for BN's two cascaded
//! stages); at [`Fidelity::Spice`] their netlist pairs receive true
//! per-device value-only updates via
//! [`CrossbarSim::update_conductances`]. Injection never rebuilds a
//! netlist, so cached factorizations and warm-GMRES preconditioners
//! survive every step, and reprogramming heals drift but never stuck-at
//! cells. Per-device injection passes the module's *pristine* conductances
//! as the ν(g) anchor ([`fault::apply_step_from`]) so the
//! conductance-dependent exponent ([`crate::fault::FaultConfig::nu_g`])
//! keeps the closed-form compose-exactness, and every fault-capable module
//! tracks its cumulative drift gain / reprogram counts for the
//! [`AnalogModule::drift_stats`] telemetry the serving snapshot tables.

use anyhow::{bail, Result};

use crate::analog::{self, ActCircuit};
use crate::backend::BackendChoice;
use crate::fault::{self, FaultStep};
use crate::mapper::layout::{p_pos, place_conv_kernel, ConvXbarGeom, Placed};
use crate::mapper::{apply_prog_noise_analog, BnFold, Crossbar, MapMode};
use crate::netlist::CrossbarSim;
use crate::nn::{ActKind, ConvGeom, DeviceJson};
use crate::spice::krylov::SolverStrategy;
use crate::spice::solve::Ordering;
use crate::util::pool::par_map_mut;
use crate::util::prng::Rng;

use super::{AnalogModule, Fidelity, ModuleDrift};

/// `gamma / sqrt(var + EPS)` fold constant — re-exported from the mapper,
/// the single source shared with [`crate::mapper::bn_fold`] and the §3.3
/// netlist builder.
pub use crate::mapper::BN_EPS;

/// Circuit-compilation environment shared by the module constructors: the
/// device model plus the SPICE-engine knobs the [`super::PipelineBuilder`]
/// resolves once per build — execution fidelity, netlist segmentation,
/// elimination ordering, linear-solver strategy
/// ([`SolverStrategy::Auto`] keeps segmented circuits direct and giant
/// monolithic ones on preconditioned GMRES), worker budget and programming
/// noise. Threading one struct through every constructor is what
/// guarantees the §3.3/§3.5 netlists honour the same device config /
/// noise / solver selection as the crossbar layers.
#[derive(Debug, Clone)]
pub struct ModuleCfg<'a> {
    pub dev: &'a DeviceJson,
    pub fidelity: Fidelity,
    pub segment: usize,
    pub ordering: Ordering,
    pub solver: SolverStrategy,
    pub backend: BackendChoice,
    pub workers: usize,
    pub prog_sigma: f64,
}

fn clamp_rails(batch: &mut [Vec<f64>], v_rail: f64) {
    for row in batch.iter_mut() {
        for v in row.iter_mut() {
            *v = v.clamp(-v_rail, v_rail);
        }
    }
}

// ---------------------------------------------------------------------------
// CrossbarModule
// ---------------------------------------------------------------------------

/// A VMM layer realized as differential crossbar hardware (FC / PConv /
/// Conv / DConv). See the module docs for the per-fidelity execution paths.
pub struct CrossbarModule {
    name: String,
    kind: &'static str,
    fidelity: Fidelity,
    workers: usize,
    v_rail: f64,
    /// device-window parameters for lifetime-fault clamping
    r_on: f64,
    g_min: f64,
    /// per-module device-hash stream ([`fault::bank_seed`])
    bank: u64,
    /// last injected step — its (time-invariant) stuck mask is re-applied
    /// after a reprogram, because rewriting cannot heal dead cells
    last_step: Option<FaultStep>,
    /// cumulative mean multiplicative conductance factor since the last
    /// write (1.0 = pristine) — [`AnalogModule::drift_stats`] telemetry
    drift_gain: f64,
    /// fault steps absorbed since the last (re)programming
    fault_steps: u64,
    /// recalibration writes over the module's lifetime
    reprograms: u64,
    /// devices rewritten by the most recent reprogram
    devices_rewritten: usize,
    inner: Inner,
}

enum Inner {
    Fc {
        cb: Crossbar,
        /// as-built conductances, restored by [`AnalogModule::reprogram`]
        pristine: Vec<Placed>,
        /// resident factor-once simulator at `Fidelity::Spice`
        sim: Option<CrossbarSim>,
    },
    Conv(ConvBanks),
}

/// Per-channel-pair crossbar banks of a conv layer. The behavioural path
/// evaluates the banks' transfer directly from the quantized kernels (same
/// arithmetic as `Crossbar::eval_ideal` over `place_conv_kernel`, without
/// materializing one `Placed` per output position); the SPICE path builds
/// real per-bank crossbars.
struct ConvBanks {
    geom: ConvXbarGeom,
    h_in: usize,
    w_in: usize,
    pad: usize,
    cin: usize,
    cout: usize,
    depthwise: bool,
    scale: f64,
    mode: MapMode,
    /// signed quantized kernels: depthwise `c*kk + a`, else
    /// `(co*cin + ci)*kk + a` with `a = kh*k + kw` row-major
    kernels: Vec<f64>,
    /// as-built kernels, restored by the behavioural reprogram path
    kernels_pristine: Vec<f64>,
    /// resident per-bank simulators at `Fidelity::Spice` (zero kernels
    /// place no bank)
    sims: Vec<BankSim>,
}

struct BankSim {
    ci: usize,
    co: usize,
    /// the bank's placed devices, aged in place by fault injection
    devices: Vec<Placed>,
    /// as-built conductances for the reprogram restore
    pristine: Vec<Placed>,
    /// per-bank device-hash stream
    bank: u64,
    sim: CrossbarSim,
}

/// One bank's solve result: (output channel, per-input column reads).
type BankSolve = Result<(usize, Vec<Vec<f64>>)>;

/// Construction parameters for a conv [`CrossbarModule`]
/// (crate-internal; built by the [`super::PipelineBuilder`]).
pub(crate) struct ConvModuleCfg {
    pub name: String,
    pub kind: &'static str,
    pub geom: ConvGeom,
    pub depthwise: bool,
    /// signed quantized kernels in bank layout (see [`ConvBanks::kernels`])
    pub kernels: Vec<f64>,
    pub scale: f64,
    pub mode: MapMode,
    pub fidelity: Fidelity,
    pub segment: usize,
    pub ordering: Ordering,
    pub solver: SolverStrategy,
    pub backend: BackendChoice,
    pub workers: usize,
}

impl ConvBanks {
    fn kk(&self) -> usize {
        self.geom.fr * self.geom.fc
    }

    fn kernel(&self, ci: usize, co: usize) -> &[f64] {
        let kk = self.kk();
        let idx = if self.depthwise { co } else { co * self.cin + ci };
        &self.kernels[idx * kk..(idx + 1) * kk]
    }

    fn ci_range(&self, co: usize) -> std::ops::Range<usize> {
        if self.depthwise {
            co..co + 1
        } else {
            0..self.cin
        }
    }

    /// Zero-pad one channel plane into the crossbar's input region layout.
    fn padded_plane(&self, x: &[f64], ci: usize) -> Vec<f64> {
        let (h, w, pad, wc) = (self.h_in, self.w_in, self.pad, self.geom.wc);
        let mut p = vec![0.0; self.geom.wr * wc];
        for y in 0..h {
            let dst = (y + pad) * wc + pad;
            let src = ci * h * w + y * w;
            p[dst..dst + w].copy_from_slice(&x[src..src + w]);
        }
        p
    }

    /// Ideal transfer of the whole bank set for one input tensor — the
    /// direct-form mirror of summing `Crossbar::eval_ideal` per bank.
    fn forward_ideal(&self, x: &[f64]) -> Vec<f64> {
        let cols = self.geom.cols();
        let (fr, fc, wc, stride, oc) =
            (self.geom.fr, self.geom.fc, self.geom.wc, self.geom.stride, self.geom.oc);
        let planes: Vec<Vec<f64>> =
            (0..self.cin).map(|ci| self.padded_plane(x, ci)).collect();
        let mut out = vec![0.0; self.cout * cols];
        for co in 0..self.cout {
            for ci in self.ci_range(co) {
                let kern = self.kernel(ci, co);
                let plane = &planes[ci];
                for i in 0..cols {
                    let base = p_pos(i, oc, wc, stride);
                    let mut acc = 0.0;
                    for a in 0..fr {
                        for b in 0..fc {
                            let q = kern[a * fc + b];
                            if q != 0.0 {
                                acc += q * plane[base + a * wc + b];
                            }
                        }
                    }
                    out[co * cols + i] += acc * self.scale;
                }
            }
        }
        out
    }

    /// SPICE transfer: every bank answers the whole batch via its resident
    /// simulator's multi-RHS path, accumulated per output channel. Banks
    /// are the shardable leaves: when there are at least as many banks as
    /// workers, whole banks are distributed across the pool (each bank's
    /// solve is one complete analog accumulation); otherwise each bank
    /// keeps its internal per-segment parallelism. Bank contributions are
    /// summed in bank order either way, so the result is bit-identical to
    /// the sequential walk.
    fn forward_spice(&mut self, inputs: &[Vec<f64>], workers: usize) -> Result<Vec<Vec<f64>>> {
        let cols = self.geom.cols();
        let mut out = vec![vec![0.0; self.cout * cols]; inputs.len()];
        // padded planes per batch item, computed once and shared by banks
        let mut planes: Vec<Vec<Vec<f64>>> = Vec::with_capacity(self.cin);
        for ci in 0..self.cin {
            planes.push(inputs.iter().map(|x| self.padded_plane(x, ci)).collect());
        }
        // per bank, in bank order
        let solved: Vec<BankSolve> =
            if workers > 1 && self.sims.len() >= workers {
                let planes = &planes;
                par_map_mut(&mut self.sims, workers, |bank| {
                    Ok((bank.co, bank.sim.solve_batch(&planes[bank.ci], 1)?))
                })
            } else {
                self.sims
                    .iter_mut()
                    .map(|bank| Ok((bank.co, bank.sim.solve_batch(&planes[bank.ci], workers)?)))
                    .collect()
            };
        for res in solved {
            let (co, per_input) = res?;
            for (k, cols_out) in per_input.into_iter().enumerate() {
                let dst = &mut out[k][co * cols..(co + 1) * cols];
                for (d, s) in dst.iter_mut().zip(&cols_out) {
                    *d += s;
                }
            }
        }
        Ok(out)
    }

    /// Independent per-channel(-pair) banks — one leaf each.
    fn n_banks(&self) -> usize {
        if self.depthwise {
            self.cout
        } else {
            self.cin * self.cout
        }
    }

    fn memristors(&self) -> usize {
        let cols = self.geom.cols();
        let kk = self.kk();
        (0..self.n_banks())
            .map(|b| {
                self.kernels[b * kk..(b + 1) * kk]
                    .iter()
                    .filter(|&&v| v != 0.0)
                    .count()
                    * cols
            })
            .sum()
    }
}

impl CrossbarModule {
    /// FC/PConv module over an explicit crossbar (builds the resident
    /// simulator at [`Fidelity::Spice`]).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn fc(
        name: String,
        kind: &'static str,
        cb: Crossbar,
        dev: &DeviceJson,
        fidelity: Fidelity,
        segment: usize,
        ordering: Ordering,
        solver: SolverStrategy,
        backend: BackendChoice,
        workers: usize,
    ) -> Result<CrossbarModule> {
        let sim = match fidelity {
            Fidelity::Spice => {
                let mut sim = CrossbarSim::new(&cb, dev, segment, ordering, solver)?;
                sim.set_backend(backend);
                Some(sim)
            }
            _ => None,
        };
        let bank = fault::bank_seed(&name);
        let pristine = cb.devices.clone();
        Ok(CrossbarModule {
            name,
            kind,
            fidelity,
            workers,
            v_rail: dev.v_rail,
            r_on: dev.r_on,
            g_min: dev.r_on / dev.r_off,
            bank,
            last_step: None,
            drift_gain: 1.0,
            fault_steps: 0,
            reprograms: 0,
            devices_rewritten: 0,
            inner: Inner::Fc { cb, pristine, sim },
        })
    }

    /// Conv/DConv module over per-channel-pair banks.
    pub(crate) fn conv(cfg: ConvModuleCfg, dev: &DeviceJson) -> Result<CrossbarModule> {
        let g = &cfg.geom;
        let geom = ConvXbarGeom::from_conv(g.h_in, g.w_in, g.k, g.stride, g.padding);
        let kk = g.k * g.k;
        let n_banks = if cfg.depthwise { g.cout } else { g.cin * g.cout };
        if cfg.kernels.len() != n_banks * kk {
            bail!(
                "conv '{}': {} kernel values for {} banks of {kk}",
                cfg.name,
                cfg.kernels.len(),
                n_banks
            );
        }
        let mut banks = ConvBanks {
            geom,
            h_in: g.h_in,
            w_in: g.w_in,
            pad: g.padding,
            cin: if cfg.depthwise { g.cout } else { g.cin },
            cout: g.cout,
            depthwise: cfg.depthwise,
            scale: cfg.scale,
            mode: cfg.mode,
            kernels_pristine: cfg.kernels.clone(),
            kernels: cfg.kernels,
            sims: Vec::new(),
        };
        if cfg.fidelity == Fidelity::Spice {
            for co in 0..banks.cout {
                for ci in banks.ci_range(co) {
                    let devices =
                        place_conv_kernel(&geom, banks.kernel(ci, co), cfg.mode.inverted());
                    if devices.is_empty() {
                        continue; // all-zero kernel: contributes nothing
                    }
                    let cb = Crossbar {
                        name: format!("{}_ci{ci}_co{co}", cfg.name),
                        rows: geom.rows(),
                        cols: geom.cols(),
                        region: geom.wr * geom.wc,
                        devices,
                        rf_scale: cfg.scale,
                        mode: cfg.mode,
                    };
                    let mut sim =
                        CrossbarSim::new(&cb, dev, cfg.segment, cfg.ordering, cfg.solver)?;
                    sim.set_backend(cfg.backend);
                    banks.sims.push(BankSim {
                        ci,
                        co,
                        bank: fault::bank_seed(&cb.name),
                        pristine: cb.devices.clone(),
                        devices: cb.devices,
                        sim,
                    });
                }
            }
        }
        Ok(CrossbarModule {
            name: cfg.name.clone(),
            kind: cfg.kind,
            fidelity: cfg.fidelity,
            workers: cfg.workers,
            v_rail: dev.v_rail,
            r_on: dev.r_on,
            g_min: dev.r_on / dev.r_off,
            bank: fault::bank_seed(&cfg.name),
            last_step: None,
            drift_gain: 1.0,
            fault_steps: 0,
            reprograms: 0,
            devices_rewritten: 0,
            inner: Inner::Conv(banks),
        })
    }

    /// The underlying crossbar of an FC/PConv module (None for conv banks).
    pub fn crossbar(&self) -> Option<&Crossbar> {
        match &self.inner {
            Inner::Fc { cb, .. } => Some(cb),
            Inner::Conv(_) => None,
        }
    }
}

impl AnalogModule for CrossbarModule {
    fn name(&self) -> &str {
        &self.name
    }

    fn kind(&self) -> &'static str {
        self.kind
    }

    fn in_dim(&self) -> usize {
        match &self.inner {
            Inner::Fc { cb, .. } => cb.region,
            Inner::Conv(cv) => cv.cin * cv.h_in * cv.w_in,
        }
    }

    fn out_dim(&self) -> usize {
        match &self.inner {
            Inner::Fc { cb, .. } => cb.cols,
            Inner::Conv(cv) => cv.cout * cv.geom.cols(),
        }
    }

    fn forward_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let expect = self.in_dim();
        for (k, x) in inputs.iter().enumerate() {
            if x.len() != expect {
                bail!("'{}': input {k} has {} values, expected {expect}", self.name, x.len());
            }
        }
        let mut out = match (&mut self.inner, self.fidelity) {
            (Inner::Fc { sim: Some(sim), .. }, Fidelity::Spice) => {
                sim.solve_batch(inputs, self.workers)?
            }
            (Inner::Fc { cb, .. }, _) => inputs.iter().map(|v| cb.eval_ideal(v)).collect(),
            (Inner::Conv(cv), Fidelity::Spice) => cv.forward_spice(inputs, self.workers)?,
            (Inner::Conv(cv), _) => inputs.iter().map(|v| cv.forward_ideal(v)).collect(),
        };
        if self.fidelity == Fidelity::Behavioural {
            clamp_rails(&mut out, self.v_rail);
        }
        Ok(out)
    }

    fn memristors(&self) -> usize {
        match &self.inner {
            Inner::Fc { cb, .. } => cb.devices.len(),
            Inner::Conv(cv) => cv.memristors(),
        }
    }

    fn opamps(&self) -> usize {
        match &self.inner {
            Inner::Fc { cb, .. } => cb.cols * cb.mode.opamps_per_port(),
            Inner::Conv(cv) => cv.geom.cols() * cv.cout * cv.mode.opamps_per_port(),
        }
    }

    fn memristor_stages(&self) -> usize {
        1
    }

    fn shardable_leaves(&self) -> usize {
        match &self.inner {
            Inner::Fc { .. } => 1,
            Inner::Conv(cv) => cv.n_banks().max(1),
        }
    }

    fn spice_circuits(&self) -> usize {
        match &self.inner {
            Inner::Fc { sim, .. } => usize::from(sim.is_some()),
            Inner::Conv(cv) => cv.sims.len(),
        }
    }

    fn spice_decks(&self) -> Vec<crate::netlist::interchange::Deck> {
        match &self.inner {
            Inner::Fc { sim: Some(sim), .. } => sim.decks(&self.name),
            Inner::Fc { .. } => Vec::new(),
            Inner::Conv(cv) => cv
                .sims
                .iter()
                .flat_map(|b| b.sim.decks(&format!("{}_ci{}co{}", self.name, b.ci, b.co)))
                .collect(),
        }
    }

    fn inject_faults(&mut self, step: &FaultStep) {
        self.last_step = Some(*step);
        self.fault_steps += 1;
        match &mut self.inner {
            Inner::Fc { cb, sim, pristine } => {
                let g0: Vec<f64> = pristine.iter().map(|p| p.g_norm).collect();
                let f = fault::apply_step_from(
                    step,
                    self.bank,
                    &mut cb.devices,
                    Some(&g0),
                    self.g_min,
                );
                self.drift_gain *= f;
                if let Some(sim) = sim {
                    sim.update_conductances(&cb.devices, self.r_on);
                }
            }
            Inner::Conv(cv) => {
                if cv.sims.is_empty() {
                    fault::apply_step_signed_from(
                        step,
                        self.bank,
                        &mut cv.kernels,
                        Some(&cv.kernels_pristine),
                    );
                    self.drift_gain *= step.mean_decay();
                } else {
                    let (mut wsum, mut fsum) = (0.0, 0.0);
                    for b in cv.sims.iter_mut() {
                        let g0: Vec<f64> = b.pristine.iter().map(|p| p.g_norm).collect();
                        let f = fault::apply_step_from(
                            step,
                            b.bank,
                            &mut b.devices,
                            Some(&g0),
                            self.g_min,
                        );
                        let w = b.devices.len() as f64;
                        wsum += w;
                        fsum += w * f;
                        b.sim.update_conductances(&b.devices, self.r_on);
                    }
                    if wsum > 0.0 {
                        self.drift_gain *= fsum / wsum;
                    }
                }
            }
        }
    }

    fn reprogram(&mut self, prog_sigma: f64, seed: u64, generation: u64) -> usize {
        let stuck = self.last_step.map(|s| s.stuck_only());
        let rewritten = match &mut self.inner {
            Inner::Fc { cb, sim, pristine } => {
                cb.devices.clone_from(pristine);
                fault::reprogram_noise(&mut cb.devices, prog_sigma, seed, self.bank, generation);
                if let Some(s) = &stuck {
                    fault::apply_step(s, self.bank, &mut cb.devices, self.g_min);
                }
                if let Some(sim) = sim {
                    sim.update_conductances(&cb.devices, self.r_on);
                }
                cb.devices.len()
            }
            Inner::Conv(cv) => {
                if cv.sims.is_empty() {
                    cv.kernels.clone_from(&cv.kernels_pristine);
                    if let Some(s) = &stuck {
                        fault::apply_step_signed(s, self.bank, &mut cv.kernels);
                    }
                    cv.kernels.iter().filter(|&&k| k != 0.0).count()
                } else {
                    let mut rewritten = 0;
                    for b in cv.sims.iter_mut() {
                        b.devices.clone_from(&b.pristine);
                        fault::reprogram_noise(
                            &mut b.devices,
                            prog_sigma,
                            seed,
                            b.bank,
                            generation,
                        );
                        if let Some(s) = &stuck {
                            fault::apply_step(s, b.bank, &mut b.devices, self.g_min);
                        }
                        rewritten += b.devices.len();
                        b.sim.update_conductances(&b.devices, self.r_on);
                    }
                    rewritten
                }
            }
        };
        self.drift_gain = 1.0;
        self.fault_steps = 0;
        self.reprograms += 1;
        self.devices_rewritten = rewritten;
        rewritten
    }

    fn drift_stats(&self) -> Option<ModuleDrift> {
        Some(ModuleDrift {
            name: self.name.clone(),
            kind: self.kind,
            drift_gain: self.drift_gain,
            fault_steps: self.fault_steps,
            reprograms: self.reprograms,
            devices_rewritten: self.devices_rewritten,
        })
    }
}

// ---------------------------------------------------------------------------
// BatchNormModule
// ---------------------------------------------------------------------------

/// Folded batch normalization: `y = (x - mean) * k + beta` per channel with
/// `k = gamma / sqrt(var + BN_EPS)` ([`BnFold`]). At [`Fidelity::Ideal`] /
/// [`Fidelity::Behavioural`] the exact affine fold is evaluated directly
/// (rail-clipped at behavioural); at [`Fidelity::Spice`] the module owns
/// the §3.3 circuit as a resident per-channel netlist pair — the
/// subtraction crossbar feeding the scale/offset conductance pairs
/// ([`analog::build_bn_crossbars`], gain-balanced across the cascade),
/// each a factor-once [`CrossbarSim`] with the builder's device config,
/// programming noise and [`SolverStrategy`] applied; spatial positions
/// and batch items are folded into one multi-RHS solve per stage.
pub struct BatchNormModule {
    name: String,
    c: usize,
    /// elements per channel (h*w for spatial tensors, 1 for vectors)
    spatial: usize,
    fold: BnFold,
    fidelity: Fidelity,
    v_rail: f64,
    workers: usize,
    /// Eq 10/11 closed-form counts (non-spice fidelities)
    formula_memristors: usize,
    formula_opamps: usize,
    /// device-window parameters for lifetime-fault clamping
    r_on: f64,
    g_min: f64,
    bank: u64,
    /// cumulative drift factor across the cascade: below spice the
    /// population-mean approximation squared per step (two cascaded
    /// crossbar stages, no per-device state — applied to the outputs),
    /// at spice the product of the per-stage mean applied factors
    /// (telemetry only; the aged conductances carry the physics)
    drift_gain: f64,
    /// fault steps absorbed since the last (re)programming
    fault_steps: u64,
    /// recalibration writes over the module's lifetime
    reprograms: u64,
    /// devices rewritten by the most recent reprogram
    devices_rewritten: usize,
    last_step: Option<FaultStep>,
    sims: Option<BnSims>,
}

/// Resident §3.3 netlist pair plus the counts of what was actually emitted.
struct BnSims {
    sub: CrossbarSim,
    scale: CrossbarSim,
    /// placed devices of the two stages, aged in place + their as-built
    /// copies for the reprogram restore
    sub_devices: Vec<Placed>,
    sub_pristine: Vec<Placed>,
    scale_devices: Vec<Placed>,
    scale_pristine: Vec<Placed>,
    memristors: usize,
    opamps: usize,
}

impl BatchNormModule {
    pub fn new(
        name: impl Into<String>,
        c: usize,
        spatial: usize,
        fold: BnFold,
        mode: MapMode,
        cfg: &ModuleCfg,
        rng: &mut Rng,
    ) -> Result<BatchNormModule> {
        let name = name.into();
        for (label, t) in [("k", &fold.k), ("mean", &fold.mean), ("beta", &fold.beta)] {
            if t.len() != c {
                bail!("bn '{name}': {label} has {} values for {c} channels", t.len());
            }
        }
        let sims = if cfg.fidelity == Fidelity::Spice {
            // the per-channel §3.3 circuit pair — exactly the Eq 10/11
            // hardware (4 devices / 2 TIAs per channel). Spatial positions
            // and batch items are folded into the multi-RHS solve at
            // forward time, so the netlist stays c columns regardless of
            // the feature-map size (a per-element unrolling would emit
            // c*spatial-column crossbars and make real-network spice
            // builds intractable).
            let (mut sub, mut scale) =
                analog::build_bn_crossbars(&name, c, 1, &fold.k, &fold.mean, &fold.beta, mode);
            apply_prog_noise_analog(&mut sub.devices, cfg.prog_sigma, rng);
            apply_prog_noise_analog(&mut scale.devices, cfg.prog_sigma, rng);
            let mut sub_sim =
                CrossbarSim::new(&sub, cfg.dev, cfg.segment, cfg.ordering, cfg.solver)?;
            sub_sim.set_backend(cfg.backend);
            let mut scale_sim =
                CrossbarSim::new(&scale, cfg.dev, cfg.segment, cfg.ordering, cfg.solver)?;
            scale_sim.set_backend(cfg.backend);
            Some(BnSims {
                memristors: sub.devices.len() + scale.devices.len(),
                opamps: (sub.cols + scale.cols) * mode.opamps_per_port(),
                sub: sub_sim,
                scale: scale_sim,
                sub_pristine: sub.devices.clone(),
                sub_devices: sub.devices,
                scale_pristine: scale.devices.clone(),
                scale_devices: scale.devices,
            })
        } else {
            None
        };
        Ok(BatchNormModule {
            name: name.clone(),
            c,
            spatial,
            fold,
            fidelity: cfg.fidelity,
            v_rail: cfg.dev.v_rail,
            workers: cfg.workers,
            formula_memristors: 4 * c,
            formula_opamps: 2 * c * mode.opamps_per_port(),
            r_on: cfg.dev.r_on,
            g_min: cfg.dev.r_on / cfg.dev.r_off,
            bank: fault::bank_seed(&name),
            drift_gain: 1.0,
            fault_steps: 0,
            reprograms: 0,
            devices_rewritten: 0,
            last_step: None,
            sims,
        })
    }
}

impl AnalogModule for BatchNormModule {
    fn name(&self) -> &str {
        &self.name
    }

    fn kind(&self) -> &'static str {
        "BN"
    }

    fn in_dim(&self) -> usize {
        self.c * self.spatial
    }

    fn out_dim(&self) -> usize {
        self.c * self.spatial
    }

    fn forward_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let expect = self.in_dim();
        for (n, x) in inputs.iter().enumerate() {
            if x.len() != expect {
                bail!("'{}': input {n} has {} values, expected {expect}", self.name, x.len());
            }
        }
        if let Some(sims) = self.sims.as_mut() {
            // §3.3 per-channel circuit chain: every (batch item, spatial
            // position) pair is one RHS column of the c-input netlists —
            // subtraction stage, then scale/offset stage
            let (c, spatial) = (self.c, self.spatial);
            let rhs: Vec<Vec<f64>> = inputs
                .iter()
                .flat_map(|x| {
                    (0..spatial)
                        .map(move |s| (0..c).map(|ch| x[ch * spatial + s]).collect())
                })
                .collect();
            let u = sims.sub.solve_batch(&rhs, self.workers)?;
            let y = sims.scale.solve_batch(&u, self.workers)?;
            return Ok((0..inputs.len())
                .map(|b| {
                    let mut row = vec![0.0; c * spatial];
                    for s in 0..spatial {
                        let col = &y[b * spatial + s];
                        for ch in 0..c {
                            row[ch * spatial + s] = col[ch];
                        }
                    }
                    row
                })
                .collect());
        }
        let mut out = Vec::with_capacity(inputs.len());
        for x in inputs {
            let mut y = vec![0.0; expect];
            for ch in 0..self.c {
                let (k, m, b) = (self.fold.k[ch], self.fold.mean[ch], self.fold.beta[ch]);
                for s in 0..self.spatial {
                    y[ch * self.spatial + s] = (x[ch * self.spatial + s] - m) * k + b;
                }
            }
            out.push(y);
        }
        if self.drift_gain != 1.0 {
            // coverage-matrix approximation of device aging below spice:
            // the population-mean decay of the two cascaded §3.3 stages
            for row in &mut out {
                for v in row.iter_mut() {
                    *v *= self.drift_gain;
                }
            }
        }
        if self.fidelity == Fidelity::Behavioural {
            clamp_rails(&mut out, self.v_rail);
        }
        Ok(out)
    }

    fn memristors(&self) -> usize {
        // Eq 10 closed form, or the emitted §3.3 netlist pair at spice
        self.sims.as_ref().map_or(self.formula_memristors, |s| s.memristors)
    }

    fn opamps(&self) -> usize {
        // Eq 11 closed form, or one TIA per emitted column at spice
        self.sims.as_ref().map_or(self.formula_opamps, |s| s.opamps)
    }

    fn memristor_stages(&self) -> usize {
        // the emitted circuit is two cascaded crossbar+TIA stages
        if self.sims.is_some() {
            2
        } else {
            1
        }
    }

    fn spice_circuits(&self) -> usize {
        if self.sims.is_some() {
            2
        } else {
            0
        }
    }

    fn spice_decks(&self) -> Vec<crate::netlist::interchange::Deck> {
        match &self.sims {
            Some(sims) => {
                let mut decks = sims.sub.decks(&format!("{}.sub", self.name));
                decks.extend(sims.scale.decks(&format!("{}.scale", self.name)));
                decks
            }
            None => Vec::new(),
        }
    }

    fn inject_faults(&mut self, step: &FaultStep) {
        self.last_step = Some(*step);
        self.fault_steps += 1;
        if let Some(sims) = self.sims.as_mut() {
            let g0: Vec<f64> = sims.sub_pristine.iter().map(|p| p.g_norm).collect();
            let f_sub = fault::apply_step_from(
                step,
                self.bank.wrapping_add(1),
                &mut sims.sub_devices,
                Some(&g0),
                self.g_min,
            );
            let g0: Vec<f64> = sims.scale_pristine.iter().map(|p| p.g_norm).collect();
            let f_scale = fault::apply_step_from(
                step,
                self.bank.wrapping_add(2),
                &mut sims.scale_devices,
                Some(&g0),
                self.g_min,
            );
            sims.sub.update_conductances(&sims.sub_devices, self.r_on);
            sims.scale.update_conductances(&sims.scale_devices, self.r_on);
            self.drift_gain *= f_sub * f_scale;
        } else {
            // two cascaded crossbar stages -> the mean decay compounds twice
            let d = step.mean_decay();
            self.drift_gain *= d * d;
        }
    }

    fn reprogram(&mut self, prog_sigma: f64, seed: u64, generation: u64) -> usize {
        let stuck = self.last_step.map(|s| s.stuck_only());
        let rewritten = if let Some(sims) = self.sims.as_mut() {
            sims.sub_devices.clone_from(&sims.sub_pristine);
            sims.scale_devices.clone_from(&sims.scale_pristine);
            fault::reprogram_noise(
                &mut sims.sub_devices,
                prog_sigma,
                seed,
                self.bank.wrapping_add(1),
                generation,
            );
            fault::reprogram_noise(
                &mut sims.scale_devices,
                prog_sigma,
                seed,
                self.bank.wrapping_add(2),
                generation,
            );
            if let Some(stuck) = stuck {
                fault::apply_step(
                    &stuck,
                    self.bank.wrapping_add(1),
                    &mut sims.sub_devices,
                    self.g_min,
                );
                fault::apply_step(
                    &stuck,
                    self.bank.wrapping_add(2),
                    &mut sims.scale_devices,
                    self.g_min,
                );
            }
            sims.sub.update_conductances(&sims.sub_devices, self.r_on);
            sims.scale.update_conductances(&sims.scale_devices, self.r_on);
            sims.sub_devices.len() + sims.scale_devices.len()
        } else {
            self.formula_memristors
        };
        self.drift_gain = 1.0;
        self.fault_steps = 0;
        self.reprograms += 1;
        self.devices_rewritten = rewritten;
        rewritten
    }

    fn drift_stats(&self) -> Option<ModuleDrift> {
        Some(ModuleDrift {
            name: self.name.clone(),
            kind: "BN",
            drift_gain: self.drift_gain,
            fault_steps: self.fault_steps,
            reprograms: self.reprograms,
            devices_rewritten: self.devices_rewritten,
        })
    }
}

// ---------------------------------------------------------------------------
// ActivationModule
// ---------------------------------------------------------------------------

/// Elementwise activation: software forms at [`Fidelity::Ideal`],
/// rail-clipped analog forms at [`Fidelity::Behavioural`], and the Fig 4
/// op-amp circuits ([`ActCircuit`]) at [`Fidelity::Spice`] (ReLU stays
/// behavioural — the paper realizes it in CMOS, not op-amps). SPICE
/// evaluation splits the batch's elements across `workers` circuit clones.
pub struct ActivationModule {
    name: String,
    act: ActKind,
    /// full vector length (c * spatial)
    dim: usize,
    fidelity: Fidelity,
    v_rail: f64,
    workers: usize,
    circuit: Option<ActCircuit>,
    opamps: usize,
}

impl ActivationModule {
    pub fn new(
        name: impl Into<String>,
        act: ActKind,
        c: usize,
        spatial: usize,
        fidelity: Fidelity,
        v_rail: f64,
        workers: usize,
    ) -> ActivationModule {
        let mut circuit = match (fidelity, act) {
            (Fidelity::Spice, ActKind::HSigmoid) => Some(analog::build_hard_sigmoid()),
            (Fidelity::Spice, ActKind::HSwish) => Some(analog::build_hard_swish()),
            _ => None,
        };
        if let Some(c) = circuit.as_mut() {
            // prime the factor cache once: per-worker clones inherit the
            // ready factorization, so batch evals are pure cached re-solves
            let _ = c.eval(0.0);
        }
        // Fig 4 op-amp budget (mapper mirror): adder+divider+limiter per
        // module for hard sigmoid, plus the per-channel multiplier branch
        // for hard swish; CMOS ReLU uses none
        let opamps = match act {
            ActKind::HSigmoid => 4,
            ActKind::HSwish => 4 * c,
            ActKind::Relu => 0,
        };
        ActivationModule {
            name: name.into(),
            act,
            dim: c * spatial,
            fidelity,
            v_rail,
            workers,
            circuit,
            opamps,
        }
    }

    /// Fast scalar paths (everything except the SPICE circuits).
    fn scalar(&self, v: f64) -> f64 {
        match (self.fidelity, self.act) {
            (Fidelity::Ideal, ActKind::Relu) => v.max(0.0),
            (Fidelity::Ideal, ActKind::HSigmoid) => analog::hard_sigmoid_sw(v),
            (Fidelity::Ideal, ActKind::HSwish) => analog::hard_swish_sw(v),
            (Fidelity::Behavioural, ActKind::HSigmoid) => {
                analog::hard_sigmoid_analog(v, self.v_rail)
            }
            (Fidelity::Behavioural, ActKind::HSwish) => {
                analog::hard_swish_analog(v, self.v_rail)
            }
            (Fidelity::Behavioural | Fidelity::Spice, ActKind::Relu) => {
                analog::relu_analog(v, self.v_rail)
            }
            (Fidelity::Spice, _) => unreachable!("SPICE activations route through forward_spice"),
        }
    }

    /// Drive every element of the batch through the Fig 4 circuit, elements
    /// split across `workers` independent circuit clones (each clone's
    /// factor cache makes its per-element Newton solves RHS-only re-solves).
    fn forward_spice(&self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        let base = self.circuit.as_ref().expect("spice activation circuit built");
        let flat: Vec<f64> = inputs.iter().flat_map(|x| x.iter().copied()).collect();
        if flat.is_empty() {
            return Ok(inputs.iter().map(|_| Vec::new()).collect());
        }
        let workers = self.workers.max(1);
        let chunk = flat.len().div_ceil(workers);
        let mut jobs: Vec<(ActCircuit, Vec<f64>)> = flat
            .chunks(chunk)
            .map(|vals| (base.clone(), vals.to_vec()))
            .collect();
        let solved = par_map_mut(&mut jobs, workers, |(circuit, vals)| -> Result<Vec<f64>> {
            vals.iter().map(|&v| circuit.eval(v)).collect()
        });
        let mut flat_out = Vec::with_capacity(flat.len());
        for r in solved {
            flat_out.extend(r?);
        }
        Ok(flat_out.chunks(self.dim).map(|c| c.to_vec()).collect())
    }
}

impl AnalogModule for ActivationModule {
    fn name(&self) -> &str {
        &self.name
    }

    fn kind(&self) -> &'static str {
        match self.act {
            ActKind::Relu => "ReLU",
            ActKind::HSwish => "HSwish",
            ActKind::HSigmoid => "HSigmoid",
        }
    }

    fn in_dim(&self) -> usize {
        self.dim
    }

    fn out_dim(&self) -> usize {
        self.dim
    }

    fn forward_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        for (n, x) in inputs.iter().enumerate() {
            if x.len() != self.dim {
                bail!("'{}': input {n} has {} values, expected {}", self.name, x.len(), self.dim);
            }
        }
        if self.fidelity == Fidelity::Spice && self.act != ActKind::Relu {
            return self.forward_spice(inputs);
        }
        Ok(inputs
            .iter()
            .map(|x| x.iter().map(|&v| self.scalar(v)).collect())
            .collect())
    }

    fn opamps(&self) -> usize {
        self.opamps
    }

    fn spice_circuits(&self) -> usize {
        // CMOS ReLU stays behavioural by design, so it holds no circuit
        usize::from(self.circuit.is_some())
    }

    fn spice_decks(&self) -> Vec<crate::netlist::interchange::Deck> {
        let Some(ac) = &self.circuit else { return Vec::new() };
        let names = ac.circuit.node_names();
        let input = ac.circuit.elements.iter().find_map(|e| match e {
            crate::spice::Element::Vsource(n, a, _, _) if *n == ac.vin_name => {
                Some(names[*a].clone())
            }
            _ => None,
        });
        vec![crate::netlist::interchange::Deck {
            name: format!("{}.act", self.name),
            circuit: ac.circuit.clone(),
            inputs: input.into_iter().collect(),
            outputs: vec![ac.out_node.clone()],
        }]
    }

    fn cmos_elements(&self) -> usize {
        // every element passes through its own activation instance
        self.dim
    }
}

// ---------------------------------------------------------------------------
// GapModule
// ---------------------------------------------------------------------------

/// Global average pooling: the §3.5 averaging column — one crossbar column
/// per channel with `1/N` conductances into the op-amp summing node. The
/// exact transfer is the per-channel mean, evaluated directly at
/// [`Fidelity::Ideal`] / [`Fidelity::Behavioural`]; at [`Fidelity::Spice`]
/// the module owns the emitted column netlist
/// ([`analog::build_gap_crossbar`]) as a resident factor-once
/// [`CrossbarSim`] with the builder's device config, programming noise and
/// [`SolverStrategy`] applied.
pub struct GapModule {
    name: String,
    c: usize,
    h: usize,
    w: usize,
    workers: usize,
    /// placed averaging conductances (netlist-derived at spice; the count
    /// coincides with Eq 12's `h*w*c`)
    memristors: usize,
    opamps: usize,
    r_on: f64,
    g_min: f64,
    bank: u64,
    /// cumulative drift factor: population-mean approximation below spice
    /// (one stage, applied to the outputs), mean applied conductance factor
    /// at spice (telemetry only)
    drift_gain: f64,
    /// fault steps absorbed since the last (re)programming
    fault_steps: u64,
    /// recalibration writes over the module's lifetime
    reprograms: u64,
    /// devices rewritten by the most recent reprogram
    devices_rewritten: usize,
    last_step: Option<FaultStep>,
    /// aged + as-built averaging devices (empty below spice)
    devices: Vec<Placed>,
    pristine: Vec<Placed>,
    sim: Option<CrossbarSim>,
}

impl GapModule {
    pub fn new(
        name: impl Into<String>,
        c: usize,
        h: usize,
        w: usize,
        mode: MapMode,
        cfg: &ModuleCfg,
        rng: &mut Rng,
    ) -> Result<GapModule> {
        let name = name.into();
        let spatial = h * w;
        let (sim, devices, memristors) = if cfg.fidelity == Fidelity::Spice {
            let mut cb = analog::build_gap_crossbar(&name, c, spatial, mode);
            apply_prog_noise_analog(&mut cb.devices, cfg.prog_sigma, rng);
            let placed = cb.devices.len();
            let mut sim = CrossbarSim::new(&cb, cfg.dev, cfg.segment, cfg.ordering, cfg.solver)?;
            sim.set_backend(cfg.backend);
            (Some(sim), cb.devices, placed)
        } else {
            (None, Vec::new(), spatial * c) // Eq 12
        };
        Ok(GapModule {
            name: name.clone(),
            c,
            h,
            w,
            workers: cfg.workers,
            memristors,
            opamps: c * mode.opamps_per_port(), // Eq 13 == one TIA per emitted column
            r_on: cfg.dev.r_on,
            g_min: cfg.dev.r_on / cfg.dev.r_off,
            bank: fault::bank_seed(&name),
            drift_gain: 1.0,
            fault_steps: 0,
            reprograms: 0,
            devices_rewritten: 0,
            last_step: None,
            pristine: devices.clone(),
            devices,
            sim,
        })
    }
}

impl AnalogModule for GapModule {
    fn name(&self) -> &str {
        &self.name
    }

    fn kind(&self) -> &'static str {
        "GAPool"
    }

    fn in_dim(&self) -> usize {
        self.c * self.h * self.w
    }

    fn out_dim(&self) -> usize {
        self.c
    }

    fn forward_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let spatial = self.h * self.w;
        let expect = self.c * spatial;
        for (n, x) in inputs.iter().enumerate() {
            if x.len() != expect {
                bail!("'{}': input {n} has {} values, expected {expect}", self.name, x.len());
            }
        }
        if let Some(sim) = self.sim.as_mut() {
            return sim.solve_batch(inputs, self.workers);
        }
        let gain = self.drift_gain;
        Ok(inputs
            .iter()
            .map(|x| {
                (0..self.c)
                    .map(|ch| {
                        gain * x[ch * spatial..(ch + 1) * spatial].iter().sum::<f64>()
                            / spatial as f64
                    })
                    .collect()
            })
            .collect())
    }

    fn memristors(&self) -> usize {
        self.memristors
    }

    fn opamps(&self) -> usize {
        self.opamps // Eq 13
    }

    fn memristor_stages(&self) -> usize {
        1
    }

    fn spice_circuits(&self) -> usize {
        usize::from(self.sim.is_some())
    }

    fn spice_decks(&self) -> Vec<crate::netlist::interchange::Deck> {
        self.sim.as_ref().map_or_else(Vec::new, |sim| sim.decks(&self.name))
    }

    fn inject_faults(&mut self, step: &FaultStep) {
        self.last_step = Some(*step);
        self.fault_steps += 1;
        if let Some(sim) = self.sim.as_mut() {
            let g0: Vec<f64> = self.pristine.iter().map(|p| p.g_norm).collect();
            let f =
                fault::apply_step_from(step, self.bank, &mut self.devices, Some(&g0), self.g_min);
            self.drift_gain *= f;
            sim.update_conductances(&self.devices, self.r_on);
        } else {
            self.drift_gain *= step.mean_decay();
        }
    }

    fn reprogram(&mut self, prog_sigma: f64, seed: u64, generation: u64) -> usize {
        let stuck = self.last_step.map(|s| s.stuck_only());
        let rewritten = if let Some(sim) = self.sim.as_mut() {
            self.devices.clone_from(&self.pristine);
            fault::reprogram_noise(&mut self.devices, prog_sigma, seed, self.bank, generation);
            if let Some(stuck) = stuck {
                fault::apply_step(&stuck, self.bank, &mut self.devices, self.g_min);
            }
            sim.update_conductances(&self.devices, self.r_on);
            self.devices.len()
        } else {
            self.memristors
        };
        self.drift_gain = 1.0;
        self.fault_steps = 0;
        self.reprograms += 1;
        self.devices_rewritten = rewritten;
        rewritten
    }

    fn drift_stats(&self) -> Option<ModuleDrift> {
        Some(ModuleDrift {
            name: self.name.clone(),
            kind: "GAPool",
            drift_gain: self.drift_gain,
            fault_steps: self.fault_steps,
            reprograms: self.reprograms,
            devices_rewritten: self.devices_rewritten,
        })
    }
}

// ---------------------------------------------------------------------------
// SeModule
// ---------------------------------------------------------------------------

/// Squeeze-and-excite side branch (pool → FC → ReLU → FC → hard sigmoid →
/// per-channel scale). The trunk tensor passes through scaled by the
/// branch's channel gains — the implicit multiply the manifest's layer list
/// leaves between `*.se.act2` and the projection conv.
pub struct SeModule {
    name: String,
    c: usize,
    spatial: usize,
    gap: GapModule,
    fc1: CrossbarModule,
    act1: ActivationModule,
    fc2: CrossbarModule,
    act2: ActivationModule,
}

impl SeModule {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: impl Into<String>,
        c: usize,
        spatial: usize,
        gap: GapModule,
        fc1: CrossbarModule,
        act1: ActivationModule,
        fc2: CrossbarModule,
        act2: ActivationModule,
    ) -> Result<SeModule> {
        let name = name.into();
        if gap.out_dim() != fc1.in_dim()
            || fc1.out_dim() != act1.in_dim()
            || act1.out_dim() != fc2.in_dim()
            || fc2.out_dim() != c
        {
            bail!(
                "se '{name}': branch dims {}->{}->{}->{}->{} do not chain back to {c} channels",
                gap.out_dim(),
                fc1.in_dim(),
                fc1.out_dim(),
                fc2.in_dim(),
                fc2.out_dim()
            );
        }
        Ok(SeModule { name, c, spatial, gap, fc1, act1, fc2, act2 })
    }
}

impl AnalogModule for SeModule {
    fn name(&self) -> &str {
        &self.name
    }

    fn kind(&self) -> &'static str {
        "SE"
    }

    fn in_dim(&self) -> usize {
        self.c * self.spatial
    }

    fn out_dim(&self) -> usize {
        self.c * self.spatial
    }

    fn forward_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let pooled = self.gap.forward_batch(inputs)?;
        let h = self.fc1.forward_batch(&pooled)?;
        let h = self.act1.forward_batch(&h)?;
        let h = self.fc2.forward_batch(&h)?;
        let gains = self.act2.forward_batch(&h)?;
        let mut out = inputs.to_vec();
        for (y, g) in out.iter_mut().zip(&gains) {
            for ch in 0..self.c {
                for s in 0..self.spatial {
                    y[ch * self.spatial + s] *= g[ch];
                }
            }
        }
        Ok(out)
    }

    fn memristors(&self) -> usize {
        self.gap.memristors() + self.fc1.memristors() + self.fc2.memristors()
    }

    fn opamps(&self) -> usize {
        self.gap.opamps()
            + self.fc1.opamps()
            + self.act1.opamps()
            + self.fc2.opamps()
            + self.act2.opamps()
    }

    fn memristor_stages(&self) -> usize {
        self.gap.memristor_stages()
            + self.fc1.memristor_stages()
            + self.fc2.memristor_stages()
    }

    fn shardable_leaves(&self) -> usize {
        // the side branch's five sub-modules are each a complete analog
        // accumulation the scheduler may place independently of the trunk
        self.gap.shardable_leaves()
            + self.fc1.shardable_leaves()
            + self.act1.shardable_leaves()
            + self.fc2.shardable_leaves()
            + self.act2.shardable_leaves()
    }

    fn spice_circuits(&self) -> usize {
        self.gap.spice_circuits()
            + self.fc1.spice_circuits()
            + self.act1.spice_circuits()
            + self.fc2.spice_circuits()
            + self.act2.spice_circuits()
    }

    fn spice_decks(&self) -> Vec<crate::netlist::interchange::Deck> {
        let mut decks = self.gap.spice_decks();
        decks.extend(self.fc1.spice_decks());
        decks.extend(self.act1.spice_decks());
        decks.extend(self.fc2.spice_decks());
        decks.extend(self.act2.spice_decks());
        decks
    }

    fn cmos_elements(&self) -> usize {
        // the squeezed branch activations plus one trunk multiplier per
        // channel (the implicit per-channel scale) — NOT the full trunk
        // tensor: the c*spatial elements only pass through multipliers
        // channel-wise
        self.act1.cmos_elements() + self.act2.cmos_elements() + self.c
    }

    fn inject_faults(&mut self, step: &FaultStep) {
        // activations are op-amp/CMOS circuits — no memristor state to age
        self.gap.inject_faults(step);
        self.fc1.inject_faults(step);
        self.fc2.inject_faults(step);
    }

    fn reprogram(&mut self, prog_sigma: f64, seed: u64, generation: u64) -> usize {
        self.gap.reprogram(prog_sigma, seed, generation)
            + self.fc1.reprogram(prog_sigma, seed, generation)
            + self.fc2.reprogram(prog_sigma, seed, generation)
    }

    fn drift_stats(&self) -> Option<ModuleDrift> {
        // one merged record for the branch: device-weighted mean of the
        // sub-module gains, maxes for the (lock-stepped) counters
        let parts = [
            (self.gap.memristors(), self.gap.drift_stats()),
            (self.fc1.memristors(), self.fc1.drift_stats()),
            (self.fc2.memristors(), self.fc2.drift_stats()),
        ];
        let (mut wsum, mut gsum) = (0.0, 0.0);
        let (mut steps, mut reps, mut devs) = (0u64, 0u64, 0usize);
        for (w, s) in parts {
            let Some(s) = s else { continue };
            let w = w.max(1) as f64;
            wsum += w;
            gsum += w * s.drift_gain;
            steps = steps.max(s.fault_steps);
            reps = reps.max(s.reprograms);
            devs += s.devices_rewritten;
        }
        if wsum == 0.0 {
            return None;
        }
        Some(ModuleDrift {
            name: self.name.clone(),
            kind: "SE",
            drift_gain: gsum / wsum,
            fault_steps: steps,
            reprograms: reps,
            devices_rewritten: devs,
        })
    }
}
