//! Network IR — the typed view of `artifacts/manifest.json`.
//!
//! The python side (python/compile/model.py::build_manifest) emits a
//! per-unit layer inventory mirroring the paper's Table 4; this module
//! parses it into [`Manifest`] / [`Layer`] and loads the raw weight tensors
//! from weights.bin into a [`WeightStore`]. The mapper (Eqs 1-15), the
//! power models (Eqs 17-18) and the report generators all consume this IR.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::bin;
use crate::util::json::Json;

pub mod tensor;
pub use tensor::Tensor;

/// One sublayer, as listed in Table 4 (Conv / BN / HSwish / DConv / GAPool /
/// PConv / HSigmoid / ReLU / FC / residual adder).
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    Conv(ConvGeom),
    DwConv(ConvGeom),
    /// 1x1 attention convs inside SE (the paper's "PConv"): pure VMM.
    PConv { name: String, unit: String, cin: usize, cout: usize, weight: String },
    Bn { name: String, unit: String, c: usize, weight: String },
    Act { name: String, unit: String, kind: ActKind, c: usize },
    GaPool { name: String, unit: String, c: usize, h_in: usize, w_in: usize },
    Fc { name: String, unit: String, cin: usize, cout: usize, weight: String },
    Residual { name: String, unit: String, c: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActKind {
    Relu,
    HSwish,
    HSigmoid,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ConvGeom {
    pub name: String,
    pub unit: String,
    pub k: usize,
    pub stride: usize,
    pub padding: usize,
    pub cin: usize,
    pub cout: usize,
    pub h_in: usize,
    pub w_in: usize,
    pub h_out: usize,
    pub w_out: usize,
    pub weight: String,
}

impl ConvGeom {
    /// Eq 1: O = (W + 2P - F)/S + 1, both spatial dims. (Padding added
    /// before the kernel subtraction — W < F alone is legal when padding
    /// covers it, and usize must not underflow.)
    pub fn check_geometry(&self) -> Result<()> {
        let o = |w: usize| (w + 2 * self.padding - self.k) / self.stride + 1;
        if o(self.h_in) != self.h_out || o(self.w_in) != self.w_out {
            bail!("conv {} violates Eq 1", self.name);
        }
        Ok(())
    }
}

impl Layer {
    pub fn name(&self) -> &str {
        match self {
            Layer::Conv(g) | Layer::DwConv(g) => &g.name,
            Layer::PConv { name, .. }
            | Layer::Bn { name, .. }
            | Layer::Act { name, .. }
            | Layer::GaPool { name, .. }
            | Layer::Fc { name, .. }
            | Layer::Residual { name, .. } => name,
        }
    }

    pub fn unit(&self) -> &str {
        match self {
            Layer::Conv(g) | Layer::DwConv(g) => &g.unit,
            Layer::PConv { unit, .. }
            | Layer::Bn { unit, .. }
            | Layer::Act { unit, .. }
            | Layer::GaPool { unit, .. }
            | Layer::Fc { unit, .. }
            | Layer::Residual { unit, .. } => unit,
        }
    }

    /// Table 4 "Layer" column name.
    pub fn kind_label(&self) -> &'static str {
        match self {
            Layer::Conv(_) => "Conv",
            Layer::DwConv(_) => "DConv",
            Layer::PConv { .. } => "PConv",
            Layer::Bn { .. } => "BN",
            Layer::Act { kind: ActKind::Relu, .. } => "ReLU",
            Layer::Act { kind: ActKind::HSwish, .. } => "HSwish",
            Layer::Act { kind: ActKind::HSigmoid, .. } => "HSigmoid",
            Layer::GaPool { .. } => "GAPool",
            Layer::Fc { .. } => "FC",
            Layer::Residual { .. } => "Add",
        }
    }
}

/// Entry of the weight table (name -> location in weights.bin + analog scale).
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
    /// per-tensor analog scale (max |w|) — present for VMM/BN tensors.
    pub scale: Option<f64>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub arch: String,
    pub width: f64,
    pub img: usize,
    pub num_classes: usize,
    pub digital_test_acc: f64,
    pub batch_sizes: Vec<usize>,
    /// artifact key ("model_b8") -> filename
    pub artifacts: BTreeMap<String, String>,
    pub layers: Vec<Layer>,
    pub weights: Vec<WeightEntry>,
    pub device: DeviceJson,
    pub dataset_file: String,
    pub dataset_n: usize,
    pub expected_file: String,
    pub expected_n: usize,
}

/// Device constants exported by python/compile/device.py::to_dict.
#[derive(Debug, Clone)]
pub struct DeviceJson {
    pub r_on: f64,
    pub r_off: f64,
    pub levels: usize,
    pub prog_sigma: f64,
    pub v_in: f64,
    pub v_rail: f64,
    pub t_mem: f64,
    pub slew_rate: f64,
    pub v_swing: f64,
    pub p_opamp: f64,
    pub p_memristor: f64,
    pub p_aux: f64,
    pub t_opamp: f64,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read manifest in {dir:?} (run `make artifacts`)"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parse manifest.json")?;
        let layers = j
            .req_arr("layers")?
            .iter()
            .map(parse_layer)
            .collect::<Result<Vec<_>>>()?;
        let weights = j
            .req_arr("weights")?
            .iter()
            .map(|e| {
                Ok(WeightEntry {
                    name: e.req_str("name")?.to_string(),
                    shape: e
                        .req_arr("shape")?
                        .iter()
                        .map(|s| s.as_usize().unwrap_or(0))
                        .collect(),
                    offset: e.req_usize("offset")?,
                    len: e.req_usize("len")?,
                    scale: e.get("scale").and_then(|s| s.as_f64()),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let dj = j.req("device")?;
        let device = DeviceJson {
            r_on: dj.req_f64("r_on")?,
            r_off: dj.req_f64("r_off")?,
            levels: dj.req_usize("levels")?,
            prog_sigma: dj.req_f64("prog_sigma")?,
            v_in: dj.req_f64("v_in")?,
            v_rail: dj.req_f64("v_rail")?,
            t_mem: dj.req_f64("t_mem")?,
            slew_rate: dj.req_f64("slew_rate")?,
            v_swing: dj.req_f64("v_swing")?,
            p_opamp: dj.req_f64("p_opamp")?,
            p_memristor: dj.req_f64("p_memristor")?,
            p_aux: dj.req_f64("p_aux")?,
            t_opamp: dj.req_f64("t_opamp")?,
        };
        let artifacts = j
            .req("artifacts")?
            .as_obj()
            .context("artifacts must be an object")?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
            .collect();
        let ds = j.req("dataset")?;
        let ex = j.req("expected_logits")?;
        Ok(Manifest {
            arch: j.req_str("arch")?.to_string(),
            width: j.req_f64("width")?,
            img: j.req_usize("img")?,
            num_classes: j.req_usize("num_classes")?,
            digital_test_acc: j.req_f64("digital_test_acc")?,
            batch_sizes: j
                .req_arr("batch_sizes")?
                .iter()
                .filter_map(|b| b.as_usize())
                .collect(),
            artifacts,
            layers,
            weights,
            device,
            dataset_file: ds.req_str("file")?.to_string(),
            dataset_n: ds.req_usize("n")?,
            expected_file: ex.req_str("file")?.to_string(),
            expected_n: ex.req_usize("n")?,
        })
    }

    pub fn weight_entry(&self, name: &str) -> Option<&WeightEntry> {
        self.weights.iter().find(|w| w.name == name)
    }

    /// Units in Table 4 order.
    pub fn units(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for l in &self.layers {
            if !seen.iter().any(|u| u == l.unit()) {
                seen.push(l.unit().to_string());
            }
        }
        seen
    }
}

fn parse_layer(e: &Json) -> Result<Layer> {
    let kind = e.req_str("layer")?;
    let name = e.req_str("name")?.to_string();
    let unit = e.req_str("unit")?.to_string();
    let conv_geom = |e: &Json| -> Result<ConvGeom> {
        Ok(ConvGeom {
            name: name.clone(),
            unit: unit.clone(),
            k: e.req_usize("k")?,
            stride: e.req_usize("stride")?,
            padding: e.req_usize("padding")?,
            cin: e.req_usize("cin")?,
            cout: e.req_usize("cout")?,
            h_in: e.req_usize("h_in")?,
            w_in: e.req_usize("w_in")?,
            h_out: e.req_usize("h_out")?,
            w_out: e.req_usize("w_out")?,
            weight: e.req_str("weight")?.to_string(),
        })
    };
    Ok(match kind {
        "conv" => Layer::Conv(conv_geom(e)?),
        "dwconv" => Layer::DwConv(conv_geom(e)?),
        "pconv" => Layer::PConv {
            name,
            unit,
            cin: e.req_usize("cin")?,
            cout: e.req_usize("cout")?,
            weight: e.req_str("weight")?.to_string(),
        },
        "bn" => Layer::Bn {
            name,
            unit,
            c: e.req_usize("c")?,
            weight: e.req_str("weight")?.to_string(),
        },
        "relu" => Layer::Act { name, unit, kind: ActKind::Relu, c: e.req_usize("c")? },
        "hswish" => Layer::Act { name, unit, kind: ActKind::HSwish, c: e.req_usize("c")? },
        "hsigmoid" => Layer::Act { name, unit, kind: ActKind::HSigmoid, c: e.req_usize("c")? },
        "gapool" => Layer::GaPool {
            name,
            unit,
            c: e.req_usize("c")?,
            h_in: e.get("h_in").and_then(|v| v.as_usize()).unwrap_or(1),
            w_in: e.get("w_in").and_then(|v| v.as_usize()).unwrap_or(1),
        },
        "fc" => Layer::Fc {
            name,
            unit,
            cin: e.req_usize("cin")?,
            cout: e.req_usize("cout")?,
            weight: e.req_str("weight")?.to_string(),
        },
        "residual" => Layer::Residual { name, unit, c: e.req_usize("c")? },
        other => bail!("unknown layer kind '{other}'"),
    })
}

/// Raw weight tensors resolved against weights.bin.
pub struct WeightStore {
    blob: Vec<f32>,
    entries: Vec<WeightEntry>,
}

impl WeightStore {
    pub fn load(dir: &Path, manifest: &Manifest) -> Result<WeightStore> {
        let blob = bin::read_weights_blob(&dir.join("weights.bin"))?;
        let need = manifest.weights.iter().map(|w| w.offset + w.len).max().unwrap_or(0);
        if blob.len() < need {
            bail!("weights.bin too short: {} < {need}", blob.len());
        }
        Ok(WeightStore { blob, entries: manifest.weights.clone() })
    }

    /// Assemble a store from in-memory parts — synthetic manifests, tests
    /// and tooling that never touch a weights.bin on disk.
    pub fn from_parts(blob: Vec<f32>, entries: Vec<WeightEntry>) -> Result<WeightStore> {
        let need = entries.iter().map(|w| w.offset + w.len).max().unwrap_or(0);
        if blob.len() < need {
            bail!("weight blob too short: {} < {need}", blob.len());
        }
        Ok(WeightStore { blob, entries })
    }

    pub fn get(&self, name: &str) -> Option<Tensor<'_>> {
        let e = self.entries.iter().find(|w| w.name == name)?;
        Some(Tensor {
            shape: e.shape.clone(),
            data: &self.blob[e.offset..e.offset + e.len],
            scale: e.scale,
        })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.name.as_str())
    }

    /// All raw weight values of VMM-bearing tensors (Fig 9 histogram input).
    pub fn all_vmm_values(&self) -> Vec<f32> {
        self.entries
            .iter()
            .filter(|e| e.name.ends_with(".w"))
            .flat_map(|e| self.blob[e.offset..e.offset + e.len].iter().copied())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "arch":"m","width":0.4,"img":32,"num_classes":10,
      "digital_test_acc":0.93,"batch_sizes":[1,8],
      "artifacts":{"model_b1":"model_b1.hlo.txt"},
      "device":{"r_on":100,"r_off":16000,"levels":64,"prog_sigma":0.01,
        "v_in":0.0025,"v_rail":8.0,"t_mem":1e-10,"slew_rate":1e7,
        "v_swing":5.0,"p_opamp":0.001,"p_memristor":1.1e-6,"p_aux":0.0005,
        "t_opamp":5e-7,"g_on":0.01,"g_off":6.25e-5},
      "dataset":{"file":"dataset.bin","n":10},
      "expected_logits":{"file":"expected_logits.bin","n":4},
      "weights":[{"name":"stem.conv.w","shape":[3,3,3,8],"offset":0,"len":216,"scale":0.5}],
      "layers":[
        {"unit":"input","layer":"conv","name":"stem.conv","k":3,"stride":1,
         "padding":1,"cin":3,"cout":8,"h_in":32,"w_in":32,"h_out":32,"w_out":32,
         "weight":"stem.conv.w"},
        {"unit":"input","layer":"bn","name":"stem.bn","c":8,"weight":"stem.bn.gamma"},
        {"unit":"input","layer":"hswish","name":"stem.act","c":8},
        {"unit":"classifier","layer":"gapool","name":"cls.gap","c":8,"h_in":4,"w_in":4},
        {"unit":"classifier","layer":"fc","name":"cls.fc2","cin":8,"cout":10,
         "weight":"cls.fc2.w"}
      ]
    }"#;

    #[test]
    fn parses_mini_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.layers.len(), 5);
        assert_eq!(m.num_classes, 10);
        assert_eq!(m.units(), vec!["input", "classifier"]);
        match &m.layers[0] {
            Layer::Conv(g) => {
                assert_eq!(g.k, 3);
                g.check_geometry().unwrap();
            }
            _ => panic!("expected conv"),
        }
        assert_eq!(m.layers[2].kind_label(), "HSwish");
        assert_eq!(m.weight_entry("stem.conv.w").unwrap().scale, Some(0.5));
    }

    #[test]
    fn geometry_violation_detected() {
        let g = ConvGeom {
            name: "x".into(),
            unit: "u".into(),
            k: 3,
            stride: 2,
            padding: 1,
            cin: 3,
            cout: 8,
            h_in: 32,
            w_in: 32,
            h_out: 30, // wrong: should be 16
            w_out: 16,
            weight: "w".into(),
        };
        assert!(g.check_geometry().is_err());
    }

    #[test]
    fn unknown_layer_kind_errors() {
        let bad = MINI.replace("\"hswish\"", "\"frobnicate\"");
        assert!(Manifest::parse(&bad).is_err());
    }
}
