"""L1 kernel vs pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes / block sizes / scales; every case asserts
allclose against kernels.ref.crossbar_vmm_ref.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import crossbar as xb
from compile.kernels import ref as kref

RTOL = 1e-5
ATOL = 1e-5


def _rand(rng, *shape):
    return rng.uniform(-1.0, 1.0, shape).astype(np.float32)


def _gpair(rng, r, c):
    g = rng.uniform(0.0, 1.0, (2, r, c)).astype(np.float32)
    return g[0], g[1]


def run_case(b, r, c, rf=1.0, rail=8.0, seed=0, **blocks):
    rng = np.random.default_rng(seed)
    v = _rand(rng, b, r)
    gp, gn = _gpair(rng, r, c)
    out = xb.crossbar_vmm(jnp.asarray(v), jnp.asarray(gp), jnp.asarray(gn),
                          rf_scale=rf, v_rail=rail, **blocks)
    ref = kref.crossbar_vmm_ref(jnp.asarray(v), jnp.asarray(gp),
                                jnp.asarray(gn), rf, rail)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=RTOL, atol=ATOL)


class TestBasic:
    def test_small_square(self):
        run_case(4, 16, 16)

    def test_single_row_vector(self):
        run_case(1, 8, 8)

    def test_single_column(self):
        run_case(4, 16, 1)

    def test_single_input(self):
        run_case(4, 1, 16)

    def test_rectangular_tall(self):
        run_case(2, 300, 40)

    def test_rectangular_wide(self):
        run_case(2, 40, 300)

    def test_larger_than_blocks(self):
        run_case(17, 515, 300)

    def test_non_multiple_of_tile(self):
        run_case(3, 13, 7)

    def test_fc_layer_shape(self):
        # classifier-scale crossbar (cls.fc1)
        run_case(8, 232, 408)


class TestPhysics:
    def test_rf_scale(self):
        run_case(4, 32, 32, rf=2.5)

    def test_tiny_rf(self):
        run_case(4, 32, 32, rf=1e-3)

    def test_rail_clips(self):
        rng = np.random.default_rng(1)
        v = np.ones((2, 64), np.float32)
        gp = np.zeros((64, 4), np.float32)
        gn = np.ones((64, 4), np.float32)
        out = np.asarray(xb.crossbar_vmm(jnp.asarray(v), jnp.asarray(gp),
                                         jnp.asarray(gn), v_rail=8.0))
        assert np.all(out == 8.0), "64 unit currents must saturate the TIA"

    def test_rail_clips_negative(self):
        v = np.ones((2, 64), np.float32)
        gp = np.ones((64, 4), np.float32)
        gn = np.zeros((64, 4), np.float32)
        out = np.asarray(xb.crossbar_vmm(jnp.asarray(v), jnp.asarray(gp),
                                         jnp.asarray(gn), v_rail=8.0))
        assert np.all(out == -8.0)

    def test_zero_conductance_is_open_circuit(self):
        # absent memristors contribute no current
        v = np.ones((1, 16), np.float32)
        gp = np.zeros((16, 3), np.float32)
        gn = np.zeros((16, 3), np.float32)
        out = np.asarray(xb.crossbar_vmm(jnp.asarray(v), jnp.asarray(gp),
                                         jnp.asarray(gn)))
        assert np.all(out == 0.0)

    def test_differential_symmetry(self):
        # swapping the pair negates the output (inverted convention)
        rng = np.random.default_rng(2)
        v = _rand(rng, 3, 32)
        gp, gn = _gpair(rng, 32, 8)
        a = np.asarray(xb.crossbar_vmm(jnp.asarray(v), jnp.asarray(gp), jnp.asarray(gn)))
        b = np.asarray(xb.crossbar_vmm(jnp.asarray(v), jnp.asarray(gn), jnp.asarray(gp)))
        np.testing.assert_allclose(a, -b, rtol=RTOL, atol=ATOL)


class TestBlocks:
    def test_block_b_1(self):
        run_case(5, 64, 64, block_b=1)

    def test_block_r_smaller(self):
        run_case(4, 100, 64, block_r=32)

    def test_block_c_smaller(self):
        run_case(4, 64, 100, block_c=32)

    def test_all_blocks_tiny(self):
        run_case(9, 33, 17, block_b=2, block_r=8, block_c=8)


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 12),
    r=st.integers(1, 130),
    c=st.integers(1, 130),
    rf=st.floats(0.01, 4.0),
    seed=st.integers(0, 1000),
)
def test_hypothesis_sweep(b, r, c, rf, seed):
    run_case(b, r, c, rf=rf, seed=seed)


@settings(max_examples=10, deadline=None)
@given(
    br=st.sampled_from([8, 16, 64, 256]),
    bc=st.sampled_from([8, 16, 64, 256]),
    bb=st.sampled_from([1, 2, 8]),
    seed=st.integers(0, 100),
)
def test_hypothesis_block_invariance(br, bc, bb, seed):
    """Output must be independent of the BlockSpec tiling."""
    rng = np.random.default_rng(seed)
    v = _rand(rng, 6, 70)
    gp, gn = _gpair(rng, 70, 50)
    base = xb.crossbar_vmm(jnp.asarray(v), jnp.asarray(gp), jnp.asarray(gn))
    tiled = xb.crossbar_vmm(jnp.asarray(v), jnp.asarray(gp), jnp.asarray(gn),
                            block_b=bb, block_r=br, block_c=bc)
    np.testing.assert_allclose(np.asarray(base), np.asarray(tiled),
                               rtol=RTOL, atol=ATOL)


def test_grouped_matches_loop():
    rng = np.random.default_rng(3)
    g, b, r, c = 4, 3, 24, 12
    v = _rand(rng, g, b, r)
    gp = rng.uniform(0, 1, (g, r, c)).astype(np.float32)
    gn = rng.uniform(0, 1, (g, r, c)).astype(np.float32)
    out = np.asarray(xb.crossbar_vmm_grouped(
        jnp.asarray(v), jnp.asarray(gp), jnp.asarray(gn)))
    for i in range(g):
        ref = np.asarray(kref.crossbar_vmm_ref(
            jnp.asarray(v[i]), jnp.asarray(gp[i]), jnp.asarray(gn[i])))
        np.testing.assert_allclose(out[i], ref, rtol=RTOL, atol=ATOL)


def test_dtype_bf16_inputs_upcast():
    rng = np.random.default_rng(4)
    v = _rand(rng, 2, 16)
    gp, gn = _gpair(rng, 16, 8)
    out = xb.crossbar_vmm(jnp.asarray(v, jnp.bfloat16),
                          jnp.asarray(gp), jnp.asarray(gn))
    assert out.dtype == jnp.float32
    ref = kref.crossbar_vmm_ref(jnp.asarray(v, jnp.bfloat16).astype(jnp.float32),
                                jnp.asarray(gp), jnp.asarray(gn))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_vmem_budget():
    """Default BlockSpec must fit the 16 MiB TPU VMEM with headroom."""
    assert xb.vmem_bytes() < 4 * 1024 * 1024


def test_mxu_macs():
    assert xb.mxu_macs(8, 256, 256) == 8 * 256 * 256
