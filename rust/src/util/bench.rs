//! Micro-bench harness (criterion is not in the offline crate cache).
//!
//! Each `cargo bench` target is a `harness = false` binary that calls
//! [`Bench::run`] per case: warmup, then timed iterations until both a
//! minimum iteration count and a minimum wall budget are met; reports
//! median / mean / p95 like criterion's summary line and collects rows so
//! benches can print paper-style tables at the end.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

pub struct Bench {
    pub min_iters: usize,
    pub min_time: Duration,
    pub warmup: usize,
    pub rows: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self { min_iters: 10, min_time: Duration::from_millis(300), warmup: 2, rows: Vec::new() }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { min_iters: 3, min_time: Duration::from_millis(50), warmup: 1, rows: Vec::new() }
    }

    /// Time `f` (which must fully perform the work per call).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters || start.elapsed() < self.min_time {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
            if samples.len() >= 10_000 {
                break;
            }
        }
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let stats = Stats {
            name: name.to_string(),
            iters: n,
            mean,
            median: samples[n / 2],
            p95: samples[((n * 95) / 100).min(n - 1)],
            min: samples[0],
        };
        println!(
            "{:<44} {:>10} iters  mean {:>12?}  median {:>12?}  min {:>12?}",
            stats.name, stats.iters, stats.mean, stats.median, stats.min
        );
        self.rows.push(stats.clone());
        stats
    }

    /// Record an externally-measured single-shot duration (for expensive
    /// cases where repeated runs are impractical, e.g. large SPICE solves).
    pub fn record_once(&mut self, name: &str, d: Duration) -> Stats {
        let stats = Stats {
            name: name.to_string(),
            iters: 1,
            mean: d,
            median: d,
            p95: d,
            min: d,
        };
        println!("{:<44} {:>10} iter   once {:>12?}", stats.name, 1, d);
        self.rows.push(stats.clone());
        stats
    }

    pub fn table(&self, title: &str) {
        println!("\n== {title} ==");
        println!("{:<44} {:>14} {:>14}", "case", "median", "mean");
        for r in &self.rows {
            println!("{:<44} {:>14?} {:>14?}", r.name, r.median, r.mean);
        }
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut b = Bench::quick();
        let s = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.iters >= 3);
        assert!(s.median <= s.p95 || s.iters < 20);
        assert_eq!(b.rows.len(), 1);
    }

    #[test]
    fn record_once_row() {
        let mut b = Bench::quick();
        b.record_once("big", Duration::from_millis(5));
        assert_eq!(b.rows[0].iters, 1);
    }
}
