//! Full-chain analog conformance suite — closes the `Fidelity::Spice` gap.
//!
//! Every [`AnalogModule`] implementation's SPICE transfer is pinned
//! against its exact transfer (the affine fold for BN, the exact mean for
//! GAP, `Crossbar::eval_ideal` for the crossbar layers, the software
//! forms for the Fig 4 activation circuits), and the full demo-network
//! chain at `Fidelity::Spice` is pinned against `Behavioural` — with a
//! structural check that no module falls back to its exact transfer at
//! spice fidelity (`AnalogModule::spice_circuits`). The only documented
//! exceptions are the CMOS ReLU (the paper realizes it without op-amps)
//! and the residual summing amplifiers.

use memx::analog::{self, KNEE_TOL};
use memx::mapper::{self, BnFold, MapMode, BN_EPS};
use memx::nn::{ActKind, DeviceJson};
use memx::backend::BackendChoice;
use memx::pipeline::{
    default_device, demo_network, ActivationModule, AnalogModule, BatchNormModule, Fidelity,
    GapModule, ModuleCfg, PipelineBuilder,
};
use memx::spice::krylov::SolverStrategy;
use memx::spice::solve::Ordering;
use memx::util::prng::Rng;

/// Spice-fidelity module environment over the given device and solver.
fn cfg(dev: &DeviceJson, solver: SolverStrategy) -> ModuleCfg<'_> {
    ModuleCfg {
        dev,
        fidelity: Fidelity::Spice,
        segment: 8,
        ordering: Ordering::Smart,
        solver,
        backend: BackendChoice::Auto,
        workers: 2,
        prog_sigma: 0.0,
    }
}

#[test]
fn bn_module_spice_transfer_pins_affine_fold() {
    let dev = default_device();
    let gamma = [1.2, -0.7, 0.4, 1.0]; // includes a negative scale
    let beta = [0.1, -0.3, 0.0, 0.25];
    let mean = [0.2, -0.1, 0.05, 0.0];
    let var = [0.9, 1e-6, 0.3, 2.0]; // includes near-zero variance
    let (c, spatial) = (4usize, 3usize);
    for mode in [MapMode::Inverted, MapMode::Dual] {
        let mut rng = Rng::new(0xB17);
        let mut bn = BatchNormModule::new(
            "t.bn",
            c,
            spatial,
            BnFold::from_stats(&gamma, &beta, &mean, &var),
            mode,
            &cfg(&dev, SolverStrategy::Auto),
            &mut rng,
        )
        .unwrap();
        assert_eq!(bn.spice_circuits(), 2, "subtraction + scale/offset netlists resident");
        assert_eq!(bn.memristor_stages(), 2, "the emitted circuit is two crossbar stages");
        assert!(bn.memristors() > 0);
        let batch: Vec<Vec<f64>> = (0..3)
            .map(|k| {
                (0..c * spatial).map(|i| ((i + k * 5) as f64 * 0.37).sin() * 0.8).collect()
            })
            .collect();
        let got = bn.forward_batch(&batch).unwrap();
        for (x, row) in batch.iter().zip(&got) {
            for ch in 0..c {
                let k = gamma[ch] / (var[ch] + BN_EPS).sqrt();
                for s in 0..spatial {
                    let want = (x[ch * spatial + s] - mean[ch]) * k + beta[ch];
                    let g = row[ch * spatial + s];
                    assert!(
                        (g - want).abs() < 1e-4 * (1.0 + want.abs()),
                        "{mode} ch {ch} s {s}: spice {g} vs fold {want}"
                    );
                }
            }
        }
    }
}

#[test]
fn gap_module_spice_pins_exact_mean() {
    let dev = default_device();
    let mut rng = Rng::new(0x6A9);
    let mut gap = GapModule::new(
        "t.gap",
        3,
        2,
        2,
        MapMode::Inverted,
        &cfg(&dev, SolverStrategy::Auto),
        &mut rng,
    )
    .unwrap();
    assert_eq!(gap.spice_circuits(), 1, "the §3.5 averaging column is resident");
    assert_eq!(gap.memristors(), 12); // Eq 12 == the emitted 1/N devices
    assert_eq!(gap.opamps(), 3); // Eq 13 == one TIA per emitted column
    let batch: Vec<Vec<f64>> = (0..4)
        .map(|k| (0..12).map(|i| ((i * 3 + k) as f64 * 0.29).cos() * 0.7).collect())
        .collect();
    let got = gap.forward_batch(&batch).unwrap();
    for (x, row) in batch.iter().zip(&got) {
        for ch in 0..3 {
            let want = x[ch * 4..(ch + 1) * 4].iter().sum::<f64>() / 4.0;
            assert!((row[ch] - want).abs() < 1e-4, "ch {ch}: {} vs {want}", row[ch]);
        }
    }
}

#[test]
fn gap_spice_survives_wire_resistance_extremes_and_iterative_solver() {
    // r_on spans 1e-2 .. 1e5 Ω (the krylov.rs extremes harness range):
    // averaging conductances from 1e2 down to 1e-5 S against the 1e6
    // op-amp gains — and the same column under SolverStrategy::Iterative
    // (every iterative solution is residual-certified, so this exercises
    // the GMRES path end to end on the §3.5 netlist)
    let (c, h, w) = (2usize, 3usize, 3usize);
    let spatial = h * w;
    let iterative = SolverStrategy::Iterative { restart: 16, tol: 1e-11, max_iter: 600 };
    for r_on in [1e-2, 1e2, 1e5] {
        let dev = DeviceJson { r_on, ..default_device() };
        for solver in [SolverStrategy::Direct, iterative] {
            let mut rng = Rng::new(0xE0);
            let mut gap =
                GapModule::new("t.gap", c, h, w, MapMode::Inverted, &cfg(&dev, solver), &mut rng)
                    .unwrap();
            let x: Vec<f64> = (0..c * spatial).map(|i| (i as f64 * 0.41).sin() * 0.6).collect();
            let got = gap.forward(&x).unwrap();
            for ch in 0..c {
                let want =
                    x[ch * spatial..(ch + 1) * spatial].iter().sum::<f64>() / spatial as f64;
                assert!(
                    (got[ch] - want).abs() < 1e-4,
                    "r_on {r_on} solver {solver}: ch {ch} {} vs {want}",
                    got[ch]
                );
            }
        }
    }
}

#[test]
fn activation_modules_spice_pin_software_transfers() {
    let dev = default_device();
    for act in [ActKind::HSigmoid, ActKind::HSwish] {
        let mut module =
            ActivationModule::new("t.act", act, 2, 2, Fidelity::Spice, dev.v_rail, 2);
        assert_eq!(module.spice_circuits(), 1, "{act:?} holds its Fig 4 circuit");
        let xs = [-4.0f64, -1.0, 0.0, 0.5, 1.0, 2.0, 4.0, -2.0];
        let batch: Vec<Vec<f64>> = xs.chunks(4).map(|c| c.to_vec()).collect();
        let got = module.forward_batch(&batch).unwrap();
        for (x, g) in xs.iter().zip(got.iter().flatten()) {
            let want = match act {
                ActKind::HSigmoid => analog::hard_sigmoid_sw(*x),
                _ => analog::hard_swish_sw(*x),
            };
            assert!(
                (g - want).abs() < KNEE_TOL + 0.02 * x.abs(),
                "{act:?} x {x}: spice {g} vs sw {want}"
            );
        }
    }
    // CMOS ReLU stays behavioural at spice BY DESIGN — the one documented
    // module-level exception (the paper realizes ReLU without op-amps)
    let relu = ActivationModule::new("t.relu", ActKind::Relu, 2, 2, Fidelity::Spice, 8.0, 1);
    assert_eq!(relu.spice_circuits(), 0);
}

#[test]
fn fc_crossbar_spice_pins_eval_ideal() {
    let dev = default_device();
    let cb = mapper::build_synthetic_fc(10, 5, 64, MapMode::Inverted, 77);
    let reference = cb.clone();
    let mut module = PipelineBuilder::new()
        .fidelity(Fidelity::Spice)
        .segment(2)
        .workers(2)
        .crossbar_module(cb, &dev)
        .unwrap();
    assert_eq!(module.spice_circuits(), 1);
    let x: Vec<f64> = (0..10).map(|i| (i as f64 * 0.33).sin() * 0.5).collect();
    let got = module.forward(&x).unwrap();
    for (g, w) in got.iter().zip(&reference.eval_ideal(&x)) {
        assert!((g - w).abs() < 1e-4 * (1.0 + w.abs()), "spice {g} vs ideal {w}");
    }
}

#[test]
fn full_demo_chain_spice_tracks_behavioural_with_no_fidelity_holes() {
    let (m, ws) = demo_network(0xD311).unwrap();
    let base = PipelineBuilder::new().segment(8).workers(2);
    let mut behav = base.clone().fidelity(Fidelity::Behavioural).build(&m, &ws).unwrap();
    let mut spice = base.fidelity(Fidelity::Spice).build(&m, &ws).unwrap();

    // structural conformance: at spice fidelity every module answers from
    // its emitted circuit — the only stages allowed to answer exactly are
    // the CMOS ReLU and the residual summing amplifier
    assert_eq!(behav.spice_circuits(), 0);
    assert!(spice.spice_circuits() > 0);
    for s in spice.stage_coverage() {
        if s.spice_exempt() {
            assert_eq!(s.spice_circuits, 0, "{} ({})", s.name, s.kind);
        } else {
            assert!(
                s.spice_circuits >= 1,
                "fidelity hole: {} ({}) falls back to its exact transfer at Fidelity::Spice",
                s.name,
                s.kind
            );
        }
    }
    // BN stages report the emitted two-stage §3.3 netlist pair
    let bn = spice.stage_coverage().into_iter().find(|s| s.kind == "BN").unwrap();
    assert_eq!((bn.spice_circuits, bn.memristor_stages), (2, 2));

    // transfer conformance: the whole chain at spice stays within the
    // accumulated circuit tolerance of the behavioural reference — the
    // Fig 4 diode knees dominate; the linear BN/GAP/crossbar netlists add
    // only op-amp finite-gain error
    let mut rng = Rng::new(0xF00);
    let batch: Vec<Vec<f64>> = (0..4)
        .map(|_| (0..behav.in_dim()).map(|_| rng.range_f64(-0.3, 0.3)).collect())
        .collect();
    let want = behav.forward_batch(&batch).unwrap();
    let got = spice.forward_batch(&batch).unwrap();
    let mut worst = 0f64;
    for (g_row, w_row) in got.iter().zip(&want) {
        for (g, w) in g_row.iter().zip(w_row) {
            assert!(g.is_finite(), "non-finite spice logit");
            worst = worst.max((g - w).abs());
        }
    }
    assert!(worst < 0.3, "chain divergence {worst} exceeds the accumulated circuit tolerance");
}

#[test]
fn emit_layer_netlists_covers_bn_and_gap_layers() {
    let (m, ws) = demo_network(0xD311).unwrap();
    let out = std::env::temp_dir().join("memx_fidelity_netlists");
    let bn_files =
        memx::netlist::emit_layer_netlists(&m, &ws, "b1.bn", MapMode::Inverted, 0, &out)
            .unwrap();
    assert_eq!(bn_files.len(), 2, "subtraction + scale/offset stage files");
    let gap_files =
        memx::netlist::emit_layer_netlists(&m, &ws, "cls.gap", MapMode::Inverted, 0, &out)
            .unwrap();
    assert_eq!(gap_files.len(), 1, "one averaging-column file");
    for f in bn_files.iter().chain(&gap_files) {
        let text = std::fs::read_to_string(f).unwrap();
        let circuit = memx::netlist::parse(&text).unwrap();
        assert!(!circuit.elements.is_empty(), "{f:?} parses to an empty circuit");
    }
    std::fs::remove_dir_all(out).ok();
}

#[test]
fn spice_chain_batch_matches_single_and_hooks_count_netlists() {
    let (m, ws) = demo_network(0xD311).unwrap();
    let mut spice = PipelineBuilder::new()
        .segment(8)
        .workers(2)
        .fidelity(Fidelity::Spice)
        .build(&m, &ws)
        .unwrap();
    let ideal = PipelineBuilder::new().fidelity(Fidelity::Ideal).build(&m, &ws).unwrap();
    // spice-mode resource hooks count the emitted netlists: the BN pair is
    // the per-channel Eq 10/11 hardware (placed devices, one TIA per
    // emitted column) but contributes two cascaded crossbar stages to the
    // Eq 17 path, unlike the closed-form single stage
    assert!(spice.memristor_stages() > ideal.memristor_stages());
    for s in spice.stage_coverage().iter().filter(|s| s.kind == "BN") {
        assert_eq!(s.opamps, 8, "{}: 2 TIAs per channel (c = 4)", s.name);
        assert_eq!(s.memristor_stages, 2, "{}", s.name);
        // 2-4 placed devices per channel (g1 + scale always; mean/offset
        // conductances only when the folded stats are nonzero)
        assert!((8..=16).contains(&s.memristors), "{}: {} devices", s.name, s.memristors);
    }
    let mut rng = Rng::new(0xAB);
    let batch: Vec<Vec<f64>> = (0..3)
        .map(|_| (0..spice.in_dim()).map(|_| rng.range_f64(-0.3, 0.3)).collect())
        .collect();
    let batched = spice.forward_batch(&batch).unwrap();
    for (k, x) in batch.iter().enumerate() {
        let single = spice.forward(x).unwrap();
        for (a, b) in single.iter().zip(&batched[k]) {
            assert!((a - b).abs() < 1e-9, "batch {k}: single {a} vs batched {b}");
        }
    }
}
