//! `memx::backend` — pluggable dense-kernel compute backends for the
//! analog hot loops.
//!
//! Every fidelity level, module, the transient engine and the server spend
//! their wall time in a handful of dense batch kernels: the multi-RHS
//! forward/backward substitution sweeps of the factored engine
//! ([`crate::spice::factor::Numeric::solve_multi`]), the GMRES
//! matvec/axpy/dot/norm primitives and Arnoldi update
//! ([`crate::spice::krylov::gmres`]), the ILU(0) triangular sweeps
//! ([`crate::spice::krylov::Ilu0::solve`]), the MNA RHS assembly of
//! batched crossbar reads, and the conv im2col reorder in
//! [`crate::nn`]. The [`Backend`] trait extracts exactly those kernels
//! behind one object-safe interface so implementations can be swapped
//! end-to-end — `rjwalters__spicier` mirrors this shape with its
//! `spicier-simd` + `backend-cpu/cuda/metal` crates, and the trait surface
//! here is deliberately narrow enough for a future GPU crate.
//!
//! Two implementations ship today:
//!
//! * [`Scalar`] — the reference kernels, extracted verbatim from the
//!   pre-backend code. The correctness baseline every other backend is
//!   parity-pinned against (`rust/tests/backend.rs`).
//! * [`Simd`] — a portable-SIMD CPU backend. The multi-RHS substitution
//!   sweeps repack the RHS columns into an interleaved
//!   structure-of-arrays buffer and stream fixed-width lanes (8/4/2
//!   columns at a time, narrowing with the remaining batch) through the
//!   factor's row program, so the inner loops are contiguous
//!   fixed-trip-count `f64` arithmetic that LLVM auto-vectorizes into
//!   AVX2 on the CI host — no `unsafe`, no nightly features. Per-lane
//!   operation order is identical to [`Scalar`]'s per-column order
//!   (including the `/diag` divisions), so multi-RHS substitution results
//!   are **bit-identical** between the two backends; reduction kernels
//!   ([`Backend::dot`], [`Backend::norm2`]) use multiple accumulators and
//!   may differ from `Scalar` by ordinary rounding (pinned to ≤1e-12
//!   relative by the parity proptests).
//!
//! # Kernel contract: pattern-fixed, value-only
//!
//! Backends receive borrowed *views* of a factorization's fixed structure
//! ([`LuLowerParts`]/[`LuUpperParts`]/[`IluParts`]) plus the current value
//! arrays — the same rule that keeps a cached
//! [`Symbolic`](crate::spice::factor::Symbolic) valid across value edits.
//! A kernel must never reorder, dedup or otherwise reinterpret the
//! structure arrays: the (pivot, target) program encodes the elimination
//! semantics, and replaying it in program order per RHS column is what
//! lets the driver swap backends without re-certifying results. Kernels
//! are pure compute: no allocation visible to the caller beyond the
//! returned vectors, no retained state, `Sync` so batched sweeps can share
//! one backend across worker threads.
//!
//! # Selection
//!
//! [`BackendChoice`] threads end-to-end: `--backend` on the
//! `spice`/`accuracy`/`serve`/`tran` CLIs → `PipelineBuilder::backend` →
//! every resident `CrossbarSim`/[`Circuit`](crate::spice::Circuit) → the
//! transient engine and the server. [`resolve`] maps a choice to the
//! kernel set: an explicit `Scalar`/`Simd` always wins; `Auto` (the
//! default everywhere) honours the `MEMX_BACKEND` environment variable
//! (`scalar`|`simd`) and otherwise picks [`Simd`].
//!
//! Process-wide kernel-time counters ([`subst_ns`]/[`matvec_ns`])
//! accumulate the nanoseconds spent inside substitution sweeps and GMRES
//! matvecs, so `memx report` and `coordinator::Snapshot` can attribute
//! wall time to kernels, not just solves.
//!
//! Follow-ons (ROADMAP): a GPU backend behind the same trait, and a
//! matrix-free stamping hook so [`Backend::spmv`] can consume a stamping
//! closure instead of a materialized triplet list.

use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use anyhow::bail;

// ---------------------------------------------------------------------------
// Selection
// ---------------------------------------------------------------------------

/// Which kernel set to run the dense batch math on (see [`resolve`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// The reference kernels (pre-backend code, extracted verbatim).
    Scalar,
    /// The portable-SIMD CPU kernels (SoA multi-RHS lane blocking).
    Simd,
    /// `MEMX_BACKEND` if set, otherwise [`BackendChoice::Simd`].
    #[default]
    Auto,
}

impl FromStr for BackendChoice {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<BackendChoice> {
        match s {
            "scalar" => Ok(BackendChoice::Scalar),
            "simd" => Ok(BackendChoice::Simd),
            "auto" => Ok(BackendChoice::Auto),
            other => bail!("unknown backend '{other}' (scalar|simd|auto)"),
        }
    }
}

impl std::fmt::Display for BackendChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendChoice::Scalar => "scalar",
            BackendChoice::Simd => "simd",
            BackendChoice::Auto => "auto",
        })
    }
}

static ENV_CHOICE: OnceLock<Option<BackendChoice>> = OnceLock::new();

/// `MEMX_BACKEND` environment override, parsed once per process. An
/// unparseable value is reported to stderr and ignored.
fn env_override() -> Option<BackendChoice> {
    *ENV_CHOICE.get_or_init(|| match std::env::var("MEMX_BACKEND") {
        Ok(s) => match s.parse::<BackendChoice>() {
            Ok(c) => Some(c),
            Err(e) => {
                eprintln!("memx: ignoring MEMX_BACKEND: {e}");
                None
            }
        },
        Err(_) => None,
    })
}

static SCALAR: Scalar = Scalar;
static SIMD: Simd = Simd;

/// The reference kernel set (always available; parity baseline).
pub fn scalar() -> &'static dyn Backend {
    &SCALAR
}

/// The portable-SIMD CPU kernel set.
pub fn simd() -> &'static dyn Backend {
    &SIMD
}

/// Map a [`BackendChoice`] to its kernel set. An explicit
/// `Scalar`/`Simd` always wins (a CLI flag beats the environment); `Auto`
/// defers to `MEMX_BACKEND` when set and otherwise runs [`Simd`].
pub fn resolve(choice: BackendChoice) -> &'static dyn Backend {
    let effective = match choice {
        BackendChoice::Auto => env_override().unwrap_or(BackendChoice::Simd),
        explicit => explicit,
    };
    match effective {
        BackendChoice::Scalar => &SCALAR,
        BackendChoice::Simd | BackendChoice::Auto => &SIMD,
    }
}

// ---------------------------------------------------------------------------
// Kernel-time attribution
// ---------------------------------------------------------------------------

static SUBST_NS: AtomicU64 = AtomicU64::new(0);
static MATVEC_NS: AtomicU64 = AtomicU64::new(0);

/// Process-wide nanoseconds spent inside triangular substitution sweeps
/// (factored multi-RHS solves + ILU(0) preconditioner applications).
pub fn subst_ns() -> u64 {
    SUBST_NS.load(Ordering::Relaxed)
}

/// Process-wide nanoseconds spent inside GMRES matrix-vector products.
pub fn matvec_ns() -> u64 {
    MATVEC_NS.load(Ordering::Relaxed)
}

pub(crate) fn add_subst_ns(ns: u64) {
    SUBST_NS.fetch_add(ns, Ordering::Relaxed);
}

pub(crate) fn add_matvec_ns(ns: u64) {
    MATVEC_NS.fetch_add(ns, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Structure views
// ---------------------------------------------------------------------------

/// Borrowed view of a complete factor's lower program: for pivot `p`,
/// targets `l_ptr[p]..l_ptr[p+1]` of `(l_rows, lvals)` eliminate against
/// pivot row `pivots[p].1` (unit diagonal implicit). The structure arrays
/// are fixed per [`Symbolic`](crate::spice::factor::Symbolic); only
/// `lvals` changes across refactors.
pub struct LuLowerParts<'a> {
    pub pivots: &'a [(usize, usize)],
    pub l_ptr: &'a [usize],
    pub l_rows: &'a [usize],
    pub lvals: &'a [f64],
}

/// Borrowed view of a complete factor's upper rows: pivot `p` solves
/// column `pivots[p].0` from RHS row `pivots[p].1` over U entries
/// `u_ptr[p]..u_ptr[p+1]` of `(u_cols, u_slots)` — the diagonal slot
/// first — against the value array `vals`.
pub struct LuUpperParts<'a> {
    pub pivots: &'a [(usize, usize)],
    pub u_ptr: &'a [usize],
    pub u_cols: &'a [usize],
    pub u_slots: &'a [usize],
    pub vals: &'a [f64],
}

/// Borrowed CSR view of an ILU(0) factor (already row-permuted): row `i`
/// spans `ptr[i]..ptr[i+1]` of `(cols, vals)`; `diag[i]` is the absolute
/// index of its diagonal; strictly-lower slots hold the L multipliers.
pub struct IluParts<'a> {
    pub ptr: &'a [usize],
    pub diag: &'a [usize],
    pub cols: &'a [usize],
    pub vals: &'a [f64],
}

// ---------------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------------

/// One set of dense batch kernels (see the module docs for the contract).
/// Object-safe and `Sync`: one `&'static dyn Backend` is shared by every
/// solve of a batched sweep across worker threads.
pub trait Backend: Sync {
    /// Short label for [`SolveStats`](crate::spice::solve::SolveStats) /
    /// bench attribution.
    fn name(&self) -> &'static str;

    /// Single-RHS forward substitution: replay the eliminations on `w` in
    /// program order.
    fn subst_lower(&self, lu: &LuLowerParts<'_>, w: &mut [f64]);

    /// Single-RHS backward substitution over the U rows into `x`
    /// (zero-initialized by the caller). Returns `Some(column)` when a
    /// diagonal has collapsed below 1e-300 — the caller reports the
    /// singular column.
    fn subst_upper(&self, lu: &LuUpperParts<'_>, w: &[f64], x: &mut [f64]) -> Option<usize>;

    /// Multi-RHS forward substitution: one traversal of the lower program
    /// applied to every column of `w`.
    fn subst_lower_multi(&self, lu: &LuLowerParts<'_>, w: &mut [Vec<f64>]);

    /// Multi-RHS backward substitution into `xs` (zero-initialized, same
    /// length as `w`). Returns `Some(column)` on a collapsed diagonal.
    fn subst_upper_multi(
        &self,
        lu: &LuUpperParts<'_>,
        w: &[Vec<f64>],
        xs: &mut [Vec<f64>],
    ) -> Option<usize>;

    /// ILU(0) preconditioner application: unit-lower forward sweep then
    /// upper backward sweep, in place over the (already permuted) `w`.
    /// Returns `Some(row)` on a collapsed diagonal.
    fn ilu_sweep(&self, ilu: &IluParts<'_>, w: &mut [f64]) -> Option<usize>;

    /// Dot product `aᵀb` (the Arnoldi projection kernel).
    fn dot(&self, a: &[f64], b: &[f64]) -> f64;

    /// `y += alpha * x` (the Arnoldi update / correction kernel).
    fn axpy(&self, alpha: f64, x: &[f64], y: &mut [f64]);

    /// Euclidean norm `‖v‖₂`.
    fn norm2(&self, v: &[f64]) -> f64;

    /// Sparse matrix-vector product over a triplet stream: `y = A x`
    /// (`y` is overwritten; duplicate `(row, col)` entries accumulate).
    fn spmv(&self, rows: &[usize], cols: &[usize], vals: &[f64], x: &[f64], y: &mut [f64]);

    /// Conv-weight im2col reorder: `[k1, k2, cin, cout]` row-major data
    /// into the `(cin*k1*k2) x cout` matmul layout (`dims` in that order).
    /// Operates on the weight blob's native `f32` (see
    /// [`crate::nn::tensor::Tensor::as_matrix`]).
    fn conv_reorder(&self, data: &[f32], dims: [usize; 4], m: &mut [f32]);

    /// Batched MNA RHS assembly: column `k` is column `k-1` (column 0:
    /// `base`) with the slot overrides `sets[k]` scattered on top — the
    /// running-override semantics of
    /// [`Circuit::dc_op_batch`](crate::spice::Circuit::dc_op_batch), where
    /// each batch entry inherits the source values of the previous one.
    fn rhs_columns(&self, base: &[f64], sets: &[Vec<(usize, f64)>]) -> Vec<Vec<f64>> {
        let mut cur = base.to_vec();
        let mut out = Vec::with_capacity(sets.len());
        for set in sets {
            for &(slot, v) in set {
                cur[slot] = v;
            }
            out.push(cur.clone());
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Shared reference kernels (used by Scalar everywhere, and by Simd where
// lane blocking has nothing to add)
// ---------------------------------------------------------------------------

fn ref_subst_lower(lu: &LuLowerParts<'_>, w: &mut [f64]) {
    for p in 0..lu.pivots.len() {
        let bp = w[lu.pivots[p].1];
        if bp != 0.0 {
            for t in lu.l_ptr[p]..lu.l_ptr[p + 1] {
                w[lu.l_rows[t]] -= lu.lvals[t] * bp;
            }
        }
    }
}

fn ref_subst_upper(lu: &LuUpperParts<'_>, w: &[f64], x: &mut [f64]) -> Option<usize> {
    for p in (0..lu.pivots.len()).rev() {
        let (col, prow) = lu.pivots[p];
        let u = lu.u_ptr[p]..lu.u_ptr[p + 1];
        let mut acc = w[prow];
        for k in u.clone().skip(1) {
            acc -= lu.vals[lu.u_slots[k]] * x[lu.u_cols[k]];
        }
        let diag = lu.vals[lu.u_slots[u.start]];
        if diag.abs() < 1e-300 {
            return Some(col);
        }
        x[col] = acc / diag;
    }
    None
}

fn ref_subst_lower_multi(lu: &LuLowerParts<'_>, w: &mut [Vec<f64>]) {
    for p in 0..lu.pivots.len() {
        let prow = lu.pivots[p].1;
        for t in lu.l_ptr[p]..lu.l_ptr[p + 1] {
            let f = lu.lvals[t];
            if f == 0.0 {
                continue;
            }
            let r = lu.l_rows[t];
            for wb in w.iter_mut() {
                wb[r] -= f * wb[prow];
            }
        }
    }
}

fn ref_subst_upper_multi(
    lu: &LuUpperParts<'_>,
    w: &[Vec<f64>],
    xs: &mut [Vec<f64>],
) -> Option<usize> {
    for p in (0..lu.pivots.len()).rev() {
        let (col, prow) = lu.pivots[p];
        let u = lu.u_ptr[p]..lu.u_ptr[p + 1];
        let diag = lu.vals[lu.u_slots[u.start]];
        if diag.abs() < 1e-300 {
            return Some(col);
        }
        for (x, wb) in xs.iter_mut().zip(w) {
            let mut acc = wb[prow];
            for kk in u.clone().skip(1) {
                acc -= lu.vals[lu.u_slots[kk]] * x[lu.u_cols[kk]];
            }
            x[col] = acc / diag;
        }
    }
    None
}

fn ref_ilu_sweep(ilu: &IluParts<'_>, w: &mut [f64]) -> Option<usize> {
    let n = ilu.diag.len();
    // forward: unit-diagonal L (strictly-lower slots hold multipliers)
    for i in 0..n {
        let mut acc = w[i];
        for t in ilu.ptr[i]..ilu.diag[i] {
            acc -= ilu.vals[t] * w[ilu.cols[t]];
        }
        w[i] = acc;
    }
    // backward: U
    for i in (0..n).rev() {
        let d = ilu.diag[i];
        let mut acc = w[i];
        for t in (d + 1)..ilu.ptr[i + 1] {
            acc -= ilu.vals[t] * w[ilu.cols[t]];
        }
        let dv = ilu.vals[d];
        if dv.abs() < 1e-300 {
            return Some(i);
        }
        w[i] = acc / dv;
    }
    None
}

fn ref_spmv(rows: &[usize], cols: &[usize], vals: &[f64], x: &[f64], y: &mut [f64]) {
    for v in y.iter_mut() {
        *v = 0.0;
    }
    for ((&i, &j), &v) in rows.iter().zip(cols).zip(vals) {
        y[i] += v * x[j];
    }
}

fn ref_axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += alpha * *xv;
    }
}

fn ref_conv_reorder(data: &[f32], [k1, k2, cin, cout]: [usize; 4], m: &mut [f32]) {
    for a in 0..k1 {
        for b in 0..k2 {
            for c in 0..cin {
                for o in 0..cout {
                    let src = ((a * k2 + b) * cin + c) * cout + o;
                    let dst = ((c * k1 * k2) + a * k2 + b) * cout + o;
                    m[dst] = data[src];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar — the reference backend
// ---------------------------------------------------------------------------

/// The reference kernels, extracted verbatim from the pre-backend solver
/// code. Every other backend is parity-pinned against this one.
#[derive(Debug, Clone, Copy, Default)]
pub struct Scalar;

impl Backend for Scalar {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn subst_lower(&self, lu: &LuLowerParts<'_>, w: &mut [f64]) {
        ref_subst_lower(lu, w);
    }

    fn subst_upper(&self, lu: &LuUpperParts<'_>, w: &[f64], x: &mut [f64]) -> Option<usize> {
        ref_subst_upper(lu, w, x)
    }

    fn subst_lower_multi(&self, lu: &LuLowerParts<'_>, w: &mut [Vec<f64>]) {
        ref_subst_lower_multi(lu, w);
    }

    fn subst_upper_multi(
        &self,
        lu: &LuUpperParts<'_>,
        w: &[Vec<f64>],
        xs: &mut [Vec<f64>],
    ) -> Option<usize> {
        ref_subst_upper_multi(lu, w, xs)
    }

    fn ilu_sweep(&self, ilu: &IluParts<'_>, w: &mut [f64]) -> Option<usize> {
        ref_ilu_sweep(ilu, w)
    }

    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    fn axpy(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        ref_axpy(alpha, x, y);
    }

    fn norm2(&self, v: &[f64]) -> f64 {
        v.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    fn spmv(&self, rows: &[usize], cols: &[usize], vals: &[f64], x: &[f64], y: &mut [f64]) {
        ref_spmv(rows, cols, vals, x, y);
    }

    fn conv_reorder(&self, data: &[f32], dims: [usize; 4], m: &mut [f32]) {
        ref_conv_reorder(data, dims, m);
    }
}

// ---------------------------------------------------------------------------
// Simd — portable-SIMD CPU backend (SoA multi-RHS lane blocking)
// ---------------------------------------------------------------------------

/// Portable-SIMD CPU kernels: the multi-RHS substitution sweeps interleave
/// RHS columns into lane-width blocks (8/4/2, narrowing with the remaining
/// batch; a final single column runs the reference loop) so the inner
/// arithmetic is contiguous fixed-width `f64` ops that LLVM
/// auto-vectorizes. Per-lane operation order matches [`Scalar`]'s
/// per-column order exactly — multi-RHS results are bit-identical.
#[derive(Debug, Clone, Copy, Default)]
pub struct Simd;

/// Interleave `cols` (each length `n`) into one `n * L` SoA buffer:
/// row `r` of lane `l` lives at `buf[r * L + l]`.
fn pack<const L: usize>(cols: &[Vec<f64>], n: usize) -> Vec<f64> {
    let mut buf = vec![0.0f64; n * L];
    for (lane, col) in cols.iter().enumerate() {
        for (r, &v) in col.iter().enumerate() {
            buf[r * L + lane] = v;
        }
    }
    buf
}

/// Scatter an SoA buffer back into per-column vectors.
fn unpack<const L: usize>(buf: &[f64], cols: &mut [Vec<f64>]) {
    for (lane, col) in cols.iter_mut().enumerate() {
        for (r, v) in col.iter_mut().enumerate() {
            *v = buf[r * L + lane];
        }
    }
}

fn lower_multi_block<const L: usize>(lu: &LuLowerParts<'_>, cols: &mut [Vec<f64>]) {
    debug_assert_eq!(cols.len(), L);
    let n = cols[0].len();
    let mut buf = pack::<L>(cols, n);
    for p in 0..lu.pivots.len() {
        let (t0, t1) = (lu.l_ptr[p], lu.l_ptr[p + 1]);
        if t0 == t1 {
            continue;
        }
        // elimination targets never alias the pivot row, so its lanes can
        // be hoisted once per pivot
        let prow = lu.pivots[p].1;
        let mut piv = [0.0f64; L];
        piv.copy_from_slice(&buf[prow * L..prow * L + L]);
        for t in t0..t1 {
            let f = lu.lvals[t];
            if f == 0.0 {
                continue;
            }
            let r = lu.l_rows[t];
            let dst = &mut buf[r * L..r * L + L];
            for (d, pv) in dst.iter_mut().zip(&piv) {
                *d -= f * *pv;
            }
        }
    }
    unpack::<L>(&buf, cols);
}

fn upper_multi_block<const L: usize>(
    lu: &LuUpperParts<'_>,
    w: &[Vec<f64>],
    xs: &mut [Vec<f64>],
) -> Option<usize> {
    debug_assert_eq!(w.len(), L);
    let n = w[0].len();
    let wbuf = pack::<L>(w, n);
    let mut xbuf = vec![0.0f64; n * L];
    for p in (0..lu.pivots.len()).rev() {
        let (col, prow) = lu.pivots[p];
        let (u0, u1) = (lu.u_ptr[p], lu.u_ptr[p + 1]);
        let diag = lu.vals[lu.u_slots[u0]];
        if diag.abs() < 1e-300 {
            return Some(col);
        }
        let mut acc = [0.0f64; L];
        acc.copy_from_slice(&wbuf[prow * L..prow * L + L]);
        for k in (u0 + 1)..u1 {
            let v = lu.vals[lu.u_slots[k]];
            let xc = lu.u_cols[k];
            let xrow = &xbuf[xc * L..xc * L + L];
            for (a, xv) in acc.iter_mut().zip(xrow) {
                *a -= v * *xv;
            }
        }
        let dst = &mut xbuf[col * L..col * L + L];
        for (d, a) in dst.iter_mut().zip(&acc) {
            *d = *a / diag;
        }
    }
    unpack::<L>(&xbuf, xs);
    None
}

/// Widest lane block not exceeding the remaining batch (8 → 4 → 2 → 1).
fn lane_width(remaining: usize) -> usize {
    match remaining {
        0 | 1 => remaining,
        2 | 3 => 2,
        4..=7 => 4,
        _ => 8,
    }
}

impl Backend for Simd {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn subst_lower(&self, lu: &LuLowerParts<'_>, w: &mut [f64]) {
        // one RHS has no lanes to fill — the reference sweep is optimal
        ref_subst_lower(lu, w);
    }

    fn subst_upper(&self, lu: &LuUpperParts<'_>, w: &[f64], x: &mut [f64]) -> Option<usize> {
        ref_subst_upper(lu, w, x)
    }

    fn subst_lower_multi(&self, lu: &LuLowerParts<'_>, w: &mut [Vec<f64>]) {
        let mut rest = w;
        while !rest.is_empty() {
            let width = lane_width(rest.len());
            let (head, tail) = rest.split_at_mut(width);
            match width {
                8 => lower_multi_block::<8>(lu, head),
                4 => lower_multi_block::<4>(lu, head),
                2 => lower_multi_block::<2>(lu, head),
                // a leftover single column replays the multi loop (not the
                // single-RHS one) so its zero-skip pattern — and therefore
                // its bit pattern — matches the lane blocks exactly
                _ => ref_subst_lower_multi(lu, head),
            }
            rest = tail;
        }
    }

    fn subst_upper_multi(
        &self,
        lu: &LuUpperParts<'_>,
        w: &[Vec<f64>],
        xs: &mut [Vec<f64>],
    ) -> Option<usize> {
        let mut done = 0;
        while done < w.len() {
            let width = lane_width(w.len() - done);
            let wb = &w[done..done + width];
            let xb = &mut xs[done..done + width];
            let bad = match width {
                8 => upper_multi_block::<8>(lu, wb, xb),
                4 => upper_multi_block::<4>(lu, wb, xb),
                2 => upper_multi_block::<2>(lu, wb, xb),
                _ => ref_subst_upper_multi(lu, wb, xb),
            };
            if bad.is_some() {
                return bad;
            }
            done += width;
        }
        None
    }

    fn ilu_sweep(&self, ilu: &IluParts<'_>, w: &mut [f64]) -> Option<usize> {
        // the ILU sweep is a single-RHS dependence chain; lane blocking has
        // nothing to add, so run the reference sweep (bit-identical)
        ref_ilu_sweep(ilu, w)
    }

    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        // 4 independent accumulators break the serial-add dependence chain
        // (reassociated vs Scalar: differs by ordinary rounding only)
        let mut acc = [0.0f64; 4];
        let mut chunks_a = a.chunks_exact(4);
        let mut chunks_b = b.chunks_exact(4);
        for (ca, cb) in (&mut chunks_a).zip(&mut chunks_b) {
            for ((s, x), y) in acc.iter_mut().zip(ca).zip(cb) {
                *s += x * y;
            }
        }
        let mut tail: f64 = chunks_a
            .remainder()
            .iter()
            .zip(chunks_b.remainder())
            .map(|(x, y)| x * y)
            .sum();
        for s in acc {
            tail += s;
        }
        tail
    }

    fn axpy(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        // elementwise with no reduction: the reference loop already
        // auto-vectorizes, and keeping it shared preserves bit-identity
        ref_axpy(alpha, x, y);
    }

    fn norm2(&self, v: &[f64]) -> f64 {
        self.dot(v, v).sqrt()
    }

    fn spmv(&self, rows: &[usize], cols: &[usize], vals: &[f64], x: &[f64], y: &mut [f64]) {
        // scatter over an unsorted triplet stream (duplicates accumulate);
        // kept identical to the reference until the matrix-free stamping
        // hook lands a CSR-normalized path
        ref_spmv(rows, cols, vals, x, y);
    }

    fn conv_reorder(&self, data: &[f32], [k1, k2, cin, cout]: [usize; 4], m: &mut [f32]) {
        // both layouts are contiguous over the cout axis: copy whole lanes
        for a in 0..k1 {
            for b in 0..k2 {
                for c in 0..cin {
                    let src = ((a * k2 + b) * cin + c) * cout;
                    let dst = ((c * k1 * k2) + a * k2 + b) * cout;
                    m[dst..dst + cout].copy_from_slice(&data[src..src + cout]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parse_display_roundtrip() {
        for s in ["scalar", "simd", "auto"] {
            let parsed: BackendChoice = s.parse().unwrap();
            assert_eq!(parsed.to_string(), s);
        }
        assert!("avx".parse::<BackendChoice>().is_err());
        assert_eq!(BackendChoice::default(), BackendChoice::Auto);
    }

    #[test]
    fn explicit_choice_resolves_regardless_of_env() {
        assert_eq!(resolve(BackendChoice::Scalar).name(), "scalar");
        assert_eq!(resolve(BackendChoice::Simd).name(), "simd");
        // Auto lands on one of the two (env-dependent), never panics
        let auto = resolve(BackendChoice::Auto).name();
        assert!(auto == "scalar" || auto == "simd");
    }

    #[test]
    fn dot_and_norm_agree_across_backends() {
        let a: Vec<f64> = (0..37).map(|i| (i as f64 * 0.61).sin()).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64 * 0.23).cos()).collect();
        let ds = scalar().dot(&a, &b);
        let dv = simd().dot(&a, &b);
        assert!((ds - dv).abs() <= 1e-12 * ds.abs().max(1.0), "{ds} vs {dv}");
        let ns = scalar().norm2(&a);
        let nv = simd().norm2(&a);
        assert!((ns - nv).abs() <= 1e-12 * ns, "{ns} vs {nv}");
    }

    #[test]
    fn conv_reorder_backends_identical() {
        let dims = [3usize, 2, 4, 5];
        let len = dims.iter().product::<usize>();
        let data: Vec<f32> = (0..len).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut ms = vec![0.0f32; len];
        let mut mv = vec![1.0f32; len];
        scalar().conv_reorder(&data, dims, &mut ms);
        simd().conv_reorder(&data, dims, &mut mv);
        assert_eq!(ms, mv);
    }

    #[test]
    fn rhs_columns_running_override_semantics() {
        let base = vec![1.0, 2.0, 3.0];
        let sets = vec![vec![(0usize, 9.0)], vec![(2usize, 7.0)], vec![]];
        let cols = scalar().rhs_columns(&base, &sets);
        assert_eq!(cols[0], vec![9.0, 2.0, 3.0]);
        // column 1 inherits column 0's override
        assert_eq!(cols[1], vec![9.0, 2.0, 7.0]);
        assert_eq!(cols[2], cols[1]);
    }

    #[test]
    fn spmv_accumulates_duplicates() {
        // y = A x with a duplicated (0,1) entry
        let rows = [0usize, 0, 1];
        let cols = [1usize, 1, 0];
        let vals = [2.0, 3.0, 4.0];
        let x = [10.0, 100.0];
        let mut y = vec![1.0; 2];
        simd().spmv(&rows, &cols, &vals, &x, &mut y);
        assert_eq!(y, vec![500.0, 40.0]);
    }

    #[test]
    fn lane_width_narrowing() {
        assert_eq!(lane_width(64), 8);
        assert_eq!(lane_width(8), 8);
        assert_eq!(lane_width(7), 4);
        assert_eq!(lane_width(3), 2);
        assert_eq!(lane_width(1), 1);
        assert_eq!(lane_width(0), 0);
    }
}
