//! Tiny scoped parallel-map built on std::thread::scope.
//!
//! rayon is not in the offline crate cache; the coordinator and the
//! segmented SPICE scheduler only need a static work-split map, which
//! std::thread::scope provides without unsafe.

/// Parallel map over `items` with up to `workers` OS threads.
/// Results are returned in input order. Panics in workers propagate.
pub fn par_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let workers = workers.max(1).min(items.len().max(1));
    if workers <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<&mut Option<R>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                **slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker missed slot")).collect()
}

/// Parallel map over mutable items (e.g. per-segment circuits whose cached
/// factorizations update during the solve). Items are split into contiguous
/// chunks, one worker per chunk; results return in input order. Panics in
/// workers propagate.
pub fn par_map_mut<T, R, F>(items: &mut [T], workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(&mut T) -> R + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.iter_mut().map(f).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Vec<R>> = Vec::new();
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = items
            .chunks_mut(chunk)
            .map(|ch| s.spawn(move || ch.iter_mut().map(f).collect::<Vec<R>>()))
            .collect();
        out = handles
            .into_iter()
            .map(|h| h.join().expect("par_map_mut worker panicked"))
            .collect();
    });
    out.into_iter().flatten().collect()
}

/// Recommended worker count for this host.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let xs: Vec<u64> = (0..100).collect();
        let ys = par_map(&xs, 4, |x| x * 2);
        assert_eq!(ys, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker() {
        let xs = vec![1, 2, 3];
        assert_eq!(par_map(&xs, 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        assert!(par_map(&xs, 4, |x| *x).is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let xs = vec![5];
        assert_eq!(par_map(&xs, 16, |x| x * x), vec![25]);
    }

    #[test]
    fn par_map_mut_updates_and_orders() {
        let mut xs: Vec<u64> = (0..57).collect();
        let ys = par_map_mut(&mut xs, 4, |x| {
            *x += 1;
            *x * 10
        });
        assert_eq!(xs, (1..=57).collect::<Vec<_>>());
        assert_eq!(ys, (1..=57).map(|x| x * 10).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_mut_single_and_empty() {
        let mut xs: Vec<u32> = vec![];
        assert!(par_map_mut(&mut xs, 4, |x| *x).is_empty());
        let mut one = vec![7u32];
        assert_eq!(par_map_mut(&mut one, 8, |x| *x + 1), vec![8]);
    }
}
