//! SPICE solver scaling — MNA solve cost vs system size for the two
//! elimination orderings and the dense fallback (supports §Perf and the
//! Fig 7 mechanism analysis: Natural ordering goes superlinear on
//! monolithic crossbars; Smart stays near-linear), plus the
//! factor-once/solve-many engine: a sweep/Newton-style repeated-solve
//! workload (same topology, new source values every iteration) comparing
//! the seed per-call `solve_with_stats` path against cached re-solves.
//!
//!   cargo bench --bench bench_spice
//!
//! Appends a run record (rows + cached-vs-cold speedups) to
//! BENCH_spice.json at the repo root.

use memx::spice::solve::{solve_dense, Ordering, SparseSys};
use memx::spice::Circuit;
use memx::util::bench::{append_json_report, black_box, Bench};
use memx::util::prng::Rng;

/// Build the MNA system of an n-input, c-column ideal-TIA crossbar.
fn crossbar_circuit(inputs: usize, cols: usize, rng: &mut Rng) -> Circuit {
    let mut c = Circuit::new("bench crossbar");
    let in_nodes: Vec<usize> = (0..inputs).map(|r| c.node(&format!("in{r}"))).collect();
    for (r, &node) in in_nodes.iter().enumerate() {
        c.vsource(&format!("V{r}"), node, 0, (r as f64 * 0.7).sin() * 0.3);
    }
    for col in 0..cols {
        let vcol = c.node(&format!("vcol{col}"));
        let vout = c.node(&format!("vout{col}"));
        for (r, &node) in in_nodes.iter().enumerate() {
            let g = 0.05 + 0.9 * rng.f64();
            c.resistor(&format!("RM{r}_{col}"), node, vcol, 100.0 / g);
        }
        c.resistor(&format!("RF{col}"), vcol, vout, 50.0);
        c.opamp(&format!("E{col}"), 0, vcol, vout);
    }
    c
}

fn main() {
    let mut b = Bench::quick();
    let mut rng = Rng::new(31);

    // dense baseline on small systems
    for &n in &[32usize, 96, 192] {
        let mut a = vec![vec![0.0; n]; n];
        let mut bb = vec![0.0; n];
        for i in 0..n {
            for _ in 0..4 {
                a[i][rng.below(n)] += rng.range_f64(-1.0, 1.0);
            }
            a[i][i] += 4.0;
            bb[i] = rng.range_f64(-1.0, 1.0);
        }
        b.run(&format!("dense LU n={n}"), || {
            black_box(solve_dense(&a, &bb).unwrap());
        });
    }

    // sparse orderings on crossbar MNA systems (per-call reference engine)
    for &(inputs, cols) in &[(128usize, 32usize), (256, 64), (512, 128)] {
        let circuit = crossbar_circuit(inputs, cols, &mut rng);
        for ord in [Ordering::Smart, Ordering::Natural] {
            b.run(&format!("mna {inputs}x{cols} {ord:?} reference"), || {
                black_box(circuit.dc_op_stats_reference(ord).unwrap());
            });
        }
    }

    // raw sparse system: block-diagonal (segmented limit case)
    for &blocks in &[200usize, 800] {
        let n = blocks * 3;
        let mut s = SparseSys::new(n);
        for k in 0..blocks {
            let i = 3 * k;
            for d in 0..3 {
                s.add(i + d, i + d, 4.0 + d as f64);
            }
            s.add(i, i + 1, 1.0);
            s.add(i + 1, i + 2, 1.0);
            s.add(i + 2, i, 0.5);
            s.add_b(i, 1.0);
        }
        b.run(&format!("block-diag {blocks}x3"), || {
            black_box(s.solve().unwrap());
        });
    }

    // --- factor-once/solve-many: repeated-solve workload ---------------
    // Sweep/Newton style: same topology every iteration, new source values
    // (RHS-only edits). Cold = the seed per-call reference elimination;
    // cached = the factored engine reusing the symbolic factorization
    // (pure re-solves at O(nnz(L+U))).
    let mut derived: Vec<(String, f64)> = Vec::new();
    for &(inputs, cols) in &[(128usize, 32usize), (256, 64), (512, 128)] {
        let mut circuit = crossbar_circuit(inputs, cols, &mut rng);
        let vidx: Vec<usize> = (0..inputs)
            .map(|r| circuit.vsource_index(&format!("V{r}")).unwrap())
            .collect();
        let mut point = 0usize;
        let bump = |c: &mut Circuit, k: usize| {
            for (r, &i) in vidx.iter().enumerate() {
                c.set_vsource_at(i, ((r * 7 + k) as f64 * 0.13).sin() * 0.3).unwrap();
            }
        };
        let cold = b.run(&format!("sweep {inputs}x{cols} cold reference"), || {
            point += 1;
            bump(&mut circuit, point);
            black_box(circuit.dc_op_stats_reference(Ordering::Smart).unwrap());
        });
        let warm = b.run(&format!("sweep {inputs}x{cols} cached resolve"), || {
            point += 1;
            bump(&mut circuit, point);
            black_box(circuit.dc_op().unwrap());
        });
        let speedup =
            cold.median.as_secs_f64() / warm.median.as_secs_f64().max(1e-12);
        println!("    -> cached-resolve median speedup {speedup:.1}x");
        derived.push((format!("sweep_{inputs}x{cols}_median_speedup"), speedup));
    }

    b.table("SPICE solver scaling");
    match append_json_report("BENCH_spice.json", "bench_spice", &b.rows, &derived) {
        Ok(()) => println!("\nrecorded trajectory entry in BENCH_spice.json"),
        Err(e) => eprintln!("\nwarning: could not write BENCH_spice.json: {e}"),
    }
}
