//! Tests for `memx::pipeline` — manifest-driven builds over in-memory
//! weight stores (no artifacts needed): each module's transfer is checked
//! against the mapper/crossbar ground truth, and the compiled pipelines
//! against hand-folded chains.

use memx::analog;
use memx::mapper::{self, MapMode};
use memx::nn::{Manifest, WeightStore};
use memx::pipeline::modules::BN_EPS;
use memx::pipeline::{default_device, Fidelity, PipelineBuilder};
use memx::util::prng::Rng;

/// Full manifest JSON around the given layer/weight fragments.
fn manifest_json(layers: &str, weights: &str) -> String {
    format!(
        r#"{{
        "arch":"test","width":1.0,"img":4,"num_classes":4,
        "digital_test_acc":0.9,"batch_sizes":[1,4],
        "artifacts":{{}},
        "device":{{"r_on":100,"r_off":16000,"levels":64,"prog_sigma":0.0,
          "v_in":0.0025,"v_rail":8.0,"t_mem":1e-10,"slew_rate":1e7,
          "v_swing":5.0,"p_opamp":0.001,"p_memristor":1.1e-6,"p_aux":0.0005,
          "t_opamp":5e-7}},
        "dataset":{{"file":"dataset.bin","n":0}},
        "expected_logits":{{"file":"expected.bin","n":0}},
        "weights":[{weights}],
        "layers":[{layers}]
        }}"#
    )
}

fn load(layers: &str, weights: &str, blob: Vec<f32>) -> (Manifest, WeightStore) {
    let m = Manifest::parse(&manifest_json(layers, weights)).expect("manifest parses");
    let ws = WeightStore::from_parts(blob, m.weights.clone()).expect("store assembles");
    (m, ws)
}

fn rand_blob(n: usize, amp: f32, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * amp).collect()
}

#[test]
fn fc_stack_ideal_matches_manual_chain_and_mapper_resources() {
    let layers = r#"
        {"unit":"main","layer":"fc","name":"fc1","cin":6,"cout":5,"weight":"a.w"},
        {"unit":"main","layer":"hswish","name":"act","c":5},
        {"unit":"main","layer":"fc","name":"fc2","cin":5,"cout":3,"weight":"b.w"}"#;
    let weights = r#"
        {"name":"a.w","shape":[6,5],"offset":0,"len":30,"scale":0.5},
        {"name":"b.w","shape":[5,3],"offset":30,"len":15,"scale":0.5}"#;
    let (m, ws) = load(layers, weights, rand_blob(45, 0.5, 13));

    let mut p = PipelineBuilder::new()
        .fidelity(Fidelity::Ideal)
        .build(&m, &ws)
        .expect("pipeline builds");
    assert_eq!((p.in_dim(), p.out_dim(), p.n_stages()), (6, 3, 3));

    // manual chain over the same crossbars: exact agreement
    let cb1 = mapper::build_fc_crossbar(&m, &ws, "fc1", MapMode::Inverted).unwrap();
    let cb2 = mapper::build_fc_crossbar(&m, &ws, "fc2", MapMode::Inverted).unwrap();
    let x: Vec<f64> = (0..6).map(|i| ((i as f64) * 0.7).sin() * 0.4).collect();
    let mid: Vec<f64> = cb1.eval_ideal(&x).iter().map(|&v| analog::hard_swish_sw(v)).collect();
    let want = cb2.eval_ideal(&mid);
    let got = p.forward(&x).unwrap();
    assert_eq!(got, want, "ideal pipeline must match the hand-folded chain exactly");

    // resource hooks mirror the Table 4 mapper counts
    let net = mapper::map_network(&m, &ws, MapMode::Inverted).unwrap();
    assert_eq!(p.memristors(), net.total_memristors());
    assert_eq!(p.opamps(), net.total_opamps());
    assert_eq!(p.memristor_stages(), net.memristor_stages());
}

#[test]
fn bn_module_folds_batch_stats_exactly() {
    let layers = r#"{"unit":"u","layer":"bn","name":"n.bn","c":4,"weight":"n.bn.gamma"}"#;
    let weights = r#"
        {"name":"n.bn.gamma","shape":[4],"offset":0,"len":4},
        {"name":"n.bn.beta","shape":[4],"offset":4,"len":4},
        {"name":"n.bn.mean","shape":[4],"offset":8,"len":4},
        {"name":"n.bn.var","shape":[4],"offset":12,"len":4}"#;
    let blob = vec![
        1.5, 0.5, -0.8, 1.0, // gamma
        0.1, -0.2, 0.3, 0.0, // beta
        0.05, -0.1, 0.2, 0.0, // mean
        0.9, 1.2, 0.4, 1.0, // var
    ];
    let (m, ws) = load(layers, weights, blob.clone());
    let mut p = PipelineBuilder::new().fidelity(Fidelity::Ideal).build(&m, &ws).unwrap();
    let x = vec![0.3, -0.4, 0.7, 0.0];
    let got = p.forward(&x).unwrap();
    for ch in 0..4 {
        let k = blob[ch] as f64 / (blob[12 + ch] as f64 + BN_EPS).sqrt();
        let want = (x[ch] - blob[8 + ch] as f64) * k + blob[4 + ch] as f64;
        assert!((got[ch] - want).abs() < 1e-12, "ch {ch}: {} vs {want}", got[ch]);
    }
}

/// Manual zero-padding into the conv crossbar's input-region layout.
fn padded_plane(x: &[f64], ci: usize, h: usize, w: usize, pad: usize) -> Vec<f64> {
    let (wr, wc) = (h + 2 * pad, w + 2 * pad);
    let mut p = vec![0.0; wr * wc];
    for y in 0..h {
        for xx in 0..w {
            p[(y + pad) * wc + xx + pad] = x[ci * h * w + y * w + xx];
        }
    }
    p
}

#[test]
fn conv_ideal_matches_per_bank_crossbar_eval() {
    let layers = r#"
        {"unit":"u","layer":"conv","name":"c0","k":3,"stride":1,"padding":1,
         "cin":2,"cout":3,"h_in":4,"w_in":4,"h_out":4,"w_out":4,"weight":"c0.w"}"#;
    let weights = r#"{"name":"c0.w","shape":[3,3,2,3],"offset":0,"len":54,"scale":0.6}"#;
    let (m, ws) = load(layers, weights, rand_blob(54, 0.6, 31));
    let mut p = PipelineBuilder::new().fidelity(Fidelity::Ideal).build(&m, &ws).unwrap();
    assert_eq!((p.in_dim(), p.out_dim()), (2 * 16, 3 * 16));

    let mut rng = Rng::new(8);
    let x: Vec<f64> = (0..32).map(|_| rng.range_f64(-0.5, 0.5)).collect();
    let got = p.forward(&x).unwrap();

    // ground truth: per-(ci,co) conv crossbars over padded planes
    for co in 0..3 {
        let mut want = vec![0.0; 16];
        for ci in 0..2 {
            let cb = mapper::build_conv_crossbar(&m, &ws, "c0", ci, co, MapMode::Inverted)
                .unwrap();
            let outs = cb.eval_ideal(&padded_plane(&x, ci, 4, 4, 1));
            for (acc, o) in want.iter_mut().zip(&outs) {
                *acc += o;
            }
        }
        for (i, w) in want.iter().enumerate() {
            assert!(
                (got[co * 16 + i] - w).abs() < 1e-12,
                "co {co} pos {i}: {} vs {w}",
                got[co * 16 + i]
            );
        }
    }
}

#[test]
fn dwconv_ideal_matches_per_channel_crossbar_eval() {
    let layers = r#"
        {"unit":"u","layer":"dwconv","name":"d0","k":3,"stride":2,"padding":1,
         "cin":2,"cout":2,"h_in":4,"w_in":4,"h_out":2,"w_out":2,"weight":"d0.w"}"#;
    let weights = r#"{"name":"d0.w","shape":[3,3,1,2],"offset":0,"len":18,"scale":0.5}"#;
    let (m, ws) = load(layers, weights, rand_blob(18, 0.5, 17));
    let mut p = PipelineBuilder::new().fidelity(Fidelity::Ideal).build(&m, &ws).unwrap();
    assert_eq!((p.in_dim(), p.out_dim()), (2 * 16, 2 * 4));

    let mut rng = Rng::new(9);
    let x: Vec<f64> = (0..32).map(|_| rng.range_f64(-0.5, 0.5)).collect();
    let got = p.forward(&x).unwrap();
    for c in 0..2 {
        let cb = mapper::build_conv_crossbar(&m, &ws, "d0", 0, c, MapMode::Inverted).unwrap();
        let want = cb.eval_ideal(&padded_plane(&x, c, 4, 4, 1));
        for (i, w) in want.iter().enumerate() {
            assert!((got[c * 4 + i] - w).abs() < 1e-12, "c {c} pos {i}");
        }
    }
}

#[test]
fn conv_spice_matches_ideal_within_tolerance() {
    // the per-bank resident-CrossbarSim path (regular conv) must track the
    // direct-form ideal transfer within the op-amp finite-gain tolerance
    let layers = r#"
        {"unit":"u","layer":"conv","name":"c0","k":3,"stride":1,"padding":1,
         "cin":2,"cout":2,"h_in":4,"w_in":4,"h_out":4,"w_out":4,"weight":"c0.w"}"#;
    let weights = r#"{"name":"c0.w","shape":[3,3,2,2],"offset":0,"len":36,"scale":0.5}"#;
    let (m, ws) = load(layers, weights, rand_blob(36, 0.5, 51));
    let base = PipelineBuilder::new().segment(8).workers(2);
    let mut spice = base.clone().fidelity(Fidelity::Spice).build(&m, &ws).unwrap();
    let mut ideal = base.fidelity(Fidelity::Ideal).build(&m, &ws).unwrap();
    let mut rng = Rng::new(14);
    let batch: Vec<Vec<f64>> = (0..2)
        .map(|_| (0..32).map(|_| rng.range_f64(-0.5, 0.5)).collect())
        .collect();
    let got = spice.forward_batch(&batch).unwrap();
    let want = ideal.forward_batch(&batch).unwrap();
    for (g_row, w_row) in got.iter().zip(&want) {
        for (g, w) in g_row.iter().zip(w_row) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "conv spice {g} vs ideal {w}");
        }
    }
}

#[test]
fn dwconv_spice_matches_ideal_within_tolerance() {
    // depthwise banks (one crossbar per channel, ci == co) on the SPICE path
    let layers = r#"
        {"unit":"u","layer":"dwconv","name":"d0","k":3,"stride":2,"padding":1,
         "cin":2,"cout":2,"h_in":4,"w_in":4,"h_out":2,"w_out":2,"weight":"d0.w"}"#;
    let weights = r#"{"name":"d0.w","shape":[3,3,1,2],"offset":0,"len":18,"scale":0.5}"#;
    let (m, ws) = load(layers, weights, rand_blob(18, 0.5, 53));
    let base = PipelineBuilder::new().segment(0).workers(2);
    let mut spice = base.clone().fidelity(Fidelity::Spice).build(&m, &ws).unwrap();
    let mut ideal = base.fidelity(Fidelity::Ideal).build(&m, &ws).unwrap();
    let mut rng = Rng::new(15);
    let x: Vec<f64> = (0..32).map(|_| rng.range_f64(-0.5, 0.5)).collect();
    let got = spice.forward(&x).unwrap();
    let want = ideal.forward(&x).unwrap();
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "dwconv spice {g} vs ideal {w}");
    }
}

#[test]
fn se_block_scales_channels_by_sigmoid_branch() {
    let layers = r#"
        {"unit":"u","layer":"gapool","name":"u.se.gap","c":4,"h_in":2,"w_in":2},
        {"unit":"u","layer":"pconv","name":"u.se.fc1","cin":4,"cout":2,"weight":"u.se.fc1.w"},
        {"unit":"u","layer":"relu","name":"u.se.act1","c":2},
        {"unit":"u","layer":"pconv","name":"u.se.fc2","cin":2,"cout":4,"weight":"u.se.fc2.w"},
        {"unit":"u","layer":"hsigmoid","name":"u.se.act2","c":4}"#;
    let weights = r#"
        {"name":"u.se.fc1.w","shape":[4,2],"offset":0,"len":8,"scale":0.5},
        {"name":"u.se.fc2.w","shape":[2,4],"offset":8,"len":8,"scale":0.5}"#;
    let (m, ws) = load(layers, weights, rand_blob(16, 0.5, 23));
    let mut p = PipelineBuilder::new().fidelity(Fidelity::Ideal).build(&m, &ws).unwrap();
    // the five manifest layers collapse into one SE module, dims preserved
    assert_eq!((p.in_dim(), p.out_dim(), p.n_stages()), (16, 16, 1));

    let mut rng = Rng::new(3);
    let x: Vec<f64> = (0..16).map(|_| rng.range_f64(-0.5, 0.5)).collect();
    let got = p.forward(&x).unwrap();

    let cb1 = mapper::build_fc_crossbar(&m, &ws, "u.se.fc1", MapMode::Inverted).unwrap();
    let cb2 = mapper::build_fc_crossbar(&m, &ws, "u.se.fc2", MapMode::Inverted).unwrap();
    let pooled: Vec<f64> = (0..4).map(|c| x[c * 4..(c + 1) * 4].iter().sum::<f64>() / 4.0).collect();
    let h: Vec<f64> = cb1.eval_ideal(&pooled).iter().map(|&v| v.max(0.0)).collect();
    let gains: Vec<f64> =
        cb2.eval_ideal(&h).iter().map(|&v| analog::hard_sigmoid_sw(v)).collect();
    for c in 0..4 {
        for s in 0..4 {
            let want = x[c * 4 + s] * gains[c];
            assert!(
                (got[c * 4 + s] - want).abs() < 1e-12,
                "c {c} s {s}: {} vs {want}",
                got[c * 4 + s]
            );
        }
    }
}

#[test]
fn residual_adds_unit_input() {
    let layers = r#"
        {"unit":"u","layer":"bn","name":"u.bn","c":3,"weight":"u.bn.gamma"},
        {"unit":"u","layer":"relu","name":"u.act","c":3},
        {"unit":"u","layer":"residual","name":"u.add","c":3}"#;
    let weights = r#"
        {"name":"u.bn.gamma","shape":[3],"offset":0,"len":3},
        {"name":"u.bn.beta","shape":[3],"offset":3,"len":3},
        {"name":"u.bn.mean","shape":[3],"offset":6,"len":3},
        {"name":"u.bn.var","shape":[3],"offset":9,"len":3}"#;
    let blob = vec![1.0, 2.0, 0.5, 0.1, 0.0, -0.1, 0.0, 0.1, 0.0, 1.0, 1.0, 1.0];
    let (m, ws) = load(layers, weights, blob.clone());
    let mut p = PipelineBuilder::new().fidelity(Fidelity::Ideal).build(&m, &ws).unwrap();
    let x = vec![0.5, -0.3, 0.2];
    let got = p.forward(&x).unwrap();
    for ch in 0..3 {
        let k = blob[ch] as f64 / (blob[9 + ch] as f64 + BN_EPS).sqrt();
        let bn = (x[ch] - blob[6 + ch] as f64) * k + blob[3 + ch] as f64;
        let want = bn.max(0.0) + x[ch]; // relu then the unit-input skip
        assert!((got[ch] - want).abs() < 1e-12, "ch {ch}: {} vs {want}", got[ch]);
    }
}

#[test]
fn gap_module_means_per_channel() {
    let layers = r#"{"unit":"cls","layer":"gapool","name":"cls.gap","c":3,"h_in":2,"w_in":2}"#;
    let (m, ws) = load(layers, "", Vec::new());
    let mut p = PipelineBuilder::new().fidelity(Fidelity::Ideal).build(&m, &ws).unwrap();
    assert_eq!((p.in_dim(), p.out_dim()), (12, 3));
    let x: Vec<f64> = (0..12).map(|i| i as f64).collect();
    let got = p.forward(&x).unwrap();
    assert_eq!(got, vec![1.5, 5.5, 9.5]);
}

#[test]
fn classify_batch_picks_identity_labels() {
    let layers = r#"{"unit":"m","layer":"fc","name":"cls","cin":4,"cout":4,"weight":"id.w"}"#;
    let weights = r#"{"name":"id.w","shape":[4,4],"offset":0,"len":16,"scale":1.0}"#;
    let mut blob = vec![0f32; 16];
    for i in 0..4 {
        blob[i * 4 + i] = 1.0;
    }
    let (m, ws) = load(layers, weights, blob);
    let mut p = PipelineBuilder::new().fidelity(Fidelity::Ideal).build(&m, &ws).unwrap();
    let batch: Vec<Vec<f64>> = (0..4)
        .map(|j| (0..4).map(|i| if i == j { 0.3 } else { 0.0 }).collect())
        .collect();
    assert_eq!(p.classify_batch(&batch).unwrap(), vec![0, 1, 2, 3]);
}

#[test]
fn dim_mismatch_fails_at_build_time() {
    let layers = r#"
        {"unit":"m","layer":"fc","name":"fc1","cin":6,"cout":5,"weight":"a.w"},
        {"unit":"m","layer":"fc","name":"fc2","cin":4,"cout":3,"weight":"b.w"}"#;
    let weights = r#"
        {"name":"a.w","shape":[6,5],"offset":0,"len":30,"scale":0.5},
        {"name":"b.w","shape":[4,3],"offset":30,"len":12,"scale":0.5}"#;
    let (m, ws) = load(layers, weights, rand_blob(42, 0.5, 5));
    let err = match PipelineBuilder::new().build(&m, &ws) {
        Ok(_) => panic!("mismatched fc dims must fail at build time"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("expects 4 inputs"), "unexpected error: {err}");
}

#[test]
fn spice_stack_matches_ideal_and_batch_is_consistent() {
    let dev = default_device();
    let base = PipelineBuilder::new().segment(3).workers(2);
    let mut spice =
        base.clone().fidelity(Fidelity::Spice).build_fc_stack(&[8, 6, 4], &dev, 21).unwrap();
    let mut ideal =
        base.fidelity(Fidelity::Ideal).build_fc_stack(&[8, 6, 4], &dev, 21).unwrap();
    let mut rng = Rng::new(4);
    let batch: Vec<Vec<f64>> = (0..3)
        .map(|_| (0..8).map(|_| rng.range_f64(-0.5, 0.5)).collect())
        .collect();
    let got = spice.forward_batch(&batch).unwrap();
    let want = ideal.forward_batch(&batch).unwrap();
    for (g_row, w_row) in got.iter().zip(&want) {
        for (g, w) in g_row.iter().zip(w_row) {
            // op-amp finite-gain tolerance, compounded over two stages
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "spice {g} vs ideal {w}");
        }
    }
    // batch-of-one equals single forward on the Spice path
    for (k, x) in batch.iter().enumerate() {
        let single = spice.forward(x).unwrap();
        for (a, b) in single.iter().zip(&got[k]) {
            assert!((a - b).abs() < 1e-9, "batch {k}: {a} vs {b}");
        }
    }
}

#[test]
fn spice_activation_circuit_matches_behavioural_within_knee() {
    // fc -> hard sigmoid at Fidelity::Spice drives every element through
    // the Fig 4 op-amp circuit (split across worker clones); it must track
    // the behavioural transfer within the diode-knee tolerance
    let layers = r#"
        {"unit":"m","layer":"fc","name":"fc1","cin":4,"cout":3,"weight":"a.w"},
        {"unit":"m","layer":"hsigmoid","name":"act","c":3}"#;
    let weights = r#"{"name":"a.w","shape":[4,3],"offset":0,"len":12,"scale":0.5}"#;
    let (m, ws) = load(layers, weights, rand_blob(12, 0.5, 41));
    let mut spice = PipelineBuilder::new()
        .fidelity(Fidelity::Spice)
        .segment(2)
        .workers(2)
        .build(&m, &ws)
        .unwrap();
    let mut behav = PipelineBuilder::new()
        .fidelity(Fidelity::Behavioural)
        .build(&m, &ws)
        .unwrap();
    let mut rng = Rng::new(6);
    let batch: Vec<Vec<f64>> = (0..2)
        .map(|_| (0..4).map(|_| rng.range_f64(-1.0, 1.0)).collect())
        .collect();
    let got = spice.forward_batch(&batch).unwrap();
    let want = behav.forward_batch(&batch).unwrap();
    for (g_row, w_row) in got.iter().zip(&want) {
        for (g, w) in g_row.iter().zip(w_row) {
            assert!(
                (g - w).abs() < analog::KNEE_TOL,
                "spice activation {g} vs behavioural {w}"
            );
        }
    }
}

#[test]
fn pipelined_spice_stack_matches_sequential_when_warm() {
    // pipelined scheduling only re-slices the batch across unit groups;
    // once every resident factorization is primed (first forward), the
    // overlapped schedule must reproduce the sequential SPICE path
    // bit for bit
    let dev = default_device();
    let mut p = PipelineBuilder::new()
        .fidelity(Fidelity::Spice)
        .segment(3)
        .workers(2)
        .build_fc_stack(&[10, 8, 8, 6], &dev, 33)
        .unwrap();
    assert!(p.n_units() >= 3, "fc stack stages must be independently schedulable");
    let mut rng = Rng::new(5);
    let batch: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..10).map(|_| rng.range_f64(-0.5, 0.5)).collect())
        .collect();
    p.forward_batch(&batch).unwrap(); // warm the factor caches
    let want = p.forward_batch(&batch).unwrap();
    for (workers, micro) in [(2, 2), (3, 1), (4, 0)] {
        let got = p.forward_batch_pipelined(&batch, workers, micro).unwrap();
        assert_eq!(got, want, "workers {workers} micro {micro}");
    }
}

#[test]
fn pipelined_se_and_conv_unit_graph_matches_sequential() {
    // a manifest walk with conv banks, BN, activations, an SE side branch
    // and a residual-closing unit — the pipelined schedule over real module
    // types must equal the sequential walk exactly (behavioural arithmetic
    // is pure, so bit-identical)
    let layers = r#"
        {"unit":"b1","layer":"conv","name":"c0","k":3,"stride":1,"padding":1,
         "cin":2,"cout":2,"h_in":4,"w_in":4,"h_out":4,"w_out":4,"weight":"c0.w"},
        {"unit":"b1","layer":"bn","name":"bn0","c":2,"weight":"bn0.gamma"},
        {"unit":"b1","layer":"relu","name":"a0","c":2},
        {"unit":"b1","layer":"gapool","name":"se.gap","c":2,"h_in":4,"w_in":4},
        {"unit":"b1","layer":"pconv","name":"se.fc1","cin":2,"cout":2,"weight":"s1.w"},
        {"unit":"b1","layer":"relu","name":"se.act1","c":2},
        {"unit":"b1","layer":"pconv","name":"se.fc2","cin":2,"cout":2,"weight":"s2.w"},
        {"unit":"b1","layer":"hsigmoid","name":"se.act2","c":2},
        {"unit":"b1","layer":"residual","name":"b1.add","c":2},
        {"unit":"cls","layer":"gapool","name":"pool","c":2,"h_in":4,"w_in":4},
        {"unit":"cls","layer":"fc","name":"fc","cin":2,"cout":3,"weight":"f.w"}"#;
    let weights = r#"
        {"name":"c0.w","shape":[3,3,2,2],"offset":0,"len":36,"scale":0.5},
        {"name":"bn0.gamma","shape":[2],"offset":36,"len":2},
        {"name":"s1.w","shape":[2,2],"offset":38,"len":4,"scale":0.5},
        {"name":"s2.w","shape":[2,2],"offset":42,"len":4,"scale":0.5},
        {"name":"f.w","shape":[2,3],"offset":46,"len":6,"scale":0.5}"#;
    let (m, ws) = load(layers, weights, rand_blob(52, 0.5, 71));
    let mut p = PipelineBuilder::new()
        .fidelity(Fidelity::Behavioural)
        .build(&m, &ws)
        .unwrap();
    // b1 closes a residual: its span is one atomic unit; cls splits
    assert!(p.units().iter().any(|u| u.closes_residual()));
    let mut rng = Rng::new(17);
    let batch: Vec<Vec<f64>> = (0..5)
        .map(|_| (0..p.in_dim()).map(|_| rng.range_f64(-0.5, 0.5)).collect())
        .collect();
    let want = p.forward_batch(&batch).unwrap();
    for (workers, micro) in [(2, 1), (3, 2), (2, 0)] {
        let got = p.forward_batch_pipelined(&batch, workers, micro).unwrap();
        assert_eq!(got, want, "workers {workers} micro {micro}");
    }
}

#[test]
fn bn_gap_spice_chain_batch_single_and_pipelined_identity() {
    // unit u0 closes a residual around conv + BN + ReLU; cls pools and
    // classifies. At Fidelity::Spice the BN §3.3 pair and the GAP §3.5
    // column are resident netlists, and (a) batched forwards equal
    // per-image forwards within the multi-RHS guarantee, (b) once warm,
    // the §5.2 pipelined schedule is bit-identical to the sequential walk.
    let layers = r#"
        {"unit":"u0","layer":"conv","name":"c0","k":3,"stride":1,"padding":1,
         "cin":2,"cout":2,"h_in":4,"w_in":4,"h_out":4,"w_out":4,"weight":"c0.w"},
        {"unit":"u0","layer":"bn","name":"bn0","c":2,"weight":"bn0.gamma"},
        {"unit":"u0","layer":"relu","name":"a0","c":2},
        {"unit":"u0","layer":"residual","name":"u0.add","c":2},
        {"unit":"cls","layer":"gapool","name":"pool","c":2,"h_in":4,"w_in":4},
        {"unit":"cls","layer":"fc","name":"fc","cin":2,"cout":3,"weight":"f.w"}"#;
    let weights = r#"
        {"name":"c0.w","shape":[3,3,2,2],"offset":0,"len":36,"scale":0.4},
        {"name":"bn0.gamma","shape":[2],"offset":36,"len":2},
        {"name":"bn0.beta","shape":[2],"offset":38,"len":2},
        {"name":"bn0.mean","shape":[2],"offset":40,"len":2},
        {"name":"bn0.var","shape":[2],"offset":42,"len":2},
        {"name":"f.w","shape":[2,3],"offset":44,"len":6,"scale":0.4}"#;
    let mut blob = rand_blob(36, 0.4, 61);
    blob.extend([0.9f32, -1.1, 0.1, -0.2, 0.05, -0.1, 0.8, 1.2]); // γ(one negative) β μ σ²
    blob.extend(rand_blob(6, 0.4, 62));
    let (m, ws) = load(layers, weights, blob);
    let mut p = PipelineBuilder::new()
        .fidelity(Fidelity::Spice)
        .segment(4)
        .workers(2)
        .build(&m, &ws)
        .unwrap();
    // the BN pair and the GAP column are resident circuits, not fallbacks
    assert!(p.spice_circuits() > 0);
    assert!(p
        .stage_coverage()
        .iter()
        .filter(|s| matches!(s.kind, "BN" | "GAPool"))
        .all(|s| s.spice_circuits >= 1));
    let mut rng = Rng::new(19);
    let batch: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..p.in_dim()).map(|_| rng.range_f64(-0.4, 0.4)).collect())
        .collect();
    // batch == single equivalence on the spice path
    let batched = p.forward_batch(&batch).unwrap();
    for (k, x) in batch.iter().enumerate() {
        let single = p.forward(x).unwrap();
        for (a, b) in single.iter().zip(&batched[k]) {
            assert!((a - b).abs() < 1e-9, "batch {k}: single {a} vs batched {b}");
        }
    }
    // warm pipelined == sequential, bit for bit
    let want = p.forward_batch(&batch).unwrap();
    for (workers, micro) in [(2, 2), (3, 1), (2, 0)] {
        let got = p.forward_batch_pipelined(&batch, workers, micro).unwrap();
        assert_eq!(got, want, "workers {workers} micro {micro}");
    }
}

#[test]
fn prog_noise_perturbs_but_preserves_structure() {
    let dev = default_device();
    let mut clean = PipelineBuilder::new()
        .fidelity(Fidelity::Ideal)
        .build_fc_stack(&[10, 6], &dev, 9)
        .unwrap();
    let mut noisy = PipelineBuilder::new()
        .fidelity(Fidelity::Ideal)
        .prog_noise(0.1, 42)
        .build_fc_stack(&[10, 6], &dev, 9)
        .unwrap();
    assert_eq!(clean.memristors(), noisy.memristors(), "noise must not drop devices");
    let x: Vec<f64> = (0..10).map(|i| ((i as f64) * 0.3).sin() * 0.4).collect();
    let a = clean.forward(&x).unwrap();
    let b = noisy.forward(&x).unwrap();
    assert!(a.iter().zip(&b).any(|(p, q)| (p - q).abs() > 1e-9), "noise must perturb");
    assert!(a.iter().zip(&b).all(|(p, q)| (p - q).abs() < 1.0), "noise must stay bounded");
}

#[test]
fn behavioural_clamps_ideal_output_to_rails() {
    // single-layer stack: behavioural == ideal followed by the TIA rail
    // clip, element for element
    let dev = default_device(); // v_rail = 8 V
    let mut ideal = PipelineBuilder::new()
        .fidelity(Fidelity::Ideal)
        .build_fc_stack(&[64, 8], &dev, 77)
        .unwrap();
    let mut behav = PipelineBuilder::new()
        .fidelity(Fidelity::Behavioural)
        .build_fc_stack(&[64, 8], &dev, 77)
        .unwrap();
    // drive hard: +25 V inputs (unphysical) so saturation is plausible; the
    // exact clamp identity must hold either way
    let x = vec![25.0; 64];
    let yi = ideal.forward(&x).unwrap();
    let yb = behav.forward(&x).unwrap();
    assert!(yb.iter().all(|v| v.abs() <= dev.v_rail + 1e-12));
    for (b, i) in yb.iter().zip(&yi) {
        assert_eq!(*b, i.clamp(-dev.v_rail, dev.v_rail), "clamp identity violated");
    }
}
