//! Serving metrics: request counters, latency histogram, throughput,
//! executor utilization and per-stage wall time.
//!
//! Since the `memx::telemetry` registry landed, this module is a **view**:
//! every counter and histogram lives in a per-server
//! [`Registry`](crate::telemetry::metrics::Registry) (exported over HTTP by
//! `memx serve --metrics-addr` as Prometheus text / JSON), and [`Snapshot`]
//! is a point-in-time read of that registry plus the process-wide solver /
//! kernel counters. The printed output is unchanged from the pre-registry
//! implementation, with p99.9 and the log2-bucket quantization bounds
//! appended to the latency section.
//!
//! The batcher thread records queue/end-to-end latencies and how long the
//! executor itself was busy per dispatched batch; pipeline-backed executors
//! additionally surface the scheduler's per-unit wall-time accounting
//! ([`StageStat`]) which is merged here and printed with the snapshot.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::pipeline::{ModuleDrift, StageStat};
use crate::telemetry::metrics::{Counter, Gauge, Histogram, Registry};

/// Poison-tolerant lock: a panicking batcher thread must not take the
/// metrics down with it — a poisoned stage table is still a table, so
/// recover the guard and keep serving reads.
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// The server's metrics surface — counter/histogram handles into its
/// [`Registry`]. Handles are lock-free on the record path; the registry is
/// what `--metrics-addr` exports.
pub struct Metrics {
    registry: Arc<Registry>,
    pub requests: Counter,
    pub completed: Counter,
    pub errors: Counter,
    pub batches: Counter,
    pub padded_slots: Counter,
    /// batches whose logit-margin EWMA crossed the drift threshold
    pub drift_detections: Counter,
    /// successful executor recalibrations (crossbar reprogram cycles)
    pub recalibrations: Counter,
    /// current depth of the request queue (sampled by the batcher loop)
    pub queue_depth: Gauge,
    /// nanoseconds the executor spent inside `run_batch`
    exec_busy_ns: Counter,
    lat: Histogram,
    queue_lat: Histogram,
    /// per-stage (unit) wall time merged from the scheduler, chain order
    stages: Mutex<Vec<StageCell>>,
    /// latest per-module drift telemetry (cumulative state, so each
    /// report replaces the table rather than accumulating)
    drift: Mutex<Vec<ModuleDrift>>,
}

struct StageCell {
    name: String,
    ns: u128,
    calls: u64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub completed: u64,
    pub errors: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub lat_mean: Duration,
    pub lat_p50: Duration,
    pub lat_p95: Duration,
    pub lat_p99: Duration,
    pub lat_p999: Duration,
    /// log2-bucket edges bracketing the true p99 — the quantization error
    /// bar of `lat_p99` (which reports the conservative upper edge), so
    /// benches can state `p99 ∈ [lo, hi]` instead of over-claiming a point
    pub lat_p99_bounds: (Duration, Duration),
    pub lat_max: Duration,
    pub queue_mean: Duration,
    /// total time the executor spent answering batches
    pub exec_busy: Duration,
    /// drift-watchdog triggers and the reprogram cycles they caused
    pub drift_detections: u64,
    pub recalibrations: u64,
    /// iterative-solver direct-factorization fallbacks (process-wide,
    /// read from [`crate::spice::solver_fallbacks`] at snapshot time)
    pub solver_fallbacks: u64,
    /// nanoseconds spent in triangular-substitution kernels (process-wide,
    /// read from [`crate::backend::subst_ns`] at snapshot time)
    pub kernel_subst_ns: u64,
    /// nanoseconds spent in GMRES matvec kernels (process-wide,
    /// read from [`crate::backend::matvec_ns`] at snapshot time)
    pub kernel_matvec_ns: u64,
    /// per-stage wall time in chain order (pipeline executors only)
    pub stages: Vec<StageStat>,
    /// per-module drift telemetry in chain order (fault-capable modules
    /// of pipeline executors only; see [`ModuleDrift`])
    pub drift_modules: Vec<ModuleDrift>,
}

impl Metrics {
    /// Build the metrics surface over a fresh registry, wiring in the
    /// process-wide solver/kernel/trace series as render-time views.
    pub fn new() -> Metrics {
        let registry = Arc::new(Registry::default());
        let m = Metrics {
            requests: registry.counter("memx_requests_total", "classification requests submitted"),
            completed: registry.counter("memx_requests_completed_total", "requests answered"),
            errors: registry.counter("memx_request_errors_total", "requests failed"),
            batches: registry.counter("memx_batches_total", "executor batches dispatched"),
            padded_slots: registry
                .counter("memx_padded_slots_total", "padding slots in dispatched batches"),
            drift_detections: registry
                .counter("memx_drift_detections_total", "drift-watchdog EWMA threshold crossings"),
            recalibrations: registry
                .counter("memx_recalibrations_total", "successful crossbar reprogram cycles"),
            queue_depth: registry
                .gauge("memx_queue_depth", "request queue depth sampled by the batcher"),
            exec_busy_ns: registry
                .counter("memx_executor_busy_ns_total", "nanoseconds inside run_batch"),
            lat: registry
                .histogram("memx_request_latency_seconds", "end-to-end request latency"),
            queue_lat: registry.histogram("memx_queue_wait_seconds", "request queue wait"),
            stages: Mutex::new(Vec::new()),
            drift: Mutex::new(Vec::new()),
            registry,
        };
        let r = &m.registry;
        r.register_fn(
            "memx_solver_fallbacks_total",
            "iterative-solver direct-factorization fallbacks (process-wide)",
            || crate::spice::solver_fallbacks() as f64,
        );
        r.register_fn(
            "memx_solver_cold_fallbacks_total",
            "cold-start iterative-solver fallbacks (process-wide)",
            || crate::spice::solver_cold_fallbacks() as f64,
        );
        r.register_fn(
            "memx_gmres_iterations_total",
            "GMRES inner iterations across all solves (process-wide)",
            || crate::spice::gmres_iterations() as f64,
        );
        r.register_fn(
            "memx_precond_reuses_total",
            "warm-preconditioner reuses across iterative solves (process-wide)",
            || crate::spice::precond_reuses() as f64,
        );
        r.register_fn(
            "memx_kernel_subst_seconds",
            "wall seconds in triangular-substitution kernels (process-wide)",
            || crate::backend::subst_ns() as f64 * 1e-9,
        );
        r.register_fn(
            "memx_kernel_matvec_seconds",
            "wall seconds in GMRES matvec kernels (process-wide)",
            || crate::backend::matvec_ns() as f64 * 1e-9,
        );
        r.register_fn(
            "memx_trace_events_dropped_total",
            "trace events lost to the collector cap (process-wide)",
            || crate::telemetry::dropped_events() as f64,
        );
        m
    }

    /// The backing registry — what `--metrics-addr` exports.
    pub fn registry(&self) -> Arc<Registry> {
        self.registry.clone()
    }

    pub fn record_latency(&self, d: Duration) {
        self.lat.record(d);
    }

    pub fn record_queue(&self, d: Duration) {
        self.queue_lat.record(d);
    }

    /// Account one executor dispatch (time spent inside `run_batch`).
    pub fn record_exec(&self, d: Duration) {
        self.exec_busy_ns.add(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Merge a scheduler stage-time drain into the per-stage table
    /// (first-seen order is kept, which is chain order for a pipeline).
    pub fn record_stage_stats(&self, stats: &[StageStat]) {
        if stats.is_empty() {
            return;
        }
        let mut table = locked(&self.stages);
        for s in stats {
            if s.calls == 0 && s.total.is_zero() {
                continue;
            }
            match table.iter_mut().find(|c| c.name == s.name) {
                Some(cell) => {
                    cell.ns += s.total.as_nanos();
                    cell.calls += s.calls;
                }
                None => table.push(StageCell {
                    name: s.name.clone(),
                    ns: s.total.as_nanos(),
                    calls: s.calls,
                }),
            }
        }
    }

    /// Replace the drift telemetry table with the pipeline's latest state
    /// ([`crate::pipeline::Pipeline::drift_telemetry`] — already
    /// cumulative, so the newest report wins).
    pub fn record_drift(&self, telemetry: Vec<ModuleDrift>) {
        if telemetry.is_empty() {
            return;
        }
        *locked(&self.drift) = telemetry;
    }

    pub fn snapshot(&self) -> Snapshot {
        let lat = self.lat.snapshot();
        let q = self.queue_lat.snapshot();
        let stages = locked(&self.stages)
            .iter()
            .map(|c| StageStat {
                name: c.name.clone(),
                total: Duration::from_nanos(c.ns.min(u64::MAX as u128) as u64),
                calls: c.calls,
            })
            .collect();
        Snapshot {
            requests: self.requests.get(),
            completed: self.completed.get(),
            errors: self.errors.get(),
            batches: self.batches.get(),
            padded_slots: self.padded_slots.get(),
            lat_mean: lat.mean(),
            lat_p50: lat.quantile(0.50),
            lat_p95: lat.quantile(0.95),
            lat_p99: lat.quantile(0.99),
            lat_p999: lat.quantile(0.999),
            lat_p99_bounds: lat.quantile_bounds(0.99),
            lat_max: lat.max(),
            queue_mean: q.mean(),
            exec_busy: Duration::from_nanos(self.exec_busy_ns.get()),
            drift_detections: self.drift_detections.get(),
            recalibrations: self.recalibrations.get(),
            solver_fallbacks: crate::spice::solver_fallbacks(),
            kernel_subst_ns: crate::backend::subst_ns(),
            kernel_matvec_ns: crate::backend::matvec_ns(),
            stages,
            drift_modules: locked(&self.drift).clone(),
        }
    }
}

impl Snapshot {
    /// Fraction of the wall the executor spent answering batches.
    pub fn utilization(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.exec_busy.as_secs_f64() / wall.as_secs_f64()
    }

    pub fn print(&self, wall: Duration) {
        let thr = self.completed as f64 / wall.as_secs_f64().max(1e-9);
        println!("  requests      {}", self.requests);
        println!("  completed     {}", self.completed);
        println!("  errors        {}", self.errors);
        println!("  batches       {} (padded slots {})", self.batches, self.padded_slots);
        println!("  throughput    {thr:.1} img/s");
        println!(
            "  latency       mean {:?}  p50 {:?}  p95 {:?}  p99 {:?}  p999 {:?}  max {:?}",
            self.lat_mean, self.lat_p50, self.lat_p95, self.lat_p99, self.lat_p999, self.lat_max
        );
        if self.completed > 0 {
            // quantiles above are quantized to log2 bucket edges — state
            // the p99 bracket so downstream benches don't over-claim
            println!(
                "                (log2 buckets: p99 in [{:?}, {:?}])",
                self.lat_p99_bounds.0, self.lat_p99_bounds.1
            );
        }
        println!("  queue wait    mean {:?}", self.queue_mean);
        println!(
            "  executor busy {:?} ({:.1}% of wall)",
            self.exec_busy,
            self.utilization(wall) * 100.0
        );
        if self.drift_detections > 0 || self.recalibrations > 0 {
            println!(
                "  drift watch   {} detections, {} recalibrations",
                self.drift_detections, self.recalibrations
            );
        }
        if self.solver_fallbacks > 0 {
            println!("  solver        {} iterative->direct fallbacks", self.solver_fallbacks);
        }
        if self.kernel_subst_ns > 0 || self.kernel_matvec_ns > 0 {
            println!(
                "  kernels       substitution {:?}  matvec {:?}",
                Duration::from_nanos(self.kernel_subst_ns),
                Duration::from_nanos(self.kernel_matvec_ns)
            );
        }
        if !self.stages.is_empty() {
            // heaviest stages first; the chain is long, keep the tail quiet
            let mut by_cost: Vec<&StageStat> = self.stages.iter().collect();
            by_cost.sort_by(|a, b| b.total.cmp(&a.total));
            let shown = by_cost.len().min(8);
            println!("  stage wall    (top {shown} of {})", self.stages.len());
            for s in &by_cost[..shown] {
                let mean = if s.calls > 0 {
                    s.total / s.calls.max(1) as u32
                } else {
                    Duration::ZERO
                };
                println!(
                    "    {:<18} total {:?}  calls {}  mean {:?}",
                    s.name, s.total, s.calls, mean
                );
            }
        }
        // device-ageing table: only modules that have actually drifted or
        // been rewritten, most-decayed first
        let mut aged: Vec<&ModuleDrift> = self
            .drift_modules
            .iter()
            .filter(|d| d.drift_gain != 1.0 || d.reprograms > 0)
            .collect();
        if !aged.is_empty() {
            aged.sort_by(|a, b| {
                a.drift_gain.partial_cmp(&b.drift_gain).unwrap_or(std::cmp::Ordering::Equal)
            });
            let shown = aged.len().min(8);
            println!("  device drift  (worst {shown} of {})", aged.len());
            for d in &aged[..shown] {
                println!(
                    "    {:<18} gain {:.4}  steps {}  reprograms {} (last rewrote {})",
                    d.name, d.drift_gain, d.fault_steps, d.reprograms, d.devices_rewritten
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let m = Metrics::default();
        for i in 1..=1000u64 {
            m.record_latency(Duration::from_micros(i * 10));
        }
        let s = m.snapshot();
        assert!(s.lat_p50 <= s.lat_p95);
        assert!(s.lat_p95 <= s.lat_p99);
        assert!(s.lat_p99 <= s.lat_p999);
        assert!(s.lat_p99 <= Duration::from_micros(s.lat_max.as_micros() as u64 * 2));
        assert!(s.lat_mean > Duration::ZERO);
        // the quantization bracket is honest: it contains the true p99
        // (9.9 ms for this uniform 10µs..10ms sweep) and the point value
        // is its conservative upper edge
        let (lo, hi) = s.lat_p99_bounds;
        assert!(lo <= Duration::from_micros(9900) && Duration::from_micros(9900) <= hi);
        assert_eq!(s.lat_p99, hi);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.lat_mean, Duration::ZERO);
        assert_eq!(s.lat_p99, Duration::ZERO);
        assert_eq!(s.lat_p999, Duration::ZERO);
        assert_eq!(s.exec_busy, Duration::ZERO);
        assert!(s.stages.is_empty());
    }

    #[test]
    fn exec_busy_and_utilization() {
        let m = Metrics::default();
        m.record_exec(Duration::from_millis(30));
        m.record_exec(Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.exec_busy, Duration::from_millis(50));
        let u = s.utilization(Duration::from_millis(100));
        assert!((u - 0.5).abs() < 1e-9, "utilization {u}");
        assert_eq!(s.utilization(Duration::ZERO), 0.0);
    }

    #[test]
    fn stage_stats_merge_by_name_in_order() {
        let m = Metrics::default();
        let drain = |a_ms: u64, b_ms: u64| {
            vec![
                StageStat {
                    name: "bneck1".into(),
                    total: Duration::from_millis(a_ms),
                    calls: 2,
                },
                StageStat {
                    name: "bneck2".into(),
                    total: Duration::from_millis(b_ms),
                    calls: 2,
                },
                // zero rows (drained twice between batches) are dropped
                StageStat { name: "idle".into(), total: Duration::ZERO, calls: 0 },
            ]
        };
        m.record_stage_stats(&drain(3, 5));
        m.record_stage_stats(&drain(1, 2));
        let s = m.snapshot();
        assert_eq!(s.stages.len(), 2);
        assert_eq!(s.stages[0].name, "bneck1");
        assert_eq!(s.stages[0].total, Duration::from_millis(4));
        assert_eq!(s.stages[0].calls, 4);
        assert_eq!(s.stages[1].total, Duration::from_millis(7));
    }

    #[test]
    fn drift_table_replaces_not_accumulates() {
        let m = Metrics::default();
        let row = |gain: f64, steps: u64| ModuleDrift {
            name: "fc1".into(),
            kind: "FC",
            drift_gain: gain,
            fault_steps: steps,
            reprograms: 0,
            devices_rewritten: 0,
        };
        m.record_drift(vec![row(0.98, 1)]);
        m.record_drift(vec![row(0.95, 2)]);
        let s = m.snapshot();
        assert_eq!(s.drift_modules.len(), 1);
        assert!((s.drift_modules[0].drift_gain - 0.95).abs() < 1e-12);
        assert_eq!(s.drift_modules[0].fault_steps, 2);
        // empty reports keep the last table instead of wiping it
        m.record_drift(Vec::new());
        assert_eq!(m.snapshot().drift_modules.len(), 1);
    }

    #[test]
    fn registry_view_exports_serving_series() {
        let m = Metrics::default();
        m.requests.add(3);
        m.completed.add(2);
        m.record_latency(Duration::from_micros(500));
        m.queue_depth.set(4.0);
        let text = m.registry().render_prometheus();
        assert!(text.contains("memx_requests_total 3"), "{text}");
        assert!(text.contains("memx_requests_completed_total 2"), "{text}");
        assert!(text.contains("memx_request_latency_seconds_count 1"), "{text}");
        assert!(text.contains("memx_queue_depth 4"), "{text}");
        // process-wide views are present even before any solve ran
        assert!(text.contains("memx_solver_fallbacks_total"), "{text}");
        assert!(text.contains("memx_gmres_iterations_total"), "{text}");
        // and the snapshot's counts agree with the registry's
        assert_eq!(m.snapshot().requests, 3);
    }
}
