//! SPICE solver scaling — MNA solve cost vs system size for the two
//! elimination orderings and the dense fallback (supports §Perf and the
//! Fig 7 mechanism analysis: Natural ordering goes superlinear on
//! monolithic crossbars; Smart stays near-linear).
//!
//!   cargo bench --bench bench_spice

use memx::spice::solve::{solve_dense, Ordering, SparseSys};
use memx::spice::Circuit;
use memx::util::bench::{black_box, Bench};
use memx::util::prng::Rng;

/// Build the MNA system of an n-input, c-column ideal-TIA crossbar.
fn crossbar_circuit(inputs: usize, cols: usize, rng: &mut Rng) -> Circuit {
    let mut c = Circuit::new("bench crossbar");
    let in_nodes: Vec<usize> = (0..inputs).map(|r| c.node(&format!("in{r}"))).collect();
    for (r, &node) in in_nodes.iter().enumerate() {
        c.vsource(&format!("V{r}"), node, 0, (r as f64 * 0.7).sin() * 0.3);
    }
    for col in 0..cols {
        let vcol = c.node(&format!("vcol{col}"));
        let vout = c.node(&format!("vout{col}"));
        for (r, &node) in in_nodes.iter().enumerate() {
            let g = 0.05 + 0.9 * rng.f64();
            c.resistor(&format!("RM{r}_{col}"), node, vcol, 100.0 / g);
        }
        c.resistor(&format!("RF{col}"), vcol, vout, 50.0);
        c.opamp(&format!("E{col}"), 0, vcol, vout);
    }
    c
}

fn main() {
    let mut b = Bench::quick();
    let mut rng = Rng::new(31);

    // dense baseline on small systems
    for &n in &[32usize, 96, 192] {
        let mut a = vec![vec![0.0; n]; n];
        let mut bb = vec![0.0; n];
        for i in 0..n {
            for _ in 0..4 {
                a[i][rng.below(n)] += rng.range_f64(-1.0, 1.0);
            }
            a[i][i] += 4.0;
            bb[i] = rng.range_f64(-1.0, 1.0);
        }
        b.run(&format!("dense LU n={n}"), || {
            black_box(solve_dense(&a, &bb).unwrap());
        });
    }

    // sparse orderings on crossbar MNA systems
    for &(inputs, cols) in &[(128usize, 32usize), (256, 64), (512, 128)] {
        let circuit = crossbar_circuit(inputs, cols, &mut rng);
        for ord in [Ordering::Smart, Ordering::Natural] {
            b.run(&format!("mna {inputs}x{cols} {ord:?}"), || {
                black_box(circuit.dc_op_with(ord).unwrap());
            });
        }
    }

    // raw sparse system: block-diagonal (segmented limit case)
    for &blocks in &[200usize, 800] {
        let n = blocks * 3;
        let mut s = SparseSys::new(n);
        for k in 0..blocks {
            let i = 3 * k;
            for d in 0..3 {
                s.add(i + d, i + d, 4.0 + d as f64);
            }
            s.add(i, i + 1, 1.0);
            s.add(i + 1, i + 2, 1.0);
            s.add(i + 2, i, 0.5);
            s.add_b(i, 1.0);
        }
        b.run(&format!("block-diag {blocks}x3"), || {
            black_box(s.solve().unwrap());
        });
    }

    b.table("SPICE solver scaling");
}
