//! E3 / Table 3 + Fig 7 (construction half) — netlist construction time of
//! memristor crossbars for the different layer types and sizes.
//!
//!   cargo bench --bench bench_construction
//!
//! The paper's Table 3 reports seconds-scale construction for crossbars up
//! to 2048x900 (conv) / 1024-input GAP; the claim under test is that the
//! framework emits netlists in seconds, not the days of manual layout.

use memx::mapper::{self, MapMode};
use memx::netlist;
use memx::nn::DeviceJson;
use memx::util::bench::{black_box, Bench};

fn device() -> DeviceJson {
    DeviceJson {
        r_on: 100.0,
        r_off: 16000.0,
        levels: 64,
        prog_sigma: 0.01,
        v_in: 2.5e-3,
        v_rail: 24.0,
        t_mem: 1e-10,
        slew_rate: 1e7,
        v_swing: 5.0,
        p_opamp: 1e-3,
        p_memristor: 1.1e-6,
        p_aux: 5e-4,
        t_opamp: 5e-7,
    }
}

fn main() {
    let dev = device();
    let mut b = Bench::default();

    // --- FC crossbars (Fig 7's x-axis: 64..1024 in/out) ---
    for &(cin, cout) in &[(64usize, 64usize), (256, 256), (512, 512), (1024, 1024)] {
        b.run(&format!("fc {cin}x{cout}: map+quantize+layout"), || {
            black_box(mapper::build_synthetic_fc(cin, cout, 64, MapMode::Inverted, 7));
        });
        let cb = mapper::build_synthetic_fc(cin, cout, 64, MapMode::Inverted, 7);
        let segs = netlist::plan_segments(cb.cols, 0);
        b.run(&format!("fc {cin}x{cout}: emit netlist text"), || {
            black_box(netlist::emit_crossbar(&cb, &dev, &segs[0], None, 1));
        });
    }

    // --- conv-channel crossbars (Table 3 conv rows: 128x36 .. 2048x900) ---
    for &(hw, k) in &[(8usize, 3usize), (16, 3), (30, 5)] {
        let geom = mapper::layout::ConvXbarGeom::from_conv(hw, hw, k, 1, 0);
        let kernel: Vec<f64> = (0..k * k).map(|i| (i as f64 - 4.0) / 8.0).collect();
        b.run(
            &format!("conv {}x{}: place kernel (Alg 1)", geom.rows(), geom.cols()),
            || {
                black_box(mapper::layout::place_conv_kernel(&geom, &kernel, true));
            },
        );
    }

    // --- GAP crossbars (Table 3: 128x1 .. 1024x1) ---
    for &n in &[128usize, 512, 1024] {
        b.run(&format!("gap {n}x1: place"), || {
            black_box(mapper::layout::place_gap(n));
        });
    }

    b.table("Table 3 / Fig 7 — construction time");
    println!("\npaper Table 3: conv 2048x900 built in 0.390 s; all rows sub-second —");
    println!("shape check: every construction above must be far below 1 s.");
}
