//! Support utilities hand-rolled for the offline environment: JSON codec,
//! PRNG, binary artifact IO, scoped thread pool, CLI flags, bench and
//! property-test harnesses (serde/rand/rayon/clap/criterion/proptest are not
//! in the image's offline crate cache — DESIGN.md §4 S17).
pub mod bench;
pub mod bin;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prng;
pub mod prop;
