//! Mini property-testing harness (proptest is not in the offline cache).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! and, on failure, performs a simple halving shrink over the generator seed
//! space is not possible — instead we re-run with the failing seed printed so
//! the case is reproducible, and shrink *sized* inputs when the generator
//! supports it via the `size` argument of [`Gen::generate`].

use super::prng::Rng;

/// A generator: seeded, sized random value.
pub trait Gen {
    type Value;
    fn generate(&self, rng: &mut Rng, size: usize) -> Self::Value;
}

impl<T, F: Fn(&mut Rng, usize) -> T> Gen for F {
    type Value = T;
    fn generate(&self, rng: &mut Rng, size: usize) -> T {
        self(rng, size)
    }
}

/// Run a property over `cases` random inputs with growing size.
/// Panics with the seed + size of the first failure (after shrinking size).
pub fn check<G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    G: Gen,
    P: Fn(&G::Value) -> bool,
{
    let base_seed = 0xC0FFEE ^ name.len() as u64;
    for case in 0..cases {
        let size = 1 + (case * 25) / cases.max(1); // grow 1..=25
        let seed = base_seed.wrapping_add(case as u64 * 0x9E3779B9);
        let v = gen.generate(&mut Rng::new(seed), size);
        if !prop(&v) {
            // shrink: retry with smaller sizes, same seed, find minimal failing size
            let mut min_fail = size;
            for s in 1..size {
                let v2 = gen.generate(&mut Rng::new(seed), s);
                if !prop(&v2) {
                    min_fail = s;
                    break;
                }
            }
            panic!(
                "property '{name}' failed: case {case}, seed {seed:#x}, size {min_fail} \
                 (reproduce: Rng::new({seed:#x}), size {min_fail})"
            );
        }
    }
}

/// Common generator: f32 vector with values in [-amp, amp].
pub fn vec_f32(amp: f32) -> impl Gen<Value = Vec<f32>> {
    move |rng: &mut Rng, size: usize| {
        let n = 1 + rng.below(size * 8);
        (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * amp).collect()
    }
}

/// Common generator: matrix dims (rows, cols) growing with size.
pub fn dims() -> impl Gen<Value = (usize, usize)> {
    |rng: &mut Rng, size: usize| (1 + rng.below(size * 6), 1 + rng.below(size * 6))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("abs-nonneg", 50, vec_f32(3.0), |v| v.iter().all(|x| x.abs() >= 0.0));
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics() {
        check("always-false", 5, dims(), |_| false);
    }

    #[test]
    fn dims_positive() {
        check("dims-positive", 50, dims(), |&(r, c)| r >= 1 && c >= 1);
    }
}
