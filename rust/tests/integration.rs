//! Integration tests over the real trained artifacts (no PJRT needed here —
//! see e2e_runtime.rs for the executable path). Skipped gracefully when
//! `make artifacts` has not run.

use std::path::{Path, PathBuf};

use memx::mapper::{self, MapMode};
use memx::netlist;
use memx::nn::{Layer, Manifest, WeightStore};
use memx::pipeline::{image_to_input, Fidelity, PipelineBuilder};
use memx::power;
use memx::spice::solve::Ordering;
use memx::util::bin::Dataset;

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_loads_and_is_consistent() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.num_classes, 10);
    assert_eq!(m.img, 32);
    assert!(m.digital_test_acc > 0.9, "trained model must clear 90%");
    assert_eq!(m.units().len(), 14); // input + 11 bottlenecks + last + classifier
    // Eq 1 holds for every conv
    for l in &m.layers {
        if let Layer::Conv(g) | Layer::DwConv(g) = l {
            g.check_geometry().unwrap();
        }
    }
    // every referenced weight exists in the table
    for l in &m.layers {
        let wname = match l {
            Layer::Conv(g) | Layer::DwConv(g) => Some(g.weight.clone()),
            Layer::Fc { weight, .. } | Layer::PConv { weight, .. } => Some(weight.clone()),
            _ => None,
        };
        if let Some(w) = wname {
            assert!(m.weight_entry(&w).is_some(), "missing weight {w}");
        }
    }
}

#[test]
fn weight_store_tensors_match_manifest_shapes() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ws = WeightStore::load(&dir, &m).unwrap();
    for e in &m.weights {
        let t = ws.get(&e.name).unwrap();
        assert_eq!(t.numel(), e.len, "{}", e.name);
        assert_eq!(t.shape, e.shape, "{}", e.name);
        // analog scale must bound the data
        if let Some(s) = t.scale {
            assert!(t.max_abs() as f64 <= s * (1.0 + 1e-5), "{}", e.name);
        }
    }
}

#[test]
fn dataset_loads_and_is_balanced() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ds = Dataset::load(&dir.join(&m.dataset_file)).unwrap();
    assert_eq!(ds.n, m.dataset_n);
    assert_eq!((ds.h, ds.w, ds.c), (32, 32, 3));
    assert!(ds.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    let mut counts = [0usize; 10];
    for &l in &ds.labels {
        counts[l as usize] += 1;
    }
    assert!(counts.iter().all(|&c| c == ds.n / 10));
}

#[test]
fn table4_mapping_totals_sane() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ws = WeightStore::load(&dir, &m).unwrap();
    let net = mapper::map_network(&m, &ws, MapMode::Inverted).unwrap();
    assert_eq!(net.layers.len(), m.layers.len());
    assert!(net.total_memristors() > 100_000, "scaled net places many devices");
    assert!(net.total_opamps() > 1_000);
    assert!(net.memristor_stages() > 50);
    // actual placed devices never exceed the paper's closed-form bound
    for l in &net.layers {
        if l.kind == "Conv" || l.kind == "DConv" {
            assert!(
                l.memristors <= l.formula_memristors,
                "{}: {} > formula {}",
                l.name,
                l.memristors,
                l.formula_memristors
            );
        }
    }
}

#[test]
fn opamp_halving_claim() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ws = WeightStore::load(&dir, &m).unwrap();
    let inv = mapper::map_network(&m, &ws, MapMode::Inverted).unwrap();
    let dual = mapper::map_network(&m, &ws, MapMode::Dual).unwrap();
    assert_eq!(inv.total_memristors(), dual.total_memristors());
    let ratio = inv.total_opamps() as f64 / dual.total_opamps() as f64;
    // crossbar ports halve exactly; activation/CMOS op-amps are mode-free,
    // so the overall ratio sits between 0.5 and 1.0, close to 0.5
    assert!(ratio > 0.45 && ratio < 0.75, "op-amp ratio {ratio}");
}

#[test]
fn trained_fc_crossbar_spice_matches_ideal() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ws = WeightStore::load(&dir, &m).unwrap();
    for mode in [MapMode::Inverted, MapMode::Dual] {
        let cb = mapper::build_fc_crossbar(&m, &ws, "cls.fc2", mode).unwrap();
        let inputs: Vec<f64> =
            (0..cb.region).map(|i| ((i as f64) * 0.21).sin() * 0.5).collect();
        let ideal = cb.eval_ideal(&inputs);
        let segs = netlist::plan_segments(cb.cols, 0);
        let text = netlist::emit_crossbar(&cb, &m.device, &segs[0], Some(&inputs), 1);
        let circuit = netlist::parse(&text).unwrap();
        let outs =
            netlist::solve_segment_outputs(&circuit, &segs[0], mode.inverted(), Ordering::Smart)
                .unwrap();
        for (c, (got, want)) in outs.iter().zip(&ideal).enumerate() {
            assert!(
                (got - want).abs() < 1e-3,
                "{mode:?} col {c}: spice {got} vs ideal {want}"
            );
        }
    }
}

#[test]
fn netlist_files_roundtrip_from_disk() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ws = WeightStore::load(&dir, &m).unwrap();
    let out = std::env::temp_dir().join("memx_netlist_test");
    let files =
        netlist::emit_layer_netlists(&m, &ws, "cls.fc2", MapMode::Inverted, 4, &out).unwrap();
    assert!(files.len() >= 2, "10 cols / 4 per seg -> 3 files");
    for f in &files {
        let text = std::fs::read_to_string(f).unwrap();
        let c = netlist::parse(&text).unwrap();
        assert!(!c.elements.is_empty());
    }
    std::fs::remove_dir_all(out).ok();
}

#[test]
fn segmented_equals_monolithic_on_trained_layer() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ws = WeightStore::load(&dir, &m).unwrap();
    let cb = mapper::build_fc_crossbar(&m, &ws, "cls.fc2", MapMode::Inverted).unwrap();
    let inputs: Vec<f64> = (0..cb.region).map(|i| (i as f64 / 50.0).cos() * 0.3).collect();
    let run = |segment: usize| -> Vec<f64> {
        let segs = netlist::plan_segments(cb.cols, segment);
        segs.iter()
            .flat_map(|seg| {
                let text =
                    netlist::emit_crossbar(&cb, &m.device, seg, Some(&inputs), segs.len());
                netlist::solve_segment_outputs(
                    &netlist::parse(&text).unwrap(),
                    seg,
                    true,
                    Ordering::Smart,
                )
                .unwrap()
            })
            .collect()
    };
    let mono = run(0);
    let seg = run(3);
    for (a, b) in mono.iter().zip(&seg) {
        assert!((a - b).abs() < 1e-9);
    }
}

#[test]
fn latency_energy_models_on_trained_network() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ws = WeightStore::load(&dir, &m).unwrap();
    let net = mapper::map_network(&m, &ws, MapMode::Inverted).unwrap();
    let t = power::latency(&net, &m.device);
    let e = power::energy(&net, &m.device, &t);
    // µs-scale analog inference, far below the paper's CPU/GPU baselines
    assert!(t.total > 1e-6 && t.total < 1e-3, "latency {}", t.total);
    assert!(t.total < power::T_GPU_RTX4090);
    assert!(e.total > 0.0 && e.total < power::E_CPU_I7_12700);
    let tp = power::latency_pipelined(&net, &m.device);
    assert!(tp.total < t.total);
    assert!(power::T_GPU_RTX4090 / tp.total > 100.0, "pipelined regime beats GPU >100x");
}

#[test]
fn pipeline_layer_spice_matches_ideal_on_trained_fc() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ws = WeightStore::load(&dir, &m).unwrap();
    let base = PipelineBuilder::new().segment(4);
    let mut spice =
        base.clone().fidelity(Fidelity::Spice).build_layer(&m, &ws, "cls.fc2").unwrap();
    let mut ideal = base.fidelity(Fidelity::Ideal).build_layer(&m, &ws, "cls.fc2").unwrap();
    let batch: Vec<Vec<f64>> = (0..3)
        .map(|k| (0..spice.in_dim()).map(|i| ((i + k) as f64 * 0.21).sin() * 0.5).collect())
        .collect();
    let got = spice.forward_batch(&batch).unwrap();
    let want = ideal.forward_batch(&batch).unwrap();
    for (k, (g_row, w_row)) in got.iter().zip(&want).enumerate() {
        for (c, (g, w)) in g_row.iter().zip(w_row).enumerate() {
            assert!((g - w).abs() < 1e-3, "vector {k} col {c}: spice {g} vs ideal {w}");
        }
    }
}

#[test]
fn pipeline_full_manifest_builds_and_classifies() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ws = WeightStore::load(&dir, &m).unwrap();
    let mut p = PipelineBuilder::new()
        .fidelity(Fidelity::Behavioural)
        .build(&m, &ws)
        .expect("full manifest compiles into a pipeline");
    assert_eq!(p.in_dim(), 3 * m.img * m.img);
    assert_eq!(p.out_dim(), m.num_classes);
    // resource hooks mirror the Table 4 mapper totals exactly
    let net = mapper::map_network(&m, &ws, MapMode::Inverted).unwrap();
    assert_eq!(p.memristors(), net.total_memristors());
    assert_eq!(p.opamps(), net.total_opamps());
    assert_eq!(p.memristor_stages(), net.memristor_stages());
    // batched end-to-end classification produces sane labels
    let ds = Dataset::load(&dir.join(&m.dataset_file)).unwrap();
    let n = 4.min(ds.n);
    let batch: Vec<Vec<f64>> =
        (0..n).map(|i| image_to_input(ds.image(i), ds.h, ds.w, ds.c)).collect();
    let labels = p.classify_batch(&batch).unwrap();
    assert_eq!(labels.len(), n);
    assert!(labels.iter().all(|&l| l < m.num_classes));
}

#[test]
fn conv_crossbar_builds_for_every_conv_layer() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ws = WeightStore::load(&dir, &m).unwrap();
    let mut checked = 0;
    for l in &m.layers {
        if let Layer::Conv(g) | Layer::DwConv(g) = l {
            let cb = mapper::build_conv_crossbar(&m, &ws, &g.name, 0, 0, MapMode::Inverted)
                .unwrap();
            assert_eq!(cb.rows, 2 * (g.h_in + 2 * g.padding) * (g.w_in + 2 * g.padding) + 2);
            assert_eq!(cb.cols, g.h_out * g.w_out);
            for d in &cb.devices {
                assert!(d.row < cb.rows && d.col < cb.cols);
            }
            checked += 1;
        }
    }
    assert!(checked >= 20, "expected many conv layers, got {checked}");
}
