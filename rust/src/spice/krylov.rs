//! `spice::krylov` — preconditioned iterative solver for giant monolithic
//! crossbar systems.
//!
//! The direct engine ([`crate::spice::factor`]) holds the complete L+U
//! factorization resident: assembled entries, fill-in, and one multiplier
//! per (pivot, target) pair. On the paper's monolithic 2050x1024 crossbar
//! that roughly doubles the matrix footprint — the exact regime where even
//! one full factorization is memory-bound. This module solves the same MNA
//! systems with restarted GMRES(m), whose resident state is only the
//! preconditioner (never larger than the assembled pattern) plus an
//! (m+1)-vector Krylov basis.
//!
//! Two preconditioners, selected by the caller
//! ([`crate::spice::Circuit`]):
//!
//! * [`Ilu0`] — incomplete LU with zero fill, computed on the assembled
//!   circuit pattern. MNA matrices carry structurally zero diagonals on
//!   every V-source/VCVS branch row, so the factorization runs on a
//!   row-permuted matrix: a max-transversal matching (MC21-style
//!   augmenting paths) first places a structural nonzero on every
//!   diagonal. On ideal-TIA crossbar patterns the permuted ILU(0) drops
//!   almost nothing and GMRES converges in a handful of iterations.
//! * A cached complete [`Numeric`] factorization — when a circuit was
//!   already factored directly and only stamp *values* drifted
//!   (programming noise, conductance drift, Newton updates), the stale
//!   factorization is a near-perfect preconditioner: warm re-solves
//!   converge in a few iterations with **no refactorization**.
//!
//! [`SolverStrategy`] is the knob threaded from `PipelineBuilder`/CLI down
//! to [`crate::spice::Circuit`]: `Direct` (the factor engine), `Iterative`
//! (always GMRES, with explicit restart/tol/max_iter), or `Auto` (GMRES
//! above the [`AUTO_NNZ_THRESHOLD`] pattern size, direct below).
//! Every iterative solution is residual-certified by the caller and falls
//! back to the direct engine, so enabling the iterative path can never
//! make results worse — only cheaper.

use std::cell::Cell;
use std::collections::HashSet;
use std::time::Instant;

use anyhow::{bail, Result};

use super::factor::Numeric;
use super::solve::{SolveStats, SparseSys};
use crate::backend::{self, Backend, IluParts};
use crate::util::pool;

/// `Auto` switches to GMRES at this many raw stamped triplets. Pattern
/// size — not system dimension — is the memory driver (the direct factor
/// holds roughly assembled + multipliers ≈ 2x the pattern), and it keeps
/// *segmented* sims of wide-input layers on the direct path: a 64-column
/// segment of the paper's 2050-input layer has a large dim (the input
/// rows are shared) but a small pattern, and direct multi-RHS
/// substitution is the right engine for it.
pub const AUTO_NNZ_THRESHOLD: usize = 1_000_000;

/// Default Krylov-subspace size before a restart.
pub const DEFAULT_RESTART: usize = 32;
/// Default relative-residual convergence target (‖b − Ax‖ / ‖b‖).
pub const DEFAULT_TOL: f64 = 1e-11;
/// Default total inner-iteration budget across restarts.
pub const DEFAULT_MAX_ITER: usize = 1000;

/// Linear-solver selection for the SPICE engine (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum SolverStrategy {
    /// Always the factor-once/solve-many direct engine.
    Direct,
    /// Always preconditioned GMRES with these parameters.
    Iterative { restart: usize, tol: f64, max_iter: usize },
    /// Direct below the monolithic thresholds, GMRES above them.
    #[default]
    Auto,
}

impl std::str::FromStr for SolverStrategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<SolverStrategy> {
        match s {
            "direct" => Ok(SolverStrategy::Direct),
            "iterative" => Ok(SolverStrategy::Iterative {
                restart: DEFAULT_RESTART,
                tol: DEFAULT_TOL,
                max_iter: DEFAULT_MAX_ITER,
            }),
            "auto" => Ok(SolverStrategy::Auto),
            other => bail!("unknown solver '{other}' (direct|iterative|auto)"),
        }
    }
}

impl std::fmt::Display for SolverStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SolverStrategy::Direct => "direct",
            SolverStrategy::Iterative { .. } => "iterative",
            SolverStrategy::Auto => "auto",
        })
    }
}

impl SolverStrategy {
    /// Should a system with this many stamped triplets take the iterative
    /// path?
    pub fn wants_iterative(&self, nnz: usize) -> bool {
        match self {
            SolverStrategy::Direct => false,
            SolverStrategy::Iterative { .. } => true,
            SolverStrategy::Auto => nnz >= AUTO_NNZ_THRESHOLD,
        }
    }

    /// GMRES parameters for this strategy (defaults unless `Iterative`).
    pub fn cfg(&self) -> KrylovCfg {
        match *self {
            SolverStrategy::Iterative { restart, tol, max_iter } => {
                KrylovCfg { restart, tol, max_iter }
            }
            _ => KrylovCfg::default(),
        }
    }
}

/// GMRES(m) parameters.
#[derive(Debug, Clone, Copy)]
pub struct KrylovCfg {
    /// Krylov-subspace size before a restart (the memory knob: the basis
    /// holds `restart + 1` dense vectors).
    pub restart: usize,
    /// Relative-residual convergence target.
    pub tol: f64,
    /// Total inner-iteration budget across restarts; exhausting it without
    /// convergence is a clean error (callers fall back to direct).
    pub max_iter: usize,
}

impl Default for KrylovCfg {
    fn default() -> Self {
        KrylovCfg { restart: DEFAULT_RESTART, tol: DEFAULT_TOL, max_iter: DEFAULT_MAX_ITER }
    }
}

/// A right preconditioner: applies `z = M⁻¹ r` for an approximation
/// `M ≈ A`. `Sync` so batched sweeps can share one preconditioner across
/// worker threads.
pub trait Precond: Sync {
    /// Solve `M z = r`.
    fn apply(&self, r: &[f64]) -> Result<Vec<f64>>;
    /// [`Precond::apply`] on an explicit [`Backend`] kernel set.
    /// Implementations whose application is a substitution sweep route it
    /// through the backend; the default ignores `kern`.
    fn apply_kern(&self, r: &[f64], kern: &dyn Backend) -> Result<Vec<f64>> {
        let _ = kern;
        self.apply(r)
    }
    /// Resident value slots backing this preconditioner (the peak-memory
    /// proxy reported in [`SolveStats::peak_entries`]).
    fn entries(&self) -> usize;
    fn label(&self) -> &'static str;
}

/// A cached complete LU (possibly factored for *stale* values) is the
/// perfect warm preconditioner — see the module docs.
impl Precond for Numeric {
    fn apply(&self, r: &[f64]) -> Result<Vec<f64>> {
        self.solve(r)
    }

    fn apply_kern(&self, r: &[f64], kern: &dyn Backend) -> Result<Vec<f64>> {
        self.solve_kern(r, kern)
    }

    fn entries(&self) -> usize {
        self.symbolic().factor_entries()
    }

    fn label(&self) -> &'static str {
        "cached-lu"
    }
}

/// Zero-fill incomplete LU over the row-permuted assembled pattern.
///
/// Mirrors the [`Numeric`] lifecycle: [`Ilu0::analyze`] once per topology
/// (pattern + transversal + CSR layout), then [`Ilu0::assemble`] /
/// [`Ilu0::factor`] per value set (flat index arithmetic, no hashing).
#[derive(Debug, Clone)]
pub struct Ilu0 {
    n: usize,
    /// (i, j) of every triplet in the stream this analysis was built from
    pattern: Vec<(u32, u32)>,
    /// triplet k accumulates into `assembled[triplet_slot[k]]`
    triplet_slot: Vec<usize>,
    /// original row placed at position p (row permutation giving a
    /// zero-free diagonal); position index == column index
    perm: Vec<usize>,
    /// CSR of the permuted pattern: row p spans `ptr[p]..ptr[p+1]`
    ptr: Vec<usize>,
    cols: Vec<usize>,
    /// absolute index of the diagonal entry of each permuted row
    diag: Vec<usize>,
    /// assembled values (pre-factor snapshot, CSR order)
    assembled: Vec<f64>,
    /// factored values: strictly-lower = L multipliers, rest = U
    vals: Vec<f64>,
    factored: bool,
}

/// Maximum bipartite matching rows→columns over the sparsity pattern
/// (iterative augmenting-path DFS). Returns `perm` with `perm[p]` = the
/// row carrying a structural nonzero in column `p`, or `None` if the
/// matrix is structurally singular.
fn max_transversal(row_cols: &[Vec<usize>], n: usize) -> Option<Vec<usize>> {
    let mut row_of_col = vec![usize::MAX; n];
    let mut col_of_row = vec![usize::MAX; n];
    // cheap greedy pass resolves almost every row of an MNA system
    for (r, cols) in row_cols.iter().enumerate() {
        for &j in cols {
            if row_of_col[j] == usize::MAX {
                row_of_col[j] = r;
                col_of_row[r] = j;
                break;
            }
        }
    }
    let mut visited = vec![usize::MAX; n]; // per-phase column stamp
    for r0 in 0..n {
        if col_of_row[r0] != usize::MAX {
            continue;
        }
        // iterative DFS: stack of (row, cursor into its column list);
        // chosen[d] = the column frame d committed to before descending
        let mut stack: Vec<(usize, usize)> = vec![(r0, 0)];
        let mut chosen: Vec<usize> = vec![usize::MAX];
        let mut augmented = false;
        'dfs: while let Some(top) = stack.len().checked_sub(1) {
            let (r, mut cur) = stack[top];
            let cols = &row_cols[r];
            // MC21 cheap-assignment lookahead: grab a free column of this
            // row outright before descending into matched ones. Without it
            // the crossbar structure (every V-branch row's free column at
            // the end of a long alternating chain) degrades each phase to
            // O(nnz); with it a phase costs the rows on the short path.
            if cur == 0 {
                if let Some(&j) = cols.iter().find(|&&j| row_of_col[j] == usize::MAX) {
                    chosen[top] = j;
                    for t in (0..stack.len()).rev() {
                        row_of_col[chosen[t]] = stack[t].0;
                        col_of_row[stack[t].0] = chosen[t];
                    }
                    augmented = true;
                    break 'dfs;
                }
            }
            while cur < cols.len() {
                let j = cols[cur];
                cur += 1;
                if visited[j] == r0 {
                    continue;
                }
                visited[j] = r0;
                stack[top] = (r, cur);
                chosen[top] = j;
                if row_of_col[j] == usize::MAX {
                    // free column: flip the alternating path
                    for t in (0..stack.len()).rev() {
                        row_of_col[chosen[t]] = stack[t].0;
                        col_of_row[stack[t].0] = chosen[t];
                    }
                    augmented = true;
                    break 'dfs;
                }
                stack.push((row_of_col[j], 0));
                chosen.push(usize::MAX);
                continue 'dfs;
            }
            stack.pop();
            chosen.pop();
        }
        if !augmented {
            return None;
        }
    }
    Some(row_of_col)
}

impl Ilu0 {
    /// Pattern analysis: deduplicate the triplet stream, find a zero-free
    /// diagonal transversal, and lay out the permuted CSR pattern.
    pub fn analyze(sys: &SparseSys) -> Result<Ilu0> {
        let n = sys.n;
        let mut pattern = Vec::with_capacity(sys.nnz());
        let mut row_sets: Vec<HashSet<usize>> = vec![HashSet::new(); n];
        for &(i, j, _) in sys.iter_triplets() {
            if i >= n || j >= n {
                bail!("ilu0: triplet ({i},{j}) out of range for n={n}");
            }
            pattern.push((i as u32, j as u32));
            row_sets[i].insert(j);
        }
        let row_cols: Vec<Vec<usize>> = row_sets
            .iter()
            .map(|s| {
                let mut v: Vec<usize> = s.iter().copied().collect();
                v.sort_unstable();
                v
            })
            .collect();
        let Some(perm) = max_transversal(&row_cols, n) else {
            bail!("ilu0: structurally singular (no zero-free diagonal transversal)");
        };
        let mut pos_of_row = vec![0usize; n];
        for (p, &r) in perm.iter().enumerate() {
            pos_of_row[r] = p;
        }
        let mut ptr = Vec::with_capacity(n + 1);
        ptr.push(0usize);
        let mut cols = Vec::new();
        let mut diag = vec![0usize; n];
        for (p, &r) in perm.iter().enumerate() {
            let rc = &row_cols[r];
            let base = cols.len();
            cols.extend_from_slice(rc);
            let Ok(off) = rc.binary_search(&p) else {
                bail!("ilu0: transversal missed diagonal {p}");
            };
            diag[p] = base + off;
            ptr.push(cols.len());
        }
        let mut triplet_slot = Vec::with_capacity(pattern.len());
        for &(i, j) in &pattern {
            let p = pos_of_row[i as usize];
            let row = &cols[ptr[p]..ptr[p + 1]];
            let off = row.binary_search(&(j as usize)).expect("pattern entry present");
            triplet_slot.push(ptr[p] + off);
        }
        let slots = cols.len();
        Ok(Ilu0 {
            n,
            pattern,
            triplet_slot,
            perm,
            ptr,
            cols,
            diag,
            assembled: vec![0.0; slots],
            vals: vec![0.0; slots],
            factored: false,
        })
    }

    /// Does this analysis apply to `sys`? True iff the triplet (i, j)
    /// stream is identical (same stamp order, same topology).
    pub fn matches(&self, sys: &SparseSys) -> bool {
        sys.n == self.n && super::solve::pattern_matches(&self.pattern, sys)
    }

    /// Cheap fingerprint (dimension + triplet count). Cache lookups gate
    /// on this before [`Ilu0::assemble`] performs the full pattern
    /// comparison, so a warm solve pays one O(nnz) check, not two.
    pub fn dims_match(&self, sys: &SparseSys) -> bool {
        sys.n == self.n && sys.nnz() == self.pattern.len()
    }

    /// Accumulate the triplet values of `sys` into the assembled slots.
    /// Returns `true` if the values are identical to the previous assembly
    /// (and a valid factorization exists) — the numeric sweep can be
    /// skipped. Errors if `sys` does not match this analysis' pattern.
    pub fn assemble(&mut self, sys: &SparseSys) -> Result<bool> {
        if !self.matches(sys) {
            bail!("ilu0: circuit topology changed — re-analysis required");
        }
        let mut fresh = vec![0.0; self.cols.len()];
        for (k, &(_, _, v)) in sys.iter_triplets().enumerate() {
            fresh[self.triplet_slot[k]] += v;
        }
        if self.factored && fresh == self.assembled {
            return Ok(true);
        }
        self.assembled = fresh;
        self.factored = false;
        Ok(false)
    }

    /// Numeric ILU(0) sweep over the fixed pattern (IKJ order; updates
    /// restricted to existing entries, so zero fill by construction).
    pub fn factor(&mut self) -> Result<()> {
        self.factored = false;
        self.vals.copy_from_slice(&self.assembled);
        let n = self.n;
        let ptr = &self.ptr;
        let cols = &self.cols;
        let diag = &self.diag;
        let vals = &mut self.vals;
        for i in 0..n {
            let ri1 = ptr[i + 1];
            let di = diag[i];
            for t in ptr[i]..di {
                let k = cols[t];
                let piv = vals[diag[k]];
                if piv.abs() < 1e-300 {
                    bail!("ilu0: pivot collapsed at column {k}");
                }
                let f = vals[t] / piv;
                vals[t] = f;
                if f == 0.0 {
                    continue;
                }
                // intersect upper(k) with the tail of row i: both column
                // lists ascend, so the search window only moves forward
                let mut lo = t + 1;
                for u in (diag[k] + 1)..ptr[k + 1] {
                    if lo >= ri1 {
                        break;
                    }
                    let j = cols[u];
                    match cols[lo..ri1].binary_search(&j) {
                        Ok(off) => {
                            vals[lo + off] -= f * vals[u];
                            lo += off + 1;
                        }
                        Err(off) => lo += off,
                    }
                }
            }
        }
        self.factored = true;
        Ok(())
    }

    /// Solve `(LU) z = P r` (the preconditioner application).
    pub fn solve(&self, r: &[f64]) -> Result<Vec<f64>> {
        self.solve_kern(r, backend::scalar())
    }

    /// [`Ilu0::solve`] on an explicit [`Backend`] kernel set.
    pub fn solve_kern(&self, r: &[f64], kern: &dyn Backend) -> Result<Vec<f64>> {
        if !self.factored {
            bail!("ilu0: solve before factor");
        }
        let n = self.n;
        if r.len() != n {
            bail!("ilu0: rhs has {} entries, system has {n}", r.len());
        }
        let t0 = Instant::now();
        let mut w: Vec<f64> = self.perm.iter().map(|&p| r[p]).collect();
        let parts = IluParts {
            ptr: &self.ptr,
            diag: &self.diag,
            cols: &self.cols,
            vals: &self.vals,
        };
        let bad = kern.ilu_sweep(&parts, &mut w);
        backend::add_subst_ns(t0.elapsed().as_nanos() as u64);
        if let Some(i) = bad {
            bail!("ilu0: zero diagonal in back-substitution at column {i}");
        }
        Ok(w)
    }
}

impl Precond for Ilu0 {
    fn apply(&self, r: &[f64]) -> Result<Vec<f64>> {
        self.solve(r)
    }

    fn apply_kern(&self, r: &[f64], kern: &dyn Backend) -> Result<Vec<f64>> {
        self.solve_kern(r, kern)
    }

    fn entries(&self) -> usize {
        self.cols.len()
    }

    fn label(&self) -> &'static str {
        "ilu0"
    }
}

/// Restarted, right-preconditioned GMRES(m) over the triplet stream of
/// `sys` (the matrix; `sys.b` is ignored — the right-hand side is the
/// explicit `b`). Right preconditioning keeps the monitored residual the
/// *true* residual, so the convergence test needs no back-transformation.
///
/// Returns the solution plus [`SolveStats`] whose `peak_entries` counts
/// the preconditioner's resident slots and the Krylov basis — the
/// iterative path's answer to the direct engine's `factor_entries`.
/// Exhausting `cfg.max_iter` without reaching `cfg.tol` is a clean `Err`.
pub fn gmres<P: Precond + ?Sized>(
    sys: &SparseSys,
    b: &[f64],
    pre: &P,
    cfg: &KrylovCfg,
) -> Result<(Vec<f64>, SolveStats)> {
    gmres_kern(sys, b, pre, cfg, backend::scalar())
}

/// [`gmres`] on an explicit [`Backend`] kernel set: the matvec, Arnoldi
/// dot/axpy/norm kernels and every preconditioner application run on
/// `kern`. Reduction kernels may reassociate, so iterative solutions can
/// differ between backends by ordinary rounding inside the residual
/// tolerance (unlike the bit-identical direct substitution path).
pub fn gmres_kern<P: Precond + ?Sized>(
    sys: &SparseSys,
    b: &[f64],
    pre: &P,
    cfg: &KrylovCfg,
    kern: &dyn Backend,
) -> Result<(Vec<f64>, SolveStats)> {
    let n = sys.n;
    if b.len() != n {
        bail!("krylov: rhs has {} entries, system has {n}", b.len());
    }
    // SoA triplet stream: validated once, then streamed by the backend
    // spmv on every Arnoldi step
    let mut t_rows = Vec::with_capacity(sys.nnz());
    let mut t_cols = Vec::with_capacity(sys.nnz());
    let mut t_vals = Vec::with_capacity(sys.nnz());
    for &(i, j, v) in sys.iter_triplets() {
        if i >= n || j >= n {
            bail!("krylov: triplet ({i},{j}) out of range for n={n}");
        }
        t_rows.push(i);
        t_cols.push(j);
        t_vals.push(v);
    }
    let m = cfg.restart.clamp(1, n.max(1));
    let mut stats = SolveStats::direct(pre.entries() + (m + 1) * n, n);
    stats.backend = kern.name();
    let mut sp = crate::telemetry::span("gmres", "kernel");
    sp.set_arg("n", n as f64);
    let bnorm = kern.norm2(b);
    if bnorm == 0.0 {
        return Ok((vec![0.0; n], stats));
    }
    let matvec_ns = Cell::new(0u64);
    let matvec = |x: &[f64]| {
        let t0 = Instant::now();
        let mut y = vec![0.0; n];
        kern.spmv(&t_rows, &t_cols, &t_vals, x, &mut y);
        matvec_ns.set(matvec_ns.get() + t0.elapsed().as_nanos() as u64);
        y
    };
    let mut x = vec![0.0; n];
    let mut iters = 0usize;
    while iters < cfg.max_iter {
        let ax = matvec(&x);
        let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        let beta = kern.norm2(&r);
        if beta <= cfg.tol * bnorm {
            stats.iterations = iters;
            stats.residual = beta / bnorm;
            stats.matvec_ns = matvec_ns.get();
            backend::add_matvec_ns(stats.matvec_ns);
            super::add_gmres_iterations(iters as u64);
            sp.set_arg("iters", iters as f64);
            return Ok((x, stats));
        }
        // Arnoldi (modified Gram-Schmidt) with Givens-rotated Hessenberg:
        // h[k] is column k (length k+2); g tracks the rotated residual
        let mut v_basis: Vec<Vec<f64>> = Vec::with_capacity(m + 1);
        v_basis.push(r.iter().map(|t| t / beta).collect());
        let mut h: Vec<Vec<f64>> = Vec::with_capacity(m);
        let mut cs = vec![0.0f64; m];
        let mut sn = vec![0.0f64; m];
        let mut g = vec![0.0f64; m + 1];
        g[0] = beta;
        let mut k_used = 0usize;
        for k in 0..m {
            if iters >= cfg.max_iter {
                break;
            }
            iters += 1;
            let z = pre.apply_kern(&v_basis[k], kern)?;
            let mut w = matvec(&z);
            let mut hk = vec![0.0f64; k + 2];
            for (i, vb) in v_basis.iter().enumerate().take(k + 1) {
                let hik = kern.dot(&w, vb);
                hk[i] = hik;
                kern.axpy(-hik, vb, &mut w);
            }
            let wnorm = kern.norm2(&w);
            hk[k + 1] = wnorm;
            if wnorm > 1e-300 {
                for wv in w.iter_mut() {
                    *wv /= wnorm;
                }
                v_basis.push(w);
            } else {
                // happy breakdown: the subspace is invariant; the rotated
                // residual below goes to ~0 and the cycle closes
                v_basis.push(vec![0.0; n]);
            }
            for i in 0..k {
                let t = cs[i] * hk[i] + sn[i] * hk[i + 1];
                hk[i + 1] = -sn[i] * hk[i] + cs[i] * hk[i + 1];
                hk[i] = t;
            }
            let d = hk[k].hypot(hk[k + 1]);
            if d < 1e-300 {
                cs[k] = 1.0;
                sn[k] = 0.0;
            } else {
                cs[k] = hk[k] / d;
                sn[k] = hk[k + 1] / d;
            }
            hk[k] = cs[k] * hk[k] + sn[k] * hk[k + 1];
            hk[k + 1] = 0.0;
            g[k + 1] = -sn[k] * g[k];
            g[k] = cs[k] * g[k];
            h.push(hk);
            k_used = k + 1;
            if g[k + 1].abs() <= cfg.tol * bnorm {
                break;
            }
        }
        if k_used == 0 {
            break;
        }
        // back-substitute y from the rotated (upper-triangular) H
        let mut y = vec![0.0f64; k_used];
        for i in (0..k_used).rev() {
            let mut acc = g[i];
            for (j, yj) in y.iter().enumerate().skip(i + 1) {
                acc -= h[j][i] * yj;
            }
            let hii = h[i][i];
            if hii.abs() < 1e-300 {
                bail!("krylov: singular least-squares system at column {i}");
            }
            y[i] = acc / hii;
        }
        // x += M⁻¹ (V y)  (right preconditioning)
        let mut corr = vec![0.0f64; n];
        for (yi, vb) in y.iter().zip(&v_basis) {
            kern.axpy(*yi, vb, &mut corr);
        }
        let zc = pre.apply_kern(&corr, kern)?;
        kern.axpy(1.0, &zc, &mut x);
    }
    let ax = matvec(&x);
    let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
    let relres = kern.norm2(&r) / bnorm;
    stats.matvec_ns = matvec_ns.get();
    backend::add_matvec_ns(stats.matvec_ns);
    // iterations were genuinely spent even when the solve fails below
    super::add_gmres_iterations(iters as u64);
    sp.set_arg("iters", iters as f64);
    // the rotated-residual estimate can be slightly optimistic; accept a
    // small slack against the true residual before declaring failure
    if relres <= cfg.tol * 10.0 {
        stats.iterations = iters;
        stats.residual = relres;
        return Ok((x, stats));
    }
    bail!(
        "krylov: gmres({}) with {} preconditioner failed to converge within {} iterations \
         (relative residual {relres:.3e}, tol {:.1e})",
        m,
        pre.label(),
        cfg.max_iter,
        cfg.tol
    )
}

/// Batched GMRES: every right-hand side shares `pre` (built once), with
/// the per-column Krylov sweeps pipelined across `workers` threads via
/// [`pool::par_map`] — the iterative twin of
/// [`Numeric::solve_multi`](super::factor::Numeric::solve_multi).
/// Aggregated stats: `iterations` sums the per-column counts, `residual`
/// is the worst column, `peak_entries` counts the shared preconditioner
/// once plus one Krylov basis per concurrent worker.
pub fn gmres_batch<P: Precond + ?Sized>(
    sys: &SparseSys,
    bs: &[Vec<f64>],
    pre: &P,
    cfg: &KrylovCfg,
    workers: usize,
) -> Result<(Vec<Vec<f64>>, SolveStats)> {
    gmres_batch_kern(sys, bs, pre, cfg, workers, backend::scalar())
}

/// [`gmres_batch`] on an explicit [`Backend`] kernel set (shared by every
/// per-column sweep across the worker threads — the trait is `Sync`).
pub fn gmres_batch_kern<P: Precond + ?Sized>(
    sys: &SparseSys,
    bs: &[Vec<f64>],
    pre: &P,
    cfg: &KrylovCfg,
    workers: usize,
    kern: &dyn Backend,
) -> Result<(Vec<Vec<f64>>, SolveStats)> {
    if bs.is_empty() {
        let mut stats = SolveStats::direct(pre.entries(), sys.n);
        stats.backend = kern.name();
        return Ok((Vec::new(), stats));
    }
    let results = pool::par_map(bs, workers.max(1), |b| gmres_kern(sys, b, pre, cfg, kern));
    let m = cfg.restart.clamp(1, sys.n.max(1));
    let concurrency = workers.max(1).min(bs.len());
    let mut stats = SolveStats::direct(pre.entries() + concurrency * (m + 1) * sys.n, sys.n);
    stats.backend = kern.name();
    let mut xs = Vec::with_capacity(bs.len());
    for r in results {
        let (x, st) = r?;
        stats.iterations += st.iterations;
        stats.residual = stats.residual.max(st.residual);
        stats.matvec_ns += st.matvec_ns;
        xs.push(x);
    }
    Ok((xs, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spice::factor;
    use crate::spice::solve::{solve_dense, Ordering};
    use crate::util::prng::Rng;

    fn random_system(n: usize, diag: f64, rng: &mut Rng) -> (Vec<Vec<f64>>, SparseSys) {
        let mut dense = vec![vec![0.0; n]; n];
        let mut sys = SparseSys::new(n);
        for i in 0..n {
            for _ in 0..3 {
                let j = rng.below(n);
                let v = rng.range_f64(-1.0, 1.0);
                dense[i][j] += v;
                sys.add(i, j, v);
            }
            dense[i][i] += diag;
            sys.add(i, i, diag);
        }
        for i in 0..n {
            sys.add_b(i, rng.range_f64(-2.0, 2.0));
        }
        (dense, sys)
    }

    fn gmres_vs_dense(n: usize, diag: f64, seed: u64, cfg: &KrylovCfg) {
        let mut rng = Rng::new(seed);
        let (dense, sys) = random_system(n, diag, &mut rng);
        let xd = solve_dense(&dense, &sys.b).unwrap();
        let mut pre = Ilu0::analyze(&sys).unwrap();
        assert!(!pre.assemble(&sys).unwrap());
        pre.factor().unwrap();
        let (x, st) = gmres(&sys, &sys.b, &pre, cfg).unwrap();
        assert!(st.iterations > 0 && st.residual <= cfg.tol * 10.0);
        for i in 0..n {
            assert!((x[i] - xd[i]).abs() < 1e-7, "n={n} diag={diag} x[{i}]: {} vs {}", x[i], xd[i]);
        }
    }

    #[test]
    fn gmres_ilu0_matches_dense() {
        let cfg = KrylovCfg::default();
        gmres_vs_dense(12, 5.0, 3, &cfg);
        gmres_vs_dense(40, 5.0, 7, &cfg);
        gmres_vs_dense(80, 5.0, 11, &cfg);
    }

    #[test]
    fn gmres_restarts_on_weakly_preconditioned_system() {
        // weak diagonal: ILU(0) is genuinely incomplete, forcing several
        // restart cycles through the small subspace
        let cfg = KrylovCfg { restart: 8, tol: 1e-10, max_iter: 4000 };
        gmres_vs_dense(60, 1.3, 17, &cfg);
    }

    #[test]
    fn zero_diagonal_handled_by_transversal() {
        // the PR 1 pivot case: both diagonals structurally zero
        let mut s = SparseSys::new(2);
        s.add(0, 1, 1.0);
        s.add(1, 0, 1.0);
        s.add_b(0, 3.0);
        s.add_b(1, 7.0);
        let mut pre = Ilu0::analyze(&s).unwrap();
        pre.assemble(&s).unwrap();
        pre.factor().unwrap();
        let (x, _) = gmres(&s, &s.b, &pre, &KrylovCfg::default()).unwrap();
        assert!((x[0] - 7.0).abs() < 1e-10 && (x[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn structurally_singular_rejected() {
        let mut s = SparseSys::new(2);
        s.add(0, 0, 1.0);
        s.add(1, 0, 1.0); // column 1 empty
        assert!(Ilu0::analyze(&s).is_err());
    }

    #[test]
    fn max_iter_exhaustion_is_clean_error() {
        let mut rng = Rng::new(5);
        let (_, sys) = random_system(30, 1.1, &mut rng);
        let mut pre = Ilu0::analyze(&sys).unwrap();
        pre.assemble(&sys).unwrap();
        pre.factor().unwrap();
        let cfg = KrylovCfg { restart: 2, tol: 1e-14, max_iter: 1 };
        let err = gmres(&sys, &sys.b, &pre, &cfg).unwrap_err();
        assert!(err.to_string().contains("failed to converge"), "{err}");
    }

    #[test]
    fn assemble_rejects_different_pattern() {
        let mut a = SparseSys::new(2);
        a.add(0, 0, 1.0);
        a.add(1, 1, 1.0);
        let mut pre = Ilu0::analyze(&a).unwrap();
        let mut b = SparseSys::new(2);
        b.add(0, 1, 1.0);
        b.add(1, 0, 1.0);
        assert!(pre.assemble(&b).is_err());
    }

    #[test]
    fn assemble_detects_unchanged_values() {
        let mut rng = Rng::new(9);
        let (_, sys) = random_system(10, 4.0, &mut rng);
        let mut pre = Ilu0::analyze(&sys).unwrap();
        assert!(!pre.assemble(&sys).unwrap());
        pre.factor().unwrap();
        assert!(pre.assemble(&sys).unwrap(), "identical values must skip the sweep");
    }

    #[test]
    fn cached_numeric_is_perfect_preconditioner() {
        // complete LU of the same values: GMRES must converge immediately
        let mut rng = Rng::new(21);
        let (dense, sys) = random_system(25, 5.0, &mut rng);
        let xd = solve_dense(&dense, &sys.b).unwrap();
        let (_, num) = factor::factor_solve(&sys, Ordering::Smart).unwrap();
        let (x, st) = gmres(&sys, &sys.b, &num, &KrylovCfg::default()).unwrap();
        assert!(st.iterations <= 2, "perfect preconditioner took {} iters", st.iterations);
        for i in 0..25 {
            assert!((x[i] - xd[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(31);
        let (_, sys) = random_system(20, 5.0, &mut rng);
        let mut pre = Ilu0::analyze(&sys).unwrap();
        pre.assemble(&sys).unwrap();
        pre.factor().unwrap();
        let bs: Vec<Vec<f64>> = (0..5)
            .map(|k| (0..20).map(|i| ((i + 3 * k) as f64 * 0.37).sin()).collect())
            .collect();
        let cfg = KrylovCfg::default();
        let (xs, st) = gmres_batch(&sys, &bs, &pre, &cfg, 3).unwrap();
        assert!(st.iterations > 0);
        for (b, x) in bs.iter().zip(&xs) {
            let (xi, _) = gmres(&sys, b, &pre, &cfg).unwrap();
            for (a, c) in x.iter().zip(&xi) {
                assert!((a - c).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn strategy_parse_display_roundtrip() {
        for s in ["direct", "iterative", "auto"] {
            let parsed: SolverStrategy = s.parse().unwrap();
            assert_eq!(parsed.to_string(), s);
        }
        assert!("gmres".parse::<SolverStrategy>().is_err());
        assert_eq!(SolverStrategy::default(), SolverStrategy::Auto);
    }

    #[test]
    fn auto_threshold_selects_by_pattern_size() {
        let auto = SolverStrategy::Auto;
        assert!(!auto.wants_iterative(1000));
        assert!(!auto.wants_iterative(AUTO_NNZ_THRESHOLD - 1));
        assert!(auto.wants_iterative(AUTO_NNZ_THRESHOLD));
        assert!(!SolverStrategy::Direct.wants_iterative(1 << 30));
        assert!("iterative".parse::<SolverStrategy>().unwrap().wants_iterative(2));
    }
}
