//! Little-endian binary readers/writers for the artifact sidecar formats
//! (weights.bin, dataset.bin, expected_logits.bin — see python/compile/aot.py
//! and data.py for the producing side).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

pub const MAGIC: u32 = 0x4D45_4D58; // "MEMX"

pub fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_f32_vec<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn write_f32_slice<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// weights.bin: `u32 magic | u32 n_f32 | f32 data[n]`
pub fn read_weights_blob(path: &Path) -> Result<Vec<f32>> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let magic = read_u32(&mut r)?;
    if magic != MAGIC {
        bail!("weights.bin bad magic {magic:#x}");
    }
    let n = read_u32(&mut r)? as usize;
    read_f32_vec(&mut r, n)
}

/// dataset.bin: `u32 magic | u32 n | u32 h | u32 w | u32 c | f32 data | u8 labels`
pub struct Dataset {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// NHWC, row-major
    pub data: Vec<f32>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn load(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
        let magic = read_u32(&mut r)?;
        if magic != MAGIC {
            bail!("dataset.bin bad magic {magic:#x}");
        }
        let n = read_u32(&mut r)? as usize;
        let h = read_u32(&mut r)? as usize;
        let w = read_u32(&mut r)? as usize;
        let c = read_u32(&mut r)? as usize;
        let data = read_f32_vec(&mut r, n * h * w * c)?;
        let mut labels = vec![0u8; n];
        r.read_exact(&mut labels)?;
        Ok(Self { n, h, w, c, data, labels })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(&MAGIC.to_le_bytes())?;
        for v in [self.n, self.h, self.w, self.c] {
            w.write_all(&(v as u32).to_le_bytes())?;
        }
        write_f32_slice(&mut w, &self.data)?;
        w.write_all(&self.labels)?;
        Ok(())
    }

    /// Image `i` as an NHWC slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.h * self.w * self.c;
        &self.data[i * sz..(i + 1) * sz]
    }

    pub fn image_len(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// expected_logits.bin: `u32 n | u32 classes | f32 logits[n*classes]`
pub fn read_expected_logits(path: &Path) -> Result<(usize, usize, Vec<f32>)> {
    let mut r = BufReader::new(File::open(path)?);
    let n = read_u32(&mut r)? as usize;
    let c = read_u32(&mut r)? as usize;
    let data = read_f32_vec(&mut r, n * c)?;
    Ok((n, c, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_roundtrip() {
        let d = Dataset {
            n: 2,
            h: 4,
            w: 4,
            c: 3,
            data: (0..2 * 4 * 4 * 3).map(|i| i as f32 * 0.25).collect(),
            labels: vec![3, 7],
        };
        let tmp = std::env::temp_dir().join("memx_ds_test.bin");
        d.save(&tmp).unwrap();
        let d2 = Dataset::load(&tmp).unwrap();
        assert_eq!(d2.n, 2);
        assert_eq!(d2.data, d.data);
        assert_eq!(d2.labels, d.labels);
        assert_eq!(d2.image(1).len(), d2.image_len());
        assert_eq!(d2.image(1)[0], d.data[48]);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let tmp = std::env::temp_dir().join("memx_badmagic.bin");
        std::fs::write(&tmp, [0u8; 64]).unwrap();
        assert!(Dataset::load(&tmp).is_err());
        assert!(read_weights_blob(&tmp).is_err());
        std::fs::remove_file(tmp).ok();
    }
}
