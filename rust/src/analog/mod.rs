//! Analog circuit modules — transistor-level models of the paper's §3.4
//! activation circuits (Fig 4) plus fast behavioural equivalents, and the
//! netlist builders for the "boring" linear stages: the §3.3 batch-norm
//! circuit ([`build_bn_crossbars`]: subtraction crossbar + scale/offset
//! conductance pairs with the mean/variance fold programmed into the
//! conductances) and the §3.5 global-average-pooling column
//! ([`build_gap_crossbar`]: `1/N` conductances into the op-amp summing
//! node).
//!
//! The circuit builders produce real [`Circuit`]s (op-amp adders /
//! dividers, diode+source limiters, a Gilbert-cell multiplier abstraction);
//! `sweep` reproduces Fig 4(c)/(d). The behavioural functions are the
//! rail-clipped piecewise forms the L2 JAX model uses — tests pin the SPICE
//! curves to them within the diode-knee tolerance. The BN/GAP builders
//! return [`Crossbar`]s ready for [`crate::netlist::emit_crossbar`] and the
//! resident [`crate::netlist::CrossbarSim`] the pipeline modules hold at
//! `Fidelity::Spice`.

use anyhow::{anyhow, Result};

use crate::mapper::layout::{place_gap, Placed};
use crate::mapper::{Crossbar, MapMode};
use crate::spice::Circuit;

/// Software hard sigmoid: relu6(x + 3) / 6.
pub fn hard_sigmoid_sw(x: f64) -> f64 {
    ((x + 3.0) / 6.0).clamp(0.0, 1.0)
}

/// Software hard swish.
pub fn hard_swish_sw(x: f64) -> f64 {
    x * hard_sigmoid_sw(x)
}

/// Behavioural analog hard sigmoid (rail-limited input — ref.py mirror).
pub fn hard_sigmoid_analog(x: f64, v_rail: f64) -> f64 {
    hard_sigmoid_sw(x.clamp(-v_rail, v_rail))
}

/// Behavioural analog hard swish.
pub fn hard_swish_analog(x: f64, v_rail: f64) -> f64 {
    let x = x.clamp(-v_rail, v_rail);
    (x * hard_sigmoid_analog(x, v_rail)).clamp(-v_rail, v_rail)
}

/// Behavioural analog ReLU (CMOS, rail-limited).
pub fn relu_analog(x: f64, v_rail: f64) -> f64 {
    x.clamp(0.0, v_rail)
}

/// A built activation circuit: drive `vin_name`, read `out_node`.
/// Cloning clones the circuit including its cached factorization, so clones
/// can solve independently (e.g. one per worker thread).
#[derive(Clone)]
pub struct ActCircuit {
    pub circuit: Circuit,
    pub vin_name: String,
    pub out_node: String,
}

impl ActCircuit {
    /// Evaluate the circuit at one input voltage.
    ///
    /// Repeated calls reuse the circuit's cached factorization: the input
    /// source edit is RHS-only, so each Newton iteration replays the
    /// symbolic analysis computed on the first solve instead of
    /// re-eliminating from scratch (see [`crate::spice::factor`]).
    pub fn eval(&mut self, vin: f64) -> Result<f64> {
        self.circuit.set_vsource(&self.vin_name, vin)?;
        let sol = self.circuit.dc_op()?;
        let n = self
            .circuit
            .node_named(&self.out_node)
            .ok_or_else(|| anyhow!("no node {}", self.out_node))?;
        Ok(sol[n])
    }

    /// Input sweep — the Fig 4(c)/(d) curves. Factor-once/solve-many:
    /// every point after the first is a cached re-solve.
    pub fn sweep(&mut self, lo: f64, hi: f64, points: usize) -> Result<Vec<(f64, f64)>> {
        (0..points)
            .map(|i| {
                let x = lo + (hi - lo) * i as f64 / (points - 1).max(1) as f64;
                Ok((x, self.eval(x)?))
            })
            .collect()
    }
}

/// Fig 4(a): hard sigmoid.
///
/// Stage 1 — inverting summing amplifier: out1 = -(x + 3)/6
///   (x through 60k, +3 V reference through 60k, Rf = 10k).
/// Stage 2 — unity inverter: hs_lin = (x + 3)/6.
/// Stage 3 — diode+source limiter (the paper's "max" operation):
///   clamp to [0, 1] with compensated clamp sources.
pub fn build_hard_sigmoid() -> ActCircuit {
    let mut c = Circuit::new("hard_sigmoid (Fig 4a)");
    let vin = c.node("vin");
    let vref = c.node("vref3");
    let sum_m = c.node("sum_vm");
    let out1 = c.node("out1");
    let inv_m = c.node("inv_vm");
    let out2 = c.node("out2");
    let lim = c.node("vout");

    c.vsource("VIN", vin, 0, 0.0);
    c.vsource("VREF", vref, 0, 3.0);
    // summing amp: Rf/Rin = 10k/60k = 1/6
    c.resistor("R1", vin, sum_m, 60_000.0);
    c.resistor("R2", vref, sum_m, 60_000.0);
    c.resistor("RF1", sum_m, out1, 10_000.0);
    c.opamp("EOP1", 0, sum_m, out1);
    // unity inverter
    c.resistor("R3", out1, inv_m, 10_000.0);
    c.resistor("RF2", inv_m, out2, 10_000.0);
    c.opamp("EOP2", 0, inv_m, out2);
    // limiter: series resistor then clamp diodes with compensating sources
    c.resistor("RS", out2, lim, 1_000.0);
    // low clamp at ~0 V: anode driven at +0.55 V so conduction starts when
    // the output node dips below ≈ -0.05 V (0.6 V knee compensated)
    let lo = c.node("vclamp_lo");
    c.vsource("VCLO", lo, 0, 0.55);
    c.diode("DLO", lo, lim);
    // high clamp at ~1 V: cathode at 1 - 0.55
    let hi = c.node("vclamp_hi");
    c.vsource("VCHI", hi, 0, 0.45);
    c.diode("DHI", lim, hi);
    ActCircuit { circuit: c, vin_name: "VIN".into(), out_node: "vout".into() }
}

/// Fig 4(b): hard swish = multiplier(x, hard_sigmoid(x)).
pub fn build_hard_swish() -> ActCircuit {
    // extend the hard-sigmoid front end's circuit in place (no moved-out
    // intermediate ActCircuit holding an emptied sentinel)
    let ActCircuit { mut circuit, .. } = build_hard_sigmoid();
    let vin = circuit.node("vin");
    let hs = circuit.node("vout");
    let out = circuit.node("vswish");
    circuit.mult("XMUL", out, vin, hs, 1.0);
    ActCircuit { circuit, vin_name: "VIN".into(), out_node: "vswish".into() }
}

/// Knee width of the diode limiter — tolerance band used when pinning the
/// SPICE curves to the piecewise software model.
pub const KNEE_TOL: f64 = 0.12;

/// Place one affine-term device under the differential crossbar
/// convention: weight `w` on input line `line` (None = the constant term,
/// realized on the ±1 V bias rows), sign handled by region selection
/// exactly like [`crate::mapper::layout::place_fc`]. `scale` normalizes
/// `|w|` into the (0, 1] conductance range; zero weights place nothing.
fn place_affine_device(
    devices: &mut Vec<Placed>,
    region: usize,
    inverted: bool,
    col: usize,
    line: Option<usize>,
    w: f64,
    scale: f64,
) {
    if w == 0.0 {
        return;
    }
    let to_neg = if inverted { w > 0.0 } else { w < 0.0 };
    let row = match line {
        Some(r) => {
            if to_neg {
                r + region
            } else {
                r
            }
        }
        // bias lines: row 2*region is held at +1 V, 2*region + 1 at -1 V
        None => 2 * region + usize::from(to_neg),
    };
    devices.push(Placed { row, col, g_norm: w.abs() / scale });
}

/// §3.3 batch-normalization circuit as two cascaded crossbars, one column
/// per processed element (channel-major, `spatial` elements per channel):
///
/// * **subtraction crossbar** (`<name>.sub`): `u = g1 * (x - mean[ch])` —
///   the element's input line plus the folded mean as a programmed
///   conductance on a bias row;
/// * **scale/offset pairs** (`<name>.scale`): `y = (k[ch]/g1) * u +
///   beta[ch]` with `k = gamma / sqrt(var + BN_EPS)` folded at compile
///   time ([`crate::mapper::BnFold`]) — the scale conductance on the
///   stage's differential input (region by sign, so negative scales need
///   no extra inverter) and the offset conductance on a bias row.
///
/// The fold's gain is **balanced across the cascade**: `g1 =
/// max(1, sqrt(|k|))` per channel, so each inverting stage's noise gain
/// stays ~`sqrt(|k|)`. Putting the whole gain in one stage would give the
/// finite-gain (1e6) TIA a closed-loop error of `(1 + |k|)/1e6` — ~5e-4
/// for the near-zero-variance folds (|k| ~ 500), outside the 1e-4
/// conformance band the fidelity suite pins; the balanced split keeps it
/// ~`2*sqrt(|k|)/1e6`. Each crossbar also normalizes its conductances into
/// (0, 1] through its TIA feedback (`rf_scale`), so arbitrarily large
/// folds stay programmable. The exact composite transfer is the affine
/// fold `(x - mean) * k + beta` — `rust/tests/fidelity.rs` pins the
/// netlists against it.
///
/// [`crate::pipeline::BatchNormModule`] and the netlist emitter
/// instantiate the per-channel form (`spatial = 1`, the Eq 10/11
/// hardware) and fold spatial positions into multi-RHS reads; larger
/// `spatial` values spatially unroll the same circuit.
pub fn build_bn_crossbars(
    name: &str,
    c: usize,
    spatial: usize,
    k: &[f64],
    mean: &[f64],
    beta: &[f64],
    mode: MapMode,
) -> (Crossbar, Crossbar) {
    assert!(c > 0 && spatial > 0, "bn crossbars need channels and elements");
    assert_eq!(k.len(), c, "k length != channels");
    assert_eq!(mean.len(), c, "mean length != channels");
    assert_eq!(beta.len(), c, "beta length != channels");
    let n = c * spatial;
    let inverted = mode.inverted();
    let g1: Vec<f64> = k.iter().map(|v| v.abs().sqrt().max(1.0)).collect();
    let w2: Vec<f64> = k.iter().zip(&g1).map(|(v, g)| v / g).collect();
    let s_sub = (0..c).fold(1.0f64, |a, ch| a.max(g1[ch]).max(mean[ch].abs() * g1[ch]));
    let s_scale = w2.iter().chain(beta).fold(1e-12f64, |a, v| a.max(v.abs()));
    let mut sub = Vec::with_capacity(2 * n);
    let mut scale = Vec::with_capacity(2 * n);
    for j in 0..n {
        let ch = j / spatial;
        place_affine_device(&mut sub, n, inverted, j, Some(j), g1[ch], s_sub);
        place_affine_device(&mut sub, n, inverted, j, None, -mean[ch] * g1[ch], s_sub);
        place_affine_device(&mut scale, n, inverted, j, Some(j), w2[ch], s_scale);
        place_affine_device(&mut scale, n, inverted, j, None, beta[ch], s_scale);
    }
    let crossbar = |suffix: &str, devices: Vec<Placed>, rf_scale: f64| Crossbar {
        name: format!("{name}.{suffix}"),
        rows: 2 * n + 2,
        cols: n,
        region: n,
        devices,
        rf_scale,
        mode,
    };
    (crossbar("sub", sub, s_sub), crossbar("scale", scale, s_scale))
}

/// §3.5 global-average-pooling crossbar: one averaging column per channel,
/// `1/N` conductances ([`place_gap`]) from the channel's `N = spatial`
/// input lines into the column's op-amp summing node. All weights are
/// positive, so the inverted convention places them on the negated-input
/// region (the TIA's `-Rf` restores `+mean`); dual mode places them
/// directly and re-inverts through the per-column inverter. The exact
/// transfer is the per-channel mean.
pub fn build_gap_crossbar(name: &str, c: usize, spatial: usize, mode: MapMode) -> Crossbar {
    assert!(c > 0 && spatial > 0, "gap crossbar needs channels and a plane");
    let region = c * spatial;
    let column = place_gap(spatial);
    let mut devices = Vec::with_capacity(region);
    for ch in 0..c {
        for p in &column {
            let line = ch * spatial + p.row;
            let row = if mode.inverted() { line + region } else { line };
            devices.push(Placed { row, col: ch, g_norm: p.g_norm });
        }
    }
    Crossbar {
        name: name.to_string(),
        rows: 2 * region + 2,
        cols: c,
        region,
        devices,
        rf_scale: 1.0,
        mode,
    }
}

/// Residual summing-amplifier stage as a crossbar: one op-amp adder column
/// per channel, `y[j] = a[j] + b[j]` with the two branch activations
/// presented as the concatenated input vector `[a, b]` (region =
/// `2 * dim` lines). Both unit weights land through
/// [`place_affine_device`], so the differential sign convention and the
/// per-column inverter in dual mode work exactly like the FC/BN builders —
/// the "Add" stages the coverage report marks spice-exempt now have a
/// first-class netlist too.
pub fn build_residual_crossbar(name: &str, dim: usize, mode: MapMode) -> Crossbar {
    assert!(dim > 0, "residual crossbar needs channels");
    let region = 2 * dim;
    let inverted = mode.inverted();
    let mut devices = Vec::with_capacity(region);
    for j in 0..dim {
        place_affine_device(&mut devices, region, inverted, j, Some(j), 1.0, 1.0);
        place_affine_device(&mut devices, region, inverted, j, Some(dim + j), 1.0, 1.0);
    }
    Crossbar {
        name: name.to_string(),
        rows: 2 * region + 2,
        cols: dim,
        region,
        devices,
        rf_scale: 1.0,
        mode,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{emit_crossbar, parse, plan_segments, solve_segment_outputs};
    use crate::nn::DeviceJson;
    use crate::spice::solve::Ordering;

    fn test_device() -> DeviceJson {
        DeviceJson {
            r_on: 100.0,
            r_off: 16000.0,
            levels: 64,
            prog_sigma: 0.0,
            v_in: 2.5e-3,
            v_rail: 8.0,
            t_mem: 1e-10,
            slew_rate: 1e7,
            v_swing: 5.0,
            p_opamp: 1e-3,
            p_memristor: 1.1e-6,
            p_aux: 5e-4,
            t_opamp: 5e-7,
        }
    }

    #[test]
    fn bn_crossbars_eval_matches_affine_fold() {
        // negative scale, a near-zero-variance-sized fold (|k| >> 1) and a
        // dead channel (k = 0) in one draw, both conventions
        let (c, spatial) = (3usize, 2usize);
        let k = [1.4, -215.0, 0.0];
        let mean = [0.2, -0.4, 0.1];
        let beta = [-0.3, 0.25, 0.0];
        for mode in [MapMode::Inverted, MapMode::Dual] {
            let (sub, scale) = build_bn_crossbars("t.bn", c, spatial, &k, &mean, &beta, mode);
            assert_eq!((sub.cols, scale.cols), (c * spatial, c * spatial));
            let x: Vec<f64> =
                (0..c * spatial).map(|i| (i as f64 * 0.37).sin() * 0.8).collect();
            let y = scale.eval_ideal(&sub.eval_ideal(&x));
            for j in 0..c * spatial {
                let ch = j / spatial;
                let want = (x[j] - mean[ch]) * k[ch] + beta[ch];
                assert!(
                    (y[j] - want).abs() < 1e-9 * (1.0 + want.abs()),
                    "{mode} j={j}: {} vs {want}",
                    y[j]
                );
            }
        }
    }

    #[test]
    fn bn_netlists_solve_to_affine_fold() {
        let (c, spatial) = (2usize, 2usize);
        let k = [2.5, -0.75];
        let mean = [0.3, -0.2];
        let beta = [0.1, -0.4];
        let (sub, scale) =
            build_bn_crossbars("t.bn", c, spatial, &k, &mean, &beta, MapMode::Inverted);
        let dev = test_device();
        let x = [0.5, -0.25, 0.8, 0.0];
        let seg = &plan_segments(sub.cols, 0)[0];
        let text = emit_crossbar(&sub, &dev, seg, Some(&x), 1);
        let u = solve_segment_outputs(&parse(&text).unwrap(), seg, true, Ordering::Smart)
            .unwrap();
        let text = emit_crossbar(&scale, &dev, seg, Some(&u), 1);
        let y = solve_segment_outputs(&parse(&text).unwrap(), seg, true, Ordering::Smart)
            .unwrap();
        for j in 0..c * spatial {
            let ch = j / spatial;
            let want = (x[j] - mean[ch]) * k[ch] + beta[ch];
            assert!(
                (y[j] - want).abs() < 1e-4 * (1.0 + want.abs()),
                "j={j}: spice {} vs fold {want}",
                y[j]
            );
        }
    }

    #[test]
    fn gap_crossbar_eval_and_netlist_match_mean() {
        let (c, spatial) = (3usize, 4usize);
        let x: Vec<f64> = (0..c * spatial).map(|i| (i as f64 * 0.7).cos() * 0.6).collect();
        let mean =
            |ch: usize| x[ch * spatial..(ch + 1) * spatial].iter().sum::<f64>() / spatial as f64;
        for mode in [MapMode::Inverted, MapMode::Dual] {
            let cb = build_gap_crossbar("t.gap", c, spatial, mode);
            assert_eq!(cb.devices.len(), c * spatial); // Eq 12
            assert_eq!(cb.cols, c);
            let got = cb.eval_ideal(&x);
            for ch in 0..c {
                assert!((got[ch] - mean(ch)).abs() < 1e-12, "{mode} ch {ch}");
            }
            let seg = &plan_segments(c, 0)[0];
            let text = emit_crossbar(&cb, &test_device(), seg, Some(&x), 1);
            let outs =
                solve_segment_outputs(&parse(&text).unwrap(), seg, mode.inverted(), Ordering::Smart)
                    .unwrap();
            for (ch, o) in outs.iter().enumerate() {
                assert!((o - mean(ch)).abs() < 1e-4, "{mode} ch {ch}: {o} vs {}", mean(ch));
            }
        }
    }

    #[test]
    fn residual_crossbar_sums_branches() {
        let dim = 3usize;
        let a = [0.4, -0.2, 0.15];
        let b = [-0.1, 0.3, 0.05];
        let x: Vec<f64> = a.iter().chain(&b).copied().collect();
        for mode in [MapMode::Inverted, MapMode::Dual] {
            let cb = build_residual_crossbar("t.add", dim, mode);
            assert_eq!(cb.devices.len(), 2 * dim);
            assert_eq!(cb.cols, dim);
            let got = cb.eval_ideal(&x);
            for j in 0..dim {
                let want = a[j] + b[j];
                assert!((got[j] - want).abs() < 1e-12, "{mode} j={j}");
            }
            let seg = &plan_segments(dim, 0)[0];
            let text = emit_crossbar(&cb, &test_device(), seg, Some(&x), 1);
            let outs = solve_segment_outputs(
                &parse(&text).unwrap(),
                seg,
                mode.inverted(),
                Ordering::Smart,
            )
            .unwrap();
            for (j, o) in outs.iter().enumerate() {
                let want = a[j] + b[j];
                assert!((o - want).abs() < 1e-4, "{mode} j={j}: spice {o} vs {want}");
            }
        }
    }

    #[test]
    fn behavioural_matches_software_inside_rails() {
        for i in -50..=50 {
            let x = i as f64 / 10.0;
            if x.abs() < 7.9 {
                assert!((hard_swish_analog(x, 8.0) - hard_swish_sw(x)).abs() < 1e-12);
            }
        }
        assert_eq!(relu_analog(-2.0, 8.0), 0.0);
        assert_eq!(relu_analog(12.0, 8.0), 8.0);
    }

    #[test]
    fn spice_hard_sigmoid_linear_region() {
        let mut hs = build_hard_sigmoid();
        for x in [-2.0, -1.0, 0.0, 1.0, 2.0] {
            let y = hs.eval(x).unwrap();
            let want = hard_sigmoid_sw(x);
            assert!((y - want).abs() < 0.02, "x={x}: spice {y} vs sw {want}");
        }
    }

    #[test]
    fn spice_hard_sigmoid_saturates() {
        let mut hs = build_hard_sigmoid();
        let y_lo = hs.eval(-6.0).unwrap();
        let y_hi = hs.eval(6.0).unwrap();
        assert!(y_lo.abs() < KNEE_TOL, "low clamp {y_lo}");
        assert!((y_hi - 1.0).abs() < KNEE_TOL, "high clamp {y_hi}");
    }

    #[test]
    fn spice_hard_sigmoid_monotone() {
        let mut hs = build_hard_sigmoid();
        let curve = hs.sweep(-5.0, 5.0, 41).unwrap();
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-6, "non-monotone at {:?}", w);
        }
    }

    #[test]
    fn spice_hard_swish_matches_software() {
        let mut hw = build_hard_swish();
        for x in [-4.0, -2.0, -1.0, 0.0, 0.5, 1.0, 2.0, 4.0] {
            let y = hw.eval(x).unwrap();
            let want = hard_swish_sw(x);
            assert!(
                (y - want).abs() < KNEE_TOL + 0.02 * x.abs(),
                "x={x}: spice {y} vs sw {want}"
            );
        }
    }

    #[test]
    fn sweep_cache_matches_cold_solves() {
        // the cached sweep (one ActCircuit reused across points) must match
        // cold solves (a freshly built circuit per point) within 1e-9 —
        // the factor-once/solve-many equivalence guarantee
        for swish in [false, true] {
            let mut warm = if swish { build_hard_swish() } else { build_hard_sigmoid() };
            let curve = warm.sweep(-4.0, 4.0, 33).unwrap();
            for &(x, y) in &curve {
                let mut cold = if swish { build_hard_swish() } else { build_hard_sigmoid() };
                let y_cold = cold.eval(x).unwrap();
                assert!(
                    (y - y_cold).abs() < 1e-9,
                    "swish={swish} x={x}: cached {y} vs cold {y_cold}"
                );
            }
        }
    }

    #[test]
    fn sweep_covers_range() {
        let mut hs = build_hard_sigmoid();
        let curve = hs.sweep(-4.0, 4.0, 17).unwrap();
        assert_eq!(curve.len(), 17);
        assert_eq!(curve[0].0, -4.0);
        assert_eq!(curve[16].0, 4.0);
    }
}
