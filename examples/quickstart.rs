//! Quickstart — the five-minute tour of the memx public API.
//!
//!   cargo run --release --example quickstart
//!
//! Loads the AOT artifacts (run `make artifacts` once), classifies a few
//! images with the memristor analog model, maps one layer to a crossbar,
//! emits + simulates its SPICE netlist, and prints the latency/energy
//! estimates — every major subsystem in ~80 lines.

#[cfg(feature = "runtime-xla")]
use std::path::Path;

#[cfg(feature = "runtime-xla")]
use memx::coordinator::{accuracy, classify_dataset};
#[cfg(feature = "runtime-xla")]
use memx::mapper::{self, MapMode};
#[cfg(feature = "runtime-xla")]
use memx::netlist;
#[cfg(feature = "runtime-xla")]
use memx::nn::{Manifest, WeightStore};
#[cfg(feature = "runtime-xla")]
use memx::power;
#[cfg(feature = "runtime-xla")]
use memx::runtime::{Engine, Model};
#[cfg(feature = "runtime-xla")]
use memx::spice::solve::Ordering;
#[cfg(feature = "runtime-xla")]
use memx::util::bin::Dataset;

#[cfg(feature = "runtime-xla")]
fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");

    // 1. runtime: load + compile the AOT'd memristor model, classify images
    let engine = Engine::new(dir)?;
    println!("PJRT platform: {}", engine.platform());
    let ds = Dataset::load(&dir.join(&engine.manifest().dataset_file))?;
    let (labels, wall) = classify_dataset(&engine, Model::Analog, &ds, 32)?;
    let acc = accuracy(&labels, &ds.labels[..labels.len()]);
    println!("analog model: {:.1}% on {} images in {wall:?}", acc * 100.0, labels.len());

    // 2. mapper: weights -> differential quantized crossbar (paper §3.2)
    let manifest = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &manifest)?;
    let cb = mapper::build_fc_crossbar(&manifest, &ws, "cls.fc2", MapMode::Inverted)?;
    println!(
        "cls.fc2 crossbar: {}x{} with {} memristors (zero weights omitted)",
        cb.rows,
        cb.cols,
        cb.devices.len()
    );

    // 3. netlist + SPICE: emit, parse back, DC-solve, compare to the ideal
    let inputs: Vec<f64> = (0..cb.region).map(|i| ((i as f64) * 0.1).sin() * 0.3).collect();
    let seg = &netlist::plan_segments(cb.cols, 0)[0];
    let text = netlist::emit_crossbar(&cb, &manifest.device, seg, Some(&inputs), 1);
    let circuit = netlist::parse(&text)?;
    let spice_out = netlist::solve_segment_outputs(&circuit, seg, true, Ordering::Smart)?;
    let ideal = cb.eval_ideal(&inputs);
    let err = spice_out
        .iter()
        .zip(&ideal)
        .fold(0f64, |a, (s, i)| a.max((s - i).abs()));
    println!("SPICE vs ideal crossbar: max error {err:.2e} over {} columns", cb.cols);

    // 4. analytical models: Eq 17 latency + Eq 18 energy
    let net = mapper::map_network(&manifest, &ws, MapMode::Inverted)?;
    let t = power::latency(&net, &manifest.device);
    let e = power::energy(&net, &manifest.device, &t);
    println!(
        "mapped network: {} memristors, {} op-amps, {} crossbar stages",
        net.total_memristors(),
        net.total_opamps(),
        net.memristor_stages()
    );
    println!(
        "inference: {:.2} µs sequential / {:.2} µs pipelined, {:.1} µJ",
        t.total * 1e6,
        power::latency_pipelined(&net, &manifest.device).total * 1e6,
        e.total * 1e6
    );
    Ok(())
}

#[cfg(not(feature = "runtime-xla"))]
fn main() {
    eprintln!(
        "this example needs the PJRT runtime: rebuild with --features runtime-xla \
         (requires the xla crate + libxla_extension; see Cargo.toml)"
    );
}
