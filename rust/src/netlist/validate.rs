//! `spicier-validate`-style differential harness for the SPICE substrate.
//!
//! Three independent legs, each deliberately sharing no code with the
//! production engine in [`crate::spice`]:
//!
//! 1. **Round-trip conformance** ([`check_deck`]): every resident module
//!    deck is emitted through [`super::interchange::emit_deck`], re-parsed,
//!    proven to capture the element list losslessly (bit-equal values
//!    after name/node normalization), and re-simulated — outputs must
//!    match the resident solve to ≤ [`ROUNDTRIP_TOL`] relative (the
//!    node-order pins in the emitter make the match exact in practice).
//! 2. **Independent reference MNA** ([`reference_dc_op`]): a dense
//!    Gaussian-elimination solver with its own stamping walk and its own
//!    Newton loop — no [`crate::spice::factor`], no
//!    [`crate::spice::solve`], no shared elimination code — checked
//!    against the production engine on the same circuits to
//!    ≤ [`REFERENCE_TOL`] relative. Only the *device models* (diode
//!    companion constants, multiplier linearization) are mirrored, since
//!    they define the circuit semantics being cross-checked.
//! 3. **Generated corpora**: [`fuzz_deck`] produces grammar-shaped (and
//!    deliberately malformed) deck text that the parser must accept or
//!    reject without panicking, and [`gen_mna_circuit`] produces random
//!    MNA systems — including the zero-diagonal V-source / VCVS pivot
//!    pairs that stress the pivoting paths in `factor` and `krylov`.
//!
//! Tolerance contract: `rel_diff` is worst-case node-voltage difference
//! divided by `max(1 V, |V|_max)` — relative for rail-scale signals,
//! absolute below one volt. [`ROUNDTRIP_TOL`] = 1e-12 (same engine, same
//! bits on both sides); [`REFERENCE_TOL`] = 1e-6 (two different
//! elimination algorithms on TIA-style systems whose conditioning is set
//! by the 1e6 op-amp gain).

use anyhow::{anyhow, bail, Context, Result};

use super::interchange::{card_name, emit_deck, parse_deck, Deck};
use crate::spice::krylov::SolverStrategy;
use crate::spice::{Circuit, Element};
use crate::util::prng::Rng;

/// Emit → parse → sim must match the resident solve this tightly.
pub const ROUNDTRIP_TOL: f64 = 1e-12;
/// Independent dense reference (and the Krylov engine) must agree with the
/// production direct engine this tightly.
pub const REFERENCE_TOL: f64 = 1e-6;
/// Reference-solver size cutoff: dense O(n³) elimination above this MNA
/// dimension is skipped (reported as `None`), not attempted.
pub const REFERENCE_DIM_CAP: usize = 800;

/// Worst node-voltage difference scaled by `max(1 V, |V|_max)`.
pub fn rel_diff(a: &[f64], b: &[f64]) -> f64 {
    let mut scale = 1.0f64;
    for v in a.iter().chain(b.iter()) {
        scale = scale.max(v.abs());
    }
    let mut worst = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        worst = worst.max((x - y).abs());
    }
    worst / scale
}

// ---------------------------------------------------------------------------
// independent dense reference MNA
// ---------------------------------------------------------------------------

/// DC operating point from the independent dense reference solver.
///
/// Same MNA formulation as the production engine — node voltages with
/// ground dropped, one branch-current unknown per V source / VCVS /
/// multiplier / inductor in element order — but its own stamping walk,
/// its own partial-pivot Gaussian elimination and its own damped Newton
/// loop. Returns the full node-voltage vector (index = node id, ground
/// included as 0 V).
pub fn reference_dc_op(c: &Circuit) -> Result<Vec<f64>> {
    let n_nodes = c.node_count();
    let n_br = c
        .elements
        .iter()
        .filter(|e| {
            matches!(
                e,
                Element::Vsource(..)
                    | Element::Vcvs(..)
                    | Element::Mult(..)
                    | Element::Inductor(..)
            )
        })
        .count();
    let dim = (n_nodes - 1) + n_br;
    if dim == 0 {
        return Ok(vec![0.0; n_nodes]);
    }
    let nonlinear = c
        .elements
        .iter()
        .any(|e| matches!(e, Element::Diode(..) | Element::Mult(..)));

    let mut v = vec![0.0; n_nodes];
    let max_iter = if nonlinear { 400 } else { 1 };
    for _ in 0..max_iter {
        let (a, b) = assemble_dense(c, dim, n_nodes, &v)?;
        let x = gauss_solve(a, b)?;
        let mut next = vec![0.0; n_nodes];
        next[1..].copy_from_slice(&x[..n_nodes - 1]);
        if !nonlinear {
            return Ok(next);
        }
        let mut delta = 0.0f64;
        for i in 0..n_nodes {
            delta = delta.max((next[i] - v[i]).abs());
        }
        for i in 0..n_nodes {
            // damped update: junction voltages move at most half a volt
            v[i] += (next[i] - v[i]).clamp(-0.5, 0.5);
        }
        if delta < 1e-11 {
            return Ok(v);
        }
    }
    bail!("reference Newton loop did not converge")
}

/// Dense MNA assembly around the linearization point `v_prev`. DC view:
/// capacitors open, inductors short. The diode companion constants and
/// the multiplier linearization mirror the production device models —
/// they are the semantics under test, not solver code.
fn assemble_dense(
    c: &Circuit,
    dim: usize,
    n_nodes: usize,
    v_prev: &[f64],
) -> Result<(Vec<Vec<f64>>, Vec<f64>)> {
    let mut a = vec![vec![0.0f64; dim]; dim];
    let mut b = vec![0.0f64; dim];
    let nd = |node: usize| node.checked_sub(1);
    let mut br = n_nodes - 1;
    for e in &c.elements {
        match *e {
            Element::Resistor(ref name, p, q, r) => {
                if r <= 0.0 {
                    bail!("resistor {name} has non-positive value {r}");
                }
                let g = 1.0 / r;
                if let Some(i) = nd(p) {
                    a[i][i] += g;
                }
                if let Some(j) = nd(q) {
                    a[j][j] += g;
                }
                if let (Some(i), Some(j)) = (nd(p), nd(q)) {
                    a[i][j] -= g;
                    a[j][i] -= g;
                }
            }
            Element::Isource(_, p, q, amps) => {
                if let Some(i) = nd(p) {
                    b[i] -= amps;
                }
                if let Some(j) = nd(q) {
                    b[j] += amps;
                }
            }
            Element::Vsource(_, p, q, volts) => {
                if let Some(i) = nd(p) {
                    a[i][br] += 1.0;
                    a[br][i] += 1.0;
                }
                if let Some(j) = nd(q) {
                    a[j][br] -= 1.0;
                    a[br][j] -= 1.0;
                }
                b[br] += volts;
                br += 1;
            }
            Element::Vccs(_, op, om, cp, cm, gm) => {
                if let (Some(i), Some(k)) = (nd(op), nd(cp)) {
                    a[i][k] += gm;
                }
                if let (Some(i), Some(l)) = (nd(op), nd(cm)) {
                    a[i][l] -= gm;
                }
                if let (Some(j), Some(k)) = (nd(om), nd(cp)) {
                    a[j][k] -= gm;
                }
                if let (Some(j), Some(l)) = (nd(om), nd(cm)) {
                    a[j][l] += gm;
                }
            }
            Element::Vcvs(_, op, om, cp, cm, gain) => {
                if let Some(i) = nd(op) {
                    a[i][br] += 1.0;
                    a[br][i] += 1.0;
                }
                if let Some(j) = nd(om) {
                    a[j][br] -= 1.0;
                    a[br][j] -= 1.0;
                }
                if let Some(i) = nd(cp) {
                    a[br][i] -= gain;
                }
                if let Some(j) = nd(cm) {
                    a[br][j] += gain;
                }
                br += 1;
            }
            Element::Mult(_, out, ca, cb, gain) => {
                // V(out) = gain·Va·Vb linearized at (Va0, Vb0):
                // V(out) - gain·Vb0·Va - gain·Va0·Vb = -gain·Va0·Vb0
                let va0 = v_prev[ca];
                let vb0 = v_prev[cb];
                if let Some(i) = nd(out) {
                    a[i][br] += 1.0;
                    a[br][i] += 1.0;
                }
                if let Some(i) = nd(ca) {
                    a[br][i] -= gain * vb0;
                }
                if let Some(j) = nd(cb) {
                    a[br][j] -= gain * va0;
                }
                b[br] -= gain * va0 * vb0;
                br += 1;
            }
            Element::Capacitor(ref name, _, _, cap) => {
                if cap <= 0.0 {
                    bail!("capacitor {name} has non-positive value {cap}");
                }
                // open at DC
            }
            Element::Inductor(ref name, p, q, ind) => {
                if ind <= 0.0 {
                    bail!("inductor {name} has non-positive value {ind}");
                }
                // short at DC, branch current as unknown
                if let Some(i) = nd(p) {
                    a[i][br] += 1.0;
                    a[br][i] += 1.0;
                }
                if let Some(j) = nd(q) {
                    a[j][br] -= 1.0;
                    a[br][j] -= 1.0;
                }
                br += 1;
            }
            Element::Diode(_, p, q, isat, nvt) => {
                // shared device model: clamped-junction Newton companion
                let v0 = (v_prev[p] - v_prev[q]).clamp(-5.0, 0.9);
                let ex = (v0 / nvt).exp();
                let g_eq = (isat / nvt * ex).max(1e-12);
                let i_eq = isat * (ex - 1.0) - g_eq * v0;
                if let Some(i) = nd(p) {
                    a[i][i] += g_eq;
                    b[i] -= i_eq;
                }
                if let Some(j) = nd(q) {
                    a[j][j] += g_eq;
                    b[j] += i_eq;
                }
                if let (Some(i), Some(j)) = (nd(p), nd(q)) {
                    a[i][j] -= g_eq;
                    a[j][i] -= g_eq;
                }
            }
        }
    }
    Ok((a, b))
}

/// Dense Gaussian elimination with partial pivoting — the reference
/// solver's own elimination, no code shared with `spice::solve` or
/// `spice::factor`.
fn gauss_solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Result<Vec<f64>> {
    let n = b.len();
    for k in 0..n {
        let mut piv = k;
        let mut best = a[k][k].abs();
        for r in k + 1..n {
            let cand = a[r][k].abs();
            if cand > best {
                best = cand;
                piv = r;
            }
        }
        if best <= f64::MIN_POSITIVE {
            bail!("reference MNA matrix is singular at column {k}");
        }
        if piv != k {
            a.swap(piv, k);
            b.swap(piv, k);
        }
        let prow = a[k].clone();
        let bk = b[k];
        let d = prow[k];
        for r in k + 1..n {
            let f = a[r][k] / d;
            if f == 0.0 {
                continue;
            }
            let row = &mut a[r];
            row[k] = 0.0;
            for j in k + 1..n {
                row[j] -= f * prow[j];
            }
            b[r] -= f * bk;
        }
    }
    let mut x = vec![0.0f64; n];
    for k in (0..n).rev() {
        let mut s = b[k];
        for j in k + 1..n {
            s -= a[k][j] * x[j];
        }
        x[k] = s / a[k][k];
    }
    Ok(x)
}

/// Solve `c` on the production engine and on the independent reference;
/// return their [`rel_diff`]. Does not enforce a tolerance — callers pick
/// the contract.
pub fn reference_vs_production(c: &Circuit) -> Result<f64> {
    let prod = c.dc_op().context("production dc_op")?;
    let reference = reference_dc_op(c).context("reference dc_op")?;
    Ok(rel_diff(&prod, &reference))
}

// ---------------------------------------------------------------------------
// deck conformance
// ---------------------------------------------------------------------------

/// Per-deck conformance result (all checks already enforced by
/// [`check_deck`]; the numbers are for reporting).
#[derive(Debug, Clone)]
pub struct DeckReport {
    pub name: String,
    pub nodes: usize,
    pub elements: usize,
    /// emit → parse → sim vs resident sim ([`rel_diff`]).
    pub roundtrip_rel: f64,
    /// worst independent-reference disagreement over resident + parsed
    /// circuit; `None` when the MNA dimension exceeds
    /// [`REFERENCE_DIM_CAP`].
    pub reference_rel: Option<f64>,
    /// Krylov-strategy solve vs direct solve on the resident circuit.
    pub krylov_rel: f64,
}

fn strip_inst(name: &str) -> String {
    name.strip_prefix("X1.").unwrap_or(name).to_string()
}

/// Map the parsed deck back onto the resident circuit's namespace: strip
/// the `X1.` instance prefix from element names, translate node ids via
/// node names, and drop the inert `Ipin` node-order pins. The result is
/// directly comparable to [`canonical_cards`] of the resident circuit —
/// equality proves the deck captured the circuit losslessly (values
/// bit-equal included). The comparison runs against card names rather
/// than raw resident names because the emitter prepends the type letter
/// to names that lack it (`XMUL` → `BXMUL`) and the parser keeps the full
/// card token.
pub fn normalize_parsed(parsed: &Circuit, resident: &Circuit) -> Result<Vec<Element>> {
    let pnames = parsed.node_names();
    let mut map = vec![0usize; pnames.len()];
    for (pid, pname) in pnames.iter().enumerate().skip(1) {
        let bare = pname.strip_prefix("X1.").unwrap_or(pname);
        map[pid] = resident
            .node_named(bare)
            .ok_or_else(|| anyhow!("round trip invented node '{pname}'"))?;
    }
    let m = |n: usize| map[n];
    Ok(parsed
        .elements
        .iter()
        .filter(|e| !e.name().contains("Ipin"))
        .map(|e| match e {
            Element::Resistor(n, p, q, v) => Element::Resistor(strip_inst(n), m(*p), m(*q), *v),
            Element::Vsource(n, p, q, v) => Element::Vsource(strip_inst(n), m(*p), m(*q), *v),
            Element::Isource(n, p, q, v) => Element::Isource(strip_inst(n), m(*p), m(*q), *v),
            Element::Vcvs(n, op, om, cp, cm, g) => {
                Element::Vcvs(strip_inst(n), m(*op), m(*om), m(*cp), m(*cm), *g)
            }
            Element::Vccs(n, op, om, cp, cm, g) => {
                Element::Vccs(strip_inst(n), m(*op), m(*om), m(*cp), m(*cm), *g)
            }
            Element::Diode(n, p, q, isat, nvt) => {
                Element::Diode(strip_inst(n), m(*p), m(*q), *isat, *nvt)
            }
            Element::Mult(n, out, ca, cb, g) => {
                Element::Mult(strip_inst(n), m(*out), m(*ca), m(*cb), *g)
            }
            Element::Capacitor(n, p, q, v) => Element::Capacitor(strip_inst(n), m(*p), m(*q), *v),
            Element::Inductor(n, p, q, v) => Element::Inductor(strip_inst(n), m(*p), m(*q), *v),
        })
        .collect())
}

/// The emitter's view of a resident element list: names mapped through
/// the [`card_name`] type-letter rule, nodes and values untouched. This
/// is what [`normalize_parsed`] output must equal exactly.
pub fn canonical_cards(c: &Circuit) -> Vec<Element> {
    c.elements
        .iter()
        .map(|e| match e {
            Element::Resistor(n, p, q, v) => Element::Resistor(card_name('R', n), *p, *q, *v),
            Element::Vsource(n, p, q, v) => Element::Vsource(card_name('V', n), *p, *q, *v),
            Element::Isource(n, p, q, v) => Element::Isource(card_name('I', n), *p, *q, *v),
            Element::Vcvs(n, op, om, cp, cm, g) => {
                Element::Vcvs(card_name('E', n), *op, *om, *cp, *cm, *g)
            }
            Element::Vccs(n, op, om, cp, cm, g) => {
                Element::Vccs(card_name('G', n), *op, *om, *cp, *cm, *g)
            }
            Element::Diode(n, p, q, isat, nvt) => {
                Element::Diode(card_name('D', n), *p, *q, *isat, *nvt)
            }
            Element::Mult(n, out, ca, cb, g) => {
                Element::Mult(card_name('B', n), *out, *ca, *cb, *g)
            }
            Element::Capacitor(n, p, q, v) => Element::Capacitor(card_name('C', n), *p, *q, *v),
            Element::Inductor(n, p, q, v) => Element::Inductor(card_name('L', n), *p, *q, *v),
        })
        .collect()
}

/// Run the full conformance contract on one deck:
///
/// 1. emit → parse succeeds and captures the element list losslessly;
/// 2. the parsed deck re-simulates to the resident solution
///    (≤ [`ROUNDTRIP_TOL`]);
/// 3. the independent dense reference agrees with the production engine
///    on both the resident and the parsed circuit (≤ [`REFERENCE_TOL`],
///    skipped above [`REFERENCE_DIM_CAP`] unknowns);
/// 4. an explicitly iterative (Krylov) solve agrees with the direct solve
///    (≤ [`REFERENCE_TOL`]).
///
/// Any violation is an `Err`; the returned report carries the measured
/// margins.
pub fn check_deck(deck: &Deck) -> Result<DeckReport> {
    let name = &deck.name;
    let resident = deck
        .circuit
        .dc_op()
        .with_context(|| format!("deck '{name}': resident solve"))?;

    // 1. lossless capture
    let text = emit_deck(deck);
    let parsed = parse_deck(&text)
        .map_err(|e| anyhow!("deck '{name}': emitted deck failed to parse: {e}"))?;
    let norm = normalize_parsed(&parsed, &deck.circuit).with_context(|| format!("deck '{name}'"))?;
    if norm != canonical_cards(&deck.circuit) {
        bail!("deck '{name}': round trip altered the element list");
    }

    // 2. re-simulate and compare every node (interface nodes keep their
    // names; internals come back with the X1. instance prefix). Both
    // sides run the deterministic pre-factorization engine: the node-order
    // pins make the parsed deck assemble the bit-identical MNA system, so
    // this comparison is exact by construction — the factored/cached
    // engine (whose pivot order may legitimately differ between a warm
    // resident circuit and a cold parsed one) is cross-checked separately
    // in steps 3 and 4.
    let (det_resident, _) = deck
        .circuit
        .dc_op_stats_reference(crate::spice::solve::Ordering::Smart)
        .with_context(|| format!("deck '{name}': resident deterministic solve"))?;
    let (det_parsed, _) = parsed
        .dc_op_stats_reference(crate::spice::solve::Ordering::Smart)
        .with_context(|| format!("deck '{name}': parsed solve"))?;
    let names = deck.circuit.node_names();
    let mut resident_by_name = Vec::with_capacity(names.len());
    let mut parsed_by_name = Vec::with_capacity(names.len());
    for (id, nm) in names.iter().enumerate().skip(1) {
        let pid = parsed
            .node_named(nm)
            .or_else(|| parsed.node_named(&format!("X1.{nm}")))
            .ok_or_else(|| anyhow!("deck '{name}': round trip lost node '{nm}'"))?;
        resident_by_name.push(det_resident[id]);
        parsed_by_name.push(det_parsed[pid]);
    }
    let roundtrip_rel = rel_diff(&resident_by_name, &parsed_by_name);
    if roundtrip_rel > ROUNDTRIP_TOL {
        bail!("deck '{name}': round-trip sim diverged ({roundtrip_rel:.3e} > {ROUNDTRIP_TOL:.0e})");
    }
    // factored engine vs its own pre-factorization engine on the resident
    let factored_rel = rel_diff(&resident, &det_resident);
    if factored_rel > REFERENCE_TOL {
        bail!(
            "deck '{name}': factored vs pre-factorization engines diverged ({factored_rel:.3e})"
        );
    }

    // 3. independent reference on both sides of the round trip
    let dim = (deck.circuit.node_count() - 1)
        + deck
            .circuit
            .elements
            .iter()
            .filter(|e| {
                matches!(
                    e,
                    Element::Vsource(..)
                        | Element::Vcvs(..)
                        | Element::Mult(..)
                        | Element::Inductor(..)
                )
            })
            .count();
    let reference_rel = if dim <= REFERENCE_DIM_CAP {
        let r1 = reference_vs_production(&deck.circuit)
            .with_context(|| format!("deck '{name}': resident vs reference"))?;
        let r2 = reference_vs_production(&parsed)
            .with_context(|| format!("deck '{name}': parsed vs reference"))?;
        let worst = r1.max(r2);
        if worst > REFERENCE_TOL {
            bail!(
                "deck '{name}': independent reference disagrees ({worst:.3e} > {REFERENCE_TOL:.0e})"
            );
        }
        Some(worst)
    } else {
        None
    };

    // 4. Krylov engine vs direct on the resident circuit
    let mut kc = deck.circuit.clone();
    kc.set_solver(SolverStrategy::Iterative { restart: 48, tol: 1e-12, max_iter: 600 });
    let ksol = kc
        .dc_op()
        .with_context(|| format!("deck '{name}': krylov solve"))?;
    let krylov_rel = rel_diff(&resident, &ksol);
    if krylov_rel > REFERENCE_TOL {
        bail!("deck '{name}': krylov vs direct diverged ({krylov_rel:.3e} > {REFERENCE_TOL:.0e})");
    }

    Ok(DeckReport {
        name: name.clone(),
        nodes: deck.circuit.node_count(),
        elements: deck.circuit.elements.len(),
        roundtrip_rel,
        reference_rel,
        krylov_rel,
    })
}

// ---------------------------------------------------------------------------
// generated corpora
// ---------------------------------------------------------------------------

const FUZZ_NODES: [&str; 9] = ["0", "gnd", "a", "b", "c", "d", "e", "n1", "n2"];

fn fuzz_node(rng: &mut Rng) -> &'static str {
    FUZZ_NODES[rng.below(FUZZ_NODES.len())]
}

fn fuzz_value(rng: &mut Rng) -> String {
    match rng.below(7) {
        0 => format!("{}", rng.range_f64(-10.0, 10.0)),
        1 => format!("{:.3}k", rng.range_f64(0.1, 99.0)),
        2 => format!("{:.1}meg", rng.range_f64(0.1, 9.0)),
        3 => format!("{}u", rng.below(1000)),
        4 => "garbage".to_string(),
        5 => format!("{:.2}ohm", rng.range_f64(1.0, 99.0)),
        _ => format!("{:.4}", rng.range_f64(0.0, 5.0)),
    }
}

fn fuzz_card(rng: &mut Rng) -> String {
    const KINDS: [&str; 12] = ["R", "V", "I", "E", "G", "C", "L", "D", "B", "Q", "Z", "W"];
    let kind = KINDS[rng.below(KINDS.len())];
    let mut toks = vec![format!("{kind}{}", rng.below(100))];
    for _ in 0..rng.below(7) {
        toks.push(fuzz_node(rng).to_string());
    }
    if rng.below(4) > 0 {
        toks.push(fuzz_value(rng));
    }
    toks.join(" ")
}

fn push_fuzz_card(out: &mut String, rng: &mut Rng) {
    if rng.below(8) == 0 {
        out.push_str("* interleaved comment\n");
    }
    let card = fuzz_card(rng);
    let toks: Vec<&str> = card.split(' ').collect();
    if rng.below(4) == 0 && toks.len() > 2 {
        // split into a continuation pair
        let cut = 1 + rng.below(toks.len() - 1);
        out.push_str(&toks[..cut].join(" "));
        out.push_str("\n+ ");
        out.push_str(&toks[cut..].join(" "));
        out.push('\n');
    } else {
        out.push_str(&card);
        out.push('\n');
    }
}

/// Grammar-shaped deck fuzzer: emits mostly-plausible interchange decks
/// with deliberate corruption — bad values, wrong arities, unknown cards,
/// duplicate or ground ports, unterminated `.SUBCKT` blocks, dangling
/// instantiations. The parser must return `Ok` or a structured `Err` on
/// every output; panicking or runaway expansion is a bug.
pub fn fuzz_deck(rng: &mut Rng, size: usize) -> String {
    let mut out = String::from("* fuzz corpus deck\n");
    let n_sub = rng.below(3);
    for s in 0..n_sub {
        out.push_str(&format!(".SUBCKT sub{s}"));
        for p in 0..rng.below(4) {
            let port = match rng.below(6) {
                0 => "p0".to_string(),          // collides when p > 0
                1 => fuzz_node(rng).to_string(), // may be ground
                _ => format!("p{p}"),
            };
            out.push(' ');
            out.push_str(&port);
        }
        out.push('\n');
        for _ in 0..rng.below(5) {
            push_fuzz_card(&mut out, rng);
        }
        if rng.below(8) > 0 {
            out.push_str(&format!(".ENDS sub{s}\n"));
        }
    }
    for _ in 0..2 + rng.below(4 + size.min(24)) {
        push_fuzz_card(&mut out, rng);
    }
    for i in 0..rng.below(3) {
        let target = if n_sub > 0 && rng.bool() {
            format!("sub{}", rng.below(n_sub))
        } else {
            "nosuch".to_string()
        };
        out.push_str(&format!("X{i} {} {} {target}\n", fuzz_node(rng), fuzz_node(rng)));
    }
    if rng.below(5) > 0 {
        out.push_str(".END\n");
    }
    out
}

/// Random solvable MNA system for the differential sweep. A spanning tree
/// of resistors over ground keeps the resistive core nonsingular; V
/// sources tie distinct nodes to ground (no source loops); every source
/// branch row has a zero diagonal, and each ideal-op-amp TIA cell adds an
/// output node whose only conductance arrives through its feedback
/// resistor — the zero-diagonal VCVS pivot pattern the production
/// `factor`/`krylov` paths must permute around. VCCS transconductances
/// stay below the smallest resistor conductance so the perturbed system
/// remains safely nonsingular.
pub fn gen_mna_circuit(rng: &mut Rng, size: usize) -> Circuit {
    let mut c = Circuit::new("fuzz-mna");
    let n = 2 + rng.below(2 + size.min(18));
    let mut ids = vec![0usize];
    for i in 0..n {
        ids.push(c.node(&format!("n{i}")));
    }
    // spanning tree to ground
    for i in 1..=n {
        let j = ids[rng.below(i)];
        c.resistor(&format!("Rt{i}"), ids[i], j, rng.range_f64(50.0, 2e4));
    }
    // extra cross links
    for k in 0..rng.below(n + 1) {
        let p = ids[1 + rng.below(n)];
        let q = ids[rng.below(n + 1)];
        if p != q {
            c.resistor(&format!("Rx{k}"), p, q, rng.range_f64(50.0, 2e4));
        }
    }
    // V sources on distinct nodes vs ground
    let mut vnodes: Vec<usize> = (1..=n).collect();
    rng.shuffle(&mut vnodes);
    let nv = 1 + rng.below(n.min(3));
    for (k, &vi) in vnodes.iter().take(nv).enumerate() {
        c.vsource(&format!("Vs{k}"), ids[vi], 0, rng.range_f64(-5.0, 5.0));
    }
    // current sources
    for k in 0..rng.below(3) {
        let p = ids[1 + rng.below(n)];
        c.isource(&format!("Is{k}"), p, 0, rng.range_f64(-1e-3, 1e-3));
    }
    // ideal-op-amp TIA cells: zero-diagonal VCVS pivot pairs
    for k in 0..1 + rng.below(3) {
        let out = c.node(&format!("op{k}"));
        let inn = ids[1 + rng.below(n)];
        c.resistor(&format!("Rf{k}"), inn, out, rng.range_f64(1e3, 1e5));
        c.vcvs(&format!("Eop{k}"), out, 0, 0, inn, 1e6);
    }
    // weak transconductances (gm well under the min tree conductance 5e-5)
    for k in 0..rng.below(3) {
        let op = ids[1 + rng.below(n)];
        let cp = ids[1 + rng.below(n)];
        c.vccs(&format!("Gm{k}"), op, 0, cp, 0, rng.range_f64(1e-7, 1e-5));
    }
    c
}

/// Sweep `cases` generated MNA circuits through production-vs-reference;
/// returns the worst observed [`rel_diff`]. Errors if any case exceeds
/// [`REFERENCE_TOL`].
pub fn differential_sweep(seed: u64, cases: usize) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let mut worst = 0.0f64;
    for i in 0..cases {
        let c = gen_mna_circuit(&mut rng, 1 + i % 16);
        let rel = reference_vs_production(&c)
            .with_context(|| format!("differential sweep case {i} (seed {seed})"))?;
        if rel > REFERENCE_TOL {
            bail!(
                "differential sweep case {i} (seed {seed}): production vs reference {rel:.3e} > {REFERENCE_TOL:.0e}"
            );
        }
        worst = worst.max(rel);
    }
    Ok(worst)
}

/// Parse `cases` fuzzed decks; returns `(accepted, rejected)`. Any panic
/// propagates — the point of the sweep.
pub fn fuzz_sweep(seed: u64, cases: usize) -> (usize, usize) {
    let mut rng = Rng::new(seed);
    let (mut ok, mut rejected) = (0usize, 0usize);
    for i in 0..cases {
        let deck = fuzz_deck(&mut rng, 1 + i % 24);
        match parse_deck(&deck) {
            Ok(_) => ok += 1,
            Err(_) => rejected += 1,
        }
    }
    (ok, rejected)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn divider() -> Circuit {
        let mut c = Circuit::new("div");
        let top = c.node("top");
        let mid = c.node("mid");
        c.vsource("V1", top, 0, 6.0);
        c.resistor("R1", top, mid, 1000.0);
        c.resistor("R2", mid, 0, 2000.0);
        c
    }

    #[test]
    fn reference_matches_hand_solution() {
        let c = divider();
        let sol = reference_dc_op(&c).unwrap();
        let mid = c.node_named("mid").unwrap();
        assert!((sol[mid] - 4.0).abs() < 1e-12, "mid = {}", sol[mid]);
    }

    #[test]
    fn reference_agrees_with_production_on_divider() {
        let rel = reference_vs_production(&divider()).unwrap();
        assert!(rel < 1e-12, "rel = {rel:.3e}");
    }

    #[test]
    fn reference_handles_zero_diagonal_pivots() {
        // TIA: virtual-ground input node + ideal op-amp row — the
        // classic zero-diagonal pivot pair
        let mut c = Circuit::new("tia");
        let inn = c.node("inn");
        let out = c.node("out");
        c.isource("Iin", 0, inn, 1e-4);
        c.resistor("Rf", inn, out, 1e4);
        c.vcvs("Eop", out, 0, 0, inn, 1e6);
        let sol = reference_dc_op(&c).unwrap();
        // I flows into inn, through Rf: V(out) ≈ -Rf * I = -1.0
        assert!((sol[out] + 1.0).abs() < 1e-4, "out = {}", sol[out]);
        let rel = reference_vs_production(&c).unwrap();
        assert!(rel < REFERENCE_TOL, "rel = {rel:.3e}");
    }

    #[test]
    fn reference_rejects_singular() {
        let mut c = Circuit::new("floating");
        let a = c.node("a");
        let b = c.node("b");
        c.resistor("R1", a, b, 100.0);
        // no path to ground: singular
        assert!(reference_dc_op(&c).is_err());
    }

    #[test]
    fn generated_corpus_agrees() {
        let worst = differential_sweep(0xA11CE, 25).unwrap();
        assert!(worst < REFERENCE_TOL, "worst = {worst:.3e}");
    }

    #[test]
    fn fuzz_corpus_never_panics() {
        let (ok, rejected) = fuzz_sweep(0xF00D, 150);
        // the generator emits both valid and corrupt decks; both outcomes
        // must occur, proving the sweep exercises accept and reject paths
        assert!(ok > 0, "no deck parsed ({rejected} rejected)");
        assert!(rejected > 0, "no deck rejected ({ok} accepted)");
    }

    #[test]
    fn check_deck_on_divider() {
        let c = divider();
        let deck = Deck {
            name: "div".into(),
            circuit: c,
            inputs: vec!["top".into()],
            outputs: vec!["mid".into()],
        };
        let report = check_deck(&deck).unwrap();
        assert!(report.roundtrip_rel <= ROUNDTRIP_TOL);
        assert!(report.reference_rel.unwrap() <= REFERENCE_TOL);
        assert!(report.krylov_rel <= REFERENCE_TOL);
        assert_eq!(report.elements, 3);
    }
}
