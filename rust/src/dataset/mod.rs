//! Dataset handling: the memx binary format (written by python at AOT
//! time), a loader for the *real* CIFAR-10 binary batches (if the user
//! supplies them — not available in this offline environment, DESIGN.md §3),
//! and a rust-native synth-cifar generator for tests/benches that must run
//! without artifacts.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Result};

pub use crate::util::bin::Dataset;
use crate::util::prng::Rng;

pub const IMG: usize = 32;
pub const NUM_CLASSES: usize = 10;
pub const CLASS_NAMES: [&str; 10] = [
    "circle", "square", "triangle", "cross", "diagonal",
    "ring", "checker", "stripes", "blob", "dots",
];

/// Load a real CIFAR-10 binary batch file (the canonical `data_batch_*.bin`
/// format: per record `u8 label | 3072 u8 pixels, CHW planar`). Converted to
/// NHWC f32 in [0,1] to match the model's input layout.
pub fn load_cifar10_batch(path: &Path) -> Result<Dataset> {
    let mut raw = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut raw)?;
    const REC: usize = 1 + 3072;
    if raw.len() % REC != 0 {
        bail!("not a CIFAR-10 binary batch: size {} % {REC} != 0", raw.len());
    }
    let n = raw.len() / REC;
    let mut data = vec![0f32; n * IMG * IMG * 3];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let rec = &raw[i * REC..(i + 1) * REC];
        labels[i] = rec[0];
        let px = &rec[1..];
        for c in 0..3 {
            for y in 0..IMG {
                for x in 0..IMG {
                    let v = px[c * IMG * IMG + y * IMG + x] as f32 / 255.0;
                    data[((i * IMG + y) * IMG + x) * 3 + c] = v;
                }
            }
        }
    }
    Ok(Dataset { n, h: IMG, w: IMG, c: 3, data, labels })
}

/// rust-native synth-cifar (same class archetypes as python/compile/data.py;
/// not byte-identical — used only where artifacts are unavailable).
pub fn synth_dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut labels: Vec<u8> = (0..n).map(|i| (i % NUM_CLASSES) as u8).collect();
    rng.shuffle(&mut labels);
    let mut data = vec![0f32; n * IMG * IMG * 3];
    for (i, &c) in labels.iter().enumerate() {
        let img = synth_image(c as usize, &mut rng);
        data[i * IMG * IMG * 3..(i + 1) * IMG * IMG * 3].copy_from_slice(&img);
    }
    Dataset { n, h: IMG, w: IMG, c: 3, data, labels }
}

const PALETTES: [([f32; 3], [f32; 3]); 10] = [
    ([0.9, 0.2, 0.2], [0.1, 0.1, 0.2]),
    ([0.2, 0.8, 0.3], [0.15, 0.1, 0.1]),
    ([0.2, 0.4, 0.9], [0.2, 0.15, 0.05]),
    ([0.9, 0.8, 0.2], [0.1, 0.2, 0.15]),
    ([0.8, 0.3, 0.8], [0.1, 0.15, 0.1]),
    ([0.3, 0.9, 0.9], [0.2, 0.1, 0.15]),
    ([0.95, 0.55, 0.15], [0.1, 0.1, 0.25]),
    ([0.6, 0.9, 0.4], [0.25, 0.1, 0.1]),
    ([0.4, 0.6, 0.95], [0.1, 0.2, 0.1]),
    ([0.9, 0.9, 0.9], [0.15, 0.15, 0.15]),
];

/// One HWC image in [0,1] for class `cls`.
pub fn synth_image(cls: usize, rng: &mut Rng) -> Vec<f32> {
    let (mut fg, mut bg) = PALETTES[cls];
    for ch in 0..3 {
        fg[ch] = (fg[ch] + 0.08 * rng.gaussian() as f32).clamp(0.0, 1.0);
        bg[ch] = (bg[ch] + 0.05 * rng.gaussian() as f32).clamp(0.0, 1.0);
    }
    let cx = rng.range_f64(10.0, 22.0) as f32;
    let cy = rng.range_f64(10.0, 22.0) as f32;
    let r = rng.range_f64(6.0, 11.0) as f32;
    let mask = class_mask(cls, cx, cy, r, rng);

    let gx = rng.range_f64(-0.12, 0.12) as f32;
    let gy = rng.range_f64(-0.12, 0.12) as f32;
    let mut img = vec![0f32; IMG * IMG * 3];
    for y in 0..IMG {
        for x in 0..IMG {
            let m = mask[y * IMG + x];
            let illum = 1.0 + gx * (x as f32 - 16.0) / 16.0 + gy * (y as f32 - 16.0) / 16.0;
            for ch in 0..3 {
                let v = if m { fg[ch] } else { bg[ch] };
                img[(y * IMG + x) * 3 + ch] = (v * illum).clamp(0.0, 1.0);
            }
        }
    }
    // speckles + noise
    let n_spk = rng.below(18);
    for _ in 0..n_spk {
        let sx = rng.below(IMG);
        let sy = rng.below(IMG);
        for ch in 0..3 {
            img[(sy * IMG + sx) * 3 + ch] = rng.f32();
        }
    }
    for v in img.iter_mut() {
        *v = (*v + 0.035 * rng.gaussian() as f32).clamp(0.0, 1.0);
    }
    img
}

fn class_mask(cls: usize, cx: f32, cy: f32, r: f32, rng: &mut Rng) -> Vec<bool> {
    let mut m = vec![false; IMG * IMG];
    let set = |m: &mut Vec<bool>, f: &dyn Fn(f32, f32) -> bool| {
        for y in 0..IMG {
            for x in 0..IMG {
                if f(x as f32, y as f32) {
                    m[y * IMG + x] = true;
                }
            }
        }
    };
    match cls {
        0 => set(&mut m, &|x, y| (x - cx).powi(2) + (y - cy).powi(2) <= r * r),
        1 => set(&mut m, &|x, y| (x - cx).abs() <= r * 0.8 && (y - cy).abs() <= r * 0.8),
        2 => set(&mut m, &|x, y| {
            y - cy <= r * 0.7 && y - cy >= -r && (x - cx).abs() <= (y - cy + r) * 0.55
        }),
        3 => {
            let t = r * rng.range_f64(0.28, 0.4) as f32;
            set(&mut m, &|x, y| {
                ((x - cx).abs() <= t && (y - cy).abs() <= r)
                    || ((y - cy).abs() <= t && (x - cx).abs() <= r)
            })
        }
        4 => {
            let t = r * rng.range_f64(0.3, 0.45) as f32;
            let sign = if rng.bool() { 1.0 } else { -1.0 };
            set(&mut m, &|x, y| {
                let d = ((x - cx) - sign * (y - cy)).abs() / std::f32::consts::SQRT_2;
                d <= t && (x - cx).abs() <= r && (y - cy).abs() <= r
            })
        }
        5 => {
            let inner = r * rng.range_f64(0.45, 0.6) as f32;
            set(&mut m, &|x, y| {
                let d2 = (x - cx).powi(2) + (y - cy).powi(2);
                d2 <= r * r && d2 >= inner * inner
            })
        }
        6 => {
            let p = rng.int_in(4, 6) as usize;
            set(&mut m, &|x, y| ((x as usize / p) + (y as usize / p)) % 2 == 0)
        }
        7 => {
            let p = rng.int_in(3, 5) as usize;
            let ph = rng.below(p);
            set(&mut m, &|_, y| ((y as usize + ph) / p) % 2 == 0)
        }
        8 => set(&mut m, &|x, y| {
            ((x - cx) / (r * 1.3)).powi(2) + ((y - cy) / (r * 0.8)).powi(2) <= 1.0
        }),
        9 => {
            for _ in 0..4 {
                let dx = rng.range_f64(6.0, 26.0) as f32;
                let dy = rng.range_f64(6.0, 26.0) as f32;
                let rr = rng.range_f64(2.2, 3.6) as f32;
                set(&mut m, &|x, y| (x - dx).powi(2) + (y - dy).powi(2) <= rr * rr)
            }
        }
        _ => unreachable!("class out of range"),
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_shapes_and_range() {
        let d = synth_dataset(20, 1);
        assert_eq!(d.n, 20);
        assert_eq!(d.data.len(), 20 * IMG * IMG * 3);
        assert!(d.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn synth_balanced() {
        let d = synth_dataset(100, 2);
        let mut counts = [0usize; 10];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn synth_deterministic() {
        let a = synth_dataset(5, 42);
        let b = synth_dataset(5, 42);
        assert_eq!(a.data, b.data);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn all_class_masks_nonempty() {
        let mut rng = Rng::new(3);
        for cls in 0..10 {
            let m = class_mask(cls, 16.0, 16.0, 8.0, &mut rng);
            let cnt = m.iter().filter(|&&b| b).count();
            assert!(cnt > 0 && cnt < IMG * IMG, "class {cls}: {cnt}");
        }
    }

    #[test]
    fn cifar10_loader_rejects_garbage() {
        let tmp = std::env::temp_dir().join("memx_cifar_garbage.bin");
        std::fs::write(&tmp, [0u8; 100]).unwrap();
        assert!(load_cifar10_batch(&tmp).is_err());
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn cifar10_loader_parses_one_record() {
        let tmp = std::env::temp_dir().join("memx_cifar_one.bin");
        let mut rec = vec![7u8]; // label
        rec.extend(std::iter::repeat(128u8).take(3072));
        std::fs::write(&tmp, &rec).unwrap();
        let d = load_cifar10_batch(&tmp).unwrap();
        assert_eq!(d.n, 1);
        assert_eq!(d.labels[0], 7);
        assert!((d.data[0] - 128.0 / 255.0).abs() < 1e-6);
        std::fs::remove_file(tmp).ok();
    }
}
