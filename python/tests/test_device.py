"""Device model (HP memristor, Eq 16) and differential mapping tests."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import device as dv


class TestHPModel:
    def test_width_bounds(self):
        d = dv.DEFAULT_DEVICE
        assert dv.doped_width(np.array([d.g_on]))[0] == 1.0
        assert abs(dv.doped_width(np.array([d.g_off]))[0]) < 1e-12

    def test_roundtrip(self):
        d = dv.DEFAULT_DEVICE
        g = np.linspace(d.g_off, d.g_on, 64)
        w = dv.doped_width(g, d)
        g2 = dv.width_to_conductance(w, d)
        np.testing.assert_allclose(g, g2, rtol=1e-10)

    def test_out_of_range_clipped(self):
        d = dv.DEFAULT_DEVICE
        w = dv.doped_width(np.array([d.g_on * 10, d.g_off / 10]), d)
        assert np.all(w >= 0.0) and np.all(w <= 1.0)

    @settings(max_examples=50, deadline=None)
    @given(st.floats(0.0, 1.0))
    def test_width_monotone(self, w):
        """More doping -> lower resistance -> higher conductance."""
        d = dv.DEFAULT_DEVICE
        g = dv.width_to_conductance(np.array([w, min(1.0, w + 0.01)]), d)
        assert g[1] >= g[0]


class TestQuantize:
    def test_endpoints_exact(self):
        q = dv.quantize_unit(np.array([0.0, 1.0]), 64)
        assert q[0] == 0.0 and q[1] == 1.0

    def test_error_bound(self):
        x = np.linspace(0, 1, 1001)
        q = dv.quantize_unit(x, 64)
        assert np.max(np.abs(q - x)) <= 0.5 / 63 + 1e-12

    def test_levels_one(self):
        assert np.all(dv.quantize_unit(np.array([0.3, 0.9]), 1) == 0.0)

    @settings(max_examples=50, deadline=None)
    @given(st.integers(2, 256), st.floats(0.0, 1.0))
    def test_idempotent(self, levels, x):
        a = np.array([x])
        q1 = dv.quantize_unit(a, levels)
        q2 = dv.quantize_unit(q1, levels)
        np.testing.assert_allclose(q1, q2)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 256))
    def test_values_on_grid(self, levels):
        x = np.random.default_rng(0).uniform(0, 1, 100)
        q = dv.quantize_unit(x, levels)
        steps = q * (levels - 1)
        np.testing.assert_allclose(steps, np.round(steps), atol=1e-9)


class TestDifferential:
    def test_reconstruct_error_bound(self):
        rng = np.random.default_rng(0)
        w = rng.normal(0, 0.3, (40, 30))
        dev = dv.DeviceParams(prog_sigma=0.0)
        pos, neg, scale = dv.weights_to_differential(w, None, dev, rng=None)
        w_hat = dv.reconstruct(pos, neg, scale)
        # quantization error <= scale * half-step
        assert np.max(np.abs(w_hat - w)) <= scale * (0.5 / (dev.levels - 1)) + 1e-9

    def test_inverted_convention(self):
        """Positive weights live in the 'neg' (inverting-input) matrix."""
        dev = dv.DeviceParams(prog_sigma=0.0)
        pos, neg, scale = dv.weights_to_differential(
            np.array([[0.5, -0.5]]), None, dev)
        assert neg[0, 0] > 0 and pos[0, 0] == 0
        assert pos[0, 1] > 0 and neg[0, 1] == 0

    def test_one_side_active(self):
        """A weight occupies exactly one side of the differential pair."""
        rng = np.random.default_rng(1)
        w = rng.normal(0, 1, (64, 64))
        pos, neg, _ = dv.weights_to_differential(w, None, dv.DeviceParams(prog_sigma=0.0))
        assert np.all((pos == 0) | (neg == 0))

    def test_scale_autodetect(self):
        w = np.array([[2.0, -4.0]])
        _, _, scale = dv.weights_to_differential(w, None, dv.DeviceParams(prog_sigma=0.0))
        assert scale == 4.0

    def test_zero_matrix(self):
        pos, neg, scale = dv.weights_to_differential(
            np.zeros((3, 3)), None, dv.DeviceParams(prog_sigma=0.0))
        assert np.all(pos == 0) and np.all(neg == 0) and scale == 1.0

    def test_prog_noise_preserves_zeros(self):
        """Zero weight = absent memristor = exactly zero current."""
        rng = np.random.default_rng(2)
        w = np.where(rng.uniform(size=(50, 50)) < 0.5, 0.0,
                     rng.normal(0, 1, (50, 50)))
        dev = dv.DeviceParams(prog_sigma=0.05)
        pos, neg, scale = dv.weights_to_differential(w, None, dev, rng=rng)
        w_hat = dv.reconstruct(pos, neg, scale)
        assert np.all(w_hat[w == 0.0] == 0.0)

    def test_prog_noise_magnitude(self):
        rng = np.random.default_rng(3)
        w = np.ones((200, 200)) * 0.5
        dev = dv.DeviceParams(prog_sigma=0.02)
        pos, neg, scale = dv.weights_to_differential(w, None, dev, rng=rng)
        rel = (dv.reconstruct(pos, neg, scale) - 0.5) / 0.5
        assert 0.01 < np.std(rel) < 0.04  # ~ prog_sigma after quantization

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10000), st.floats(0.05, 3.0))
    def test_reconstruct_hypothesis(self, seed, amp):
        rng = np.random.default_rng(seed)
        w = rng.normal(0, amp, (17, 23))
        dev = dv.DeviceParams(prog_sigma=0.0, levels=128)
        pos, neg, scale = dv.weights_to_differential(w, None, dev)
        w_hat = dv.reconstruct(pos, neg, scale)
        assert np.max(np.abs(w_hat - w)) <= scale / (dev.levels - 1)


class TestDeviceParams:
    def test_t_opamp(self):
        d = dv.DeviceParams(slew_rate=10e6, v_swing=5.0)
        assert abs(d.t_opamp - 0.5e-6) < 1e-12

    def test_to_dict_has_derived(self):
        d = dv.DEFAULT_DEVICE.to_dict()
        assert "g_on" in d and "t_opamp" in d
        assert d["g_on"] == 1.0 / dv.DEFAULT_DEVICE.r_on
