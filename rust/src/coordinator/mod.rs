//! L3 coordinator — the backend-agnostic inference service.
//!
//! Topology (executors are built ON the service thread, so even !Send
//! backends like the PJRT engine fit behind the queue):
//!
//! ```text
//!   clients ──mpsc──► batcher thread ──(assembled batches)──► executor
//!   (Client::classify)  plan_batch()     same thread owns the executor
//!        ◄──────────── per-request oneshot responses ◄────────┘
//! ```
//!
//! The batcher+executor run on a single dedicated thread: it drains the
//! queue, assembles a batch per [`batcher::plan_batch`], answers it through
//! one [`InferenceExecutor`] and responds to each request through its
//! response channel. The executor is the pluggable piece:
//!
//! * [`PipelineExecutor`] — the analog crossbar [`Pipeline`] with the
//!   §5.2 pipelined stage scheduler
//!   ([`Pipeline::forward_batch_pipelined`]); always available, so
//!   `memx serve --model analog` works in the default offline build.
//! * `EngineExecutor` — the PJRT engine (digital / analog-model HLO
//!   executables); needs the `runtime-xla` feature.
//!
//! This mirrors the paper's deployment model where one analog accelerator
//! serves a stream of sensor frames; [`metrics`] capture queue/end-to-end
//! latency, executor utilization and per-stage wall time for Fig 8-style
//! runs. The batching policy ([`batcher`]), [`accuracy`] and the bulk
//! paths ([`classify_dataset_analog`], and `classify_dataset` with
//! `runtime-xla`) are pure library calls.

pub mod batcher;
pub mod metrics;

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::backend::BackendChoice;
use crate::fault::FaultModel;
use crate::pipeline::{image_to_input, Fidelity, ModuleDrift, Pipeline, PipelineBuilder, StageStat};
use crate::telemetry;
use crate::util::argmax_rows;
use crate::util::bin::Dataset;
use metrics::Metrics;

#[cfg(feature = "runtime-xla")]
use crate::runtime::{Engine, Model};

// ---------------------------------------------------------------------------
// InferenceExecutor — the serving core's backend contract
// ---------------------------------------------------------------------------

/// A batched flat-image → logits backend the serving thread can drive.
///
/// The contract is deliberately small: the batcher assembles padded
/// batches of `img_elems()`-float HWC images at one of the
/// `available_batches()` sizes and expects `batch * num_classes()` logits
/// back. Executors are constructed on the service thread (see
/// [`Server::start_with`]), so implementations need not be `Send`.
pub trait InferenceExecutor {
    /// Human-readable backend summary for logs.
    fn describe(&self) -> String;

    /// Floats per input image (h*w*c, HWC row-major).
    fn img_elems(&self) -> usize;

    /// Logits per image.
    fn num_classes(&self) -> usize;

    /// Batch sizes this executor serves efficiently (the batcher plans
    /// over these; any positive, deduplicated set works).
    fn available_batches(&self) -> Vec<usize>;

    /// Prepare the hot path (compile executables, prime factor caches).
    /// Runs once on the service thread before the first request.
    fn warmup(&mut self) -> Result<()> {
        Ok(())
    }

    /// Answer one assembled batch: `images.len()` is a multiple of
    /// [`InferenceExecutor::img_elems`]; returns row-major logits
    /// (`batch * num_classes` floats).
    fn run_batch(&mut self, images: &[f32]) -> Result<Vec<f32>>;

    /// Drain per-stage wall-time accounting since the last call (pipeline
    /// schedulers report their unit timings here; default: none).
    fn take_stage_stats(&mut self) -> Vec<StageStat> {
        Vec::new()
    }

    /// Current per-module device-ageing telemetry (cumulative drift gain,
    /// absorbed fault steps, reprogram counts). Default: none — only
    /// fault-capable analog backends have device state to report.
    fn drift_telemetry(&self) -> Vec<ModuleDrift> {
        Vec::new()
    }

    /// Restore the backend to its as-programmed state (reprogram drifted
    /// crossbars, refresh caches). Called by the serving thread's drift
    /// watchdog between batches; returns how many devices were rewritten
    /// (0 = nothing to recalibrate, the default for stateless backends).
    fn recalibrate(&mut self) -> Result<u64> {
        Ok(0)
    }
}

/// Structured per-request failure the serving thread attaches when an
/// executor errors mid-stream: which batch failed, how big it was, and the
/// executor's own message. Clients can `downcast_ref::<ExecuteError>()` on
/// the returned `anyhow::Error` to tell executor faults apart from
/// submission/shape errors.
#[derive(Debug, Clone)]
pub struct ExecuteError {
    /// 1-based batch ordinal (matches the `batches` metric)
    pub batch: u64,
    /// real (unpadded) requests that failed with it
    pub batch_size: usize,
    pub detail: String,
}

impl std::fmt::Display for ExecuteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "execute failed on batch {} ({} requests): {}",
            self.batch, self.batch_size, self.detail
        )
    }
}

impl std::error::Error for ExecuteError {}

/// Positive, ascending, deduplicated batch-size plan set (the batcher's
/// contract), with `fallback` substituted when nothing survives.
fn sanitize_batch_sizes(sizes: &[usize], fallback: &[usize]) -> Vec<usize> {
    let mut out: Vec<usize> = sizes.iter().copied().filter(|&b| b > 0).collect();
    if out.is_empty() {
        out = fallback.to_vec();
    }
    out.sort_unstable();
    out.dedup();
    out
}

// ---------------------------------------------------------------------------
// PipelineExecutor — the analog crossbar backend (always available)
// ---------------------------------------------------------------------------

/// [`InferenceExecutor`] over the analog crossbar [`Pipeline`]: converts
/// each HWC image to channel-major planes and answers batches through the
/// pipelined stage scheduler
/// ([`Pipeline::forward_batch_pipelined`] — workers > 1 overlaps unit
/// groups across micro-batches; per-image results stay bit-identical to
/// the sequential path).
pub struct PipelineExecutor {
    pipeline: Pipeline,
    h: usize,
    w: usize,
    c: usize,
    batches: Vec<usize>,
    workers: usize,
    micro_batch: usize,
    faults: Option<FaultDrive>,
}

/// Simulated deployment-time aging attached to a [`PipelineExecutor`]:
/// every served batch advances the [`FaultModel`] clock and injects the
/// increment into the resident crossbars; [`InferenceExecutor::recalibrate`]
/// reprograms them back to the as-built weights (stuck cells persist).
struct FaultDrive {
    model: FaultModel,
    /// simulated hours of aging per served batch
    hours_per_batch: f64,
    /// read-disturb events charged per image in a batch
    reads_per_image: u64,
    /// programming noise applied on each reprogram cycle
    prog_sigma: f64,
    /// reprogram generation counter (seeds fresh write noise per cycle)
    generation: u64,
}

impl PipelineExecutor {
    /// Wrap a compiled pipeline. `batches` is the batcher's plan set
    /// (sanitized here); `workers` is the scheduler width (0 = auto).
    pub fn new(
        pipeline: Pipeline,
        (h, w, c): (usize, usize, usize),
        batches: &[usize],
        workers: usize,
    ) -> Result<PipelineExecutor> {
        if pipeline.in_dim() != h * w * c {
            bail!(
                "pipeline expects {} inputs, images are {h}x{w}x{c} = {}",
                pipeline.in_dim(),
                h * w * c
            );
        }
        let workers = if workers == 0 { crate::util::pool::default_workers() } else { workers };
        Ok(PipelineExecutor {
            pipeline,
            h,
            w,
            c,
            batches: sanitize_batch_sizes(batches, &[1, 8, 32]),
            workers,
            micro_batch: 0, // auto: sized from batch / unit-group count
            faults: None,
        })
    }

    /// Compile the trained artifacts into a pipeline-backed executor.
    ///
    /// The scheduler owns the thread budget: when unit groups overlap
    /// (`workers` > 1, or auto on a multi-core host) the modules are built
    /// with single-threaded internal solves, so SPICE segment workers do
    /// not multiply under the group threads into `workers²`
    /// oversubscription.
    pub fn from_artifacts(
        dir: &Path,
        fidelity: Fidelity,
        workers: usize,
        backend: BackendChoice,
    ) -> Result<PipelineExecutor> {
        let m = crate::nn::Manifest::load(dir)?;
        let ws = crate::nn::WeightStore::load(dir, &m)?;
        let sched = if workers == 0 { crate::util::pool::default_workers() } else { workers };
        // overlapping groups -> single-threaded module solves; a width-1
        // scheduler (sequential units) keeps the modules' own parallelism
        // (0 = builder auto)
        let pipeline = PipelineBuilder::new()
            .fidelity(fidelity)
            .backend(backend)
            .workers(if sched > 1 { 1 } else { 0 })
            .build(&m, &ws)?;
        Self::new(pipeline, (m.img, m.img, 3), &m.batch_sizes, sched)
    }

    /// Override the scheduler's micro-batch size (0 = auto).
    pub fn micro_batch(mut self, micro_batch: usize) -> Self {
        self.micro_batch = micro_batch;
        self
    }

    /// Attach a device-lifetime fault clock: each served batch ages the
    /// resident crossbars by `hours_per_batch` simulated hours and
    /// `reads_per_image` read-disturb events per image, and
    /// [`InferenceExecutor::recalibrate`] reprograms them (with
    /// `prog_sigma` fresh write noise) when the drift watchdog fires.
    pub fn with_faults(
        mut self,
        model: FaultModel,
        hours_per_batch: f64,
        reads_per_image: u64,
        prog_sigma: f64,
    ) -> Self {
        self.faults = Some(FaultDrive {
            model,
            hours_per_batch,
            reads_per_image,
            prog_sigma,
            generation: 0,
        });
        self
    }

    pub fn pipeline(&self) -> &Pipeline {
        &self.pipeline
    }
}

impl InferenceExecutor for PipelineExecutor {
    fn describe(&self) -> String {
        format!("analog pipeline [{}], {} workers", self.pipeline.describe(), self.workers)
    }

    fn img_elems(&self) -> usize {
        self.h * self.w * self.c
    }

    fn num_classes(&self) -> usize {
        self.pipeline.out_dim()
    }

    fn available_batches(&self) -> Vec<usize> {
        self.batches.clone()
    }

    fn warmup(&mut self) -> Result<()> {
        // one zero image primes every resident simulator's factorization so
        // the first served batch is already cached re-solves
        let zero = vec![vec![0.0; self.pipeline.in_dim()]];
        self.pipeline.forward_batch(&zero)?;
        self.pipeline.take_stage_stats(); // warmup time is not serving time
        Ok(())
    }

    fn run_batch(&mut self, images: &[f32]) -> Result<Vec<f32>> {
        let img = self.img_elems();
        if img == 0 || images.len() % img != 0 {
            bail!("batch of {} floats is not a multiple of {img}", images.len());
        }
        let batch: Vec<Vec<f64>> = images
            .chunks(img)
            .map(|chunk| image_to_input(chunk, self.h, self.w, self.c))
            .collect();
        if let Some(f) = self.faults.as_mut() {
            // age the crossbars in place before answering: value-only
            // conductance updates, the cached factorizations survive
            let step = f.model.advance(f.hours_per_batch, f.reads_per_image * batch.len() as u64);
            self.pipeline.inject_faults(&step);
        }
        let rows = self.pipeline.forward_batch_pipelined(&batch, self.workers, self.micro_batch)?;
        Ok(rows.iter().flat_map(|r| r.iter().map(|&v| v as f32)).collect())
    }

    fn take_stage_stats(&mut self) -> Vec<StageStat> {
        self.pipeline.take_stage_stats()
    }

    fn drift_telemetry(&self) -> Vec<ModuleDrift> {
        self.pipeline.drift_telemetry()
    }

    fn recalibrate(&mut self) -> Result<u64> {
        let Some(f) = self.faults.as_mut() else {
            return Ok(0);
        };
        f.generation += 1;
        let rewritten = self.pipeline.reprogram(f.prog_sigma, f.model.cfg().seed, f.generation);
        // drift restarts from the freshly written state
        f.model.reset_clock();
        Ok(rewritten as u64)
    }
}

// ---------------------------------------------------------------------------
// EngineExecutor — the PJRT backend (runtime-xla)
// ---------------------------------------------------------------------------

/// [`InferenceExecutor`] over the PJRT [`Engine`] (pre-compiled HLO batch
/// variants). Built on the service thread because PJRT handles are !Send.
#[cfg(feature = "runtime-xla")]
pub struct EngineExecutor {
    engine: Engine,
    model: Model,
}

#[cfg(feature = "runtime-xla")]
impl EngineExecutor {
    pub fn new(dir: &Path, model: Model) -> Result<EngineExecutor> {
        Ok(EngineExecutor { engine: Engine::new(dir)?, model })
    }
}

#[cfg(feature = "runtime-xla")]
impl InferenceExecutor for EngineExecutor {
    fn describe(&self) -> String {
        format!("pjrt {:?} on {}", self.model, self.engine.platform())
    }

    fn img_elems(&self) -> usize {
        let m = self.engine.manifest();
        m.img * m.img * 3
    }

    fn num_classes(&self) -> usize {
        self.engine.manifest().num_classes
    }

    fn available_batches(&self) -> Vec<usize> {
        self.engine.available_batches()
    }

    fn warmup(&mut self) -> Result<()> {
        // pre-compile every batch variant so serving never JITs
        for b in self.engine.available_batches() {
            self.engine.get(self.model, b)?;
        }
        Ok(())
    }

    fn run_batch(&mut self, images: &[f32]) -> Result<Vec<f32>> {
        let img = self.img_elems();
        if img == 0 || images.len() % img != 0 {
            bail!("batch of {} floats is not a multiple of {img}", images.len());
        }
        let exec = self.engine.get(self.model, images.len() / img)?;
        exec.run(images)
    }
}

// ---------------------------------------------------------------------------
// Server — queue + batcher thread over any executor
// ---------------------------------------------------------------------------

/// One classification result.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub label: usize,
    pub logits: Vec<f32>,
    /// end-to-end latency observed by the server
    pub latency: Duration,
}

struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    resp: Sender<Result<Prediction>>,
}

/// Cloneable submission handle.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    img_elems: usize,
    metrics: Arc<Metrics>,
}

impl Client {
    /// Blocking classify of one NHWC image.
    pub fn classify(&self, image: Vec<f32>) -> Result<Prediction> {
        if image.len() != self.img_elems {
            return Err(anyhow!("image has {} floats, expected {}", image.len(), self.img_elems));
        }
        self.metrics.requests.inc();
        let (tx, rx) = channel();
        self.tx
            .send(Request { image, enqueued: Instant::now(), resp: tx })
            .map_err(|_| anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

/// Which backend [`Server::start`] should build on its service thread.
#[derive(Debug, Clone)]
pub enum Backend {
    /// The offline analog crossbar pipeline ([`PipelineExecutor`]).
    Analog {
        fidelity: Fidelity,
        /// pipelined-scheduler width (0 = auto)
        workers: usize,
        /// dense-kernel backend for the SPICE engine ([`crate::backend`])
        backend: BackendChoice,
    },
    /// The PJRT engine ([`EngineExecutor`]).
    #[cfg(feature = "runtime-xla")]
    Pjrt { model: Model },
}

impl Backend {
    fn build(self, dir: &Path) -> Result<Box<dyn InferenceExecutor>> {
        match self {
            Backend::Analog { fidelity, workers, backend } => {
                Ok(Box::new(PipelineExecutor::from_artifacts(dir, fidelity, workers, backend)?))
            }
            #[cfg(feature = "runtime-xla")]
            Backend::Pjrt { model } => Ok(Box::new(EngineExecutor::new(dir, model)?)),
        }
    }
}

/// Online-recalibration policy: the serving thread tracks the per-batch
/// mean top1−top2 logit margin as an EWMA; once a baseline is established
/// over the first `warm_batches`, an EWMA below `margin_frac * baseline`
/// flags drift and triggers [`InferenceExecutor::recalibrate`] between
/// batches, rate-limited by `cooldown_batches`.
#[derive(Debug, Clone, Copy)]
pub struct RecalPolicy {
    pub enabled: bool,
    /// EWMA smoothing factor in (0, 1]; higher reacts faster
    pub ewma_alpha: f64,
    /// batches to average before the margin baseline is frozen
    pub warm_batches: u64,
    /// drift threshold as a fraction of the baseline margin
    pub margin_frac: f64,
    /// minimum batches between recalibration attempts
    pub cooldown_batches: u64,
}

impl Default for RecalPolicy {
    fn default() -> Self {
        RecalPolicy {
            enabled: true,
            ewma_alpha: 0.3,
            warm_batches: 3,
            margin_frac: 0.6,
            cooldown_batches: 5,
        }
    }
}

impl RecalPolicy {
    /// No drift watching — the seed behavior of [`Server::start_with`].
    pub fn disabled() -> Self {
        RecalPolicy { enabled: false, ..Default::default() }
    }
}

/// The serving thread's drift-watchdog state over [`RecalPolicy`].
struct DriftWatch {
    policy: RecalPolicy,
    ewma: Option<f64>,
    baseline: Option<f64>,
    batches_seen: u64,
    cooldown_until: u64,
}

impl DriftWatch {
    fn new(policy: RecalPolicy) -> DriftWatch {
        DriftWatch { policy, ewma: None, baseline: None, batches_seen: 0, cooldown_until: 0 }
    }

    /// Feed one batch's mean logit margin; true = drift flagged, the
    /// caller should recalibrate now.
    fn observe(&mut self, margin: f64) -> bool {
        if !self.policy.enabled || !margin.is_finite() {
            return false;
        }
        self.batches_seen += 1;
        let a = self.policy.ewma_alpha.clamp(1e-6, 1.0);
        let ewma = match self.ewma {
            Some(prev) => a * margin + (1.0 - a) * prev,
            None => margin,
        };
        self.ewma = Some(ewma);
        if self.baseline.is_none() {
            if self.batches_seen >= self.policy.warm_batches.max(1) {
                self.baseline = Some(ewma);
            }
            return false;
        }
        let baseline = self.baseline.expect("baseline frozen above");
        if ewma < self.policy.margin_frac * baseline && self.batches_seen >= self.cooldown_until {
            self.cooldown_until = self.batches_seen + self.policy.cooldown_batches.max(1);
            return true;
        }
        false
    }

    /// A recalibration landed: re-learn the baseline from the fresh state.
    fn reset(&mut self) {
        self.ewma = None;
        self.baseline = None;
        self.batches_seen = 0;
        self.cooldown_until = 0;
    }
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub backend: Backend,
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            backend: Backend::Analog {
                fidelity: Fidelity::Behavioural,
                workers: 0,
                backend: BackendChoice::Auto,
            },
            max_wait: batcher::default_max_wait(),
        }
    }
}

pub struct Server {
    client: Client,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    pub warmup: Duration,
}

impl Server {
    /// Start the service over the trained artifacts: the configured
    /// backend is built and warmed on the service thread (PJRT handles are
    /// !Send; pipeline warmup primes the factor caches), then serves.
    pub fn start(artifacts_dir: &Path, cfg: ServerConfig) -> Result<Server> {
        let dir = artifacts_dir.to_path_buf();
        let backend = cfg.backend;
        Self::start_with(cfg.max_wait, move || backend.build(&dir))
    }

    /// Start the service over an explicit executor factory. The factory
    /// runs on the service thread, so it may capture paths/configs (it
    /// must be `Send`) while producing a !Send executor. This is also the
    /// seam tests use to serve stub or synthetic executors without
    /// artifacts.
    pub fn start_with<F>(max_wait: Duration, factory: F) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn InferenceExecutor>> + Send + 'static,
    {
        Self::start_with_policy(max_wait, RecalPolicy::disabled(), factory)
    }

    /// [`Server::start_with`] plus an online drift watchdog: the serving
    /// thread monitors the per-batch logit-margin EWMA under `policy` and
    /// calls [`InferenceExecutor::recalibrate`] between batches when it
    /// degrades past the threshold.
    pub fn start_with_policy<F>(
        max_wait: Duration,
        policy: RecalPolicy,
        factory: F,
    ) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn InferenceExecutor>> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let m2 = metrics.clone();
        let stop2 = stop.clone();

        let (ready_tx, ready_rx) = channel::<Result<(Duration, usize)>>();
        let join = std::thread::Builder::new()
            .name("memx-serve".into())
            .spawn(move || serve_thread(factory, max_wait, policy, rx, m2, stop2, ready_tx))
            .expect("spawn server thread");
        let (warmup, img_elems) = ready_rx
            .recv()
            .map_err(|_| anyhow!("server thread died during warmup"))??;
        Ok(Server {
            client: Client { tx, img_elems, metrics },
            stop,
            join: Some(join),
            warmup,
        })
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.client.metrics.clone()
    }

    /// Expose this server's metrics registry over HTTP (Prometheus text at
    /// `/metrics`, JSON at `/metrics.json`) — the `--metrics-addr` seam.
    pub fn serve_metrics(&self, addr: &str) -> Result<telemetry::http::MetricsServer> {
        telemetry::http::MetricsServer::serve(addr, self.client.metrics.registry())
    }

    /// The one stop/join sequence (shared by [`Server::shutdown`] and
    /// `Drop`): raise the stop flag and wait for the service thread.
    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            j.join().ok();
        }
    }

    /// Graceful shutdown (also performed on drop).
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn serve_thread<F>(
    factory: F,
    max_wait: Duration,
    policy: RecalPolicy,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    ready: Sender<Result<(Duration, usize)>>,
) where
    F: FnOnce() -> Result<Box<dyn InferenceExecutor>>,
{
    // build + warm the executor
    let t0 = Instant::now();
    let mut exec = match factory().and_then(|mut e| {
        e.warmup()?;
        Ok(e)
    }) {
        Ok(e) => e,
        Err(e) => {
            ready.send(Err(e)).ok();
            return;
        }
    };
    let sizes = sanitize_batch_sizes(&exec.available_batches(), &[1]);
    let img_elems = exec.img_elems();
    let classes = exec.num_classes();
    if img_elems == 0 || classes == 0 {
        ready
            .send(Err(anyhow!(
                "executor '{}' reports a degenerate shape ({img_elems} image floats, {classes} classes)",
                exec.describe()
            )))
            .ok();
        return;
    }
    ready.send(Ok((t0.elapsed(), img_elems))).ok();

    let mut queue: Vec<Request> = Vec::new();
    // reusable input buffer — hot path stays allocation-free after warmup
    let largest = *sizes.last().expect("non-empty batch sizes");
    let mut input = vec![0f32; largest * img_elems];
    let mut watch = DriftWatch::new(policy);
    // chrome-trace track for request lifetimes (allocated on first use:
    // they start on client threads and close here, so they get their own
    // track to keep this thread's batch/forward spans strictly nested)
    let mut req_track: Option<u64> = None;

    while !stop.load(Ordering::Relaxed) {
        // drain everything currently queued
        while let Ok(r) = rx.try_recv() {
            queue.push(r);
        }
        metrics.queue_depth.set(queue.len() as f64);
        let waited_out = queue
            .first()
            .map(|r| r.enqueued.elapsed() >= max_wait)
            .unwrap_or(false);
        let Some(plan) = batcher::plan_batch(&sizes, queue.len(), waited_out) else {
            // nothing to do: block briefly for the next request
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(r) => queue.push(r),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    if queue.is_empty() {
                        break;
                    }
                }
            }
            continue;
        };

        let batch: Vec<Request> = queue.drain(..plan.real).collect();
        let t_deq = Instant::now();
        let enq: Vec<Instant> = if telemetry::enabled() {
            batch.iter().map(|r| r.enqueued).collect()
        } else {
            Vec::new()
        };
        let buf = &mut input[..plan.size * img_elems];
        for (i, r) in batch.iter().enumerate() {
            buf[i * img_elems..(i + 1) * img_elems].copy_from_slice(&r.image);
            metrics.record_queue(t_deq.saturating_duration_since(r.enqueued));
        }
        // pad by replicating the last real image
        for i in plan.real..plan.size {
            let (head, tail) = buf.split_at_mut(i * img_elems);
            tail[..img_elems].copy_from_slice(&head[(plan.real - 1) * img_elems..plan.real * img_elems]);
        }
        metrics.batches.inc();
        metrics.padded_slots.add((plan.size - plan.real) as u64);

        let t_run = Instant::now();
        let run = exec.run_batch(buf);
        let t_done = Instant::now();
        metrics.record_exec(t_done.saturating_duration_since(t_run));
        telemetry::span_closed_args(
            "forward",
            "forward",
            t_run,
            t_done,
            &[("batch", plan.size as f64), ("real", plan.real as f64)],
        );
        metrics.record_stage_stats(&exec.take_stage_stats());
        metrics.record_drift(exec.drift_telemetry());
        let run = run.and_then(|logits| {
            if logits.len() != plan.size * classes {
                bail!("executor returned {} logits for a batch of {}", logits.len(), plan.size);
            }
            Ok(logits)
        });
        match run {
            Ok(logits) => {
                let labels = argmax_rows(&logits, classes);
                for (i, r) in batch.into_iter().enumerate() {
                    let latency = r.enqueued.elapsed();
                    metrics.record_latency(latency);
                    metrics.completed.inc();
                    let pred = Prediction {
                        label: labels[i],
                        logits: logits[i * classes..(i + 1) * classes].to_vec(),
                        latency,
                    };
                    r.resp.send(Ok(pred)).ok();
                }
                // drift watchdog: a collapsing top1-top2 margin over the
                // real (unpadded) rows is the online symptom of conductance
                // decay — recalibrate between batches, never mid-batch
                if watch.policy.enabled && classes >= 2 {
                    let margin = mean_margin(&logits, classes, plan.real);
                    if watch.observe(margin) {
                        metrics.drift_detections.inc();
                        telemetry::event(telemetry::Event::DriftDetected { margin });
                        let _rsp = telemetry::span("recalibrate", "serve");
                        match exec.recalibrate() {
                            Ok(n) if n > 0 => {
                                metrics.recalibrations.inc();
                                telemetry::event(telemetry::Event::Recalibrated { devices: n });
                                watch.reset();
                            }
                            // nothing reprogrammable, or the attempt failed:
                            // the cooldown stops the watchdog from spinning
                            _ => {}
                        }
                    }
                }
            }
            Err(e) => {
                let batch_no = metrics.batches.get();
                telemetry::event(telemetry::Event::ExecutorError { batch: batch_no });
                for r in batch {
                    metrics.errors.inc();
                    r.resp
                        .send(Err(anyhow::Error::new(ExecuteError {
                            batch: batch_no,
                            batch_size: plan.real,
                            detail: e.to_string(),
                        })))
                        .ok();
                }
            }
        }
        if telemetry::enabled() {
            // close the batch interval (this thread's track: it strictly
            // contains the forward span) and each request's lifetime (the
            // "requests" virtual track: lifetimes start on client threads
            // and can straddle batch boundaries)
            let t_end = Instant::now();
            telemetry::span_closed_args(
                "batch",
                "serve",
                t_deq,
                t_end,
                &[("size", plan.size as f64), ("real", plan.real as f64)],
            );
            let track = *req_track.get_or_insert_with(|| telemetry::virtual_track("requests"));
            for e in &enq {
                let queue_us =
                    t_deq.saturating_duration_since(*e).as_nanos() as f64 / 1e3;
                telemetry::span_closed_on(
                    track,
                    "request",
                    "serve",
                    *e,
                    t_end,
                    &[("queue_us", queue_us)],
                );
            }
        }
    }
    telemetry::flush_thread();
}

/// Mean top1−top2 logit margin over the first `rows` rows of a row-major
/// logits buffer — the drift watchdog's confidence signal.
fn mean_margin(logits: &[f32], classes: usize, rows: usize) -> f64 {
    if rows == 0 || classes < 2 {
        return f64::INFINITY;
    }
    let mut sum = 0.0;
    for i in 0..rows {
        let row = &logits[i * classes..(i + 1) * classes];
        let (mut top, mut second) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
        for &v in row {
            if v > top {
                second = top;
                top = v;
            } else if v > second {
                second = v;
            }
        }
        sum += (top - second) as f64;
    }
    sum / rows as f64
}

// ---------------------------------------------------------------------------
// Bulk evaluation paths
// ---------------------------------------------------------------------------

#[cfg(feature = "runtime-xla")]
/// Synchronous bulk evaluation (no batcher thread): classify `n` images from
/// a dataset with greedy largest-batch packing. Returns (labels, wall time).
pub fn classify_dataset(
    engine: &Engine,
    model: Model,
    ds: &crate::util::bin::Dataset,
    n: usize,
) -> Result<(Vec<usize>, Duration)> {
    let n = n.min(ds.n);
    let img = ds.image_len();
    let mut labels = Vec::with_capacity(n);
    let t0 = Instant::now();
    let mut i = 0;
    while i < n {
        let b = engine.pick_batch(n - i);
        let exec = engine.get(model, b)?;
        let take = b.min(n - i);
        let mut buf = vec![0f32; b * img];
        for j in 0..take {
            buf[j * img..(j + 1) * img].copy_from_slice(ds.image(i + j));
        }
        for j in take..b {
            let src = ds.image(i + take - 1).to_vec();
            buf[j * img..(j + 1) * img].copy_from_slice(&src);
        }
        let logits = exec.run(&buf)?;
        labels.extend(argmax_rows(&logits, exec.num_classes).into_iter().take(take));
        i += take;
    }
    Ok((labels, t0.elapsed()))
}

/// Synchronous bulk evaluation through the analog crossbar [`Pipeline`] —
/// the offline counterpart of the PJRT `classify_dataset`: images are
/// packed with the same [`batcher::plan_batch`] policy the server uses,
/// and each batch is answered by one [`Pipeline::forward_batch`] call — so
/// at [`Fidelity::Spice`](crate::pipeline::Fidelity::Spice) every crossbar
/// read amortizes the whole batch over a single multi-RHS
/// [`CrossbarSim::solve_batch`](crate::netlist::CrossbarSim::solve_batch)
/// substitution pass per segment. Returns (labels, wall time).
pub fn classify_dataset_analog(
    pipeline: &mut Pipeline,
    ds: &Dataset,
    n: usize,
    batch_sizes: &[usize],
) -> Result<(Vec<usize>, Duration)> {
    let n = n.min(ds.n);
    let sizes = sanitize_batch_sizes(batch_sizes, &[16]);
    let mut labels = Vec::with_capacity(n);
    let t0 = Instant::now();
    let mut i = 0;
    while i < n {
        // waited_out: bulk evaluation never holds requests back
        let Some(plan) = batcher::plan_batch(&sizes, n - i, true) else {
            break;
        };
        let take = plan.real.min(n - i);
        let batch: Vec<Vec<f64>> = (0..take)
            .map(|j| image_to_input(ds.image(i + j), ds.h, ds.w, ds.c))
            .collect();
        labels.extend(pipeline.classify_batch(&batch)?);
        i += take;
    }
    Ok((labels, t0.elapsed()))
}

/// Accuracy of predicted labels vs dataset ground truth.
pub fn accuracy(labels: &[usize], truth: &[u8]) -> f64 {
    if labels.is_empty() {
        return 0.0;
    }
    let correct = labels.iter().zip(truth).filter(|(p, t)| **p == **t as usize).count();
    correct as f64 / labels.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{argmax, default_device, PipelineBuilder};

    #[test]
    fn accuracy_counts() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    fn tiny_dataset(n: usize, h: usize, w: usize, c: usize) -> Dataset {
        Dataset {
            n,
            h,
            w,
            c,
            data: (0..n * h * w * c).map(|i| (i % 7) as f32 / 7.0).collect(),
            labels: vec![0; n],
        }
    }

    #[test]
    fn analog_path_batches_and_classifies() {
        let (h, w, c) = (2, 2, 3);
        let n = 5;
        let ds = tiny_dataset(n, h, w, c);
        let dev = default_device();
        let mut p = PipelineBuilder::new()
            .fidelity(Fidelity::Ideal)
            .build_fc_stack(&[h * w * c, 4], &dev, 3)
            .unwrap();
        let (labels, _) = classify_dataset_analog(&mut p, &ds, n, &[2]).unwrap();
        assert_eq!(labels.len(), n);
        assert!(labels.iter().all(|&l| l < 4));
        // the batched serving path must agree with per-image forwards
        for (i, &label) in labels.iter().enumerate() {
            let x = image_to_input(ds.image(i), h, w, c);
            assert_eq!(label, argmax(&p.forward(&x).unwrap()), "image {i}");
        }
    }

    #[test]
    fn server_serves_pipeline_executor_offline() {
        let (h, w, c) = (2, 2, 3);
        let n = 9;
        let ds = tiny_dataset(n, h, w, c);
        let server = Server::start_with(Duration::from_millis(1), move || {
            let dev = default_device();
            let pipeline = PipelineBuilder::new()
                .fidelity(Fidelity::Behavioural)
                .build_fc_stack(&[h * w * c, 6, 4], &dev, 11)?;
            // explicit micro-batch of 1: maximum overlap between the two
            // unit groups for every served batch
            Ok(Box::new(
                PipelineExecutor::new(pipeline, (h, w, c), &[1, 4], 2)?.micro_batch(1),
            ) as Box<dyn InferenceExecutor>)
        })
        .unwrap();
        let client = server.client();
        // served labels must equal the direct pipeline forward
        let mut reference = PipelineBuilder::new()
            .fidelity(Fidelity::Behavioural)
            .build_fc_stack(&[h * w * c, 6, 4], &default_device(), 11)
            .unwrap();
        for i in 0..n {
            let p = client.classify(ds.image(i).to_vec()).unwrap();
            assert_eq!(p.logits.len(), 4);
            let x = image_to_input(ds.image(i), h, w, c);
            // the executor rounds logits through f32 — mirror it exactly
            let want: Vec<f64> =
                reference.forward(&x).unwrap().iter().map(|&v| v as f32 as f64).collect();
            assert_eq!(p.label, argmax(&want), "image {i}");
        }
        // malformed images are rejected at the client
        assert!(client.classify(vec![0.0; 5]).is_err());
        let snap = server.metrics().snapshot();
        assert_eq!(snap.completed, n as u64);
        assert_eq!(snap.errors, 0);
        assert!(snap.exec_busy > Duration::ZERO);
        assert!(!snap.stages.is_empty(), "pipeline executor reports stage times");
        server.shutdown();
    }
}
