//! Metrics registry: counters, gauges and log-scale histograms with
//! Prometheus text exposition and JSON rendering.
//!
//! This generalizes the histogram that used to live privately in
//! `coordinator/metrics.rs`: the serving [`Metrics`]
//! (`crate::coordinator::metrics::Metrics`) is now a *view* over a
//! [`Registry`] — every counter/histogram it records lands here and is
//! exported over HTTP by [`crate::telemetry::http::MetricsServer`].
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are `Arc`-backed and
//! lock-free on the record path (relaxed atomics); the registry mutex is
//! only taken at registration and render time. Registries are instantiable
//! (not a process singleton) so parallel test servers never collide on
//! series names; process-wide series (solver fallbacks, kernel wall time)
//! are attached as [`Registry::register_fn`] callbacks read at render time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::json::Json;

/// Fixed log2-scale histogram bucket count: 1 µs up to ~67 s.
pub const BUCKETS: usize = 27;

/// Poison-tolerant lock (a panicking recorder must not take exports down).
fn locked<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

// ---------------------------------------------------------------------------
// Handles
// ---------------------------------------------------------------------------

/// Monotonic counter handle (cheap to clone; all clones share the cell).
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (an f64 stored as bits).
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Lock-free log2-scale duration histogram: bucket `b` holds samples with
/// `floor(log2(µs)) == b`, i.e. the interval `[2^b, 2^(b+1))` µs. Reads
/// ([`Histogram::snapshot`]) are collected bucket-by-bucket with relaxed
/// loads — statistically consistent, never blocking a recorder.
#[derive(Clone, Default)]
pub struct Histogram(Arc<HistCore>);

pub struct HistCore {
    counts: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    max_us: AtomicU64,
    n: AtomicU64,
}

impl Default for HistCore {
    fn default() -> HistCore {
        HistCore {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
            n: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128).max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        let c = &self.0;
        c.counts[b].fetch_add(1, Ordering::Relaxed);
        c.sum_us.fetch_add(us, Ordering::Relaxed);
        c.max_us.fetch_max(us, Ordering::Relaxed);
        c.n.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let c = &self.0;
        HistSnapshot {
            counts: std::array::from_fn(|b| c.counts[b].load(Ordering::Relaxed)),
            sum_us: c.sum_us.load(Ordering::Relaxed),
            max_us: c.max_us.load(Ordering::Relaxed),
            n: c.n.load(Ordering::Relaxed),
        }
    }
}

/// A consistent point-in-time read of a [`Histogram`] with the quantile
/// arithmetic. Quantiles are quantized to the log2 bucket edges:
/// [`HistSnapshot::quantile`] reports the conservative *upper* edge, and
/// [`HistSnapshot::quantile_bounds`] exposes the full bucket `[lo, hi)` so
/// benches can report the error bar instead of over-claiming a point p99.
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    pub counts: [u64; BUCKETS],
    pub sum_us: u64,
    pub max_us: u64,
    pub n: u64,
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot { counts: [0; BUCKETS], sum_us: 0, max_us: 0, n: 0 }
    }
}

impl HistSnapshot {
    /// Bucket index holding the q-quantile sample, if any were recorded.
    fn quantile_bucket(&self, q: f64) -> Option<usize> {
        if self.n == 0 {
            return None;
        }
        let target = ((self.n as f64 * q).ceil() as u64).clamp(1, self.n);
        let mut acc = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Some(b);
            }
        }
        Some(BUCKETS - 1)
    }

    /// Conservative q-quantile: the *upper* edge of the bucket holding the
    /// target sample (the true quantile is ≤ this, but may be up to one
    /// bucket width lower — see [`HistSnapshot::quantile_bounds`]).
    pub fn quantile(&self, q: f64) -> Duration {
        match self.quantile_bucket(q) {
            None => Duration::ZERO,
            Some(b) if b == BUCKETS - 1 => Duration::from_micros(self.max_us),
            Some(b) => Duration::from_micros(1u64 << (b + 1)),
        }
    }

    /// The `[lower, upper]` bucket edges bracketing the q-quantile — the
    /// quantization error bar of [`HistSnapshot::quantile`]. The true
    /// quantile lies inside this interval; its width doubles every bucket,
    /// so a 10 ms p99 carries a ~5 ms error bar.
    pub fn quantile_bounds(&self, q: f64) -> (Duration, Duration) {
        match self.quantile_bucket(q) {
            None => (Duration::ZERO, Duration::ZERO),
            Some(b) => (
                Duration::from_micros(1u64 << b),
                if b == BUCKETS - 1 {
                    Duration::from_micros(self.max_us)
                } else {
                    Duration::from_micros(1u64 << (b + 1))
                },
            ),
        }
    }

    pub fn mean(&self) -> Duration {
        if self.n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.n)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

enum Kind {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
    /// Read-at-render-time view over state owned elsewhere (process-wide
    /// atomics like solver fallbacks); rendered as a gauge.
    Func(Box<dyn Fn() -> f64 + Send + Sync>),
}

struct Entry {
    name: String,
    help: String,
    kind: Kind,
}

/// A named collection of metrics. Instantiable — each `Server` owns one —
/// and rendered as Prometheus text exposition (`render_prometheus`) or
/// JSON (`render_json`).
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    /// Register (or fetch the existing handle of) a counter.
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        let mut es = locked(&self.entries);
        if let Some(e) = es.iter().find(|e| e.name == name) {
            if let Kind::Counter(c) = &e.kind {
                return c.clone();
            }
        }
        let c = Counter::default();
        es.push(Entry { name: name.into(), help: help.into(), kind: Kind::Counter(c.clone()) });
        c
    }

    /// Register (or fetch the existing handle of) a gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        let mut es = locked(&self.entries);
        if let Some(e) = es.iter().find(|e| e.name == name) {
            if let Kind::Gauge(g) = &e.kind {
                return g.clone();
            }
        }
        let g = Gauge::default();
        es.push(Entry { name: name.into(), help: help.into(), kind: Kind::Gauge(g.clone()) });
        g
    }

    /// Register (or fetch the existing handle of) a log2-scale histogram.
    /// The series is exported with bucket edges in **seconds**.
    pub fn histogram(&self, name: &str, help: &str) -> Histogram {
        let mut es = locked(&self.entries);
        if let Some(e) = es.iter().find(|e| e.name == name) {
            if let Kind::Histogram(h) = &e.kind {
                return h.clone();
            }
        }
        let h = Histogram::default();
        es.push(Entry { name: name.into(), help: help.into(), kind: Kind::Histogram(h.clone()) });
        h
    }

    /// Register a render-time callback series (view over external state).
    pub fn register_fn(
        &self,
        name: &str,
        help: &str,
        f: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        let mut es = locked(&self.entries);
        if es.iter().any(|e| e.name == name) {
            return;
        }
        es.push(Entry { name: name.into(), help: help.into(), kind: Kind::Func(Box::new(f)) });
    }

    /// Prometheus text exposition format 0.0.4. Histogram `le` edges are in
    /// seconds; bucket counts are cumulative; `_sum` is in seconds.
    pub fn render_prometheus(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        for e in locked(&self.entries).iter() {
            let _ = writeln!(out, "# HELP {} {}", e.name, e.help);
            match &e.kind {
                Kind::Counter(c) => {
                    let _ = writeln!(out, "# TYPE {} counter", e.name);
                    let _ = writeln!(out, "{} {}", e.name, c.get());
                }
                Kind::Gauge(g) => {
                    let _ = writeln!(out, "# TYPE {} gauge", e.name);
                    let _ = writeln!(out, "{} {}", e.name, g.get());
                }
                Kind::Func(f) => {
                    let _ = writeln!(out, "# TYPE {} gauge", e.name);
                    let _ = writeln!(out, "{} {}", e.name, f());
                }
                Kind::Histogram(h) => {
                    let s = h.snapshot();
                    let _ = writeln!(out, "# TYPE {} histogram", e.name);
                    let mut acc = 0u64;
                    for (b, &c) in s.counts.iter().enumerate() {
                        acc += c;
                        let le = (1u64 << (b + 1)) as f64 / 1e6;
                        let _ = writeln!(out, "{}_bucket{{le=\"{le}\"}} {acc}", e.name);
                    }
                    let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {}", e.name, s.n);
                    let _ = writeln!(out, "{}_sum {}", e.name, s.sum_us as f64 / 1e6);
                    let _ = writeln!(out, "{}_count {}", e.name, s.n);
                }
            }
        }
        out
    }

    /// JSON rendering: scalar series map to numbers, histograms to an
    /// object with count/sum/quantiles (upper bucket edges, seconds).
    pub fn render_json(&self) -> String {
        let mut pairs: Vec<(String, Json)> = Vec::new();
        for e in locked(&self.entries).iter() {
            let v = match &e.kind {
                Kind::Counter(c) => Json::num(c.get() as f64),
                Kind::Gauge(g) => Json::num(g.get()),
                Kind::Func(f) => Json::num(f()),
                Kind::Histogram(h) => {
                    let s = h.snapshot();
                    Json::obj(vec![
                        ("count", Json::num(s.n as f64)),
                        ("sum_seconds", Json::num(s.sum_us as f64 / 1e6)),
                        ("mean_seconds", Json::num(s.mean().as_secs_f64())),
                        ("p50_seconds", Json::num(s.quantile(0.50).as_secs_f64())),
                        ("p95_seconds", Json::num(s.quantile(0.95).as_secs_f64())),
                        ("p99_seconds", Json::num(s.quantile(0.99).as_secs_f64())),
                        ("p999_seconds", Json::num(s.quantile(0.999).as_secs_f64())),
                        ("max_seconds", Json::num(s.max().as_secs_f64())),
                    ])
                }
            };
            pairs.push((e.name.clone(), v));
        }
        Json::Obj(pairs.into_iter().collect()).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip() {
        let r = Registry::default();
        let c = r.counter("memx_test_total", "test counter");
        c.inc();
        c.add(2);
        assert_eq!(c.get(), 3);
        // re-registration returns the same cell
        let c2 = r.counter("memx_test_total", "test counter");
        c2.inc();
        assert_eq!(c.get(), 4);
        let g = r.gauge("memx_test_gauge", "test gauge");
        g.set(2.5);
        assert!((g.get() - 2.5).abs() < 1e-12);
        let text = r.render_prometheus();
        assert!(text.contains("memx_test_total 4"), "{text}");
        assert!(text.contains("# TYPE memx_test_total counter"), "{text}");
        assert!(text.contains("memx_test_gauge 2.5"), "{text}");
    }

    #[test]
    fn histogram_quantile_bounds_bracket_quantile() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 10));
        }
        let s = h.snapshot();
        assert_eq!(s.n, 1000);
        let p99 = s.quantile(0.99);
        let (lo, hi) = s.quantile_bounds(0.99);
        assert!(lo < hi);
        assert_eq!(p99, hi, "point quantile is the conservative upper edge");
        // the true p99 (9900 µs) lies inside the reported bucket
        let truth = Duration::from_micros(9900);
        assert!(lo <= truth && truth <= hi, "{lo:?} <= {truth:?} <= {hi:?}");
        // bucket width is one octave
        assert_eq!(hi.as_micros(), lo.as_micros() * 2);
        assert!(s.quantile(0.50) <= s.quantile(0.95));
        assert!(s.quantile(0.95) <= s.quantile(0.999));
        // the top bucket reports the observed max, not a 2x edge
        assert!(s.quantile(1.0) <= Duration::from_micros(s.max_us) * 2);
    }

    #[test]
    fn prometheus_histogram_is_cumulative() {
        let r = Registry::default();
        let h = r.histogram("memx_lat_seconds", "latency");
        h.record(Duration::from_micros(3)); // bucket 1 [2,4)
        h.record(Duration::from_micros(100)); // bucket 6 [64,128)
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE memx_lat_seconds histogram"), "{text}");
        assert!(text.contains("memx_lat_seconds_bucket{le=\"0.000004\"} 1"), "{text}");
        assert!(text.contains("memx_lat_seconds_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("memx_lat_seconds_count 2"), "{text}");
        let json = r.render_json();
        assert!(json.contains("\"memx_lat_seconds\""), "{json}");
        assert!(json.contains("\"count\":2"), "{json}");
    }

    #[test]
    fn fn_series_reads_live_state() {
        let r = Registry::default();
        let c = Counter::default();
        let view = c.clone();
        r.register_fn("memx_view_total", "external view", move || view.get() as f64);
        c.add(7);
        assert!(r.render_prometheus().contains("memx_view_total 7"));
    }
}
