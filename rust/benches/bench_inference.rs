//! E5/E6/E10 / Fig 8 — inference latency + energy: the pipeline end-to-end
//! batched-forward workload (batch 1 vs 16 vs 64 through
//! `Pipeline::forward_batch`, appended to BENCH_pipeline.json), the
//! analytical crossbar models (Eqs 17/18) against the paper's GPU/CPU
//! baselines, and — with the `runtime-xla` feature — the *measured* digital
//! PJRT latency on this host per batch size.
//!
//!   cargo bench --bench bench_inference

use memx::pipeline::{default_device, Fidelity, PipelineBuilder};
use memx::util::bench::{append_json_report, black_box, Bench};
use memx::util::prng::Rng;

/// End-to-end batched pipeline forward: how much a batch amortizes the
/// per-image cost (at SPICE fidelity, batches share one multi-RHS
/// substitution pass per crossbar segment).
fn pipeline_workload() -> anyhow::Result<()> {
    let dev = default_device();
    let dims = [96usize, 96, 48, 10];
    let mut rng = Rng::new(11);
    let inputs: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..dims[0]).map(|_| rng.range_f64(-0.5, 0.5)).collect())
        .collect();

    println!("== pipeline end-to-end batched forward (fc {dims:?}) ==");
    let mut b = Bench::quick();
    let mut derived: Vec<(String, f64)> = Vec::new();
    let mut spice_per_image: Vec<(usize, f64)> = Vec::new();
    for fidelity in [Fidelity::Behavioural, Fidelity::Spice] {
        let mut pipe = PipelineBuilder::new()
            .fidelity(fidelity)
            .segment(32)
            .build_fc_stack(&dims, &dev, 3)?;
        for &batch in &[1usize, 16, 64] {
            let chunk = &inputs[..batch];
            let stats = b.run(&format!("pipeline {fidelity} b{batch}"), || {
                black_box(pipe.forward_batch(chunk).expect("forward_batch"));
            });
            let per_image = stats.mean_secs() / batch as f64;
            println!("    -> per-image {:.1} µs", per_image * 1e6);
            if fidelity == Fidelity::Spice {
                spice_per_image.push((batch, per_image));
            }
        }
    }
    if let (Some(&(_, t1)), Some(&(_, t64))) =
        (spice_per_image.first(), spice_per_image.last())
    {
        derived.push(("spice_b64_vs_b1_per_image_speedup".into(), t1 / t64.max(1e-12)));
    }
    b.table("pipeline batched forward");
    match append_json_report("BENCH_pipeline.json", "bench_inference_pipeline", &b.rows, &derived)
    {
        Ok(()) => println!("(appended to BENCH_pipeline.json)"),
        Err(e) => eprintln!("warning: could not append BENCH_pipeline.json: {e}"),
    }
    Ok(())
}

/// Eq 17/18 analytical figures over the trained manifest (skipped without
/// artifacts).
fn analytical_workload() -> anyhow::Result<()> {
    use memx::mapper::{self, MapMode};
    use memx::nn::{Manifest, WeightStore};
    use memx::power;

    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_inference: artifacts missing — skipping the analytical Fig 8 section");
        return Ok(());
    }
    let m = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &m)?;
    let net = mapper::map_network(&m, &ws, MapMode::Inverted)?;
    let t_seq = power::latency(&net, &m.device);
    let t_pipe = power::latency_pipelined(&net, &m.device);
    let e = power::energy(&net, &m.device, &t_seq);
    println!("\n== Fig 8(a,b): analytical memristor inference ==");
    println!(
        "sequential: {:.3} µs (N_m={} stages) | pipelined: {:.3} µs | energy {:.2} µJ",
        t_seq.total * 1e6,
        t_seq.n_m,
        t_pipe.total * 1e6,
        e.total * 1e6
    );
    println!(
        "vs paper baselines: GPU {:.1}x/{:.0}x (seq/pipe), CPU {:.1}x/{:.0}x",
        power::T_GPU_RTX4090 / t_seq.total,
        power::T_GPU_RTX4090 / t_pipe.total,
        power::T_CPU_I7_12700 / t_seq.total,
        power::T_CPU_I7_12700 / t_pipe.total
    );
    Ok(())
}

/// Measured digital + analog-model PJRT latency on this host.
#[cfg(feature = "runtime-xla")]
fn pjrt_workload() -> anyhow::Result<()> {
    use memx::nn::Manifest;
    use memx::runtime::{Engine, Model};
    use memx::util::bin::Dataset;

    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_inference: artifacts missing — skipping the PJRT section");
        return Ok(());
    }
    let m = Manifest::load(dir)?;
    let engine = Engine::new(dir)?;
    let ds = Dataset::load(&dir.join(&m.dataset_file))?;
    let mut b = Bench::quick(); // analog-model runs are seconds each
    for &batch in &engine.available_batches() {
        for model in [Model::Digital, Model::Analog] {
            let exec = engine.get(model, batch)?;
            let img = ds.image_len();
            let mut buf = vec![0f32; batch * img];
            for j in 0..batch {
                buf[j * img..(j + 1) * img].copy_from_slice(ds.image(j % ds.n));
            }
            let stats = b.run(&format!("{model:?} pjrt b{batch}"), || {
                exec.run(&buf).expect("execute");
            });
            println!(
                "    -> per-image {:.3} ms",
                stats.mean_secs() * 1e3 / batch as f64
            );
        }
    }
    b.table("Fig 8 — measured digital/analog-model latency on this host");
    println!("\npaper §5.2: GPU 0.1654 ms, CPU 3.3924 ms per image; analog 1.24 µs");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    pipeline_workload()?;
    analytical_workload()?;
    #[cfg(feature = "runtime-xla")]
    pjrt_workload()?;
    Ok(())
}
