"""AOT exporter — the single build-time python entrypoint.

Lowers the analog (memristor) and digital (baseline) MobileNetV3 forwards to
HLO **text** artifacts for the rust PJRT runtime, and writes the manifest /
weights / dataset sidecars the rust mapper and coordinator consume.

HLO text — NOT ``lowered.compiler_ir("hlo")`` / ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` crate
expects) rejects; the text parser reassigns ids (see /opt/xla-example).

Outputs in --outdir:
  model_b{1,8,32}.hlo.txt    analog memristor forward, weights baked
  digital_b{1,8,32}.hlo.txt  fp32 reference forward, weights baked
  manifest.json              arch + layer inventory + artifact index +
                             device params + weight table (offsets/scales)
  weights.bin                raw f32 tensors (little-endian, manifest order)
  dataset.bin                held-out test split (synth-cifar)
  expected_logits.bin        python-side analog logits for the first 64
                             test images — runtime cross-validation
  params.npz                 (input, produced by compile.train)
"""

import argparse
import json
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import device as dv
from . import model as M

BATCH_SIZES = (1, 8, 32)
N_TEST = 2000
N_EXPECTED = 64
ANALOG_SEED = 7


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    print_large_constants=True is load-bearing: the default printer elides
    any sizeable constant as `{...}`, which XLA's text parser silently reads
    back as ZEROS — every baked weight would vanish (caught by `memx verify`
    against expected_logits.bin).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_forward(params, width, batch, ctx):
    """Weights are baked as constants via closure: the artifact is
    self-contained and the rust hot path feeds images only."""
    jp = {k: jnp.asarray(v) for k, v in params.items()}

    def fwd(x):
        return (M.forward(jp, x, ctx, width=width),)

    spec = jax.ShapeDtypeStruct((batch, 32, 32, 3), jnp.float32)
    return jax.jit(fwd).lower(spec)


def export_hlo(params, width, outdir, dev):
    analog = M.convert_params_analog(params, dev, seed=ANALOG_SEED)
    index = {}
    for b in BATCH_SIZES:
        for mode in ("model", "digital"):
            # native_conv=False: XLA convolution ops miscompile through the
            # HLO-text AOT path (DESIGN.md §8) — export the im2col form.
            # use_kernel=False for the serving artifacts: on CPU-PJRT the
            # interpret-mode pallas lowering emulates the kernel grid with
            # while-loops and runs ~38x slower than the identical-numerics
            # dot form (EXPERIMENTS.md §Perf L2). The pallas kernel is the
            # TPU hot path; one kernel-path artifact is exported below for
            # runtime cross-validation.
            ctx = M.Ctx(analog=analog if mode == "model" else None, dev=dev,
                        native_conv=False, use_kernel=False)
            text = to_hlo_text(lower_forward(params, width, b, ctx))
            name = f"{mode}_b{b}.hlo.txt"
            with open(f"{outdir}/{name}", "w") as f:
                f.write(text)
            index[f"{mode}_b{b}"] = name
            print(f"[aot] wrote {name} ({len(text)/1e6:.1f} MB)")
    # kernel-path variant (pallas interpret lowering) at one batch size:
    # tests assert it matches the served artifact's logits.
    ctx = M.Ctx(analog=analog, dev=dev, native_conv=False, use_kernel=True)
    text = to_hlo_text(lower_forward(params, width, 8, ctx))
    with open(f"{outdir}/model_kernelpath_b8.hlo.txt", "w") as f:
        f.write(text)
    index["model_kernelpath_b8"] = "model_kernelpath_b8.hlo.txt"
    print(f"[aot] wrote model_kernelpath_b8.hlo.txt ({len(text)/1e6:.1f} MB)")
    return analog, index


def export_weights(params, analog, outdir):
    """weights.bin: concatenated little-endian f32 tensors; the manifest
    carries (name, shape, offset, len, scale) so rust can reconstruct both
    the raw weights (Fig 9 histogram, netlists) and the analog scales."""
    table = []
    offset = 0
    blob = bytearray()
    for name in sorted(params.keys()):
        arr = np.ascontiguousarray(params[name], dtype="<f4")
        entry = {
            "name": name,
            "shape": list(arr.shape),
            "offset": offset,
            "len": int(arr.size),
        }
        akey = name if name in analog else None
        if akey is not None:
            entry["scale"] = float(analog[akey]["scale"])
        table.append(entry)
        blob.extend(arr.tobytes())
        offset += arr.size
    with open(f"{outdir}/weights.bin", "wb") as f:
        f.write(struct.pack("<II", D.MAGIC, len(blob) // 4))
        f.write(bytes(blob))
    return table


def export_dataset(outdir):
    xt, yt = D.make_dataset(N_TEST, seed=5678)  # == train.py's test split
    D.write_dataset_bin(f"{outdir}/dataset.bin", xt, yt)
    print(f"[aot] wrote dataset.bin ({N_TEST} images)")
    return xt, yt


def export_expected(params, width, analog, dev, xt, outdir):
    """Analog logits for the first N_EXPECTED test images, computed through
    the same jit that was lowered — the rust runtime must reproduce these
    bit-for-bit modulo PJRT scheduling (tolerance 1e-4)."""
    ctx = M.Ctx(analog=analog, dev=dev)
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    logits = np.asarray(
        jax.jit(lambda x: M.forward(jp, x, ctx, width=width))(
            jnp.asarray(xt[:N_EXPECTED])
        )
    ).astype("<f4")
    with open(f"{outdir}/expected_logits.bin", "wb") as f:
        f.write(struct.pack("<II", logits.shape[0], logits.shape[1]))
        f.write(logits.tobytes())
    print(f"[aot] wrote expected_logits.bin {logits.shape}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", default="../artifacts/params.npz")
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--untrained", action="store_true",
                    help="use freshly-initialized weights (CI/smoke only)")
    args = ap.parse_args()

    dev = dv.DEFAULT_DEVICE
    if args.untrained:
        width = 0.4
        params = M.init_params(0, width)
        test_acc = -1.0
    else:
        npz = np.load(args.params)
        width = float(npz["__width"])
        test_acc = float(npz["__test_acc"])
        params = {k: npz[k] for k in npz.files if not k.startswith("__")}
    print(f"[aot] width={width} digital test_acc={test_acc:.4f}")

    analog, index = export_hlo(params, width, args.outdir, dev)
    table = export_weights(params, analog, args.outdir)
    xt, yt = export_dataset(args.outdir)
    export_expected(params, width, analog, dev, xt, args.outdir)

    manifest = M.build_manifest(params, width=width)
    manifest.update(
        {
            "digital_test_acc": test_acc,
            "batch_sizes": list(BATCH_SIZES),
            "artifacts": index,
            "device": dev.to_dict(),
            "analog_seed": ANALOG_SEED,
            "weights": table,
            "dataset": {"file": "dataset.bin", "n": N_TEST},
            "expected_logits": {"file": "expected_logits.bin", "n": N_EXPECTED},
        }
    )
    with open(f"{args.outdir}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print("[aot] wrote manifest.json")


if __name__ == "__main__":
    main()
