"""Pure-jnp oracle for the Pallas crossbar kernel.

Semantics of one differential memristor crossbar bank with inverting TIAs
(paper §3.2, Eq 4, inverted convention):

  I_col   = sum_i V_i * (Gpos[i,c] - Gneg[i,c])      (Kirchhoff)
  V_out_c = -Rf * I_col = Rf * sum_i V_i * (Gneg - Gpos)[i,c]

followed by the TIA output-rail saturation.  ``gpos``/``gneg`` are the
*normalized* conductance matrices (in weight units, see device.py); the
physical Rf and full-scale factors collapse into ``rf_scale``.
"""

import jax.numpy as jnp


def crossbar_vmm_ref(v, g_pos, g_neg, rf_scale=1.0, v_rail=8.0):
    """v: (..., R) inputs; g_pos/g_neg: (R, C). Returns (..., C)."""
    out = jnp.matmul(v, g_neg - g_pos) * rf_scale
    return jnp.clip(out, -v_rail, v_rail)


def hard_sigmoid_ref(x):
    """Software hard sigmoid used by MobileNetV3: relu6(x + 3) / 6."""
    return jnp.clip(x + 3.0, 0.0, 6.0) / 6.0


def hard_swish_ref(x):
    return x * hard_sigmoid_ref(x)


def relu_ref(x):
    return jnp.maximum(x, 0.0)


def analog_hard_sigmoid_ref(x, v_rail=8.0):
    """Analog circuit (Fig 4a): op-amp adder (+3), divider (/6), diode
    limiter clamps to [0, 1]; the *input* was already rail-limited by the
    previous TIA stage, which the clip on x models."""
    x = jnp.clip(x, -v_rail, v_rail)
    return jnp.clip((x + 3.0) / 6.0, 0.0, 1.0)


def analog_hard_swish_ref(x, v_rail=8.0):
    """Fig 4b: hard-sigmoid branch followed by an analog multiplier.
    The multiplier output is also bounded by the rails."""
    x = jnp.clip(x, -v_rail, v_rail)
    return jnp.clip(x * analog_hard_sigmoid_ref(x, v_rail), -v_rail, v_rail)


def analog_relu_ref(x, v_rail=8.0):
    """CMOS ReLU (Priyanka et al. 2019) with rail saturation."""
    return jnp.clip(jnp.maximum(x, 0.0), 0.0, v_rail)
