//! netlist_pipeline — the automated framework end-to-end (paper §4):
//! trained weights -> conductances (Eq 16) -> crossbar layout (Alg 1) ->
//! segmented SPICE netlists -> parallel DC simulation -> functional check.
//!
//!   cargo run --release --example netlist_pipeline [layer] [segment_cols]
//!
//! Mirrors the paper's Fig 6 block diagram: conversion module (mapper),
//! layer module (netlist emitter with §4.2 segmentation), model module
//! (the layer picked from the trained manifest), assessment module (the
//! MNA solver validating the crossbar against its ideal transfer).

use std::path::Path;
use std::time::Instant;

use memx::mapper::{self, MapMode};
use memx::netlist;
use memx::nn::{Manifest, WeightStore};
use memx::spice::solve::Ordering;
use memx::util::pool::par_map;
use memx::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let layer = std::env::args().nth(1).unwrap_or_else(|| "cls.fc1".into());
    let segment: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(64);
    let dir = Path::new("artifacts");
    let outdir = Path::new("target/netlists");

    // conversion module: weights -> differential quantized conductances
    let m = Manifest::load(dir)?;
    let ws = WeightStore::load(dir, &m)?;
    let t0 = Instant::now();
    let cb = mapper::build_fc_crossbar(&m, &ws, &layer, MapMode::Inverted)?;
    println!(
        "[convert+layout] {layer}: {}x{} crossbar, {} devices in {:?}",
        cb.rows,
        cb.cols,
        cb.devices.len(),
        t0.elapsed()
    );

    // layer module: emit segmented netlist files (construction-time metric)
    let t0 = Instant::now();
    let files = netlist::emit_layer_netlists(&m, &ws, &layer, MapMode::Inverted, segment, outdir)?;
    println!(
        "[netlist] {} file(s) ({} columns each) in {:?} -> {outdir:?}",
        files.len(),
        segment,
        t0.elapsed()
    );

    // assessment module: drive a random input vector through every segment
    // (parsed back from disk — the full framework path) and compare with
    // the behavioural crossbar
    let mut rng = Rng::new(2024);
    let inputs: Vec<f64> = (0..cb.region).map(|_| rng.range_f64(-0.5, 0.5)).collect();
    let ideal = cb.eval_ideal(&inputs);
    let segs = netlist::plan_segments(cb.cols, segment);

    let t0 = Instant::now();
    let seg_results = par_map(&segs, memx::util::pool::default_workers(), |seg| {
        let text = netlist::emit_crossbar(&cb, &m.device, seg, Some(&inputs), segs.len());
        let circuit = netlist::parse(&text).expect("parse emitted netlist");
        netlist::solve_segment_outputs(&circuit, seg, true, Ordering::Smart)
            .expect("solve segment")
    });
    let wall = t0.elapsed();

    let spice: Vec<f64> = seg_results.into_iter().flatten().collect();
    let max_err = spice
        .iter()
        .zip(&ideal)
        .fold(0f64, |a, (s, i)| a.max((s - i).abs()));
    println!(
        "[assess] {} segments simulated in {wall:?}; max |SPICE - ideal| = {max_err:.3e}",
        segs.len()
    );
    anyhow::ensure!(max_err < 1e-3, "SPICE disagrees with the analog model");
    println!("netlist pipeline OK");
    Ok(())
}
