//! SplitMix64 + xoshiro256** PRNG.
//!
//! The offline crate cache has rand_core but not rand, so we carry the two
//! standard small generators: SplitMix64 for seeding, xoshiro256** for the
//! stream. Used by the mapper (programming noise), the synthetic dataset
//! generator, benches and the mini property-test harness.

/// SplitMix64 — used to expand a single u64 seed.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second gaussian from Box-Muller
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()], spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // rejection-free multiply-shift (Lemire); bias negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let th = std::f64::consts::TAU * v;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(6);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn below_covers_all() {
        let mut r = Rng::new(7);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.below(5)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
