//! SPICE netlist layer — text emission, parsing, and the paper's §4.2
//! segmentation strategy (splitting one crossbar module into per-column-
//! group files to tame simulation time).
//!
//! Emitted dialect (a ngspice/PSpice-compatible subset):
//!
//! ```text
//! * memx crossbar <name>  (mode inverted, seg 2/32)
//! Vin12 in12 0 DC 0.0025
//! RM12_7 in12 vcol7 2521.3
//! RF7 vcol7 vout7 50.0
//! EOP7 vout7 0 0 vcol7 1e6
//! .op
//! .end
//! ```
//!
//! Node conventions: `in<r>` crossbar input lines (r indexes the full
//! physical crossbar even in segment files), `vcol<c>` TIA virtual grounds,
//! `vout<c>` outputs, `vinv<c>` the dual-mode inverter outputs.
//!
//! Repeated reads of the same crossbar should go through [`CrossbarSim`]:
//! it parses each segment once, then reuses the per-segment cached LU
//! factorization for every input vector (parallel across segments, with a
//! multi-RHS batch path) instead of re-emitting, re-parsing and
//! re-eliminating per read.
//!
//! # Interchange dialect and validation
//!
//! The flat cards above predate the structured [`interchange`] dialect,
//! which is what external tooling should target:
//!
//! * [`interchange`] — `.SUBCKT`-structured decks for every resident
//!   module kind (crossbar segments, batch-norm pairs, GAP columns, Fig-4
//!   activation cells), plus a full parser: element cards
//!   `R/V/I/E/G/C/L/D/B`, engineering suffixes (`10k`, `4.7u`, `1meg`),
//!   `+` continuation lines, comments, nested subcircuit expansion, and
//!   structured [`interchange::ParseError`]s carrying line/column. See the
//!   module docs for the card table and subcircuit conventions.
//! * [`validate`] — the differential harness behind `memx validate`: a
//!   deliberately independent dense MNA reference solver cross-checked
//!   against the production engine, the emit → parse → simulate
//!   round-trip contract, and deck fuzzing.
//!
//! Tolerance contract: decks emitted by [`interchange::emit_deck`] carry
//! node-order pins, so re-simulating the parsed deck is *bit-identical*
//! to the resident circuit under the deterministic reference engine
//! (enforced at [`validate::ROUNDTRIP_TOL`] = 1e-12); the independent
//! dense reference and the Krylov engine agree with the production
//! factored path to [`validate::REFERENCE_TOL`] = 1e-6. Run
//! `memx validate` (or `--quick` in CI) to sweep the demo network's
//! decks through all three legs.

pub mod interchange;
pub mod validate;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::BackendChoice;
use crate::mapper::layout::Placed;
use crate::mapper::{build_fc_crossbar, Crossbar, MapMode};
use crate::nn::{DeviceJson, Manifest, WeightStore};
use crate::spice::krylov::SolverStrategy;
use crate::spice::solve::{Ordering, SolveStats};
use crate::spice::transient::{
    resistor_energy, settling_time, Integrator, TranConfig, TranStats, Waveform,
};
use crate::spice::{Circuit, Element};
use crate::util::pool::par_map_mut;

/// Conductance mapping: normalized g in (0,1] -> physical resistance.
/// G_phys = g * g_on, i.e. R = r_on / g. With 64 levels the smallest
/// nonzero g is 1/63 -> R = 6.3 kΩ < r_off, so every placed device is
/// within the HP model's [r_on, r_off] range (DESIGN.md §8).
pub fn device_resistance(g_norm: f64, r_on: f64) -> f64 {
    assert!(g_norm > 0.0, "zero-weight devices are not placed");
    r_on / g_norm
}

/// TIA feedback: de-normalizes the column current (see mapper::Crossbar):
/// V_out = Rf * Σ V_i * G_i with Rf = rf_scale * r_on.
pub fn feedback_resistance(rf_scale: f64, r_on: f64) -> f64 {
    rf_scale * r_on
}

/// One emitted segment: which columns of the parent crossbar it carries.
#[derive(Debug, Clone)]
pub struct Segment {
    pub index: usize,
    pub col_start: usize,
    pub col_end: usize, // exclusive
}

/// Split `cols` into groups of `segment` columns (0 = no segmentation).
pub fn plan_segments(cols: usize, segment: usize) -> Vec<Segment> {
    if segment == 0 || segment >= cols {
        return vec![Segment { index: 0, col_start: 0, col_end: cols }];
    }
    (0..cols.div_ceil(segment))
        .map(|i| Segment {
            index: i,
            col_start: i * segment,
            col_end: ((i + 1) * segment).min(cols),
        })
        .collect()
}

/// Render one segment of a crossbar as netlist text. `inputs` supplies the
/// voltage of each *direct-region* input line (bias lines are fixed ±1 V);
/// pass None to emit all-zero sources (weights-only netlist).
pub fn emit_crossbar(
    cb: &Crossbar,
    dev: &DeviceJson,
    seg: &Segment,
    inputs: Option<&[f64]>,
    n_segments: usize,
) -> String {
    let mut s = String::with_capacity(1 << 16);
    s.push_str(&format!(
        "* memx crossbar {} (mode {:?}, seg {}/{}, cols {}..{})\n",
        cb.name, cb.mode, seg.index + 1, n_segments, seg.col_start, seg.col_end
    ));
    s.push_str(&format!(
        "* rows {} cols {} region {} rf_scale {}\n",
        cb.rows, cb.cols, cb.region, cb.rf_scale
    ));

    // which input lines does this segment actually touch?
    let mut used_rows: Vec<bool> = vec![false; cb.rows];
    for d in &cb.devices {
        if d.col >= seg.col_start && d.col < seg.col_end {
            used_rows[d.row] = true;
        }
    }
    // input sources: direct region in<r>, negated region uses the same
    // physical source index offset by the region (separate source: the
    // hardware negation amplifier output)
    for r in 0..cb.rows {
        if !used_rows[r] {
            continue;
        }
        let v = input_voltage(cb, r, inputs);
        s.push_str(&format!("Vin{r} in{r} 0 DC {v}\n"));
    }
    // devices
    let rf = feedback_resistance(cb.rf_scale, dev.r_on);
    for d in &cb.devices {
        if d.col < seg.col_start || d.col >= seg.col_end {
            continue;
        }
        let res = device_resistance(d.g_norm, dev.r_on);
        s.push_str(&format!("RM{}_{} in{} vcol{} {res}\n", d.row, d.col, d.row, d.col));
    }
    // per-column TIA (+ inverter in dual mode)
    for c in seg.col_start..seg.col_end {
        s.push_str(&format!("RF{c} vcol{c} vout{c} {rf}\n"));
        s.push_str(&format!("EOP{c} vout{c} 0 0 vcol{c} 1e6\n"));
        if !cb.mode.inverted() {
            // unity inverter: Rin = Rf2 = 10k into a second op-amp
            s.push_str(&format!("RIA{c} vout{c} vsum{c} 10000\n"));
            s.push_str(&format!("RIB{c} vsum{c} vinv{c} 10000\n"));
            s.push_str(&format!("EIN{c} vinv{c} 0 0 vsum{c} 1e6\n"));
        }
    }
    s.push_str(".op\n.end\n");
    s
}

fn input_voltage(cb: &Crossbar, row: usize, inputs: Option<&[f64]>) -> f64 {
    input_voltage_region(cb.region, row, inputs)
}

/// Node read back as column `col`'s output (see the module-level node
/// conventions): the TIA output in inverted mode, the dual-mode inverter
/// output otherwise. Single source of truth for the readers
/// ([`solve_segment_outputs`], [`CrossbarSim`]).
pub fn output_node_name(inverted: bool, col: usize) -> String {
    if inverted {
        format!("vout{col}")
    } else {
        format!("vinv{col}")
    }
}

/// Voltage of input line `row` given the direct-region values (see
/// [`emit_crossbar`]): rows [0, region) direct, [region, 2*region) negated,
/// then the +1 V / -1 V bias lines.
fn input_voltage_region(region: usize, row: usize, inputs: Option<&[f64]>) -> f64 {
    if row < region {
        inputs.map_or(0.0, |v| v[row])
    } else if row < 2 * region {
        inputs.map_or(0.0, |v| -v[row - region])
    } else if row == 2 * region {
        1.0
    } else {
        -1.0
    }
}

/// Parse netlist text back into a [`Circuit`] (round-trip validation and
/// the simulate-from-file path that Fig 7 measures).
pub fn parse(text: &str) -> Result<Circuit> {
    let title = text.lines().next().unwrap_or("").trim_start_matches('*').trim();
    let mut c = Circuit::new(title);
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('*') || line.starts_with('.') {
            continue;
        }
        let tok: Vec<&str> = line.split_whitespace().collect();
        let ctx = || format!("netlist line {}: '{line}'", lineno + 1);
        let kind = line.chars().next().unwrap().to_ascii_uppercase();
        match kind {
            'R' => {
                if tok.len() != 4 {
                    bail!("{}: resistor needs 4 tokens", ctx());
                }
                let (a, b) = (c.node(tok[1]), c.node(tok[2]));
                let v: f64 = tok[3].parse().with_context(ctx)?;
                c.resistor(tok[0], a, b, v);
            }
            'V' => {
                // Vname n+ n- [DC] value
                let (val_idx, min_len) = if tok.len() >= 5 && tok[3].eq_ignore_ascii_case("dc")
                {
                    (4, 5)
                } else {
                    (3, 4)
                };
                if tok.len() < min_len {
                    bail!("{}: vsource needs value", ctx());
                }
                let (a, b) = (c.node(tok[1]), c.node(tok[2]));
                let v: f64 = tok[val_idx].parse().with_context(ctx)?;
                c.vsource(tok[0], a, b, v);
            }
            'I' => {
                if tok.len() != 4 {
                    bail!("{}: isource needs 4 tokens", ctx());
                }
                let (a, b) = (c.node(tok[1]), c.node(tok[2]));
                let v: f64 = tok[3].parse().with_context(ctx)?;
                c.isource(tok[0], a, b, v);
            }
            'E' => {
                if tok.len() != 6 {
                    bail!("{}: VCVS needs 6 tokens", ctx());
                }
                let (op, om) = (c.node(tok[1]), c.node(tok[2]));
                let (cp, cm) = (c.node(tok[3]), c.node(tok[4]));
                let g: f64 = tok[5].parse().with_context(ctx)?;
                c.vcvs(tok[0], op, om, cp, cm, g);
            }
            'D' => {
                if tok.len() < 3 {
                    bail!("{}: diode needs 3 tokens", ctx());
                }
                let (a, k) = (c.node(tok[1]), c.node(tok[2]));
                c.diode(tok[0], a, k);
            }
            other => bail!("{}: unsupported element '{other}'", ctx()),
        }
    }
    Ok(c)
}

/// Factor-once / solve-many simulator for one crossbar.
///
/// Construction emits + parses the (optionally segmented) netlists once;
/// every subsequent input vector is applied as V-source edits — RHS-only,
/// so each segment's cached LU factorization ([`crate::spice::factor`]) is
/// reused and a read costs one O(nnz(L+U)) substitution per segment.
/// Independent segments solve in parallel ([`par_map_mut`]), and
/// [`CrossbarSim::solve_batch`] amortizes a whole batch of input vectors
/// over a single multi-RHS substitution pass per segment — the batched
/// crossbar column-read path used by the benches and the Fig 7 report.
pub struct CrossbarSim {
    segments: Vec<SegmentSim>,
    region: usize,
    cols: usize,
    ordering: Ordering,
}

struct SegmentSim {
    circuit: Circuit,
    /// (vsource element index, physical crossbar row) per input line
    vin: Vec<(usize, usize)>,
    /// output node id per column of this segment
    out_nodes: Vec<usize>,
}

impl CrossbarSim {
    /// Emit + parse + index every segment (`segment` = columns per file,
    /// 0 = monolithic). All sources start at 0 V / bias levels. `solver`
    /// selects each segment circuit's linear engine —
    /// [`SolverStrategy::Auto`] keeps segmented circuits on the direct
    /// factor path and moves giant monolithic ones onto preconditioned
    /// GMRES (see [`crate::spice::krylov`]).
    pub fn new(
        cb: &Crossbar,
        dev: &DeviceJson,
        segment: usize,
        ordering: Ordering,
        solver: SolverStrategy,
    ) -> Result<CrossbarSim> {
        let segs = plan_segments(cb.cols, segment);
        let n_segments = segs.len();
        let mut segments = Vec::with_capacity(n_segments);
        for seg in &segs {
            let text = emit_crossbar(cb, dev, seg, None, n_segments);
            let mut circuit = parse(&text)?;
            circuit.set_solver(solver);
            // one pass over the element list (vsource_index per row would
            // make construction quadratic in the crossbar size)
            let vin: Vec<(usize, usize)> = {
                let mut by_name = std::collections::HashMap::new();
                for (i, e) in circuit.elements.iter().enumerate() {
                    if let Element::Vsource(n, ..) = e {
                        by_name.insert(n.as_str(), i);
                    }
                }
                (0..cb.rows)
                    .filter_map(|r| {
                        by_name.get(format!("Vin{r}").as_str()).map(|&i| (i, r))
                    })
                    .collect()
            };
            let out_nodes = (seg.col_start..seg.col_end)
                .map(|c| {
                    let name = output_node_name(cb.mode.inverted(), c);
                    circuit
                        .node_named(&name)
                        .ok_or_else(|| anyhow!("output node {name} missing"))
                })
                .collect::<Result<Vec<usize>>>()?;
            segments.push(SegmentSim { circuit, vin, out_nodes });
        }
        Ok(CrossbarSim { segments, region: cb.region, cols: cb.cols, ordering })
    }

    pub fn n_segments(&self) -> usize {
        self.segments.len()
    }

    /// Snapshot every resident segment as a structured interchange deck
    /// ([`interchange::Deck`]): the segment circuit at its current
    /// operating point (sources, conductance edits and all), with the
    /// input-line source nodes as deck inputs and the per-column read
    /// nodes as outputs. Deck names are `{prefix}.seg{i}`. These are what
    /// `memx validate` sweeps through the round-trip and differential
    /// checks.
    pub fn decks(&self, prefix: &str) -> Vec<interchange::Deck> {
        self.segments
            .iter()
            .enumerate()
            .map(|(i, seg)| {
                let names = seg.circuit.node_names();
                let inputs: Vec<String> = seg
                    .vin
                    .iter()
                    .filter_map(|&(idx, _)| match seg.circuit.elements.get(idx) {
                        Some(Element::Vsource(_, a, _, _)) => Some(names[*a].clone()),
                        _ => None,
                    })
                    .collect();
                let outputs: Vec<String> =
                    seg.out_nodes.iter().map(|&n| names[n].clone()).collect();
                interchange::Deck {
                    name: format!("{prefix}.seg{i}"),
                    circuit: seg.circuit.clone(),
                    inputs,
                    outputs,
                }
            })
            .collect()
    }

    /// Select the dense-kernel backend for every resident segment circuit.
    /// Value-only, like [`CrossbarSim::update_conductances`]: cached
    /// factorizations stay valid, only the substitution/Krylov kernels
    /// change. Transient twins cloned by [`CrossbarSim::tran_read`] inherit
    /// the choice with the circuit.
    pub fn set_backend(&mut self, backend: BackendChoice) {
        for seg in &mut self.segments {
            seg.circuit.set_backend(backend);
        }
    }

    /// Value-only conductance update: rewrite every placed device's
    /// `RM<row>_<col>` resistor to `device_resistance(g_norm, r_on)` without
    /// touching the circuit topology, so each segment's cached symbolic
    /// factorization (and the warm-GMRES preconditioner-reuse contract) is
    /// preserved across the edit — the mechanism behind fault injection and
    /// online recalibration ([`crate::fault`]). Devices whose column falls
    /// outside a segment are simply skipped there; returns the number of
    /// device resistors updated (each device lives in exactly one segment).
    pub fn update_conductances(&mut self, devices: &[Placed], r_on: f64) -> usize {
        let mut updated = 0;
        for seg in &mut self.segments {
            let mut by_name = std::collections::HashMap::new();
            for (i, e) in seg.circuit.elements.iter().enumerate() {
                if let Element::Resistor(n, ..) = e {
                    if n.starts_with("RM") {
                        by_name.insert(n.clone(), i);
                    }
                }
            }
            for d in devices {
                let Some(&i) = by_name.get(&format!("RM{}_{}", d.row, d.col)) else {
                    continue;
                };
                if let Some(Element::Resistor(_, _, _, r)) = seg.circuit.elements.get_mut(i)
                {
                    *r = device_resistance(d.g_norm, r_on);
                    updated += 1;
                }
            }
        }
        updated
    }

    /// Like [`CrossbarSim::solve`], additionally returning each segment's
    /// [`SolveStats`] — the drift tests pin that post-recalibration
    /// re-solves reuse the cached factorization/preconditioner
    /// (`precond_reused`, bounded iteration counts) instead of refactoring
    /// cold.
    pub fn solve_stats(&mut self, inputs: &[f64]) -> Result<(Vec<f64>, Vec<SolveStats>)> {
        if inputs.len() != self.region {
            bail!("crossbar sim: {} inputs, region is {}", inputs.len(), self.region);
        }
        let (region, ordering) = (self.region, self.ordering);
        let mut out = Vec::with_capacity(self.cols);
        let mut stats = Vec::with_capacity(self.segments.len());
        for seg in &mut self.segments {
            let _sp = crate::telemetry::span("segment_solve", "solve")
                .arg("cols", seg.out_nodes.len() as f64);
            for &(idx, r) in &seg.vin {
                seg.circuit
                    .set_vsource_at(idx, input_voltage_region(region, r, Some(inputs)))?;
            }
            let (sol, st) = seg.circuit.dc_op_stats(ordering)?;
            out.extend(seg.out_nodes.iter().map(|&n| sol[n]));
            stats.push(st);
        }
        Ok((out, stats))
    }

    /// Per-column outputs for one input vector (len = crossbar region),
    /// solving segments sequentially.
    pub fn solve(&mut self, inputs: &[f64]) -> Result<Vec<f64>> {
        self.solve_par(inputs, 1)
    }

    /// Like [`CrossbarSim::solve`] with segments distributed over
    /// `workers` threads.
    pub fn solve_par(&mut self, inputs: &[f64], workers: usize) -> Result<Vec<f64>> {
        if inputs.len() != self.region {
            bail!("crossbar sim: {} inputs, region is {}", inputs.len(), self.region);
        }
        let (region, ordering) = (self.region, self.ordering);
        let results = par_map_mut(&mut self.segments, workers, |seg| -> Result<Vec<f64>> {
            let _sp = crate::telemetry::span("segment_solve", "solve")
                .arg("cols", seg.out_nodes.len() as f64);
            for &(idx, r) in &seg.vin {
                seg.circuit
                    .set_vsource_at(idx, input_voltage_region(region, r, Some(inputs)))?;
            }
            let sol = seg.circuit.dc_op_with(ordering)?;
            Ok(seg.out_nodes.iter().map(|&n| sol[n]).collect())
        });
        let mut out = Vec::with_capacity(self.cols);
        for r in results {
            out.extend(r?);
        }
        Ok(out)
    }

    /// Batched reads: outputs for each input vector, one factorization
    /// (or one shared Krylov preconditioner) and a single multi-RHS pass
    /// per segment ([`Circuit::dc_op_batch_par`]), segments parallel over
    /// `workers`. A monolithic (single-segment) simulator hands the whole
    /// worker budget to the per-RHS Krylov sweeps instead.
    pub fn solve_batch(
        &mut self,
        inputs: &[Vec<f64>],
        workers: usize,
    ) -> Result<Vec<Vec<f64>>> {
        for iv in inputs {
            if iv.len() != self.region {
                bail!("crossbar sim: {} inputs, region is {}", iv.len(), self.region);
            }
        }
        let _sp = crate::telemetry::span("crossbar_solve_batch", "solve")
            .arg("batch", inputs.len() as f64)
            .arg("segments", self.segments.len() as f64);
        let inner_workers = if self.segments.len() == 1 { workers.max(1) } else { 1 };
        let (region, ordering, cols) = (self.region, self.ordering, self.cols);
        let per_seg = par_map_mut(&mut self.segments, workers, |seg| -> Result<Vec<Vec<f64>>> {
            let _sp = crate::telemetry::span("segment_solve", "solve")
                .arg("cols", seg.out_nodes.len() as f64);
            let overrides: Vec<Vec<(usize, f64)>> = inputs
                .iter()
                .map(|iv| {
                    seg.vin
                        .iter()
                        .map(|&(idx, r)| (idx, input_voltage_region(region, r, Some(iv))))
                        .collect()
                })
                .collect();
            let sols = seg.circuit.dc_op_batch_par(&overrides, ordering, inner_workers)?;
            Ok(sols
                .into_iter()
                .map(|sol| seg.out_nodes.iter().map(|&n| sol[n]).collect())
                .collect())
        });
        let mut out: Vec<Vec<f64>> = vec![Vec::with_capacity(cols); inputs.len()];
        for segres in per_seg {
            for (k, seg_cols) in segres?.into_iter().enumerate() {
                out[k].extend(seg_cols);
            }
        }
        Ok(out)
    }

    /// Simulate one read pulse in the time domain: settling latency +
    /// integrated device energy, the simulated counterpart of the
    /// analytical `power::` estimates.
    ///
    /// Builds a dynamic *twin* of each resident segment (the DC circuits
    /// and their cached factorizations are untouched): every input source
    /// becomes a rise-limited [`Waveform::Pulse`] from 0 V to its read
    /// level, each TIA virtual ground gains a `c_col` parasitic, and each
    /// output node drives an `r_out`/`c_load` line stage — the node the
    /// settling time and final outputs are measured at. The twin is a
    /// value-superset of the DC netlist, so the settled outputs converge
    /// to [`CrossbarSim::solve`] for the same inputs.
    pub fn tran_read(&self, inputs: &[f64], pulse: &ReadPulse) -> Result<TranRead> {
        if inputs.len() != self.region {
            bail!("crossbar sim: {} inputs, region is {}", inputs.len(), self.region);
        }
        if pulse.r_out <= 0.0 || pulse.c_load <= 0.0 {
            bail!("read pulse: r_out and c_load must be positive");
        }
        let _sp = crate::telemetry::span("tran_read", "solve")
            .arg("segments", self.segments.len() as f64);
        let tau = pulse.r_out * pulse.c_load;
        let t_stop = if pulse.t_stop > 0.0 { pulse.t_stop } else { pulse.rise + 12.0 * tau };
        // resolve the input edge; the LTE controller grows h after it
        let h0 = if pulse.rise > 0.0 {
            (pulse.rise * 0.25).min(tau * 0.1)
        } else {
            tau * 0.02
        };
        let region = self.region;
        let mut outputs = Vec::with_capacity(self.cols);
        let mut settle = 0.0_f64;
        let mut energy = 0.0_f64;
        let mut stats = TranStats::default();
        for seg in &self.segments {
            let mut twin = seg.circuit.clone();
            // launch every input line (incl. bias rows) as a read pulse
            for &(idx, r) in &seg.vin {
                let v = input_voltage_region(region, r, Some(inputs));
                twin.set_waveform(
                    idx,
                    Waveform::Pulse {
                        v1: 0.0,
                        v2: v,
                        delay: 0.0,
                        rise: pulse.rise,
                        fall: pulse.rise,
                        width: 2.0 * t_stop,
                        period: 0.0,
                    },
                )?;
            }
            // column-line parasitic at each TIA virtual ground (the RF
            // feedback resistor's first node by emission convention)
            let vcols: Vec<usize> = twin
                .elements
                .iter()
                .filter_map(|e| match e {
                    Element::Resistor(n, a, _, _) if n.starts_with("RF") => Some(*a),
                    _ => None,
                })
                .collect();
            for (k, &vc) in vcols.iter().enumerate() {
                twin.capacitor(&format!("CC{k}"), vc, 0, pulse.c_col);
            }
            // output line-driver stage: the measured read node per column
            let mut load_nodes = Vec::with_capacity(seg.out_nodes.len());
            for (k, &on) in seg.out_nodes.iter().enumerate() {
                let ld = twin.node(&format!("vload{k}"));
                twin.resistor(&format!("RD{k}"), on, ld, pulse.r_out);
                twin.capacitor(&format!("CL{k}"), ld, 0, pulse.c_load);
                load_nodes.push(ld);
            }
            let mut cfg = TranConfig::new(t_stop, h0).with_integrator(pulse.integrator);
            cfg.ordering = self.ordering;
            let res = twin.tran(&cfg)?;
            let last = res.voltages[0]
                .last()
                .ok_or_else(|| anyhow!("transient produced no time points"))?;
            outputs.extend(load_nodes.iter().map(|&n| last[n]));
            settle = settle.max(settling_time(&res, 0, &load_nodes, pulse.settle_rtol));
            energy += resistor_energy(&twin, &res, 0, "RM");
            stats.absorb(&res.stats);
        }
        Ok(TranRead { outputs, settle_s: settle, energy_j: energy, stats })
    }
}

/// Read-pulse excitation + output-stage parasitics for
/// [`CrossbarSim::tran_read`].
///
/// The resident DC netlists use ideal op-amps (VCVS, zero output
/// impedance) — every node would settle instantaneously. The transient
/// twin therefore adds the dynamics the analytical §4 latency model only
/// estimates: a line-driver stage (`r_out` into `c_load`) hung off each
/// column output, and a `c_col` parasitic at each TIA virtual ground.
/// With the defaults, `r_out·c_load = 0.5 µs` — the paper's op-amp
/// response time — so the simulated settling time is directly comparable
/// to the analytical `t_mem + t_opamp` column.
#[derive(Debug, Clone)]
pub struct ReadPulse {
    /// Input-source rise/fall time (s); every input line ramps from 0 V
    /// to its read level over this window.
    pub rise: f64,
    /// Output line-driver resistance (Ω).
    pub r_out: f64,
    /// Line + sampling capacitance at each driven output (F).
    pub c_load: f64,
    /// Column-line parasitic at each TIA virtual ground (F).
    pub c_col: f64,
    /// Settling band as a fraction of the final output value.
    pub settle_rtol: f64,
    /// Simulation horizon (s); 0.0 = auto (`rise + 12·r_out·c_load`).
    pub t_stop: f64,
    pub integrator: Integrator,
}

impl Default for ReadPulse {
    fn default() -> Self {
        ReadPulse {
            rise: 10e-9,
            r_out: 1e3,
            c_load: 0.5e-9,
            c_col: 10e-12,
            settle_rtol: 0.01,
            t_stop: 0.0,
            integrator: Integrator::TrBdf2,
        }
    }
}

/// Result of one simulated read pulse ([`CrossbarSim::tran_read`]).
#[derive(Debug, Clone)]
pub struct TranRead {
    /// Per-column outputs sampled at the end of the pulse (settled).
    pub outputs: Vec<f64>,
    /// Worst-case (max over segments) output settling time (s),
    /// measured from pulse launch to the last excursion outside the
    /// `settle_rtol` band at any driven output node.
    pub settle_s: f64,
    /// Energy dissipated in the memristor devices over the read (J),
    /// integrated from the transient trajectory (trapezoid rule).
    pub energy_j: f64,
    /// Merged transient-engine counters across segments (one symbolic
    /// analysis per segment).
    pub stats: TranStats,
}

/// Solve a parsed crossbar segment and extract the per-column outputs.
pub fn solve_segment_outputs(
    circuit: &Circuit,
    seg: &Segment,
    inverted: bool,
    ordering: crate::spice::solve::Ordering,
) -> Result<Vec<f64>> {
    let sol = circuit.dc_op_with(ordering)?;
    (seg.col_start..seg.col_end)
        .map(|cidx| {
            let name = output_node_name(inverted, cidx);
            circuit
                .node_named(&name)
                .map(|n| sol[n])
                .ok_or_else(|| anyhow!("output node {name} missing"))
        })
        .collect()
}

/// Emit one crossbar's segmented netlist files under `outdir` (weights-only
/// sources; file names derive from the crossbar's own name). `segment` =
/// columns per file (0 = single monolithic file).
pub fn emit_crossbar_files(
    cb: &Crossbar,
    dev: &DeviceJson,
    segment: usize,
    outdir: &Path,
) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(outdir)?;
    let segs = plan_segments(cb.cols, segment);
    let mut files = Vec::new();
    for seg in &segs {
        let text = emit_crossbar(cb, dev, seg, None, segs.len());
        let path =
            outdir.join(format!("{}_seg{:03}.sp", cb.name.replace('.', "_"), seg.index));
        std::fs::write(&path, text)?;
        files.push(path);
    }
    Ok(files)
}

/// Emit netlist files for a named layer of the trained network: FC/PConv
/// crossbars, the §3.3 batch-norm pair (subtraction + scale/offset stages,
/// one column per channel — spatial replication is a runtime property) or
/// the §3.5 GAP averaging columns. `segment` = columns per file (0 = single
/// monolithic file).
pub fn emit_layer_netlists(
    m: &Manifest,
    ws: &WeightStore,
    layer: &str,
    mode: MapMode,
    segment: usize,
    outdir: &Path,
) -> Result<Vec<PathBuf>> {
    let found = m
        .layers
        .iter()
        .find(|l| l.name() == layer)
        .ok_or_else(|| anyhow!("layer '{layer}' not found"))?;
    match found {
        crate::nn::Layer::Bn { c, weight, .. } => {
            let fold = crate::mapper::bn_fold(ws, weight, *c)?;
            let (sub, scale) =
                crate::analog::build_bn_crossbars(layer, *c, 1, &fold.k, &fold.mean, &fold.beta, mode);
            let mut files = emit_crossbar_files(&sub, &m.device, segment, outdir)?;
            files.extend(emit_crossbar_files(&scale, &m.device, segment, outdir)?);
            Ok(files)
        }
        crate::nn::Layer::GaPool { c, h_in, w_in, .. } => {
            let cb = crate::analog::build_gap_crossbar(layer, *c, h_in * w_in, mode);
            emit_crossbar_files(&cb, &m.device, segment, outdir)
        }
        crate::nn::Layer::Residual { c, .. } => {
            let cb = crate::analog::build_residual_crossbar(layer, *c, mode);
            emit_crossbar_files(&cb, &m.device, segment, outdir)
        }
        _ => {
            let cb = build_fc_crossbar(m, ws, layer, mode)?;
            emit_crossbar_files(&cb, &m.device, segment, outdir)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::build_synthetic_fc;
    use crate::spice::solve::Ordering;

    fn test_device() -> DeviceJson {
        DeviceJson {
            r_on: 100.0,
            r_off: 16000.0,
            levels: 64,
            prog_sigma: 0.0,
            v_in: 2.5e-3,
            v_rail: 8.0,
            t_mem: 1e-10,
            slew_rate: 1e7,
            v_swing: 5.0,
            p_opamp: 1e-3,
            p_memristor: 1.1e-6,
            p_aux: 5e-4,
            t_opamp: 5e-7,
        }
    }

    #[test]
    fn resistance_mapping_in_device_range() {
        let r = device_resistance(1.0 / 63.0, 100.0);
        assert!(r > 100.0 && r < 16000.0, "min-level device {r}");
        assert_eq!(device_resistance(1.0, 100.0), 100.0);
    }

    #[test]
    fn segments_cover_all_columns() {
        let segs = plan_segments(100, 32);
        assert_eq!(segs.len(), 4);
        assert_eq!(segs[0].col_start, 0);
        assert_eq!(segs[3].col_end, 100);
        let total: usize = segs.iter().map(|s| s.col_end - s.col_start).sum();
        assert_eq!(total, 100);
        assert_eq!(plan_segments(10, 0).len(), 1);
        assert_eq!(plan_segments(10, 16).len(), 1);
    }

    #[test]
    fn emit_parse_roundtrip_counts() {
        let cb = build_synthetic_fc(8, 4, 64, MapMode::Inverted, 3);
        let seg = &plan_segments(4, 0)[0];
        let text = emit_crossbar(&cb, &test_device(), seg, None, 1);
        let circuit = parse(&text).unwrap();
        // element count: devices + per-used-row source + (RF + EOP) per col
        let n_r = text.lines().filter(|l| l.starts_with('R')).count();
        let n_v = text.lines().filter(|l| l.starts_with("Vin")).count();
        let n_e = text.lines().filter(|l| l.starts_with('E')).count();
        assert_eq!(circuit.elements.len(), n_r + n_v + n_e);
        assert_eq!(n_e, 4); // one TIA per column, inverted mode
    }

    #[test]
    fn dual_mode_emits_inverters() {
        let cb = build_synthetic_fc(8, 4, 64, MapMode::Dual, 3);
        let seg = &plan_segments(4, 0)[0];
        let text = emit_crossbar(&cb, &test_device(), seg, None, 1);
        let n_e = text.lines().filter(|l| l.starts_with('E')).count();
        assert_eq!(n_e, 8); // TIA + inverter per column
    }

    #[test]
    fn spice_solution_matches_ideal_eval() {
        // the SPICE-solved crossbar must match the behavioural model
        let cb = build_synthetic_fc(12, 5, 64, MapMode::Inverted, 17);
        let inputs: Vec<f64> = (0..12).map(|i| ((i as f64) * 0.7).sin() * 0.5).collect();
        let ideal = cb.eval_ideal(&inputs);
        let seg = &plan_segments(5, 0)[0];
        let text = emit_crossbar(&cb, &test_device(), seg, Some(&inputs), 1);
        let circuit = parse(&text).unwrap();
        let outs = solve_segment_outputs(&circuit, seg, true, Ordering::Smart).unwrap();
        for (c, (got, want)) in outs.iter().zip(&ideal).enumerate() {
            assert!((got - want).abs() < 1e-4, "col {c}: spice {got} vs ideal {want}");
        }
    }

    #[test]
    fn segmented_solution_equals_monolithic() {
        let cb = build_synthetic_fc(16, 8, 64, MapMode::Inverted, 23);
        let inputs: Vec<f64> = (0..16).map(|i| (i as f64 / 16.0) - 0.5).collect();
        let dev = test_device();
        // monolithic
        let mono_seg = &plan_segments(8, 0)[0];
        let mono = parse(&emit_crossbar(&cb, &dev, mono_seg, Some(&inputs), 1)).unwrap();
        let mono_out = solve_segment_outputs(&mono, mono_seg, true, Ordering::Smart).unwrap();
        // segmented (2 cols per file)
        let segs = plan_segments(8, 2);
        let mut seg_out = Vec::new();
        for seg in &segs {
            let c = parse(&emit_crossbar(&cb, &dev, seg, Some(&inputs), segs.len())).unwrap();
            seg_out.extend(solve_segment_outputs(&c, seg, true, Ordering::Smart).unwrap());
        }
        for (a, b) in mono_out.iter().zip(&seg_out) {
            assert!((a - b).abs() < 1e-9, "segmentation must not change results");
        }
    }

    #[test]
    fn dual_mode_spice_matches_ideal() {
        let cb = build_synthetic_fc(10, 3, 64, MapMode::Dual, 29);
        let inputs: Vec<f64> = (0..10).map(|i| (i as f64 * 0.3).cos() * 0.4).collect();
        let ideal = cb.eval_ideal(&inputs);
        let seg = &plan_segments(3, 0)[0];
        let text = emit_crossbar(&cb, &test_device(), seg, Some(&inputs), 1);
        let circuit = parse(&text).unwrap();
        let outs = solve_segment_outputs(&circuit, seg, false, Ordering::Smart).unwrap();
        for (c, (got, want)) in outs.iter().zip(&ideal).enumerate() {
            assert!((got - want).abs() < 1e-4, "col {c}: {got} vs {want}");
        }
    }

    #[test]
    fn crossbar_sim_matches_ideal_and_oneshot() {
        let cb = build_synthetic_fc(14, 6, 64, MapMode::Inverted, 31);
        let dev = test_device();
        let mut sim =
            CrossbarSim::new(&cb, &dev, 2, Ordering::Smart, SolverStrategy::Auto).unwrap();
        assert_eq!(sim.n_segments(), 3);
        for trial in 0..3 {
            let inputs: Vec<f64> =
                (0..14).map(|i| ((i + trial) as f64 * 0.53).sin() * 0.4).collect();
            let got = sim.solve_par(&inputs, 2).unwrap();
            let ideal = cb.eval_ideal(&inputs);
            for (c, (g, w)) in got.iter().zip(&ideal).enumerate() {
                assert!((g - w).abs() < 1e-4, "trial {trial} col {c}: {g} vs {w}");
            }
            // cached sim must agree with the one-shot emit+parse+solve path
            let seg = &plan_segments(6, 0)[0];
            let text = emit_crossbar(&cb, &dev, seg, Some(&inputs), 1);
            let oneshot =
                solve_segment_outputs(&parse(&text).unwrap(), seg, true, Ordering::Smart)
                    .unwrap();
            for (c, (g, w)) in got.iter().zip(&oneshot).enumerate() {
                assert!((g - w).abs() < 1e-9, "trial {trial} col {c}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn crossbar_sim_batch_matches_sequential() {
        let cb = build_synthetic_fc(10, 4, 64, MapMode::Dual, 12);
        let dev = test_device();
        let mut sim =
            CrossbarSim::new(&cb, &dev, 0, Ordering::Smart, SolverStrategy::Auto).unwrap();
        let batch: Vec<Vec<f64>> = (0..5)
            .map(|k| (0..10).map(|i| ((i * 2 + k) as f64 * 0.29).cos() * 0.3).collect())
            .collect();
        let batched = sim.solve_batch(&batch, 2).unwrap();
        assert_eq!(batched.len(), 5);
        for (k, iv) in batch.iter().enumerate() {
            let seq = sim.solve(iv).unwrap();
            for (a, b) in batched[k].iter().zip(&seq) {
                assert!((a - b).abs() < 1e-9, "batch {k}");
            }
        }
    }

    #[test]
    fn crossbar_sim_iterative_solver_matches_direct() {
        let cb = build_synthetic_fc(12, 5, 64, MapMode::Inverted, 44);
        let dev = test_device();
        let iterative = SolverStrategy::Iterative { restart: 16, tol: 1e-11, max_iter: 300 };
        let mut direct =
            CrossbarSim::new(&cb, &dev, 0, Ordering::Smart, SolverStrategy::Direct).unwrap();
        let mut gmres = CrossbarSim::new(&cb, &dev, 0, Ordering::Smart, iterative).unwrap();
        for trial in 0..3 {
            let inputs: Vec<f64> =
                (0..12).map(|i| ((i + trial) as f64 * 0.41).sin() * 0.4).collect();
            let want = direct.solve(&inputs).unwrap();
            let got = gmres.solve(&inputs).unwrap();
            for (c, (x, y)) in want.iter().zip(&got).enumerate() {
                assert!((x - y).abs() < 1e-6, "trial {trial} col {c}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn update_conductances_matches_rebuild() {
        // value-only drift through the cached sim must equal a from-scratch
        // emit+parse of the drifted crossbar
        let mut cb = build_synthetic_fc(10, 4, 64, MapMode::Inverted, 9);
        let dev = test_device();
        let mut sim =
            CrossbarSim::new(&cb, &dev, 2, Ordering::Smart, SolverStrategy::Auto).unwrap();
        let inputs: Vec<f64> = (0..10).map(|i| (i as f64 * 0.33).sin() * 0.4).collect();
        let pristine = sim.solve(&inputs).unwrap();
        let g_min = dev.r_on / dev.r_off;
        for d in cb.devices.iter_mut() {
            d.g_norm = (d.g_norm * 0.9).max(g_min);
        }
        let n = sim.update_conductances(&cb.devices, dev.r_on);
        assert_eq!(n, cb.devices.len(), "every placed device must be rewritten");
        let got = sim.solve(&inputs).unwrap();
        assert!(
            got.iter().zip(&pristine).any(|(a, b)| (a - b).abs() > 1e-9),
            "drift must move the outputs"
        );
        let mut fresh =
            CrossbarSim::new(&cb, &dev, 2, Ordering::Smart, SolverStrategy::Auto).unwrap();
        let want = fresh.solve(&inputs).unwrap();
        for (c, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-9, "col {c}: {a} vs {b}");
        }
    }

    #[test]
    fn solve_stats_reports_per_segment() {
        let cb = build_synthetic_fc(8, 4, 64, MapMode::Inverted, 5);
        let dev = test_device();
        let mut sim =
            CrossbarSim::new(&cb, &dev, 2, Ordering::Smart, SolverStrategy::Auto).unwrap();
        let inputs = vec![0.1; 8];
        let (out, stats) = sim.solve_stats(&inputs).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(stats.len(), sim.n_segments());
        let plain = sim.solve(&inputs).unwrap();
        for (a, b) in out.iter().zip(&plain) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn tran_read_settles_to_dc_outputs() {
        let cb = build_synthetic_fc(8, 3, 64, MapMode::Inverted, 7);
        let dev = test_device();
        let mut sim =
            CrossbarSim::new(&cb, &dev, 0, Ordering::Smart, SolverStrategy::Auto).unwrap();
        let inputs: Vec<f64> = (0..8).map(|i| (i as f64 * 0.37).sin() * 0.4).collect();
        let dc = sim.solve(&inputs).unwrap();
        let pulse = ReadPulse::default();
        let rd = sim.tran_read(&inputs, &pulse).unwrap();
        assert_eq!(rd.outputs.len(), 3);
        for (c, (got, want)) in rd.outputs.iter().zip(&dc).enumerate() {
            assert!(
                (got - want).abs() < 1e-3 + 1e-3 * want.abs(),
                "col {c}: tran {got} vs dc {want}"
            );
        }
        // the load RC is the dominant pole: 1% settling of a driven RC is
        // ~4.6 tau; allow slack for the input ramp and step granularity
        let tau = pulse.r_out * pulse.c_load;
        assert!(
            rd.settle_s > 0.5 * tau && rd.settle_s < 11.0 * tau,
            "settle {} vs tau {tau}",
            rd.settle_s
        );
        assert!(rd.energy_j > 0.0, "devices must dissipate during the read");
        assert_eq!(rd.stats.symbolic_analyses, 1, "one segment, one analysis");
        assert!(rd.stats.steps_accepted > 10);
        // the resident DC sim must be untouched by the transient twin
        let dc2 = sim.solve(&inputs).unwrap();
        for (a, b) in dc.iter().zip(&dc2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn tran_read_dual_mode_segmented() {
        let cb = build_synthetic_fc(6, 4, 64, MapMode::Dual, 3);
        let dev = test_device();
        let mut sim =
            CrossbarSim::new(&cb, &dev, 2, Ordering::Smart, SolverStrategy::Auto).unwrap();
        assert_eq!(sim.n_segments(), 2);
        let inputs: Vec<f64> = (0..6).map(|i| (i as f64 * 0.51).cos() * 0.3).collect();
        let dc = sim.solve(&inputs).unwrap();
        let rd = sim.tran_read(&inputs, &ReadPulse::default()).unwrap();
        assert_eq!(rd.outputs.len(), 4);
        for (c, (got, want)) in rd.outputs.iter().zip(&dc).enumerate() {
            assert!(
                (got - want).abs() < 1e-3 + 1e-3 * want.abs(),
                "col {c}: tran {got} vs dc {want}"
            );
        }
        assert_eq!(rd.stats.symbolic_analyses, 2, "one analysis per segment");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("Qx 1 2 3\n").is_err());
        assert!(parse("R1 a b\n").is_err());
        assert!(parse("V1 a b notanumber\n").is_err());
    }
}
