//! SPICE substrate — DC operating-point simulator for the generated
//! memristor netlists (the paper validates on SPICE; DESIGN.md §3 maps
//! their PSpice runs to this MNA engine).
//!
//! Supported elements (all the generated netlists need):
//!   R  resistor                      V  independent voltage source
//!   E  VCVS (op-amp = high-gain E)   I  independent current source
//!   D  diode (Shockley, solved by Newton-Raphson companion iteration)
//!
//! Node 0 is ground. The engine performs Modified Nodal Analysis: node
//! voltages plus branch currents for V and E elements; diodes are
//! linearized per Newton iteration until max voltage delta < tol.

pub mod solve;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use solve::{solve_dense, SparseSys};

/// Circuit element.
#[derive(Debug, Clone, PartialEq)]
pub enum Element {
    /// name, n+, n-, ohms
    Resistor(String, usize, usize, f64),
    /// name, n+, n-, volts
    Vsource(String, usize, usize, f64),
    /// name, n+, n-, amps (flows n+ -> n-)
    Isource(String, usize, usize, f64),
    /// name, out+, out-, ctrl+, ctrl-, gain
    Vcvs(String, usize, usize, usize, usize, f64),
    /// name, anode, cathode, saturation current, emission*Vt
    Diode(String, usize, usize, f64, f64),
    /// name, out (vs ground), ctrl_a, ctrl_b, gain: V(out) = gain*V(a)*V(b).
    /// Behavioural analog multiplier (Gilbert-cell abstraction, Fig 4b);
    /// nonlinear — solved by the same Newton loop as diodes.
    Mult(String, usize, usize, usize, f64),
}

impl Element {
    pub fn name(&self) -> &str {
        match self {
            Element::Resistor(n, ..)
            | Element::Vsource(n, ..)
            | Element::Isource(n, ..)
            | Element::Vcvs(n, ..)
            | Element::Diode(n, ..)
            | Element::Mult(n, ..) => n,
        }
    }
}

/// A flat circuit: elements over integer nodes (0 = ground).
#[derive(Debug, Clone, Default)]
pub struct Circuit {
    pub title: String,
    pub elements: Vec<Element>,
    next_node: usize,
    names: BTreeMap<String, usize>,
}

impl Circuit {
    pub fn new(title: &str) -> Self {
        let mut c = Circuit { title: title.to_string(), ..Default::default() };
        c.names.insert("0".into(), 0);
        c.names.insert("gnd".into(), 0);
        c.next_node = 1;
        c
    }

    /// Intern a named node.
    pub fn node(&mut self, name: &str) -> usize {
        if let Some(&n) = self.names.get(name) {
            return n;
        }
        let n = self.next_node;
        self.next_node += 1;
        self.names.insert(name.to_string(), n);
        n
    }

    /// Fresh anonymous node.
    pub fn fresh(&mut self) -> usize {
        let n = self.next_node;
        self.next_node += 1;
        self.names.insert(format!("_n{n}"), n);
        n
    }

    pub fn node_count(&self) -> usize {
        self.next_node
    }

    pub fn node_named(&self, name: &str) -> Option<usize> {
        self.names.get(name).copied()
    }

    pub fn resistor(&mut self, name: &str, a: usize, b: usize, ohms: f64) {
        self.elements.push(Element::Resistor(name.into(), a, b, ohms));
    }

    pub fn vsource(&mut self, name: &str, a: usize, b: usize, volts: f64) {
        self.elements.push(Element::Vsource(name.into(), a, b, volts));
    }

    pub fn isource(&mut self, name: &str, a: usize, b: usize, amps: f64) {
        self.elements.push(Element::Isource(name.into(), a, b, amps));
    }

    pub fn vcvs(&mut self, name: &str, op: usize, om: usize, cp: usize, cm: usize, gain: f64) {
        self.elements.push(Element::Vcvs(name.into(), op, om, cp, cm, gain));
    }

    pub fn mult(&mut self, name: &str, out: usize, a: usize, b: usize, gain: f64) {
        self.elements.push(Element::Mult(name.into(), out, a, b, gain));
    }

    pub fn diode(&mut self, name: &str, a: usize, k: usize) {
        // 1N4148-ish: Is = 2.52e-9 A, n*Vt = 1.752 * 25.85 mV
        self.elements.push(Element::Diode(name.into(), a, k, 2.52e-9, 1.752 * 0.02585));
    }

    /// Ideal op-amp as a VCVS with high open-loop gain (paper's ideal-TIA
    /// assumption). out is referenced to ground.
    pub fn opamp(&mut self, name: &str, vplus: usize, vminus: usize, out: usize) {
        self.vcvs(name, out, 0, vplus, vminus, 1e6);
    }

    /// Update the value of an existing V source (reprogramming crossbar
    /// inputs between solves without rebuilding the circuit).
    pub fn set_vsource(&mut self, name: &str, volts: f64) -> Result<()> {
        for e in self.elements.iter_mut() {
            if let Element::Vsource(n, _, _, v) = e {
                if n == name {
                    *v = volts;
                    return Ok(());
                }
            }
        }
        bail!("no vsource named '{name}'")
    }

    fn num_branches(&self) -> usize {
        self.elements
            .iter()
            .filter(|e| {
                matches!(e, Element::Vsource(..) | Element::Vcvs(..) | Element::Mult(..))
            })
            .count()
    }

    /// DC operating point. Returns node voltages (index = node id).
    pub fn dc_op(&self) -> Result<Vec<f64>> {
        self.dc_op_with(solve::Ordering::Smart)
    }

    /// DC operating point under an explicit elimination ordering (the Fig 7
    /// benchmarks contrast Natural vs Smart — see spice::solve docs).
    pub fn dc_op_with(&self, ordering: solve::Ordering) -> Result<Vec<f64>> {
        Ok(self.dc_op_stats(ordering)?.0)
    }

    /// DC operating point + solver work/memory counters (Fig 7 reads the
    /// peak resident matrix entries of monolithic vs segmented solves).
    pub fn dc_op_stats(
        &self,
        ordering: solve::Ordering,
    ) -> Result<(Vec<f64>, solve::SolveStats)> {
        let n_nodes = self.node_count();
        let n_br = self.num_branches();
        let dim = (n_nodes - 1) + n_br; // ground eliminated
        let has_diodes = self
            .elements
            .iter()
            .any(|e| matches!(e, Element::Diode(..) | Element::Mult(..)));

        let mut v_nodes = vec![0.0; n_nodes];
        let mut stats = solve::SolveStats { peak_entries: 0, unknowns: dim };
        let max_newton = if has_diodes { 200 } else { 1 };
        for _it in 0..max_newton {
            let sys = self.stamp(dim, n_nodes, &v_nodes)?;
            let x = if dim <= 220 {
                // dense path for small circuits (activation modules)
                let mut a = vec![vec![0.0; dim]; dim];
                for &(i, j, v) in sys.iter_triplets() {
                    a[i][j] += v;
                }
                stats = solve::SolveStats { peak_entries: dim * dim, unknowns: dim };
                solve_dense(&a, &sys.b).context("dense MNA solve")?
            } else {
                let (x, st) = sys.solve_with_stats(ordering).context("sparse MNA solve")?;
                stats = st;
                x
            };
            let mut new_v = vec![0.0; n_nodes];
            new_v[1..].copy_from_slice(&x[..n_nodes - 1]);
            // damped Newton update for diode convergence
            let mut delta = 0.0f64;
            for i in 0..n_nodes {
                delta = delta.max((new_v[i] - v_nodes[i]).abs());
            }
            if has_diodes {
                for i in 0..n_nodes {
                    let step = new_v[i] - v_nodes[i];
                    v_nodes[i] += step.clamp(-0.5, 0.5); // limit junction jumps
                }
            } else {
                v_nodes = new_v;
            }
            if delta < 1e-9 || !has_diodes {
                return Ok((v_nodes, stats));
            }
        }
        Ok((v_nodes, stats)) // damped iterations exhausted; callers check outputs
    }

    /// Build the MNA system around the current diode linearization point.
    fn stamp(&self, dim: usize, n_nodes: usize, v_prev: &[f64]) -> Result<SparseSys> {
        let mut sys = SparseSys::new(dim);
        // node index helper: ground (0) is dropped
        let idx = |node: usize| -> Option<usize> { (node > 0).then(|| node - 1) };
        let mut br = n_nodes - 1; // branch current unknowns follow nodes

        for e in &self.elements {
            match *e {
                Element::Resistor(ref name, a, b, r) => {
                    if r <= 0.0 {
                        bail!("resistor {name} has non-positive value {r}");
                    }
                    let g = 1.0 / r;
                    if let Some(i) = idx(a) {
                        sys.add(i, i, g);
                    }
                    if let Some(j) = idx(b) {
                        sys.add(j, j, g);
                    }
                    if let (Some(i), Some(j)) = (idx(a), idx(b)) {
                        sys.add(i, j, -g);
                        sys.add(j, i, -g);
                    }
                }
                Element::Isource(_, a, b, amps) => {
                    if let Some(i) = idx(a) {
                        sys.add_b(i, -amps);
                    }
                    if let Some(j) = idx(b) {
                        sys.add_b(j, amps);
                    }
                }
                Element::Vsource(_, a, b, volts) => {
                    if let Some(i) = idx(a) {
                        sys.add(i, br, 1.0);
                        sys.add(br, i, 1.0);
                    }
                    if let Some(j) = idx(b) {
                        sys.add(j, br, -1.0);
                        sys.add(br, j, -1.0);
                    }
                    sys.add_b(br, volts);
                    br += 1;
                }
                Element::Vcvs(_, op, om, cp, cm, gain) => {
                    // v(op) - v(om) = gain * (v(cp) - v(cm))
                    if let Some(i) = idx(op) {
                        sys.add(i, br, 1.0);
                        sys.add(br, i, 1.0);
                    }
                    if let Some(j) = idx(om) {
                        sys.add(j, br, -1.0);
                        sys.add(br, j, -1.0);
                    }
                    if let Some(i) = idx(cp) {
                        sys.add(br, i, -gain);
                    }
                    if let Some(j) = idx(cm) {
                        sys.add(br, j, gain);
                    }
                    br += 1;
                }
                Element::Mult(_, out, ca, cb2, gain) => {
                    // Newton linearization of V(out) = g*Va*Vb around
                    // (Va0, Vb0):  V(out) - g*Vb0*Va - g*Va0*Vb = -g*Va0*Vb0
                    let va0 = v_prev[ca];
                    let vb0 = v_prev[cb2];
                    if let Some(i) = idx(out) {
                        sys.add(i, br, 1.0);
                        sys.add(br, i, 1.0);
                    }
                    if let Some(i) = idx(ca) {
                        sys.add(br, i, -gain * vb0);
                    }
                    if let Some(j) = idx(cb2) {
                        sys.add(br, j, -gain * va0);
                    }
                    sys.add_b(br, -gain * va0 * vb0);
                    br += 1;
                }
                Element::Diode(_, a, k, isat, nvt) => {
                    // Newton companion: G_eq = dI/dV at v0, I_eq = I(v0) - G_eq*v0
                    let v0 = (v_prev[a] - v_prev[k]).clamp(-5.0, 0.9);
                    let ex = (v0 / nvt).exp();
                    let g_eq = (isat / nvt * ex).max(1e-12);
                    let i_eq = isat * (ex - 1.0) - g_eq * v0;
                    if let Some(i) = idx(a) {
                        sys.add(i, i, g_eq);
                        sys.add_b(i, -i_eq);
                    }
                    if let Some(j) = idx(k) {
                        sys.add(j, j, g_eq);
                        sys.add_b(j, i_eq);
                    }
                    if let (Some(i), Some(j)) = (idx(a), idx(k)) {
                        sys.add(i, j, -g_eq);
                        sys.add(j, i, -g_eq);
                    }
                }
            }
        }
        Ok(sys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn voltage_divider() {
        let mut c = Circuit::new("divider");
        let vin = c.node("in");
        let mid = c.node("mid");
        c.vsource("V1", vin, 0, 10.0);
        c.resistor("R1", vin, mid, 1000.0);
        c.resistor("R2", mid, 0, 1000.0);
        let v = c.dc_op().unwrap();
        assert!((v[mid] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn current_source_into_resistor() {
        let mut c = Circuit::new("ir");
        let n = c.node("n");
        c.isource("I1", 0, n, 1e-3); // 1 mA into n
        c.resistor("R1", n, 0, 2000.0);
        let v = c.dc_op().unwrap();
        assert!((v[n] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn inverting_tia() {
        // TIA: 1 V through 1k into virtual ground, Rf = 1k -> out = -1 V
        let mut c = Circuit::new("tia");
        let vin = c.node("in");
        let vminus = c.node("vm");
        let out = c.node("out");
        c.vsource("V1", vin, 0, 1.0);
        c.resistor("Rin", vin, vminus, 1000.0);
        c.resistor("Rf", vminus, out, 1000.0);
        c.opamp("X1", 0, vminus, out);
        let v = c.dc_op().unwrap();
        assert!((v[out] + 1.0).abs() < 1e-4, "out {}", v[out]);
        assert!(v[vminus].abs() < 1e-4, "virtual ground {}", v[vminus]);
    }

    #[test]
    fn summing_tia_two_inputs() {
        // two input branches into one virtual ground: out = -(v1*g1 + v2*g2)*Rf
        let mut c = Circuit::new("sum");
        let v1 = c.node("v1");
        let v2 = c.node("v2");
        let vm = c.node("vm");
        let out = c.node("out");
        c.vsource("V1", v1, 0, 0.5);
        c.vsource("V2", v2, 0, -0.25);
        c.resistor("R1", v1, vm, 1000.0);
        c.resistor("R2", v2, vm, 500.0);
        c.resistor("Rf", vm, out, 1000.0);
        c.opamp("X1", 0, vm, out);
        let v = c.dc_op().unwrap();
        let expect = -(0.5 / 1000.0 - 0.25 / 500.0) * 1000.0; // = 0.0
        assert!((v[out] - expect).abs() < 1e-4, "out {}", v[out]);
    }

    #[test]
    fn diode_forward_drop() {
        let mut c = Circuit::new("d");
        let vin = c.node("in");
        let mid = c.node("mid");
        c.vsource("V1", vin, 0, 5.0);
        c.resistor("R1", vin, mid, 1000.0);
        c.diode("D1", mid, 0);
        let v = c.dc_op().unwrap();
        assert!(v[mid] > 0.4 && v[mid] < 0.85, "diode drop {}", v[mid]);
    }

    #[test]
    fn diode_reverse_blocks() {
        let mut c = Circuit::new("dr");
        let vin = c.node("in");
        let mid = c.node("mid");
        c.vsource("V1", vin, 0, -5.0);
        c.resistor("R1", vin, mid, 1000.0);
        c.diode("D1", mid, 0);
        let v = c.dc_op().unwrap();
        assert!((v[mid] + 5.0).abs() < 0.01, "reverse diode should block: {}", v[mid]);
    }

    #[test]
    fn set_vsource_updates() {
        let mut c = Circuit::new("sv");
        let vin = c.node("in");
        c.vsource("V1", vin, 0, 1.0);
        c.resistor("R1", vin, 0, 100.0);
        assert!((c.dc_op().unwrap()[vin] - 1.0).abs() < 1e-12);
        c.set_vsource("V1", 3.0).unwrap();
        assert!((c.dc_op().unwrap()[vin] - 3.0).abs() < 1e-12);
        assert!(c.set_vsource("nope", 0.0).is_err());
    }

    #[test]
    fn negative_resistor_rejected() {
        let mut c = Circuit::new("bad");
        let n = c.node("n");
        c.vsource("V1", n, 0, 1.0);
        c.resistor("R1", n, 0, -5.0);
        assert!(c.dc_op().is_err());
    }

    #[test]
    fn larger_sparse_path() {
        // >220 unknowns forces the sparse backend: chain of dividers
        let mut c = Circuit::new("chain");
        let mut prev = c.node("in");
        c.vsource("V1", prev, 0, 1.0);
        for i in 0..300 {
            let nxt = c.node(&format!("n{i}"));
            c.resistor(&format!("Ra{i}"), prev, nxt, 100.0);
            c.resistor(&format!("Rb{i}"), nxt, 0, 1e6);
            prev = nxt;
        }
        let v = c.dc_op().unwrap();
        // RC-less transmission line: voltage decays monotonically along the
        // ladder and stays strictly positive
        let first = c.node_named("n0").unwrap();
        let mid = c.node_named("n150").unwrap();
        let last = c.node_named("n299").unwrap();
        assert!(v[first] > v[mid] && v[mid] > v[last], "non-monotone ladder");
        assert!(v[last] > 0.0 && v[first] < 1.0, "ladder end {}", v[last]);
    }
}
