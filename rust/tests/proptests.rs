//! Property-based tests (util::prop mini-harness; proptest is not in the
//! offline crate cache) over the coordinator invariants, the layout
//! formulas, the solver and the JSON codec.

use memx::analog;
use memx::coordinator::batcher::plan_batch;
use memx::fault::{self, FaultConfig, FaultModel};
use memx::mapper::layout::{
    out_dim, p_neg, p_pos, place_conv_kernel, place_fc, ConvXbarGeom, FcXbarGeom, Placed,
};
use memx::mapper::{self, BnFold, MapMode, BN_EPS};
use memx::netlist::plan_segments;
use memx::pipeline::{
    default_device, synthetic_stack_crossbars, AnalogModule, BatchNormModule, Fidelity,
    ModuleCfg, PipelineBuilder,
};
use memx::spice::factor;
use memx::spice::krylov::{gmres, Ilu0, KrylovCfg, SolverStrategy};
use memx::spice::solve::{solve_dense, Ordering, SparseSys};
use memx::util::json::Json;
use memx::util::prng::Rng;
use memx::util::prop::check;

#[test]
fn prop_eq1_consistent_with_placement_bounds() {
    check(
        "eq1-bounds",
        200,
        |rng: &mut Rng, size: usize| {
            let w = 3 + rng.below(4 + size * 2);
            let k = 1 + rng.below(w.min(5));
            let p = rng.below(k); // padding < kernel
            let s = 1 + rng.below(2);
            (w, k, p, s)
        },
        |&(w, k, p, s)| {
            let o = out_dim(w, k, p, s);
            // last window must fit in the padded input
            (o - 1) * s + k <= w + 2 * p && o >= 1
        },
    );
}

#[test]
fn prop_eq23_rows_disjoint_regions() {
    check(
        "eq2-eq3-regions",
        100,
        |rng: &mut Rng, size: usize| {
            let w = 3 + rng.below(3 + size);
            let k = 1 + rng.below(w.min(4));
            let s = 1 + rng.below(2);
            (w, k, s, rng.next_u64())
        },
        |&(w, k, s, _)| {
            let g = ConvXbarGeom::from_conv(w, w, k, s, 0);
            let region = g.wr * g.wc;
            (0..g.cols()).all(|i| {
                let pp = p_pos(i, g.oc, g.wc, s);
                let pn = p_neg(i, g.oc, g.wr, g.wc, s);
                pp < region && pn >= region && pn == pp + region
            })
        },
    );
}

#[test]
fn prop_placement_device_count_equals_nonzeros_times_outputs() {
    check(
        "placement-count",
        100,
        |rng: &mut Rng, size: usize| {
            let w = 4 + rng.below(3 + size);
            let k = 1 + rng.below(3);
            let kernel: Vec<f64> = (0..k * k)
                .map(|_| {
                    if rng.f64() < 0.3 {
                        0.0
                    } else {
                        rng.range_f64(-1.0, 1.0)
                    }
                })
                .collect();
            (w, k, kernel)
        },
        |(w, k, kernel)| {
            let g = ConvXbarGeom::from_conv(*w, *w, *k, 1, 0);
            let placed = place_conv_kernel(&g, kernel, true);
            let nnz = kernel.iter().filter(|&&v| v != 0.0).count();
            placed.len() == nnz * g.cols()
        },
    );
}

#[test]
fn prop_fc_eval_is_linear() {
    // crossbar transfer must be linear below the rails: f(a+b) = f(a)+f(b)
    check(
        "fc-linearity",
        60,
        |rng: &mut Rng, size: usize| {
            let cin = 2 + rng.below(4 + size);
            let cout = 1 + rng.below(3 + size / 2);
            (cin, cout, rng.next_u64())
        },
        |&(cin, cout, seed)| {
            let cb = mapper::build_synthetic_fc(cin, cout, 64, MapMode::Inverted, seed);
            let mut rng = Rng::new(seed ^ 0xabc);
            let a: Vec<f64> = (0..cin).map(|_| rng.range_f64(-0.3, 0.3)).collect();
            let b: Vec<f64> = (0..cin).map(|_| rng.range_f64(-0.3, 0.3)).collect();
            let ab: Vec<f64> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
            let fa = cb.eval_ideal(&a);
            let fb = cb.eval_ideal(&b);
            let fab = cb.eval_ideal(&ab);
            fab.iter()
                .zip(fa.iter().zip(&fb))
                .all(|(s, (x, y))| (s - (x + y)).abs() < 1e-9)
        },
    );
}

#[test]
fn prop_quantize_error_bounded() {
    check(
        "quantize-bound",
        200,
        |rng: &mut Rng, _| (rng.range_f64(0.0, 1.0), 2 + rng.below(255)),
        |&(x, levels)| {
            let q = mapper::quantize_unit(x, levels);
            (q - x).abs() <= 0.5 / (levels - 1) as f64 + 1e-12 && (0.0..=1.0).contains(&q)
        },
    );
}

#[test]
fn prop_fc_dual_inverted_same_function() {
    check(
        "dual-inverted-equal",
        40,
        |rng: &mut Rng, size: usize| (2 + rng.below(4 + size), 1 + rng.below(4), rng.next_u64()),
        |&(cin, cout, seed)| {
            let a = mapper::build_synthetic_fc(cin, cout, 64, MapMode::Inverted, seed);
            let b = mapper::build_synthetic_fc(cin, cout, 64, MapMode::Dual, seed);
            let mut rng = Rng::new(seed);
            let v: Vec<f64> = (0..cin).map(|_| rng.range_f64(-0.5, 0.5)).collect();
            a.eval_ideal(&v)
                .iter()
                .zip(b.eval_ideal(&v))
                .all(|(x, y)| (x - y).abs() < 1e-12)
        },
    );
}

#[test]
fn prop_fc_placement_one_side() {
    check(
        "fc-one-side",
        60,
        |rng: &mut Rng, size: usize| {
            let cin = 1 + rng.below(5 + size);
            let cout = 1 + rng.below(4);
            let w: Vec<f64> = (0..cin * cout).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            (cin, cout, w)
        },
        |(cin, cout, w)| {
            let g = FcXbarGeom { cin: *cin, cout: *cout };
            let placed = place_fc(&g, w, None, true);
            // at most one device per (row mod cin, col)
            let mut seen = std::collections::HashSet::new();
            placed.iter().all(|p| p.row < g.rows() - 2 && seen.insert((p.row % cin, p.col)))
        },
    );
}

#[test]
fn prop_segments_partition_columns() {
    check(
        "segments-partition",
        100,
        |rng: &mut Rng, size: usize| (1 + rng.below(50 * size), rng.below(70)),
        |&(cols, seg)| {
            let segs = plan_segments(cols, seg);
            let mut covered = 0;
            let mut prev_end = 0;
            for s in &segs {
                if s.col_start != prev_end {
                    return false;
                }
                covered += s.col_end - s.col_start;
                prev_end = s.col_end;
            }
            covered == cols && prev_end == cols
        },
    );
}

#[test]
fn prop_batcher_never_exceeds_queue_or_sizes() {
    check(
        "batcher-sound",
        150,
        |rng: &mut Rng, _| {
            let avail = vec![1usize, 8, 32];
            (avail, rng.below(100), rng.bool())
        },
        |(avail, queued, waited)| match plan_batch(avail, *queued, *waited) {
            None => *queued == 0 || (!waited && *queued < 32),
            Some(p) => {
                avail.contains(&p.size) && p.real <= p.size && p.real <= *queued && p.real > 0
            }
        },
    );
}

#[test]
fn prop_sparse_solver_residual_small() {
    check(
        "sparse-residual",
        40,
        |rng: &mut Rng, size: usize| {
            let n = 3 + rng.below(5 + size * 4);
            let mut sys = SparseSys::new(n);
            for i in 0..n {
                for _ in 0..3 {
                    sys.add(i, rng.below(n), rng.range_f64(-1.0, 1.0));
                }
                sys.add(i, i, 4.0 + rng.f64());
                sys.add_b(i, rng.range_f64(-2.0, 2.0));
            }
            sys
        },
        |sys| match sys.solve() {
            // loose absolute bound: random ill-scaled systems accumulate
            // ~1e-6 residuals in f64; a *wrong* solve shows O(1) residuals,
            // which is what this property guards against
            Ok(x) => sys.residual(&x) < 1e-4,
            Err(_) => false,
        },
    );
}

/// Random MNA-like system generator shared by the factored-solver
/// properties: diagonally-dominant resistive core, optional zero-diagonal
/// pairs (forcing off-diagonal pivoting) and op-amp-structured 1e6-gain
/// branch rows (the real TIA stamp pattern: unit branch couplings plus a
/// high-gain control entry). Returns (dense mirror, system, n_opamps).
fn gen_mna_like(rng: &mut Rng, size: usize) -> (Vec<Vec<f64>>, SparseSys, usize) {
    let n0 = 4 + rng.below(4 + size * 2);
    let opamps = rng.below(3);
    let n = n0 + 2 * opamps; // out node + branch row per op-amp
    let mut dense = vec![vec![0.0; n]; n];
    let mut sys = SparseSys::new(n);
    let mut add = |d: &mut Vec<Vec<f64>>, s: &mut SparseSys, i: usize, j: usize, v: f64| {
        d[i][j] += v;
        s.add(i, j, v);
    };
    // zero-diagonal swap pairs on a prefix of even indices
    let pairs = rng.below(n0 / 2 + 1).min(2);
    for k in 0..pairs {
        let (i, j) = (2 * k, 2 * k + 1);
        add(&mut dense, &mut sys, i, j, 3.0 + rng.f64());
        add(&mut dense, &mut sys, j, i, 3.0 + rng.f64());
    }
    for i in 2 * pairs..n0 {
        for _ in 0..3 {
            let j = rng.below(n0);
            add(&mut dense, &mut sys, i, j, rng.range_f64(-1.0, 1.0));
        }
        add(&mut dense, &mut sys, i, i, 5.0 + rng.f64());
    }
    // op-amp branch rows: V(out) = -1e6 * V(ctrl), TIA-style feedback
    for k in 0..opamps {
        let out = n0 + 2 * k;
        let br = n0 + 2 * k + 1;
        let ctrl = rng.below(n0);
        add(&mut dense, &mut sys, out, br, 1.0);
        add(&mut dense, &mut sys, br, out, 1.0);
        add(&mut dense, &mut sys, br, ctrl, -1e6);
        add(&mut dense, &mut sys, out, out, 1e-3);
        add(&mut dense, &mut sys, out, ctrl, -1e-3);
    }
    for i in 0..n {
        sys.add_b(i, rng.range_f64(-2.0, 2.0));
    }
    (dense, sys, opamps)
}

/// Scaled residual of x for `sys` (same acceptance shape the engine uses).
fn scaled_residual(sys: &SparseSys, x: &[f64]) -> f64 {
    let mut r = sys.b.clone();
    let mut scale = 1.0f64;
    for &bv in &sys.b {
        scale = scale.max(bv.abs());
    }
    for &(i, j, v) in sys.iter_triplets() {
        let t = v * x[j];
        r[i] -= t;
        scale = scale.max(t.abs());
    }
    r.iter().fold(0.0f64, |a, &v| a.max(v.abs())) / scale
}

#[test]
fn prop_factored_solutions_match_dense() {
    check(
        "factored-vs-dense",
        60,
        |rng: &mut Rng, size: usize| {
            let (dense, sys, opamps) = gen_mna_like(rng, size);
            (dense, sys, opamps, rng.bool())
        },
        |(dense, sys, opamps, smart)| {
            let ord = if *smart { Ordering::Smart } else { Ordering::Natural };
            let Ok(xd) = solve_dense(dense, &sys.b) else {
                // singular draws must fail on the factored path too
                return factor::factor_solve(sys, ord).is_err()
                    || scaled_residual(sys, &factor::factor_solve(sys, ord).unwrap().0)
                        < 1e-6;
            };
            let Ok((xs, _)) = factor::factor_solve(sys, ord) else { return false };
            // 1e6-gain systems are ill-conditioned: any backward-stable
            // solver drifts from dense by ~cond*eps, so the hard criterion
            // is the scaled residual (a wrong solve shows O(1) residuals);
            // solution agreement gets conditioning-aware headroom
            let sol_tol = if *opamps > 0 { 1e-4 } else { 1e-6 };
            scaled_residual(sys, &xs) < 1e-6
                && xd
                    .iter()
                    .zip(&xs)
                    .all(|(d, s)| (d - s).abs() < sol_tol * (1.0 + d.abs()))
        },
    );
}

#[test]
fn prop_refactor_matches_fresh_analysis() {
    // same topology, rescaled values: refactor (fixed pattern) must agree
    // with a from-scratch analysis at the new values
    check(
        "refactor-vs-fresh",
        40,
        |rng: &mut Rng, size: usize| {
            let (_, sys, _) = gen_mna_like(rng, size);
            (sys, 0.25 + rng.f64() * 4.0)
        },
        |(sys, scale)| {
            let Ok((_, mut num)) = factor::factor_solve(sys, Ordering::Smart) else {
                return true; // singular draw — nothing to compare
            };
            let mut sys2 = SparseSys::new(sys.n);
            for &(i, j, v) in sys.iter_triplets() {
                sys2.add(i, j, v * scale);
            }
            for (i, &bv) in sys.b.iter().enumerate() {
                sys2.add_b(i, bv);
            }
            let refactored = match num.assemble(&sys2) {
                Ok(false) => {
                    if num.refactor().is_err() {
                        return true; // stale pivots — caller would re-analyze
                    }
                    num.solve(&sys2.b)
                }
                Ok(true) => num.solve(&sys2.b),
                Err(_) => return false, // identical stream must match
            };
            let Ok(xr) = refactored else { return false };
            let Ok((xf, _)) = factor::factor_solve(&sys2, Ordering::Smart) else {
                return false;
            };
            xr.iter()
                .zip(&xf)
                .all(|(a, b)| (a - b).abs() < 1e-9 * (1.0 + a.abs()))
                && scaled_residual(&sys2, &xr) < 1e-6
        },
    );
}

#[test]
fn prop_gmres_ilu0_matches_factored() {
    // GMRES + ILU(0) must agree with the direct factor engine on random
    // MNA-like systems — including the zero-diagonal swap pairs (the PR 1
    // pivot cases) and 1e6-gain op-amp branch rows gen_mna_like draws
    check(
        "gmres-ilu0-vs-factored",
        60,
        |rng: &mut Rng, size: usize| gen_mna_like(rng, size),
        |(_, sys, opamps)| {
            let direct = factor::factor_solve(sys, Ordering::Smart);
            let mut pre = match Ilu0::analyze(sys) {
                Ok(p) => p,
                // structurally singular: the direct path must agree
                Err(_) => return direct.is_err(),
            };
            if pre.assemble(sys).is_err() || pre.factor().is_err() {
                return true; // numeric ILU breakdown — the engine falls back
            }
            // tol 1e-9: the attainable true residual on 1e6-gain draws
            // stagnates near eps*cond ~ 1e-10; the hard correctness
            // criterion below is the scaled residual
            let cfg = KrylovCfg { restart: 24, tol: 1e-9, max_iter: 3000 };
            match gmres(sys, &sys.b, &pre, &cfg) {
                Ok((x, st)) => st.iterations > 0 && scaled_residual(sys, &x) < 1e-6,
                // well-conditioned draws must converge; 1e6-gain draws may
                // legitimately stall (the residual-gated engine falls back
                // to direct in that case), as may singular ones
                Err(_) => *opamps > 0 || direct.is_err(),
            }
        },
    );
}

#[test]
fn prop_gmres_cached_lu_warm_matches_direct() {
    // complete LU of *stale* values as preconditioner: after a value-only
    // rescale, warm GMRES must match a fresh factorization of the new
    // values without ever refactoring the old one
    check(
        "gmres-warm-cached-lu",
        40,
        |rng: &mut Rng, size: usize| {
            let (_, sys, opamps) = gen_mna_like(rng, size);
            // per-entry drift (±2%) — a uniform rescale would be the
            // trivially-preconditioned scale*I case
            let mut sys2 = SparseSys::new(sys.n);
            for &(i, j, v) in sys.iter_triplets() {
                sys2.add(i, j, v * (1.0 + rng.range_f64(-0.02, 0.02)));
            }
            for (i, &bv) in sys.b.iter().enumerate() {
                sys2.add_b(i, bv);
            }
            (sys, sys2, opamps)
        },
        |(sys, sys2, opamps)| {
            let Ok((_, num)) = factor::factor_solve(sys, Ordering::Smart) else {
                return true; // singular draw — nothing to warm-start
            };
            let cfg = KrylovCfg { restart: 24, tol: 1e-9, max_iter: 3000 };
            let Ok((xw, st)) = gmres(sys2, &sys2.b, &num, &cfg) else {
                // drifting 2% of a 1e6-gain entry can push a draw toward
                // singularity; benign draws must warm-converge
                return *opamps > 0;
            };
            let Ok((xf, _)) = factor::factor_solve(sys2, Ordering::Smart) else {
                return scaled_residual(sys2, &xw) < 1e-6;
            };
            // same convention as prop_factored_solutions_match_dense: the
            // hard criterion is the scaled residual; solution agreement
            // gets conditioning-aware headroom (forward error of a
            // residual-tol stop grows with cond, ~1e6 on op-amp draws)
            let sol_tol = if *opamps > 0 { 1e-2 } else { 1e-4 };
            st.iterations > 0
                && scaled_residual(sys2, &xw) < 1e-6
                && xw
                    .iter()
                    .zip(&xf)
                    .all(|(a, b)| (a - b).abs() < sol_tol * (1.0 + b.abs()))
        },
    );
}

#[test]
fn prop_gmres_convergence_failure_is_clean_error() {
    // exhausting max_iter must surface as Err, never a panic or a silently
    // wrong answer
    check(
        "gmres-max-iter-clean-error",
        30,
        |rng: &mut Rng, size: usize| gen_mna_like(rng, size),
        |(_, sys, _)| {
            let Ok(mut pre) = Ilu0::analyze(sys) else { return true };
            if pre.assemble(sys).is_err() || pre.factor().is_err() {
                return true;
            }
            let cfg = KrylovCfg { restart: 2, tol: 1e-308, max_iter: 1 };
            match gmres(sys, &sys.b, &pre, &cfg) {
                // an unreachable tolerance must be reported as failure...
                Err(e) => e.to_string().contains("failed to converge"),
                // ...unless the rhs is tiny enough to satisfy it outright
                Ok((x, _)) => scaled_residual(sys, &x) < 1e-6,
            }
        },
    );
}

#[test]
fn prop_iterative_crossbar_circuits_match_reference() {
    // whole circuits under SolverStrategy::Iterative vs the per-call
    // reference engine, across wire-resistance extremes (1e-2..1e5 ohms)
    check(
        "iterative-crossbar-vs-reference",
        12,
        |rng: &mut Rng, size: usize| {
            let inputs = 4 + rng.below(4 + size);
            let cols = 2 + rng.below(2 + size / 2);
            let r_exp = rng.range_f64(-2.0, 5.0);
            (inputs, cols, 10f64.powf(r_exp), rng.next_u64())
        },
        |&(inputs, cols, r_base, seed)| {
            let mut c = memx::spice::synthetic_crossbar_circuit(inputs, cols, r_base, seed);
            c.set_solver(SolverStrategy::Iterative {
                restart: 16,
                tol: 1e-11,
                max_iter: 600,
            });
            let Ok(xi) = c.dc_op() else { return false };
            let Ok((xr, _)) = c.dc_op_stats_reference(Ordering::Smart) else {
                return false;
            };
            let scale = xr.iter().fold(1.0f64, |a, v| a.max(v.abs()));
            xi.iter().zip(&xr).all(|(a, b)| (a - b).abs() < 1e-6 * scale)
        },
    );
}

#[test]
fn prop_sweep_cache_equivalence() {
    // cached ActCircuit sweeps (factor-once/solve-many) match cold solves
    // (fresh circuit per point) within 1e-9 — the acceptance criterion of
    // the factored engine on the nonlinear activation circuits
    check(
        "sweep-cache-equivalence",
        6,
        |rng: &mut Rng, _| (rng.range_f64(-5.0, -2.0), rng.range_f64(2.0, 5.0), rng.bool()),
        |&(lo, hi, swish)| {
            let mut warm =
                if swish { analog::build_hard_swish() } else { analog::build_hard_sigmoid() };
            let Ok(curve) = warm.sweep(lo, hi, 9) else { return false };
            curve.iter().all(|&(x, y)| {
                let mut cold = if swish {
                    analog::build_hard_swish()
                } else {
                    analog::build_hard_sigmoid()
                };
                cold.eval(x).map(|yc| (y - yc).abs() < 1e-9).unwrap_or(false)
            })
        },
    );
}

#[test]
fn prop_bn_spice_netlists_match_affine_fold() {
    // the §3.3 netlist pair (subtraction crossbar + scale/offset pairs,
    // solved through the resident CrossbarSims) vs the exact affine fold
    // over random gamma/beta/mean/var draws — including negative scales
    // and near-zero variances — within 1e-4
    check(
        "bn-spice-affine-fold",
        8,
        |rng: &mut Rng, size: usize| {
            let c = 1 + rng.below(3 + size.min(3));
            let spatial = 1 + rng.below(3);
            let gamma: Vec<f64> = (0..c).map(|_| rng.range_f64(-1.5, 1.5)).collect();
            let beta: Vec<f64> = (0..c).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let mean: Vec<f64> = (0..c).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let var: Vec<f64> = (0..c)
                .map(|_| {
                    if rng.below(4) == 0 {
                        rng.range_f64(0.0, 1e-4) // near-zero variance draw
                    } else {
                        rng.range_f64(0.05, 2.0)
                    }
                })
                .collect();
            (c, spatial, gamma, beta, mean, var, rng.next_u64())
        },
        |(c, spatial, gamma, beta, mean, var, seed)| {
            let dev = default_device();
            let cfg = ModuleCfg {
                dev: &dev,
                fidelity: Fidelity::Spice,
                segment: 3,
                ordering: Ordering::Smart,
                solver: SolverStrategy::Auto,
                backend: memx::backend::BackendChoice::Auto,
                workers: 1,
                prog_sigma: 0.0,
            };
            let mut rng = Rng::new(seed ^ 0xB17);
            let Ok(mut bn) = BatchNormModule::new(
                "p.bn",
                *c,
                *spatial,
                BnFold::from_stats(gamma, beta, mean, var),
                MapMode::Inverted,
                &cfg,
                &mut rng,
            ) else {
                return false;
            };
            let x: Vec<f64> = (0..c * spatial).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let Ok(got) = bn.forward(&x) else { return false };
            (0..*c).all(|ch| {
                let k = gamma[ch] / (var[ch] + BN_EPS).sqrt();
                (0..*spatial).all(|s| {
                    let want = (x[ch * spatial + s] - mean[ch]) * k + beta[ch];
                    (got[ch * spatial + s] - want).abs() < 1e-4 * (1.0 + want.abs())
                })
            })
        },
    );
}

/// Random small FC-stack dims (first entry = input dim) plus a layer seed.
fn gen_stack_dims(rng: &mut Rng, size: usize) -> (Vec<usize>, u64) {
    let n_layers = 2 + rng.below(2); // 2-3 crossbars
    let mut dims = vec![2 + rng.below(4 + size)];
    for _ in 0..n_layers {
        dims.push(1 + rng.below(4 + size));
    }
    (dims, rng.next_u64())
}

#[test]
fn prop_pipeline_ideal_matches_eval_ideal_chain() {
    // a Fidelity::Ideal pipeline is EXACTLY the fold of Crossbar::eval_ideal
    // over its layers — bit-for-bit, no tolerance
    check("pipeline-ideal-exact", 25, gen_stack_dims, |(dims, seed)| {
        let dev = default_device();
        let mut p = PipelineBuilder::new()
            .fidelity(Fidelity::Ideal)
            .build_fc_stack(dims, &dev, *seed)
            .unwrap();
        let cbs = synthetic_stack_crossbars(dims, dev.levels, MapMode::Inverted, *seed);
        let mut rng = Rng::new(seed ^ 0x9A);
        let x: Vec<f64> = (0..dims[0]).map(|_| rng.range_f64(-0.5, 0.5)).collect();
        let got = p.forward(&x).unwrap();
        let mut want = x;
        for cb in &cbs {
            want = cb.eval_ideal(&want);
        }
        got == want
    });
}

#[test]
fn prop_pipeline_spice_matches_ideal_within_tolerance() {
    // the Spice-fidelity pipeline (resident CrossbarSim per layer, batched
    // multi-RHS reads) stays within the op-amp finite-gain tolerance of the
    // ideal chain on random small FC stacks
    check(
        "pipeline-spice-tol",
        6,
        |rng: &mut Rng, _| {
            let dims = vec![2 + rng.below(5), 1 + rng.below(4), 1 + rng.below(3)];
            (dims, rng.next_u64())
        },
        |(dims, seed)| {
            let dev = default_device();
            let base = PipelineBuilder::new().segment(2).workers(2);
            let mut spice = base
                .clone()
                .fidelity(Fidelity::Spice)
                .build_fc_stack(dims, &dev, *seed)
                .unwrap();
            let mut ideal = base
                .fidelity(Fidelity::Ideal)
                .build_fc_stack(dims, &dev, *seed)
                .unwrap();
            let mut rng = Rng::new(seed ^ 0x5C);
            let batch: Vec<Vec<f64>> = (0..2)
                .map(|_| (0..dims[0]).map(|_| rng.range_f64(-0.5, 0.5)).collect())
                .collect();
            let got = spice.forward_batch(&batch).unwrap();
            let want = ideal.forward_batch(&batch).unwrap();
            got.iter().zip(&want).all(|(g_row, w_row)| {
                g_row
                    .iter()
                    .zip(w_row)
                    .all(|(g, w)| (g - w).abs() < 1e-3 * (1.0 + w.abs()))
            })
        },
    );
}

/// Deterministic random unit chain (FC crossbar stages interleaved with
/// batch-norm stages and GAP averaging columns, some units closed by
/// residual adders) — the "random stage graph" the pipelined scheduler is
/// checked on. Returns the pipeline and its input dim.
fn build_random_unit_pipeline(
    seed: u64,
    n_units: usize,
    fidelity: Fidelity,
) -> (memx::pipeline::Pipeline, usize) {
    use memx::pipeline::{GapModule, Pipeline, Stage};

    let dev = default_device();
    let builder = PipelineBuilder::new().fidelity(fidelity);
    let cfg = ModuleCfg {
        dev: &dev,
        fidelity,
        segment: 4,
        ordering: Ordering::Smart,
        solver: SolverStrategy::Auto,
        backend: memx::backend::BackendChoice::Auto,
        workers: 1,
        prog_sigma: 0.0,
    };
    let mut rng = Rng::new(seed);
    let mut dim = 2 + rng.below(6);
    let in_dim = dim;
    let mut stages: Vec<Stage> = Vec::new();
    for u in 0..n_units {
        let unit = format!("u{u}");
        // residual units keep their dim so the skip adds elementwise
        let residual = rng.bool();
        let n_mods = 1 + rng.below(2);
        for m in 0..n_mods {
            match rng.below(4) {
                // batch-norm stage: dim-preserving random affine fold
                0 => {
                    let gamma: Vec<f64> =
                        (0..dim).map(|_| rng.range_f64(-1.5, 1.5)).collect();
                    let beta: Vec<f64> = (0..dim).map(|_| rng.range_f64(-0.5, 0.5)).collect();
                    let mean: Vec<f64> = (0..dim).map(|_| rng.range_f64(-0.5, 0.5)).collect();
                    let var: Vec<f64> = (0..dim).map(|_| rng.range_f64(0.05, 2.0)).collect();
                    let module = BatchNormModule::new(
                        format!("{unit}.bn{m}"),
                        dim,
                        1,
                        BnFold::from_stats(&gamma, &beta, &mean, &var),
                        MapMode::Inverted,
                        &cfg,
                        &mut rng,
                    )
                    .unwrap();
                    stages
                        .push(Stage::Module { unit: unit.clone(), module: Box::new(module) });
                }
                // averaging column: bridge crossbar into c*2, then GAP back
                // to c (dim changes, so only inside residual-free units)
                1 if !residual => {
                    let c = 1 + rng.below(3);
                    let cb = mapper::build_synthetic_fc(
                        dim,
                        c * 2,
                        dev.levels,
                        MapMode::Inverted,
                        seed ^ (u as u64 * 977 + m as u64 * 131 + 19),
                    );
                    let module = builder.crossbar_module(cb, &dev).unwrap();
                    stages
                        .push(Stage::Module { unit: unit.clone(), module: Box::new(module) });
                    let gap = GapModule::new(
                        format!("{unit}.gap{m}"),
                        c,
                        2,
                        1,
                        MapMode::Inverted,
                        &cfg,
                        &mut rng,
                    )
                    .unwrap();
                    stages.push(Stage::Module { unit: unit.clone(), module: Box::new(gap) });
                    dim = c;
                }
                // FC crossbar stage (the original generator arm)
                _ => {
                    let dout = if residual { dim } else { 1 + rng.below(6) };
                    let cb = mapper::build_synthetic_fc(
                        dim,
                        dout,
                        dev.levels,
                        MapMode::Inverted,
                        seed ^ (u as u64 * 977 + m as u64 * 131 + 7),
                    );
                    let module = builder.crossbar_module(cb, &dev).unwrap();
                    stages
                        .push(Stage::Module { unit: unit.clone(), module: Box::new(module) });
                    dim = dout;
                }
            }
        }
        if residual {
            stages.push(Stage::Residual {
                name: format!("{unit}.add"),
                unit: unit.clone(),
                dim,
                channels: dim,
            });
        }
    }
    (Pipeline::from_stages(stages, fidelity).unwrap(), in_dim)
}

#[test]
fn prop_pipelined_scheduler_matches_sequential() {
    // the §5.2 overlapped schedule must be bit-identical to the sequential
    // unit walk on random stage graphs, for any worker count / micro-batch
    check(
        "pipelined-scheduler-exact",
        20,
        |rng: &mut Rng, size: usize| {
            (
                rng.next_u64(),
                1 + rng.below(3 + size.min(3)), // units
                1 + rng.below(4),               // workers
                rng.below(4),                   // micro-batch (0 = auto)
            )
        },
        |&(seed, n_units, workers, micro)| {
            let (mut p, in_dim) =
                build_random_unit_pipeline(seed, n_units, Fidelity::Behavioural);
            let mut rng = Rng::new(seed ^ 0xF00D);
            let batch: Vec<Vec<f64>> = (0..5 + rng.below(4))
                .map(|_| (0..in_dim).map(|_| rng.range_f64(-0.6, 0.6)).collect())
                .collect();
            let want = p.forward_batch(&batch).unwrap();
            let got = p.forward_batch_pipelined(&batch, workers, micro).unwrap();
            got == want
        },
    );
}

#[test]
fn prop_pipeline_forward_batch_equals_forward() {
    // regression: forward_batch(&[x]) == forward(x), and batching commutes
    // with per-item evaluation on the behavioural path
    check("pipeline-batch-single", 20, gen_stack_dims, |(dims, seed)| {
        let dev = default_device();
        let mut p = PipelineBuilder::new()
            .fidelity(Fidelity::Behavioural)
            .build_fc_stack(dims, &dev, *seed)
            .unwrap();
        let mut rng = Rng::new(seed ^ 0x33);
        let batch: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..dims[0]).map(|_| rng.range_f64(-0.5, 0.5)).collect())
            .collect();
        let batched = p.forward_batch(&batch).unwrap();
        batch
            .iter()
            .zip(&batched)
            .all(|(x, row)| p.forward(x).unwrap() == *row)
    });
}

#[test]
fn prop_json_roundtrip() {
    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool()),
            2 => Json::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.below(8);
                Json::Str((0..n).map(|_| char::from(32 + rng.below(94) as u8)).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(
        "json-roundtrip",
        120,
        |rng: &mut Rng, size: usize| gen_json(rng, (size / 6).min(3)),
        |v| Json::parse(&v.to_string()).map(|p| p == *v).unwrap_or(false),
    );
}

#[test]
fn prop_prog_noise_stays_in_signed_window() {
    // quantized signed weights stay in [-1, 1] under write noise, exact
    // zeros stay zero (no device is placed for them), and nothing goes NaN
    // for any noise amplitude
    check(
        "prog-noise-window",
        120,
        |rng: &mut Rng, size: usize| {
            let n = 1 + rng.below(8 + 4 * size);
            let q: Vec<f64> = (0..n)
                .map(|_| if rng.f64() < 0.2 { 0.0 } else { rng.range_f64(-1.0, 1.0) })
                .collect();
            (q, rng.range_f64(0.0, 0.6), rng.next_u64())
        },
        |(q, sigma, seed)| {
            let mut noisy = q.clone();
            mapper::apply_prog_noise(&mut noisy, *sigma, &mut Rng::new(*seed));
            q.iter().zip(&noisy).all(|(&b, &a)| {
                a.is_finite() && (-1.0..=1.0).contains(&a) && (b != 0.0 || a == 0.0)
            })
        },
    );
}

#[test]
fn prop_prog_noise_analog_respects_conductance_window() {
    // analog writes never leave (0, max(g0, 1)]: never NaN, never negative
    // or zero, never above the device's own programmed ceiling (bias
    // devices legitimately sit above g_norm = 1)
    check(
        "prog-noise-analog-window",
        120,
        |rng: &mut Rng, size: usize| {
            let n = 1 + rng.below(8 + 4 * size);
            let g: Vec<f64> = (0..n).map(|_| rng.range_f64(1e-4, 1.4)).collect();
            (g, rng.range_f64(0.0, 0.8), rng.next_u64())
        },
        |(g, sigma, seed)| {
            let mut devices: Vec<Placed> = g
                .iter()
                .enumerate()
                .map(|(i, &g0)| Placed { row: i, col: 0, g_norm: g0 })
                .collect();
            mapper::apply_prog_noise_analog(&mut devices, *sigma, &mut Rng::new(*seed));
            devices.iter().zip(g).all(|(d, &g0)| {
                d.g_norm.is_finite() && d.g_norm > 0.0 && d.g_norm <= g0.max(1.0)
            })
        },
    );
}

#[test]
fn prop_fault_engine_keeps_devices_in_window() {
    // any drift/read-disturb/stuck-at history followed by a recalibration
    // write keeps every conductance finite, positive, and at or below the
    // device's programmed ceiling — the [g_off, g_on] window contract
    check(
        "fault-window",
        100,
        |rng: &mut Rng, size: usize| {
            let n = 1 + rng.below(10 + 4 * size);
            let g: Vec<f64> = (0..n).map(|_| rng.range_f64(1e-3, 1.3)).collect();
            let cfg = FaultConfig {
                drift_nu: rng.range_f64(0.0, 0.5),
                nu_sigma: rng.range_f64(0.0, 1.5),
                nu_g: rng.range_f64(0.0, 2.0),
                t0_hours: rng.range_f64(0.1, 10.0),
                read_disturb_rate: rng.range_f64(0.0, 0.1),
                temp_c: rng.range_f64(-20.0, 120.0),
                stuck_on_frac: rng.range_f64(0.0, 0.2),
                stuck_off_frac: rng.range_f64(0.0, 0.2),
                seed: rng.next_u64(),
                ..FaultConfig::default()
            };
            let hours: Vec<f64> =
                (0..1 + rng.below(4)).map(|_| rng.range_f64(0.0, 5_000.0)).collect();
            (g, cfg, hours, rng.next_u64())
        },
        |(g, cfg, hours, bank)| {
            let g_min = 1e-3;
            let mut devices: Vec<Placed> = g
                .iter()
                .enumerate()
                .map(|(i, &g0)| Placed { row: i, col: 0, g_norm: g0 })
                .collect();
            let mut model = FaultModel::new(*cfg);
            for &h in hours {
                let step = model.advance(h, (h * 1e4) as u64);
                let md = step.mean_decay();
                if !(md > 0.0 && md <= 1.0) {
                    return false;
                }
                // pristine-anchored ν(g): the conductance-dependent
                // exponent must keep the same window contract
                let ratio =
                    fault::apply_step_from(&step, *bank, &mut devices, Some(g.as_slice()), g_min);
                if !(ratio.is_finite() && ratio > 0.0) {
                    return false;
                }
            }
            fault::reprogram_noise(&mut devices, 0.1, cfg.seed, *bank, 2);
            devices.iter().zip(g).all(|(d, &g0)| {
                d.g_norm.is_finite() && d.g_norm > 0.0 && d.g_norm <= g0.max(1.0)
            })
        },
    );
}

#[test]
fn prop_fault_step_signed_never_flips_sign_or_escapes() {
    // behavioural (signed-kernel) drift: magnitudes only shrink or saturate,
    // stuck-OFF zeroes, and no weight ever changes sign or leaves [-1, 1]
    check(
        "fault-signed-window",
        100,
        |rng: &mut Rng, size: usize| {
            let n = 1 + rng.below(10 + 4 * size);
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let cfg = FaultConfig {
                drift_nu: rng.range_f64(0.0, 0.4),
                nu_sigma: rng.range_f64(0.0, 1.0),
                nu_g: rng.range_f64(0.0, 2.0),
                stuck_on_frac: rng.range_f64(0.0, 0.3),
                stuck_off_frac: rng.range_f64(0.0, 0.3),
                seed: rng.next_u64(),
                ..FaultConfig::default()
            };
            (w, cfg, rng.range_f64(0.0, 20_000.0), rng.next_u64())
        },
        |(w, cfg, hours, bank)| {
            let mut drifted = w.clone();
            let step = FaultModel::new(*cfg).advance(*hours, 100_000);
            fault::apply_step_signed_from(&step, *bank, &mut drifted, Some(w.as_slice()));
            w.iter()
                .zip(&drifted)
                .all(|(&b, &a)| a.is_finite() && (-1.0..=1.0).contains(&a) && a * b >= 0.0)
        },
    );
}

#[test]
fn prop_be_halving_timestep_shrinks_rc_error() {
    // backward Euler is order 1: halving the fixed step must cut the
    // sup-norm error of an RC charging curve vs V(1 − e^{−t/τ}) by close
    // to half — 0.75 leaves slack for the h² correction terms
    check(
        "be-halving-rc",
        25,
        |rng: &mut Rng, _size: usize| {
            (
                rng.range_f64(100.0, 10_000.0),  // R
                rng.range_f64(1e-9, 1e-6),       // C
                rng.range_f64(0.5, 5.0),         // step amplitude
                rng.range_f64(0.02, 0.2),        // h / tau
            )
        },
        |&(r, cap, v, h_over_tau)| {
            let tau = r * cap;
            let err = |h: f64| -> f64 {
                let mut ckt = memx::spice::Circuit::new("rc");
                let vin = ckt.node("in");
                let n1 = ckt.node("n1");
                ckt.vsource_wave(
                    "V1",
                    vin,
                    0,
                    memx::spice::transient::Waveform::Pulse {
                        v1: 0.0,
                        v2: v,
                        delay: 0.0,
                        rise: 0.0,
                        fall: 0.0,
                        width: 1e9,
                        period: 0.0,
                    },
                );
                ckt.resistor("R1", vin, n1, r);
                ckt.capacitor("C1", n1, 0, cap);
                let cfg = memx::spice::transient::TranConfig::fixed_step(2.0 * tau, h)
                    .with_integrator(memx::spice::transient::Integrator::BackwardEuler);
                let res = ckt.tran(&cfg).unwrap();
                let mut e = 0.0f64;
                for (k, &t) in res.times.iter().enumerate() {
                    let exact = v * (1.0 - (-t / tau).exp());
                    e = e.max((res.voltages[0][k][n1] - exact).abs() / v);
                }
                e
            };
            let coarse = err(h_over_tau * tau);
            let fine = err(0.5 * h_over_tau * tau);
            fine > 0.0 && fine < 0.75 * coarse
        },
    );
}

#[test]
fn prop_prng_shuffle_preserves_multiset() {
    check(
        "shuffle-multiset",
        60,
        |rng: &mut Rng, size: usize| {
            let n = 1 + rng.below(10 * size);
            let v: Vec<u32> = (0..n).map(|_| rng.below(16) as u32).collect();
            (v, rng.next_u64())
        },
        |(v, seed)| {
            let mut shuffled = v.clone();
            Rng::new(*seed).shuffle(&mut shuffled);
            let mut a = v.clone();
            let mut b = shuffled;
            a.sort();
            b.sort();
            a == b
        },
    );
}
