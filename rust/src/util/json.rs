//! Minimal JSON codec (parser + writer).
//!
//! serde is not present in this image's offline crate cache (DESIGN.md §4
//! S17), and the manifest format is small and stable, so we carry our own
//! rfc8259-subset implementation: objects, arrays, strings (with escapes),
//! f64 numbers, booleans, null. Numbers are stored as f64 — adequate for the
//! manifest (largest integers are tensor offsets « 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("json type error: expected {expected} at {path}")]
    Type { expected: &'static str, path: String },
    #[error("json missing key: {0}")]
    Missing(String),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(JsonError::Parse(p.i, "trailing data".into()));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?.as_str().ok_or(JsonError::Type { expected: "string", path: key.into() })
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?.as_f64().ok_or(JsonError::Type { expected: "number", path: key.into() })
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?.as_arr().ok_or(JsonError::Type { expected: "array", path: key.into() })
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError::Parse(self.i, msg.to_string()))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err("bad literal")
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| JsonError::Parse(self.i, "bad utf8".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::Parse(self.i, "bad hex".into()))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one utf-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| JsonError::Parse(self.i, "bad utf8".into()))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError::Parse(start, format!("bad number: {e}")))
    }
}

// -- writer ------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn rejects_unterminated() {
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("[1,2").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"s":"he\"llo","t":true,"n":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_print_without_dot() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn req_missing_errors() {
        let v = Json::parse("{}").unwrap();
        assert!(v.req("nope").is_err());
    }
}
