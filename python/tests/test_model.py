"""L2 model tests: analog/digital equivalence at ideal device settings,
activation circuit models, BN module, manifest consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import device as dv
from compile import model as M
from compile.kernels import ref as kref

WIDTH = 0.25  # small width keeps these tests fast


@pytest.fixture(scope="module")
def params():
    return M.init_params(3, WIDTH)


@pytest.fixture(scope="module")
def imgs():
    x, _ = D.make_dataset(4, seed=99)
    return jnp.asarray(x)


IDEAL = dv.DeviceParams(levels=1_000_000, prog_sigma=0.0, v_rail=1e9)


class TestEquivalence:
    def test_ideal_analog_matches_digital(self, params, imgs):
        dig = M.forward(params, imgs, M.Ctx(), width=WIDTH)
        ana_p = M.convert_params_analog(params, IDEAL)
        ana = M.forward(params, imgs, M.Ctx(analog=ana_p, dev=IDEAL,
                                            use_kernel=False), width=WIDTH)
        np.testing.assert_allclose(np.asarray(dig), np.asarray(ana),
                                   rtol=1e-3, atol=1e-3)

    def test_kernel_path_matches_ref_path(self, params, imgs):
        ana_p = M.convert_params_analog(params, dv.DEFAULT_DEVICE)
        a = M.forward(params, imgs, M.Ctx(analog=ana_p, use_kernel=True), width=WIDTH)
        b = M.forward(params, imgs, M.Ctx(analog=ana_p, use_kernel=False), width=WIDTH)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    def test_quantization_degrades_gracefully(self, params, imgs):
        """64-level quantization + 1% noise must stay close to fp32 logits on
        the *logit* scale (the paper's <1%-accuracy-drop regime)."""
        dig = np.asarray(M.forward(params, imgs, M.Ctx(), width=WIDTH))
        ana_p = M.convert_params_analog(params, dv.DEFAULT_DEVICE)
        ana = np.asarray(M.forward(params, imgs, M.Ctx(analog=ana_p), width=WIDTH))
        spread = np.std(dig)
        assert np.max(np.abs(dig - ana)) < 5 * spread + 0.5

    def test_analog_deterministic(self, params, imgs):
        ana_p = M.convert_params_analog(params, dv.DEFAULT_DEVICE, seed=7)
        a = M.forward(params, imgs, M.Ctx(analog=ana_p), width=WIDTH)
        b = M.forward(params, imgs, M.Ctx(analog=ana_p), width=WIDTH)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestShapes:
    def test_logits_shape(self, params, imgs):
        out = M.forward(params, imgs, M.Ctx(), width=WIDTH)
        assert out.shape == (4, M.NUM_CLASSES)

    def test_batch_one(self, params, imgs):
        out = M.forward(params, imgs[:1], M.Ctx(), width=WIDTH)
        assert out.shape == (1, M.NUM_CLASSES)

    def test_param_count_positive(self, params):
        assert M.count_params(params) > 50_000

    def test_widths_produce_different_sizes(self):
        p1 = M.init_params(0, 0.25)
        p2 = M.init_params(0, 0.5)
        assert M.count_params(p2) > M.count_params(p1)


class TestActivationCircuits:
    """Fig 4: analog circuits vs software functions."""

    def test_hard_sigmoid_linear_region(self):
        x = jnp.linspace(-2.9, 2.9, 59)
        np.testing.assert_allclose(
            np.asarray(kref.analog_hard_sigmoid_ref(x)),
            np.asarray(kref.hard_sigmoid_ref(x)), rtol=1e-6, atol=1e-6)

    def test_hard_sigmoid_saturation(self):
        x = jnp.array([-10.0, -3.0, 3.0, 10.0])
        out = np.asarray(kref.analog_hard_sigmoid_ref(x))
        np.testing.assert_allclose(out, [0.0, 0.0, 1.0, 1.0], atol=1e-6)

    def test_hard_swish_matches_software_within_rails(self):
        x = jnp.linspace(-7.9, 7.9, 159)
        np.testing.assert_allclose(
            np.asarray(kref.analog_hard_swish_ref(x)),
            np.asarray(kref.hard_swish_ref(x)), rtol=1e-5, atol=1e-6)

    def test_hard_swish_rail_clamp(self):
        out = np.asarray(kref.analog_hard_swish_ref(jnp.array([100.0]), v_rail=8.0))
        assert out[0] == 8.0

    def test_relu_negative_region(self):
        x = jnp.linspace(-5, -0.1, 20)
        assert np.all(np.asarray(kref.analog_relu_ref(x)) == 0.0)


class TestBatchNorm:
    def test_analog_bn_matches_digital_at_ideal(self, params):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 1, (2, 8, 8, params["stem.conv.w"].shape[-1]))
                        .astype(np.float32))
        ana_p = M.convert_params_analog(params, IDEAL)
        dig = M.batch_norm(M.Ctx(), "stem.bn", x, params)
        ana = M.batch_norm(M.Ctx(analog=ana_p, dev=IDEAL), "stem.bn", x, params)
        np.testing.assert_allclose(np.asarray(dig), np.asarray(ana),
                                   rtol=1e-4, atol=1e-4)

    def test_train_mode_uses_batch_stats(self, params, imgs):
        stats: dict = {}
        M.forward(params, imgs, M.Ctx(), width=WIDTH, train=True, stats_out=stats)
        assert "stem.bn" in stats
        m, v = stats["stem.bn"]
        assert m.shape == (params["stem.conv.w"].shape[-1],)
        assert np.all(np.asarray(v) >= 0)


class TestConvForms:
    def test_digital_conv_equals_im2col_form(self, params):
        """The native XLA conv (digital fast path) and the crossbar im2col
        dataflow must agree — this pins the Eq 1-3 placement semantics."""
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(0, 1, (2, 9, 9, 3)).astype(np.float32))
        w = jnp.asarray(rng.normal(0, 0.5, (3, 3, 3, 5)).astype(np.float32))
        native = M.conv2d(M.Ctx(), "w", x, w, stride=2, padding=1)
        pats = M._patches(x, 3, 2, 1)
        b, ho, wo, feat = pats.shape
        manual = (pats.reshape(b * ho * wo, feat) @ M._w_matrix(w)).reshape(b, ho, wo, -1)
        np.testing.assert_allclose(np.asarray(native), np.asarray(manual),
                                   rtol=1e-4, atol=1e-4)

    def test_depthwise_digital_vs_manual(self):
        rng = np.random.default_rng(6)
        c = 4
        x = jnp.asarray(rng.normal(0, 1, (2, 8, 8, c)).astype(np.float32))
        w = jnp.asarray(rng.normal(0, 0.5, (3, 3, 1, c)).astype(np.float32))
        out = M.depthwise_conv2d(M.Ctx(), "w", x, w, stride=1, padding=1)
        # brute-force per channel
        for ch in range(c):
            ref = M.conv2d(M.Ctx(), "w", x[..., ch:ch + 1],
                           w[:, :, :, ch:ch + 1], stride=1, padding=1)
            np.testing.assert_allclose(np.asarray(out[..., ch]),
                                       np.asarray(ref[..., 0]),
                                       rtol=1e-4, atol=1e-4)


class TestManifest:
    def test_manifest_covers_all_weights(self, params):
        man = M.build_manifest(params, width=WIDTH)
        weight_keys = {l.get("weight") for l in man["layers"] if "weight" in l}
        for k in params:
            if k.endswith(".conv.w") or k.endswith(".dw.w"):
                assert k in weight_keys, f"{k} missing from manifest"

    def test_manifest_geometry_consistent(self, params):
        """Eq 1: O = (W - F + 2P)/S + 1 holds for every conv entry."""
        man = M.build_manifest(params, width=WIDTH)
        for l in man["layers"]:
            if l["layer"] in ("conv", "dwconv"):
                for d in ("h", "w"):
                    o = (l[f"{d}_in"] - l["k"] + 2 * l["padding"]) // l["stride"] + 1
                    assert o == l[f"{d}_out"], l["name"]

    def test_manifest_chain_shapes(self, params):
        """Spatial dims flow 32 -> 4 through the three downsamples."""
        man = M.build_manifest(params, width=WIDTH)
        convs = [l for l in man["layers"] if l["layer"] in ("conv", "dwconv")]
        assert convs[0]["h_in"] == 32
        assert convs[-1]["h_out"] == 4

    def test_manifest_units_match_table4_structure(self, params):
        man = M.build_manifest(params, width=WIDTH)
        units = {l["unit"] for l in man["layers"]}
        assert "input" in units and "classifier" in units
        assert sum(1 for u in units if u.startswith("bottleneck")) == 11
