//! Linear solvers for the MNA system.
//!
//! * [`solve_dense`] — dense LU with partial pivoting (activation circuits,
//!   unit tests; n <= a few hundred).
//! * [`SparseSys`] — sparse Gaussian elimination over hash-map rows with a
//!   column->rows index, in two elimination orderings:
//!
//!   - [`Ordering::Natural`]: node-number order with diagonal-preference
//!     pivoting — the classic textbook/early-SPICE behaviour. On monolithic
//!     crossbar matrices this floods the virtual-ground rows with fill-in
//!     and goes superlinear in the column count, which is exactly the
//!     simulation-time explosion the paper's Fig 7 reports for PSpice and
//!     attacks with netlist segmentation.
//!   - [`Ordering::Smart`]: Markowitz-lite (ascending initial column count)
//!     with sparsest-pivot-row preference — our optimized mode; crossbar
//!     systems eliminate input nodes through their single-entry V-source
//!     branch rows with zero fill and solve near-linearly.
//!
//! Fig 7 benches run both (see benches/bench_segmentation.rs); the engine
//! defaults to Smart everywhere else.
//!
//! This module is the **reference implementation**: simple, per-call,
//! allocation-heavy, used for correctness cross-checks and the Fig 7
//! ordering study. The hot path ([`crate::spice::Circuit::dc_op`] and
//! friends) runs on the factor-once / solve-many engine in
//! [`crate::spice::factor`], which caches the symbolic factorization per
//! circuit topology and re-solves in O(nnz(L+U)); its results are
//! residual-guarded against this reference within 1e-9.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Dense LU with partial pivoting. O(n^3); fine for n <= ~512.
pub fn solve_dense(a: &[Vec<f64>], b: &[f64]) -> Result<Vec<f64>> {
    let n = b.len();
    if a.len() != n || a.iter().any(|r| r.len() != n) {
        bail!("dense solve: non-square system");
    }
    let mut m: Vec<Vec<f64>> = a.to_vec();
    let mut x = b.to_vec();
    for k in 0..n {
        let (p, pv) = (k..n)
            .map(|i| (i, m[i][k].abs()))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        if pv < 1e-300 {
            bail!("dense solve: singular at column {k}");
        }
        m.swap(k, p);
        x.swap(k, p);
        for i in k + 1..n {
            let f = m[i][k] / m[k][k];
            if f == 0.0 {
                continue;
            }
            for j in k..n {
                m[i][j] -= f * m[k][j];
            }
            x[i] -= f * x[k];
        }
    }
    for k in (0..n).rev() {
        let mut s = x[k];
        for j in k + 1..n {
            s -= m[k][j] * x[j];
        }
        x[k] = s / m[k][k];
    }
    Ok(x)
}

/// Elimination ordering (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ordering {
    Natural,
    Smart,
}

/// Work/memory counters from one sparse solve.
///
/// Direct solves report only the first two fields; the iterative engine
/// ([`crate::spice::krylov`]) additionally fills the Krylov counters so
/// benches and the `BENCH_spice.json` schema can contrast the paths.
#[derive(Debug, Clone, Copy)]
pub struct SolveStats {
    /// resident matrix entries: elimination peak (original + fill +
    /// multipliers) for direct solves, preconditioner slots + Krylov basis
    /// for iterative ones
    pub peak_entries: usize,
    pub unknowns: usize,
    /// GMRES inner iterations (0 = direct solve)
    pub iterations: usize,
    /// final relative residual of an iterative solve (0.0 for direct)
    pub residual: f64,
    /// a warm iterative solve reused a cached preconditioner (complete-LU
    /// or ILU pattern) without any fresh analysis/refactorization
    pub precond_reused: bool,
    /// which [`crate::backend`] kernel set ran the dense batch math
    pub backend: &'static str,
    /// nanoseconds inside triangular substitution sweeps for this solve
    /// (0 when the path predates the backend extraction, e.g. the
    /// reference eliminator)
    pub subst_ns: u64,
    /// nanoseconds inside GMRES matrix-vector products for this solve
    pub matvec_ns: u64,
}

impl SolveStats {
    /// Counters of a direct (non-Krylov) solve.
    pub fn direct(peak_entries: usize, unknowns: usize) -> SolveStats {
        SolveStats {
            peak_entries,
            unknowns,
            iterations: 0,
            residual: 0.0,
            precond_reused: false,
            backend: "scalar",
            subst_ns: 0,
            matvec_ns: 0,
        }
    }
}

/// Does `pattern` equal the (i, j) triplet stream of `sys` (same stamp
/// order, same topology)? Shared by the factor and krylov engines'
/// cache-validity checks.
pub(crate) fn pattern_matches(pattern: &[(u32, u32)], sys: &SparseSys) -> bool {
    if sys.nnz() != pattern.len() {
        return false;
    }
    pattern
        .iter()
        .zip(sys.iter_triplets())
        .all(|(&(pi, pj), &(i, j, _))| pi as usize == i && pj as usize == j)
}

/// Sparse linear system `A x = b` assembled from triplets.
#[derive(Debug, Clone, Default)]
pub struct SparseSys {
    pub n: usize,
    triplets: Vec<(usize, usize, f64)>,
    pub b: Vec<f64>,
}

impl SparseSys {
    pub fn new(n: usize) -> Self {
        Self { n, triplets: Vec::new(), b: vec![0.0; n] }
    }

    pub fn add(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.n && j < self.n);
        if v != 0.0 {
            self.triplets.push((i, j, v));
        }
    }

    /// Structural add: records the entry even when the value is currently
    /// zero. Stamps whose *coefficients* vary across Newton iterations
    /// (e.g. multiplier linearizations around a zero operating point) use
    /// this so the sparsity pattern — and any cached symbolic
    /// factorization keyed on it — stays stable across iterations.
    pub fn add_keep(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.n && j < self.n);
        self.triplets.push((i, j, v));
    }

    pub fn add_b(&mut self, i: usize, v: f64) {
        self.b[i] += v;
    }

    pub fn nnz(&self) -> usize {
        self.triplets.len()
    }

    /// Raw (possibly duplicated) triplets — used by the dense fallback path.
    pub fn iter_triplets(&self) -> impl Iterator<Item = &(usize, usize, f64)> {
        self.triplets.iter()
    }

    pub fn solve(&self) -> Result<Vec<f64>> {
        self.solve_with(Ordering::Smart)
    }

    pub fn solve_with(&self, ord: Ordering) -> Result<Vec<f64>> {
        Ok(self.solve_with_stats(ord)?.0)
    }

    /// Sparse Gaussian elimination. Returns x with ||Ax-b|| small for
    /// well-conditioned MNA systems (high-gain op-amps are ~1e6 so partial
    /// magnitude checks guard the pivots), plus work/memory counters
    /// (peak resident matrix entries incl. fill-in; elimination flops) —
    /// the Fig 7 memory-footprint comparison reads these.
    pub fn solve_with_stats(&self, ord: Ordering) -> Result<(Vec<f64>, SolveStats)> {
        let n = self.n;
        // assemble hash rows + column index
        let mut rows: Vec<HashMap<usize, f64>> = vec![HashMap::new(); n];
        for &(i, j, v) in &self.triplets {
            *rows[i].entry(j).or_insert(0.0) += v;
        }
        for r in rows.iter_mut() {
            r.retain(|_, v| *v != 0.0);
        }
        // assembled (deduplicated) nonzeros — the honest pre-elimination
        // footprint; raw triplet counts contain duplicate stamps and would
        // inflate the monolithic-vs-segmented memory comparison
        let assembled_nnz: usize = rows.iter().map(|r| r.len()).sum();
        let mut col_rows: Vec<Vec<usize>> = vec![Vec::new(); n]; // may hold stale ids
        for (i, r) in rows.iter().enumerate() {
            for &j in r.keys() {
                col_rows[j].push(i);
            }
        }
        let mut b = self.b.clone();
        let mut used = vec![false; n];

        let col_order: Vec<usize> = match ord {
            Ordering::Natural => (0..n).collect(),
            Ordering::Smart => {
                let mut order: Vec<usize> = (0..n).collect();
                let counts: Vec<usize> = (0..n).map(|j| col_rows[j].len()).collect();
                order.sort_by_key(|&j| counts[j]);
                order
            }
        };

        // (col, pivot row) in elimination order
        let mut pivots: Vec<(usize, usize)> = Vec::with_capacity(n);
        for &col in &col_order {
            // prune stale ids, pick pivot
            let mut best: Option<(usize, f64, usize)> = None; // (row, |v|, nnz)
            let mut live: Vec<usize> = Vec::with_capacity(col_rows[col].len());
            for &r in &col_rows[col] {
                if used[r] {
                    continue;
                }
                let Some(&v) = rows[r].get(&col) else { continue };
                if v == 0.0 {
                    continue;
                }
                live.push(r);
                let av = v.abs();
                let nz = rows[r].len();
                let better = match (ord, best) {
                    (_, None) => true,
                    // Natural: classic partial pivoting — max |v| in the
                    // column, no sparsity awareness (fill-in follows the
                    // node numbering, the early-SPICE behaviour)
                    (Ordering::Natural, Some((_, bv, _))) => av > bv,
                    // Smart: prefer sparser rows unless magnitude collapses
                    (Ordering::Smart, Some((_, bv, bn))) => {
                        (nz < bn && av > 1e-3 * bv) || (av > 1e3 * bv && nz <= bn)
                    }
                };
                if better {
                    best = Some((r, av, nz));
                }
            }
            let Some((prow, pv, _)) = best else {
                bail!("sparse solve: singular at column {col}");
            };
            if pv < 1e-300 {
                bail!("sparse solve: numerically singular at column {col}");
            }
            used[prow] = true;
            pivots.push((col, prow));
            let pivot_val = rows[prow][&col];
            let prow_data: Vec<(usize, f64)> =
                rows[prow].iter().map(|(&j, &v)| (j, v)).collect();
            let bp = b[prow];
            for &r in &live {
                if r == prow || used[r] {
                    continue;
                }
                let Some(&vc) = rows[r].get(&col) else { continue };
                let f = vc / pivot_val;
                rows[r].remove(&col);
                if f == 0.0 {
                    continue;
                }
                for &(j, v) in &prow_data {
                    if j == col {
                        continue;
                    }
                    let e = rows[r].entry(j).or_insert_with(|| {
                        col_rows[j].push(r); // new fill-in
                        0.0
                    });
                    *e -= f * v;
                    if e.abs() < 1e-300 {
                        rows[r].remove(&j);
                    }
                }
                b[r] -= f * bp;
            }
            col_rows[col].clear();
        }

        // back substitution in reverse elimination order
        let mut x = vec![0.0; n];
        for &(col, prow) in pivots.iter().rev() {
            let mut s = b[prow];
            let mut diag = 0.0;
            for (&j, &v) in &rows[prow] {
                if j == col {
                    diag = v;
                } else {
                    s -= v * x[j];
                }
            }
            if diag.abs() < 1e-300 {
                bail!("sparse solve: zero diagonal in back-substitution");
            }
            x[col] = s / diag;
        }
        let peak = rows.iter().map(|r| r.len()).sum::<usize>().max(assembled_nnz);
        Ok((x, SolveStats::direct(peak, n)))
    }

    /// Residual max-norm ||Ax - b||_inf (for tests / diagnostics).
    pub fn residual(&self, x: &[f64]) -> f64 {
        let mut r = self.b.clone();
        for &(i, j, v) in &self.triplets {
            r[i] -= v * x[j];
        }
        r.iter().fold(0.0f64, |a, &v| a.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn dense_2x2() {
        let a = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        let x = solve_dense(&a, &[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn dense_singular_detected() {
        let a = vec![vec![1.0, 2.0], vec![2.0, 4.0]];
        assert!(solve_dense(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn dense_needs_pivoting() {
        let a = vec![vec![0.0, 1.0], vec![1.0, 0.0]];
        let x = solve_dense(&a, &[3.0, 7.0]).unwrap();
        assert!((x[0] - 7.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
    }

    fn random_system(n: usize, rng: &mut Rng) -> (Vec<Vec<f64>>, SparseSys, Vec<f64>) {
        let mut dense = vec![vec![0.0; n]; n];
        let mut sys = SparseSys::new(n);
        for i in 0..n {
            for _ in 0..3 {
                let j = rng.below(n);
                let v = rng.range_f64(-1.0, 1.0);
                dense[i][j] += v;
                sys.add(i, j, v);
            }
            dense[i][i] += 5.0;
            sys.add(i, i, 5.0);
        }
        let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
        for (i, &v) in b.iter().enumerate() {
            sys.add_b(i, v);
        }
        (dense, sys, b)
    }

    #[test]
    fn sparse_matches_dense_random_both_orderings() {
        let mut rng = Rng::new(11);
        for trial in 0..8 {
            let n = 5 + trial * 4;
            let (dense, sys, b) = random_system(n, &mut rng);
            let xd = solve_dense(&dense, &b).unwrap();
            for ord in [Ordering::Smart, Ordering::Natural] {
                let xs = sys.solve_with(ord).unwrap();
                for i in 0..n {
                    assert!((xd[i] - xs[i]).abs() < 1e-9, "{ord:?} trial {trial} x[{i}]");
                }
                assert!(sys.residual(&xs) < 1e-9);
            }
        }
    }

    #[test]
    fn sparse_duplicate_triplets_summed() {
        let mut s = SparseSys::new(1);
        s.add(0, 0, 1.5);
        s.add(0, 0, 0.5);
        s.add_b(0, 4.0);
        let x = s.solve().unwrap();
        assert!((x[0] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn peak_entries_counts_assembled_not_raw_triplets() {
        // 20 duplicate triplets assemble into 2 entries; the stat must not
        // take max against the raw (duplicated) triplet count
        let mut s = SparseSys::new(2);
        for _ in 0..10 {
            s.add(0, 0, 0.1);
            s.add(1, 1, 0.1);
        }
        s.add_b(0, 1.0);
        let (_, st) = s.solve_with_stats(Ordering::Smart).unwrap();
        assert_eq!(s.nnz(), 20);
        assert_eq!(st.peak_entries, 2, "dedupe before comparing");
    }

    #[test]
    fn sparse_singular_detected() {
        let mut s = SparseSys::new(2);
        s.add(0, 0, 1.0);
        s.add(1, 0, 1.0); // column 1 empty
        assert!(s.solve().is_err());
        assert!(s.solve_with(Ordering::Natural).is_err());
    }

    #[test]
    fn sparse_needs_off_diagonal_pivot() {
        // zero diagonal forces non-diagonal pivot row in both orderings
        let mut s = SparseSys::new(2);
        s.add(0, 1, 1.0);
        s.add(1, 0, 1.0);
        s.add_b(0, 3.0);
        s.add_b(1, 7.0);
        for ord in [Ordering::Smart, Ordering::Natural] {
            let x = s.solve_with(ord).unwrap();
            assert!((x[0] - 7.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12, "{ord:?}");
        }
    }

    #[test]
    fn sparse_block_diagonal_fast_path() {
        // 200 independent 2x2 blocks — the segmented-crossbar structure
        let n = 400;
        let mut s = SparseSys::new(n);
        for k in 0..200 {
            let i = 2 * k;
            s.add(i, i, 2.0);
            s.add(i, i + 1, 1.0);
            s.add(i + 1, i, 1.0);
            s.add(i + 1, i + 1, 3.0);
            s.add_b(i, 5.0);
            s.add_b(i + 1, 10.0);
        }
        let x = s.solve().unwrap();
        for k in 0..200 {
            assert!((x[2 * k] - 1.0).abs() < 1e-10);
            assert!((x[2 * k + 1] - 3.0).abs() < 1e-10);
        }
    }

    #[test]
    fn wide_value_range_stays_accurate() {
        // mixes 1e-4-siemens conductances with 1e6 op-amp gains
        let mut s = SparseSys::new(3);
        s.add(0, 0, 1e-4);
        s.add(0, 1, -1e-4);
        s.add(1, 0, -1e-4);
        s.add(1, 1, 2e-4);
        s.add(1, 2, 1.0);
        s.add(2, 1, 1e6);
        s.add(2, 2, 1.0);
        s.add_b(0, 1e-3);
        let x = s.solve().unwrap();
        assert!(s.residual(&x) < 1e-9);
    }
}
