//! E4 / Fig 7 (simulation half) — SPICE simulation of FC crossbars,
//! monolithic vs segmented netlists (§4.2's distributed-simulation claim:
//! ~13x at the 2050x1024 crossbar on PSpice).
//!
//!   cargo bench --bench bench_segmentation [max_size]
//!
//! What we measure on our substrate (EXPERIMENTS.md E4 discusses the
//! divergence):
//!   * wall time, monolithic vs 64-column segments, Smart + Natural
//!     orderings — with a fill-aware sparse solver the monolithic *time*
//!     penalty largely disappears (an improvement over the paper's tool);
//!   * peak resident solver memory (matrix entries incl. fill) — the
//!     segmentation win that persists regardless of ordering: the largest
//!     simultaneously-resident system shrinks by ~the segment ratio, which
//!     is what makes the paper's 2050x1024 case tractable on small hosts
//!     and lets segments run distributed (util::pool::par_map).

use std::time::Instant;

use memx::mapper::{self, MapMode};
use memx::netlist;
use memx::nn::DeviceJson;
use memx::spice::solve::Ordering;
use memx::util::bench;
use memx::util::pool;

fn device() -> DeviceJson {
    DeviceJson {
        r_on: 100.0,
        r_off: 16000.0,
        levels: 64,
        prog_sigma: 0.01,
        v_in: 2.5e-3,
        v_rail: 24.0,
        t_mem: 1e-10,
        slew_rate: 1e7,
        v_swing: 5.0,
        p_opamp: 1e-3,
        p_memristor: 1.1e-6,
        p_aux: 5e-4,
        t_opamp: 5e-7,
    }
}

struct Run {
    wall: std::time::Duration,
    peak_entries: usize,
    outputs: Vec<f64>,
}

fn simulate(
    cb: &mapper::Crossbar,
    dev: &DeviceJson,
    segment: usize,
    ord: Ordering,
    inputs: &[f64],
) -> Run {
    let segs = netlist::plan_segments(cb.cols, segment);
    let t0 = Instant::now();
    let mut outputs = Vec::with_capacity(cb.cols);
    let mut peak = 0usize;
    for seg in &segs {
        let text = netlist::emit_crossbar(cb, dev, seg, Some(inputs), segs.len());
        let circuit = netlist::parse(&text).expect("parse");
        let (sol, stats) = circuit.dc_op_stats(ord).expect("solve");
        peak = peak.max(stats.peak_entries);
        for c in seg.col_start..seg.col_end {
            let node = circuit.node_named(&format!("vout{c}")).expect("vout");
            outputs.push(sol[node]);
        }
    }
    Run { wall: t0.elapsed(), peak_entries: peak, outputs }
}

fn main() {
    let max: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let dev = device();
    const SEG: usize = 64;

    println!("== Fig 7: FC crossbar simulation, monolithic vs segmented ({SEG} cols/file) ==");
    println!("| size | ordering | t mono | t seg | t ratio | peak mem mono | peak mem seg | mem ratio | max |Δ| |");
    println!("|---|---|---:|---:|---:|---:|---:|---:|---:|");
    let sizes: Vec<usize> =
        [64usize, 128, 256, 512, 1024].into_iter().filter(|&s| s <= max).collect();
    for &n in &sizes {
        let cb = mapper::build_synthetic_fc(n, n, 64, MapMode::Inverted, 99);
        let inputs: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.13).sin() * 0.4).collect();
        let ideal = cb.eval_ideal(&inputs);
        for ord in [Ordering::Smart, Ordering::Natural] {
            if ord == Ordering::Natural && n > 256 {
                // Natural-order cost is already demonstrated at <=256; keep
                // the bench finite (see bench_spice for the scaling law).
                continue;
            }
            let mono = simulate(&cb, &dev, 0, ord, &inputs);
            let seg = simulate(&cb, &dev, SEG, ord, &inputs);
            let err = mono
                .outputs
                .iter()
                .chain(&seg.outputs)
                .zip(ideal.iter().chain(&ideal))
                .fold(0f64, |a, (g, i)| a.max((g - i).abs()));
            println!(
                "| {n}x{n} | {ord:?} | {:?} | {:?} | {:.1}x | {} | {} | {:.1}x | {err:.1e} |",
                mono.wall,
                seg.wall,
                mono.wall.as_secs_f64() / seg.wall.as_secs_f64().max(1e-12),
                mono.peak_entries,
                seg.peak_entries,
                mono.peak_entries as f64 / seg.peak_entries.max(1) as f64,
            );
        }
    }
    println!("\npaper Fig 7: ~13x simulation-time reduction at 2050x1024 (PSpice).");
    println!("our engine: the time penalty is an artifact of LU ordering (Natural");
    println!("pathology shown in bench_spice); the enduring segmentation win here is");
    println!("peak solver memory (+ distributed execution via par_map on multicore).");

    // --- factor-once / solve-many over segments -------------------------
    // The per-call path above re-emits, re-parses and re-eliminates every
    // segment per input vector. CrossbarSim factors each segment once and
    // answers subsequent vectors from the cached LU (parallel segments,
    // multi-RHS batch path). Cold = first read incl. emit+parse+analyze.
    println!("\n== factor-once/solve-many: segmented crossbar reads ({SEG} cols/file) ==");
    println!("| size | cold first read | cached read | speedup | batch of 8 (per read) | max |Δ| vs per-call |");
    println!("|---|---:|---:|---:|---:|---:|");
    let workers = pool::default_workers();
    let mut stats = Vec::new();
    let mut derived: Vec<(String, f64)> = Vec::new();
    for &n in &sizes {
        let cb = mapper::build_synthetic_fc(n, n, 64, MapMode::Inverted, 99);
        let inputs: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.13).sin() * 0.4).collect();
        let reference = simulate(&cb, &dev, SEG, Ordering::Smart, &inputs);

        let t0 = Instant::now();
        let mut sim = netlist::CrossbarSim::new(
            &cb,
            &dev,
            SEG,
            Ordering::Smart,
            memx::spice::krylov::SolverStrategy::Auto,
        )
        .expect("build sim");
        let first = sim.solve_par(&inputs, workers).expect("cold read");
        let cold = t0.elapsed();

        // cached reads with fresh input vectors (RHS-only edits)
        let reads = 8usize;
        let t0 = Instant::now();
        let mut last = Vec::new();
        for k in 1..=reads {
            let v: Vec<f64> =
                (0..n).map(|i| ((i + k) as f64 * 0.17).sin() * 0.4).collect();
            last = sim.solve_par(&v, workers).expect("cached read");
        }
        let cached = t0.elapsed() / reads as u32;
        assert_eq!(last.len(), cb.cols);

        // batched multi-RHS reads
        let batch: Vec<Vec<f64>> = (0..8)
            .map(|k| (0..n).map(|i| ((i * 3 + k) as f64 * 0.11).cos() * 0.4).collect())
            .collect();
        let t0 = Instant::now();
        let outs = sim.solve_batch(&batch, workers).expect("batch read");
        let per_batched = t0.elapsed() / batch.len() as u32;
        assert_eq!(outs.len(), batch.len());

        let err = first
            .iter()
            .zip(&reference.outputs)
            .fold(0f64, |a, (g, r)| a.max((g - r).abs()));
        let speedup = cold.as_secs_f64() / cached.as_secs_f64().max(1e-12);
        println!(
            "| {n}x{n} | {cold:?} | {cached:?} | {speedup:.1}x | {per_batched:?} | {err:.1e} |"
        );
        stats.push(bench::Stats {
            name: format!("seg{SEG} {n}x{n} cached read"),
            iters: reads,
            mean: cached,
            median: cached,
            p95: cached,
            min: cached,
        });
        derived.push((format!("seg_{n}x{n}_cold_vs_cached"), speedup));
    }
    if let Err(e) =
        bench::append_json_report("BENCH_spice.json", "bench_segmentation", &stats, &derived)
    {
        eprintln!("warning: could not write BENCH_spice.json: {e}");
    }
}
