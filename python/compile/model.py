"""L2 — MobileNetV3-Small (CIFAR-scaled) in JAX, in two modes:

* ``digital``  — exact fp32 reference (the "PyTorch-equivalent" baseline of
  the paper's Table 1), trained with this module's fwd/bwd.
* ``analog``   — the memristor computing paradigm: every VMM-bearing layer
  (conv / depthwise / pointwise / SE / FC / GAP / BN) routed through the L1
  Pallas crossbar kernel with differentially-split, level-quantized,
  programming-noised conductances and TIA rail saturation; activations use
  the analog circuit models (Fig 4).

The topology is the standard MobileNetV3-Small bottleneck stack (Howard et
al. 2019) with CIFAR adaptations: 32x32 input, first conv stride 1, three
spatial downsamples (32->16->8->4), width multiplier 0.5 — the same
"scaled-down MobileNetV3" regime as the paper's §5.1 CIFAR-10 experiment
(Table 4's bottleneck0..10).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import device as dv
from .kernels import crossbar as xbar
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Architecture spec
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BneckCfg:
    k: int        # depthwise kernel size
    exp: int      # expansion channels
    out: int      # output channels
    se: bool      # squeeze-and-excite
    act: str      # "relu" | "hswish"
    stride: int


def _c(ch: int, mult: float, min_ch: int = 8) -> int:
    """Width-scaled channel count, rounded to a multiple of 4."""
    v = max(min_ch, int(ch * mult + 2) // 4 * 4)
    return v


def mobilenet_v3_small_cifar(width: float = 0.5):
    """Returns (stem_ch, [BneckCfg...], last_ch, hidden_ch).

    MobileNetV3-Small table with strides adapted for 32x32 inputs:
    stem stride 1; downsamples at bneck1, bneck3, bneck8 (32->16->8->4)."""
    c = lambda ch: _c(ch, width)
    stem = c(16)
    cfgs = [
        BneckCfg(3, c(16),  c(16), True,  "relu",   1),   # bneck0
        BneckCfg(3, c(72),  c(24), False, "relu",   2),   # bneck1
        BneckCfg(3, c(88),  c(24), False, "relu",   1),   # bneck2
        BneckCfg(5, c(96),  c(40), True,  "hswish", 2),   # bneck3
        BneckCfg(5, c(240), c(40), True,  "hswish", 1),   # bneck4
        BneckCfg(5, c(240), c(40), True,  "hswish", 1),   # bneck5
        BneckCfg(5, c(120), c(48), True,  "hswish", 1),   # bneck6
        BneckCfg(5, c(144), c(48), True,  "hswish", 1),   # bneck7
        BneckCfg(5, c(288), c(96), True,  "hswish", 2),   # bneck8
        BneckCfg(5, c(576), c(96), True,  "hswish", 1),   # bneck9
        BneckCfg(5, c(576), c(96), True,  "hswish", 1),   # bneck10
    ]
    last = c(576)
    hidden = c(1024)
    return stem, cfgs, last, hidden


NUM_CLASSES = 10
EPS = 1e-5

# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _conv_init(rng, k, cin, cout):
    fan_in = k * k * cin
    std = float(np.sqrt(2.0 / fan_in))
    return (rng.standard_normal((k, k, cin, cout)) * std).astype(np.float32)


def _fc_init(rng, cin, cout):
    std = float(np.sqrt(2.0 / cin))
    w = (rng.standard_normal((cin, cout)) * std).astype(np.float32)
    b = np.zeros((cout,), np.float32)
    return w, b


def _bn_init(c):
    return {
        "gamma": np.ones((c,), np.float32),
        "beta": np.zeros((c,), np.float32),
        "mean": np.zeros((c,), np.float32),
        "var": np.ones((c,), np.float32),
    }


def init_params(seed: int = 0, width: float = 0.5) -> dict:
    """Flat dict of numpy arrays, keys like 'b3.dw.w', 'b3.dw.bn.gamma'."""
    rng = np.random.default_rng(seed)
    stem, cfgs, last, hidden = mobilenet_v3_small_cifar(width)
    p: dict[str, np.ndarray] = {}
    p["stem.conv.w"] = _conv_init(rng, 3, 3, stem)
    for k, v in _bn_init(stem).items():
        p[f"stem.bn.{k}"] = v
    cin = stem
    for i, cfg in enumerate(cfgs):
        pre = f"b{i}"
        if cfg.exp != cin:
            p[f"{pre}.exp.w"] = _conv_init(rng, 1, cin, cfg.exp)
            for k, v in _bn_init(cfg.exp).items():
                p[f"{pre}.exp.bn.{k}"] = v
        p[f"{pre}.dw.w"] = _conv_init(rng, cfg.k, 1, cfg.exp)  # (k,k,1,exp)
        for k, v in _bn_init(cfg.exp).items():
            p[f"{pre}.dw.bn.{k}"] = v
        if cfg.se:
            sq = max(8, cfg.exp // 4 // 4 * 4)
            w1, b1 = _fc_init(rng, cfg.exp, sq)
            w2, b2 = _fc_init(rng, sq, cfg.exp)
            p[f"{pre}.se.fc1.w"], p[f"{pre}.se.fc1.b"] = w1, b1
            p[f"{pre}.se.fc2.w"], p[f"{pre}.se.fc2.b"] = w2, b2
        p[f"{pre}.proj.w"] = _conv_init(rng, 1, cfg.exp, cfg.out)
        for k, v in _bn_init(cfg.out).items():
            p[f"{pre}.proj.bn.{k}"] = v
        cin = cfg.out
    p["last.conv.w"] = _conv_init(rng, 1, cin, last)
    for k, v in _bn_init(last).items():
        p[f"last.bn.{k}"] = v
    w1, b1 = _fc_init(rng, last, hidden)
    w2, b2 = _fc_init(rng, hidden, NUM_CLASSES)
    p["cls.fc1.w"], p["cls.fc1.b"] = w1, b1
    p["cls.fc2.w"], p["cls.fc2.b"] = w2, b2
    return p


def count_params(params: dict) -> int:
    return int(sum(int(np.prod(v.shape)) for v in params.values()))


# ---------------------------------------------------------------------------
# Analog conversion — weights -> differential quantized conductances
# ---------------------------------------------------------------------------

def convert_params_analog(params: dict, dev: dv.DeviceParams, seed: int = 7) -> dict:
    """Precompute, for every VMM weight / BN scale / bias, the differential
    quantized conductance pair (paper Eq 16 + §3.2 inverted convention) with
    programming noise.  The result is a dict name -> dict of numpy arrays
    consumed by `forward(..., analog=...)` and baked into the AOT artifact.
    """
    rng = np.random.default_rng(seed)
    out: dict[str, dict] = {}

    def diff(name, w):
        pos, neg, scale = dv.weights_to_differential(np.asarray(w), None, dev, rng)
        out[name] = {"pos": pos, "neg": neg, "scale": np.float32(scale)}

    for name, w in params.items():
        if name.endswith(".w") or name.endswith(".b"):
            diff(name, w)
    # Fold BN into per-channel scale k = gamma/sqrt(var+eps) and offset beta,
    # each realized by a differential memristor pair (paper Eqs 8/9).
    bn_names = sorted({n.rsplit(".", 1)[0] for n in params if n.endswith(".gamma")})
    for bn in bn_names:
        gamma = params[f"{bn}.gamma"]
        var = params[f"{bn}.var"]
        beta = params[f"{bn}.beta"]
        k = gamma / np.sqrt(var + EPS)
        diff(f"{bn}.k", k)
        diff(f"{bn}.beta_q", beta)
    return out


def _eff(analog_entry) -> jnp.ndarray:
    """Effective signed weight realized by a differential pair."""
    e = analog_entry
    return (jnp.asarray(e["neg"]) - jnp.asarray(e["pos"])) * jnp.float32(e["scale"])


# ---------------------------------------------------------------------------
# Layer primitives (digital and analog paths)
# ---------------------------------------------------------------------------

def _patches(x, k, stride, padding):
    """im2col: x (B,H,W,C) -> (B,Ho,Wo, C*k*k) with feature order (C,kh,kw).

    Built from pad + strided slices + stack only — deliberately NOT
    jax.lax.conv_general_dilated_patches: XLA convolution ops miscompile (to
    zeros) through the StableHLO -> HLO-text -> xla_extension 0.5.1 AOT
    path this repo ships on (see DESIGN.md §8), and slicing also mirrors the
    crossbar's physical wiring (each kernel tap is a dedicated input line).
    """
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    ho = (h + 2 * padding - k) // stride + 1
    wo = (w + 2 * padding - k) // stride + 1
    taps = []
    for a in range(k):
        for bb in range(k):
            sl = jax.lax.slice(
                xp,
                (0, a, bb, 0),
                (b, a + (ho - 1) * stride + 1, bb + (wo - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            taps.append(sl)  # (B, Ho, Wo, C)
    pats = jnp.stack(taps, axis=-1)  # (B, Ho, Wo, C, k*k)
    return pats.reshape(b, ho, wo, c * k * k)


def _w_matrix(w):
    """HWIO conv weight (k,k,cin,cout) -> (cin*k*k, cout) matching _patches
    feature order (C, kh, kw)."""
    k1, k2, cin, cout = w.shape
    return jnp.transpose(w, (2, 0, 1, 3)).reshape(k1 * k2 * cin, cout)


class Ctx:
    """Forward context: mode flags + device constants."""

    def __init__(self, analog=None, dev=dv.DEFAULT_DEVICE, interpret=True,
                 use_kernel=True, native_conv=True):
        self.analog = analog          # dict from convert_params_analog, or None
        self.dev = dev
        self.interpret = interpret
        self.use_kernel = use_kernel  # route VMMs through the Pallas kernel
        # native XLA convolutions: fast for on-host training/eval, but they
        # MUST be disabled for AOT export (XLA 0.5.1 miscompiles conv ops
        # arriving via HLO text — the exporter uses the im2col form).
        self.native_conv = native_conv

    @property
    def is_analog(self):
        return self.analog is not None


def _vmm(ctx: Ctx, name: str, v2d, w_digital):
    """Dispatch a (B,R)x(R,C) VMM to the crossbar kernel (analog) or a plain
    matmul (digital)."""
    if not ctx.is_analog:
        return v2d @ w_digital
    e = ctx.analog[name]
    rail = ctx.dev.v_rail
    pos, neg = jnp.asarray(e["pos"]), jnp.asarray(e["neg"])
    if pos.ndim == 4:  # conv weight: quantization is elementwise, so the
        pos = _w_matrix(pos)  # im2col transpose commutes with it
        neg = _w_matrix(neg)
    if ctx.use_kernel:
        return xbar.crossbar_vmm(
            v2d, pos, neg,
            rf_scale=float(e["scale"]), v_rail=float(rail),
            interpret=ctx.interpret,
        )
    return kref.crossbar_vmm_ref(
        v2d, pos, neg, rf_scale=float(e["scale"]), v_rail=float(rail))


def conv2d(ctx: Ctx, name: str, x, w, stride=1, padding=0):
    """Regular convolution.  Analog: im2col + crossbar VMM (paper §3.2: the
    sliding window realized by memristor placement; Eqs 1-3).  Digital: the
    native XLA convolution (reference semantics are identical; the im2col
    form exists to mirror the crossbar dataflow)."""
    if not ctx.is_analog and ctx.native_conv:
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(padding, padding), (padding, padding)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    k = w.shape[0]
    pats = _patches(x, k, stride, padding)
    b, ho, wo, feat = pats.shape
    out = _vmm(ctx, name, pats.reshape(b * ho * wo, feat), _w_matrix(w))
    return out.reshape(b, ho, wo, -1)


def depthwise_conv2d(ctx: Ctx, name: str, x, w, stride=1, padding=0):
    """Depthwise convolution: per-channel crossbars without the cross-channel
    current summation (paper Fig 10a).  Implemented as im2col with the
    (C, kh, kw) feature order and a block-diagonal effective weight —
    numerically identical to C independent small crossbars."""
    k1, k2, _, c = w.shape
    if not ctx.is_analog and ctx.native_conv:
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(padding, padding), (padding, padding)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=c)
    pats = _patches(x, k1, stride, padding)          # (B,Ho,Wo, C*k*k)
    b, ho, wo, feat = pats.shape
    kk = k1 * k2
    pats = pats.reshape(b * ho * wo, c, kk)          # per-channel patches
    if not ctx.is_analog:
        wm = jnp.transpose(w.reshape(kk, c), (1, 0))  # (C, k*k)
        out = jnp.einsum("nck,ck->nc", pats, wm)
        return out.reshape(b, ho, wo, c)
    e = ctx.analog[name]
    # (k,k,1,C) -> (C, k*k) per-channel differential banks
    pos = jnp.transpose(jnp.asarray(e["pos"]).reshape(kk, c), (1, 0))
    neg = jnp.transpose(jnp.asarray(e["neg"]).reshape(kk, c), (1, 0))
    geff = (neg - pos) * jnp.float32(e["scale"])     # (C, k*k)
    out = jnp.einsum("nck,ck->nc", pats, geff)
    return jnp.clip(out, -ctx.dev.v_rail, ctx.dev.v_rail).reshape(b, ho, wo, c)


def batch_norm(ctx: Ctx, name: str, x, p, train_stats=None):
    """Inference BN.  Digital: exact.  Analog: the memristor BN module
    (paper §3.3, Eqs 8/9): subtraction crossbar (exact unit conductances),
    quantized differential scale k and offset beta, TIA rail clip."""
    if train_stats is not None:
        mean, var = train_stats
    else:
        mean, var = p[f"{name}.mean"], p[f"{name}.var"]
    if not ctx.is_analog:
        k = p[f"{name}.gamma"] / jnp.sqrt(var + EPS)
        return (x - mean) * k + p[f"{name}.beta"]
    k_eff = _eff(ctx.analog[f"{name}.k"])
    b_eff = _eff(ctx.analog[f"{name}.beta_q"])
    y = (x - mean) * k_eff + b_eff
    return jnp.clip(y, -ctx.dev.v_rail, ctx.dev.v_rail)


def act(ctx: Ctx, kind: str, x):
    if ctx.is_analog:
        rail = ctx.dev.v_rail
        if kind == "relu":
            return kref.analog_relu_ref(x, rail)
        if kind == "hswish":
            return kref.analog_hard_swish_ref(x, rail)
        if kind == "hsigmoid":
            return kref.analog_hard_sigmoid_ref(x, rail)
    else:
        if kind == "relu":
            return kref.relu_ref(x)
        if kind == "hswish":
            return kref.hard_swish_ref(x)
        if kind == "hsigmoid":
            return kref.hard_sigmoid_ref(x)
    raise ValueError(kind)


def global_avg_pool(ctx: Ctx, x):
    """GAP (paper §3.5): crossbar with 1/N conductances.  The per-layer scale
    makes 1/N exactly representable, so analog == digital up to the rail."""
    y = jnp.mean(x, axis=(1, 2))
    if ctx.is_analog:
        y = jnp.clip(y, -ctx.dev.v_rail, ctx.dev.v_rail)
    return y


def fully_connected(ctx: Ctx, name: str, x, w, b):
    y = _vmm(ctx, f"{name}.w", x, w)
    if not ctx.is_analog:
        return y + b
    b_eff = _eff(ctx.analog[f"{name}.b"])
    return jnp.clip(y + b_eff, -ctx.dev.v_rail, ctx.dev.v_rail)


def se_block(ctx: Ctx, pre: str, x, p):
    """Squeeze-and-excite (paper's PConv attention pair + HSigmoid + analog
    multiplier)."""
    s = global_avg_pool(ctx, x)
    s = fully_connected(ctx, f"{pre}.se.fc1", s, p[f"{pre}.se.fc1.w"], p[f"{pre}.se.fc1.b"])
    s = act(ctx, "relu", s)
    s = fully_connected(ctx, f"{pre}.se.fc2", s, p[f"{pre}.se.fc2.w"], p[f"{pre}.se.fc2.b"])
    s = act(ctx, "hsigmoid", s)
    y = x * s[:, None, None, :]
    if ctx.is_analog:  # analog multiplier output is rail-bounded
        y = jnp.clip(y, -ctx.dev.v_rail, ctx.dev.v_rail)
    return y


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------

def forward(params, x, ctx: Ctx | None = None, width: float = 0.5,
            train: bool = False, stats_out: dict | None = None):
    """Logits for x (B,32,32,3) in [0,1].

    train=True: BN uses batch statistics, and (mean, var) per BN layer are
    recorded into ``stats_out`` so the trainer can update running stats.
    """
    ctx = ctx or Ctx()
    p = params
    stem, cfgs, last, hidden = mobilenet_v3_small_cifar(width)
    v = (x - 0.5) * 2.0  # sensor voltages, normalized full scale (±2.5 mV)

    def bn(name, h):
        if train:
            axes = tuple(range(h.ndim - 1))
            m = jnp.mean(h, axis=axes)
            va = jnp.var(h, axis=axes)
            if stats_out is not None:
                stats_out[name] = (m, va)
            return batch_norm(ctx, name, h, p, (m, va))
        return batch_norm(ctx, name, h, p, None)

    h = conv2d(ctx, "stem.conv.w", v, p["stem.conv.w"], stride=1, padding=1)
    h = bn("stem.bn", h)
    h = act(ctx, "hswish", h)

    cin = stem
    for i, cfg in enumerate(cfgs):
        pre = f"b{i}"
        inp = h
        if cfg.exp != cin:
            h = conv2d(ctx, f"{pre}.exp.w", h, p[f"{pre}.exp.w"])
            h = bn(f"{pre}.exp.bn", h)
            h = act(ctx, cfg.act, h)
        h = depthwise_conv2d(ctx, f"{pre}.dw.w", h, p[f"{pre}.dw.w"],
                             stride=cfg.stride, padding=cfg.k // 2)
        h = bn(f"{pre}.dw.bn", h)
        h = act(ctx, cfg.act, h)
        if cfg.se:
            h = se_block(ctx, pre, h, p)
        h = conv2d(ctx, f"{pre}.proj.w", h, p[f"{pre}.proj.w"])
        h = bn(f"{pre}.proj.bn", h)
        if cfg.stride == 1 and cin == cfg.out:
            h = h + inp  # residual adder module
            if ctx.is_analog:
                h = jnp.clip(h, -ctx.dev.v_rail, ctx.dev.v_rail)
        cin = cfg.out

    h = conv2d(ctx, "last.conv.w", h, p["last.conv.w"])
    h = bn("last.bn", h)
    h = act(ctx, "hswish", h)

    h = global_avg_pool(ctx, h)
    h = fully_connected(ctx, "cls.fc1", h, p["cls.fc1.w"], p["cls.fc1.b"])
    h = act(ctx, "hswish", h)
    logits = fully_connected(ctx, "cls.fc2", h, p["cls.fc2.w"], p["cls.fc2.b"])
    return logits


# ---------------------------------------------------------------------------
# Manifest — layer inventory for the rust mapper (Table 4 / netlists)
# ---------------------------------------------------------------------------

def build_manifest(params: dict, width: float = 0.5, img: int = 32) -> dict:
    """Structured per-unit layer list mirroring the paper's Table 4: for each
    sublayer its geometry (input HxWxC, kernel, stride, padding, output) and
    the weight keys; the rust mapper derives crossbar sizes, memristor /
    op-amp counts (Eqs 5-15) and parallelism from this."""
    stem, cfgs, last, hidden = mobilenet_v3_small_cifar(width)
    units = []
    h = w = img

    def conv_entry(name, unit, typ, k, s, pd, cin, cout, hh, ww, wkey):
        ho = (hh - k + 2 * pd) // s + 1
        wo = (ww - k + 2 * pd) // s + 1
        return {
            "unit": unit, "layer": typ, "name": name,
            "k": k, "stride": s, "padding": pd,
            "cin": cin, "cout": cout,
            "h_in": hh, "w_in": ww, "h_out": ho, "w_out": wo,
            "weight": wkey,
        }, ho, wo

    layers = []
    e, h, w = conv_entry("stem.conv", "input", "conv", 3, 1, 1, 3, stem, h, w, "stem.conv.w")
    layers.append(e)
    layers.append({"unit": "input", "layer": "bn", "name": "stem.bn", "c": stem,
                   "weight": "stem.bn.gamma"})
    layers.append({"unit": "input", "layer": "hswish", "name": "stem.act", "c": stem})
    cin = stem
    for i, cfg in enumerate(cfgs):
        unit = f"bottleneck{i}"
        pre = f"b{i}"
        if cfg.exp != cin:
            e, _, _ = conv_entry(f"{pre}.exp", unit, "conv", 1, 1, 0, cin, cfg.exp, h, w, f"{pre}.exp.w")
            layers.append(e)
            layers.append({"unit": unit, "layer": "bn", "name": f"{pre}.exp.bn",
                           "c": cfg.exp, "weight": f"{pre}.exp.bn.gamma"})
            layers.append({"unit": unit, "layer": cfg.act, "name": f"{pre}.exp.act", "c": cfg.exp})
        e, ho, wo = conv_entry(f"{pre}.dw", unit, "dwconv", cfg.k, cfg.stride,
                               cfg.k // 2, cfg.exp, cfg.exp, h, w, f"{pre}.dw.w")
        layers.append(e)
        layers.append({"unit": unit, "layer": "bn", "name": f"{pre}.dw.bn",
                       "c": cfg.exp, "weight": f"{pre}.dw.bn.gamma"})
        layers.append({"unit": unit, "layer": cfg.act, "name": f"{pre}.dw.act", "c": cfg.exp})
        h, w = ho, wo
        if cfg.se:
            sq = params[f"{pre}.se.fc1.w"].shape[1]
            layers.append({"unit": unit, "layer": "gapool", "name": f"{pre}.se.gap",
                           "c": cfg.exp, "h_in": h, "w_in": w})
            layers.append({"unit": unit, "layer": "pconv", "name": f"{pre}.se.fc1",
                           "cin": cfg.exp, "cout": sq, "weight": f"{pre}.se.fc1.w"})
            layers.append({"unit": unit, "layer": "relu", "name": f"{pre}.se.act1", "c": sq})
            layers.append({"unit": unit, "layer": "pconv", "name": f"{pre}.se.fc2",
                           "cin": sq, "cout": cfg.exp, "weight": f"{pre}.se.fc2.w"})
            layers.append({"unit": unit, "layer": "hsigmoid", "name": f"{pre}.se.act2", "c": cfg.exp})
        e, _, _ = conv_entry(f"{pre}.proj", unit, "conv", 1, 1, 0, cfg.exp, cfg.out, h, w, f"{pre}.proj.w")
        layers.append(e)
        layers.append({"unit": unit, "layer": "bn", "name": f"{pre}.proj.bn",
                       "c": cfg.out, "weight": f"{pre}.proj.bn.gamma"})
        if cfg.stride == 1 and cin == cfg.out:
            layers.append({"unit": unit, "layer": "residual", "name": f"{pre}.add", "c": cfg.out})
        cin = cfg.out
    e, _, _ = conv_entry("last.conv", "last_conv", "conv", 1, 1, 0, cin, last, h, w, "last.conv.w")
    layers.append(e)
    layers.append({"unit": "last_conv", "layer": "bn", "name": "last.bn", "c": last,
                   "weight": "last.bn.gamma"})
    layers.append({"unit": "last_conv", "layer": "hswish", "name": "last.act", "c": last})
    layers.append({"unit": "classifier", "layer": "gapool", "name": "cls.gap",
                   "c": last, "h_in": h, "w_in": w})
    layers.append({"unit": "classifier", "layer": "fc", "name": "cls.fc1",
                   "cin": last, "cout": hidden, "weight": "cls.fc1.w"})
    layers.append({"unit": "classifier", "layer": "hswish", "name": "cls.act", "c": hidden})
    layers.append({"unit": "classifier", "layer": "fc", "name": "cls.fc2",
                   "cin": hidden, "cout": NUM_CLASSES, "weight": "cls.fc2.w"})
    return {
        "arch": "mobilenet_v3_small_cifar",
        "width": width,
        "img": img,
        "num_classes": NUM_CLASSES,
        "stem": stem,
        "last": last,
        "hidden": hidden,
        "layers": layers,
    }
