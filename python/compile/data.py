"""synth-cifar: a procedurally generated CIFAR-10-shaped dataset.

The real CIFAR-10 binaries are not available in this offline environment
(DESIGN.md §3).  This module generates a 10-class, 32x32x3 image
classification task with the same tensor layout, enough intra-class
variation to be non-trivial, and a fixed seed so python and rust consume
identical bytes.

Classes (0..9) are shape x texture archetypes, each with a class palette,
random position / size / distractors / illumination and additive noise:

  0 filled circle        5 ring (annulus)
  1 filled square        6 checkerboard
  2 triangle             7 horizontal stripes
  3 plus / cross         8 radial gradient blob
  4 diagonal bar         9 four-dot constellation

The loader in rust/src/dataset/ reads the binary file written by
``write_dataset_bin`` (format documented there and in DESIGN.md §7).
"""

import struct

import numpy as np

NUM_CLASSES = 10
IMG = 32
CLASS_NAMES = [
    "circle", "square", "triangle", "cross", "diagonal",
    "ring", "checker", "stripes", "blob", "dots",
]

# Per-class base palettes (fg, bg) — perturbed per sample.
_PALETTES = np.array([
    [[0.9, 0.2, 0.2], [0.1, 0.1, 0.2]],
    [[0.2, 0.8, 0.3], [0.15, 0.1, 0.1]],
    [[0.2, 0.4, 0.9], [0.2, 0.15, 0.05]],
    [[0.9, 0.8, 0.2], [0.1, 0.2, 0.15]],
    [[0.8, 0.3, 0.8], [0.1, 0.15, 0.1]],
    [[0.3, 0.9, 0.9], [0.2, 0.1, 0.15]],
    [[0.95, 0.55, 0.15], [0.1, 0.1, 0.25]],
    [[0.6, 0.9, 0.4], [0.25, 0.1, 0.1]],
    [[0.4, 0.6, 0.95], [0.1, 0.2, 0.1]],
    [[0.9, 0.9, 0.9], [0.15, 0.15, 0.15]],
], dtype=np.float32)


def _grid():
    y, x = np.mgrid[0:IMG, 0:IMG].astype(np.float32)
    return x, y


def _mask_for(cls: int, rng: np.random.Generator) -> np.ndarray:
    """Binary foreground mask for one sample of class `cls`."""
    x, y = _grid()
    cx = rng.uniform(10, 22)
    cy = rng.uniform(10, 22)
    r = rng.uniform(6, 11)
    if cls == 0:  # circle
        return ((x - cx) ** 2 + (y - cy) ** 2) <= r * r
    if cls == 1:  # square
        return (np.abs(x - cx) <= r * 0.8) & (np.abs(y - cy) <= r * 0.8)
    if cls == 2:  # triangle (upward)
        return (y - cy <= r * 0.7) & (y - cy >= -r) & (
            np.abs(x - cx) <= (y - cy + r) * 0.55)
    if cls == 3:  # plus / cross
        t = r * rng.uniform(0.28, 0.4)
        return ((np.abs(x - cx) <= t) & (np.abs(y - cy) <= r)) | (
            (np.abs(y - cy) <= t) & (np.abs(x - cx) <= r))
    if cls == 4:  # diagonal bar
        t = r * rng.uniform(0.3, 0.45)
        sign = 1.0 if rng.uniform() < 0.5 else -1.0
        d = np.abs((x - cx) - sign * (y - cy)) / np.sqrt(2.0)
        inside = (np.abs(x - cx) <= r) & (np.abs(y - cy) <= r)
        return (d <= t) & inside
    if cls == 5:  # ring
        d2 = (x - cx) ** 2 + (y - cy) ** 2
        return (d2 <= r * r) & (d2 >= (r * rng.uniform(0.45, 0.6)) ** 2)
    if cls == 6:  # checkerboard
        p = int(rng.integers(4, 7))
        return (((x.astype(np.int32) // p) + (y.astype(np.int32) // p)) % 2) == 0
    if cls == 7:  # horizontal stripes
        p = int(rng.integers(3, 6))
        ph = int(rng.integers(0, p))
        return ((y.astype(np.int32) + ph) // p) % 2 == 0
    if cls == 8:  # radial gradient blob -> soft threshold
        d2 = ((x - cx) / (r * 1.3)) ** 2 + ((y - cy) / (r * 0.8)) ** 2
        return d2 <= 1.0
    # cls == 9: four-dot constellation
    m = np.zeros((IMG, IMG), dtype=bool)
    for _ in range(4):
        dx = rng.uniform(6, 26)
        dy = rng.uniform(6, 26)
        rr = rng.uniform(2.2, 3.6)
        m |= ((x - dx) ** 2 + (y - dy) ** 2) <= rr * rr
    return m


def make_sample(cls: int, rng: np.random.Generator) -> np.ndarray:
    """One HWC float32 image in [0,1]."""
    fg, bg = _PALETTES[cls]
    fg = np.clip(fg + rng.normal(0, 0.08, 3), 0, 1).astype(np.float32)
    bg = np.clip(bg + rng.normal(0, 0.05, 3), 0, 1).astype(np.float32)
    mask = _mask_for(cls, rng).astype(np.float32)[..., None]
    img = mask * fg + (1.0 - mask) * bg
    # illumination gradient
    x, y = _grid()
    gx = rng.uniform(-0.12, 0.12)
    gy = rng.uniform(-0.12, 0.12)
    illum = 1.0 + gx * (x - 16) / 16 + gy * (y - 16) / 16
    img = img * illum[..., None]
    # distractor speckles
    n_spk = int(rng.integers(0, 18))
    for _ in range(n_spk):
        sx, sy = rng.integers(0, IMG, 2)
        img[sy, sx] = rng.uniform(0, 1, 3)
    img = img + rng.normal(0, 0.035, img.shape)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def make_dataset(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """n samples, balanced classes. Returns (images NHWC f32, labels u8)."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n) % NUM_CLASSES
    rng.shuffle(labels)
    imgs = np.stack([make_sample(int(c), rng) for c in labels])
    return imgs.astype(np.float32), labels.astype(np.uint8)


MAGIC = 0x4D454D58  # "MEMX"


def write_dataset_bin(path: str, imgs: np.ndarray, labels: np.ndarray) -> None:
    """Binary layout (little-endian):
    u32 magic | u32 n | u32 h | u32 w | u32 c | f32 data[n*h*w*c] | u8 labels[n]
    """
    n, h, w, c = imgs.shape
    with open(path, "wb") as f:
        f.write(struct.pack("<IIIII", MAGIC, n, h, w, c))
        f.write(imgs.astype("<f4").tobytes())
        f.write(labels.astype(np.uint8).tobytes())


def read_dataset_bin(path: str) -> tuple[np.ndarray, np.ndarray]:
    with open(path, "rb") as f:
        magic, n, h, w, c = struct.unpack("<IIIII", f.read(20))
        assert magic == MAGIC, f"bad magic {magic:#x}"
        data = np.frombuffer(f.read(n * h * w * c * 4), dtype="<f4")
        labels = np.frombuffer(f.read(n), dtype=np.uint8)
    return data.reshape(n, h, w, c).copy(), labels.copy()
