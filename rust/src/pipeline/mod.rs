//! `memx::pipeline` — the trait-based analog inference API: from a trained
//! [`Manifest`](crate::nn::Manifest) + [`WeightStore`](crate::nn::WeightStore)
//! to batched crossbar logits in one composable surface.
//!
//! The paper's architecture is a chain of five memristive module types —
//! convolution, batch normalization, activation, global average pooling and
//! fully connected. This module makes that chain the unit of the public
//! API: each paper module is an [`AnalogModule`] implementation
//! ([`CrossbarModule`], [`BatchNormModule`], [`ActivationModule`],
//! [`GapModule`], plus [`SeModule`] for the squeeze-and-excite side branch),
//! and a [`PipelineBuilder`] compiles the manifest directly into a runnable
//! [`Pipeline`] — replacing the old ad-hoc `map_network → emit → parse →
//! sim` choreography.
//!
//! # Manifest → logits walkthrough
//!
//! ```no_run
//! use memx::nn::{Manifest, WeightStore};
//! use memx::pipeline::{Fidelity, PipelineBuilder};
//!
//! fn main() -> anyhow::Result<()> {
//!     let dir = std::path::Path::new("artifacts");
//!     // 1. the typed network IR: layer inventory + weight table
//!     let manifest = Manifest::load(dir)?;
//!     let weights = WeightStore::load(dir, &manifest)?;
//!     // 2. compile it: quantize weights onto devices (Eq 16), lay out the
//!     //    differential crossbars (Algorithm 1) and pick the execution
//!     //    fidelity for every stage
//!     let mut pipeline = PipelineBuilder::new()
//!         .fidelity(Fidelity::Behavioural)
//!         .build(&manifest, &weights)?;
//!     // 3. run it, batch-first: one image in channel-major planes
//!     let image = vec![0.0; pipeline.in_dim()];
//!     let logits = pipeline.forward_batch(&[image])?;
//!     println!("predicted class {}", memx::pipeline::argmax(&logits[0]));
//!     Ok(())
//! }
//! ```
//!
//! # Fidelity levels
//!
//! * [`Fidelity::Ideal`] — exact quantized-weight arithmetic: crossbars via
//!   [`Crossbar::eval_ideal`](crate::mapper::Crossbar::eval_ideal),
//!   activations via the software functions. The digital reference for the
//!   mapped network.
//! * [`Fidelity::Behavioural`] — the analog operating point the L2 JAX
//!   model uses: the same crossbar arithmetic with TIA rail saturation, and
//!   the rail-clipped activation forms.
//! * [`Fidelity::Spice`] — circuit-level: every crossbar owns a resident
//!   [`CrossbarSim`](crate::netlist::CrossbarSim) (factor-once / solve-many,
//!   batches amortized over one multi-RHS substitution per segment via
//!   [`CrossbarSim::solve_batch`](crate::netlist::CrossbarSim::solve_batch)),
//!   and hard-sigmoid / hard-swish run through their Fig 4 op-amp circuits
//!   ([`ActCircuit`](crate::analog::ActCircuit)).
//!
//! Data layout between modules: spatial tensors travel as channel-major
//! planes `[c][h*w]` (row-major within a plane); vectors are plain `[c]`.
//! [`image_to_input`] converts the dataset's HWC images.

pub mod builder;
pub mod modules;

use anyhow::{bail, Result};

pub use builder::{default_device, synthetic_stack_crossbars, PipelineBuilder};
pub use modules::{ActivationModule, BatchNormModule, CrossbarModule, GapModule, SeModule};

/// Execution fidelity of a compiled [`Pipeline`] (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// exact quantized-weight arithmetic, software activations
    Ideal,
    /// rail-clipped analog behavioural models (the L2 operating point)
    Behavioural,
    /// resident SPICE simulators per crossbar + Fig 4 activation circuits
    Spice,
}

impl std::str::FromStr for Fidelity {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Fidelity> {
        match s {
            "ideal" => Ok(Fidelity::Ideal),
            "behavioural" | "behavioral" => Ok(Fidelity::Behavioural),
            "spice" => Ok(Fidelity::Spice),
            other => bail!("unknown fidelity '{other}' (ideal|behavioural|spice)"),
        }
    }
}

impl std::fmt::Display for Fidelity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Fidelity::Ideal => "ideal",
            Fidelity::Behavioural => "behavioural",
            Fidelity::Spice => "spice",
        })
    }
}

/// One analog stage of the paper's module chain. Implementations own their
/// device state (crossbars, resident simulators, activation circuits) and
/// answer whole batches per call — the batch-first contract the serving
/// tier scales on.
pub trait AnalogModule {
    /// Layer name (manifest name or a synthetic label).
    fn name(&self) -> &str;

    /// Table 4 kind label ("Conv", "BN", "HSwish", "GAPool", "FC", ...).
    fn kind(&self) -> &'static str;

    /// Input vector length this module expects.
    fn in_dim(&self) -> usize;

    /// Output vector length this module produces.
    fn out_dim(&self) -> usize;

    /// Forward a batch of input vectors (each of length [`Self::in_dim`]).
    /// At [`Fidelity::Spice`] this is where the multi-RHS batch
    /// amortization happens — one factorization, one substitution pass per
    /// crossbar segment for the whole batch.
    fn forward_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>>;

    /// Single-vector convenience — `forward_batch` of a batch of one.
    fn forward(&mut self, x: &[f64]) -> Result<Vec<f64>> {
        let batch = [x.to_vec()];
        let mut out = self.forward_batch(&batch)?;
        out.pop().ok_or_else(|| anyhow::anyhow!("module returned an empty batch"))
    }

    /// Physically placed memristors (resource hook; Table 4 column).
    fn memristors(&self) -> usize {
        0
    }

    /// Op-amps (resource hook; Table 4 column).
    fn opamps(&self) -> usize {
        0
    }

    /// Memristor-crossbar stages this module contributes to the critical
    /// path (Eq 17 N_m). Composite modules may contribute several.
    fn memristor_stages(&self) -> usize {
        0
    }
}

/// One stage of a compiled [`Pipeline`].
pub enum Stage {
    /// A paper module, tagged with the manifest unit it belongs to.
    Module { unit: String, module: Box<dyn AnalogModule> },
    /// The residual summing amplifier closing a bottleneck unit: adds the
    /// vector that entered the unit (MobileNetV3 skip semantics — stride 1,
    /// matching channels). `dim` is the full vector length; `channels`
    /// counts the per-channel summing amplifiers (the mapper's "Add" row).
    Residual { name: String, unit: String, dim: usize, channels: usize },
}

impl Stage {
    fn unit(&self) -> &str {
        match self {
            Stage::Module { unit, .. } | Stage::Residual { unit, .. } => unit,
        }
    }
}

/// A runnable analog network: the paper's module chain compiled by
/// [`PipelineBuilder`], with end-to-end [`Pipeline::forward_batch`] /
/// [`Pipeline::classify_batch`].
pub struct Pipeline {
    stages: Vec<Stage>,
    /// `checkpoint[i]`: snapshot the batch before stage `i` — set on the
    /// first stage of every unit that ends in a residual adder, so
    /// `forward_batch` only clones where a skip connection consumes it.
    checkpoint: Vec<bool>,
    fidelity: Fidelity,
    in_dim: usize,
    out_dim: usize,
}

impl Pipeline {
    /// Assemble a pipeline from explicit stages, validating that every
    /// module's input length matches its predecessor's output.
    pub fn from_stages(stages: Vec<Stage>, fidelity: Fidelity) -> Result<Pipeline> {
        let mut dims: Option<(usize, usize)> = None; // (in, current)
        for s in &stages {
            match s {
                Stage::Module { module, .. } => {
                    let (input, cur) = match dims {
                        None => (module.in_dim(), module.in_dim()),
                        Some(d) => d,
                    };
                    if module.in_dim() != cur {
                        bail!(
                            "stage '{}' ({}) expects {} inputs, previous stage produces {}",
                            module.name(),
                            module.kind(),
                            module.in_dim(),
                            cur
                        );
                    }
                    dims = Some((input, module.out_dim()));
                }
                Stage::Residual { name, dim, .. } => {
                    let Some((input, cur)) = dims else {
                        bail!("residual '{name}' cannot be the first stage");
                    };
                    if *dim != cur {
                        bail!("residual '{name}' expects {dim} inputs, previous stage produces {cur}");
                    }
                    dims = Some((input, cur));
                }
            }
        }
        let Some((in_dim, out_dim)) = dims else {
            bail!("pipeline needs at least one module");
        };
        // mark the first stage of each residual-closing unit for checkpoint
        let mut checkpoint = vec![false; stages.len()];
        for (i, s) in stages.iter().enumerate() {
            if let Stage::Residual { unit, .. } = s {
                let mut first = i;
                while first > 0 && stages[first - 1].unit() == unit {
                    first -= 1;
                }
                checkpoint[first] = true;
            }
        }
        Ok(Pipeline { stages, checkpoint, fidelity, in_dim, out_dim })
    }

    /// Assemble a single-unit pipeline from bare modules.
    pub fn from_modules(
        modules: Vec<Box<dyn AnalogModule>>,
        fidelity: Fidelity,
    ) -> Result<Pipeline> {
        let stages = modules
            .into_iter()
            .map(|module| Stage::Module { unit: "main".into(), module })
            .collect();
        Self::from_stages(stages, fidelity)
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    pub fn fidelity(&self) -> Fidelity {
        self.fidelity
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Total placed memristors across all stages (Table 4 bottom row).
    pub fn memristors(&self) -> usize {
        self.stages
            .iter()
            .map(|s| match s {
                Stage::Module { module, .. } => module.memristors(),
                Stage::Residual { .. } => 0,
            })
            .sum()
    }

    /// Total op-amps across all stages (residual adders count one summing
    /// amplifier per channel, as in the mapper).
    pub fn opamps(&self) -> usize {
        self.stages
            .iter()
            .map(|s| match s {
                Stage::Module { module, .. } => module.opamps(),
                Stage::Residual { channels, .. } => *channels,
            })
            .sum()
    }

    /// Memristor-crossbar stages on the critical path (Eq 17 N_m).
    pub fn memristor_stages(&self) -> usize {
        self.stages
            .iter()
            .map(|s| match s {
                Stage::Module { module, .. } => module.memristor_stages(),
                Stage::Residual { .. } => 0,
            })
            .sum()
    }

    /// One-line summary for logs and demos.
    pub fn describe(&self) -> String {
        format!(
            "{} stages ({} fidelity), {} -> {} dims, {} memristors / {} op-amps / N_m {}",
            self.n_stages(),
            self.fidelity,
            self.in_dim,
            self.out_dim,
            self.memristors(),
            self.opamps(),
            self.memristor_stages()
        )
    }

    /// End-to-end batched inference: every stage answers the whole batch
    /// before the next begins, so each crossbar read is one multi-RHS
    /// substitution pass per segment at [`Fidelity::Spice`].
    pub fn forward_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        for (k, x) in inputs.iter().enumerate() {
            if x.len() != self.in_dim {
                bail!("input {k} has {} values, pipeline expects {}", x.len(), self.in_dim);
            }
        }
        let mut cur: Vec<Vec<f64>> = inputs.to_vec();
        // the batch entering the current residual-closing unit (cloned only
        // at stages `from_stages` marked — units without a skip pay nothing)
        let mut unit_input: Vec<Vec<f64>> = Vec::new();
        for (idx, stage) in self.stages.iter_mut().enumerate() {
            if self.checkpoint[idx] {
                unit_input = cur.clone();
            }
            match stage {
                Stage::Module { module, .. } => {
                    cur = module.forward_batch(&cur)?;
                }
                Stage::Residual { name, dim, .. } => {
                    if unit_input.len() != cur.len() {
                        bail!(
                            "residual '{name}': {} checkpointed inputs for a batch of {}",
                            unit_input.len(),
                            cur.len()
                        );
                    }
                    for (y, x0) in cur.iter_mut().zip(&unit_input) {
                        if y.len() != *dim || x0.len() != *dim {
                            bail!(
                                "residual '{name}': {} outputs vs {} unit inputs (expected {dim})",
                                y.len(),
                                x0.len()
                            );
                        }
                        for (a, b) in y.iter_mut().zip(x0) {
                            *a += b;
                        }
                    }
                }
            }
        }
        Ok(cur)
    }

    /// Single-vector forward — a batch of one.
    pub fn forward(&mut self, x: &[f64]) -> Result<Vec<f64>> {
        let batch = [x.to_vec()];
        let mut out = self.forward_batch(&batch)?;
        out.pop().ok_or_else(|| anyhow::anyhow!("pipeline returned an empty batch"))
    }

    /// Batched classification: forward then per-row argmax.
    pub fn classify_batch(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<usize>> {
        Ok(self.forward_batch(inputs)?.iter().map(|row| argmax(row)).collect())
    }
}

/// Index of the largest logit (0 for an empty slice).
pub fn argmax(v: &[f64]) -> usize {
    let mut best = (0usize, f64::NEG_INFINITY);
    for (i, &x) in v.iter().enumerate() {
        if x > best.1 {
            best = (i, x);
        }
    }
    best.0
}

/// Convert one dataset image (HWC row-major, the PJRT/NHWC layout) into the
/// pipeline's channel-major planes `[c][h*w]`.
pub fn image_to_input(img: &[f32], h: usize, w: usize, c: usize) -> Vec<f64> {
    assert_eq!(img.len(), h * w * c, "image length != h*w*c");
    let mut v = vec![0.0; h * w * c];
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                v[ch * h * w + y * w + x] = img[(y * w + x) * c + ch] as f64;
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fidelity_fromstr_display_roundtrip() {
        for f in [Fidelity::Ideal, Fidelity::Behavioural, Fidelity::Spice] {
            let parsed: Fidelity = f.to_string().parse().unwrap();
            assert_eq!(parsed, f);
        }
        assert_eq!("behavioral".parse::<Fidelity>().unwrap(), Fidelity::Behavioural);
        assert!("fast".parse::<Fidelity>().is_err());
    }

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[-5.0, -1.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn image_to_input_channel_major() {
        // 1x2 image, 2 channels: HWC [p0c0, p0c1, p1c0, p1c1]
        let img = [1.0f32, 10.0, 2.0, 20.0];
        let v = image_to_input(&img, 1, 2, 2);
        assert_eq!(v, vec![1.0, 2.0, 10.0, 20.0]);
    }

    #[test]
    fn empty_pipeline_rejected() {
        assert!(Pipeline::from_modules(Vec::new(), Fidelity::Ideal).is_err());
    }
}
