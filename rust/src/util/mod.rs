//! Support utilities hand-rolled for the offline environment: JSON codec,
//! PRNG, binary artifact IO, scoped thread pool, CLI flags, bench and
//! property-test harnesses (serde/rand/rayon/clap/criterion/proptest are not
//! in the image's offline crate cache — DESIGN.md §4 S17).
pub mod bench;
pub mod bin;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prng;
pub mod prop;

/// Row-major argmax over `classes`-wide logit rows (first maximum wins —
/// the same tie convention as [`crate::pipeline::argmax`]). Shared by the
/// PJRT runtime and the serving coordinator.
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<usize> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            let mut best = (0usize, f32::NEG_INFINITY);
            for (i, &v) in row.iter().enumerate() {
                if v > best.1 {
                    best = (i, v);
                }
            }
            best.0
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_rows_picks_first_max_per_row() {
        assert_eq!(argmax_rows(&[0.1, 0.9, 0.8, 0.2], 2), vec![1, 0]);
        assert_eq!(argmax_rows(&[1.0, 1.0, 0.5], 3), vec![0], "first max wins ties");
        assert!(argmax_rows(&[], 4).is_empty());
    }
}
