//! memx — memristor crossbar computing paradigm for MobileNetV3.
//!
//! Reproduction of "A Novel Computing Paradigm for MobileNetV3 using
//! Memristor" (Li et al., 2024). Three-layer architecture (DESIGN.md):
//! JAX/Pallas analog model AOT-compiled to HLO artifacts, executed from this
//! rust coordinator via PJRT; the paper's automated mapping framework
//! (crossbar layout -> SPICE netlists -> MNA simulation) lives here too,
//! unified behind the trait-based [`pipeline`] inference API (manifest ->
//! analog module chain -> batched crossbar logits, with the §5.2 pipelined
//! stage scheduler) and served through the backend-agnostic
//! [`coordinator`] queue (`InferenceExecutor`: analog pipeline offline,
//! PJRT engine under `runtime-xla`).
pub mod analog;
pub mod backend;
pub mod coordinator;
pub mod dataset;
pub mod fault;
pub mod mapper;
pub mod netlist;
pub mod nn;
pub mod pipeline;
pub mod power;
pub mod report;
/// PJRT runtime — requires the `runtime-xla` feature (the `xla` crate +
/// libxla_extension are not in the offline crate cache; see Cargo.toml).
#[cfg(feature = "runtime-xla")]
pub mod runtime;
pub mod spice;
pub mod telemetry;
pub mod util;
